"""Paper Table 3: lossless retrieval across N, k, r, and embedding dim.

The paper reports 100% recall for every setting; this benchmark sweeps the
same axes (reduced sizes by default; REPRO_BENCH_FULL=1 runs N up to 1e5 and
all dims incl. 1536/3072) and reports measured recall of the full protocol
against the plaintext oracle, on uniform AND clustered corpora (the latter
violates Lemma 1's assumption — the adversarial case).
"""

from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import FULL, emit
from repro.core import protocol
from repro.data import synth
from repro.retrieval.index import FlatIndex


def _recall_once(emb, index, user, cloud, q, k, key):
    _, ids, _ = protocol.run_remoterag(user, cloud, q, key)
    want = np.argsort(-(emb @ q), kind="stable")[:k]
    return len(set(ids.tolist()) & set(want.tolist())) / k


def run() -> None:
    rng = np.random.default_rng(0)
    ns = ([10_000, 100_000] if FULL else [2_000, 8_000])
    ks = [5, 10, 15, 20]
    rs = [0.03, 0.05, 0.07, 0.1]
    dims = [384, 768, 1536, 3072] if FULL else [384, 768]
    trials = 5 if FULL else 2

    for corpus_kind in ("uniform", "clustered"):
        gen = (synth.uniform_corpus if corpus_kind == "uniform"
               else synth.clustered_corpus)
        # N sweep (k=5, r=0.05, dim=384)
        for N in ns:
            emb = gen(rng, N, 384)
            index = FlatIndex.build(emb)
            index.documents = [b""] * N
            user = protocol.RemoteRagUser(n=384, N=N, k=5, radius=0.05,
                                          backend="rlwe", rng=rng)
            cloud = protocol.RemoteRagCloud(index,
                                            rlwe_params=user.rlwe_params)
            qs = synth.queries_near_corpus(rng, emb, trials)
            rec = np.mean([
                _recall_once(emb, index, user, cloud, q, 5,
                             jax.random.PRNGKey(i))
                for i, q in enumerate(qs)])
            emit(f"table3/{corpus_kind}/N{N}", 0.0,
                 f"recall={rec:.3f};kprime={user.plan.kprime}")

        # k and r sweeps on a fixed corpus
        N = ns[0]
        emb = gen(rng, N, 384)
        index = FlatIndex.build(emb)
        index.documents = [b""] * N
        qs = synth.queries_near_corpus(rng, emb, trials)
        for k in ks:
            user = protocol.RemoteRagUser(n=384, N=N, k=k, radius=0.05,
                                          backend="rlwe", rng=rng)
            cloud = protocol.RemoteRagCloud(index,
                                            rlwe_params=user.rlwe_params)
            rec = np.mean([
                _recall_once(emb, index, user, cloud, q, k,
                             jax.random.PRNGKey(10 + i))
                for i, q in enumerate(qs)])
            emit(f"table3/{corpus_kind}/k{k}", 0.0,
                 f"recall={rec:.3f};kprime={user.plan.kprime}")
        for r in rs:
            user = protocol.RemoteRagUser(n=384, N=N, k=5, radius=r,
                                          backend="rlwe", rng=rng)
            cloud = protocol.RemoteRagCloud(index,
                                            rlwe_params=user.rlwe_params)
            rec = np.mean([
                _recall_once(emb, index, user, cloud, q, 5,
                             jax.random.PRNGKey(20 + i))
                for i, q in enumerate(qs)])
            emit(f"table3/{corpus_kind}/r{r}", 0.0,
                 f"recall={rec:.3f};kprime={user.plan.kprime}")

    # dim sweep (uniform)
    for dim in dims:
        N = ns[0]
        emb = synth.uniform_corpus(rng, N, dim)
        index = FlatIndex.build(emb)
        index.documents = [b""] * N
        user = protocol.RemoteRagUser(n=dim, N=N, k=5, radius=0.05,
                                      backend="rlwe", rng=rng)
        cloud = protocol.RemoteRagCloud(index, rlwe_params=user.rlwe_params)
        qs = synth.queries_near_corpus(rng, emb, trials)
        rec = np.mean([
            _recall_once(emb, index, user, cloud, q, 5,
                         jax.random.PRNGKey(30 + i))
            for i, q in enumerate(qs)])
        emit(f"table3/uniform/dim{dim}", 0.0,
             f"recall={rec:.3f};kprime={user.plan.kprime}")
