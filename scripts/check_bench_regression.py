#!/usr/bin/env python3
"""CI gate on the encrypted re-rank perf trajectory.

Reads BENCH_rlwe.json (written by ``python -m benchmarks.run --only rlwe``)
and fails if cached scoring is not faster than cold per-request packing at
any recorded batch size.

    scripts/check_bench_regression.py [BENCH_rlwe.json] [min_speedup=1.0]
"""

from __future__ import annotations

import json
import sys


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_rlwe.json"
    min_speedup = float(sys.argv[2]) if len(sys.argv) > 2 else 1.0
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:   # missing file or truncated JSON
        print(f"FAIL: cannot read {path}: {e}", file=sys.stderr)
        return 2
    results = data.get("results", {})
    if not results:
        print(f"FAIL: {path} has no results", file=sys.stderr)
        return 2
    failures = 0
    for name in sorted(results):
        row = results[name]
        speedup = row.get("speedup_cached_vs_cold")
        if speedup is None or speedup < min_speedup:
            print(f"FAIL {name}: cached speedup {speedup} < {min_speedup} "
                  f"(cold {row.get('cold_pack_us')}us, "
                  f"cached {row.get('cached_us')}us)", file=sys.stderr)
            failures += 1
        else:
            print(f"ok   {name}: cached {speedup:.2f}x faster than cold "
                  f"({row.get('cached_us'):.0f}us vs "
                  f"{row.get('cold_pack_us'):.0f}us)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
