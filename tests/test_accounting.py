"""Table 2 formulas + wire-size models."""

import pytest

from repro.core import accounting as acc


def test_table2_formulas():
    n, N, k, kp = 768, 10**5, 5, 160
    ig = acc.privacy_ignorant(n, k)
    assert (ig.rounds, ig.numbers, ig.documents) == (1.0, n, k)
    co = acc.privacy_conscious(n, N)
    assert (co.rounds, co.numbers, co.documents) == (2.0, n + 2 * N + 1, N)
    di = acc.remoterag_direct(n, k, kp)
    assert (di.rounds, di.numbers, di.documents) == (2.5, 2 * n + k + kp + 1, k)
    ot = acc.remoterag_ot(n, kp)
    assert (ot.rounds, ot.numbers, ot.documents) == (3.0, 2 * (n + kp + 1), kp)


def test_remoterag_beats_conscious_by_orders_of_magnitude():
    n, N, k, kp = 768, 10**6, 5, 160
    conscious = acc.privacy_conscious(n, N).bytes_total()
    direct = acc.remoterag_direct(n, k, kp).bytes_total()
    assert conscious / direct > 10_000  # paper: 1.43 GB vs 46.66 KB


def test_optimized_rounds():
    c = acc.optimized_rounds(acc.remoterag_ot(768, 160))
    assert c.rounds == 2.0


def test_backend_wire_models():
    # Paillier query: n ciphertexts; RLWE query: ceil(n/1024) ciphertexts.
    assert acc.paillier_query_bytes(768) == 768 * 512
    assert acc.rlwe_query_bytes(768) == 1 * 2 * 3 * 4096 * 20 // 8
    assert acc.rlwe_query_bytes(3072) == 3 * 2 * 3 * 4096 * 20 // 8
    # RLWE response packs 4 candidates/ct at n<=1024, 2 at n>1024.
    one_ct = 2 * 3 * 4096 * 20 // 8
    assert acc.rlwe_scores_bytes(160, 768) == 40 * one_ct
    assert acc.rlwe_scores_bytes(160, 1536) == 80 * one_ct
    # RLWE query upload is smaller than Paillier's for n = 768
    assert acc.rlwe_query_bytes(768) < acc.paillier_query_bytes(768)


def test_paper_headline_numbers_consistent():
    """46.66 KB (direct) at k'=160: formula bytes in the right ballpark with
    beta=4B numbers and ~230B documents (paper's eta differs; order check)."""
    di = acc.remoterag_direct(768, 5, 160)
    assert 10_000 < di.bytes_total(beta=4, eta=1024) < 100_000
