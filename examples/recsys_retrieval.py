"""Private candidate retrieval for the two-tower recsys arch.

    PYTHONPATH=src python examples/recsys_retrieval.py

two-tower-retrieval is the RemoteRAG-native assigned architecture: its
candidate index is a unit-norm embedding corpus, so the paper's protocol
wraps it unchanged.  The "user query" here is the *user tower output* —
exactly the sensitive object (someone's taste vector) the paper protects.

1. train the reduced two-tower model briefly on the synthetic click task,
2. index the item-tower embeddings,
3. run private retrieval of the user's top-k items.
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import protocol
from repro.models import recsys as rec
from repro.retrieval.index import FlatIndex
from repro.train import optimizer as opt_lib
from repro.train import trainer

N_ITEMS = 4_000
K = 5


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = registry.get("two-tower-retrieval").reduced
    params = rec.twotower_init(jax.random.PRNGKey(0), cfg)

    # brief training on in-batch softmax (synthetic co-click pairs)
    opt_cfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=50)
    opt_state = opt_lib.init(params, opt_cfg)
    step = jax.jit(trainer.make_train_step(
        lambda p, u, i: rec.twotower_loss(p, cfg, u, i), opt_cfg))
    for s in range(50):
        srng = np.random.default_rng(s)
        uf = jnp.asarray(srng.integers(0, cfg.user_vocab, (64, cfg.n_user_feats)))
        itf = jnp.asarray(uf[:, : cfg.n_item_feats] % cfg.item_vocab)  # co-click
        params, opt_state, m = step(params, opt_state, (uf, itf))
    print(f"two-tower trained 50 steps, final in-batch loss {float(m['loss']):.3f}")

    # index the item corpus
    item_feats = jnp.asarray(
        rng.integers(0, cfg.item_vocab, (N_ITEMS, cfg.n_item_feats)))
    item_embs = np.asarray(rec.item_embedding(params, cfg, item_feats))
    index = FlatIndex.build(
        item_embs, documents=[f"item-{i}".encode() for i in range(N_ITEMS)])

    # the private query = user tower output
    dim = item_embs.shape[1]
    user_feats = jnp.asarray(rng.integers(0, cfg.user_vocab,
                                          (1, cfg.n_user_feats)))
    taste = np.asarray(rec.user_embedding(params, cfg, user_feats))[0]

    user = protocol.RemoteRagUser(n=dim, N=N_ITEMS, k=K, radius=0.1,
                                  backend="rlwe", rng=rng)
    cloud = protocol.RemoteRagCloud(index, rlwe_params=user.rlwe_params)
    items, ids, tr = protocol.run_remoterag(user, cloud, taste,
                                            jax.random.PRNGKey(1))

    oracle = np.argsort(-(item_embs @ taste), kind="stable")[:K]
    recall = len(set(ids.tolist()) & set(oracle.tolist())) / K
    print(f"private retrieval: items={[d.decode() for d in items]}")
    print(f"recall vs plaintext ranking: {recall:.0%}  "
          f"k'={user.plan.kprime}  wire={tr.total_bytes/1024:.1f} KB")
    assert recall == 1.0


if __name__ == "__main__":
    main()
