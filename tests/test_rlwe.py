"""RNS-RLWE additive HE: roundtrip, homomorphism, packed inner products."""

import numpy as np
import pytest

from repro.crypto import rlwe


def _unit(rng, *shape):
    x = rng.normal(size=shape)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


@pytest.fixture(scope="module")
def small_params():
    return rlwe.RlweParams(n_poly=1024, chunk=512)


@pytest.fixture(scope="module")
def default_params():
    return rlwe.RlweParams()  # N=4096, chunk=1024


def test_params_validate():
    rlwe.RlweParams()  # should not raise
    with pytest.raises(AssertionError):
        rlwe.RlweParams(scale_q_bits=16, scale_c_bits=16, t_bits=28)


def test_encrypted_dot_small_dim(small_params):
    rng = np.random.default_rng(0)
    sk = rlwe.keygen(small_params, rng)
    n_dim = 384
    q = _unit(rng, n_dim)
    cands = _unit(rng, 9, n_dim)  # not a multiple of cands_per_ct (=2)
    ct = rlwe.encrypt_query(sk, q, rng)
    packed = rlwe.pack_candidates(small_params, cands)
    res = rlwe.encrypted_scores(small_params, ct, packed)
    got = rlwe.decrypt_scores(sk, res)
    want = cands @ q
    np.testing.assert_allclose(got, want, atol=2e-3)


@pytest.mark.parametrize("n_dim", [384, 768, 1536, 3072])
def test_encrypted_dot_all_paper_dims(default_params, n_dim):
    """All five embedding-model dimensions from the paper (Table 5)."""
    rng = np.random.default_rng(1)
    sk = rlwe.keygen(default_params, rng)
    q = _unit(rng, n_dim)
    cands = _unit(rng, 8, n_dim)
    ct = rlwe.encrypt_query(sk, q, rng)
    packed = rlwe.pack_candidates(default_params, cands)
    got = rlwe.decrypt_scores(sk, rlwe.encrypted_scores(default_params, ct, packed))
    np.testing.assert_allclose(got, cands @ q, atol=2e-3)


def test_ranking_preserved_vs_plaintext(default_params):
    """The encrypted path must reproduce the exact plaintext top-k ranking."""
    rng = np.random.default_rng(2)
    sk = rlwe.keygen(default_params, rng)
    n_dim, k_prime = 768, 64
    q = _unit(rng, n_dim)
    cands = _unit(rng, k_prime, n_dim)
    ct = rlwe.encrypt_query(sk, q, rng)
    packed = rlwe.pack_candidates(default_params, cands)
    got = rlwe.decrypt_scores(sk, rlwe.encrypted_scores(default_params, ct, packed))
    want_order = np.argsort(-(cands @ q))[:5]
    got_order = np.argsort(-got)[:5]
    np.testing.assert_array_equal(got_order, want_order)


def test_distances_match_theorem2(default_params):
    rng = np.random.default_rng(3)
    sk = rlwe.keygen(default_params, rng)
    q = _unit(rng, 384)
    cands = _unit(rng, 4, 384)
    ct = rlwe.encrypt_query(sk, q, rng)
    got = rlwe.cosine_distances(
        rlwe.decrypt_scores(
            sk, rlwe.encrypted_scores(default_params, ct,
                                      rlwe.pack_candidates(default_params, cands))))
    want = 1.0 - cands @ q
    np.testing.assert_allclose(got, want, atol=2e-3)
    # Theorem 2: d_l2 = sqrt(2 d_cos)
    np.testing.assert_allclose(
        np.linalg.norm(cands - q, axis=-1), np.sqrt(2 * want), rtol=1e-6)


def test_additive_homomorphism(small_params):
    """dec(enc(x) + enc(y)) scores == <x+y, c> via two queries' ciphertext sum."""
    rng = np.random.default_rng(4)
    sk = rlwe.keygen(small_params, rng)
    x = _unit(rng, 256)
    y = _unit(rng, 256)
    cands = _unit(rng, 4, 256)
    cx = rlwe.encrypt_query(sk, x, rng)
    cy = rlwe.encrypt_query(sk, y, rng)
    import jax.numpy as jnp
    qmods = np.array(small_params.primes, np.int64)[None, :, None]
    c0 = (np.asarray(cx.c0).astype(np.int64) + np.asarray(cy.c0)) % qmods
    c1 = (np.asarray(cx.c1).astype(np.int64) + np.asarray(cy.c1)) % qmods
    summed = rlwe.QueryCiphertext(jnp.asarray(c0.astype(np.int32)),
                                  jnp.asarray(c1.astype(np.int32)), 256)
    packed = rlwe.pack_candidates(small_params, cands)
    got = rlwe.decrypt_scores(
        sk, rlwe.encrypted_scores(small_params, summed, packed))
    np.testing.assert_allclose(got, cands @ (x + y), atol=4e-3)


def test_ciphertext_indistinguishable_without_key(small_params):
    """Same query under fresh randomness yields different ciphertexts whose
    difference is full-range — a basic sanity check, not a security proof."""
    rng = np.random.default_rng(5)
    sk = rlwe.keygen(small_params, rng)
    q = _unit(rng, 256)
    c1 = rlwe.encrypt_query(sk, q, rng)
    c2 = rlwe.encrypt_query(sk, q, rng)
    diff = np.asarray(c1.c0).astype(np.int64) - np.asarray(c2.c0).astype(np.int64)
    assert np.std(diff) > small_params.primes[0] / 10


def test_wire_size_accounting(default_params):
    b = default_params.ciphertext_bytes()
    assert b == 2 * 3 * 4096 * 20 // 8  # 61,440 B per ciphertext
