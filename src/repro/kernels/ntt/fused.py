"""Fused Pallas kernel for the cached encrypted re-rank hot path.

One ``pallas_call`` per RNS prime computes, for every (batch lane, result
ciphertext) grid cell, both NTT-domain accumulators of the cloud's ct (x) p:

    acc_z = sum_{s < cpt} sum_{c < chunks}  tw[s] . polys[s, c] . f_z[c]
                                                              (z in {0, 1})

where ``polys`` are the candidate-cache plaintexts (slot-0 packing, already
in the NTT domain), ``tw[s]`` is the NTT-domain diagonal of the monomial
X^{s*stride} (realizing the candidate's slot offset as a pointwise twiddle
rotate instead of a host repack + forward NTT), and ``f_z`` are the forward
NTTs of the query ciphertext components.  The old composition issued one
dispatch per (rotate, Hadamard, mod-add) stage; here rotate -> Hadamard(c0,
c1) -> slot/chunk accumulation run on a single VMEM-resident tile — one HBM
read of the gathered cache rows and one HBM write of the two accumulators.

Everything is int32: products are Barrett-reduced to [0, q), and the final
slot/chunk sum accumulates raw (cpt*chunks terms * q < 2^31, asserted) and
is reduced once — bit-identical to a chain of mod_add.

Two variants share the rotate/Hadamard/accumulate body:

  * `fused_rerank_pallas`       — NTT-domain accumulators out (the inverse
                                  NTT stays in the separate `ntt_pallas`
                                  dispatch; kept for staged comparisons).
  * `fused_rerank_intt_pallas`  — additionally absorbs the per-prime inverse
                                  NTT: the (acc0, acc1) pair of a grid cell
                                  is a (2, N) tile that runs the exact
                                  `inv_butterflies` network of the standalone
                                  kernel before leaving VMEM, so the result
                                  ciphertext components come out in the
                                  coefficient domain with no extra HBM
                                  round-trip.  This is the ROADMAP-named
                                  batch-8 bottleneck fix: cached scoring is
                                  Hadamard/iNTT-bound once packing is hoisted
                                  into the candidate cache.  The per-prime
                                  results are stacked into the RNS (CRT)
                                  ciphertext layout inside the same jit; the
                                  bignum CRT *lift* itself stays host-side at
                                  decryption — big_q ~ 2^60 cannot live in
                                  int32 lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.crypto import modring
from repro.crypto.modring import PrimeCtx
from repro.kernels.ntt import ntt as _ntt


def _accumulate(polys_ref, tw_ref, f0_ref, f1_ref, *, q: int, mu: int,
                cpt: int, chunks: int):
    """Shared kernel body: twiddle rotate -> Hadamard(c0, c1) -> raw-sum ->
    one Barrett reduction.  Returns a (2, n) tile [acc0; acc1] in [0, q)."""
    n = polys_ref.shape[-1]
    g = polys_ref[...].reshape(cpt, chunks, n)
    tw = tw_ref[...]                                    # (cpt, n)
    f0 = f0_ref[...].reshape(chunks, n)
    f1 = f1_ref[...].reshape(chunks, n)
    rot = modring.mod_mul(g, tw[:, None, :], q, mu)     # slot twiddle rotate
    p0 = modring.mod_mul(rot, f0[None], q, mu).reshape(cpt * chunks, n)
    p1 = modring.mod_mul(rot, f1[None], q, mu).reshape(cpt * chunks, n)
    return jnp.stack([
        modring.barrett_reduce(jnp.sum(p0, axis=0), q, mu),
        modring.barrett_reduce(jnp.sum(p1, axis=0), q, mu)])


def _fused_kernel(polys_ref, tw_ref, f0_ref, f1_ref, o0_ref, o1_ref, *,
                  q: int, mu: int, cpt: int, chunks: int):
    n = polys_ref.shape[-1]
    acc = _accumulate(polys_ref, tw_ref, f0_ref, f1_ref, q=q, mu=mu,
                      cpt=cpt, chunks=chunks)
    o0_ref[...] = acc[0].reshape(1, 1, n)
    o1_ref[...] = acc[1].reshape(1, 1, n)


def _fused_intt_kernel(polys_ref, tw_ref, f0_ref, f1_ref, ipsi_ref,
                       o0_ref, o1_ref, *, q: int, mu: int, cpt: int,
                       chunks: int, n_inv: int):
    n = polys_ref.shape[-1]
    acc = _accumulate(polys_ref, tw_ref, f0_ref, f1_ref, q=q, mu=mu,
                      cpt=cpt, chunks=chunks)
    # absorb the inverse NTT: the (2, n) accumulator tile runs the exact
    # butterfly network of the standalone kernel while still VMEM-resident
    out = _ntt.inv_butterflies(acc, ipsi_ref[...], q=q, mu=mu, n=n,
                               n_inv=n_inv)
    o0_ref[...] = out[0].reshape(1, 1, n)
    o1_ref[...] = out[1].reshape(1, 1, n)


@functools.partial(jax.jit, static_argnames=("ctx", "interpret"))
def fused_rerank_pallas(polys, tw, f0, f1, ctx: PrimeCtx, *,
                        interpret: bool = True):
    """Rotate -> Hadamard(c0, c1) -> slot/chunk mod-sum for one prime.

    polys: (B, num_ct, cpt*chunks, N) gathered cache rows, slot-major;
    tw: (cpt, N) monomial twiddles; f0/f1: (B, chunks, N) query NTTs.
    Returns (acc0, acc1), each (B, num_ct, N) int32 in [0, q).
    """
    bsz, num_ct, rows, n = polys.shape
    cpt, chunks = tw.shape[0], f0.shape[1]
    assert rows == cpt * chunks, (rows, cpt, chunks)
    assert n == ctx.n and f0.shape == f1.shape == (bsz, chunks, n)
    assert rows * (ctx.q - 1) < 2**31, "int32 accumulator would wrap"
    kern = functools.partial(_fused_kernel, q=ctx.q, mu=ctx.mu,
                             cpt=cpt, chunks=chunks)
    out = jax.ShapeDtypeStruct((bsz, num_ct, n), jnp.int32)
    return pl.pallas_call(
        kern,
        grid=(bsz, num_ct),
        in_specs=[
            pl.BlockSpec((1, 1, rows, n), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((cpt, n), lambda b, t: (0, 0)),
            pl.BlockSpec((1, chunks, n), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, chunks, n), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, 1, n), lambda b, t: (b, t, 0)),
                   pl.BlockSpec((1, 1, n), lambda b, t: (b, t, 0))],
        out_shape=[out, out],
        interpret=interpret,
    )(polys, tw, f0, f1)


@functools.partial(jax.jit, static_argnames=("ctx", "interpret"))
def fused_rerank_intt_pallas(polys, tw, f0, f1, ctx: PrimeCtx, *,
                             interpret: bool = True):
    """Rotate -> Hadamard(c0, c1) -> slot/chunk mod-sum -> inverse NTT for
    one prime, in a single kernel.

    Same contract as `fused_rerank_pallas` but the returned (acc0, acc1)
    are in the *coefficient* domain: each grid cell's accumulator pair is
    inverse-NTT'd as a (2, N) tile before it leaves VMEM (the exact
    `inv_butterflies` network of `ntt_pallas`, so outputs are bit-identical
    to fused_rerank_pallas followed by the standalone inverse NTT).
    """
    bsz, num_ct, rows, n = polys.shape
    cpt, chunks = tw.shape[0], f0.shape[1]
    assert rows == cpt * chunks, (rows, cpt, chunks)
    assert n == ctx.n and f0.shape == f1.shape == (bsz, chunks, n)
    assert rows * (ctx.q - 1) < 2**31, "int32 accumulator would wrap"
    kern = functools.partial(_fused_intt_kernel, q=ctx.q, mu=ctx.mu,
                             cpt=cpt, chunks=chunks, n_inv=ctx.n_inv)
    out = jax.ShapeDtypeStruct((bsz, num_ct, n), jnp.int32)
    ipsi = jnp.asarray(ctx.ipsi_table)
    return pl.pallas_call(
        kern,
        grid=(bsz, num_ct),
        in_specs=[
            pl.BlockSpec((1, 1, rows, n), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((cpt, n), lambda b, t: (0, 0)),
            pl.BlockSpec((1, chunks, n), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, chunks, n), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((n,), lambda b, t: (0,)),
        ],
        out_specs=[pl.BlockSpec((1, 1, n), lambda b, t: (b, t, 0)),
                   pl.BlockSpec((1, 1, n), lambda b, t: (b, t, 0))],
        out_shape=[out, out],
        interpret=interpret,
    )(polys, tw, f0, f1, ipsi)


__all__ = ["fused_rerank_pallas", "fused_rerank_intt_pallas"]
