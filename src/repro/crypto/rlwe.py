"""TPU-native RNS-RLWE additively homomorphic encryption ("BFV-lite").

This is the hardware adaptation of the paper's PHE (Module 2a).  Paillier is
bignum modexp — hostile to the MXU/VPU — so we use the RLWE analogue of "PHE
with ct+ct and ct*plain": BFV without relinearisation.

Scheme (symmetric key; the user is both encryptor and decryptor):

  ring      R_q = Z_q[X]/(X^N + 1),  q = q_0 q_1 q_2  (RNS, ~20-bit NTT primes)
  secret    s ternary in {-1, 0, 1}^N
  enc(m)    c0 = a*s + e + Delta*m,  c1 = a;   a ~ U(R_q), e ~ CBD(eta)
  dec(ct)   m = round(t/q * centered(c0 - c1*s)) mod t
  add       componentwise;  ct (x) p = (c0*p, c1*p)  for plaintext p in R

Encrypted inner products use negacyclic-convolution packing: the fixed-point
query chunk is the plaintext of a ciphertext; each candidate chunk is packed
*reversed* into a plain polynomial at block offset o_b, so coefficient
o_b + chunk - 1 of ct (x) p is exactly <query_chunk, cand_chunk>.  Chunks of
dimension > chunk_size are summed homomorphically.  Multiple candidates share
one ciphertext via block stride (N/stride candidates per result ciphertext).

The per-document half of that packing (reverse placement + forward NTT) is
request-invariant, so it is hoisted into an NTT-domain candidate cache
built once per index; at request time a candidate's block offset is realized
as a pointwise monomial-twiddle rotate in the NTT domain (bit-identical to
fresh packing — see CandidateCache / encrypted_scores_cached_batch).  Two
cache layouts share one packed pool: the dense `CandidateCache` keeps the
whole corpus resident in device memory, and the corpus-scale
`ShardedCandidateCache` partitions it into host-pooled shards with an
LRU-pinned device-resident hot set and per-request on-demand gather of only
the k' selected candidates' rows.  Shard admission is frequency-aware and
asynchronous by default — a background admitter performs the shard-sized
host->device copy off the request path and atomically swaps the shard in,
admitting only shards whose decayed touch counter reaches a threshold (see
CandidateCacheConfig; `async_admission=False` restores the deterministic
synchronous first-touch LRU for replay tests).

Correctness budget (validated in `RlweParams.validate`): every *extraction*
coefficient of m*p is an inner product of unit-norm vectors scaled by
Delta_q*Delta_c (Cauchy-Schwarz) and therefore < t/2; mod-t wraps can only
occur at garbage coefficients, which decryption treats coefficient-locally.
Noise after plain-mult is ||e||_inf * ||p||_1 <= eta * C * Delta_c * sqrt(cs),
far below q / (2t).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import math
import threading
import time
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs

from repro.crypto import modring
from repro.crypto.modring import PrimeCtx
from repro.kernels.ntt import ops as ntt_ops
from repro.kernels.ntt import ref as ntt_ref


@dataclasses.dataclass(frozen=True, eq=False)
class RlweParams:
    n_poly: int = 4096          # ring dimension N
    num_primes: int = 3         # RNS primes (~20 bits each)
    t_bits: int = 28            # plaintext modulus t = 2^t_bits
    scale_q_bits: int = 13      # query fixed-point scale  Delta_q = 2^13
    scale_c_bits: int = 13      # candidate fixed-point scale Delta_c = 2^13
    eta: int = 8                # CBD noise parameter, |e| <= eta
    chunk: int = 1024           # dot-product chunk size (<= n_poly)

    def __post_init__(self):
        assert self.n_poly % self.chunk == 0
        self.validate()

    @functools.cached_property
    def primes(self) -> tuple:
        return modring.find_ntt_primes(2 * self.n_poly, self.num_primes)

    @functools.cached_property
    def ctxs(self) -> tuple:
        return tuple(PrimeCtx.build(q, self.n_poly) for q in self.primes)

    @functools.cached_property
    def big_q(self) -> int:
        return math.prod(self.primes)

    @property
    def t(self) -> int:
        return 1 << self.t_bits

    @functools.cached_property
    def delta(self) -> int:
        return self.big_q // self.t

    @property
    def scale_q(self) -> int:
        return 1 << self.scale_q_bits

    @property
    def scale_c(self) -> int:
        return 1 << self.scale_c_bits

    def stride(self, n_dim: int) -> int:
        """Block stride: extraction at o_b + chunk - 1 must clear the previous
        block's span o_b + chunk - 1 + (chunk_used - 1)."""
        return self.chunk if n_dim <= self.chunk else 2 * self.chunk

    def cands_per_ct(self, n_dim: int) -> int:
        return self.n_poly // self.stride(n_dim)

    def num_chunks(self, n_dim: int) -> int:
        return -(-n_dim // self.chunk)

    def validate(self) -> None:
        # plaintext range: extraction coefficients bounded by Delta_q*Delta_c
        # (unit-norm Cauchy-Schwarz) + quantization slop < t/2.
        assert (1 << (self.scale_q_bits + self.scale_c_bits)) * 1.1 < self.t / 2, \
            "plaintext scales overflow t"
        # noise: after plain-mult and chunk-summing,
        #   |noise| <= eta * cands_per_ct_max * Delta_c * sqrt(chunk) * chunks_max
        worst = (self.eta * (self.n_poly // self.chunk) * self.scale_c
                 * math.isqrt(self.chunk) * 4)
        assert 2 * self.t * worst < self.big_q, "noise budget exceeded"

    def ciphertext_bytes(self, packed_bits: int = 20) -> int:
        """Wire size of one ciphertext (2 components, RNS, bit-packed)."""
        return 2 * self.num_primes * self.n_poly * packed_bits // 8


@dataclasses.dataclass(frozen=True, eq=False)
class RlweSecretKey:
    params: RlweParams
    s: np.ndarray          # (N,) int8 ternary
    s_ntt: jnp.ndarray     # (P, N) int32 — NTT(s) per prime


@dataclasses.dataclass(frozen=True, eq=False)
class QueryCiphertext:
    """Encrypted, chunked query embedding: (chunks, P, N) int32 per component."""
    c0: jnp.ndarray
    c1: jnp.ndarray
    n_dim: int


@dataclasses.dataclass(frozen=True, eq=False)
class PackedCandidates:
    """NTT-domain packed candidate plaintexts.

    polys: (num_ct, chunks, P, N) int32; candidate i lives in result ct
    i // cands_per_ct at extraction coefficient (i % cands_per_ct) * stride
    + chunk - 1.
    """
    polys: jnp.ndarray
    n_dim: int
    num_cands: int


@dataclasses.dataclass(frozen=True, eq=False)
class ScoreCiphertexts:
    """Encrypted inner products: (num_ct, P, N) int32 per component."""
    c0: jnp.ndarray
    c1: jnp.ndarray
    n_dim: int
    num_cands: int


@dataclasses.dataclass(frozen=True, eq=False)
class ScoreCiphertextBatch:
    """B stacked score ciphertexts: (B, num_ct, P, N) int32 per component.

    The serving path keeps this stacked form end-to-end (scoring ->
    decryption) so no per-lane device work happens; `lane`/`lanes` hand out
    per-request views for the wire messages."""
    c0: jnp.ndarray
    c1: jnp.ndarray
    n_dim: int
    num_cands: int

    @property
    def batch(self) -> int:
        return self.c0.shape[0]

    def lane(self, b: int) -> ScoreCiphertexts:
        return ScoreCiphertexts(c0=self.c0[b], c1=self.c1[b],
                                n_dim=self.n_dim, num_cands=self.num_cands)

    def lanes(self) -> list:
        return [self.lane(b) for b in range(self.batch)]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _to_rns(values: np.ndarray, params: RlweParams) -> np.ndarray:
    """Signed int64 (..., N) -> RNS int32 (P, ..., N)."""
    out = [np.mod(values, q).astype(np.int32) for q in params.primes]
    return np.stack(out, axis=0)


def _cbd(rng: np.random.Generator, eta: int, n: int) -> np.ndarray:
    a = rng.integers(0, 2, size=(eta, n)).sum(axis=0)
    b = rng.integers(0, 2, size=(eta, n)).sum(axis=0)
    return (a - b).astype(np.int64)


def keygen(params: RlweParams, rng: np.random.Generator) -> RlweSecretKey:
    s = rng.integers(-1, 2, size=(params.n_poly,)).astype(np.int8)
    s_rns = _to_rns(s.astype(np.int64), params)  # (P, N)
    s_ntt = jnp.stack([
        ntt_ops.ntt_fwd(jnp.asarray(s_rns[i]), ctx)
        for i, ctx in enumerate(params.ctxs)
    ])
    return RlweSecretKey(params=params, s=s, s_ntt=s_ntt)


def _fixed_point(e: np.ndarray, scale: int) -> np.ndarray:
    return np.rint(np.asarray(e, np.float64) * scale).astype(np.int64)


# ---------------------------------------------------------------------------
# user side: encrypt / decrypt
# ---------------------------------------------------------------------------

def encrypt_query(sk: RlweSecretKey, e: np.ndarray,
                  rng: np.random.Generator) -> QueryCiphertext:
    """Encrypt a unit-norm query embedding of any dimension (chunked)."""
    p = sk.params
    n_dim = e.shape[-1]
    chunks = p.num_chunks(n_dim)
    ints = _fixed_point(e, p.scale_q)
    c0s, c1s = [], []
    for c in range(chunks):
        m = np.zeros(p.n_poly, np.int64)
        seg = ints[c * p.chunk:(c + 1) * p.chunk]
        m[: len(seg)] = seg
        # signed (centered) encoding: Delta*m mod q, computed per RNS prime.
        # An unsigned mod-t lift would add a Delta*t*w term that explodes
        # under plain-mult; signed encoding keeps Dec(ct (x) p) = m*p exactly
        # while |(m*p)_j| < t/2 at the coefficients we read.
        err = _cbd(rng, p.eta, p.n_poly)
        c0_p, c1_p = [], []
        for i, ctx in enumerate(p.ctxs):
            a = rng.integers(0, ctx.q, size=(p.n_poly,)).astype(np.int32)
            dm = (int(p.delta % ctx.q) * np.mod(m, ctx.q)) % ctx.q  # int64 safe
            a_s = ntt_ops.ntt_inv(
                ntt_ops.pointwise_mul(
                    ntt_ops.ntt_fwd(jnp.asarray(a), ctx), sk.s_ntt[i], ctx),
                ctx)
            c0 = (np.asarray(a_s).astype(np.int64) + err + dm) % ctx.q
            c0_p.append(c0.astype(np.int32))
            c1_p.append(a)
        c0s.append(np.stack(c0_p))
        c1s.append(np.stack(c1_p))
    return QueryCiphertext(
        c0=jnp.asarray(np.stack(c0s)), c1=jnp.asarray(np.stack(c1s)), n_dim=n_dim)


def decrypt_rns(params: RlweParams, s_ntt: jnp.ndarray, c0: jnp.ndarray,
                c1: jnp.ndarray, *, use_pallas=None) -> np.ndarray:
    """RNS phase of decryption: d = c0 - c1*s per prime.

    ``c0``/``c1`` are (..., P, N); ``s_ntt`` broadcasts against the leading
    dims of NTT(c1) — pass (P, N) for one key or (B, 1, P, N)-style stacks
    for a batch of per-tenant keys.  Returns int64 (..., P, N).
    """
    d_p = []
    for i, ctx in enumerate(params.ctxs):
        f1 = ntt_ops.ntt_fwd(c1[..., i, :], ctx, use_pallas=use_pallas)
        sb = jnp.broadcast_to(s_ntt[..., i, :], f1.shape)
        c1s = ntt_ops.ntt_inv(
            ntt_ops.pointwise_mul(f1, sb, ctx, use_pallas=use_pallas), ctx,
            use_pallas=use_pallas)
        d = modring.mod_sub(c0[..., i, :], c1s, ctx.q)
        d_p.append(np.asarray(d).astype(np.int64))
    return np.stack(d_p, axis=-2)


def extract_scores(params: RlweParams, d_rns: np.ndarray, n_dim: int,
                   num_cands: int) -> np.ndarray:
    """CRT-reconstruct the extraction coefficients of d_rns (num_ct, P, N)
    (Python bignums) -> float scores (num_cands,)."""
    p = params
    stride = p.stride(n_dim)
    cpt = p.cands_per_ct(n_dim)
    g = [p.big_q // q for q in p.primes]
    h = [pow(gi % qi, -1, qi) for gi, qi in zip(g, p.primes)]
    scale = float(p.scale_q * p.scale_c)
    out = np.zeros(num_cands, np.float64)
    for cand in range(num_cands):
        ct_i, slot = divmod(cand, cpt)
        coeff = slot * stride + p.chunk - 1
        big = 0
        for i, qi in enumerate(p.primes):
            big += int(d_rns[ct_i, i, coeff]) * g[i] * h[i]
        big %= p.big_q
        if big > p.big_q // 2:
            big -= p.big_q
        val = round(big * p.t / p.big_q)  # noise removal
        # centered mod t
        val = ((val + p.t // 2) % p.t) - p.t // 2
        out[cand] = val / scale
    return out


def decrypt_scores(sk: RlweSecretKey, res: ScoreCiphertexts) -> np.ndarray:
    """Decrypt packed inner products -> float scores (len num_cands)."""
    d_rns = decrypt_rns(sk.params, sk.s_ntt, res.c0, res.c1)
    return extract_scores(sk.params, d_rns, res.n_dim, res.num_cands)


# ---------------------------------------------------------------------------
# cloud side: NTT-domain candidate cache (build once, serve many)
# ---------------------------------------------------------------------------

def params_key(params: RlweParams) -> tuple:
    """Value identity of an RlweParams: two instances with the same key are
    interchangeable for packing/scoring (primes derive from n_poly+num_primes)."""
    return (params.n_poly, params.num_primes, params.t_bits,
            params.scale_q_bits, params.scale_c_bits, params.eta, params.chunk)


@dataclasses.dataclass(frozen=True, eq=False)
class CandidateCache:
    """Per-document NTT-domain plaintexts, packed once at index-build time.

    ``polys[d, c]`` holds document d's chunk c reverse-packed at slot 0
    (p[chunk-1-j] = seg[j]) and forward-NTT'd per prime: (num_docs, chunks,
    P, N) int32 — 4*P*N bytes per chunk per document (48 KiB/doc/chunk at
    the default N=4096, P=3).  Realizing document d at slot s of a result
    ciphertext is a pointwise multiply by ``twiddles[:, s]``, the NTT-domain
    diagonal of the monomial X^{s*stride}: the slot-0 support [0, chunk)
    never crosses X^N + 1 for s < cands_per_ct, so X^{s*stride} * base is
    exactly the polynomial the cold packer would have built, and the NTT is
    a ring isomorphism — cached scoring is bit-identical to fresh packing.

    ``stride``/``cands_per_ct``/``num_chunks`` are hoisted out of the hot
    loops; `check_compatible` rejects reuse under different ``RlweParams``
    (the build-once/serve-many contract is per (index, params-value) pair).
    """
    params: RlweParams
    polys: jnp.ndarray             # (num_docs, chunks, P, N) int32, NTT domain
    twiddles: jnp.ndarray          # (P, cands_per_ct, N) int32, NTT(X^{s*stride})
    n_dim: int
    num_docs: int
    stride: int
    cands_per_ct: int
    num_chunks: int

    @property
    def nbytes(self) -> int:
        return int(self.polys.size) * 4

    def host_pool(self) -> np.ndarray:
        """Host view/copy of the packed pool, memoized on first use so every
        sharded re-view (`shard_candidate_cache`) shares ONE host array no
        matter how many configs consume it — and dense-only callers never
        pay for it.  Zero-copy on the CPU backend; one D2H on accelerators.
        """
        pool = self.__dict__.get("_host_pool")
        if pool is None:
            # frozen dataclass: memoize via __dict__ (cached_property style)
            pool = self.__dict__["_host_pool"] = np.asarray(self.polys)
        return pool

    def check_compatible(self, params: RlweParams, n_dim=None) -> None:
        _check_cache_compatible(self, params, n_dim)


def _cache_geometry(params: RlweParams, n_dim: int) -> tuple:
    """(chunks, stride, cands_per_ct) with the int32-accumulator check the
    scoring kernels rely on (slot/chunk accumulators sum cpt*chunks raw
    int32 terms in [0, q) before one Barrett reduction)."""
    chunks = params.num_chunks(n_dim)
    stride = params.stride(n_dim)
    cpt = params.cands_per_ct(n_dim)
    assert cpt * chunks * (params.primes[0] - 1) < 2**31, \
        "cpt*chunks too large for the int32 accumulator"
    return chunks, stride, cpt


def _pack_corpus_ntt(params: RlweParams, emb: np.ndarray) -> np.ndarray:
    """The corpus half of negacyclic packing, hoisted offline: every
    document's chunks reverse-packed at slot 0 and forward-NTT'd per prime.
    Returns the host pool (num_docs, chunks, P, N) int32 — the single source
    of truth backing both the dense and the sharded candidate cache."""
    num_docs, n_dim = emb.shape
    chunks, _, _ = _cache_geometry(params, n_dim)
    # pack + NTT in document blocks: peak transient host memory is one
    # ~64 MiB int64 staging buffer (plus its RNS copy), not 3x the corpus
    block = max(1, (1 << 23) // (chunks * params.n_poly))
    parts = []
    for lo in range(0, num_docs, block):
        seg_emb = emb[lo:lo + block]
        ints = _fixed_point(seg_emb, params.scale_c)      # (b, n_dim)
        polys = np.zeros((len(seg_emb), chunks, params.n_poly), np.int64)
        for c in range(chunks):
            seg = ints[:, c * params.chunk:(c + 1) * params.chunk]
            polys[:, c, params.chunk - 1 - np.arange(seg.shape[1])] = seg
        rns = _to_rns(polys, params)                      # (P, b, chunks, N)
        parts.append(np.stack([
            np.asarray(ntt_ops.ntt_fwd(jnp.asarray(rns[i]), ctx))
            for i, ctx in enumerate(params.ctxs)
        ], axis=2))                                       # (b, chunks, P, N)
    return parts[0] if len(parts) == 1 else np.concatenate(parts)


def _slot_twiddles(params: RlweParams, n_dim: int) -> jnp.ndarray:
    """NTT-domain diagonals of the slot monomials X^{s*stride}: (P, cpt, N)."""
    _, stride, cpt = _cache_geometry(params, n_dim)
    mono = np.zeros((cpt, params.n_poly), np.int64)
    mono[np.arange(cpt), np.arange(cpt) * stride] = 1
    mrns = _to_rns(mono, params)                          # (P, cpt, N)
    return jnp.stack([
        ntt_ops.ntt_fwd(jnp.asarray(mrns[i]), ctx)
        for i, ctx in enumerate(params.ctxs)
    ])                                                    # (P, cpt, N)


def build_candidate_cache(params: RlweParams,
                          embeddings: np.ndarray) -> CandidateCache:
    """Precompute the NTT-domain plaintexts of every document (slot 0) plus
    the per-slot monomial twiddles.  One vectorized host pack + one forward
    NTT per prime for the whole corpus; after this the server's encrypted
    workload touches only per-request data.  The whole pool lives dense in
    device memory — at corpus scale use `build_sharded_candidate_cache`."""
    emb = np.asarray(embeddings)
    num_docs, n_dim = emb.shape
    chunks, stride, cpt = _cache_geometry(params, n_dim)
    pool = _pack_corpus_ntt(params, emb)
    return CandidateCache(params=params, polys=jnp.asarray(pool),
                          twiddles=_slot_twiddles(params, n_dim),
                          n_dim=n_dim, num_docs=num_docs, stride=stride,
                          cands_per_ct=cpt, num_chunks=chunks)


# ---------------------------------------------------------------------------
# cloud side: sharded HBM-resident candidate cache (corpus scale)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CandidateCacheConfig:
    """Knobs for the sharded candidate cache (hashable: `FlatIndex` memoizes
    one cache per (RlweParams value, config) pair).

    shard_docs / num_shards   partition of the corpus into contiguous
                              document ranges (specify one; ``shard_docs``
                              wins).  Default: 8 shards.
    max_resident_bytes        device-memory budget for LRU-pinned hot shards.
                              ``None`` = unbounded (every admitted shard
                              stays resident), ``0`` = stream-only (no
                              admission; each request gathers its k' rows
                              from the host pool on demand).
    pin_on_access             allow admission of missed shards to device
                              residency (subject to the budget and the
                              admission policy below).  ``False`` keeps the
                              resident set fixed to whatever `pin` loaded.
    async_admission           True (default): admissions run on a background
                              admitter thread — the shard-sized host->device
                              copy happens off the request path and the
                              shard is atomically swapped into the resident
                              set when the copy completes; `gather` never
                              blocks on an in-flight admission (it streams
                              the k' rows from the host pool until the shard
                              is resident).  False: the deterministic legacy
                              mode — synchronous, unconditional first-touch
                              admission inside `gather`, preserving the
                              bit-identical LRU traces the determinism tests
                              pin down.
    admit_threshold           (async mode) admit a shard only on its
                              ``admit_threshold``-th touch within the decay
                              window — the default 2 ("second touch") keeps
                              one-shot uniform sweeps from churning the
                              resident set while repeat traffic still admits
                              after one repeat.
    admit_window              (async mode) decayed-counter window: every
                              ``admit_window`` counted shard touches, all
                              touch counters are halved (and sub-1 counters
                              dropped), so stale popularity ages out.
                              ``None`` (default) resolves at build time to
                              ``max(8, num_shards)`` — the window that
                              separates the regimes: traffic spread
                              uniformly over all shards touches each shard
                              about once per window, so its counter decays
                              before the second touch and nothing is ever
                              admitted (zero churn), while traffic
                              concentrated on a minority of shards
                              re-touches them several times per window and
                              admits after one repeat.
    max_pending_admissions    (async mode) bound on queued background
                              admissions; further admission requests are
                              dropped (and counted) until the queue drains,
                              so a regime shift cannot build an unbounded
                              copy backlog.

    One config for both regimes: with async admission the admission cost is
    off the request path, so the default policy serves *skewed* traffic
    (hot shards admitted after one repeat touch, then gathered device-side)
    and *uniform* traffic (requests stream from the host pool; background
    churn is bounded by the queue cap) without per-regime tuning —
    `benchmarks/rlwe_bench.py` gates both regimes under this one default.
    Stream-only (``max_resident_bytes=0``) and operator placement
    (``pin_on_access=False`` + explicit `ShardedCandidateCache.pin`) remain
    available for fixed deployments.
    """
    shard_docs: Optional[int] = None
    num_shards: Optional[int] = None
    max_resident_bytes: Optional[int] = None
    pin_on_access: bool = True
    async_admission: bool = True
    admit_threshold: int = 2
    admit_window: Optional[int] = None
    max_pending_admissions: int = 4

    def __post_init__(self):
        # CLI-reachable knobs: fail loudly at construction, not mid-serve
        if self.admit_threshold < 1:
            raise ValueError(
                f"admit_threshold must be >= 1, got {self.admit_threshold}")
        if self.admit_window is not None and self.admit_window < 1:
            raise ValueError(
                f"admit_window must be >= 1, got {self.admit_window}")
        if self.max_pending_admissions < 1:
            raise ValueError(f"max_pending_admissions must be >= 1, got "
                             f"{self.max_pending_admissions}")

    def resolve_admit_window(self, num_shards: int) -> int:
        """``None`` -> the regime-separating auto window (see class doc)."""
        if self.admit_window is not None:
            return self.admit_window
        return max(8, num_shards)

    def resolve_shard_docs(self, num_docs: int) -> int:
        if self.shard_docs is not None:
            if self.shard_docs <= 0:        # CLI-reachable: fail loudly
                raise ValueError(
                    f"shard_docs must be positive, got {self.shard_docs}")
            return self.shard_docs
        n_shards = self.num_shards if self.num_shards is not None else 8
        if n_shards <= 0:
            raise ValueError(f"num_shards must be positive, got {n_shards}")
        return max(1, -(-num_docs // n_shards))


@dataclasses.dataclass(eq=False)
class ShardedCandidateCache:
    """Capacity-aware sharded view of the NTT-domain candidate pool.

    The per-document plaintexts (the same (doc, chunk, P, N) int32 rows a
    dense `CandidateCache` would hold on device) live in a flat host pool
    partitioned into contiguous document shards; document d maps to shard
    ``d // shard_docs``, local row ``d % shard_docs`` — assigned at index
    build, aligned with `FlatIndex` row sharding.  Device memory holds only

      * an LRU set of *pinned hot shards* bounded by ``max_resident_bytes``
        (repeat tenants hitting the same shard gather device-side), and
      * the per-request gather buffer: the k' selected candidates' chunks,
        fetched on demand (`jnp.take` from a resident shard, or a host-side
        row gather of just those k' rows for a non-resident shard).

    Gathered rows are the exact pool rows the dense cache would `jnp.take`,
    so sharded scoring is bit-identical to the dense cache and to cold
    packing regardless of the resident set, eviction history, admission
    policy, or any in-flight background admission.

    Admission policy (see `CandidateCacheConfig`): in the default *async*
    mode a missed shard is only a candidate for residency — its decayed
    touch counter must reach ``admit_threshold`` (2nd touch by default)
    before an admission is enqueued to the background admitter thread,
    which stages the host->device copy into a private buffer and atomically
    swaps the shard into the resident set under the cache lock.  `gather`
    never waits: until the swap it streams the selected rows from the host
    pool (double-buffered admission — the request path and the in-flight
    copy never share a buffer).  `prefetch` lets the serving engine enqueue
    those admissions as soon as the batched top-k' candidate ids are known,
    so the copy overlaps the request's encrypt/Hadamard compute; a prefetch
    counts the touch, and the request's own `gather` of the same ids does
    not double-count it.

    With ``async_admission=False`` eviction/admission is the deterministic
    legacy mode: shards are admitted synchronously on first touch in access
    order (MRU at the back of an OrderedDict), evicted oldest-first
    whenever the resident set exceeds the budget; a re-accessed shard is
    re-pinned the same way.  ``hits``/``misses`` count shard-group lookups
    (one per distinct shard touched by a gather), not individual documents.
    """
    params: RlweParams
    twiddles: jnp.ndarray          # (P, cpt, N) — same as the dense cache
    n_dim: int
    num_docs: int
    stride: int
    cands_per_ct: int
    num_chunks: int
    shard_docs: int
    pool: np.ndarray               # host (num_docs, chunks, P, N) backing store
    shards: list                   # views into ``pool``, <=shard_docs docs each
    epoch: int = 0                 # corpus epoch (bumped by `ingest_tail`)
    max_resident_bytes: Optional[int] = None
    pin_on_access: bool = True
    async_admission: bool = True
    admit_threshold: int = 2
    admit_window: int = 64
    max_pending_admissions: int = 4
    sharding: Optional[object] = None   # jax.sharding.Sharding for pinned shards
    _resident: collections.OrderedDict = dataclasses.field(
        default_factory=collections.OrderedDict, repr=False)
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    gathered_bytes: int = 0        # host->device on-demand row traffic
    peak_resident_bytes: int = 0
    admissions: int = 0            # completed admissions (sync + async + pin)
    async_admissions: int = 0      # ... of which completed on the admitter
    prefetches: int = 0            # shard touches recorded via `prefetch`
    admit_enqueued: int = 0        # admissions handed to the admitter
    admit_dropped: int = 0         # admission requests dropped (queue full)
    policy_deferrals: int = 0      # touches below admit_threshold (no admit)

    def __post_init__(self):
        # Admitter state lives outside the dataclass fields: one lock
        # guards the resident set + policy counters; the condition wakes
        # the (lazily started) admitter thread and `flush` waiters.
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: collections.deque = collections.deque()
        self._inflight: set = set()       # enqueued or mid-copy shard ids
        self._touch_counts: dict = {}     # shard id -> decayed touch count
        self._touches = 0                 # counted touches since build
        self._prefetched: set = set()     # touches already counted upstream
        self._worker: Optional[threading.Thread] = None
        self._closed = False
        self._admit_hook = None           # test seam: called(s) pre-swap
        self._ingest_hook = None          # test seam: called(self) pre-publish
        # shard boundary table: shard s owns docs [starts[s], starts[s+1]).
        # Uniform `d // shard_docs` at build; `ingest_tail` appends
        # boundaries, so the mapping stays valid for ragged tail shards.
        self._starts = np.cumsum(
            [0] + [s.shape[0] for s in self.shards])[:-1]
        self.ingests = 0                  # tail shards appended since build
        # telemetry sink (repro.obs): the serving engine re-binds these
        # every dispatch via `set_trace_context` — the cache is index-
        # memoized and may outlive any one engine.  Spans record only
        # shard ids and byte/row counts (redaction enforced by the
        # tracer); the admitter thread records on its own "admitter"
        # track, parented to the batch whose prefetch/gather enqueued it.
        self.tracer = obs.NULL_TRACER
        self._trace_batch: Optional[int] = None

    def set_trace_context(self, tracer, batch_id: Optional[int]) -> None:
        """Bind the tracer + current batch id for spans this cache emits
        (including admissions completed later on the admitter thread)."""
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self._trace_batch = batch_id

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def pool_nbytes(self) -> int:
        """Total host pool size — what the dense cache would pin on device."""
        return sum(s.nbytes for s in self.shards)

    def host_pool(self) -> np.ndarray:
        """The full packed pool including any ingested tail shards — the
        original backing array when the cache never grew, else one
        concatenated copy (re-view/densify paths only; the request path
        always reads per-shard)."""
        with self._lock:
            shards = list(self.shards)
        if self.pool.shape[0] == sum(s.shape[0] for s in shards):
            return self.pool
        return np.concatenate(shards, axis=0)

    def _resident_bytes_locked(self) -> int:
        return sum(int(v.size) * 4 for v in self._resident.values())

    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._resident_bytes_locked()

    @property
    def resident_shards(self) -> tuple:
        """Resident shard ids, LRU -> MRU (deterministic under a fixed
        access trace; asserted in tests)."""
        with self._lock:
            return tuple(self._resident.keys())

    def stats(self) -> dict:
        # one lock scope: the admitter swaps/evicts concurrently, so every
        # _resident-derived value must come from the same snapshot
        with self._lock:
            resident_bytes = self._resident_bytes_locked()
            resident_shards = tuple(self._resident.keys())
            pending = len(self._inflight)
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "gathered_bytes": self.gathered_bytes,
                "resident_bytes": resident_bytes,
                "peak_resident_bytes": self.peak_resident_bytes,
                "pool_bytes": self.pool_nbytes,
                "num_shards": self.num_shards,
                "resident_shards": resident_shards,
                "admissions": self.admissions,
                "async_admissions": self.async_admissions,
                "prefetches": self.prefetches,
                "admit_enqueued": self.admit_enqueued,
                "admit_dropped": self.admit_dropped,
                "policy_deferrals": self.policy_deferrals,
                "pending_admissions": pending,
                "epoch": self.epoch,
                "ingests": self.ingests}

    def check_compatible(self, params: RlweParams, n_dim=None) -> None:
        _check_cache_compatible(self, params, n_dim)

    def shard_of(self, doc_id: int) -> int:
        return int(np.searchsorted(self._starts, int(doc_id),
                                   side="right")) - 1

    def _shard_ids(self, flat: np.ndarray) -> np.ndarray:
        """Validated document ids -> shard ids (the single id->shard
        mapping `gather` and `prefetch` share).  Boundary-table lookup:
        identical to ``flat // shard_docs`` for the uniform build layout,
        and still correct for ragged tail shards appended by
        `ingest_tail` (ids below an earlier epoch's num_docs always map
        the same way — the table only ever grows)."""
        if flat.size and (flat.min() < 0 or flat.max() >= self.num_docs):
            # negative ids would alias shards[-1] via Python indexing and
            # silently gather the wrong document; fail loudly instead
            raise IndexError(
                f"candidate ids must be in [0, {self.num_docs}); got "
                f"[{flat.min()}, {flat.max()}]")
        return np.searchsorted(self._starts, flat, side="right") - 1

    def pin(self, shard_id: int) -> None:
        """Explicitly admit a shard to device residency (LRU position =
        most-recent); evicts oldest shards if over budget.  Always
        synchronous — operator placement wants the shard resident on
        return, whatever the background policy."""
        with self.tracer.span("cache_pin", shard=int(shard_id),
                              batch_id=self._trace_batch):
            with self._lock:
                self._admit_locked(int(shard_id))

    # -- admission: shared swap-in (caller holds the lock) -------------------

    def _fits_budget(self, s: int) -> bool:
        return (self.max_resident_bytes is None
                or self.shards[s].nbytes <= self.max_resident_bytes)

    def _swap_in_locked(self, s: int, arr) -> None:
        """Atomically install a staged device copy of shard ``s``: evict
        LRU-first down to budget, then publish.  The staging buffer was
        built outside the lock (and, on the async path, off the request
        thread), so residency never exceeds the budget and `gather` never
        observes a half-copied shard — it streams from the host pool until
        this swap."""
        nbytes = self.shards[s].nbytes
        if self.max_resident_bytes is not None:
            while (self._resident_bytes_locked() + nbytes
                   > self.max_resident_bytes):
                evicted, _ = self._resident.popitem(last=False)
                self.evictions += 1
                # tracer has its own lock and never takes the cache lock,
                # so recording under the cache lock cannot deadlock
                self.tracer.event("cache_evict", shard=int(evicted),
                                  batch_id=self._trace_batch)
        self._resident[s] = arr
        self.admissions += 1
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self._resident_bytes_locked())

    def _stage_copy(self, s: int):
        arr = jnp.asarray(self.shards[s])
        if self.sharding is not None:
            arr = jax.device_put(arr, self.sharding)
        return arr

    def _admit_locked(self, s: int) -> None:
        """Legacy synchronous admission (also `pin`): copy + swap inline."""
        if s in self._resident:
            self._resident.move_to_end(s)
            return
        if not self._fits_budget(s):
            return                  # shard alone exceeds the budget: stream
        with self.tracer.span("cache_admit", shard=int(s),
                              batch_id=self._trace_batch,
                              bytes=int(self.shards[s].nbytes)):
            self._swap_in_locked(s, self._stage_copy(s))

    # -- admission: frequency-aware policy + background admitter -------------

    def _touch_locked(self, s: int) -> None:
        """Count one (non-prefetched) touch of a missed shard and enqueue a
        background admission when the decayed counter reaches the
        threshold."""
        if self.max_resident_bytes == 0 or not self._fits_budget(s):
            return                  # stream-only / oversized: never admit
        self._touches += 1
        if self._touches % self.admit_window == 0:
            # decay: halve every counter each window; sub-1 entries age out
            self._touch_counts = {k: v / 2
                                  for k, v in self._touch_counts.items()
                                  if v >= 1.0}
        count = self._touch_counts.get(s, 0.0) + 1.0
        self._touch_counts[s] = count
        if count < self.admit_threshold:
            self.policy_deferrals += 1
            return
        if s in self._resident or s in self._inflight:
            return
        if len(self._queue) >= self.max_pending_admissions:
            self.admit_dropped += 1   # counter keeps it eligible next touch
            return
        self._touch_counts.pop(s, None)
        self._inflight.add(s)
        # the triggering batch rides along so the admitter's span is
        # parented to the request that earned the admission
        self._queue.append((s, self._trace_batch))
        self.admit_enqueued += 1
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(
                target=self._admit_worker, name="shard-admitter", daemon=True)
            self._worker.start()
        self._cv.notify_all()

    def _admit_worker(self) -> None:
        """Background admitter: drain the queue one shard at a time.  The
        H2D copy (`_stage_copy` + block_until_ready) runs outside the lock —
        the request path keeps streaming from the host pool meanwhile — and
        only the final swap takes the lock.  An idle worker retires after a
        timeout (releasing its reference to the cache and pool); the next
        enqueue respawns one — `_touch_locked` checks under the same lock,
        so no admission can fall between a retiring and a spawning worker."""
        while True:
            with self._cv:
                if not self._queue and not self._closed:
                    self._cv.wait(timeout=60.0)
                if not self._queue:       # closed, or idled out: retire
                    self._worker = None
                    return
                s, parent = self._queue.popleft()
            tracer = self.tracer
            t0 = tracer.clock() if tracer.enabled else 0.0
            try:
                hook = self._admit_hook   # test seam: delay/observe the copy
                if hook is not None:
                    hook(s)
                arr = self._stage_copy(s)
                jax.block_until_ready(arr)   # the copy, off-request-path
            except Exception:             # noqa: BLE001 — a failed copy must
                arr = None                # not strand flush()/later admits
            swapped = False
            with self._cv:
                self._inflight.discard(s)
                if arr is None:
                    pass                  # dropped; next touch retries
                elif s in self._resident:
                    self._resident.move_to_end(s)
                elif self._fits_budget(s) and self.max_resident_bytes != 0:
                    self._swap_in_locked(s, arr)
                    self.async_admissions += 1
                    swapped = True
                self._cv.notify_all()     # wake flush()
            if tracer.enabled:
                # span covers the whole off-path admission (staged copy +
                # swap) on the admitter's own track, so the timeline shows
                # it overlapping the request's encrypt/score compute
                tracer.record("cache_admit", t0, tracer.clock(),
                              track="admitter", batch_id=parent,
                              shard=int(s),
                              bytes=int(self.shards[s].nbytes),
                              ok=swapped)

    def prefetch(self, ids) -> int:
        """Serving-engine admission hook: note the shard touches implied by
        a batch's top-k' candidate ``ids`` and enqueue any admissions the
        policy grants *now*, before the request's encrypt/Hadamard work, so
        the background copy overlaps compute.  The subsequent `gather` of
        the same ids does not double-count these touches.  Returns the
        number of shards whose touch was recorded.  No-op (returns 0) when
        admission is disabled or in synchronous legacy mode."""
        if not (self.pin_on_access and self.async_admission):
            return 0
        flat = np.asarray(ids).reshape(-1)
        shard_ids = self._shard_ids(flat)
        if flat.size == 0:
            return 0
        tracer = self.tracer
        t0 = tracer.clock() if tracer.enabled else 0.0
        touched = 0
        with self._lock:
            # one fresh credit set per batch: stale credits from a previous
            # prefetch (e.g. a shard that became resident before its gather)
            # must not suppress future miss accounting
            self._prefetched = set()
            for s in np.unique(shard_ids):
                s = int(s)
                if s in self._resident:
                    continue          # gather will hit; nothing to admit
                self._touch_locked(s)
                self._prefetched.add(s)
                self.prefetches += 1
                touched += 1
        if tracer.enabled:
            tracer.record("cache_prefetch", t0, tracer.clock(),
                          batch_id=self._trace_batch, shards=touched)
        return touched

    def flush(self, timeout: float = 60.0) -> None:
        """Block until every enqueued admission has completed (or timed
        out).  Request paths never need this — it exists so tests and
        benchmarks can observe the converged resident set."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while self._queue or self._inflight:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"shard admissions did not drain within {timeout}s "
                        f"({len(self._queue)} queued, "
                        f"{len(self._inflight)} in flight)")
                self._cv.wait(remaining)

    def close(self) -> None:
        """Stop the admitter thread (pending admissions still complete).
        Idempotent; the cache remains usable afterwards in streaming mode
        (a later admission restarts the worker)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        worker = self._worker
        if worker is not None and worker.is_alive():
            worker.join(timeout=60.0)
        with self._cv:
            self._closed = False      # allow lazy restart

    def ingest_tail(self, rows: np.ndarray, *, epoch: int) -> None:
        """Streaming ingestion: append newly packed docs as a *tail shard*
        and stamp the cache with the new corpus ``epoch``.

        ``rows`` is the `_pack_corpus_ntt` output for the new documents —
        fully materialized before this call, like the admitter's staged
        copy, so the publish under the cache lock is a pointer append: a
        concurrent `gather` observes either the pre-ingest shard table or
        the complete tail shard, never a half-swapped one.  Ids below the
        previous ``num_docs`` keep their shard mapping (the boundary table
        only grows), which is what makes a fixed-epoch replay bit-identical
        while ingestion runs.  The tail shard then rides the *existing*
        atomic admission path to device residency — enqueued to the
        background admitter (staged copy off-lock, `_swap_in_locked`
        publish); until that swap, gathers stream it from the host like
        any other non-resident shard."""
        rows = np.ascontiguousarray(rows)
        want = (self.num_chunks, self.params.num_primes, self.params.n_poly)
        if rows.ndim != 4 or rows.shape[1:] != want:
            raise ValueError(
                f"tail shard rows must be (m, {want[0]}, {want[1]}, "
                f"{want[2]}), got {rows.shape}")
        if rows.shape[0] == 0:
            return
        hook = self._ingest_hook    # test seam: interleave pre-publish
        if hook is not None:
            hook(self)
        with self._cv:
            if epoch <= self.epoch:
                raise ValueError(
                    f"stale ingest epoch {epoch} (cache is at "
                    f"{self.epoch})")
            s = len(self.shards)
            self.shards.append(rows)
            self._starts = np.append(self._starts, self.num_docs)
            self.num_docs += rows.shape[0]
            self.epoch = epoch
            self.ingests += 1
            # warm the tail through the normal admission machinery
            if (self.pin_on_access and self.async_admission
                    and self.max_resident_bytes != 0
                    and self._fits_budget(s)
                    and len(self._queue) < self.max_pending_admissions):
                self._inflight.add(s)
                self._queue.append((s, self._trace_batch))
                self.admit_enqueued += 1
                if self._worker is None or not self._worker.is_alive():
                    self._worker = threading.Thread(
                        target=self._admit_worker, name="shard-admitter",
                        daemon=True)
                    self._worker.start()
            self._cv.notify_all()

    def gather(self, ids) -> jnp.ndarray:
        """On-demand gather of the selected candidates' cached rows:
        (B, num_cands) document ids -> (B, num_cands, chunks, P, N) device
        array, touching only those k' documents per lane.

        Ids are grouped by shard; resident shards gather device-side
        (`jnp.take`), non-resident shards gather just the selected rows from
        the host pool.  When ``pin_on_access``, a miss feeds the admission
        policy: synchronous first-touch LRU admission in legacy mode
        (``async_admission=False``), else a counted touch that may enqueue a
        background admission — the gather itself never waits on the copy."""
        ids = np.asarray(ids)
        assert ids.ndim == 2, "ids must be (B, num_cands)"
        bsz, nc = ids.shape
        tracer = self.tracer
        t0 = tracer.clock() if tracer.enabled else 0.0
        h0, m0, g0 = self.hits, self.misses, self.gathered_bytes
        flat = ids.reshape(-1)
        shard_ids = self._shard_ids(flat)
        local = flat - self._starts[shard_ids]
        order = np.argsort(shard_ids, kind="stable")      # group by shard
        uniq, starts = np.unique(shard_ids[order], return_index=True)
        bounds = np.append(starts, order.size)
        parts = []
        for s, lo, hi in zip(uniq, bounds[:-1], bounds[1:]):
            s = int(s)
            sel = order[lo:hi]
            loc = local[sel]
            with self._lock:                  # vs admitter swap/evict
                dev = self._resident.get(s)
                if dev is not None:
                    self.hits += 1
                    self._resident.move_to_end(s)         # LRU touch
                    self._prefetched.discard(s)   # credit no longer needed
                elif self.pin_on_access:
                    if not self.async_admission:
                        self._admit_locked(s)
                    elif s in self._prefetched:
                        self._prefetched.discard(s)   # counted at prefetch
                    else:
                        self._touch_locked(s)
            if dev is not None:
                rows = jnp.take(dev, jnp.asarray(loc), axis=0)
            else:
                self.misses += 1
                rows = jnp.asarray(self.shards[s][loc])   # host row gather
                self.gathered_bytes += int(rows.size) * 4
            parts.append(rows)
        g = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
        inv = np.empty_like(order)
        inv[order] = np.arange(order.size)                # undo the grouping
        g = jnp.take(g, jnp.asarray(inv), axis=0)
        out = g.reshape(bsz, nc, self.num_chunks,
                        self.params.num_primes, self.params.n_poly)
        if tracer.enabled:
            tracer.record("cache_gather", t0, tracer.clock(),
                          batch_id=self._trace_batch, lanes=int(bsz),
                          num_cands=int(nc), shards=int(uniq.size),
                          hits=self.hits - h0, misses=self.misses - m0,
                          bytes=self.gathered_bytes - g0)
        return out


def _check_cache_compatible(cache, params: RlweParams, n_dim=None) -> None:
    if params_key(params) != params_key(cache.params):
        raise ValueError(
            f"candidate cache was built for RlweParams "
            f"{params_key(cache.params)} but scoring uses "
            f"{params_key(params)}; rebuild the cache for these params")
    if n_dim is not None and n_dim != cache.n_dim:
        raise ValueError(
            f"candidate cache packs n_dim={cache.n_dim} but the query "
            f"has n_dim={n_dim}")


def _shard_pool(params: RlweParams, pool: np.ndarray, n_dim: int,
                config: CandidateCacheConfig,
                sharding=None, twiddles=None,
                epoch: int = 0) -> ShardedCandidateCache:
    num_docs = pool.shape[0]
    chunks, stride, cpt = _cache_geometry(params, n_dim)
    shard_docs = config.resolve_shard_docs(num_docs)
    shards = [pool[lo:lo + shard_docs]                    # views, no copy
              for lo in range(0, num_docs, shard_docs)]
    if twiddles is None:
        twiddles = _slot_twiddles(params, n_dim)
    return ShardedCandidateCache(
        params=params, twiddles=twiddles, n_dim=n_dim,
        num_docs=num_docs, stride=stride, cands_per_ct=cpt,
        num_chunks=chunks, shard_docs=shard_docs, pool=pool, shards=shards,
        epoch=epoch,
        max_resident_bytes=config.max_resident_bytes,
        pin_on_access=config.pin_on_access,
        async_admission=config.async_admission,
        admit_threshold=config.admit_threshold,
        admit_window=config.resolve_admit_window(len(shards)),
        max_pending_admissions=config.max_pending_admissions,
        sharding=sharding)


def build_sharded_candidate_cache(
        params: RlweParams, embeddings: np.ndarray, *,
        config: Optional[CandidateCacheConfig] = None,
        sharding=None) -> ShardedCandidateCache:
    """Pack + forward-NTT the corpus once (host pool) and partition it into
    shards.  ``sharding`` optionally places pinned shards with a
    `jax.sharding.Sharding` (mesh row axes — see `FlatIndex`)."""
    emb = np.asarray(embeddings)
    config = config if config is not None else CandidateCacheConfig()
    pool = _pack_corpus_ntt(params, emb)
    return _shard_pool(params, pool, emb.shape[1], config, sharding)


def shard_candidate_cache(cache,
                          config: Optional[CandidateCacheConfig] = None,
                          sharding=None) -> ShardedCandidateCache:
    """Re-view an existing cache's pool (dense `CandidateCache` or another
    `ShardedCandidateCache`) as a sharded cache under a new config, without
    re-packing — bit-identity between the views is true by construction,
    and the packed pool (the expensive pack + forward-NTT product) is built
    once per params value no matter how many configs consume it."""
    config = config if config is not None else CandidateCacheConfig()
    pool = cache.host_pool()       # includes any ingested tail shards
    return _shard_pool(cache.params, pool, cache.n_dim, config, sharding,
                       twiddles=cache.twiddles,
                       epoch=getattr(cache, "epoch", 0))


def densify_candidate_cache(cache: ShardedCandidateCache) -> CandidateCache:
    """Dense device-resident view of a sharded cache's pool (one
    host->device copy, no re-pack; the host pool stays shared)."""
    pool = cache.host_pool()       # includes any ingested tail shards
    dense = CandidateCache(
        params=cache.params, polys=jnp.asarray(pool),
        twiddles=cache.twiddles, n_dim=cache.n_dim,
        num_docs=pool.shape[0], stride=cache.stride,
        cands_per_ct=cache.cands_per_ct, num_chunks=cache.num_chunks)
    dense.__dict__["_host_pool"] = pool         # keep the pool shared
    return dense


def _scores_pipeline(c0, c1, g, twiddles, ctxs, cpt, pad, use_pallas):
    """Traced body shared by the dense and pre-gathered entry points: zero
    padding for the last result ciphertext's empty slots, then per prime a
    query forward NTT and the fused rotate -> Hadamard -> slot/chunk mod-sum
    -> inverse NTT (one kernel per prime on the Pallas path; the per-prime
    loop unrolls at trace time, and the RNS stack of coefficient-domain
    outputs is assembled in the same jit — no host round-trips)."""
    bsz, num_cands = g.shape[0], g.shape[1]
    chunks, n = c0.shape[1], c0.shape[-1]
    if pad:                  # empty slots of the last result ciphertext
        g = jnp.concatenate(
            [g, jnp.zeros((bsz, pad) + g.shape[2:], jnp.int32)], axis=1)
    num_ct = (num_cands + pad) // cpt
    outs0, outs1 = [], []
    for i, ctx in enumerate(ctxs):
        f0 = ntt_ops.ntt_fwd(c0[:, :, i, :], ctx, use_pallas=use_pallas)
        f1 = ntt_ops.ntt_fwd(c1[:, :, i, :], ctx, use_pallas=use_pallas)
        polys_i = g[..., i, :].reshape(bsz, num_ct, cpt * chunks, n)
        acc0, acc1 = ntt_ops.fused_rotate_hadamard_intt(
            polys_i, twiddles[i], f0, f1, ctx, use_pallas=use_pallas)
        outs0.append(acc0)
        outs1.append(acc1)
    return jnp.stack(outs0, axis=2), jnp.stack(outs1, axis=2)


@functools.partial(jax.jit,
                   static_argnames=("ctxs", "cpt", "pad", "use_pallas"))
def _cached_scores(c0, c1, polys, ids, twiddles, ctxs, cpt, pad, use_pallas):
    """Whole-batch dense-cache scoring in ONE compiled call: the cache
    gather, last-ct zero padding, and the per-prime loop all live in a
    single trace, so the full gather -> rotate -> Hadamard -> slot/chunk
    mod-sum -> iNTT pipeline runs without host round-trips.  ``use_pallas``
    is static: the same trace routes through the fused Pallas kernel or the
    jitted XLA references (one layout/padding implementation for both, so
    the bit-identity contract holds by construction)."""
    bsz, num_cands = ids.shape
    g = jnp.take(polys, ids.reshape(-1), axis=0)
    g = g.reshape((bsz, num_cands) + polys.shape[1:])   # (B, nc, chunks, P, N)
    return _scores_pipeline(c0, c1, g, twiddles, ctxs, cpt, pad, use_pallas)


@functools.partial(jax.jit,
                   static_argnames=("ctxs", "cpt", "pad", "use_pallas"))
def _gathered_scores(c0, c1, g, twiddles, ctxs, cpt, pad, use_pallas):
    """Sharded-cache scoring: same compiled pipeline as `_cached_scores`
    minus the dense gather — ``g`` (B, nc, chunks, P, N) was assembled by
    `ShardedCandidateCache.gather` (a stateful LRU, so it cannot live inside
    the jit).  Identical trace below the gather => identical bits."""
    return _scores_pipeline(c0, c1, g, twiddles, ctxs, cpt, pad, use_pallas)


def encrypted_scores_cached_batch(params: RlweParams,
                                  q_cts: Sequence[QueryCiphertext],
                                  cache, cand_ids,
                                  *, use_pallas=None) -> ScoreCiphertextBatch:
    """Batched ct (x) p against cached NTT-domain candidates (``cache`` is a
    dense `CandidateCache` or a `ShardedCandidateCache`).

    Per-request work: one gather of k' cached rows per lane (device `take`
    for the dense cache; shard-grouped on-demand gather for the sharded
    cache), then per prime one fused rotate -> Hadamard -> slot/chunk
    mod-sum -> inverse NTT (Pallas kernel or the jitted XLA fallback) plus
    2*chunks query forward NTTs.  No per-candidate host loop and no
    candidate forward NTTs — those moved to the cache build.  Bit-identical
    to pack_candidates_batch + encrypted_scores_batch (same decrypted
    scores, same wire bytes), for either cache kind.
    """
    ids = np.asarray(cand_ids)
    assert ids.ndim == 2, "cand_ids must be (B, num_cands)"
    bsz, num_cands = ids.shape
    assert len(q_cts) == bsz
    cache.check_compatible(params, q_cts[0].n_dim)
    cpt = cache.cands_per_ct
    num_ct = -(-num_cands // cpt)
    pad = num_ct * cpt - num_cands
    c0 = jnp.stack([q.c0 for q in q_cts])                 # (B, chunks, P, N)
    c1 = jnp.stack([q.c1 for q in q_cts])
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if isinstance(cache, ShardedCandidateCache):
        g = cache.gather(ids)                 # (B, nc, chunks, P, N)
        all0, all1 = _gathered_scores(
            c0, c1, g, cache.twiddles, params.ctxs, cpt, pad,
            bool(use_pallas))
    else:
        all0, all1 = _cached_scores(
            c0, c1, cache.polys, jnp.asarray(ids), cache.twiddles,
            params.ctxs, cpt, pad, bool(use_pallas))
    return ScoreCiphertextBatch(c0=all0, c1=all1, n_dim=cache.n_dim,
                                num_cands=num_cands)


def encrypted_scores_cached(params: RlweParams, q_ct: QueryCiphertext,
                            cache, cand_ids,
                            *, use_pallas=None) -> ScoreCiphertexts:
    """Cached ct (x) p for one query (the B=1 slice of the batch version)."""
    res = encrypted_scores_cached_batch(
        params, [q_ct], cache, np.asarray(cand_ids)[None],
        use_pallas=use_pallas)
    return res.lane(0)


# ---------------------------------------------------------------------------
# cloud side: pack candidates, encrypted scoring
# ---------------------------------------------------------------------------

def pack_candidates_batch(params: RlweParams,
                          cands: np.ndarray) -> jnp.ndarray:
    """Pack (B, num_cands, n_dim) candidate rows -> (B, num_ct, chunks, P, N)
    NTT-domain plaintexts.  The reversed placement (p[o + chunk-1 - j] =
    seg[j]) vectorizes over B; the NTT batches all leading dims."""
    bsz, num_cands, n_dim = cands.shape
    chunks = params.num_chunks(n_dim)
    stride = params.stride(n_dim)
    cpt = params.cands_per_ct(n_dim)
    num_ct = -(-num_cands // cpt)
    ints = _fixed_point(cands, params.scale_c)  # (B, num_cands, n_dim)

    polys = np.zeros((bsz, num_ct, chunks, params.n_poly), np.int64)
    for cand in range(num_cands):
        ct_i, slot = divmod(cand, cpt)
        o = slot * stride
        for c in range(chunks):
            seg = ints[:, cand, c * params.chunk:(c + 1) * params.chunk]
            idx = o + params.chunk - 1 - np.arange(seg.shape[1])
            polys[:, ct_i, c, idx] = seg
    rns = _to_rns(polys, params)  # (P, B, num_ct, chunks, N)
    return jnp.stack([
        ntt_ops.ntt_fwd(jnp.asarray(rns[i]), ctx)
        for i, ctx in enumerate(params.ctxs)
    ], axis=3)  # (B, num_ct, chunks, P, N) — stays on device


def pack_candidates(params: RlweParams, cands: np.ndarray) -> PackedCandidates:
    """Pack candidate embeddings (num_cands, n_dim) into NTT-domain
    plaintexts (the B=1 slice of the batch packer — one source of truth)."""
    num_cands, n_dim = cands.shape
    polys = pack_candidates_batch(params, np.asarray(cands)[None])[0]
    return PackedCandidates(polys=polys, n_dim=n_dim, num_cands=num_cands)


@functools.partial(jax.jit, static_argnames=("ctxs",))
def _scores_batch_ref(c0, c1, packed, ctxs):
    """Whole-batch fallback scoring in ONE compiled call: the per-prime loop
    unrolls at trace time (no host round-trips between primes) and the
    homomorphic chunk-sum is a vectorized mod-sum, not a Python loop."""
    outs0, outs1 = [], []
    for i, ctx in enumerate(ctxs):
        f0 = ntt_ref.ntt_fwd_ref(c0[:, :, i, :], ctx)   # (B, chunks, N)
        f1 = ntt_ref.ntt_fwd_ref(c1[:, :, i, :], ctx)
        pk = packed[:, :, :, i, :]                      # (B, num_ct, chunks, N)
        prod0 = modring.mod_mul(pk, f0[:, None], ctx.q, ctx.mu)
        prod1 = modring.mod_mul(pk, f1[:, None], ctx.q, ctx.mu)
        acc0 = modring.mod_sum(prod0, ctx.q, ctx.mu, axis=2)
        acc1 = modring.mod_sum(prod1, ctx.q, ctx.mu, axis=2)
        outs0.append(ntt_ref.ntt_inv_ref(acc0, ctx))
        outs1.append(ntt_ref.ntt_inv_ref(acc1, ctx))
    return jnp.stack(outs0, axis=2), jnp.stack(outs1, axis=2)


def encrypted_scores_batch_stacked(params: RlweParams,
                                   q_cts: Sequence[QueryCiphertext],
                                   packed: jnp.ndarray, num_cands: int,
                                   n_dim: int, *,
                                   use_pallas=None) -> ScoreCiphertextBatch:
    """Batched ct (x) p: B query ciphertexts against (B, num_ct, chunks, P,
    N) packed candidates, chunk-summed in the NTT domain — one NTT dispatch
    per prime for the whole batch.

    This is the cloud's entire encrypted workload: 2 * chunks forward NTTs
    per query (amortized over all candidates), one Hadamard modmul per
    (lane, result-ct, chunk, component, prime), and 2 inverse NTTs per
    result ct.  The result stays stacked on device.
    """
    c0 = jnp.stack([q.c0 for q in q_cts])  # (B, chunks, P, N)
    c1 = jnp.stack([q.c1 for q in q_cts])
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        all0, all1 = _scores_batch_ref(c0, c1, packed, params.ctxs)
        return ScoreCiphertextBatch(c0=all0, c1=all1, n_dim=n_dim,
                                    num_cands=num_cands)
    c0_out, c1_out = [], []
    for i, ctx in enumerate(params.ctxs):
        f0 = ntt_ops.ntt_fwd(c0[:, :, i, :], ctx, use_pallas=True)
        f1 = ntt_ops.ntt_fwd(c1[:, :, i, :], ctx, use_pallas=True)
        pk = packed[:, :, :, i, :]                 # (B, num_ct, chunks, N)
        f0b = jnp.broadcast_to(f0[:, None], pk.shape)
        f1b = jnp.broadcast_to(f1[:, None], pk.shape)
        prod0 = ntt_ops.pointwise_mul(pk, f0b, ctx, use_pallas=True)
        prod1 = ntt_ops.pointwise_mul(pk, f1b, ctx, use_pallas=True)
        acc0 = modring.mod_sum(prod0, ctx.q, ctx.mu, axis=2)
        acc1 = modring.mod_sum(prod1, ctx.q, ctx.mu, axis=2)
        c0_out.append(ntt_ops.ntt_inv(acc0, ctx, use_pallas=True))
        c1_out.append(ntt_ops.ntt_inv(acc1, ctx, use_pallas=True))
    return ScoreCiphertextBatch(
        c0=jnp.stack(c0_out, axis=2), c1=jnp.stack(c1_out, axis=2),
        n_dim=n_dim, num_cands=num_cands)


def encrypted_scores_batch(params: RlweParams,
                           q_cts: Sequence[QueryCiphertext],
                           packed: jnp.ndarray, num_cands: int, n_dim: int,
                           *, use_pallas=None) -> list:
    """List-of-lanes view of `encrypted_scores_batch_stacked` (lanes are
    views of one stacked device array, no per-lane crypto work)."""
    return encrypted_scores_batch_stacked(
        params, q_cts, packed, num_cands, n_dim,
        use_pallas=use_pallas).lanes()


def encrypted_scores(params: RlweParams, q_ct: QueryCiphertext,
                     packed: PackedCandidates, *,
                     use_pallas=None) -> ScoreCiphertexts:
    """ct (x) p per candidate block (the B=1 slice of the batch version)."""
    assert q_ct.n_dim == packed.n_dim
    return encrypted_scores_batch(
        params, [q_ct], packed.polys[None], num_cands=packed.num_cands,
        n_dim=packed.n_dim, use_pallas=use_pallas)[0]


def decrypt_scores_batch(sks: Sequence[RlweSecretKey], cts,
                         *, use_pallas=None) -> list:
    """Decrypt B score ciphertexts under B (distinct) tenant keys with one
    NTT dispatch per prime; CRT extraction stays per-lane (host bignums).

    ``cts`` is either a list of ScoreCiphertexts or a ScoreCiphertextBatch —
    the stacked form skips the per-lane restack entirely."""
    params = sks[0].params
    if isinstance(cts, ScoreCiphertextBatch):
        c0, c1 = cts.c0, cts.c1
        meta = [(cts.n_dim, cts.num_cands)] * cts.batch
    else:
        c0 = jnp.stack([c.c0 for c in cts])        # (B, num_ct, P, N)
        c1 = jnp.stack([c.c1 for c in cts])
        meta = [(c.n_dim, c.num_cands) for c in cts]
    s_ntt = jnp.stack([sk.s_ntt for sk in sks])[:, None]  # (B, 1, P, N)
    d_rns = decrypt_rns(params, s_ntt, c0, c1, use_pallas=use_pallas)
    return [extract_scores(params, d_rns[b], nd, nc)
            for b, (nd, nc) in enumerate(meta)]


def cosine_distances(scores: np.ndarray) -> np.ndarray:
    """Paper Definition 2 over decrypted inner products."""
    return 1.0 - scores


__all__ = [
    "RlweParams", "RlweSecretKey", "QueryCiphertext", "PackedCandidates",
    "ScoreCiphertexts", "ScoreCiphertextBatch", "CandidateCache",
    "CandidateCacheConfig", "ShardedCandidateCache",
    "build_sharded_candidate_cache", "shard_candidate_cache",
    "densify_candidate_cache",
    "params_key", "build_candidate_cache", "keygen", "encrypt_query",
    "decrypt_scores", "decrypt_scores_batch", "decrypt_rns",
    "extract_scores", "pack_candidates", "pack_candidates_batch",
    "encrypted_scores", "encrypted_scores_batch",
    "encrypted_scores_batch_stacked", "encrypted_scores_cached",
    "encrypted_scores_cached_batch", "cosine_distances",
]
