"""Synthetic corpora standing in for MS MARCO + real embedding models.

The paper's theory (Lemma 1) models the corpus as uniform on S^{n-1}; we
provide that plus two harder regimes:

  * "uniform"   — iid gaussian, normalized (matches the theory exactly)
  * "clustered" — mixture of vMF-like clusters (realistic topical corpora;
                  the adversarial case for Theorem-1's uniform assumption)
  * "tokens"    — documents are token multisets over a vocabulary and the
                  embedding is a normalized random projection of the tf
                  vector.  Embeddings carry recoverable token signal, which
                  is what the Fig.-4 inversion-attack proxies need.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


def unit(x: np.ndarray) -> np.ndarray:
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def uniform_corpus(rng: np.random.Generator, n_docs: int, dim: int) -> np.ndarray:
    return unit(rng.normal(size=(n_docs, dim)).astype(np.float32))


def clustered_corpus(rng: np.random.Generator, n_docs: int, dim: int,
                     *, n_clusters: int = 64,
                     concentration: float = 6.0) -> np.ndarray:
    """Mixture of spherical clusters: center + gaussian/concentration, renorm."""
    centers = unit(rng.normal(size=(n_clusters, dim)))
    assign = rng.integers(0, n_clusters, size=n_docs)
    noise = rng.normal(size=(n_docs, dim)) / np.sqrt(concentration * dim)
    return unit(centers[assign] + noise).astype(np.float32)


@dataclasses.dataclass
class TokenCorpus:
    embeddings: np.ndarray        # (n_docs, dim) unit rows
    token_sets: List[set]         # per-doc token ids
    documents: List[bytes]        # rendered docs
    projection: np.ndarray        # (vocab, dim) — the "embedding model"
    vocab: int

    def embed_tokens(self, tokens) -> np.ndarray:
        tf = np.zeros(self.vocab, np.float32)
        for t in tokens:
            tf[t] += 1.0
        v = tf @ self.projection
        return v / (np.linalg.norm(v) + 1e-9)


def token_corpus(rng: np.random.Generator, n_docs: int, dim: int,
                 *, vocab: int = 4096, doc_len: int = 24,
                 zipf_a: float = 1.3,
                 paraphrases: int = 0, swap_frac: float = 0.3) -> TokenCorpus:
    """``paraphrases`` > 0 groups documents into near-duplicate clusters
    (each base doc plus `paraphrases` variants with ~swap_frac tokens swapped)
    — the dense-semantic-neighbourhood structure real corpora have, which is
    what makes embedding-inversion degrade *gracefully* with perturbation
    radius (paper Fig. 4) instead of cliff-dropping at the NN distance."""
    projection = rng.normal(size=(vocab, dim)).astype(np.float32) / np.sqrt(dim)
    token_lists = []
    while len(token_lists) < n_docs:
        base = np.minimum(rng.zipf(zipf_a, size=doc_len) - 1, vocab - 1)
        token_lists.append(base)
        for i in range(min(paraphrases, n_docs - len(token_lists))):
            var = base.copy()
            # graded distances: 1, 2, 3... token swaps (embedding distance
            # ~ sqrt(2*(k)/doc_len) — the near-duplicate shell)
            n_swap = min(1 + i % max(1, int(swap_frac * doc_len)), doc_len)
            idx = rng.choice(doc_len, n_swap, replace=False)
            var[idx] = np.minimum(rng.zipf(zipf_a, size=n_swap) - 1, vocab - 1)
            token_lists.append(var)
    token_sets, documents, embs = [], [], []
    for toks in token_lists[:n_docs]:
        token_sets.append(set(int(t) for t in toks))
        documents.append((" ".join(f"tok{t}" for t in sorted(token_sets[-1])))
                         .encode())
        tf = np.bincount(toks, minlength=vocab).astype(np.float32)
        embs.append(tf @ projection)
    embeddings = unit(np.asarray(embs, np.float32))
    return TokenCorpus(embeddings=embeddings, token_sets=token_sets,
                       documents=documents, projection=projection, vocab=vocab)


def queries_near_corpus(rng: np.random.Generator, corpus: np.ndarray,
                        n_queries: int, *, jitter: float = 0.15) -> np.ndarray:
    """Queries correlated with corpus rows (realistic retrieval workload)."""
    picks = rng.integers(0, corpus.shape[0], size=n_queries)
    noise = rng.normal(size=(n_queries, corpus.shape[1])) * jitter
    return unit(corpus[picks] + noise).astype(np.float32)


def passages(rng: np.random.Generator, n_docs: int,
             avg_bytes: int = 1024) -> List[bytes]:
    """MS-MARCO-like passage payloads (sized for eta-unit accounting)."""
    lens = np.maximum(rng.poisson(avg_bytes, size=n_docs), 16)
    return [bytes(rng.integers(97, 123, size=l, dtype=np.uint8)) for l in lens]


__all__ = ["unit", "uniform_corpus", "clustered_corpus", "TokenCorpus",
           "token_corpus", "queries_near_corpus", "passages"]
