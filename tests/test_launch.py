"""Launch layer: mesh constructors, HLO collective parser, roofline math."""

import numpy as np
import pytest

from repro.launch import mesh as mesh_lib


def test_mesh_axes_helpers():
    # without touching device state: operate on names via fake mesh objects
    class FakeMesh:
        axis_names = ("data", "model")
    assert mesh_lib.batch_axes(FakeMesh()) == ("data",)
    assert mesh_lib.row_axes(FakeMesh()) == ("data", "model")

    class FakePod:
        axis_names = ("pod", "data", "model")
    assert mesh_lib.batch_axes(FakePod()) == ("pod", "data")


HLO_SAMPLE = """
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), replica_groups={}
  %ag.1 = bf16[32,256]{1,0} all-gather(bf16[2,256]{1,0} %y), dimensions={0}
  %rs = f32[4,64]{1,0} reduce-scatter(f32[64,64]{1,0} %z), dimensions={0}
  %cp = s32[8]{0} collective-permute(s32[8]{0} %w)
  %ars = f32[16,16]{1,0} all-reduce-start(f32[16,16]{1,0} %v)
  %nope = f32[2,2]{1,0} add(f32[2,2]{1,0} %a, f32[2,2]{1,0} %b)
"""


def test_collective_parser():
    # import parses XLA_FLAGS at module top; safe in-process since it only
    # sets an env var for future processes, not this one's backend
    from repro.launch import dryrun

    out = dryrun.parse_collectives(HLO_SAMPLE)
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["result_bytes"] == 16 * 128 * 4 + 16 * 16 * 4
    assert out["all-gather"]["result_bytes"] == 32 * 256 * 2
    assert out["reduce-scatter"]["result_bytes"] == 4 * 64 * 4
    assert out["collective-permute"]["result_bytes"] == 8 * 4
    wire = dryrun.effective_wire_bytes(out, 16)
    assert wire > 0


def test_effective_wire_ring_model():
    from repro.launch import dryrun

    coll = {"all-reduce": {"count": 1, "result_bytes": 1000}}
    # ring all-reduce moves 2*(n-1)/n * bytes
    assert dryrun.effective_wire_bytes(coll, 16) == pytest.approx(
        2 * 1000 * 15 / 16)


def test_roofline_model_flops_sane():
    from benchmarks.roofline_report import model_flops

    # llama3 train_4k: 6 * 8e9 * 1.05e6 tokens ~ 5e16
    f = model_flops("llama3-8b", "train_4k")
    assert 3e16 < f < 8e16
    # decode: 2 * N * batch
    f = model_flops("llama3-8b", "decode_32k")
    assert 1e12 < f < 1e13
    # moe uses active params
    f_moe = model_flops("qwen3-moe-30b-a3b", "train_4k")
    f_if_dense = 6 * 30e9 * 256 * 4096
    assert f_moe < 0.3 * f_if_dense
