"""fm [recsys]: n_sparse=39 embed_dim=10 interaction=fm-2way
pairwise <vi,vj>xi xj via the O(nk) sum-square trick [Rendle ICDM'10]."""
from repro.models.recsys import FmConfig

CONFIG = FmConfig(name="fm", n_sparse=39, embed_dim=10,
                  vocab_per_field=100_000)

REDUCED = FmConfig(name="fm-smoke", n_sparse=5, embed_dim=4,
                   vocab_per_field=100)
