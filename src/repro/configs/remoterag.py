"""remoterag — the paper's own service config: N=1e6 documents, n=768
embeddings (gtr-t5-base), k=5, k'=160 (the Table-4 operating point)."""
from repro.crypto.rlwe import RlweParams

RLWE = RlweParams()
N_DOCS = 10 ** 6
DIM = 768
K = 5
KPRIME = 160
