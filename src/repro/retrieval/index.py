"""Device-sharded flat corpus index.

The cloud's N document embeddings are row-sharded across every axis of the
mesh (the paper's single-host vector DB, scaled out).  Each device owns a
contiguous row range; global ids are shard_offset + local id.  Documents
themselves (bytes) stay host-side, keyed by global id.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class IndexSlice:
    """A contiguous row-range view of a `FlatIndex` — the unit of replica
    placement in the scale-out serving tier (`repro.serve.router`).

    ``embeddings`` holds rows ``[start, stop)`` of the parent index;
    global ids are ``start + local id``, so a slice's search results drop
    straight into the parent's id space.  Slices are views for placement
    and search only — documents and candidate caches stay with the parent
    index (the re-rank and fetch stages address them by global id)."""

    embeddings: jax.Array          # (stop - start, n) parent rows
    start: int
    stop: int

    @property
    def num_rows(self) -> int:
        return self.stop - self.start

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]


def plan_row_slices(num_rows: int, num_slices: int, *,
                    align: int = 1) -> list:
    """Contiguous near-equal ``(start, stop)`` row ranges covering
    ``[0, num_rows)``.

    ``align`` snaps interior boundaries to multiples of itself (pass the
    candidate cache's shard size so replica slices and cache shards share
    boundaries — one doc range is then exactly one placement unit for
    both).  Raises if ``num_rows`` cannot be cut into ``num_slices``
    nonempty aligned ranges."""
    if num_slices < 1:
        raise ValueError(f"num_slices must be >= 1, got {num_slices}")
    if align < 1:
        raise ValueError(f"align must be >= 1, got {align}")
    if num_slices > num_rows:
        raise ValueError(f"cannot cut {num_rows} rows into {num_slices} "
                         f"nonempty slices")
    bounds = [0]
    for r in range(1, num_slices):
        cut = round(num_rows * r / num_slices / align) * align
        cut = max(cut, bounds[-1] + align)      # keep every slice nonempty
        bounds.append(cut)
    bounds.append(num_rows)
    if any(b >= e for b, e in zip(bounds[:-1], bounds[1:])):
        raise ValueError(
            f"align={align} cannot cut {num_rows} rows into {num_slices} "
            f"nonempty aligned slices")
    return list(zip(bounds[:-1], bounds[1:]))


@dataclasses.dataclass
class FlatIndex:
    """A flat (exact-search) embedding index, optionally mesh-sharded."""

    embeddings: jax.Array          # (N, n) unit-norm rows
    mesh: Optional[Mesh] = None
    row_axes: Optional[tuple] = None   # mesh axes the rows are sharded over
    documents: Optional[Sequence[bytes]] = None
    # NTT-domain candidate caches, memoized per RlweParams value so every
    # RemoteRagCloud over this index shares one build (build-once/serve-many)
    _cand_caches: dict = dataclasses.field(default_factory=dict, repr=False,
                                           compare=False)

    @property
    def num_rows(self) -> int:
        return self.embeddings.shape[0]

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]

    @classmethod
    def build(cls, embeddings: np.ndarray, *, mesh: Optional[Mesh] = None,
              row_axes: Optional[tuple] = None,
              documents: Optional[Sequence[bytes]] = None,
              normalize: bool = True) -> "FlatIndex":
        emb = np.asarray(embeddings, np.float32)
        if normalize:
            emb = emb / np.linalg.norm(emb, axis=-1, keepdims=True)
        if mesh is not None:
            row_axes = row_axes or tuple(mesh.axis_names)
            n_shards = int(np.prod([mesh.shape[a] for a in row_axes]))
            pad = (-emb.shape[0]) % n_shards
            if pad:
                emb = np.concatenate([emb, np.zeros((pad, emb.shape[1]),
                                                    np.float32)])
            sharding = NamedSharding(mesh, P(row_axes, None))
            arr = jax.device_put(jnp.asarray(emb), sharding)
        else:
            arr = jnp.asarray(emb)
        return cls(embeddings=arr, mesh=mesh, row_axes=row_axes,
                   documents=documents)

    def fetch_documents(self, ids: Sequence[int]):
        assert self.documents is not None, "index built without documents"
        return [self.documents[int(i)] for i in ids]

    def rows(self, ids) -> jax.Array:
        """Gather embedding rows by global id (host-driven, small batches)."""
        return jnp.take(self.embeddings, jnp.asarray(ids), axis=0)

    def candidate_cache(self, rlwe_params, config=None):
        """NTT-domain candidate cache for this index under ``rlwe_params``
        (see crypto.rlwe): every document's reversed-chunk plaintext
        forward-NTT'd once, so the encrypted re-rank never re-packs or
        re-NTTs candidates per request.  Built on first use and memoized per
        (RlweParams *value*, config) pair.

        ``config=None`` builds the dense `rlwe.CandidateCache` (the whole
        pool device-resident: 4 * P * N bytes per chunk per row — fine up to
        a few thousand documents).  Passing an `rlwe.CandidateCacheConfig`
        builds the corpus-scale `rlwe.ShardedCandidateCache` instead: shard
        assignment happens here at index-build time (contiguous global-id
        ranges, same layout as the mesh row sharding of ``embeddings``), and
        when the index is mesh-sharded the pinned hot shards inherit a
        row sharding over the same mesh axes (documents per shard must
        divide evenly over the mesh row shards; otherwise shards stay
        unsharded on device).  The config also carries the shard admission
        policy (async background admitter, 2nd-touch frequency threshold —
        see the `rlwe.CandidateCacheConfig` docstring); configs differing
        only in policy share one packed pool but keep separate resident
        sets, since the whole config is part of the memoization key."""
        from repro.crypto import rlwe

        pk = rlwe.params_key(rlwe_params)
        key = (pk, config)
        cache = self._cand_caches.get(key)
        if cache is None:
            # the packed pool (corpus pack + forward NTT) depends only on
            # the params value: any existing cache for pk donates its pool
            # and the new config is just a re-view, not a re-build
            donor = next((c for (p, _), c in self._cand_caches.items()
                          if p == pk), None)
            if config is None:
                cache = (rlwe.densify_candidate_cache(donor)
                         if donor is not None else
                         rlwe.build_candidate_cache(
                             rlwe_params, np.asarray(self.embeddings)))
            else:
                sharding = self._shard_sharding(rlwe_params, config)
                cache = (rlwe.shard_candidate_cache(donor, config, sharding)
                         if donor is not None else
                         rlwe.build_sharded_candidate_cache(
                             rlwe_params, np.asarray(self.embeddings),
                             config=config, sharding=sharding))
            self._cand_caches[key] = cache
        return cache

    def peek_candidate_cache(self, rlwe_params, config=None):
        """The memoized cache for (params value, config) if already built,
        else None — never triggers a build (stats/observability paths)."""
        from repro.crypto import rlwe

        return self._cand_caches.get((rlwe.params_key(rlwe_params), config))

    def slice_view(self, start: int, stop: int) -> IndexSlice:
        """A contiguous row-range view ``[start, stop)`` of this index (the
        replica placement unit — see `IndexSlice`).  The slice materializes
        its rows once here; repeated searches over it never re-gather."""
        if not (0 <= start < stop <= self.num_rows):
            raise ValueError(
                f"slice [{start}, {stop}) out of range for "
                f"{self.num_rows}-row index")
        return IndexSlice(embeddings=self.embeddings[start:stop],
                          start=start, stop=stop)

    def _shard_sharding(self, rlwe_params, config):
        """NamedSharding for a pinned cache shard (doc axis over the mesh
        row axes), or None when the index is unsharded / indivisible."""
        if self.mesh is None:
            return None
        shard_docs = config.resolve_shard_docs(self.num_rows)
        n_shards = int(np.prod([self.mesh.shape[a] for a in self.row_axes]))
        if shard_docs % n_shards or self.num_rows % shard_docs:
            return None
        return NamedSharding(self.mesh, P(self.row_axes, None, None, None))


__all__ = ["FlatIndex", "IndexSlice", "plan_row_slices"]
