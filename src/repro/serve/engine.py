"""Micro-batching request engine for the RemoteRAG protocol.

Requests enqueue via `submit`; `step` forms at most one batch per call using
two triggers — size (a compatible group reached `max_batch`) and deadline
(the group's oldest request waited `max_wait_s`) — and runs the full protocol
for that batch:

  module 1    vmapped DistanceDP perturbation (per-request PRNG keys)
  module 2a   ONE batched score-top-k' kernel invocation over the shared
              index (run first, so sharded-cache shard admissions can be
              prefetched from the candidate ids — the background H2D copy
              overlaps the per-tenant host encryption that follows), then
              per-tenant query encryption (host), batched RLWE re-rank
              against the index's NTT-domain candidate cache (no per-request
              packing/forward NTTs) and batched decryption under per-tenant
              keys
  module 2b/c direct fetch or k-of-k' OT per request (host)

Batches group by (backend, n, k'): the stacked crypto needs equal ciphertext
shapes, which (n, k') pins down.  Every lane is bit-identical to the
sequential `protocol.run_remoterag` driver — same docs, ids and wire bytes —
so `EngineConfig(sequential=True)` exists purely as the latency/throughput
comparison path.

Failure handling: a dispatch that raises loses nothing — the popped
requests go back to the head of their group queue for one retry
(`EngineConfig.max_retries`), after which they come back as `ServeResult`
error results; the batch is recorded in the metrics only on completion.
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import secrets
import time
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

import jax

from repro.core import protocol
from repro.crypto import paillier as pai
from repro.crypto import rlwe
from repro.retrieval.index import FlatIndex
from repro.serve import batching
from repro.serve.metrics import ServeMetrics
from repro.serve.session import Session, SessionManager


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8          # size trigger
    max_wait_s: float = 0.02    # deadline trigger (age of a group's head)
    sequential: bool = False    # comparison path: loop run_remoterag
    use_pallas: Optional[bool] = None
    # RLWE re-rank candidate cache: True = serve from the index's NTT-domain
    # cache, False = cold per-request packing (bit-identical reference).
    use_candidate_cache: bool = True
    # None = dense device-resident cache; an rlwe.CandidateCacheConfig
    # selects the sharded corpus-scale cache (shard size, device-memory
    # budget for LRU-pinned hot shards, admission policy).
    cache_config: Optional["rlwe.CandidateCacheConfig"] = None
    # retries per request after a failed dispatch before the request is
    # returned as an error result (0 = fail immediately, never re-enqueue)
    max_retries: int = 1
    # bounded per-tenant latency/batch-size sample windows (exact totals
    # for counts and wire bytes are kept regardless) — see serve.metrics
    metrics_window: int = 8192


@dataclasses.dataclass
class ServeRequest:
    request_id: int
    tenant: str
    embedding: np.ndarray
    key: jax.Array
    t_enqueue: float
    group: tuple = ()           # queue key, kept for failure re-enqueue
    retries: int = 0            # dispatch attempts already failed


@dataclasses.dataclass
class ServeResult:
    request_id: int
    tenant: str
    docs: List[bytes]
    ids: np.ndarray
    transcript: Optional[protocol.ProtocolTranscript]
    latency_s: float
    batch_size: int
    # None on success; the dispatch failure (repr) after retries exhausted.
    # Failed requests are returned, never silently dropped.
    error: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None


class ServeEngine:
    """Multi-tenant front end over one RemoteRagCloud."""

    config: EngineConfig
    sessions: SessionManager
    cloud: protocol.RemoteRagCloud
    metrics: ServeMetrics

    def __init__(self, index: FlatIndex, *,
                 config: Optional[EngineConfig] = None,
                 sessions: Optional[SessionManager] = None,
                 clock=time.monotonic):
        self.config = EngineConfig() if config is None else config
        # `is None` (not truthiness): an empty SessionManager has len 0
        self.sessions = SessionManager() if sessions is None else sessions
        self.cloud = protocol.RemoteRagCloud(
            index, rlwe_params=self.sessions.rlwe_params,
            use_pallas=self.config.use_pallas,
            use_candidate_cache=self.config.use_candidate_cache,
            cache_config=self.config.cache_config)
        self.metrics = ServeMetrics(window=self.config.metrics_window)
        self._clock = clock
        self._ids = itertools.count()
        # per-group FIFO queues keyed once at submit: dispatch pops from a
        # group head instead of rescanning/rewriting one global list
        self._queues: Dict[tuple, Deque[ServeRequest]] = {}

    # -- session + queue ----------------------------------------------------

    def open_session(self, tenant: str, **session_kwargs) -> Session:
        return self.sessions.open(tenant, **session_kwargs)

    def submit(self, tenant: str, embedding: np.ndarray,
               key: Optional[jax.Array] = None) -> int:
        """Enqueue one query for `tenant` (session must be open).  Returns a
        request id; results come back from step()/drain().

        ``key`` seeds the DistanceDP noise.  The default draws OS entropy —
        a predictable key (e.g. the request counter) would let the cloud
        replay the noise and strip the perturbation; pass an explicit key
        only for replay/parity setups.
        """
        if tenant not in self.sessions:
            # a real error, not an assert: `python -O` strips asserts and a
            # missing session would then surface as an opaque KeyError deep
            # inside dispatch (or worse, silently mis-batch)
            raise KeyError(f"no open session for tenant {tenant!r}; call "
                           f"open_session first")
        rid = next(self._ids)
        if key is None:
            key = jax.random.PRNGKey(secrets.randbits(63))
        sess = self.sessions.get(tenant)
        group = (sess.backend, np.shape(embedding)[-1], sess.plan.kprime)
        self._queues.setdefault(group, collections.deque()).append(
            ServeRequest(
                request_id=rid, tenant=tenant,
                embedding=np.asarray(embedding, np.float32), key=key,
                t_enqueue=self._clock(), group=group))
        return rid

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def cache_stats(self) -> Optional[dict]:
        """LRU / gather counters of the sharded candidate cache (None for
        the dense cache, cold packing, or before the lazy build — this
        never triggers the build itself)."""
        cache = self.cloud.index.peek_candidate_cache(
            self.cloud.rlwe_params, self.cloud.cache_config)
        if isinstance(cache, rlwe.ShardedCandidateCache):
            return cache.stats()
        return None

    # -- dispatch -----------------------------------------------------------

    def step(self, *, force: bool = False) -> List[ServeResult]:
        """Dispatch at most one batch if a trigger fired (or `force`).

        Among triggered groups the one with the oldest head request wins —
        a group that keeps hitting the size trigger must not starve another
        group whose deadline expired."""
        now = self._clock()
        cfg = self.config
        chosen = None
        for key, group in self._queues.items():
            size_hit = len(group) >= cfg.max_batch
            deadline_hit = (now - group[0].t_enqueue) >= cfg.max_wait_s
            if (size_hit or deadline_hit or force) and (
                    chosen is None
                    or group[0].t_enqueue
                    < self._queues[chosen][0].t_enqueue):
                chosen = key
        if chosen is None:
            return []
        group = self._queues[chosen]
        batch = [group.popleft()
                 for _ in range(min(cfg.max_batch, len(group)))]
        if not group:
            del self._queues[chosen]
        return self._dispatch(batch)

    def drain(self) -> List[ServeResult]:
        """Flush the queue completely (batch by batch); results in request
        order."""
        out: List[ServeResult] = []
        while self._queues:
            out.extend(self.step(force=True))
        return sorted(out, key=lambda r: r.request_id)

    def _dispatch(self, batch: Sequence[ServeRequest]) -> List[ServeResult]:
        """Run one batch through the protocol; never lose a request.

        The batch is recorded in the metrics only after it completed — a
        protocol failure must not leave a phantom batch in the dispatch
        stats.  On failure every popped request is accounted for: requests
        with retry budget left go back to the *head* of their group queue
        (so a later step() re-dispatches them in order), the rest come back
        as error results.  The sequential comparison path fails per lane,
        so one poisoned request cannot sink its batchmates."""
        results: List[ServeResult] = []
        failed: List[tuple] = []            # (request, its exception)
        if self.config.sequential:
            for req in batch:
                try:
                    results.append(self._run_one(req))
                except Exception as e:      # noqa: BLE001 — lane-isolated
                    failed.append((req, e))
        else:
            try:
                results = self._run_batched(batch)
            except Exception as e:          # noqa: BLE001 — batch-isolated
                failed = [(req, e) for req in batch]
        if not failed:
            self.metrics.record_batch(len(batch))
        for res in results:
            self.metrics.record(res.tenant, latency_s=res.latency_s,
                                batch_size=res.batch_size,
                                transcript=res.transcript)
        if failed:
            results = results + self._fail_or_requeue(failed, len(batch))
        return results

    def _fail_or_requeue(self, failed: Sequence[tuple],
                         batch_size: int) -> List[ServeResult]:
        """Failure tail of `_dispatch` (``failed`` is (request, exception)
        pairs — each lane keeps *its own* failure): re-enqueue requests
        with retry budget (at the head of their group, preserving request
        order) and turn the rest into error results."""
        self.metrics.record_dispatch_failure(len(failed))
        retry = [(r, e) for r, e in failed
                 if r.retries < self.config.max_retries]
        dead = [(r, e) for r, e in failed
                if r.retries >= self.config.max_retries]
        for req, _ in reversed(retry):      # appendleft: keep id order
            req.retries += 1
            self._queues.setdefault(req.group,
                                    collections.deque()).appendleft(req)
        if retry:
            self.metrics.record_retries(len(retry))
        out = []
        for req, err in dead:
            self.metrics.record_error(req.tenant)
            out.append(ServeResult(
                request_id=req.request_id, tenant=req.tenant, docs=[],
                ids=np.empty(0, np.int64), transcript=None,
                latency_s=self._clock() - req.t_enqueue,
                batch_size=batch_size, error=repr(err)))
        return out

    # -- sequential comparison path ----------------------------------------

    def _run_one(self, req: ServeRequest) -> ServeResult:
        sess = self.sessions.get(req.tenant)
        docs, ids, tr = protocol.run_remoterag(sess.user, self.cloud,
                                               req.embedding, req.key)
        sess.num_requests += 1
        return ServeResult(request_id=req.request_id, tenant=req.tenant,
                           docs=docs, ids=ids, transcript=tr,
                           latency_s=self._clock() - req.t_enqueue,
                           batch_size=1)

    # -- batched protocol path ---------------------------------------------

    def _run_batched(self, batch: Sequence[ServeRequest]) -> List[ServeResult]:
        sessions = [self.sessions.get(r.tenant) for r in batch]
        users = [s.user for s in sessions]
        backend = users[0].backend
        kprime = users[0].plan.kprime
        params = self.sessions.rlwe_params

        # module 1: vmapped DistanceDP over per-request keys / per-tenant eps
        E = np.stack([r.embedding for r in batch])
        pert = batching.perturb_batch([r.key for r in batch], E,
                                      [u.plan.eps for u in users])

        # module 2a, cloud half first: one top-k' kernel call for all lanes.
        # Running it before the host-side encryption surfaces the candidate
        # ids early so sharded-cache shard admissions can be prefetched —
        # the background H2D copy then overlaps the RLWE encrypt work below
        # (the ROADMAP's async-overlap item, applied to data movement).
        # Bit-identity is unaffected: top-k' consumes only the perturbed
        # embeddings, never the tenants' rng streams.
        res = batching.topk_batch(self.cloud.index, pert, kprime,
                                  use_pallas=self.config.use_pallas)
        cand_ids = np.asarray(res.indices)                    # (B, k')
        if backend == "rlwe":
            cache = self.cloud.candidate_cache
            if isinstance(cache, rlwe.ShardedCandidateCache):
                cache.prefetch(cand_ids)

        # module 2a, user half: encrypt queries (host, submission order so
        # each tenant's rng stream matches the sequential path)
        wire_reqs = [
            protocol.Request(perturbed=pb, kprime=kprime,
                             enc_query=user.encrypt_query(req.embedding),
                             backend=backend)
            for user, req, pb in zip(users, batch, pert)]
        # module 2a, cloud half continued: one batched encrypted re-rank.
        # The RLWE path hits the index's NTT-domain candidate cache — dense
        # (one device take) or sharded (batched lanes gather only their k'
        # rows from the shard pool; prefetched admissions may already have
        # swapped the hot shards in) — no per-request packing or candidate
        # forward NTTs either way.
        if backend == "rlwe":
            if cache is not None:
                enc_stack = batching.encrypted_scores_cached_batch(
                    params, [w.enc_query for w in wire_reqs], cache,
                    cand_ids, use_pallas=self.config.use_pallas)
            else:                         # cold reference path
                rows = np.asarray(
                    self.cloud.index.rows(cand_ids.reshape(-1)))
                cand_rows = rows.reshape(len(batch), kprime, -1)
                packed = batching.pack_candidates_batch(params, cand_rows)
                enc_stack = batching.encrypted_scores_batch_stacked(
                    params, [w.enc_query for w in wire_reqs], packed,
                    num_cands=kprime, n_dim=cand_rows.shape[-1],
                    use_pallas=self.config.use_pallas)
            encs = enc_stack.lanes()
        else:
            rows = np.asarray(self.cloud.index.rows(cand_ids.reshape(-1)))
            cand_rows = rows.reshape(len(batch), kprime, -1)
            encs = [pai.encrypted_scores(u.sk.pub, w.enc_query, cr)
                    for u, w, cr in zip(users, wire_reqs, cand_rows)]
        replies = [protocol.Reply(candidate_ids=cand_ids[b], enc_scores=encs[b])
                   for b in range(len(batch))]

        # back on the users: batched decryption (per-tenant keys) + sort
        if backend == "rlwe":
            scores_list = batching.decrypt_scores_batch(
                [u.sk for u in users], enc_stack,
                use_pallas=self.config.use_pallas)
        else:
            scores_list = [pai.decrypt_scores(u.sk, e)
                           for u, e in zip(users, encs)]

        results = []
        for sess, user, req, wreq, reply, scores in zip(
                sessions, users, batch, wire_reqs, replies, scores_list):
            positions = user.positions_from_scores(
                scores, len(reply.candidate_ids))
            docs, ids, tr = protocol.finish_request(
                user, self.cloud, wreq, reply, positions)
            sess.num_requests += 1
            results.append(ServeResult(
                request_id=req.request_id, tenant=req.tenant, docs=docs,
                ids=ids, transcript=tr,
                latency_s=self._clock() - req.t_enqueue,
                batch_size=len(batch)))
        return results


__all__ = ["EngineConfig", "ServeRequest", "ServeResult", "ServeEngine"]
