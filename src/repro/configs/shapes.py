"""Assigned input-shape sets, per architecture family.

Every (arch x shape) pair is one dry-run cell.  LM ``decode_*`` / ``long_*``
shapes lower `serve_step` (one token against a KV cache), not `train_step`.
Graph sizes are padded up to multiples of 1024 so every mesh shard is even.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _pad(x: int, to: int = 1024) -> int:
    return -(-x // to) * to


@dataclasses.dataclass(frozen=True)
class LmShape:
    name: str
    kind: str              # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


LM_SHAPES = {
    "train_4k": LmShape("train_4k", "train", 4096, 256),
    "prefill_32k": LmShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": LmShape("decode_32k", "decode", 32768, 128),
    "long_500k": LmShape("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class GraphShape:
    name: str
    kind: str              # "full" | "minibatch" | "batched_small"
    n_nodes: int
    n_edges: int
    d_feat: int
    batch_nodes: Optional[int] = None
    fanout: Optional[Tuple[int, ...]] = None


GNN_SHAPES = {
    # cora-scale full batch (2708 /10556 padded)
    "full_graph_sm": GraphShape("full_graph_sm", "full",
                                _pad(2708), _pad(10556), 1433),
    # reddit-scale sampled training: static upper bounds for fanout 15-10
    # seeds 1024 -> <=1024*15 L1 edges -> <=15360*10 L2 edges
    "minibatch_lg": GraphShape("minibatch_lg", "minibatch",
                               _pad(1024 * (1 + 15 + 150)),   # 170k nodes
                               _pad(1024 * 15 + 15360 * 10),  # 169k edges
                               512, batch_nodes=1024, fanout=(15, 10)),
    "ogb_products": GraphShape("ogb_products", "full",
                               _pad(2_449_029), _pad(61_859_140), 100),
    # 128 graphs x (30 nodes, 64 edges), flattened with block-diag edges
    "molecule": GraphShape("molecule", "batched_small",
                           _pad(30 * 128), _pad(64 * 128), 32),
}


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    kind: str              # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: Optional[int] = None


RECSYS_SHAPES = {
    "train_batch": RecsysShape("train_batch", "train", 65_536),
    "serve_p99": RecsysShape("serve_p99", "serve", 512),
    "serve_bulk": RecsysShape("serve_bulk", "serve", 262_144),
    "retrieval_cand": RecsysShape("retrieval_cand", "retrieval", 1,
                                  n_candidates=1_000_000),
}


@dataclasses.dataclass(frozen=True)
class RagShape:
    name: str
    kind: str              # "module1" | "module2"
    corpus: int = 0
    dim: int = 768
    batch: int = 1
    kprime: int = 160


REMOTERAG_SHAPES = {
    # Module 1: plaintext top-k' scoring over the sharded corpus
    "module1_1m": RagShape("module1_1m", "module1", corpus=2 ** 20, dim=768,
                           batch=32, kprime=160),
    # Module 2a: batched encrypted re-ranking (256 concurrent requests)
    "module2_b256": RagShape("module2_b256", "module2", batch=256,
                             dim=768, kprime=160),
}


__all__ = ["LmShape", "LM_SHAPES", "GraphShape", "GNN_SHAPES",
           "RecsysShape", "RECSYS_SHAPES", "RagShape", "REMOTERAG_SHAPES"]
