"""repro.serve engine: batched == sequential parity, micro-batch triggers,
plan cache, metrics accounting."""

import math
import threading
import time

import numpy as np
import pytest

import jax

from repro import obs
from repro.crypto import rlwe
from repro.data import synth
from repro.retrieval.index import FlatIndex
from repro.serve import EngineConfig, ServeEngine
from repro.serve.session import PlanCache, SessionManager

N_DOCS, DIM, K = 1500, 64, 4
N_REQ = 8
TENANTS = ("alice", "bob", "carol")
# small ring keeps the CPU NTTs fast; semantics identical to the default
PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    emb = synth.uniform_corpus(rng, N_DOCS, DIM)
    docs = [f"passage-{i}".encode() for i in range(N_DOCS)]
    index = FlatIndex.build(emb, documents=docs)
    queries = synth.queries_near_corpus(rng, emb, N_REQ)
    return index, emb, queries


def _build(index, *, sequential, max_batch, clock=None, backend="rlwe",
           **config_kw):
    kw = {"clock": clock} if clock is not None else {}
    eng = ServeEngine(
        index,
        config=EngineConfig(max_batch=max_batch, max_wait_s=30.0,
                            sequential=sequential, **config_kw),
        sessions=SessionManager(rlwe_params=PARAMS,
                                deterministic_seeds=True), **kw)
    session_kw = {"paillier_bits": 256} if backend == "paillier" else {}
    for t in TENANTS:
        eng.open_session(t, n=DIM, N=N_DOCS, k=K, radius=0.05,
                         backend=backend, **session_kw)
    return eng


def _run(index, queries, *, sequential, max_batch, **config_kw):
    eng = _build(index, sequential=sequential, max_batch=max_batch,
                 **config_kw)
    for i, q in enumerate(queries):
        eng.submit(TENANTS[i % len(TENANTS)], q, key=jax.random.PRNGKey(i))
    return eng, eng.drain()


def test_batched_matches_sequential_across_batch_sizes(corpus):
    """Same docs / ids / wire bytes at batch sizes 1, 3, 8 as the sequential
    run_remoterag path — the batched crypto is bit-compatible."""
    index, emb, queries = corpus
    _, seq = _run(index, queries, sequential=True, max_batch=1)
    assert [r.batch_size for r in seq] == [1] * N_REQ
    for max_batch in (1, 3, 8):
        eng, got = _run(index, queries, sequential=False,
                        max_batch=max_batch)
        assert len(got) == N_REQ
        assert max(r.batch_size for r in got) == min(max_batch, N_REQ)
        for rs, rb in zip(seq, got):
            assert rs.request_id == rb.request_id
            assert rs.ids.tolist() == rb.ids.tolist()
            assert rs.docs == rb.docs
            assert (rs.transcript.total_bytes
                    == rb.transcript.total_bytes)
            assert (rs.transcript.request_bytes
                    == rb.transcript.request_bytes)
            assert rs.transcript.reply_bytes == rb.transcript.reply_bytes


def test_batched_results_match_plaintext_oracle(corpus):
    index, emb, queries = corpus
    _, got = _run(index, queries, sequential=False, max_batch=8)
    for res in got:
        q = queries[res.request_id]
        oracle = np.argsort(-(emb @ q), kind="stable")[:K]
        assert set(res.ids.tolist()) == set(oracle.tolist())
        assert res.docs == [f"passage-{i}".encode() for i in res.ids]


def test_plan_cache_hits_for_repeat_tenants():
    cache = PlanCache()
    mgr = SessionManager(rlwe_params=PARAMS, plan_cache=cache)
    a = mgr.open("a", n=DIM, N=N_DOCS, k=K, radius=0.05)
    assert (cache.hits, cache.misses) == (0, 1)
    b = mgr.open("b", n=DIM, N=N_DOCS, k=K, radius=0.05)
    assert (cache.hits, cache.misses) == (1, 1)
    assert a.plan is b.plan          # cached object reused, no re-planning
    assert a.user.sk is not b.user.sk  # but keys stay per-tenant
    mgr.open("c", n=DIM, N=N_DOCS, k=K, radius=0.09)
    assert cache.misses == 2         # different knobs -> new plan
    # re-opening an existing tenant with identical knobs is a no-op ...
    assert mgr.open("a", n=DIM, N=N_DOCS, k=K, radius=0.05) is a
    # ... but changing the knobs of a live session is an error
    with pytest.raises(ValueError, match="different knobs"):
        mgr.open("a", n=DIM, N=N_DOCS, k=K, radius=0.09)


def test_paillier_batched_matches_sequential(corpus):
    """The paillier backend rides the same staged pipeline through the
    crypto-backend seam (vectorized RNS crypto on the batched path, the
    object path sequentially); parity must hold down to the wire bytes,
    incl. deterministic keygen."""
    index, emb, queries = corpus

    def run(sequential):
        eng = ServeEngine(
            index,
            config=EngineConfig(max_batch=4, max_wait_s=30.0,
                                sequential=sequential),
            sessions=SessionManager(rlwe_params=PARAMS,
                                    deterministic_seeds=True))
        for t in TENANTS[:2]:
            eng.open_session(t, n=DIM, N=N_DOCS, k=K, radius=0.05,
                             backend="paillier", paillier_bits=256)
        for i in range(4):
            eng.submit(TENANTS[i % 2], queries[i], key=jax.random.PRNGKey(i))
        return eng.drain()

    seq, got = run(True), run(False)
    assert [r.batch_size for r in got] == [4] * 4
    for rs, rb in zip(seq, got):
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
        assert rs.transcript.total_bytes == rb.transcript.total_bytes


def test_size_and_deadline_triggers(corpus):
    index, _, queries = corpus
    now = [0.0]
    eng = _build(index, sequential=False, max_batch=3,
                 clock=lambda: now[0])
    eng.config = EngineConfig(max_batch=3, max_wait_s=5.0, sequential=False)
    eng.submit("alice", queries[0], key=jax.random.PRNGKey(0))
    eng.submit("bob", queries[1], key=jax.random.PRNGKey(1))
    assert eng.step() == []          # neither trigger fired
    assert eng.pending == 2
    eng.submit("carol", queries[2], key=jax.random.PRNGKey(2))
    out = eng.step()                 # size trigger: 3 == max_batch
    assert len(out) == 3 and eng.pending == 0
    eng.submit("alice", queries[3], key=jax.random.PRNGKey(3))
    assert eng.step() == []
    now[0] += 6.0                    # age past the deadline
    out = eng.step()
    assert len(out) == 1 and out[0].batch_size == 1


def test_metrics_accounting(corpus):
    index, _, queries = corpus
    eng, got = _run(index, queries, sequential=False, max_batch=8)
    summary = eng.metrics.summary()
    agg = summary["aggregate"]
    assert agg["count"] == N_REQ
    assert set(summary["tenants"]) == set(TENANTS)
    per_tenant = sum(s["count"] for s in summary["tenants"].values())
    assert per_tenant == N_REQ
    want_wire = sum(r.transcript.total_bytes for r in got)
    assert eng.metrics.aggregate.total_wire_bytes == want_wire
    assert agg["p99_latency_s"] >= agg["p50_latency_s"] >= 0
    assert "failures" not in summary         # clean run: no failure block


def test_submit_without_session_raises_keyerror(corpus):
    """A missing session is a real error, not an assert (`python -O`
    strips asserts, which would turn this into silent mis-batching)."""
    index, _, queries = corpus
    eng = _build(index, sequential=False, max_batch=2)
    with pytest.raises(KeyError, match="nobody"):
        eng.submit("nobody", queries[0])
    # a (1, n) embedding would group with (n,) requests (the key uses the
    # last axis) and then blow up the batch stack mid-dispatch — rejected
    # at submit instead
    with pytest.raises(ValueError, match="1-D"):
        eng.submit(TENANTS[0], queries[0][None, :])


class _FaultyFetch:
    """Fault-injecting cloud seam: `handle_fetch` raises the first
    ``fail_times`` calls, then delegates — the failure lands mid-dispatch,
    after the crypto, exactly where a lost batch would hurt most."""

    def __init__(self, cloud, fail_times):
        self.cloud = cloud
        self.remaining = fail_times
        self.calls = 0

    def __call__(self, cand_ids, msg):
        self.calls += 1
        if self.remaining:
            self.remaining -= 1
            raise RuntimeError("injected cloud fault")
        return type(self.cloud).handle_fetch(self.cloud, cand_ids, msg)


class _PoisonIds:
    """Persistently poison ONE lane: raise whenever the fetch resolves to
    the poisoned request's result ids (its batched lane *and* its solo
    quarantine retry fail; every other lane's fetch delegates)."""

    def __init__(self, cloud, poison_ids):
        self.cloud = cloud
        self.poison_ids = list(poison_ids)

    def __call__(self, cand_ids, msg):
        ids = [int(cand_ids[p]) for p in msg.positions]
        if ids == self.poison_ids:
            raise RuntimeError("persistently poisoned lane")
        return type(self.cloud).handle_fetch(self.cloud, cand_ids, msg)


def test_single_poisoned_lane_in_full_batch(corpus):
    """One persistently poisoned lane in a batch of 8: exactly that request
    errors, the other 7 succeed bit-identically to the sequential path, no
    healthy lane is encrypted twice, and the metrics record exactly one
    batch (no phantom or duplicate batches)."""
    index, _, queries = corpus
    _, want = _run(index, queries, sequential=True, max_batch=1)
    # distinct result sets per request, so ids identify the poisoned lane
    assert len({tuple(r.ids.tolist()) for r in want}) == N_REQ
    eng = _build(index, sequential=False, max_batch=8)
    eng.cloud.handle_fetch = _PoisonIds(eng.cloud, want[0].ids.tolist())
    for i, q in enumerate(queries):
        eng.submit(TENANTS[i % len(TENANTS)], q, key=jax.random.PRNGKey(i))
    got = eng.drain()
    assert len(got) == N_REQ
    bad = [r for r in got if not r.ok]
    assert [r.request_id for r in bad] == [0]
    assert "persistently poisoned lane" in bad[0].error
    assert bad[0].quarantined and bad[0].docs == [] and bad[0].ids.size == 0
    for rs, rb in zip(want[1:], got[1:]):
        assert rb.ok and not rb.quarantined
        assert rs.request_id == rb.request_id
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
        assert rs.transcript.total_bytes == rb.transcript.total_bytes
        assert rs.transcript.request_bytes == rb.transcript.request_bytes
        assert rs.transcript.reply_bytes == rb.transcript.reply_bytes
    m = eng.metrics
    assert m.num_batches == 1 and list(m.dispatch_sizes) == [N_REQ]
    assert m.failed_dispatches == 0
    assert m.quarantined_lanes == 1 and m.retried_requests == 1
    assert m.quarantined_retry_ok == 0 and m.error_results == 1
    # 8 batched lane encryptions + 1 solo-retry encryption; the 7 healthy
    # lanes were each encrypted exactly once
    assert m.lane_encryptions == N_REQ + 1
    assert m.healthy_reencryptions == 0
    assert m.aggregate.count == N_REQ - 1       # healthy lanes, once each
    # occupancy counts *completed* lanes: the quarantined one is lost fill
    assert m.dispatch_lanes == N_REQ - 1
    assert m.occupancy(N_REQ) == (N_REQ - 1) / N_REQ
    assert eng.pending == 0


def test_paillier_poisoned_lane_isolated_like_rlwe(corpus):
    """Fault isolation is backend-neutral through the crypto seam: one
    persistently poisoned lane in a paillier batch of 8 errors alone,
    its 7 batchmates complete bit-identically to the sequential path, no
    healthy lane is re-encrypted — exactly the rlwe contract."""
    index, _, queries = corpus
    _, want = _run(index, queries, sequential=True, max_batch=1,
                   backend="paillier")
    eng = _build(index, sequential=False, max_batch=8, backend="paillier")
    eng.cloud.handle_fetch = _PoisonIds(eng.cloud, want[0].ids.tolist())
    for i, q in enumerate(queries):
        eng.submit(TENANTS[i % len(TENANTS)], q, key=jax.random.PRNGKey(i))
    got = eng.drain()
    assert len(got) == N_REQ
    bad = [r for r in got if not r.ok]
    assert [r.request_id for r in bad] == [0]
    assert bad[0].quarantined
    for rs, rb in zip(want[1:], got[1:]):
        assert rb.ok and not rb.quarantined
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
        assert rs.transcript.total_bytes == rb.transcript.total_bytes
    m = eng.metrics
    assert m.quarantined_lanes == 1 and m.error_results == 1
    assert m.lane_encryptions == N_REQ + 1
    assert m.healthy_reencryptions == 0


def test_paillier_traced_run_covers_same_stages(corpus):
    """Tracing is backend-neutral through the crypto seam: a traced
    paillier batch emits the same core stage spans as rlwe, the score
    spans carry backend="paillier", and tracing changes nothing."""
    index, _, queries = corpus
    _, base = _run(index, queries, sequential=False, max_batch=8,
                   backend="paillier")
    eng, got = _run(index, queries, sequential=False, max_batch=8,
                    backend="paillier", trace=True)
    assert len(got) == N_REQ and all(r.ok for r in got)
    for rb, rt in zip(base, got):
        assert rb.ids.tolist() == rt.ids.tolist()
        assert rb.transcript.total_bytes == rt.transcript.total_bytes
    spans = eng.tracer.spans()
    names = {s.name for s in spans}
    assert {"queue_wait", "dispatch", "perturb", "topk", "encrypt",
            "score", "decrypt", "finish"} <= names
    score_spans = [s for s in spans if s.name == "score"]
    assert score_spans
    assert all(s.attrs.get("backend") == "paillier" for s in score_spans)


def test_poison_that_disappears_on_retry(corpus):
    """A transient lane fault quarantines only that lane: its batchmates
    complete from their already-computed state (never re-encrypted), the
    quarantined lane heals on its solo retry and is recorded exactly once,
    with latency measured from the original submit."""
    index, _, queries = corpus
    _, want = _run(index, queries, sequential=False, max_batch=8)
    eng = _build(index, sequential=False, max_batch=8)
    eng.cloud.handle_fetch = _FaultyFetch(eng.cloud, fail_times=1)
    for i, q in enumerate(queries):
        eng.submit(TENANTS[i % len(TENANTS)], q, key=jax.random.PRNGKey(i))
    got = eng.drain()
    assert len(got) == N_REQ and all(r.ok for r in got)
    healed = [r for r in got if r.quarantined]
    assert [r.request_id for r in healed] == [0]
    for rs, rb in zip(want, got):
        assert rs.request_id == rb.request_id
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
    m = eng.metrics
    # one real batch; the solo retry is not a batch of its own, and the
    # quarantined lane is not counted as completed in-batch fill
    assert m.num_batches == 1 and list(m.dispatch_sizes) == [N_REQ]
    assert m.dispatch_lanes == N_REQ - 1
    assert m.failed_dispatches == 0
    assert m.quarantined_lanes == 1 and m.retried_requests == 1
    assert m.quarantined_retry_ok == 1 and m.error_results == 0
    # recorded once per request — no double count for the healed lane
    assert m.aggregate.count == N_REQ
    assert m.healthy_reencryptions == 0
    assert m.lane_encryptions == N_REQ + 1      # only the healed lane twice
    summary = eng.metrics.summary()
    healed_tenant = summary["tenants"][healed[0].tenant]
    assert healed_tenant["quarantined_retry_ok"] == 1
    assert "errors" not in healed_tenant        # healed != terminal error
    assert eng.pending == 0


def test_dispatch_failure_after_retries_returns_error_results(corpus):
    """When the cloud keeps failing for every lane, drain() still
    terminates and hands every request back as an error result — zero
    requests lost, zero phantom batches recorded."""
    index, _, queries = corpus
    eng = _build(index, sequential=False, max_batch=3)
    eng.cloud.handle_fetch = _FaultyFetch(eng.cloud, fail_times=10**9)
    rids = [eng.submit(TENANTS[i], queries[i], key=jax.random.PRNGKey(i))
            for i in range(3)]
    got = eng.drain()
    assert [r.request_id for r in got] == rids
    assert all(not r.ok for r in got)
    assert all("injected cloud fault" in r.error for r in got)
    assert all(r.docs == [] and r.ids.size == 0 and r.transcript is None
               for r in got)
    assert eng.pending == 0
    assert eng.metrics.num_batches == 0      # no phantom batches
    assert eng.metrics.failed_dispatches == 1    # all lanes quarantined
    assert eng.metrics.quarantined_lanes == 3
    assert eng.metrics.retried_requests == 3     # one solo retry each
    assert eng.metrics.quarantined_retry_ok == 0
    summary = eng.metrics.summary()
    assert summary["failures"]["error_results"] == 3
    assert eng.metrics.aggregate.errors == 3
    # error-only tenants have no latency samples — their summaries (and the
    # aggregate's) must degrade gracefully, not crash on an empty window
    assert summary["aggregate"] == {"count": 0, "errors": 3}
    for t in TENANTS:
        assert summary["tenants"][t] == {"count": 0, "errors": 1}
    # the engine stays healthy: un-fault the cloud and serve again
    eng.cloud.handle_fetch = _FaultyFetch(eng.cloud, fail_times=0)
    eng.submit(TENANTS[0], queries[0], key=jax.random.PRNGKey(0))
    ok = eng.drain()
    assert len(ok) == 1 and ok[0].ok


def test_batched_stage_fault_is_bisected_to_one_lane(corpus, monkeypatch):
    """A fault inside a *batched* stage (here: the vmapped DistanceDP
    perturbation) is attributed by bisection to the one offending lane:
    its batchmates survive the same dispatch, and the quarantined lane
    heals on the solo sequential retry (which does not use the batched
    seam).  The poisoned lane never reached encryption, so no healthy
    crypto is wasted."""
    from repro.serve import batching as batching_mod

    index, _, queries = corpus
    poison_q = np.asarray(queries[2], np.float32)
    real = batching_mod.perturb_batch

    def poisoned(keys, E, epss):
        if any(np.array_equal(row, poison_q) for row in np.asarray(E)):
            raise RuntimeError("poisoned batched stage")
        return real(keys, E, epss)

    monkeypatch.setattr(batching_mod, "perturb_batch", poisoned)
    _, want = _run(index, queries, sequential=True, max_batch=1)
    eng = _build(index, sequential=False, max_batch=8)
    for i, q in enumerate(queries):
        eng.submit(TENANTS[i % len(TENANTS)], q, key=jax.random.PRNGKey(i))
    got = eng.drain()
    assert len(got) == N_REQ and all(r.ok for r in got)
    assert [r.request_id for r in got if r.quarantined] == [2]
    for rs, rb in zip(want, got):
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
    m = eng.metrics
    assert m.quarantined_lanes == 1 and m.quarantined_retry_ok == 1
    # 7 healthy batched encryptions + 1 solo-retry encryption; the
    # quarantined lane was dropped before the encrypt stage
    assert m.lane_encryptions == N_REQ
    assert m.healthy_reencryptions == 0
    assert m.num_batches == 1 and list(m.dispatch_sizes) == [N_REQ]


def test_batch_only_heisenbug_heals_without_quarantine(corpus, monkeypatch):
    """A fault that only manifests on multi-lane invocations (a batch-only
    heisenbug) bisects down to singleton re-runs that all succeed: every
    lane completes, nothing is quarantined, and — because the fault sat in
    a pre-encryption stage — no query is encrypted twice."""
    from repro.serve import batching as batching_mod

    index, _, queries = corpus
    real = batching_mod.topk_batch

    def flaky(index_, pert, kprime, *, use_pallas=None, nprobe=None):
        if np.shape(pert)[0] > 1:
            raise RuntimeError("batch-only fault")
        return real(index_, pert, kprime, use_pallas=use_pallas,
                    nprobe=nprobe)

    monkeypatch.setattr(batching_mod, "topk_batch", flaky)
    _, want = _run(index, queries, sequential=True, max_batch=1)
    eng = _build(index, sequential=False, max_batch=8)
    for i, q in enumerate(queries):
        eng.submit(TENANTS[i % len(TENANTS)], q, key=jax.random.PRNGKey(i))
    got = eng.drain()
    assert len(got) == N_REQ and all(r.ok for r in got)
    assert not any(r.quarantined for r in got)
    for rs, rb in zip(want, got):
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
    m = eng.metrics
    assert m.quarantined_lanes == 0 and m.error_results == 0
    assert m.lane_encryptions == N_REQ and m.healthy_reencryptions == 0


def test_sequential_dispatch_isolates_poisoned_lane(corpus):
    """On the sequential comparison path a single poisoned request must not
    sink its batchmates: healthy lanes complete, the poisoned one errors
    after its solo quarantine retry."""
    index, _, queries = corpus
    eng = _build(index, sequential=True, max_batch=3)
    # fail exactly the 2nd request and its retry: lane order is r0(1),
    # r1(2, fails), r2(3) — the lane loop continues past the failure —
    # then the quarantined r1 retries solo as call 4 and fails for good
    calls = [0]

    def poisoned(cand_ids, msg):
        calls[0] += 1
        if calls[0] in (2, 4):
            raise RuntimeError("poisoned lane")
        return type(eng.cloud).handle_fetch(eng.cloud, cand_ids, msg)
    eng.cloud.handle_fetch = poisoned
    for i in range(3):
        eng.submit(TENANTS[i], queries[i], key=jax.random.PRNGKey(i))
    got = eng.drain()
    assert len(got) == 3
    oks = [r for r in got if r.ok]
    bad = [r for r in got if not r.ok]
    assert len(oks) == 2 and len(bad) == 1
    assert "poisoned lane" in bad[0].error and bad[0].quarantined


def _refill_engine(index, clock, *, max_batch=3, max_wait_s=5.0):
    eng = ServeEngine(
        index,
        config=EngineConfig(max_batch=max_batch, max_wait_s=max_wait_s,
                            sequential=False),
        sessions=SessionManager(rlwe_params=PARAMS,
                                deterministic_seeds=True), clock=clock)
    for t in TENANTS:
        eng.open_session(t, n=DIM, N=N_DOCS, k=K, radius=0.05,
                         backend="rlwe")
    return eng


def test_refill_admits_compatible_request_immediately(corpus):
    """A group whose batch dispatched under max_batch holds a refill
    credit: a compatible request arriving within the batching window is
    dispatched by the next step() immediately, without aging out
    max_wait_s again.  The credit expires after one window."""
    index, _, queries = corpus
    now = [0.0]
    eng = _refill_engine(index, lambda: now[0])
    eng.submit("alice", queries[0], key=jax.random.PRNGKey(0))
    eng.submit("bob", queries[1], key=jax.random.PRNGKey(1))
    assert eng.step() == []              # neither trigger fired
    now[0] = 5.0                         # deadline: partial batch of 2 < 3
    assert len(eng.step()) == 2
    # refill: a compatible late arrival does not wait out a new deadline
    eng.submit("carol", queries[2], key=jax.random.PRNGKey(2))
    now[0] = 5.001
    out = eng.step()
    assert len(out) == 1 and out[0].ok
    assert eng.metrics.refill_dispatches == 1
    assert eng.metrics.refilled_requests == 1
    # a refill dispatch must not re-grant the credit (it would self-renew
    # and the group would never form a real batch again): the next arrival
    # is back to normal size/deadline batching
    now[0] = 5.002
    eng.submit("alice", queries[3], key=jax.random.PRNGKey(3))
    assert eng.step() == []              # no credit: back to batching
    assert eng.metrics.refill_dispatches == 1
    now[0] = 10.002                      # its own deadline fires normally
    assert len(eng.step()) == 1
    assert eng.metrics.refill_dispatches == 1
    # ... and a deadline-granted credit expires after one batching window
    now[0] = 15.2                        # credit from 10.002 expired at
    eng.submit("bob", queries[4], key=jax.random.PRNGKey(4))
    assert eng.step() == []              # 15.002; request age is only 0
    assert eng.metrics.refill_dispatches == 1
    now[0] = 20.2
    assert len(eng.step()) == 1          # deadline again
    assert eng.metrics.summary()["refills"]["refill_dispatches"] == 1


def test_refill_serves_burst_tail(corpus):
    """A full size-triggered dispatch that leaves requests queued grants a
    credit too: the burst tail rides the next step() instead of waiting
    out the deadline (and the refill dispatch does not re-grant)."""
    index, _, queries = corpus
    now = [0.0]
    eng = _refill_engine(index, lambda: now[0])
    for i in range(4):
        eng.submit(TENANTS[i % 3], queries[i], key=jax.random.PRNGKey(i))
    assert len(eng.step()) == 3          # size trigger: 3 of the 4
    now[0] = 0.001
    out = eng.step()                     # tail of 1 rides the credit
    assert len(out) == 1 and out[0].ok
    assert eng.metrics.refill_dispatches == 1
    assert eng.metrics.refilled_requests == 1
    now[0] = 0.002                       # no self-renewal from the refill
    eng.submit("alice", queries[4], key=jax.random.PRNGKey(4))
    assert eng.step() == []


def test_refill_ignores_incompatible_group(corpus):
    """A refill credit belongs to the (backend, n, k') group that earned
    it: an incompatible request (paillier backend here, so a different
    group key) must wait out its own triggers."""
    index, _, queries = corpus
    now = [0.0]
    eng = _refill_engine(index, lambda: now[0])
    eng.open_session("dora", n=DIM, N=N_DOCS, k=K, radius=0.05,
                     backend="paillier", paillier_bits=256)
    eng.submit("alice", queries[0], key=jax.random.PRNGKey(0))
    now[0] = 5.0
    assert len(eng.step()) == 1          # partial dispatch -> rlwe credit
    # incompatible arrival: different (backend, n, k') group, no credit
    eng.submit("dora", queries[1], key=jax.random.PRNGKey(1))
    now[0] = 5.001
    assert eng.step() == []              # must not ride the rlwe credit
    assert eng.metrics.refill_dispatches == 0
    now[0] = 5.001 + 5.0                 # its own deadline
    out = eng.step()
    assert len(out) == 1 and out[0].ok


def test_close_drains_and_stops_admitter(corpus):
    """`close()` (and the context manager) drains pending work, stops the
    sharded cache's background admitter thread, and rejects further
    submissions; close is idempotent."""
    index, _, queries = corpus
    cfg = EngineConfig(
        max_batch=4, max_wait_s=30.0,
        cache_config=rlwe.CandidateCacheConfig(num_shards=4))
    with ServeEngine(index, config=cfg,
                     sessions=SessionManager(
                         rlwe_params=PARAMS,
                         deterministic_seeds=True)) as eng:
        for t in TENANTS:
            eng.open_session(t, n=DIM, N=N_DOCS, k=K, radius=0.05,
                             backend="rlwe")
        for i in range(3):
            eng.submit(TENANTS[i], queries[i], key=jax.random.PRNGKey(i))
        out = eng.close()                # drains the queued requests
        assert len(out) == 3 and all(r.ok for r in out)
        cache = eng.cloud.index.peek_candidate_cache(
            eng.cloud.rlwe_params, eng.cloud.cache_config)
        assert isinstance(cache, rlwe.ShardedCandidateCache)
        worker = cache._worker
        assert worker is None or not worker.is_alive()
        with pytest.raises(RuntimeError, match="closed"):
            eng.submit(TENANTS[0], queries[0])
        assert eng.close() == []         # idempotent
    # __exit__ re-closes (a no-op); the engine object stays inspectable
    assert eng.metrics.aggregate.count == 3


def test_metrics_window_bounded():
    """Latency/batch samples are windowed (no unbounded growth under the
    million-user north star) while counts and byte totals stay exact."""
    from repro.core.protocol import ProtocolTranscript
    from repro.serve.metrics import ServeMetrics

    m = ServeMetrics(window=4)
    tr = ProtocolTranscript(plan=None, path="direct", request_bytes=10,
                            reply_bytes=5, fetch_bytes=1, docs_bytes=2,
                            ot_wire_bytes=0)
    for i in range(10):
        m.record("t", latency_s=float(i), batch_size=2, transcript=tr)
        m.record_batch(2)
    agg = m.aggregate
    assert agg.count == 10                       # exact total
    assert agg.total_wire_bytes == 10 * 18       # exact total
    assert len(agg.latencies_s) == 4             # bounded window
    assert list(agg.latencies_s) == [6.0, 7.0, 8.0, 9.0]
    assert agg.percentile(50) == 7.5             # over the window
    assert m.num_batches == 10 and len(m.dispatch_sizes) == 4
    assert m.summary()["aggregate"]["count"] == 10
    with pytest.raises(ValueError, match="window"):
        ServeMetrics(window=0).record("t", latency_s=0.0, batch_size=1,
                                      transcript=tr)


def test_tenant_percentile_nan_on_empty_window():
    """An error-only (or untouched) tenant has no latency samples;
    percentile must read as NaN, never an opaque numpy error."""
    from repro.serve.metrics import ServeMetrics, TenantStats

    stats = TenantStats(window=4)
    assert math.isnan(stats.percentile(50))
    assert math.isnan(stats.percentile(99))
    assert stats.summary() == {"count": 0}
    # the summary of an error-only tenant includes the error count but
    # never calls percentile on the empty window
    m = ServeMetrics()
    m.record_error("ghost")
    summ = m.summary()
    assert summ["tenants"]["ghost"] == {"count": 0, "errors": 1}
    assert math.isnan(m.aggregate.percentile(50))


def test_summary_always_surfaces_healthy_reencryptions():
    """healthy_reencryptions is the CI-gated isolation contract: a nonzero
    value must surface in summary() even when every other failure counter
    is zero (a healthy-looking run that silently re-encrypted would
    otherwise hide its contract breach)."""
    from repro.serve.metrics import ServeMetrics

    m = ServeMetrics()
    assert "failures" not in m.summary()         # clean run stays compact
    m.record_healthy_reencryptions(2)
    failures = m.summary()["failures"]
    assert failures["healthy_reencryptions"] == 2
    assert failures["quarantined_lanes"] == 0    # the only nonzero trigger


def test_metrics_occupancy_and_window_edges():
    from repro.core.protocol import ProtocolTranscript
    from repro.serve.metrics import ServeMetrics

    m = ServeMetrics()
    assert m.occupancy(8) is None                # no batches yet
    m.record_batch(8, completed=5)               # 3 lanes quarantined away
    assert m.occupancy(8) == pytest.approx(5 / 8)
    m.record_batch(8)                            # full batch, all completed
    assert m.occupancy(8) == pytest.approx(13 / 16)
    assert m.occupancy(0) is None                # degenerate max_batch

    # window=1 is the tightest legal window: every sample evicts the last
    tr = ProtocolTranscript(plan=None, path="direct", request_bytes=10,
                            reply_bytes=5, fetch_bytes=1, docs_bytes=2,
                            ot_wire_bytes=0)
    m1 = ServeMetrics(window=1)
    for i in range(3):
        m1.record("t", latency_s=float(i), batch_size=1, transcript=tr)
    agg = m1.aggregate
    assert list(agg.latencies_s) == [2.0]
    assert agg.percentile(50) == 2.0 and agg.percentile(99) == 2.0
    assert agg.count == 3                        # exact total survives
    assert agg.total_wire_bytes == 3 * 18


def test_tracing_disabled_by_default(corpus):
    """EngineConfig() leaves tracing off: the engine runs on the shared
    NULL tracer, records nothing, and refuses to write an empty trace."""
    index, _, queries = corpus
    eng, got = _run(index, queries, sequential=False, max_batch=8)
    assert all(r.ok for r in got)
    assert eng.tracer is obs.NULL_TRACER
    assert eng.tracer.spans() == []
    assert eng.trace_summary() is None
    assert "trace" not in eng.metrics.summary()
    with pytest.raises(RuntimeError, match="trace"):
        eng.write_trace("/tmp/should-not-exist.json")


def test_traced_run_stages_redaction_reconciliation(corpus, tmp_path):
    """The tentpole end-to-end: a traced batched run (a) stays
    bit-identical to the untraced run, (b) covers every pipeline stage,
    (c) carries only whitelisted scalar attrs on every span (redaction by
    construction over a *real* stream), (d) nests stage spans inside
    their dispatch and reconciles queue_wait + dispatch with each
    request's end-to-end latency, and (e) exports a loadable
    Chrome-trace."""
    index, _, queries = corpus
    _, base = _run(index, queries, sequential=False, max_batch=8)
    eng, got = _run(index, queries, sequential=False, max_batch=8,
                    trace=True)
    assert len(got) == N_REQ and all(r.ok for r in got)
    for rb, rt in zip(base, got):                # (a) tracing changes nothing
        assert rb.ids.tolist() == rt.ids.tolist()
        assert rb.docs == rt.docs
        assert rb.transcript.total_bytes == rt.transcript.total_bytes

    spans = eng.tracer.spans()
    names = {s.name for s in spans}
    assert {"queue_wait", "dispatch", "perturb", "topk", "encrypt",
            "score", "decrypt", "finish"} <= names          # (b)

    for s in spans:                              # (c) redaction contract
        for key, val in s.attrs.items():
            assert key in obs.ALLOWED_ATTR_KEYS
            assert isinstance(val, (bool, int, float, str))
            if isinstance(val, str):
                assert len(val) <= 64

    # (d) timeline consistency: every stage span nests inside its batch's
    # dispatch interval, and queue_wait + dispatch explain each latency
    dispatches = {s.batch_id: s for s in spans if s.name == "dispatch"}
    waits = {s.request_id: s for s in spans if s.name == "queue_wait"}
    eps = 1e-6
    for s in spans:
        if s.name in ("dispatch", "queue_wait") or s.batch_id is None \
                or s.track == "admitter" or s.duration_s == 0.0:
            continue
        d = dispatches[s.batch_id]
        assert d.t_start - eps <= s.t_start
        assert s.t_end <= d.t_end + eps
    assert len(waits) == N_REQ
    for res in got:
        w = waits[res.request_id]
        d = dispatches[w.batch_id]
        assert res.latency_s <= w.duration_s + d.duration_s + 0.05
    # per-batch stage-duration sums can never exceed the dispatch span
    for b, d in dispatches.items():
        stage_sum = sum(s.duration_s for s in spans
                        if s.batch_id == b and s.track == "engine"
                        and s.name in ("perturb", "topk", "score",
                                       "decrypt"))
        assert stage_sum <= d.duration_s + eps

    # summary merge + stage histograms
    summ = eng.metrics.summary()
    assert summ["trace"]["stages"]["dispatch"]["count"] >= 1
    assert eng.trace_summary() == eng.tracer.snapshot()

    path = tmp_path / "serve-trace.json"         # (e) export round-trip
    n_events = eng.write_trace(str(path))
    assert n_events == len(spans)
    doc = obs.load_chrome_trace(str(path))
    assert doc["metadata"]["stage_summary"] == eng.tracer.stage_summary()


def test_sharded_admission_span_parented_and_overlapping_encrypt(corpus):
    """The async shard admitter emits "cache_admit" spans on its own
    "admitter" track, parented (batch_id) to the dispatch that enqueued
    the admission — and the admission copy genuinely overlaps that
    batch's encrypt stage.  The overlap is forced deterministically: the
    admit hook blocks until the first encrypt begins, and the encrypts
    are slowed enough that the copy lands inside one."""
    index, _, queries = corpus
    eng = _build(index, sequential=False, max_batch=8, trace=True,
                 cache_config=rlwe.CandidateCacheConfig(
                     num_shards=8, admit_threshold=1))
    cache = eng.cloud.candidate_cache
    assert isinstance(cache, rlwe.ShardedCandidateCache)
    encrypt_started = threading.Event()
    for t in TENANTS:
        user = eng.sessions.get(t).user
        orig = user.encrypt_query

        def slow_encrypt(emb, _orig=orig):
            encrypt_started.set()
            time.sleep(0.05)        # hold the encrypt span open
            return _orig(emb)

        user.encrypt_query = slow_encrypt
    cache._admit_hook = lambda s: encrypt_started.wait(timeout=10.0)
    try:
        for i, q in enumerate(queries):
            eng.submit(TENANTS[i % len(TENANTS)], q,
                       key=jax.random.PRNGKey(i))
        got = eng.drain()
        assert all(r.ok for r in got)
        cache.flush()
        spans = eng.tracer.spans()
        admits = [s for s in spans
                  if s.name == "cache_admit" and s.track == "admitter"]
        assert admits, "background admitter must emit admission spans"
        dispatch_bids = {s.batch_id for s in spans if s.name == "dispatch"}
        for a in admits:                      # parented to a real dispatch
            assert a.batch_id in dispatch_bids
            assert a.attrs["ok"] is True and a.attrs["bytes"] > 0
        gathers = [s for s in spans if s.name == "cache_gather"]
        assert gathers and all(g.batch_id in dispatch_bids for g in gathers)
        encrypts = [s for s in spans if s.name == "encrypt"]
        assert any(a.t_start < e.t_end and e.t_start < a.t_end
                   for a in admits for e in encrypts), \
            "admission copy must overlap the encrypt stage"
    finally:
        cache._admit_hook = None              # cache is index-memoized
        eng.close()
