"""Distributed exact top-k' search over a sharded FlatIndex.

Per-device: the fused Pallas score+select kernel reduces the local shard to
(B, k_local) candidates.  Cross-device: shards are stacked along a leading
axis (shard_map out_spec), and a tiny replicated top-k merge runs outside.
Collective bytes scale with devices * B * k (KB), never with N.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels.scoretopk import ops as sops
from repro.retrieval.index import FlatIndex, IndexSlice


class SearchResult(NamedTuple):
    values: jax.Array    # (B, k) descending scores (inner products)
    indices: jax.Array   # (B, k) int32 global ids
    exact: jax.Array     # () bool


def make_sharded_topk(mesh, axes, n_rows: int, k: int, *, tile: int = 2048,
                      per_tile_k: Optional[int] = None, use_pallas=None):
    """Functional core: (queries, corpus) -> SearchResult, jit/lower-able.

    ``corpus`` must be row-sharded over ``axes``; rows must divide evenly.
    """
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    rows_local = n_rows // n_shards
    k_local = min(k, rows_local)

    def local_search(q, shard):
        # linearized shard position over the row axes
        pos = jnp.int32(0)
        for a in axes:
            pos = pos * mesh.shape[a] + jax.lax.axis_index(a)
        out = sops.topk_scores(q, shard, k_local, tile=min(tile, rows_local),
                               per_tile_k=per_tile_k, use_pallas=use_pallas)
        gidx = out.indices + pos * rows_local
        return (out.values[None], gidx[None],
                out.exact.reshape(1)[None])

    def search(queries, corpus):
        stacked_v, stacked_i, stacked_ok = shard_map(
            local_search, mesh=mesh,
            in_specs=(P(), P(axes, None)),
            out_specs=(P(axes), P(axes), P(axes)),
            check_rep=False,
        )(queries, corpus)
        b = queries.shape[0]
        flat_v = jnp.swapaxes(stacked_v, 0, 1).reshape(b, n_shards * k_local)
        flat_i = jnp.swapaxes(stacked_i, 0, 1).reshape(b, n_shards * k_local)
        k_eff = min(k, n_shards * k_local)
        mv, mpos = jax.lax.top_k(flat_v, k_eff)
        mi = jnp.take_along_axis(flat_i, mpos, axis=1)
        return SearchResult(mv, mi, jnp.all(stacked_ok))

    return search


def distributed_topk(index: FlatIndex, queries, k: int, *,
                     tile: int = 2048, per_tile_k: Optional[int] = None,
                     use_pallas=None) -> SearchResult:
    """Exact top-k of <query, corpus row> over the (possibly sharded) index."""
    n_rows = index.num_rows  # includes shard padding
    if index.mesh is None:
        out = sops.topk_scores(queries, index.embeddings, k, tile=tile,
                               per_tile_k=per_tile_k, use_pallas=use_pallas)
        return SearchResult(out.values, out.indices, out.exact)
    search = make_sharded_topk(index.mesh, index.row_axes, n_rows, k,
                               tile=tile, per_tile_k=per_tile_k,
                               use_pallas=use_pallas)
    return search(queries, index.embeddings)


def slice_topk(sl: IndexSlice, queries, k: int, *, tile: int = 2048,
               per_tile_k: Optional[int] = None,
               use_pallas=None) -> SearchResult:
    """Exact top-k over one replica's row slice, in *global* ids.

    Runs the same fused score+select as the full-index path (same tile
    schedule, same stable tie-break toward lower row id), then offsets
    local ids by ``sl.start``.  Per-slice results merged by (score desc,
    global id asc) therefore reproduce the full-index top-k bit-for-bit —
    the invariant the scale-out router's differential harness pins.
    """
    k_local = min(k, sl.num_rows)
    out = sops.topk_scores(queries, sl.embeddings, k_local,
                           tile=min(tile, sl.num_rows),
                           per_tile_k=per_tile_k, use_pallas=use_pallas)
    return SearchResult(out.values, out.indices + sl.start, out.exact)


def distances_from_scores(values):
    """Cosine distance (paper Definition 2) from inner-product scores."""
    return 1.0 - values


__all__ = ["SearchResult", "make_sharded_topk", "distributed_topk",
           "slice_topk", "distances_from_scores"]
