"""TPU-native RNS-RLWE additively homomorphic encryption ("BFV-lite").

This is the hardware adaptation of the paper's PHE (Module 2a).  Paillier is
bignum modexp — hostile to the MXU/VPU — so we use the RLWE analogue of "PHE
with ct+ct and ct*plain": BFV without relinearisation.

Scheme (symmetric key; the user is both encryptor and decryptor):

  ring      R_q = Z_q[X]/(X^N + 1),  q = q_0 q_1 q_2  (RNS, ~20-bit NTT primes)
  secret    s ternary in {-1, 0, 1}^N
  enc(m)    c0 = a*s + e + Delta*m,  c1 = a;   a ~ U(R_q), e ~ CBD(eta)
  dec(ct)   m = round(t/q * centered(c0 - c1*s)) mod t
  add       componentwise;  ct (x) p = (c0*p, c1*p)  for plaintext p in R

Encrypted inner products use negacyclic-convolution packing: the fixed-point
query chunk is the plaintext of a ciphertext; each candidate chunk is packed
*reversed* into a plain polynomial at block offset o_b, so coefficient
o_b + chunk - 1 of ct (x) p is exactly <query_chunk, cand_chunk>.  Chunks of
dimension > chunk_size are summed homomorphically.  Multiple candidates share
one ciphertext via block stride (N/stride candidates per result ciphertext).

Correctness budget (validated in `RlweParams.validate`): every *extraction*
coefficient of m*p is an inner product of unit-norm vectors scaled by
Delta_q*Delta_c (Cauchy-Schwarz) and therefore < t/2; mod-t wraps can only
occur at garbage coefficients, which decryption treats coefficient-locally.
Noise after plain-mult is ||e||_inf * ||p||_1 <= eta * C * Delta_c * sqrt(cs),
far below q / (2t).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import numpy as np

import jax.numpy as jnp

from repro.crypto import modring
from repro.crypto.modring import PrimeCtx
from repro.kernels.ntt import ops as ntt_ops


@dataclasses.dataclass(frozen=True, eq=False)
class RlweParams:
    n_poly: int = 4096          # ring dimension N
    num_primes: int = 3         # RNS primes (~20 bits each)
    t_bits: int = 28            # plaintext modulus t = 2^t_bits
    scale_q_bits: int = 13      # query fixed-point scale  Delta_q = 2^13
    scale_c_bits: int = 13      # candidate fixed-point scale Delta_c = 2^13
    eta: int = 8                # CBD noise parameter, |e| <= eta
    chunk: int = 1024           # dot-product chunk size (<= n_poly)

    def __post_init__(self):
        assert self.n_poly % self.chunk == 0
        self.validate()

    @functools.cached_property
    def primes(self) -> tuple:
        return modring.find_ntt_primes(2 * self.n_poly, self.num_primes)

    @functools.cached_property
    def ctxs(self) -> tuple:
        return tuple(PrimeCtx.build(q, self.n_poly) for q in self.primes)

    @functools.cached_property
    def big_q(self) -> int:
        return math.prod(self.primes)

    @property
    def t(self) -> int:
        return 1 << self.t_bits

    @functools.cached_property
    def delta(self) -> int:
        return self.big_q // self.t

    @property
    def scale_q(self) -> int:
        return 1 << self.scale_q_bits

    @property
    def scale_c(self) -> int:
        return 1 << self.scale_c_bits

    def stride(self, n_dim: int) -> int:
        """Block stride: extraction at o_b + chunk - 1 must clear the previous
        block's span o_b + chunk - 1 + (chunk_used - 1)."""
        return self.chunk if n_dim <= self.chunk else 2 * self.chunk

    def cands_per_ct(self, n_dim: int) -> int:
        return self.n_poly // self.stride(n_dim)

    def num_chunks(self, n_dim: int) -> int:
        return -(-n_dim // self.chunk)

    def validate(self) -> None:
        # plaintext range: extraction coefficients bounded by Delta_q*Delta_c
        # (unit-norm Cauchy-Schwarz) + quantization slop < t/2.
        assert (1 << (self.scale_q_bits + self.scale_c_bits)) * 1.1 < self.t / 2, \
            "plaintext scales overflow t"
        # noise: after plain-mult and chunk-summing,
        #   |noise| <= eta * cands_per_ct_max * Delta_c * sqrt(chunk) * chunks_max
        worst = (self.eta * (self.n_poly // self.chunk) * self.scale_c
                 * math.isqrt(self.chunk) * 4)
        assert 2 * self.t * worst < self.big_q, "noise budget exceeded"

    def ciphertext_bytes(self, packed_bits: int = 20) -> int:
        """Wire size of one ciphertext (2 components, RNS, bit-packed)."""
        return 2 * self.num_primes * self.n_poly * packed_bits // 8


@dataclasses.dataclass(frozen=True, eq=False)
class RlweSecretKey:
    params: RlweParams
    s: np.ndarray          # (N,) int8 ternary
    s_ntt: jnp.ndarray     # (P, N) int32 — NTT(s) per prime


@dataclasses.dataclass(frozen=True, eq=False)
class QueryCiphertext:
    """Encrypted, chunked query embedding: (chunks, P, N) int32 per component."""
    c0: jnp.ndarray
    c1: jnp.ndarray
    n_dim: int


@dataclasses.dataclass(frozen=True, eq=False)
class PackedCandidates:
    """NTT-domain packed candidate plaintexts.

    polys: (num_ct, chunks, P, N) int32; candidate i lives in result ct
    i // cands_per_ct at extraction coefficient (i % cands_per_ct) * stride
    + chunk - 1.
    """
    polys: jnp.ndarray
    n_dim: int
    num_cands: int


@dataclasses.dataclass(frozen=True, eq=False)
class ScoreCiphertexts:
    """Encrypted inner products: (num_ct, P, N) int32 per component."""
    c0: jnp.ndarray
    c1: jnp.ndarray
    n_dim: int
    num_cands: int


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _to_rns(values: np.ndarray, params: RlweParams) -> np.ndarray:
    """Signed int64 (..., N) -> RNS int32 (P, ..., N)."""
    out = [np.mod(values, q).astype(np.int32) for q in params.primes]
    return np.stack(out, axis=0)


def _cbd(rng: np.random.Generator, eta: int, n: int) -> np.ndarray:
    a = rng.integers(0, 2, size=(eta, n)).sum(axis=0)
    b = rng.integers(0, 2, size=(eta, n)).sum(axis=0)
    return (a - b).astype(np.int64)


def keygen(params: RlweParams, rng: np.random.Generator) -> RlweSecretKey:
    s = rng.integers(-1, 2, size=(params.n_poly,)).astype(np.int8)
    s_rns = _to_rns(s.astype(np.int64), params)  # (P, N)
    s_ntt = jnp.stack([
        ntt_ops.ntt_fwd(jnp.asarray(s_rns[i]), ctx)
        for i, ctx in enumerate(params.ctxs)
    ])
    return RlweSecretKey(params=params, s=s, s_ntt=s_ntt)


def _fixed_point(e: np.ndarray, scale: int) -> np.ndarray:
    return np.rint(np.asarray(e, np.float64) * scale).astype(np.int64)


# ---------------------------------------------------------------------------
# user side: encrypt / decrypt
# ---------------------------------------------------------------------------

def encrypt_query(sk: RlweSecretKey, e: np.ndarray,
                  rng: np.random.Generator) -> QueryCiphertext:
    """Encrypt a unit-norm query embedding of any dimension (chunked)."""
    p = sk.params
    n_dim = e.shape[-1]
    chunks = p.num_chunks(n_dim)
    ints = _fixed_point(e, p.scale_q)
    c0s, c1s = [], []
    for c in range(chunks):
        m = np.zeros(p.n_poly, np.int64)
        seg = ints[c * p.chunk:(c + 1) * p.chunk]
        m[: len(seg)] = seg
        # signed (centered) encoding: Delta*m mod q, computed per RNS prime.
        # An unsigned mod-t lift would add a Delta*t*w term that explodes
        # under plain-mult; signed encoding keeps Dec(ct (x) p) = m*p exactly
        # while |(m*p)_j| < t/2 at the coefficients we read.
        err = _cbd(rng, p.eta, p.n_poly)
        c0_p, c1_p = [], []
        for i, ctx in enumerate(p.ctxs):
            a = rng.integers(0, ctx.q, size=(p.n_poly,)).astype(np.int32)
            dm = (int(p.delta % ctx.q) * np.mod(m, ctx.q)) % ctx.q  # int64 safe
            a_s = ntt_ops.ntt_inv(
                ntt_ops.pointwise_mul(
                    ntt_ops.ntt_fwd(jnp.asarray(a), ctx), sk.s_ntt[i], ctx),
                ctx)
            c0 = (np.asarray(a_s).astype(np.int64) + err + dm) % ctx.q
            c0_p.append(c0.astype(np.int32))
            c1_p.append(a)
        c0s.append(np.stack(c0_p))
        c1s.append(np.stack(c1_p))
    return QueryCiphertext(
        c0=jnp.asarray(np.stack(c0s)), c1=jnp.asarray(np.stack(c1s)), n_dim=n_dim)


def decrypt_rns(params: RlweParams, s_ntt: jnp.ndarray, c0: jnp.ndarray,
                c1: jnp.ndarray, *, use_pallas=None) -> np.ndarray:
    """RNS phase of decryption: d = c0 - c1*s per prime.

    ``c0``/``c1`` are (..., P, N); ``s_ntt`` broadcasts against the leading
    dims of NTT(c1) — pass (P, N) for one key or (B, 1, P, N)-style stacks
    for a batch of per-tenant keys.  Returns int64 (..., P, N).
    """
    d_p = []
    for i, ctx in enumerate(params.ctxs):
        f1 = ntt_ops.ntt_fwd(c1[..., i, :], ctx, use_pallas=use_pallas)
        sb = jnp.broadcast_to(s_ntt[..., i, :], f1.shape)
        c1s = ntt_ops.ntt_inv(
            ntt_ops.pointwise_mul(f1, sb, ctx, use_pallas=use_pallas), ctx,
            use_pallas=use_pallas)
        d = modring.mod_sub(c0[..., i, :], c1s, ctx.q)
        d_p.append(np.asarray(d).astype(np.int64))
    return np.stack(d_p, axis=-2)


def extract_scores(params: RlweParams, d_rns: np.ndarray, n_dim: int,
                   num_cands: int) -> np.ndarray:
    """CRT-reconstruct the extraction coefficients of d_rns (num_ct, P, N)
    (Python bignums) -> float scores (num_cands,)."""
    p = params
    stride = p.stride(n_dim)
    cpt = p.cands_per_ct(n_dim)
    g = [p.big_q // q for q in p.primes]
    h = [pow(gi % qi, -1, qi) for gi, qi in zip(g, p.primes)]
    scale = float(p.scale_q * p.scale_c)
    out = np.zeros(num_cands, np.float64)
    for cand in range(num_cands):
        ct_i, slot = divmod(cand, cpt)
        coeff = slot * stride + p.chunk - 1
        big = 0
        for i, qi in enumerate(p.primes):
            big += int(d_rns[ct_i, i, coeff]) * g[i] * h[i]
        big %= p.big_q
        if big > p.big_q // 2:
            big -= p.big_q
        val = round(big * p.t / p.big_q)  # noise removal
        # centered mod t
        val = ((val + p.t // 2) % p.t) - p.t // 2
        out[cand] = val / scale
    return out


def decrypt_scores(sk: RlweSecretKey, res: ScoreCiphertexts) -> np.ndarray:
    """Decrypt packed inner products -> float scores (len num_cands)."""
    d_rns = decrypt_rns(sk.params, sk.s_ntt, res.c0, res.c1)
    return extract_scores(sk.params, d_rns, res.n_dim, res.num_cands)


# ---------------------------------------------------------------------------
# cloud side: pack candidates, encrypted scoring
# ---------------------------------------------------------------------------

def pack_candidates_batch(params: RlweParams,
                          cands: np.ndarray) -> jnp.ndarray:
    """Pack (B, num_cands, n_dim) candidate rows -> (B, num_ct, chunks, P, N)
    NTT-domain plaintexts.  The reversed placement (p[o + chunk-1 - j] =
    seg[j]) vectorizes over B; the NTT batches all leading dims."""
    bsz, num_cands, n_dim = cands.shape
    chunks = params.num_chunks(n_dim)
    stride = params.stride(n_dim)
    cpt = params.cands_per_ct(n_dim)
    num_ct = -(-num_cands // cpt)
    ints = _fixed_point(cands, params.scale_c)  # (B, num_cands, n_dim)

    polys = np.zeros((bsz, num_ct, chunks, params.n_poly), np.int64)
    for cand in range(num_cands):
        ct_i, slot = divmod(cand, cpt)
        o = slot * stride
        for c in range(chunks):
            seg = ints[:, cand, c * params.chunk:(c + 1) * params.chunk]
            idx = o + params.chunk - 1 - np.arange(seg.shape[1])
            polys[:, ct_i, c, idx] = seg
    rns = _to_rns(polys, params)  # (P, B, num_ct, chunks, N)
    ntt_polys = np.stack([
        np.asarray(ntt_ops.ntt_fwd(jnp.asarray(rns[i]), ctx))
        for i, ctx in enumerate(params.ctxs)
    ])  # (P, B, num_ct, chunks, N)
    return jnp.asarray(np.transpose(ntt_polys, (1, 2, 3, 0, 4)))


def pack_candidates(params: RlweParams, cands: np.ndarray) -> PackedCandidates:
    """Pack candidate embeddings (num_cands, n_dim) into NTT-domain
    plaintexts (the B=1 slice of the batch packer — one source of truth)."""
    num_cands, n_dim = cands.shape
    polys = pack_candidates_batch(params, np.asarray(cands)[None])[0]
    return PackedCandidates(polys=polys, n_dim=n_dim, num_cands=num_cands)


def encrypted_scores_batch(params: RlweParams,
                           q_cts: Sequence[QueryCiphertext],
                           packed: jnp.ndarray, num_cands: int, n_dim: int,
                           *, use_pallas=None) -> list:
    """Batched ct (x) p: B query ciphertexts against (B, num_ct, chunks, P,
    N) packed candidates, chunk-summed in the NTT domain — one NTT dispatch
    per prime for the whole batch.

    This is the cloud's entire encrypted workload: 2 * chunks forward NTTs
    per query (amortized over all candidates), one Hadamard modmul per
    (lane, result-ct, chunk, component, prime), and 2 inverse NTTs per
    result ct.  Returns a list of B ScoreCiphertexts.
    """
    c0 = jnp.stack([q.c0 for q in q_cts])  # (B, chunks, P, N)
    c1 = jnp.stack([q.c1 for q in q_cts])
    c0_out, c1_out = [], []
    for i, ctx in enumerate(params.ctxs):
        f0 = ntt_ops.ntt_fwd(c0[:, :, i, :], ctx, use_pallas=use_pallas)
        f1 = ntt_ops.ntt_fwd(c1[:, :, i, :], ctx, use_pallas=use_pallas)
        pk = packed[:, :, :, i, :]                 # (B, num_ct, chunks, N)
        f0b = jnp.broadcast_to(f0[:, None], pk.shape)
        f1b = jnp.broadcast_to(f1[:, None], pk.shape)
        prod0 = ntt_ops.pointwise_mul(pk, f0b, ctx, use_pallas=use_pallas)
        prod1 = ntt_ops.pointwise_mul(pk, f1b, ctx, use_pallas=use_pallas)
        # homomorphic chunk-sum in NTT domain (mod-add over chunk axis)
        acc0 = prod0[:, :, 0, :]
        acc1 = prod1[:, :, 0, :]
        for c in range(1, prod0.shape[2]):
            acc0 = modring.mod_add(acc0, prod0[:, :, c, :], ctx.q)
            acc1 = modring.mod_add(acc1, prod1[:, :, c, :], ctx.q)
        c0_out.append(ntt_ops.ntt_inv(acc0, ctx, use_pallas=use_pallas))
        c1_out.append(ntt_ops.ntt_inv(acc1, ctx, use_pallas=use_pallas))
    all0 = jnp.stack(c0_out, axis=2)               # (B, num_ct, P, N)
    all1 = jnp.stack(c1_out, axis=2)
    return [ScoreCiphertexts(c0=all0[b], c1=all1[b], n_dim=n_dim,
                             num_cands=num_cands)
            for b in range(all0.shape[0])]


def encrypted_scores(params: RlweParams, q_ct: QueryCiphertext,
                     packed: PackedCandidates, *,
                     use_pallas=None) -> ScoreCiphertexts:
    """ct (x) p per candidate block (the B=1 slice of the batch version)."""
    assert q_ct.n_dim == packed.n_dim
    return encrypted_scores_batch(
        params, [q_ct], packed.polys[None], num_cands=packed.num_cands,
        n_dim=packed.n_dim, use_pallas=use_pallas)[0]


def decrypt_scores_batch(sks: Sequence[RlweSecretKey],
                         cts: Sequence[ScoreCiphertexts],
                         *, use_pallas=None) -> list:
    """Decrypt B score ciphertexts under B (distinct) tenant keys with one
    NTT dispatch per prime; CRT extraction stays per-lane (host bignums)."""
    params = sks[0].params
    c0 = jnp.stack([c.c0 for c in cts])            # (B, num_ct, P, N)
    c1 = jnp.stack([c.c1 for c in cts])
    s_ntt = jnp.stack([sk.s_ntt for sk in sks])[:, None]  # (B, 1, P, N)
    d_rns = decrypt_rns(params, s_ntt, c0, c1, use_pallas=use_pallas)
    return [extract_scores(params, d_rns[b], ct.n_dim, ct.num_cands)
            for b, ct in enumerate(cts)]


def cosine_distances(scores: np.ndarray) -> np.ndarray:
    """Paper Definition 2 over decrypted inner products."""
    return 1.0 - scores


__all__ = [
    "RlweParams", "RlweSecretKey", "QueryCiphertext", "PackedCandidates",
    "ScoreCiphertexts", "keygen", "encrypt_query", "decrypt_scores",
    "decrypt_scores_batch", "decrypt_rns", "extract_scores",
    "pack_candidates", "pack_candidates_batch", "encrypted_scores",
    "encrypted_scores_batch", "cosine_distances",
]
