"""TPU-native RNS-RLWE additively homomorphic encryption ("BFV-lite").

This is the hardware adaptation of the paper's PHE (Module 2a).  Paillier is
bignum modexp — hostile to the MXU/VPU — so we use the RLWE analogue of "PHE
with ct+ct and ct*plain": BFV without relinearisation.

Scheme (symmetric key; the user is both encryptor and decryptor):

  ring      R_q = Z_q[X]/(X^N + 1),  q = q_0 q_1 q_2  (RNS, ~20-bit NTT primes)
  secret    s ternary in {-1, 0, 1}^N
  enc(m)    c0 = a*s + e + Delta*m,  c1 = a;   a ~ U(R_q), e ~ CBD(eta)
  dec(ct)   m = round(t/q * centered(c0 - c1*s)) mod t
  add       componentwise;  ct (x) p = (c0*p, c1*p)  for plaintext p in R

Encrypted inner products use negacyclic-convolution packing: the fixed-point
query chunk is the plaintext of a ciphertext; each candidate chunk is packed
*reversed* into a plain polynomial at block offset o_b, so coefficient
o_b + chunk - 1 of ct (x) p is exactly <query_chunk, cand_chunk>.  Chunks of
dimension > chunk_size are summed homomorphically.  Multiple candidates share
one ciphertext via block stride (N/stride candidates per result ciphertext).

The per-document half of that packing (reverse placement + forward NTT) is
request-invariant, so it is hoisted into an NTT-domain `CandidateCache`
built once per index; at request time a candidate's block offset is realized
as a pointwise monomial-twiddle rotate in the NTT domain (bit-identical to
fresh packing — see CandidateCache / encrypted_scores_cached_batch).

Correctness budget (validated in `RlweParams.validate`): every *extraction*
coefficient of m*p is an inner product of unit-norm vectors scaled by
Delta_q*Delta_c (Cauchy-Schwarz) and therefore < t/2; mod-t wraps can only
occur at garbage coefficients, which decryption treats coefficient-locally.
Noise after plain-mult is ||e||_inf * ||p||_1 <= eta * C * Delta_c * sqrt(cs),
far below q / (2t).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.crypto import modring
from repro.crypto.modring import PrimeCtx
from repro.kernels.ntt import ops as ntt_ops
from repro.kernels.ntt import ref as ntt_ref


@dataclasses.dataclass(frozen=True, eq=False)
class RlweParams:
    n_poly: int = 4096          # ring dimension N
    num_primes: int = 3         # RNS primes (~20 bits each)
    t_bits: int = 28            # plaintext modulus t = 2^t_bits
    scale_q_bits: int = 13      # query fixed-point scale  Delta_q = 2^13
    scale_c_bits: int = 13      # candidate fixed-point scale Delta_c = 2^13
    eta: int = 8                # CBD noise parameter, |e| <= eta
    chunk: int = 1024           # dot-product chunk size (<= n_poly)

    def __post_init__(self):
        assert self.n_poly % self.chunk == 0
        self.validate()

    @functools.cached_property
    def primes(self) -> tuple:
        return modring.find_ntt_primes(2 * self.n_poly, self.num_primes)

    @functools.cached_property
    def ctxs(self) -> tuple:
        return tuple(PrimeCtx.build(q, self.n_poly) for q in self.primes)

    @functools.cached_property
    def big_q(self) -> int:
        return math.prod(self.primes)

    @property
    def t(self) -> int:
        return 1 << self.t_bits

    @functools.cached_property
    def delta(self) -> int:
        return self.big_q // self.t

    @property
    def scale_q(self) -> int:
        return 1 << self.scale_q_bits

    @property
    def scale_c(self) -> int:
        return 1 << self.scale_c_bits

    def stride(self, n_dim: int) -> int:
        """Block stride: extraction at o_b + chunk - 1 must clear the previous
        block's span o_b + chunk - 1 + (chunk_used - 1)."""
        return self.chunk if n_dim <= self.chunk else 2 * self.chunk

    def cands_per_ct(self, n_dim: int) -> int:
        return self.n_poly // self.stride(n_dim)

    def num_chunks(self, n_dim: int) -> int:
        return -(-n_dim // self.chunk)

    def validate(self) -> None:
        # plaintext range: extraction coefficients bounded by Delta_q*Delta_c
        # (unit-norm Cauchy-Schwarz) + quantization slop < t/2.
        assert (1 << (self.scale_q_bits + self.scale_c_bits)) * 1.1 < self.t / 2, \
            "plaintext scales overflow t"
        # noise: after plain-mult and chunk-summing,
        #   |noise| <= eta * cands_per_ct_max * Delta_c * sqrt(chunk) * chunks_max
        worst = (self.eta * (self.n_poly // self.chunk) * self.scale_c
                 * math.isqrt(self.chunk) * 4)
        assert 2 * self.t * worst < self.big_q, "noise budget exceeded"

    def ciphertext_bytes(self, packed_bits: int = 20) -> int:
        """Wire size of one ciphertext (2 components, RNS, bit-packed)."""
        return 2 * self.num_primes * self.n_poly * packed_bits // 8


@dataclasses.dataclass(frozen=True, eq=False)
class RlweSecretKey:
    params: RlweParams
    s: np.ndarray          # (N,) int8 ternary
    s_ntt: jnp.ndarray     # (P, N) int32 — NTT(s) per prime


@dataclasses.dataclass(frozen=True, eq=False)
class QueryCiphertext:
    """Encrypted, chunked query embedding: (chunks, P, N) int32 per component."""
    c0: jnp.ndarray
    c1: jnp.ndarray
    n_dim: int


@dataclasses.dataclass(frozen=True, eq=False)
class PackedCandidates:
    """NTT-domain packed candidate plaintexts.

    polys: (num_ct, chunks, P, N) int32; candidate i lives in result ct
    i // cands_per_ct at extraction coefficient (i % cands_per_ct) * stride
    + chunk - 1.
    """
    polys: jnp.ndarray
    n_dim: int
    num_cands: int


@dataclasses.dataclass(frozen=True, eq=False)
class ScoreCiphertexts:
    """Encrypted inner products: (num_ct, P, N) int32 per component."""
    c0: jnp.ndarray
    c1: jnp.ndarray
    n_dim: int
    num_cands: int


@dataclasses.dataclass(frozen=True, eq=False)
class ScoreCiphertextBatch:
    """B stacked score ciphertexts: (B, num_ct, P, N) int32 per component.

    The serving path keeps this stacked form end-to-end (scoring ->
    decryption) so no per-lane device work happens; `lane`/`lanes` hand out
    per-request views for the wire messages."""
    c0: jnp.ndarray
    c1: jnp.ndarray
    n_dim: int
    num_cands: int

    @property
    def batch(self) -> int:
        return self.c0.shape[0]

    def lane(self, b: int) -> ScoreCiphertexts:
        return ScoreCiphertexts(c0=self.c0[b], c1=self.c1[b],
                                n_dim=self.n_dim, num_cands=self.num_cands)

    def lanes(self) -> list:
        return [self.lane(b) for b in range(self.batch)]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _to_rns(values: np.ndarray, params: RlweParams) -> np.ndarray:
    """Signed int64 (..., N) -> RNS int32 (P, ..., N)."""
    out = [np.mod(values, q).astype(np.int32) for q in params.primes]
    return np.stack(out, axis=0)


def _cbd(rng: np.random.Generator, eta: int, n: int) -> np.ndarray:
    a = rng.integers(0, 2, size=(eta, n)).sum(axis=0)
    b = rng.integers(0, 2, size=(eta, n)).sum(axis=0)
    return (a - b).astype(np.int64)


def keygen(params: RlweParams, rng: np.random.Generator) -> RlweSecretKey:
    s = rng.integers(-1, 2, size=(params.n_poly,)).astype(np.int8)
    s_rns = _to_rns(s.astype(np.int64), params)  # (P, N)
    s_ntt = jnp.stack([
        ntt_ops.ntt_fwd(jnp.asarray(s_rns[i]), ctx)
        for i, ctx in enumerate(params.ctxs)
    ])
    return RlweSecretKey(params=params, s=s, s_ntt=s_ntt)


def _fixed_point(e: np.ndarray, scale: int) -> np.ndarray:
    return np.rint(np.asarray(e, np.float64) * scale).astype(np.int64)


# ---------------------------------------------------------------------------
# user side: encrypt / decrypt
# ---------------------------------------------------------------------------

def encrypt_query(sk: RlweSecretKey, e: np.ndarray,
                  rng: np.random.Generator) -> QueryCiphertext:
    """Encrypt a unit-norm query embedding of any dimension (chunked)."""
    p = sk.params
    n_dim = e.shape[-1]
    chunks = p.num_chunks(n_dim)
    ints = _fixed_point(e, p.scale_q)
    c0s, c1s = [], []
    for c in range(chunks):
        m = np.zeros(p.n_poly, np.int64)
        seg = ints[c * p.chunk:(c + 1) * p.chunk]
        m[: len(seg)] = seg
        # signed (centered) encoding: Delta*m mod q, computed per RNS prime.
        # An unsigned mod-t lift would add a Delta*t*w term that explodes
        # under plain-mult; signed encoding keeps Dec(ct (x) p) = m*p exactly
        # while |(m*p)_j| < t/2 at the coefficients we read.
        err = _cbd(rng, p.eta, p.n_poly)
        c0_p, c1_p = [], []
        for i, ctx in enumerate(p.ctxs):
            a = rng.integers(0, ctx.q, size=(p.n_poly,)).astype(np.int32)
            dm = (int(p.delta % ctx.q) * np.mod(m, ctx.q)) % ctx.q  # int64 safe
            a_s = ntt_ops.ntt_inv(
                ntt_ops.pointwise_mul(
                    ntt_ops.ntt_fwd(jnp.asarray(a), ctx), sk.s_ntt[i], ctx),
                ctx)
            c0 = (np.asarray(a_s).astype(np.int64) + err + dm) % ctx.q
            c0_p.append(c0.astype(np.int32))
            c1_p.append(a)
        c0s.append(np.stack(c0_p))
        c1s.append(np.stack(c1_p))
    return QueryCiphertext(
        c0=jnp.asarray(np.stack(c0s)), c1=jnp.asarray(np.stack(c1s)), n_dim=n_dim)


def decrypt_rns(params: RlweParams, s_ntt: jnp.ndarray, c0: jnp.ndarray,
                c1: jnp.ndarray, *, use_pallas=None) -> np.ndarray:
    """RNS phase of decryption: d = c0 - c1*s per prime.

    ``c0``/``c1`` are (..., P, N); ``s_ntt`` broadcasts against the leading
    dims of NTT(c1) — pass (P, N) for one key or (B, 1, P, N)-style stacks
    for a batch of per-tenant keys.  Returns int64 (..., P, N).
    """
    d_p = []
    for i, ctx in enumerate(params.ctxs):
        f1 = ntt_ops.ntt_fwd(c1[..., i, :], ctx, use_pallas=use_pallas)
        sb = jnp.broadcast_to(s_ntt[..., i, :], f1.shape)
        c1s = ntt_ops.ntt_inv(
            ntt_ops.pointwise_mul(f1, sb, ctx, use_pallas=use_pallas), ctx,
            use_pallas=use_pallas)
        d = modring.mod_sub(c0[..., i, :], c1s, ctx.q)
        d_p.append(np.asarray(d).astype(np.int64))
    return np.stack(d_p, axis=-2)


def extract_scores(params: RlweParams, d_rns: np.ndarray, n_dim: int,
                   num_cands: int) -> np.ndarray:
    """CRT-reconstruct the extraction coefficients of d_rns (num_ct, P, N)
    (Python bignums) -> float scores (num_cands,)."""
    p = params
    stride = p.stride(n_dim)
    cpt = p.cands_per_ct(n_dim)
    g = [p.big_q // q for q in p.primes]
    h = [pow(gi % qi, -1, qi) for gi, qi in zip(g, p.primes)]
    scale = float(p.scale_q * p.scale_c)
    out = np.zeros(num_cands, np.float64)
    for cand in range(num_cands):
        ct_i, slot = divmod(cand, cpt)
        coeff = slot * stride + p.chunk - 1
        big = 0
        for i, qi in enumerate(p.primes):
            big += int(d_rns[ct_i, i, coeff]) * g[i] * h[i]
        big %= p.big_q
        if big > p.big_q // 2:
            big -= p.big_q
        val = round(big * p.t / p.big_q)  # noise removal
        # centered mod t
        val = ((val + p.t // 2) % p.t) - p.t // 2
        out[cand] = val / scale
    return out


def decrypt_scores(sk: RlweSecretKey, res: ScoreCiphertexts) -> np.ndarray:
    """Decrypt packed inner products -> float scores (len num_cands)."""
    d_rns = decrypt_rns(sk.params, sk.s_ntt, res.c0, res.c1)
    return extract_scores(sk.params, d_rns, res.n_dim, res.num_cands)


# ---------------------------------------------------------------------------
# cloud side: NTT-domain candidate cache (build once, serve many)
# ---------------------------------------------------------------------------

def params_key(params: RlweParams) -> tuple:
    """Value identity of an RlweParams: two instances with the same key are
    interchangeable for packing/scoring (primes derive from n_poly+num_primes)."""
    return (params.n_poly, params.num_primes, params.t_bits,
            params.scale_q_bits, params.scale_c_bits, params.eta, params.chunk)


@dataclasses.dataclass(frozen=True, eq=False)
class CandidateCache:
    """Per-document NTT-domain plaintexts, packed once at index-build time.

    ``polys[d, c]`` holds document d's chunk c reverse-packed at slot 0
    (p[chunk-1-j] = seg[j]) and forward-NTT'd per prime: (num_docs, chunks,
    P, N) int32 — 4*P*N bytes per chunk per document (48 KiB/doc/chunk at
    the default N=4096, P=3).  Realizing document d at slot s of a result
    ciphertext is a pointwise multiply by ``twiddles[:, s]``, the NTT-domain
    diagonal of the monomial X^{s*stride}: the slot-0 support [0, chunk)
    never crosses X^N + 1 for s < cands_per_ct, so X^{s*stride} * base is
    exactly the polynomial the cold packer would have built, and the NTT is
    a ring isomorphism — cached scoring is bit-identical to fresh packing.

    ``stride``/``cands_per_ct``/``num_chunks`` are hoisted out of the hot
    loops; `check_compatible` rejects reuse under different ``RlweParams``
    (the build-once/serve-many contract is per (index, params-value) pair).
    """
    params: RlweParams
    polys: jnp.ndarray             # (num_docs, chunks, P, N) int32, NTT domain
    twiddles: jnp.ndarray          # (P, cands_per_ct, N) int32, NTT(X^{s*stride})
    n_dim: int
    num_docs: int
    stride: int
    cands_per_ct: int
    num_chunks: int

    @property
    def nbytes(self) -> int:
        return int(self.polys.size) * 4

    def check_compatible(self, params: RlweParams, n_dim=None) -> None:
        if params_key(params) != params_key(self.params):
            raise ValueError(
                f"candidate cache was built for RlweParams "
                f"{params_key(self.params)} but scoring uses "
                f"{params_key(params)}; rebuild the cache for these params")
        if n_dim is not None and n_dim != self.n_dim:
            raise ValueError(
                f"candidate cache packs n_dim={self.n_dim} but the query "
                f"has n_dim={n_dim}")


def build_candidate_cache(params: RlweParams,
                          embeddings: np.ndarray) -> CandidateCache:
    """Precompute the NTT-domain plaintexts of every document (slot 0) plus
    the per-slot monomial twiddles.  One vectorized host pack + one forward
    NTT per prime for the whole corpus; after this the server's encrypted
    workload touches only per-request data."""
    emb = np.asarray(embeddings)
    num_docs, n_dim = emb.shape
    chunks = params.num_chunks(n_dim)
    stride = params.stride(n_dim)
    cpt = params.cands_per_ct(n_dim)
    # slot/chunk accumulators in the scoring kernels sum cpt*chunks raw
    # int32 terms in [0, q) before one Barrett reduction
    assert cpt * chunks * (params.primes[0] - 1) < 2**31, \
        "cpt*chunks too large for the int32 accumulator"
    # pack + NTT in document blocks: peak transient host memory is one
    # ~64 MiB int64 staging buffer (plus its RNS copy), not 3x the corpus
    block = max(1, (1 << 23) // (chunks * params.n_poly))
    parts = []
    for lo in range(0, num_docs, block):
        seg_emb = emb[lo:lo + block]
        ints = _fixed_point(seg_emb, params.scale_c)      # (b, n_dim)
        polys = np.zeros((len(seg_emb), chunks, params.n_poly), np.int64)
        for c in range(chunks):
            seg = ints[:, c * params.chunk:(c + 1) * params.chunk]
            polys[:, c, params.chunk - 1 - np.arange(seg.shape[1])] = seg
        rns = _to_rns(polys, params)                      # (P, b, chunks, N)
        parts.append(jnp.stack([
            ntt_ops.ntt_fwd(jnp.asarray(rns[i]), ctx)
            for i, ctx in enumerate(params.ctxs)
        ], axis=2))                                       # (b, chunks, P, N)
    cache_polys = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    mono = np.zeros((cpt, params.n_poly), np.int64)
    mono[np.arange(cpt), np.arange(cpt) * stride] = 1
    mrns = _to_rns(mono, params)                          # (P, cpt, N)
    twiddles = jnp.stack([
        ntt_ops.ntt_fwd(jnp.asarray(mrns[i]), ctx)
        for i, ctx in enumerate(params.ctxs)
    ])                                                    # (P, cpt, N)
    return CandidateCache(params=params, polys=cache_polys, twiddles=twiddles,
                          n_dim=n_dim, num_docs=num_docs, stride=stride,
                          cands_per_ct=cpt, num_chunks=chunks)


@functools.partial(jax.jit,
                   static_argnames=("ctxs", "cpt", "pad", "use_pallas"))
def _cached_scores(c0, c1, polys, ids, twiddles, ctxs, cpt, pad, use_pallas):
    """Whole-batch cached scoring in ONE compiled call: the cache gather,
    last-ct zero padding, and the per-prime loop all live in a single trace,
    so the full gather -> rotate -> Hadamard -> slot/chunk mod-sum -> iNTT
    pipeline runs without host round-trips.  ``use_pallas`` is static: the
    same trace routes through the fused Pallas kernel + kernel NTTs or the
    jitted XLA references (one layout/padding implementation for both, so
    the bit-identity contract holds by construction)."""
    bsz, num_cands = ids.shape
    chunks, n = c0.shape[1], c0.shape[-1]
    g = jnp.take(polys, ids.reshape(-1), axis=0)
    g = g.reshape((bsz, num_cands) + polys.shape[1:])   # (B, nc, chunks, P, N)
    if pad:                  # empty slots of the last result ciphertext
        g = jnp.concatenate(
            [g, jnp.zeros((bsz, pad) + g.shape[2:], jnp.int32)], axis=1)
    num_ct = (num_cands + pad) // cpt
    outs0, outs1 = [], []
    for i, ctx in enumerate(ctxs):
        f0 = ntt_ops.ntt_fwd(c0[:, :, i, :], ctx, use_pallas=use_pallas)
        f1 = ntt_ops.ntt_fwd(c1[:, :, i, :], ctx, use_pallas=use_pallas)
        polys_i = g[..., i, :].reshape(bsz, num_ct, cpt * chunks, n)
        acc0, acc1 = ntt_ops.fused_rotate_hadamard(
            polys_i, twiddles[i], f0, f1, ctx, use_pallas=use_pallas)
        outs0.append(ntt_ops.ntt_inv(acc0, ctx, use_pallas=use_pallas))
        outs1.append(ntt_ops.ntt_inv(acc1, ctx, use_pallas=use_pallas))
    return jnp.stack(outs0, axis=2), jnp.stack(outs1, axis=2)


def encrypted_scores_cached_batch(params: RlweParams,
                                  q_cts: Sequence[QueryCiphertext],
                                  cache: CandidateCache, cand_ids,
                                  *, use_pallas=None) -> ScoreCiphertextBatch:
    """Batched ct (x) p against cached NTT-domain candidates.

    Per-request work: one gather of k' cached rows per lane, one fused
    rotate -> Hadamard -> slot/chunk mod-sum per prime (Pallas kernel or the
    jitted XLA fallback), 2*chunks forward NTTs for the query and 2 inverse
    NTTs per result ciphertext.  No per-candidate host loop and no candidate
    forward NTTs — those moved to `build_candidate_cache`.  Bit-identical to
    pack_candidates_batch + encrypted_scores_batch (same decrypted scores,
    same wire bytes).
    """
    ids = np.asarray(cand_ids)
    assert ids.ndim == 2, "cand_ids must be (B, num_cands)"
    bsz, num_cands = ids.shape
    assert len(q_cts) == bsz
    cache.check_compatible(params, q_cts[0].n_dim)
    cpt = cache.cands_per_ct
    num_ct = -(-num_cands // cpt)
    pad = num_ct * cpt - num_cands
    c0 = jnp.stack([q.c0 for q in q_cts])                 # (B, chunks, P, N)
    c1 = jnp.stack([q.c1 for q in q_cts])
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    all0, all1 = _cached_scores(
        c0, c1, cache.polys, jnp.asarray(ids), cache.twiddles,
        params.ctxs, cpt, pad, bool(use_pallas))
    return ScoreCiphertextBatch(c0=all0, c1=all1, n_dim=cache.n_dim,
                                num_cands=num_cands)


def encrypted_scores_cached(params: RlweParams, q_ct: QueryCiphertext,
                            cache: CandidateCache, cand_ids,
                            *, use_pallas=None) -> ScoreCiphertexts:
    """Cached ct (x) p for one query (the B=1 slice of the batch version)."""
    res = encrypted_scores_cached_batch(
        params, [q_ct], cache, np.asarray(cand_ids)[None],
        use_pallas=use_pallas)
    return res.lane(0)


# ---------------------------------------------------------------------------
# cloud side: pack candidates, encrypted scoring
# ---------------------------------------------------------------------------

def pack_candidates_batch(params: RlweParams,
                          cands: np.ndarray) -> jnp.ndarray:
    """Pack (B, num_cands, n_dim) candidate rows -> (B, num_ct, chunks, P, N)
    NTT-domain plaintexts.  The reversed placement (p[o + chunk-1 - j] =
    seg[j]) vectorizes over B; the NTT batches all leading dims."""
    bsz, num_cands, n_dim = cands.shape
    chunks = params.num_chunks(n_dim)
    stride = params.stride(n_dim)
    cpt = params.cands_per_ct(n_dim)
    num_ct = -(-num_cands // cpt)
    ints = _fixed_point(cands, params.scale_c)  # (B, num_cands, n_dim)

    polys = np.zeros((bsz, num_ct, chunks, params.n_poly), np.int64)
    for cand in range(num_cands):
        ct_i, slot = divmod(cand, cpt)
        o = slot * stride
        for c in range(chunks):
            seg = ints[:, cand, c * params.chunk:(c + 1) * params.chunk]
            idx = o + params.chunk - 1 - np.arange(seg.shape[1])
            polys[:, ct_i, c, idx] = seg
    rns = _to_rns(polys, params)  # (P, B, num_ct, chunks, N)
    return jnp.stack([
        ntt_ops.ntt_fwd(jnp.asarray(rns[i]), ctx)
        for i, ctx in enumerate(params.ctxs)
    ], axis=3)  # (B, num_ct, chunks, P, N) — stays on device


def pack_candidates(params: RlweParams, cands: np.ndarray) -> PackedCandidates:
    """Pack candidate embeddings (num_cands, n_dim) into NTT-domain
    plaintexts (the B=1 slice of the batch packer — one source of truth)."""
    num_cands, n_dim = cands.shape
    polys = pack_candidates_batch(params, np.asarray(cands)[None])[0]
    return PackedCandidates(polys=polys, n_dim=n_dim, num_cands=num_cands)


@functools.partial(jax.jit, static_argnames=("ctxs",))
def _scores_batch_ref(c0, c1, packed, ctxs):
    """Whole-batch fallback scoring in ONE compiled call: the per-prime loop
    unrolls at trace time (no host round-trips between primes) and the
    homomorphic chunk-sum is a vectorized mod-sum, not a Python loop."""
    outs0, outs1 = [], []
    for i, ctx in enumerate(ctxs):
        f0 = ntt_ref.ntt_fwd_ref(c0[:, :, i, :], ctx)   # (B, chunks, N)
        f1 = ntt_ref.ntt_fwd_ref(c1[:, :, i, :], ctx)
        pk = packed[:, :, :, i, :]                      # (B, num_ct, chunks, N)
        prod0 = modring.mod_mul(pk, f0[:, None], ctx.q, ctx.mu)
        prod1 = modring.mod_mul(pk, f1[:, None], ctx.q, ctx.mu)
        acc0 = modring.mod_sum(prod0, ctx.q, ctx.mu, axis=2)
        acc1 = modring.mod_sum(prod1, ctx.q, ctx.mu, axis=2)
        outs0.append(ntt_ref.ntt_inv_ref(acc0, ctx))
        outs1.append(ntt_ref.ntt_inv_ref(acc1, ctx))
    return jnp.stack(outs0, axis=2), jnp.stack(outs1, axis=2)


def encrypted_scores_batch_stacked(params: RlweParams,
                                   q_cts: Sequence[QueryCiphertext],
                                   packed: jnp.ndarray, num_cands: int,
                                   n_dim: int, *,
                                   use_pallas=None) -> ScoreCiphertextBatch:
    """Batched ct (x) p: B query ciphertexts against (B, num_ct, chunks, P,
    N) packed candidates, chunk-summed in the NTT domain — one NTT dispatch
    per prime for the whole batch.

    This is the cloud's entire encrypted workload: 2 * chunks forward NTTs
    per query (amortized over all candidates), one Hadamard modmul per
    (lane, result-ct, chunk, component, prime), and 2 inverse NTTs per
    result ct.  The result stays stacked on device.
    """
    c0 = jnp.stack([q.c0 for q in q_cts])  # (B, chunks, P, N)
    c1 = jnp.stack([q.c1 for q in q_cts])
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        all0, all1 = _scores_batch_ref(c0, c1, packed, params.ctxs)
        return ScoreCiphertextBatch(c0=all0, c1=all1, n_dim=n_dim,
                                    num_cands=num_cands)
    c0_out, c1_out = [], []
    for i, ctx in enumerate(params.ctxs):
        f0 = ntt_ops.ntt_fwd(c0[:, :, i, :], ctx, use_pallas=True)
        f1 = ntt_ops.ntt_fwd(c1[:, :, i, :], ctx, use_pallas=True)
        pk = packed[:, :, :, i, :]                 # (B, num_ct, chunks, N)
        f0b = jnp.broadcast_to(f0[:, None], pk.shape)
        f1b = jnp.broadcast_to(f1[:, None], pk.shape)
        prod0 = ntt_ops.pointwise_mul(pk, f0b, ctx, use_pallas=True)
        prod1 = ntt_ops.pointwise_mul(pk, f1b, ctx, use_pallas=True)
        acc0 = modring.mod_sum(prod0, ctx.q, ctx.mu, axis=2)
        acc1 = modring.mod_sum(prod1, ctx.q, ctx.mu, axis=2)
        c0_out.append(ntt_ops.ntt_inv(acc0, ctx, use_pallas=True))
        c1_out.append(ntt_ops.ntt_inv(acc1, ctx, use_pallas=True))
    return ScoreCiphertextBatch(
        c0=jnp.stack(c0_out, axis=2), c1=jnp.stack(c1_out, axis=2),
        n_dim=n_dim, num_cands=num_cands)


def encrypted_scores_batch(params: RlweParams,
                           q_cts: Sequence[QueryCiphertext],
                           packed: jnp.ndarray, num_cands: int, n_dim: int,
                           *, use_pallas=None) -> list:
    """List-of-lanes view of `encrypted_scores_batch_stacked` (lanes are
    views of one stacked device array, no per-lane crypto work)."""
    return encrypted_scores_batch_stacked(
        params, q_cts, packed, num_cands, n_dim,
        use_pallas=use_pallas).lanes()


def encrypted_scores(params: RlweParams, q_ct: QueryCiphertext,
                     packed: PackedCandidates, *,
                     use_pallas=None) -> ScoreCiphertexts:
    """ct (x) p per candidate block (the B=1 slice of the batch version)."""
    assert q_ct.n_dim == packed.n_dim
    return encrypted_scores_batch(
        params, [q_ct], packed.polys[None], num_cands=packed.num_cands,
        n_dim=packed.n_dim, use_pallas=use_pallas)[0]


def decrypt_scores_batch(sks: Sequence[RlweSecretKey], cts,
                         *, use_pallas=None) -> list:
    """Decrypt B score ciphertexts under B (distinct) tenant keys with one
    NTT dispatch per prime; CRT extraction stays per-lane (host bignums).

    ``cts`` is either a list of ScoreCiphertexts or a ScoreCiphertextBatch —
    the stacked form skips the per-lane restack entirely."""
    params = sks[0].params
    if isinstance(cts, ScoreCiphertextBatch):
        c0, c1 = cts.c0, cts.c1
        meta = [(cts.n_dim, cts.num_cands)] * cts.batch
    else:
        c0 = jnp.stack([c.c0 for c in cts])        # (B, num_ct, P, N)
        c1 = jnp.stack([c.c1 for c in cts])
        meta = [(c.n_dim, c.num_cands) for c in cts]
    s_ntt = jnp.stack([sk.s_ntt for sk in sks])[:, None]  # (B, 1, P, N)
    d_rns = decrypt_rns(params, s_ntt, c0, c1, use_pallas=use_pallas)
    return [extract_scores(params, d_rns[b], nd, nc)
            for b, (nd, nc) in enumerate(meta)]


def cosine_distances(scores: np.ndarray) -> np.ndarray:
    """Paper Definition 2 over decrypted inner products."""
    return 1.0 - scores


__all__ = [
    "RlweParams", "RlweSecretKey", "QueryCiphertext", "PackedCandidates",
    "ScoreCiphertexts", "ScoreCiphertextBatch", "CandidateCache",
    "params_key", "build_candidate_cache", "keygen", "encrypt_query",
    "decrypt_scores", "decrypt_scores_batch", "decrypt_rns",
    "extract_scores", "pack_candidates", "pack_candidates_batch",
    "encrypted_scores", "encrypted_scores_batch",
    "encrypted_scores_batch_stacked", "encrypted_scores_cached",
    "encrypted_scores_cached_batch", "cosine_distances",
]
