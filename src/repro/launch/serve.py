"""Serving driver: the private RAG service end to end.

Builds a synthetic corpus + FlatIndex, instantiates the RemoteRAG cloud and a
user, and serves a stream of queries through the full protocol (Module 1
DistanceDP + range limitation, Module 2a encrypted re-rank, Module 2b/2c
retrieval), printing latency and wire-size stats per request.

`python -m repro.launch.serve --n-docs 20000 --requests 5 --backend rlwe`
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

from repro.core import protocol
from repro.data import synth
from repro.retrieval.index import FlatIndex


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=384)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--radius", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--backend", choices=("rlwe", "paillier"), default="rlwe")
    ap.add_argument("--corpus", choices=("uniform", "clustered"),
                    default="uniform")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    gen = (synth.uniform_corpus if args.corpus == "uniform"
           else synth.clustered_corpus)
    emb = gen(rng, args.n_docs, args.dim)
    docs = synth.passages(rng, args.n_docs, avg_bytes=256)
    index = FlatIndex.build(emb, documents=docs)

    user = protocol.RemoteRagUser(n=args.dim, N=args.n_docs, k=args.k,
                                  radius=args.radius, backend=args.backend,
                                  rng=rng)
    cloud = protocol.RemoteRagCloud(
        index, rlwe_params=getattr(user, "rlwe_params", None))
    queries = synth.queries_near_corpus(rng, emb, args.requests)

    print(json.dumps({"plan": {
        "eps": user.plan.eps, "kprime": user.plan.kprime,
        "path": user.plan.path, "radius": user.plan.radius}}))

    stats = []
    for i, q in enumerate(queries):
        t0 = time.monotonic()
        docs_out, ids, tr = protocol.run_remoterag(
            user, cloud, q, jax.random.PRNGKey(i))
        dt = time.monotonic() - t0
        plain = np.argsort(-(emb @ q), kind="stable")[: args.k]
        recall = len(set(ids.tolist()) & set(plain.tolist())) / args.k
        stats.append({"request": i, "latency_s": round(dt, 3),
                      "recall": recall, "wire_bytes": tr.total_bytes,
                      "path": tr.path})
        print(json.dumps(stats[-1]))
    lat = [s["latency_s"] for s in stats]
    print(json.dumps({"summary": {
        "mean_latency_s": round(float(np.mean(lat)), 3),
        "mean_recall": float(np.mean([s["recall"] for s in stats])),
        "mean_wire_kb": round(float(np.mean(
            [s["wire_bytes"] for s in stats])) / 1024, 2)}}))


if __name__ == "__main__":
    main()
