import secrets

import pytest

from repro.crypto import ot


def test_receiver_gets_selected_messages():
    msgs = [f"document-{i}".encode() * 3 for i in range(12)]
    got, wire = ot.run_ot(msgs, selected=[2, 7, 11])
    assert got == [msgs[2], msgs[7], msgs[11]]
    assert wire > 0


def test_non_selected_keys_mismatch():
    """A cheating receiver cannot decrypt unselected messages."""
    msgs = [secrets.token_bytes(64) for _ in range(5)]
    sender = ot.OtSender(messages=msgs)
    receiver = ot.OtReceiver(selected=[0], total=5)
    A = sender.round1()
    bs = receiver.round1(A)
    enc = sender.round2(bs)
    # decrypt index 3 with the honest-path key (c_3 was 1)
    key = ot._hash_key(pow(A, receiver._bs[3], receiver.p))
    forged = ot._xor(enc[3], ot._keystream(key, 3, len(enc[3])))
    assert forged != msgs[3]


def test_sender_view_independent_of_selection():
    """B_i are uniformly distributed regardless of c_i: the sender's view for
    a selected index has the same support as for an unselected one."""
    msgs = [b"x" * 8 for _ in range(4)]
    sender = ot.OtSender(messages=msgs)
    A = sender.round1()
    r_sel = ot.OtReceiver(selected=[0, 1, 2, 3], total=4)
    r_none = ot.OtReceiver(selected=[], total=4)
    bs_sel = r_sel.round1(A)
    bs_none = r_none.round1(A)
    # all group elements in range and distinct (overwhelming probability)
    for b in bs_sel + bs_none:
        assert 0 < b < ot.MODP_2048_P
    assert len(set(bs_sel + bs_none)) == 8


def test_variable_length_messages():
    msgs = [b"a", b"bb" * 100, b"ccc" * 1000]
    got, _ = ot.run_ot(msgs, selected=[1, 2])
    assert got == [msgs[1], msgs[2]]


def test_wire_size_formula():
    """Appendix A.1: 1.5 rounds, (k'+1) group elements + k' encrypted docs."""
    k_prime, doc = 8, 256
    msgs = [secrets.token_bytes(doc) for _ in range(k_prime)]
    _, wire = ot.run_ot(msgs, selected=[0])
    group = (ot.MODP_2048_P.bit_length() + 7) // 8
    assert wire == group * (1 + k_prime) + k_prime * doc
