"""Production mesh construction.

single pod: (16, 16) = ("data", "model")   — 256 chips
multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips

A function (not a module-level constant) so importing never touches jax
device state; dryrun.py sets XLA_FLAGS before any jax import.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Axes that carry data parallelism (pod extends data)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def row_axes(mesh) -> tuple:
    """All axes, for corpus/embedding-table row sharding."""
    return tuple(mesh.axis_names)


__all__ = ["make_production_mesh", "batch_axes", "row_axes"]
