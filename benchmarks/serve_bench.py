"""Serving-engine benchmark: batched vs sequential QPS and latency.

    PYTHONPATH=src python -m benchmarks.serve_bench

Builds one synthetic corpus, opens a pool of tenant sessions, then pushes the
same request stream through (a) the sequential one-query-per-step path and
(b) the micro-batching engine at several batch sizes.  Reports throughput
(QPS), p50/p99 enqueue-to-result latency, and mean wire KB per request, and
checks the two paths return identical per-query results (ids + wire bytes).

Default sizes finish in a few minutes on CPU; REPRO_BENCH_FULL=1 scales the
corpus and request count toward the paper's 10^6-document setting.

Beyond the CSV rows this writes machine-readable ``BENCH_serve.json``
(path override: BENCH_SERVE_JSON); ``scripts/check_bench_regression.py
--serve-json`` gates batch-8 occupancy and the batched-vs-sequential QPS
ratio on it.

The run finishes with a closed-loop offered-load sweep (the ``overload``
section): a paced open-arrival driver pushes 0.5x/1x/2x the measured
saturation throughput through an admission-controlled engine (token-free,
bounded queue + deadline shedding, alternating interactive/best-effort
priorities) and through an unlimited engine at 2x.  The gate
(`_check_overload`) requires goodput to hold past the knee, interactive
p99 to stay bounded, and offered == completed + shed at every point —
zero lost requests — while the unlimited config collapses.

``--overload-smoke`` runs a seconds-scale version of just that sweep on a
tiny corpus (no JSON written) — wired into scripts/smoke.sh.

It then runs the scale-out **replica sweep** (the ``replica_sweep``
section): the same stream through a `repro.serve.ReplicaRouter` at 1/2/4
replicas — QPS, p99, merge overhead, per-query parity with the 1-replica
run — plus a fault point (one replica's slice scan poisoned mid-drain at
2 replicas) whose accounting must balance exactly: offered == returned,
zero lost.  ``host_cpus`` is recorded so the regression gate can apply
the physical scaling bound (2 replicas >= 1.3x on multi-core hosts,
bounded router overhead on 1-CPU hosts) — see docs/scale_out.md.

Last, the ``retry_lane`` section: a transiently-faulted stream through
the engine with quarantine solo retries on the background retry lane vs
inline on the dispatch thread, against a fault-free baseline — the
healthy requests' p99 with the lane on is gated
(``--max-retry-p99-ratio``) against the fault-free run.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax

from benchmarks.common import FULL, emit
from repro.crypto import rlwe
from repro.data import synth
from repro.retrieval.index import FlatIndex
from repro.serve import (AdmissionConfig, AdmissionError, EngineConfig,
                         ReplicaRouter, RouterConfig, ServeEngine)

N_DOCS = 200_000 if FULL else 20_000
DIM = 384 if FULL else 128
N_REQUESTS = 64 if FULL else 16
N_TENANTS = 8
K = 5
RADIUS = 0.05
BATCH_SIZES = (1, 4, 8)
# CPU-friendly ring: the serving hot loop is NTT-bound, and n_poly=1024
# still fits DIM-dim queries in one chunk (identical protocol semantics).
RLWE_PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)

OUT_PATH = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")


def build_engine(index, *, sequential: bool, max_batch: int,
                 admission: AdmissionConfig | None = None,
                 n_docs: int = None, dim: int = None) -> ServeEngine:
    from repro.serve.session import SessionManager

    n_docs = N_DOCS if n_docs is None else n_docs
    dim = DIM if dim is None else dim
    # deterministic seeds: the sequential and batched engines must replay
    # identical tenant key/noise streams for the per-query parity check
    engine = ServeEngine(
        index,
        config=EngineConfig(max_batch=max_batch, sequential=sequential,
                            admission=admission),
        sessions=SessionManager(rlwe_params=RLWE_PARAMS,
                                deterministic_seeds=True))
    for t in range(N_TENANTS):
        engine.open_session(f"tenant-{t}", n=dim, N=n_docs, k=K,
                            radius=RADIUS, backend="rlwe")
    return engine


def run_stream(engine: ServeEngine, queries, *, warmup: bool = True) -> tuple:
    """Push the stream through once untimed (jit warmup for this engine's
    batch shapes), then measure the steady-state pass."""
    from repro.serve.metrics import ServeMetrics

    if warmup:
        for i, q in enumerate(queries):
            engine.submit(f"tenant-{i % N_TENANTS}", q,
                          key=jax.random.PRNGKey(i))
        engine.drain()
        engine.metrics = ServeMetrics()
    t0 = time.monotonic()
    for i, q in enumerate(queries):
        engine.submit(f"tenant-{i % N_TENANTS}", q,
                      key=jax.random.PRNGKey(i))
    results = engine.drain()
    wall = time.monotonic() - t0
    return results, wall


# -- closed-loop offered-load sweep -----------------------------------------

def warm_batch_sizes(index, max_batch: int, queries, *,
                     n_docs: int = None, dim: int = None) -> None:
    """Compile every dispatch shape 1..max_batch once.  The paced driver
    forms whatever partial batches the arrival process yields; an
    unwarmed shape would bill jit compilation to the measured latency."""
    engine = build_engine(index, sequential=False, max_batch=max_batch,
                          n_docs=n_docs, dim=dim)
    for bs in range(1, max_batch + 1):
        for i in range(bs):
            engine.submit(f"tenant-{i % N_TENANTS}",
                          queries[i % len(queries)],
                          key=jax.random.PRNGKey(1000 + bs * 16 + i))
        engine.drain()
    engine.close()


def run_offered_load(engine: ServeEngine, queries, *, offered_qps: float,
                     n: int, deadline_s: float) -> dict:
    """Paced open-arrival driver: request i arrives at i/offered_qps,
    priorities alternate interactive/best-effort, every request carries
    the same deadline.  Submits and step() share one thread (the engine
    is synchronous), so a long dispatch naturally delays — then bursts —
    the overdue arrivals, exactly the closed-loop overload shape.
    Returns the per-point accounting dict for the bench JSON."""
    results = []
    prio_by_rid = {}
    rejected = 0
    submitted = 0
    t0 = time.monotonic()
    while submitted < n:
        due = t0 + submitted / offered_qps
        now = time.monotonic()
        if now >= due:
            i = submitted
            prio = "interactive" if i % 2 == 0 else "best_effort"
            try:
                rid = engine.submit(
                    f"tenant-{i % N_TENANTS}", queries[i % len(queries)],
                    key=jax.random.PRNGKey(i), priority=prio,
                    deadline_s=deadline_s)
                prio_by_rid[rid] = prio
            except AdmissionError:
                rejected += 1
            submitted += 1
            continue
        stepped = engine.step()
        results.extend(stepped)
        if not stepped:
            time.sleep(min(0.0005, max(due - time.monotonic(), 0.0)))
    results.extend(engine.drain())
    wall = time.monotonic() - t0

    rids = [r.request_id for r in results]
    assert len(rids) == len(set(rids)), "duplicate results in paced run"
    completed = [r for r in results if r.shed_reason is None]
    ok = [r for r in completed if r.ok]
    good = [r for r in ok if r.latency_s <= deadline_s]
    shed = [r for r in results if r.shed_reason is not None]
    lats = [r.latency_s for r in ok]
    ia_lats = [r.latency_s for r in ok
               if prio_by_rid.get(r.request_id) == "interactive"]
    return {
        "offered_qps": offered_qps,
        "offered": n,
        "completed": len(completed),
        "completed_ok": len(ok),
        "shed": len(shed) + rejected,
        "rejected_submits": rejected,
        # the zero-loss contract: every offered request is accounted for
        # as a completion, a shed result, or a typed submit rejection
        "lost": n - len(results) - rejected,
        "wall_s": wall,
        "goodput_qps": len(good) / wall,
        "deadline_misses": len(ok) - len(good),
        "p99_s": float(np.percentile(lats, 99)) if lats else None,
        "p99_interactive_s": (float(np.percentile(ia_lats, 99))
                              if ia_lats else None),
        "shed_by_reason": dict(engine.metrics.shed_by_reason),
    }


def overload_sweep(index, queries, *, capacity_qps: float, max_batch: int,
                   n_per_point: int, n_docs: int = None,
                   dim: int = None) -> dict:
    """Offered-load curve around the measured saturation point.

    Admission-controlled points at 0.5x/1x/2x capacity (bounded queue +
    deadline shedding; the deadline is four batch-services, the queue
    bound four batches) and an unlimited point at 2x (admission=None —
    requests still carry deadlines so misses are counted, but nothing is
    ever shed and the queue grows without bound)."""
    deadline_s = 4.0 * max_batch / capacity_qps
    max_queue = 4 * max_batch
    admission = AdmissionConfig(max_queue=max_queue,
                                default_deadline_s=deadline_s)
    warm_batch_sizes(index, max_batch, queries, n_docs=n_docs, dim=dim)
    points = {}
    for label, mult, adm_cfg in (("0.5x", 0.5, admission),
                                 ("1x", 1.0, admission),
                                 ("2x", 2.0, admission),
                                 ("2x_unlimited", 2.0, None)):
        engine = build_engine(index, sequential=False, max_batch=max_batch,
                              admission=adm_cfg, n_docs=n_docs, dim=dim)
        # one full unpaced batch: seeds the controller's per-group
        # dispatch estimate (deadline shedding needs an observed p50)
        for i in range(max_batch):
            engine.submit(f"tenant-{i % N_TENANTS}",
                          queries[i % len(queries)],
                          key=jax.random.PRNGKey(2000 + i))
        engine.drain()
        from repro.serve.metrics import ServeMetrics
        engine.metrics = ServeMetrics()
        point = run_offered_load(engine, queries,
                                 offered_qps=mult * capacity_qps,
                                 n=n_per_point, deadline_s=deadline_s)
        point["admission"] = adm_cfg is not None
        engine.close()
        points[label] = point
        emit(f"serve_overload_{label}", point["wall_s"] * 1e6,
             f"offered={point['offered_qps']:.1f}qps "
             f"goodput={point['goodput_qps']:.2f}qps "
             f"shed={point['shed']} lost={point['lost']} "
             f"p99_ia={point['p99_interactive_s'] or float('nan'):.3f}s")
        assert point["lost"] == 0, f"lost requests at {label}: {point}"
    return {
        "capacity_qps": capacity_qps,
        "max_batch": max_batch,
        "deadline_s": deadline_s,
        "max_queue": max_queue,
        # the CI bound on interactive p99 under overload: two deadlines
        # (a request either completes within ~its budget or is shed)
        "p99_bound_s": 2.0 * deadline_s,
        "points": points,
    }


def overload_smoke() -> None:
    """Seconds-scale overload sweep on a tiny corpus for scripts/smoke.sh:
    checks the zero-loss contract and that the 2x point actually sheds.
    Writes no JSON."""
    n_docs, dim, max_batch, n_point = 2_000, 64, 4, 16
    rng = np.random.default_rng(0)
    emb = synth.uniform_corpus(rng, n_docs, dim)
    docs = [f"doc-{i}".encode() for i in range(n_docs)]
    index = FlatIndex.build(emb, documents=docs)
    queries = synth.queries_near_corpus(rng, emb, 8)

    engine = build_engine(index, sequential=False, max_batch=max_batch,
                          n_docs=n_docs, dim=dim)
    results, wall = run_stream(engine, queries, warmup=True)
    engine.close()
    capacity = len(results) / wall
    print(f"# overload smoke: capacity ~{capacity:.1f} qps")
    section = overload_sweep(index, queries, capacity_qps=capacity,
                             max_batch=max_batch, n_per_point=n_point,
                             n_docs=n_docs, dim=dim)
    two_x = section["points"]["2x"]
    assert two_x["shed"] > 0, "2x overload point must shed something"
    for label, point in section["points"].items():
        assert point["lost"] == 0, f"lost requests at {label}"
        assert point["offered"] == (point["completed"] + point["shed"]), \
            f"accounting mismatch at {label}: {point}"
    print("# overload smoke ok")


# -- replica-router scale-out sweep ------------------------------------------

def build_router(index, num_replicas: int, *, max_batch: int,
                 n_docs: int = None, dim: int = None) -> ReplicaRouter:
    from repro.serve.session import SessionManager

    n_docs = N_DOCS if n_docs is None else n_docs
    dim = DIM if dim is None else dim
    router = ReplicaRouter(
        index,
        config=RouterConfig(num_replicas=num_replicas,
                            engine=EngineConfig(max_batch=max_batch)),
        sessions=SessionManager(rlwe_params=RLWE_PARAMS,
                                deterministic_seeds=True))
    for t in range(N_TENANTS):
        router.open_session(f"tenant-{t}", n=dim, N=n_docs, k=K,
                            radius=RADIUS, backend="rlwe")
    return router


def replica_sweep(index, queries, *, max_batch: int,
                  n_docs: int = None, dim: int = None) -> dict:
    """Scale-out sweep (the ``replica_sweep`` section): the same request
    stream through a ReplicaRouter at 1/2/4 replicas — QPS, p99 and the
    merge overhead per point, per-query parity against the 1-replica run
    (the router's bit-identity contract, here checked end to end on the
    bench corpus) — then a fault point: one replica poisoned mid-drain at
    2 replicas, every request accounted for (zero lost).

    ``host_cpus`` is recorded because the scaling gate is physical: on a
    multi-core host 2 replicas must reach >= 1.3x the 1-replica QPS
    (replica drains and slice scans run on separate workers); a 1-CPU
    host cannot parallelize threads, so the gate there bounds router
    overhead instead (`scripts/check_bench_regression.py`)."""
    stream = list(queries) * 2       # smooth short-stream QPS noise
    points = {}
    baseline = None
    for n_rep in (1, 2, 4):
        router = build_router(index, n_rep, max_batch=max_batch,
                              n_docs=n_docs, dim=dim)
        for i, q in enumerate(stream):           # jit warmup pass
            router.submit(f"tenant-{i % N_TENANTS}", q,
                          key=jax.random.PRNGKey(i))
        router.drain()
        merge0 = router.metrics.summary()["merge_wall_s"]
        t0 = time.monotonic()
        for i, q in enumerate(stream):
            router.submit(f"tenant-{i % N_TENANTS}", q,
                          key=jax.random.PRNGKey(i))
        results = router.drain()
        wall = time.monotonic() - t0
        m = router.metrics.summary()
        router.close()
        assert all(r.ok for r in results)
        assert m["quarantines"] == [] and m["late_dropped"] == 0
        if baseline is None:
            baseline = results
        else:    # bit-identity vs the 1-replica run, per query
            for rb, rn in zip(baseline, results):
                assert rb.request_id == rn.request_id
                assert rb.ids.tolist() == rn.ids.tolist(), (
                    f"id mismatch at {n_rep} replicas: {rb.ids} vs {rn.ids}")
                assert rb.docs == rn.docs
                assert (rb.transcript.total_bytes
                        == rn.transcript.total_bytes)
        lats = [r.latency_s for r in results]
        merge_s = m["merge_wall_s"] - merge0
        qps = len(results) / wall
        points[str(n_rep)] = {
            "replicas": n_rep,
            "qps": qps,
            "p50_s": float(np.percentile(lats, 50)),
            "p99_s": float(np.percentile(lats, 99)),
            "merge_wall_s": merge_s,
            "merge_frac": merge_s / wall,
            "scatter_calls": m["scatter_calls"],
        }
        emit(f"serve_replicas_{n_rep}", wall / len(results) * 1e6,
             f"qps={qps:.3f} p99={points[str(n_rep)]['p99_s']:.3f}s "
             f"merge={100.0 * merge_s / wall:.2f}%")

    # fault point: poison one replica's slice scan mid-run at 2 replicas;
    # the router must quarantine it, fall back for its slice, and resolve
    # every ledgered request — offered == returned, zero lost
    router = build_router(index, 2, max_batch=max_batch,
                          n_docs=n_docs, dim=dim)
    for i, q in enumerate(stream):               # warmup before the fault
        router.submit(f"tenant-{i % N_TENANTS}", q,
                      key=jax.random.PRNGKey(i))
    router.drain()
    victim = 1

    def poison(replica_id: int) -> None:
        if replica_id == victim:
            raise RuntimeError("injected scan fault")

    router._scan_hook = poison
    rids = [router.submit(f"tenant-{i % N_TENANTS}", q,
                          key=jax.random.PRNGKey(i))
            for i, q in enumerate(stream)]
    results = router.drain()
    m = router.metrics.summary()
    router.close()
    got_rids = [r.request_id for r in results]
    assert sorted(got_rids) == sorted(rids), "fault point lost a request"
    fault = {
        "victim": victim,
        "offered": len(rids),
        "returned": len(results),
        "ok": sum(r.ok for r in results),
        "quarantine_errors": sum(bool(r.quarantined and not r.ok)
                                 for r in results),
        "lost": len(rids) - len(results),
        "submitted": m["submitted"],
        "completed": m["completed"],
        "quarantine_resolved": m["quarantine_resolved"],
        "late_dropped": m["late_dropped"],
        "fallback_scans": m["fallback_scans"],
        "quarantines": m["quarantines"],
    }
    emit("serve_replicas_fault", 0.0,
         f"offered={fault['offered']} returned={fault['returned']} "
         f"quarantined={fault['quarantine_errors']} lost={fault['lost']}")
    return {
        "host_cpus": os.cpu_count(),
        "max_batch": max_batch,
        "requests": len(stream),
        "parity_checked": True,
        "points": points,
        "fault": fault,
    }


def retry_lane_section() -> dict:
    """Quarantine retry-lane impact (the ``retry_lane`` section): the
    same transiently-faulted stream (4 lanes fail their first fetch, the
    solo retry succeeds) through the engine with the background retry
    lane on vs off, plus a fault-free baseline.  With the lane on, solo
    retries run off the dispatch thread, so the *healthy* requests' p99
    must stay within the gated ratio of the fault-free run
    (`scripts/check_bench_regression.py --max-retry-p99-ratio`, default
    1.5); the lane-off pass records what inline retries cost the same
    healthy traffic."""
    from repro.serve.session import SessionManager

    dim, n_docs, n_req, mb, faults = 64, 2048, 24, 4, (0, 6, 12, 18)
    rng = np.random.default_rng(7)
    emb = synth.uniform_corpus(rng, n_docs, dim)
    index = FlatIndex.build(emb,
                            documents=synth.passages(rng, n_docs,
                                                     avg_bytes=128))
    queries = synth.queries_near_corpus(rng, emb, n_req)

    def run_pass(retry_lane: bool, poison_idsets):
        eng = ServeEngine(
            index,
            config=EngineConfig(max_batch=mb, max_wait_s=30.0,
                                retry_lane=retry_lane),
            sessions=SessionManager(rlwe_params=RLWE_PARAMS,
                                    deterministic_seeds=True))
        for t in range(4):
            eng.open_session(f"tenant-{t}", n=dim, N=n_docs, k=K,
                             radius=RADIUS, backend="rlwe")

        def submit_all():
            for i, q in enumerate(queries):
                eng.submit(f"tenant-{i % 4}", q, key=jax.random.PRNGKey(i))

        if poison_idsets is not None:
            # fault every even-numbered fetch of a poisoned lane: the
            # batch dispatch faults, its solo retry heals — in the warmup
            # round too, so the solo-retry path jit-compiles *before*
            # timing starts
            real = type(eng.cloud).handle_fetch
            seen = {ids: 0 for ids in poison_idsets}

            def poisoned(cand_ids, msg):
                ids = tuple(int(cand_ids[p]) for p in msg.positions)
                if ids in seen:
                    seen[ids] += 1
                    if seen[ids] % 2 == 1:   # transient: retry succeeds
                        raise RuntimeError("bench transient fetch fault")
                return real(eng.cloud, cand_ids, msg)

            eng.cloud.handle_fetch = poisoned
        submit_all()                # warmup for every batch + retry shape
        eng.drain()
        from repro.serve.metrics import ServeMetrics
        eng.metrics = ServeMetrics()
        submit_all()
        results = sorted(eng.drain(), key=lambda r: r.request_id)
        m = eng.metrics
        eng.close()
        assert len(results) == n_req, "retry-lane pass lost a request"
        assert all(r.ok for r in results), \
            "transient faults must resolve via the solo retry"
        healthy = [r.latency_s for j, r in enumerate(results)
                   if j not in faults]
        return results, m, float(np.percentile(healthy, 99))

    clean, _, p99_ff = run_pass(True, None)
    idsets = [tuple(clean[j].ids.tolist()) for j in faults]
    _, m_lane, p99_lane = run_pass(True, idsets)
    _, m_inline, p99_inline = run_pass(False, idsets)
    assert m_lane.retried_requests >= len(faults)
    assert m_inline.retried_requests >= len(faults)

    section = {
        "requests": n_req,
        "max_batch": mb,
        "faulted_requests": len(faults),
        "lost_requests": 0,
        "p99_fault_free_s": p99_ff,
        "p99_healthy_retry_lane_s": p99_lane,
        "p99_healthy_inline_s": p99_inline,
        "healthy_p99_ratio_vs_fault_free": p99_lane / p99_ff,
        "healthy_p99_ratio_vs_inline": p99_lane / p99_inline,
        "retried_requests_lane": m_lane.retried_requests,
        "retried_requests_inline": m_inline.retried_requests,
        "quarantined_lanes": m_lane.quarantined_lanes,
    }
    emit("serve_retry_lane_p99", p99_lane * 1e6,
         f"{section['healthy_p99_ratio_vs_fault_free']:.2f}x_fault_free_"
         f"{section['healthy_p99_ratio_vs_inline']:.2f}x_inline")
    return section


def main() -> None:
    rng = np.random.default_rng(0)
    emb = synth.uniform_corpus(rng, N_DOCS, DIM)
    docs = synth.passages(rng, N_DOCS, avg_bytes=256)
    index = FlatIndex.build(emb, documents=docs)
    queries = synth.queries_near_corpus(rng, emb, N_REQUESTS)

    print(f"# serve_bench: {N_DOCS} docs x dim {DIM}, {N_REQUESTS} requests "
          f"from {N_TENANTS} tenants, k={K}")

    seq_engine = build_engine(index, sequential=True, max_batch=1)
    seq_results, seq_wall = run_stream(seq_engine, queries)
    seq_qps = len(seq_results) / seq_wall
    agg = seq_engine.metrics.aggregate
    emit("serve_sequential", seq_wall / len(seq_results) * 1e6,
         f"qps={seq_qps:.3f} p50={agg.percentile(50):.3f}s "
         f"p99={agg.percentile(99):.3f}s "
         f"wire_kb={agg.total_wire_bytes / agg.count / 1024:.1f}")
    results_json = {"sequential": {
        "qps": seq_qps,
        "p50_s": agg.percentile(50),
        "p99_s": agg.percentile(99),
        "wire_kb_per_request": agg.total_wire_bytes / agg.count / 1024,
    }}

    qps_by_bs = {}
    for bs in BATCH_SIZES:
        engine = build_engine(index, sequential=False, max_batch=bs)
        results, wall = run_stream(engine, queries)
        qps = len(results) / wall
        qps_by_bs[bs] = qps
        agg = engine.metrics.aggregate
        occ = engine.metrics.occupancy(bs)
        emit(f"serve_batched_b{bs}", wall / len(results) * 1e6,
             f"qps={qps:.3f} p50={agg.percentile(50):.3f}s "
             f"p99={agg.percentile(99):.3f}s "
             f"speedup={qps / seq_qps:.2f}x "
             f"occupancy={occ:.2f}")
        # the clean stream must not trip the fault-isolation machinery
        assert engine.metrics.quarantined_lanes == 0
        assert engine.metrics.error_results == 0
        assert engine.metrics.healthy_reencryptions == 0
        # per-query parity with the sequential path
        for rs, rb in zip(seq_results, results):
            assert rs.ids.tolist() == rb.ids.tolist(), (
                f"id mismatch at batch {bs}: {rs.ids} vs {rb.ids}")
            assert rs.docs == rb.docs
            assert rs.transcript.total_bytes == rb.transcript.total_bytes, (
                f"wire mismatch at batch {bs}")
        results_json[f"batch{bs}"] = {
            "qps": qps,
            "p50_s": agg.percentile(50),
            "p99_s": agg.percentile(99),
            "speedup_vs_sequential": qps / seq_qps,
            "occupancy": occ,
            "num_batches": engine.metrics.num_batches,
            "refill_dispatches": engine.metrics.refill_dispatches,
        }

    big = max(bs for bs in BATCH_SIZES if bs >= 8)
    print(f"# batched (b={big}) {qps_by_bs[big]:.3f} qps vs sequential "
          f"{seq_qps:.3f} qps ({qps_by_bs[big] / seq_qps:.2f}x)")
    assert qps_by_bs[big] > seq_qps, \
        "batched throughput at batch >= 8 must beat sequential"
    results_json["parity_checked"] = True
    results_json["big_batch"] = big

    # closed-loop offered-load sweep around the measured saturation point
    results_json["overload"] = overload_sweep(
        index, queries, capacity_qps=qps_by_bs[big], max_batch=big,
        n_per_point=192 if FULL else 96)

    # scale-out replica sweep + fault point (docs/scale_out.md)
    results_json["replica_sweep"] = replica_sweep(index, queries,
                                                  max_batch=4)

    # quarantine retry-lane impact on healthy-batch p99 (docs/serving.md)
    results_json["retry_lane"] = retry_lane_section()

    payload = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "config": {"num_docs": N_DOCS, "dim": DIM,
                   "requests": N_REQUESTS, "tenants": N_TENANTS, "k": K,
                   "batch_sizes": list(BATCH_SIZES),
                   "n_poly": RLWE_PARAMS.n_poly,
                   "chunk": RLWE_PARAMS.chunk, "full": FULL},
        "results": results_json,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH}", flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--overload-smoke", action="store_true",
                    help="seconds-scale overload sweep on a tiny corpus "
                         "(zero-loss + shed-at-2x asserts, no JSON) — "
                         "used by scripts/smoke.sh")
    if ap.parse_args().overload_smoke:
        overload_smoke()
    else:
        main()
