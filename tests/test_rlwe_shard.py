"""Sharded HBM-resident candidate cache: sharded on-demand gather must be
bit-identical to the dense cache and to cold per-request packing (batch
1/3/8, both strides, fallback + fused Pallas kernel); the fused-iNTT kernel
must match the staged fallback; LRU eviction / re-pinning must be
deterministic under a fixed access trace (legacy ``async_admission=False``
mode) and must never change the bits.  The admission-policy suite below
pins down the async/frequency-aware path: convergence to the synchronous
resident set, bit-identity while an admission is in flight, the 2nd-touch
rule under one-shot sweeps, counter decay, the bounded admit queue, and the
prefetch touch-credit accounting."""

import threading

import numpy as np
import pytest

from repro.crypto import rlwe
from repro.kernels.ntt import ops as ntt_ops

# n_dim=384 <= chunk -> stride=chunk (2 cands/ct); n_dim=768 > chunk ->
# stride=2*chunk (1 cand/ct, 2 chunks): both packing regimes.
PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)
NUM_DOCS = 40
KPRIME = 9          # not a multiple of cands_per_ct=2: pad path
SHARD_DOCS = 8      # 5 shards over 40 docs


def _unit(rng, *shape):
    x = rng.normal(size=shape)
    return (x / np.linalg.norm(x, axis=-1, keepdims=True)).astype(np.float32)


@pytest.fixture(scope="module")
def sk():
    return rlwe.keygen(PARAMS, np.random.default_rng(0))


@pytest.fixture(scope="module", params=[384, 768])
def setup(request, sk):
    n_dim = request.param
    rng = np.random.default_rng(n_dim)
    docs = _unit(rng, NUM_DOCS, n_dim)
    dense = rlwe.build_candidate_cache(PARAMS, docs)
    q_cts = [rlwe.encrypt_query(sk, q, rng) for q in _unit(rng, 8, n_dim)]
    return n_dim, docs, dense, q_cts


def _sharded(dense, **kw):
    kw.setdefault("shard_docs", SHARD_DOCS)
    return rlwe.shard_candidate_cache(dense,
                                      rlwe.CandidateCacheConfig(**kw))


def test_shard_geometry_and_pool_accounting(setup):
    n_dim, docs, dense, _ = setup
    sh = _sharded(dense)
    assert sh.num_shards == -(-NUM_DOCS // SHARD_DOCS)
    assert sh.shard_docs == SHARD_DOCS
    assert (sh.n_dim, sh.num_docs) == (n_dim, NUM_DOCS)
    assert (sh.stride, sh.cands_per_ct, sh.num_chunks) == (
        dense.stride, dense.cands_per_ct, dense.num_chunks)
    # the shard pool is exactly the dense pool, re-viewed
    assert sh.pool_nbytes == dense.nbytes
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s) for s in sh.shards]),
        np.asarray(dense.polys))
    assert sh.shard_of(0) == 0 and sh.shard_of(NUM_DOCS - 1) == 4
    # nothing resident before the first gather
    assert sh.resident_bytes == 0 and sh.resident_shards == ()


def test_build_sharded_matches_shard_of_dense(setup):
    n_dim, docs, dense, _ = setup
    built = rlwe.build_sharded_candidate_cache(
        PARAMS, docs, config=rlwe.CandidateCacheConfig(num_shards=4))
    rev = _sharded(dense, shard_docs=built.shard_docs)
    assert built.num_shards == rev.num_shards
    for a, b in zip(built.shards, rev.shards):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(built.twiddles),
                                  np.asarray(dense.twiddles))


@pytest.mark.parametrize("bsz", [1, 3, 8])
@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["xla", "pallas"])
def test_sharded_bit_identical_to_dense_and_cold(setup, bsz, use_pallas):
    n_dim, docs, dense, q_cts = setup
    rng = np.random.default_rng(bsz)
    ids = rng.integers(0, NUM_DOCS, size=(bsz, KPRIME))
    packed = rlwe.pack_candidates_batch(PARAMS, docs[ids])
    cold = rlwe.encrypted_scores_batch_stacked(
        PARAMS, q_cts[:bsz], packed, KPRIME, n_dim, use_pallas=use_pallas)
    cached = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:bsz], dense, ids, use_pallas=use_pallas)
    sh = _sharded(dense, max_resident_bytes=2 * dense.nbytes // 5)
    sharded = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:bsz], sh, ids, use_pallas=use_pallas)
    for a, b in ((cold, cached), (cold, sharded)):
        np.testing.assert_array_equal(np.asarray(a.c0), np.asarray(b.c0))
        np.testing.assert_array_equal(np.asarray(a.c1), np.asarray(b.c1))
        assert (a.n_dim, a.num_cands) == (b.n_dim, b.num_cands)


def test_fused_intt_kernel_bit_identical_to_staged(setup):
    """ops.fused_rotate_hadamard_intt (Pallas and XLA) == the staged
    fused accumulate + standalone inverse NTT, coefficient-exactly."""
    n_dim, docs, dense, q_cts = setup
    rng = np.random.default_rng(7)
    ids = rng.integers(0, NUM_DOCS, size=(2, KPRIME))
    cpt, chunks = dense.cands_per_ct, dense.num_chunks
    num_ct = -(-KPRIME // cpt)
    pad = num_ct * cpt - KPRIME
    import jax.numpy as jnp
    g = np.asarray(dense.polys)[ids.reshape(-1)].reshape(
        (2, KPRIME) + np.asarray(dense.polys).shape[1:])
    if pad:
        g = np.concatenate(
            [g, np.zeros((2, pad) + g.shape[2:], np.int32)], axis=1)
    c0 = jnp.stack([q.c0 for q in q_cts[:2]])
    for i, ctx in enumerate(PARAMS.ctxs):
        f0 = ntt_ops.ntt_fwd(c0[:, :, i, :], ctx, use_pallas=False)
        polys_i = jnp.asarray(g[..., i, :]).reshape(
            2, num_ct, cpt * chunks, PARAMS.n_poly)
        tw = dense.twiddles[i]
        acc0, acc1 = ntt_ops.fused_rotate_hadamard(
            polys_i, tw, f0, f0, ctx, use_pallas=False)
        want0 = np.asarray(ntt_ops.ntt_inv(acc0, ctx, use_pallas=False))
        want1 = np.asarray(ntt_ops.ntt_inv(acc1, ctx, use_pallas=False))
        for up in (False, True):
            got0, got1 = ntt_ops.fused_rotate_hadamard_intt(
                polys_i, tw, f0, f0, ctx, use_pallas=up)
            np.testing.assert_array_equal(want0, np.asarray(got0))
            np.testing.assert_array_equal(want1, np.asarray(got1))


def test_lru_eviction_and_repin_deterministic(setup):
    """A fixed access trace must produce the same hit/miss/eviction sequence
    and the same resident set on two fresh caches — and identical bits to
    the dense cache at every step of the trace.  ``async_admission=False``
    selects the synchronous first-touch mode this trace was written for
    (the async policy admits on 2nd touch, off-thread)."""
    n_dim, docs, dense, q_cts = setup
    budget = 2 * dense.nbytes // 5          # room for exactly 2 of 5 shards
    # gathers process touched shards in sorted order (np.unique), so:
    trace = [np.array([[0, 1, 8, 9]]),       # miss 0, miss 1 -> (0, 1)
             np.array([[16, 17, 0, 1]]),     # hit 0 (-> MRU), miss 2,
                                             # evict 1 -> (0, 2)
             np.array([[8, 9, 8, 9]]),       # miss 1, evict 0 -> (2, 1)
             np.array([[32, 33, 39, 0]])]    # miss 0 evicts 2, miss 4
                                             # evicts 1 -> (0, 4)
    logs = []
    for _ in range(2):
        sh = _sharded(dense, max_resident_bytes=budget,
                      async_admission=False)
        log = []
        for ids in trace:
            got = rlwe.encrypted_scores_cached_batch(
                PARAMS, q_cts[:1], sh, ids, use_pallas=False)
            want = rlwe.encrypted_scores_cached_batch(
                PARAMS, q_cts[:1], dense, ids, use_pallas=False)
            np.testing.assert_array_equal(np.asarray(want.c0),
                                          np.asarray(got.c0))
            log.append((sh.hits, sh.misses, sh.evictions,
                        sh.resident_shards))
        logs.append(log)
        assert sh.resident_bytes <= budget
    assert logs[0] == logs[1], "eviction must be deterministic"
    # the semantics of the trace, not just reproducibility:
    hits, misses, evictions, resident = logs[0][-1]
    assert (hits, misses, evictions) == (1, 6, 4)
    assert resident == (0, 4)               # LRU -> MRU after the last step
    assert evictions == misses - len(resident)


def test_stream_only_budget_zero(setup):
    n_dim, docs, dense, q_cts = setup
    sh = _sharded(dense, max_resident_bytes=0)
    ids = np.arange(KPRIME)[None] % NUM_DOCS
    got = rlwe.encrypted_scores_cached_batch(PARAMS, q_cts[:1], sh, ids)
    want = rlwe.encrypted_scores_cached_batch(PARAMS, q_cts[:1], dense, ids)
    np.testing.assert_array_equal(np.asarray(want.c0), np.asarray(got.c0))
    assert sh.resident_shards == () and sh.evictions == 0
    assert sh.misses > 0 and sh.gathered_bytes > 0
    # a shard bigger than the whole budget is never pinned either
    tight = _sharded(dense, max_resident_bytes=dense.nbytes // 5 - 1)
    rlwe.encrypted_scores_cached_batch(PARAMS, q_cts[:1], tight, ids)
    assert tight.resident_shards == ()


def test_pin_on_access_false_keeps_resident_set_fixed(setup):
    n_dim, docs, dense, q_cts = setup
    sh = _sharded(dense, pin_on_access=False)
    sh.pin(2)
    assert sh.resident_shards == (2,)
    ids = np.array([[0, 8, 16, 17]])        # shards 0, 1 miss; 2 hits
    got = rlwe.encrypted_scores_cached_batch(PARAMS, q_cts[:1], sh, ids)
    want = rlwe.encrypted_scores_cached_batch(PARAMS, q_cts[:1], dense, ids)
    np.testing.assert_array_equal(np.asarray(want.c0), np.asarray(got.c0))
    assert sh.resident_shards == (2,) and sh.hits == 1 and sh.misses == 2


def test_gather_rows_match_pool(setup):
    n_dim, docs, dense, _ = setup
    sh = _sharded(dense)
    rng = np.random.default_rng(3)
    ids = rng.integers(0, NUM_DOCS, size=(2, 5))
    g = np.asarray(sh.gather(ids))
    pool = np.asarray(dense.polys)
    np.testing.assert_array_equal(g, pool[ids])


def test_sharded_scores_decrypt_like_cold(setup, sk):
    n_dim, docs, dense, q_cts = setup
    sh = _sharded(dense, max_resident_bytes=0)
    rng = np.random.default_rng(5)
    ids = rng.integers(0, NUM_DOCS, size=(1, KPRIME))
    got = rlwe.decrypt_scores(
        sk, rlwe.encrypted_scores_cached(PARAMS, q_cts[0], sh, ids[0]))
    want = rlwe.decrypt_scores(
        sk, rlwe.encrypted_scores(
            PARAMS, q_cts[0], rlwe.pack_candidates(PARAMS, docs[ids[0]])))
    np.testing.assert_array_equal(got, want)


def test_sharded_cache_rejects_mismatched_params(setup):
    n_dim, docs, dense, q_cts = setup
    sh = _sharded(dense)
    other = rlwe.RlweParams(n_poly=1024, chunk=256)
    with pytest.raises(ValueError, match="rebuild the cache"):
        sh.check_compatible(other)
    with pytest.raises(ValueError, match="n_dim"):
        sh.check_compatible(PARAMS, n_dim=n_dim + 64)
    ids = np.zeros((1, 4), np.int64)
    with pytest.raises(ValueError, match="rebuild the cache"):
        rlwe.encrypted_scores_cached_batch(other, q_cts[:1], sh, ids)


def test_index_memoizes_per_params_and_config(setup):
    from repro.retrieval.index import FlatIndex
    n_dim, docs, _, _ = setup
    index = FlatIndex.build(docs, normalize=False)
    cfg = rlwe.CandidateCacheConfig(shard_docs=SHARD_DOCS)
    a = index.candidate_cache(PARAMS, cfg)
    assert isinstance(a, rlwe.ShardedCandidateCache)
    # same (params value, config) -> same build; dense keyed separately
    assert index.candidate_cache(
        rlwe.RlweParams(n_poly=1024, chunk=512),
        rlwe.CandidateCacheConfig(shard_docs=SHARD_DOCS)) is a
    dense = index.candidate_cache(PARAMS)
    assert isinstance(dense, rlwe.CandidateCache) and dense is not a
    assert index.candidate_cache(
        PARAMS, rlwe.CandidateCacheConfig(shard_docs=4)) is not a
    # peek never builds
    assert index.peek_candidate_cache(PARAMS, cfg) is a
    assert index.peek_candidate_cache(
        PARAMS, rlwe.CandidateCacheConfig(shard_docs=5)) is None
    # one packed pool per params value: later configs re-view the donor's
    # pool instead of re-packing the corpus (dense included)
    b = index.candidate_cache(PARAMS, rlwe.CandidateCacheConfig(shard_docs=4))
    assert b.pool is a.pool
    assert dense.host_pool() is a.pool
    np.testing.assert_array_equal(np.asarray(dense.polys), a.pool)


def test_admission_never_exceeds_budget_transiently(setup):
    """Eviction happens before the admission copy: with a budget of one
    shard, the resident set is exactly the last-touched shard and peak
    never exceeds the budget."""
    n_dim, docs, dense, q_cts = setup
    one_shard = dense.nbytes // 5
    sh = _sharded(dense, max_resident_bytes=one_shard,
                  async_admission=False)
    for ids in ([[0, 1]], [[8, 9]], [[0, 16]]):
        rlwe.encrypted_scores_cached_batch(
            PARAMS, q_cts[:1], sh, np.asarray(ids))
        assert sh.resident_bytes <= one_shard
    assert sh.peak_resident_bytes <= one_shard
    assert sh.resident_shards == (2,)       # last touched (sorted order)


def test_gather_rejects_out_of_range_ids(setup):
    n_dim, docs, dense, _ = setup
    sh = _sharded(dense)
    with pytest.raises(IndexError, match="candidate ids"):
        sh.gather(np.array([[0, -1]]))
    with pytest.raises(IndexError, match="candidate ids"):
        sh.gather(np.array([[NUM_DOCS]]))


def test_dense_cache_shares_memoized_host_pool(setup):
    """shard_candidate_cache from a dense cache re-views the memoized host
    pool — one host array no matter how many configs consume it."""
    n_dim, docs, dense, _ = setup
    sh1 = _sharded(dense, shard_docs=8)
    sh2 = _sharded(dense, shard_docs=4)
    assert sh1.pool is dense.host_pool() and sh2.pool is dense.host_pool()


def test_config_rejects_nonpositive_sharding():
    with pytest.raises(ValueError, match="shard_docs must be positive"):
        rlwe.CandidateCacheConfig(shard_docs=0).resolve_shard_docs(10)
    with pytest.raises(ValueError, match="num_shards must be positive"):
        rlwe.CandidateCacheConfig(num_shards=0).resolve_shard_docs(10)


def test_densify_roundtrip(setup):
    n_dim, docs, dense, q_cts = setup
    sh = _sharded(dense)
    back = rlwe.densify_candidate_cache(sh)
    np.testing.assert_array_equal(np.asarray(back.polys),
                                  np.asarray(dense.polys))
    resharded = rlwe.shard_candidate_cache(sh,
                                           rlwe.CandidateCacheConfig(
                                               shard_docs=4))
    assert resharded.pool is sh.pool        # no re-pack, no copy
    ids = np.arange(KPRIME)[None] % NUM_DOCS
    a = rlwe.encrypted_scores_cached_batch(PARAMS, q_cts[:1], back, ids)
    b = rlwe.encrypted_scores_cached_batch(PARAMS, q_cts[:1], resharded, ids)
    np.testing.assert_array_equal(np.asarray(a.c0), np.asarray(b.c0))


# ---------------------------------------------------------------------------
# async, frequency-aware admission policy
# ---------------------------------------------------------------------------

def test_async_admission_converges_to_sync_resident_set(setup):
    """With admit_threshold=1, the async admitter must converge (after a
    flush) to exactly the synchronous first-touch LRU state under a fixed
    trace — same resident set/order and same hit/miss counts at each step."""
    n_dim, docs, dense, _ = setup
    budget = 2 * dense.nbytes // 5
    trace = [np.array([[0, 1, 8, 9]]), np.array([[16, 17, 0, 1]]),
             np.array([[8, 9, 8, 9]]), np.array([[32, 33, 39, 0]])]
    sync = _sharded(dense, max_resident_bytes=budget, async_admission=False)
    asy = _sharded(dense, max_resident_bytes=budget, admit_threshold=1)
    for ids in trace:
        sync.gather(ids)
        asy.gather(ids)
        asy.flush()
        assert asy.resident_shards == sync.resident_shards
        assert (asy.hits, asy.misses) == (sync.hits, sync.misses)
    assert asy.evictions == sync.evictions
    assert asy.async_admissions == asy.admissions == sync.admissions


def test_gather_bit_identical_while_admission_in_flight(setup):
    """`gather` streams from the host pool while the admitter copy is in
    flight; the scores must be bit-identical to the dense cache before,
    during, and after the atomic swap-in."""
    n_dim, docs, dense, q_cts = setup
    sh = _sharded(dense, admit_threshold=1)
    started, release = threading.Event(), threading.Event()

    def hook(_s):                   # hold the copy mid-flight
        started.set()
        assert release.wait(30)
    sh._admit_hook = hook

    ids = np.array([[0, 1, 2, 3, 8, 9]])    # shards 0 and 1
    want = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:1], dense, ids, use_pallas=False)
    cold = rlwe.encrypted_scores_batch_stacked(
        PARAMS, q_cts[:1], rlwe.pack_candidates_batch(PARAMS, docs[ids]),
        ids.shape[1], n_dim, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(want.c0), np.asarray(cold.c0))
    got_cold = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:1], sh, ids, use_pallas=False)     # enqueues 0, 1
    assert started.wait(30)
    assert sh.stats()["pending_admissions"] > 0
    got_inflight = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:1], sh, ids, use_pallas=False)     # streams, no block
    release.set()
    sh.flush()
    assert sh.resident_shards == (0, 1)
    got_resident = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:1], sh, ids, use_pallas=False)     # device take
    for got in (got_cold, got_inflight, got_resident):
        np.testing.assert_array_equal(np.asarray(want.c0),
                                      np.asarray(got.c0))
        np.testing.assert_array_equal(np.asarray(want.c1),
                                      np.asarray(got.c1))
    assert sh.hits >= 2             # the post-swap gather hit both shards


def test_second_touch_never_admits_one_shot_sweep(setup):
    """The 2nd-touch policy must not admit anything under a uniform
    one-shot sweep (every shard touched exactly once)."""
    n_dim, docs, dense, _ = setup
    sh = _sharded(dense)            # defaults: async, admit_threshold=2
    for lo in range(0, NUM_DOCS, SHARD_DOCS):
        sh.gather(np.array([[lo, lo + 1]]))     # one touch per shard
    sh.flush()
    st = sh.stats()
    assert st["resident_shards"] == ()
    assert st["admit_enqueued"] == st["admissions"] == 0
    assert st["policy_deferrals"] == sh.num_shards
    assert st["misses"] == sh.num_shards
    # ... while a second pass (repeat traffic) admits everything in range
    for lo in range(0, NUM_DOCS, SHARD_DOCS):
        sh.gather(np.array([[lo, lo + 1]]))
    sh.flush()
    assert len(sh.resident_shards) > 0
    assert sh.stats()["async_admissions"] > 0


def test_auto_window_sustained_uniform_never_admits(setup):
    """The auto admit_window (= num_shards for >= 8 shards) makes
    *sustained* uniform traffic decay every counter before its second
    touch: many full-corpus sweeps admit nothing, while skewed traffic on
    the same config admits after one repeat."""
    n_dim, docs, dense, _ = setup
    sh = _sharded(dense, shard_docs=4)          # 10 shards, auto window 10
    assert sh.admit_window == 10
    uniform = np.arange(0, NUM_DOCS, 4)[None]   # every shard, every gather
    for _ in range(6):
        sh.gather(uniform)
    sh.flush()
    st = sh.stats()
    assert st["resident_shards"] == () and st["admit_enqueued"] == 0
    assert st["policy_deferrals"] == 6 * sh.num_shards
    # same config, skewed ids (2 of 10 shards): admitted on the 2nd gather
    sk = _sharded(dense, shard_docs=4)
    for _ in range(3):
        sk.gather(np.array([[0, 1, 4, 5]]))     # shards 0, 1 only
    sk.flush()
    assert set(sk.resident_shards) == {0, 1}


def test_touch_counter_decay_ages_out_stale_popularity(setup):
    """One touch, then a full decay window of other-shard traffic, then a
    second touch: the first touch must have aged out, so no admission."""
    n_dim, docs, dense, _ = setup
    sh = _sharded(dense, admit_window=4)
    sh.gather(np.array([[0]]))              # shard 0: count 1
    for lo in (8, 16, 24):                  # 3 more touches -> window ends,
        sh.gather(np.array([[lo]]))         # counters halve and age out
    sh.gather(np.array([[0]]))              # shard 0 again: count back to 1
    sh.flush()
    assert sh.resident_shards == () and sh.admit_enqueued == 0
    # without decay the same trace admits shard 0
    sh2 = _sharded(dense, admit_window=1024)
    for lo in (0, 8, 16, 24, 0):
        sh2.gather(np.array([[lo]]))
    sh2.flush()
    assert 0 in sh2.resident_shards


def test_admit_queue_bounded_drops_are_counted(setup):
    """The admit queue is bounded: with the worker blocked, excess
    admission requests are dropped (and counted), never accumulated."""
    n_dim, docs, dense, _ = setup
    sh = _sharded(dense, admit_threshold=1, max_pending_admissions=1)
    started, release = threading.Event(), threading.Event()

    def hook(_s):
        started.set()
        assert release.wait(30)
    sh._admit_hook = hook
    sh.gather(np.array([[0, 8, 16, 24, 32]]))   # 5 shards, queue cap 1
    assert started.wait(30)
    st = sh.stats()
    assert st["admit_dropped"] >= 2             # worker holds 1, queue 1
    release.set()
    sh.flush()
    assert len(sh.resident_shards) <= 2
    # dropped shards stay eligible: their counter kept them over threshold.
    # Each gather+flush round admits at least one more shard (the queue may
    # still drop some mid-gather — the worker races the touch loop), so a
    # few rounds converge to everything resident.
    for _ in range(4):
        sh.gather(np.array([[0, 8, 16, 24, 32]]))
        sh.flush()
    assert len(sh.resident_shards) == 5


def test_prefetch_counts_touch_once_and_overlaps(setup):
    """A prefetch records the touch; the request's own gather of the same
    ids must not double-count it (otherwise every request would hit the
    2nd-touch threshold immediately)."""
    n_dim, docs, dense, q_cts = setup
    sh = _sharded(dense)                        # threshold 2
    ids = np.array([[0, 1, 8]])                 # shards 0, 1
    assert sh.prefetch(ids) == 2
    rlwe.encrypted_scores_cached_batch(PARAMS, q_cts[:1], sh, ids,
                                       use_pallas=False)
    sh.flush()
    assert sh.resident_shards == ()             # single touch: no admission
    assert sh.stats()["prefetches"] == 2
    assert sh.stats()["policy_deferrals"] == 2
    # second request for the same region reaches the threshold at prefetch
    # time — the admission is enqueued before the gather even runs
    assert sh.prefetch(ids) == 2
    sh.flush()
    assert sh.resident_shards == (0, 1)
    assert sh.stats()["async_admissions"] == 2
    # stream-only caches still account prefetches but never admit
    sh0 = _sharded(dense, max_resident_bytes=0)
    assert sh0.prefetch(ids) == 2 and sh0.prefetch(ids) == 2
    sh0.flush()
    assert sh0.resident_shards == () and sh0.stats()["prefetches"] == 4


def test_prefetch_rejects_out_of_range_ids(setup):
    n_dim, docs, dense, _ = setup
    sh = _sharded(dense)
    with pytest.raises(IndexError, match="candidate ids"):
        sh.prefetch(np.array([[0, NUM_DOCS]]))
    assert sh.prefetch(np.empty((1, 0), np.int64)) == 0


def test_async_cache_close_is_idempotent(setup):
    n_dim, docs, dense, _ = setup
    sh = _sharded(dense, admit_threshold=1)
    sh.gather(np.array([[0, 8]]))
    sh.close()
    sh.close()                                  # idempotent
    assert sh.stats()["pending_admissions"] == 0
    # the cache stays usable (and can admit again) after close
    sh.gather(np.array([[16]]))
    sh.flush()
    assert 16 // SHARD_DOCS in sh.resident_shards


def test_config_rejects_bad_admission_knobs():
    with pytest.raises(ValueError, match="admit_threshold"):
        rlwe.CandidateCacheConfig(admit_threshold=0)
    with pytest.raises(ValueError, match="admit_window"):
        rlwe.CandidateCacheConfig(admit_window=0)
    with pytest.raises(ValueError, match="max_pending_admissions"):
        rlwe.CandidateCacheConfig(max_pending_admissions=0)


def test_serve_engine_sharded_cache_end_to_end():
    """The engine on a sharded-cache config returns the same docs/ids as on
    the dense cache, and exposes LRU stats."""
    import jax
    from repro.retrieval.index import FlatIndex
    from repro.serve import EngineConfig, ServeEngine, SessionManager

    n_dim, n_docs, k = 128, 60, 3
    rng = np.random.default_rng(11)
    docs = _unit(rng, n_docs, n_dim)
    texts = [f"doc-{i}".encode() for i in range(n_docs)]

    def run(cache_config):
        index = FlatIndex.build(docs, documents=texts, normalize=False)
        engine = ServeEngine(
            index,
            config=EngineConfig(max_batch=3, use_candidate_cache=True,
                                cache_config=cache_config),
            sessions=SessionManager(rlwe_params=PARAMS,
                                    deterministic_seeds=True))
        for t in ("a", "b", "c"):
            engine.open_session(t, n=n_dim, N=n_docs, k=k, radius=0.05)
        for qi, t in enumerate(("a", "b", "c")):
            engine.submit(t, docs[qi], key=jax.random.PRNGKey(qi))
        return engine, engine.drain()

    cfg = rlwe.CandidateCacheConfig(shard_docs=16, max_resident_bytes=0)
    eng_dense, res_dense = run(None)
    eng_shard, res_shard = run(cfg)
    assert eng_dense.cache_stats() is None
    stats = eng_shard.cache_stats()
    assert stats is not None and stats["misses"] > 0
    # the admission/prefetch counters are part of the observability surface
    for key in ("admissions", "async_admissions", "prefetches",
                "admit_enqueued", "admit_dropped", "policy_deferrals",
                "pending_admissions"):
        assert key in stats
    # stream-only engine config: the prefetch hook still fires per batch
    # (the touches are counted) but nothing is ever admitted
    assert stats["prefetches"] > 0
    assert stats["admissions"] == 0 and stats["resident_shards"] == ()
    for a, b in zip(res_dense, res_shard):
        assert a.tenant == b.tenant
        np.testing.assert_array_equal(a.ids, b.ids)
        assert a.docs == b.docs
        assert a.transcript.total_bytes == b.transcript.total_bytes
