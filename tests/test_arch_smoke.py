"""Per-assigned-arch smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs.  Full configs are exercised via the dry-run."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf_lib
from repro.train import optimizer as opt_lib
from repro.train import trainer as trainer_lib

LM_ARCHS = ["llama3-8b", "qwen3-8b", "qwen2.5-14b", "qwen3-moe-30b-a3b",
            "granite-moe-3b-a800m"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = registry.get(arch).reduced
    params = tf_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = opt_lib.AdamWConfig(warmup_steps=1, total_steps=10)
    opt_state = opt_lib.init(params, opt_cfg)
    step = trainer_lib.make_train_step(
        lambda p, t, y: tf_lib.loss_fn(p, cfg, t, y), opt_cfg,
        param_dtype=cfg.jdtype)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    params, opt_state, m = jax.jit(step)(params, opt_state, (tokens, tokens))
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # a second step must reduce nothing to NaN
    params, _, m2 = jax.jit(step)(params, opt_state, (tokens, tokens))
    assert np.isfinite(float(m2["loss"]))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_serve(arch):
    cfg = registry.get(arch).reduced
    params = tf_lib.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, cache = tf_lib.prefill(params, cfg, tokens, max_len=12)
    assert logits.shape == (2, 8, cfg.padded_vocab)
    nxt = jnp.argmax(logits[:, -1, :1000], -1).astype(jnp.int32)[:, None]
    lg, cache = tf_lib.decode_step(params, cfg, nxt, cache)
    assert lg.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(lg)).all()


def test_full_lm_configs_match_assignment():
    c = registry.get("llama3-8b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4096, 32, 8, 14336, 128256)
    c = registry.get("qwen3-8b").config
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab, c.qk_norm) == \
        (36, 4096, 12288, 151936, True)
    c = registry.get("qwen2.5-14b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab, c.qkv_bias) == \
        (48, 5120, 40, 13824, 152064, True)
    c = registry.get("qwen3-moe-30b-a3b").config
    assert (c.n_layers, c.d_model, c.moe_experts, c.moe_top_k, c.moe_d_ff) == \
        (48, 2048, 128, 8, 768)
    c = registry.get("granite-moe-3b-a800m").config
    assert (c.n_layers, c.d_model, c.moe_experts, c.moe_top_k, c.vocab) == \
        (32, 1536, 40, 8, 49155)
    # ~8B params for llama3-8b (sanity of param_count accounting)
    assert 7e9 < registry.get("llama3-8b").config.param_count() < 9e9
    # qwen3-moe: ~30B total, ~3B active
    moe = registry.get("qwen3-moe-30b-a3b").config
    assert 25e9 < moe.param_count() < 36e9
    assert 2e9 < moe.active_param_count() < 4.5e9


def test_gnn_smoke_train_step():
    cfg = registry.get("graphcast").reduced
    params = gnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    v, e = 40, 120
    batch = (jnp.asarray(rng.normal(size=(v, cfg.d_feat)), jnp.float32),
             jnp.asarray(rng.integers(0, v, e), jnp.int32),
             jnp.asarray(rng.integers(0, v, e), jnp.int32),
             jnp.asarray(rng.normal(size=(v, cfg.n_vars)), jnp.float32))
    opt_cfg = opt_lib.AdamWConfig(warmup_steps=1, total_steps=10)
    opt_state = opt_lib.init(params, opt_cfg)
    step = trainer_lib.make_train_step(
        lambda p, nf, es, ed, t: gnn_lib.loss_fn(
            p, cfg, gnn_lib.GraphBatch(nf, es, ed, t)),
        opt_cfg, param_dtype=cfg.jdtype)
    params, opt_state, m = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))


RECSYS_ARCHS = ["fm", "two-tower-retrieval", "dien", "dcn-v2"]


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke_train_step(arch):
    from repro.configs.families import _recsys_batch, _recsys_init
    cfg = registry.get(arch).reduced
    params = _recsys_init(arch, cfg, abstract=False, key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b = 16
    _, _, loss = _recsys_batch(arch, cfg, b)
    if arch == "fm":
        batch = (jnp.asarray(rng.integers(0, 500, (b, cfg.n_sparse)),
                             jnp.int32),
                 jnp.asarray(rng.integers(0, 2, b), jnp.float32))
    elif arch == "dcn-v2":
        batch = (jnp.asarray(rng.normal(size=(b, cfg.n_dense)), jnp.float32),
                 jnp.asarray(rng.integers(0, 600, (b, cfg.n_sparse)),
                             jnp.int32),
                 jnp.asarray(rng.integers(0, 2, b), jnp.float32))
    elif arch == "dien":
        batch = (jnp.asarray(rng.integers(0, 500, (b, cfg.seq_len)),
                             jnp.int32),
                 jnp.asarray(rng.integers(0, 500, b), jnp.int32),
                 jnp.asarray(rng.integers(0, 2, b), jnp.float32))
    else:
        batch = (jnp.asarray(rng.integers(0, 500, (b, cfg.n_user_feats)),
                             jnp.int32),
                 jnp.asarray(rng.integers(0, 500, (b, cfg.n_item_feats)),
                             jnp.int32))
    opt_cfg = opt_lib.AdamWConfig(warmup_steps=1, total_steps=10)
    opt_state = opt_lib.init(params, opt_cfg)
    step = trainer_lib.make_train_step(loss, opt_cfg, param_dtype=cfg.jdtype)
    params, opt_state, m = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0


def test_registry_covers_assignment():
    assert set(registry.ASSIGNED) == {
        "llama3-8b", "qwen3-8b", "qwen2.5-14b", "qwen3-moe-30b-a3b",
        "granite-moe-3b-a800m", "graphcast", "fm", "two-tower-retrieval",
        "dien", "dcn-v2"}
    # 40 assigned cells total
    total = sum(len(registry.get(a).shapes) for a in registry.ASSIGNED)
    assert total == 40
