"""dcn-v2 [recsys]: n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3
mlp=1024-1024-512 interaction=cross [arXiv:2008.13535]."""
from repro.models.recsys import DcnV2Config

CONFIG = DcnV2Config(name="dcn-v2", n_dense=13, n_sparse=26, embed_dim=16,
                     n_cross_layers=3, mlp=(1024, 1024, 512),
                     vocab_per_field=100_000)

REDUCED = DcnV2Config(name="dcn-v2-smoke", n_dense=4, n_sparse=6, embed_dim=8,
                      n_cross_layers=2, mlp=(32, 16), vocab_per_field=100)
