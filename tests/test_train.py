"""Training substrate: optimizer math, checkpoint/restart determinism,
failure drills, straggler mitigation, gradient compression."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data.pipeline import ClickSyntheticTask, LmSyntheticTask
from repro.train import checkpoint as ckpt
from repro.train import compress, fault
from repro.train import optimizer as opt_lib
from repro.train import trainer


def _quad_problem():
    """min ||p - c||^2 — closed-form sanity for AdamW."""
    c = jnp.asarray([1.0, -2.0, 3.0])

    def loss(p, x):
        del x
        return jnp.sum(jnp.square(p["w"] - c))

    params = {"w": jnp.zeros(3)}
    return loss, params


def test_adamw_converges_on_quadratic():
    loss, params = _quad_problem()
    cfg = opt_lib.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                              total_steps=300, min_lr_ratio=1.0)
    state = opt_lib.init(params, cfg)
    step = trainer.make_train_step(loss, cfg)
    for _ in range(300):
        params, state, m = jax.jit(step)(params, state, (jnp.zeros(()),))
    np.testing.assert_allclose(np.asarray(params["w"]), [1, -2, 3], atol=1e-2)


def test_grad_accumulation_matches_full_batch():
    rng = np.random.default_rng(0)
    w = {"w": jnp.asarray(rng.normal(size=(4, 2)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(8, 2)), jnp.float32)

    def loss(p, x, y):
        return jnp.mean(jnp.square(x @ p["w"] - y))

    cfg = opt_lib.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10)
    s1 = opt_lib.init(w, cfg)
    s2 = opt_lib.init(w, cfg)
    full = trainer.make_train_step(loss, cfg, microbatches=1)
    micro = trainer.make_train_step(loss, cfg, microbatches=4)
    p1, _, m1 = jax.jit(full)(w, s1, (x, y))
    p2, _, m2 = jax.jit(micro)(w, s2, (x, y))
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5, atol=1e-6)


def test_schedule_warmup_and_cosine():
    cfg = opt_lib.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                              min_lr_ratio=0.1)
    assert float(opt_lib.schedule(cfg, 5)) == pytest.approx(0.5)
    assert float(opt_lib.schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(opt_lib.schedule(cfg, 110)) == pytest.approx(0.1, abs=1e-3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "b": {"c": jnp.float32(4.0)}}
    ckpt.save(tmp_path, 7, tree)
    assert ckpt.latest_step(tmp_path) == 7
    got = ckpt.restore(tmp_path, 7, tree)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(6).reshape(2, 3))
    assert float(got["b"]["c"]) == 4.0


def test_checkpoint_gc_and_commit(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, tree, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]
    # a checkpoint without COMMIT must be invisible
    (tmp_path / "step_00000009").mkdir()
    assert ckpt.latest_step(tmp_path) == 4


def test_resumable_run_restart_is_bit_exact(tmp_path):
    """Train 20 steps straight vs die-at-12-and-restart: same final params."""
    loss, params0 = _quad_problem()
    cfg = opt_lib.AdamWConfig(lr=0.05, warmup_steps=0, total_steps=100)
    step = trainer.make_train_step(loss, cfg)
    jstep = jax.jit(step)

    def step_fn(state, batch):
        p, s = state
        p, s, m = jstep(p, s, batch)
        return (p, s), m

    batches = lambda i: (jnp.zeros(()),)

    # run A: straight through
    sa = (params0, opt_lib.init(params0, cfg))
    ra = fault.ResumableRun(str(tmp_path / "a"), checkpoint_every=5)
    sa, _, _ = ra.run(step_fn, sa, batches, 20)

    # run B: injected failure at step 12, then restart
    sb = (params0, opt_lib.init(params0, cfg))
    rb = fault.ResumableRun(str(tmp_path / "b"), checkpoint_every=5)
    inj = fault.FailureInjector(fail_at_steps=(12,))
    with pytest.raises(fault.InjectedFailure):
        rb.run(step_fn, sb, batches, 20, injector=inj)
    # restart from checkpoint (step 9), replays 10..19
    sb2 = (params0, opt_lib.init(params0, cfg))
    sb2, done, _ = rb.run(step_fn, sb2, batches, 20, injector=inj)
    assert done == 10
    np.testing.assert_allclose(np.asarray(sa[0]["w"]), np.asarray(sb2[0]["w"]),
                               rtol=1e-6)


def test_straggler_monitor():
    mon = fault.StragglerMonitor(threshold=2.0, redistribute_after=2)
    assert not mon.observe(0, 1.0)
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 5.0)       # straggler
    assert mon.observe(3, 5.0)       # second in a row -> redistribution
    assert mon.redistributions == 1
    assert not mon.observe(4, 1.0)


def test_int8_quantization_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, s = compress.quantize_int8(g)
    rt = compress.dequantize_int8(q, s)
    assert float(jnp.max(jnp.abs(rt - g))) <= float(s) * 0.5 + 1e-6
    # error feedback: accumulated compressed updates converge to the truth
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        sent, err = compress.ef_step(g, err)
        acc = acc + sent
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                               atol=float(s))


def test_pipeline_is_seekable_and_deterministic():
    task = LmSyntheticTask(vocab=1000, seq_len=32, global_batch=4, seed=3)
    a1, t1 = task.batch(5)
    a2, t2 = task.batch(5)
    np.testing.assert_array_equal(a1, a2)
    b1, _ = task.batch(6)
    assert not np.array_equal(a1, b1)
    np.testing.assert_array_equal(t1[:, :-1], a1[:, 1:])


def test_click_task_learnable_signal():
    task = ClickSyntheticTask(n_sparse=10, vocab_per_field=100, global_batch=4096)
    ids, labels = task.batch(0)
    assert ids.shape == (4096, 10) and 0.05 < labels.mean() < 0.95
    feat = (ids % 7 == 0).sum(-1)
    # clicks correlate with the latent preference
    assert np.corrcoef(feat, labels)[0, 1] > 0.2
