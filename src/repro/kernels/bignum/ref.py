"""Residue-number-system (RNS) bignum arithmetic: reference half.

The vectorized Paillier backend (`repro.crypto.paillier_vec`) needs modular
multiplication and exponentiation over ~512-2048-bit moduli, batched over
thousands of independent values, on hardware whose SIMD units know nothing
about bignums.  Schoolbook limb arithmetic vectorizes badly under XLA: the
carry/reduction graph is thousands of tiny elementwise ops that the CPU
backend materializes one buffer at a time (measured ~5x *slower* than
CPython's C bignums).  The classic answer — the ROADMAP's "RNS/CRT limb
batching" item — is to represent each value by its residues modulo many
machine-word primes:

  * channel products are independent (no carries): one fused elementwise
    multiply across a ``[batch, channels]`` array;
  * the only cross-channel work is Montgomery reduction's two *base
    extensions*, and each is a matrix product against a fixed integer
    matrix — an Eigen GEMM, the one thing XLA CPU is unconditionally
    good at.

Layout.  A value is a float64 vector of ``2s + 1`` residue channels:
``s`` primes forming base M (the Montgomery modulus), ``s`` primes forming
the auxiliary base M', and one redundant power-of-two channel m_r = 2^23
used by the exact (Shenoy–Kumaresan) second base extension.  Channels are
23-bit integers stored in float64 lanes — products stay below 2^46 and GEMM
accumulations below 2^53, so every operation is *exact* in doubles while
vectorizing at full SIMD width.  Batched ciphertext blocks are shaped
``[batch, k', channels]``.

Algorithm (Bajard–Imbert RNS Montgomery with an exact second extension):
values live in Montgomery form v·M mod N and in the *incomplete reduction*
domain [0, (s+1)·N).  One multiply is

  1. channel product        x = a·b                (elementwise, all channels)
  2. xi_i = x_i·c1_i mod m_i with c1 = -N^{-1}·(M/m_i)^{-1}   (base M)
  3. q-hat = sum xi_i·(M/m_i): residues on M' + m_r via GEMM against E1
  4. w = (x + q-hat·N)/M on M' + m_r  (elementwise, folded constants)
  5. extend w back to base M exactly: Shenoy–Kumaresan via the m_r channel
     (alpha = number of M' overflows, recovered exactly because alpha <= s
     < m_r), GEMM against E2

The first extension is allowed to overshoot by alpha·M (Bajard's trick): it
only shifts w by multiples of N, which the incomplete-reduction domain
absorbs; the headroom bits in M keep the domain closed under multiplication.

This module is the pure-NumPy mirror of the jitted ops in ``ops.py`` —
same formulas, same constants, differential-tested against Python ``pow``
in tests/test_bignum.py.  Keep the two in lockstep.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence

import numpy as np


CH_BITS = 23                    # channel width: products < 2^46 exact in f64
RADIX = 1 << CH_BITS            # the redundant S-K modulus m_r (power of two)
HEADROOM_BITS = 20              # M >= 2^HEADROOM * modulus: closes the
                                # incomplete-reduction domain under multiply
# Policy budget: moduli needing more channels than this fall back to the
# object-path bignum implementation (compile size + GEMM width stay bounded).
# The exactness ceiling is 128 channels (sum of 2^46 products in f64); the
# policy budget sits well under it.  1024-bit Paillier keys (2048-bit n^2,
# 90 channels) are the first fallback tier.
MAX_CHANNELS = 64
HARD_CHANNELS = 128


def _is_small_prime(n: int) -> bool:
    """Deterministic Miller-Rabin, valid far beyond 2^23 channel range."""
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


@functools.lru_cache(maxsize=None)
def _channel_primes(count: int) -> tuple:
    """The ``count`` largest primes below 2^23, descending (deterministic,
    shared by every modulus of a given channel count)."""
    out: List[int] = []
    c = RADIX - 1
    while len(out) < count:
        if _is_small_prime(c):
            out.append(c)
        c -= 2
    return tuple(out)


def num_channels(modulus: int) -> int:
    """Channels per base for ``modulus`` (bit length + headroom, 23/channel)."""
    return -(-(modulus.bit_length() + HEADROOM_BITS) // CH_BITS)


def fits(modulus: int, budget: int | None = None) -> bool:
    """True when ``modulus`` is inside the compiled channel budget."""
    limit = MAX_CHANNELS if budget is None else budget
    return num_channels(modulus) <= min(limit, HARD_CHANNELS)


@dataclasses.dataclass(frozen=True)
class RnsSystem:
    """Modulus-independent channel system: the primes and the two base-
    extension matrices.  One instance per channel count ``s``, shared by
    every key of that size class (so multi-tenant batches whose lanes hold
    different keys of one size compile exactly once)."""
    s: int
    m: tuple                    # base M primes
    mp: tuple                   # base M' primes
    M: int
    Mp: int
    Mi: tuple                   # M / m_i
    Mpi: tuple                  # M' / mp_j
    E1: np.ndarray              # [s, s+1]: (M/m_i) mod t,  t in mp + (m_r,)
    E2: np.ndarray              # [s, s+1]: (M'/mp_j) mod t, t in m + (m_r,)
    Minv_t: np.ndarray          # [s+1]: M^{-1} mod t, t in mp + (m_r,)
    c4: np.ndarray              # [s]: (M'/mp_j)^{-1} mod mp_j
    Mp_mod_m: np.ndarray        # [s]: M' mod m_i
    Mpinv_r: float              # M'^{-1} mod m_r
    mv: np.ndarray              # [s] base M primes, f64
    mpv: np.ndarray             # [s] base M' primes, f64
    tgt: np.ndarray             # [s+1] = mp + (m_r,), f64
    allm: np.ndarray            # [2s+1] all channel moduli, f64
    pow2: np.ndarray            # [s, 2s+1]: 2^(23*l) mod channel (to_rns GEMM)
    crt_inv: tuple              # [s]: (M/m_i)^{-1} mod m_i (from_rns weights)


@functools.lru_cache(maxsize=None)
def get_system(s: int) -> RnsSystem:
    if s > HARD_CHANNELS:
        raise ValueError(
            f"{s} channels exceeds the f64-exactness ceiling {HARD_CHANNELS}")
    ps = _channel_primes(2 * s)
    m, mp = ps[:s], ps[s:]
    M = 1
    for p in m:
        M *= p
    Mp = 1
    for p in mp:
        Mp *= p
    Mi = tuple(M // p for p in m)
    Mpi = tuple(Mp // p for p in mp)
    tgt = list(mp) + [RADIX]
    allm = list(m) + list(mp) + [RADIX]
    return RnsSystem(
        s=s, m=m, mp=mp, M=M, Mp=Mp, Mi=Mi, Mpi=Mpi,
        E1=np.array([[mi % t for t in tgt] for mi in Mi], np.float64),
        E2=np.array([[mpi % t for t in list(m) + [RADIX]] for mpi in Mpi],
                    np.float64),
        Minv_t=np.array([pow(M, -1, t) for t in tgt], np.float64),
        c4=np.array([pow(Mpi[j], -1, p) for j, p in enumerate(mp)],
                    np.float64),
        Mp_mod_m=np.array([Mp % p for p in m], np.float64),
        Mpinv_r=float(pow(Mp, -1, RADIX)),
        mv=np.array(m, np.float64),
        mpv=np.array(mp, np.float64),
        tgt=np.array(tgt, np.float64),
        allm=np.array(allm, np.float64),
        pow2=np.array([[pow(2, CH_BITS * l, t) for t in allm]
                       for l in range(s)], np.float64),
        crt_inv=tuple(pow(Mi[i], -1, p) for i, p in enumerate(m)),
    )


@dataclasses.dataclass(frozen=True)
class RnsModulus:
    """Per-modulus constants on top of a shared `RnsSystem`."""
    system: RnsSystem
    modulus: int
    c1: np.ndarray              # [s]: (-N^{-1}·(M/m_i)^{-1}) mod m_i
    NMinv_t: np.ndarray         # [s+1]: (N·M^{-1}) mod t, t in mp + (m_r,)
    one: np.ndarray             # [2s+1]: to_rns(M mod N) — Montgomery one
    plain_one: np.ndarray       # [2s+1]: to_rns(1) — demontgomerize partner


def for_modulus(modulus: int) -> RnsModulus:
    """Build the per-modulus channel constants (host side, cached by the
    caller per key)."""
    sysm = get_system(num_channels(modulus))
    c1 = np.array([(-pow(modulus, -1, p) * pow(sysm.Mi[i], -1, p)) % p
                   for i, p in enumerate(sysm.m)], np.float64)
    NMinv_t = np.array(
        [modulus % t * pow(sysm.M, -1, t) % t
         for t in (list(sysm.mp) + [RADIX])], np.float64)
    ctx = RnsModulus(system=sysm, modulus=modulus, c1=c1, NMinv_t=NMinv_t,
                     one=np.empty(0), plain_one=np.empty(0))
    one = to_rns(ctx, [sysm.M % modulus])[0]
    plain_one = to_rns(ctx, [1])[0]
    object.__setattr__(ctx, "one", one)
    object.__setattr__(ctx, "plain_one", plain_one)
    return ctx


# ---------------------------------------------------------------------------
# host conversions
# ---------------------------------------------------------------------------

def to_mont(ctx: RnsModulus, x: int) -> int:
    """Canonical int -> Montgomery form (host bignum, exact)."""
    return x * ctx.system.M % ctx.modulus


def from_mont(ctx: RnsModulus, x: int) -> int:
    return x * pow(ctx.system.M, -1, ctx.modulus) % ctx.modulus


def to_rns(ctx: RnsModulus, values: Sequence[int]) -> np.ndarray:
    """Batch-decompose ints (< M) into channel vectors, [len(values), 2s+1].

    One ``to_bytes`` per value, then a vectorized bit-regroup into 23-bit
    limbs and a GEMM against the fixed 2^(23l) power table — exact in f64
    (limbs and table entries < 2^23, accumulation < s·2^46 <= 2^52)."""
    sysm = ctx.system
    s = sysm.s
    nbits = s * CH_BITS
    nbytes = (nbits + 7) // 8
    raw = np.frombuffer(
        b"".join(int(v).to_bytes(nbytes, "little") for v in values),
        np.uint8).reshape(len(values), nbytes)
    bits = np.unpackbits(raw, axis=1, bitorder="little")[:, :nbits]
    limbs = bits.reshape(len(values), s, CH_BITS).astype(np.float64)
    limbs = limbs @ (2.0 ** np.arange(CH_BITS))
    return _mod(limbs @ sysm.pow2, sysm.allm)


def from_rns(ctx: RnsModulus, vec: np.ndarray) -> List[int]:
    """Channel vectors [..., 2s+1] -> exact ints via CRT over base M.

    Valid for any value < M — in particular the whole incomplete-reduction
    domain [0, (s+1)·N).  Callers reduce mod N themselves."""
    sysm = ctx.system
    flat = np.asarray(vec, np.float64).reshape(-1, vec.shape[-1])
    # small CRT coefficients vectorized (residue * inv mod p is < 2^46,
    # exact in f64); only the weighted bignum sum runs per value
    coef = _mod(flat[:, :sysm.s] * np.array(sysm.crt_inv, np.float64),
                sysm.mv).astype(np.int64)
    out = []
    for row in coef:
        x = 0
        for i in range(sysm.s):
            x += int(row[i]) * sysm.Mi[i]
        out.append(x % sysm.M)
    return out


# ---------------------------------------------------------------------------
# reference arithmetic (NumPy mirror of ops.py — keep formulas in lockstep)
# ---------------------------------------------------------------------------

def _mod(t: np.ndarray, m: np.ndarray) -> np.ndarray:
    """Exact floor-division modular reduction for |t| < 2^52.

    The reciprocal is rounded, so the quotient can be off by one either
    way: two conditional corrections pin the residue into [0, m)."""
    q = np.floor(t * (1.0 / m))
    r = t - q * m
    r = r + m * (r < 0)
    return r - m * (r >= m)


def mont_mul(ctx: RnsModulus, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """One RNS Montgomery multiply: mont(x), mont(y) -> mont(x·y), both
    sides and the result in the incomplete domain [0, (s+1)·N)."""
    sysm = ctx.system
    s = sysm.s
    x = _mod(a * b, sysm.allm)
    xi = _mod(x[..., :s] * ctx.c1, sysm.mv)
    u = _mod(xi @ sysm.E1, sysm.tgt)
    wt = _mod(x[..., s:] * sysm.Minv_t + u * ctx.NMinv_t, sysm.tgt)
    xip = _mod(wt[..., :s] * sysm.c4, sysm.mpv)
    g2 = xip @ sysm.E2
    alpha = _mod((_mod(g2[..., s:], float(RADIX)) - wt[..., s:])
                 * sysm.Mpinv_r, float(RADIX))
    wm = _mod(g2[..., :s] - alpha * sysm.Mp_mod_m, sysm.mv)
    return np.concatenate([wm, wt], axis=-1)


def mont_exp(ctx: RnsModulus, base: np.ndarray, exponent: int) -> np.ndarray:
    """Square-and-multiply reference exponentiation (host loop)."""
    acc = np.broadcast_to(ctx.one, base.shape).copy()
    for bit in bin(exponent)[2:]:
        acc = mont_mul(ctx, acc, acc)
        if bit == "1":
            acc = mont_mul(ctx, acc, base)
    return acc


def modmul(ctx: RnsModulus, x: int, y: int) -> int:
    """End-to-end scalar check helper: x·y mod N through the RNS path."""
    a = to_rns(ctx, [to_mont(ctx, x % ctx.modulus)])
    b = to_rns(ctx, [to_mont(ctx, y % ctx.modulus)])
    out = mont_mul(ctx, mont_mul(ctx, a, b)[0], ctx.plain_one)
    return from_rns(ctx, out)[0] % ctx.modulus


__all__ = [
    "CH_BITS", "RADIX", "HEADROOM_BITS", "MAX_CHANNELS", "HARD_CHANNELS",
    "RnsSystem", "RnsModulus", "get_system", "for_modulus", "num_channels",
    "fits", "to_mont", "from_mont", "to_rns", "from_rns", "mont_mul",
    "mont_exp", "modmul",
]
