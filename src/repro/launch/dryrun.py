import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces a JSON record with:
  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline
  * collective bytes parsed from the post-SPMD HLO (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute), with ring-model
    effective-wire-bytes estimates
  * lower/compile wall times

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k \
      [--multipod] [--out runs/dryrun]
  python -m repro.launch.dryrun --all [--multipod]

NOTE: the 512-device XLA flag above MUST precede any jax import; run this
module in its own process (never import it from tests).
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax

from repro.configs import registry
from repro.launch.mesh import make_production_mesh

V5E_PEAK_FLOPS = 197e12      # bf16 per chip
V5E_HBM_BW = 819e9           # bytes/s per chip
V5E_ICI_BW = 50e9            # bytes/s per link

COLLECTIVE_RE = re.compile(
    r"=\s+([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective type from post-SPMD HLO."""
    out = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * DTYPE_BYTES[dtype]
        rec = out.setdefault(op, {"count": 0, "result_bytes": 0})
        rec["count"] += 1
        rec["result_bytes"] += b
    return out


def effective_wire_bytes(collectives: dict, n_devices: int) -> float:
    """Ring-model per-device wire bytes (standard algorithm bandwidth)."""
    f = (n_devices - 1) / max(n_devices, 1)
    total = 0.0
    for op, rec in collectives.items():
        b = rec["result_bytes"]
        if op == "all-reduce":
            total += 2 * b * f
        elif op in ("all-gather", "reduce-scatter", "all-to-all"):
            total += b * f
        elif op == "collective-permute":
            total += b
    return total


def run_cell(arch: str, shape: str, multi_pod: bool,
             roofline: bool = False, scan_knob=None,
             variant=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    entry = registry.get(arch)
    cell = entry.build_cell(entry.config, entry.shapes[shape], mesh,
                            roofline=roofline, scan_knob=scan_knob,
                            variant=variant)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "devices": mesh.devices.size}
    t0 = time.monotonic()
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        rec["lower_s"] = round(time.monotonic() - t0, 2)
        t1 = time.monotonic()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.monotonic() - t1, 2)
    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
        rec["bytes_per_device"] = (rec.get("argument_size_in_bytes", 0)
                                   + rec.get("temp_size_in_bytes", 0))
        # XLA:CPU ignores donation, so donated in/out buffers double-count;
        # on TPU the output aliases the donated input.
        rec["donated"] = bool(cell.donate_argnums)
        if cell.donate_argnums:
            rec["bytes_per_device_donation_adjusted"] = max(
                rec["bytes_per_device"] - rec.get("output_size_in_bytes", 0),
                0)
    cost = compiled.cost_analysis()
    if cost:
        # cost_analysis reports the PER-PARTITION (per-device) module
        rec["hlo_flops"] = float(cost.get("flops", -1))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", -1))
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo)
    rec["collective_wire_bytes_per_device"] = effective_wire_bytes(
        rec["collectives"], mesh.devices.size)
    # roofline terms (per chip); hlo_* and wire bytes are already per-device
    if "hlo_flops" in rec and rec["hlo_flops"] > 0:
        rec["t_compute_s"] = rec["hlo_flops"] / V5E_PEAK_FLOPS
    if "hlo_bytes" in rec and rec["hlo_bytes"] > 0:
        rec["t_memory_s"] = rec["hlo_bytes"] / V5E_HBM_BW
    rec["t_collective_s"] = (rec["collective_wire_bytes_per_device"]
                             / V5E_ICI_BW)
    return rec


def run_roofline(arch: str, shape: str, variant=None) -> dict:
    """Exact roofline metrics on the single-pod mesh.

    cost_analysis visits while-loop bodies once (independent of trip count),
    so the roofline variant compiles the cell with every scan fully unrolled
    (and microbatches=1): FLOPs / bytes / collective counts are then exact.
    Memory fields are dropped — the scanned 'pod' record is the memory/fit
    proof; this record is the compute/communication ground truth.
    """
    rec = run_cell(arch, shape, multi_pod=False, roofline=True,
                   variant=variant)
    rec["roofline_method"] = "unrolled"
    rec["variant"] = variant
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "bytes_per_device",
              "generated_code_size_in_bytes"):
        rec.pop(k, None)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--roofline", action="store_true",
                    help="exact roofline metrics via trip-count "
                         "extrapolation (single-pod only)")
    ap.add_argument("--variant", default=None,
                    help="hillclimb variant (moe_a2a, tp_repl, micro2, ...)")
    ap.add_argument("--out", default="runs/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in registry.REGISTRY:
            if args.roofline and arch == "dien":
                # dien's 2x100-step unrolled GRU backward is a pathologically
                # slow XLA:CPU compile; its scanned records are kept with the
                # scan-1x marker + analytic seq-factor note (EXPERIMENTS.md).
                print("[skip] dien roofline (scan-1x + analytic correction)")
                continue
            for shape in registry.get(arch).shapes:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    for arch, shape in cells:
        tag = "multipod" if args.multipod else "pod"
        if args.roofline:
            tag = "roofline"
        if args.variant:
            tag += f"-{args.variant}"
        path = outdir / f"{arch}__{shape}__{tag}.json"
        if path.exists():
            print(f"[skip] {path}")
            continue
        print(f"[dryrun] {arch} x {shape} ({tag}) ...", flush=True)
        try:
            if args.roofline:
                rec = run_roofline(arch, shape, variant=args.variant)
            else:
                rec = run_cell(arch, shape, args.multipod,
                               variant=args.variant)
            rec["ok"] = True
        except Exception as e:  # record failures for triage
            rec = {"arch": arch, "shape": shape, "mesh": tag, "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        path.write_text(json.dumps(rec, indent=2))
        status = "OK" if rec.get("ok") else f"FAIL: {rec.get('error')}"
        print(f"[dryrun] {arch} x {shape} ({tag}) -> {status}", flush=True)


if __name__ == "__main__":
    main()
