"""Roofline report generator: runs/dryrun/*.json -> markdown tables.

For every (arch x shape) cell on the single-pod mesh:
  t_compute    = HLO_FLOPs  / (chips * 197 TFLOP/s bf16)
  t_memory     = HLO_bytes  / (chips * 819 GB/s HBM)
  t_collective = wire_bytes / (chips-local 50 GB/s ICI; ring model)
plus MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) and the useful-compute
ratio MODEL/HLO.  FLOP/byte numbers come from the `roofline` records (fully
unrolled scans — exact); memory-fit numbers come from the scanned `pod`
records.

`python -m benchmarks.roofline_report [--out EXPERIMENTS_roofline.md]`
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import registry
from repro.configs import shapes as shp

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic 'useful' FLOPs for the step (6ND for training; 2ND/token for
    inference), per the §Roofline definition."""
    entry = registry.get(arch)
    if entry.family == "lm":
        cfg = entry.config
        n_act = cfg.active_param_count()
        s = shp.LM_SHAPES[shape_name]
        if s.kind == "train":
            tokens = s.global_batch * s.seq_len
            return 6.0 * n_act * tokens
        if s.kind == "prefill":
            tokens = s.global_batch * s.seq_len
            return 2.0 * n_act * tokens
        return 2.0 * n_act * s.global_batch  # decode: 1 token per sequence
    if entry.family == "gnn":
        g = shp.GNN_SHAPES[shape_name]
        cfg = entry.config
        d = cfg.d_hidden
        per_layer = g.n_edges * (3 * d * d + d * d) * 2 \
            + g.n_nodes * (2 * d * d + d * d) * 2
        fwd = cfg.n_layers * per_layer \
            + g.n_nodes * (g.d_feat * d + d * d) * 2 \
            + g.n_nodes * (d * d + d * cfg.n_vars) * 2
        return 3.0 * fwd  # fwd + bwd(2x)
    if entry.family == "recsys":
        s = shp.RECSYS_SHAPES[shape_name]
        b = s.n_candidates if s.kind == "retrieval" else s.batch
        cfg = entry.config
        if arch == "fm":
            per = cfg.n_sparse * cfg.embed_dim * 4
        elif arch == "dcn-v2":
            d = cfg.d_in
            per = (cfg.n_cross_layers * d * d
                   + d * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1]
                   + cfg.mlp[1] * cfg.mlp[2]) * 2
        elif arch == "dien":
            per = cfg.seq_len * 2 * 3 * (cfg.embed_dim + cfg.gru_dim) \
                * cfg.gru_dim * 2
        else:  # two-tower
            d_in = cfg.n_user_feats * cfg.embed_dim
            per = (d_in * cfg.tower_mlp[0]
                   + cfg.tower_mlp[0] * cfg.tower_mlp[1]
                   + cfg.tower_mlp[1] * cfg.tower_mlp[2]) * 2
            if s.kind == "retrieval":
                per = cfg.tower_mlp[-1] * 2  # dot per candidate
        mult = 3.0 if s.kind == "train" else 1.0
        return mult * b * per
    # remoterag
    s = shp.REMOTERAG_SHAPES[shape_name]
    if s.kind == "module1":
        return 2.0 * s.batch * s.corpus * s.dim
    # module2: pointwise modmuls dominate; count 1 "flop" per modmul
    return float(s.batch * 2 * 3 * 4096 * (-(-s.kprime // 4) + 2))


def load(outdir: Path, arch: str, shape: str, tag: str):
    p = outdir / f"{arch}__{shape}__{tag}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return rec if rec.get("ok") else None


IDEAL_TERM = {
    # which roofline term a *perfect* implementation of this shape kind would
    # be bound by: training/prefill -> compute; decode/serving/retrieval ->
    # memory (streaming weights/KV/corpus once).
    "train": "compute", "prefill": "compute", "full": "compute",
    "minibatch": "compute", "batched_small": "compute",
    "decode": "memory", "serve": "memory", "retrieval": "memory",
    "module1": "memory", "module2": "compute",
}


def shape_kind(arch: str, shape: str) -> str:
    entry = registry.get(arch)
    return getattr(entry.shapes[shape], "kind", "train")


def build_rows(outdir: Path):
    rows = []
    for arch in registry.REGISTRY:
        for shape in registry.get(arch).shapes:
            roof = load(outdir, arch, shape, "roofline")
            pod = load(outdir, arch, shape, "pod")
            multi = load(outdir, arch, shape, "multipod")
            src = roof or pod
            if src is None:
                rows.append({"arch": arch, "shape": shape, "missing": True,
                             "pod_ok": bool(pod), "multi_ok": bool(multi)})
                continue
            n_dev = src["devices"]
            # hlo_flops / hlo_bytes are PER-DEVICE (per-partition HLO module)
            tc = src.get("hlo_flops", 0) / PEAK
            tm = src.get("hlo_bytes", 0) / HBM
            tx = src.get("collective_wire_bytes_per_device", 0) / ICI
            terms = {"compute": tc, "memory": tm, "collective": tx}
            dom = max(terms, key=terms.get)
            ideal = IDEAL_TERM.get(shape_kind(arch, shape), "compute")
            # fraction-of-roofline: the term a perfect implementation would
            # be bound by, over the estimated step bound (max of terms).
            frac = terms[ideal] / max(max(terms.values()), 1e-12)
            # frac* excludes the HLO-bytes memory term (an unfused-CPU upper
            # bound — see EXPERIMENTS.md §Roofline): ideal over max(tc, tx).
            ideal_nomem = tc if ideal != "collective" else tx
            frac_star = ideal_nomem / max(tc, tx, 1e-12)
            mf = model_flops(arch, shape)
            mem = (pod or {})
            rows.append({
                "arch": arch, "shape": shape, "missing": False,
                "pod_ok": bool(pod), "multi_ok": bool(multi),
                "exact": bool(roof),
                "t_compute_s": tc, "t_memory_s": tm, "t_collective_s": tx,
                "bottleneck": dom, "ideal": ideal,
                "model_flops": mf,
                "hlo_flops_total": src.get("hlo_flops", 0) * n_dev,
                "useful_ratio": (mf / (src.get("hlo_flops", 1) * n_dev)
                                 if src.get("hlo_flops") else 0.0),
                "roofline_fraction": frac,
                "roofline_fraction_star": frac_star,
                "mem_gb_per_dev": mem.get(
                    "bytes_per_device_donation_adjusted",
                    mem.get("bytes_per_device", 0)) / 1e9,
            })
    return rows


def to_markdown(rows) -> str:
    out = ["| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bound | ideal | frac | frac* | MODEL/HLO | GB/dev | pod | 2pod |"
           " exact |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("missing"):
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | pending |"
                       f" - | - | - | - | - | {'Y' if r['pod_ok'] else 'N'} |"
                       f" {'Y' if r['multi_ok'] else 'N'} | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} | "
            f"{r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} | "
            f"{r['bottleneck']} | {r['ideal']} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['roofline_fraction_star']:.3f} | "
            f"{r['useful_ratio']:.2f} | {r['mem_gb_per_dev']:.1f} | "
            f"{'Y' if r['pod_ok'] else 'N'} | "
            f"{'Y' if r['multi_ok'] else 'N'} | "
            f"{'Y' if r.get('exact') else 'scan-1x'} |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--out", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()
    rows = build_rows(Path(args.dir))
    md = to_markdown(rows)
    if args.out:
        Path(args.out).write_text(md)
    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=1))
    print(md)


if __name__ == "__main__":
    main()
