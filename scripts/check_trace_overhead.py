#!/usr/bin/env python3
"""CI gate: tracing must be free when off and loadable when on.

Three checks, in order:

1. **Disabled call-site overhead** — every instrumented call site pays
   one NULL-tracer method call when tracing is off; that must stay under
   ``MAX_DISABLED_US_PER_CALL`` (a microsecond-scale bound, measured over
   a million calls), so `EngineConfig(trace=False)` engines are
   indistinguishable from the pre-instrumentation engine.
2. **Enabled end-to-end factor** — a traced serve stream must finish
   within ``MAX_TRACED_FACTOR`` of the same stream untraced (plus a
   fixed slack absorbing wall-clock noise on a seconds-long run).  The
   crypto dominates; span recording is microseconds per stage.
3. **Trace file validity** — the traced run must write a Chrome-trace
   JSON that loads, covers every core pipeline stage, and whose
   queue_wait + dispatch intervals reconcile with each request's
   end-to-end latency.

    PYTHONPATH=src python scripts/check_trace_overhead.py

Exit 0 on pass, 1 on any failed check (wired into scripts/smoke.sh).
"""

from __future__ import annotations

import os
import sys
import tempfile
import time

import numpy as np

import jax

from repro import obs
from repro.crypto import rlwe
from repro.retrieval.index import FlatIndex
from repro.serve import EngineConfig, ServeEngine
from repro.serve.session import SessionManager

MAX_DISABLED_US_PER_CALL = 10.0   # NULL-tracer span call, amortized
MAX_TRACED_FACTOR = 1.5           # traced wall vs untraced wall ...
TRACED_SLACK_S = 1.0              # ... plus fixed noise slack
CORE_STAGES = ("queue_wait", "dispatch", "perturb", "topk", "encrypt",
               "score", "decrypt", "finish")

N_DOCS, DIM, K, N_REQ, MAX_BATCH = 512, 64, 4, 8, 4
PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)


def check_disabled_overhead() -> int:
    n = 1_000_000
    tracer = obs.NULL_TRACER
    t0 = time.perf_counter()
    for _ in range(n):
        with tracer.span("stage", batch_id=1, lanes=8):
            pass
    per_call_us = (time.perf_counter() - t0) / n * 1e6
    if per_call_us > MAX_DISABLED_US_PER_CALL:
        print(f"FAIL disabled-overhead: {per_call_us:.2f}us per NULL span "
              f"call > {MAX_DISABLED_US_PER_CALL}us", file=sys.stderr)
        return 1
    print(f"ok   disabled-overhead: {per_call_us:.2f}us per NULL span "
          f"call (bound {MAX_DISABLED_US_PER_CALL}us)")
    return 0


def _run_stream(index, queries, *, trace: bool):
    eng = ServeEngine(
        index,
        config=EngineConfig(max_batch=MAX_BATCH, max_wait_s=30.0,
                            trace=trace),
        sessions=SessionManager(rlwe_params=PARAMS,
                                deterministic_seeds=True))
    for t in range(4):
        eng.open_session(f"smoke-{t}", n=DIM, N=N_DOCS, k=K,
                         radius=0.05, backend="rlwe")
    for i in range(N_REQ):
        eng.submit(f"smoke-{i % 4}", queries[i], key=jax.random.PRNGKey(i))
    t0 = time.perf_counter()
    results = eng.drain()
    wall = time.perf_counter() - t0
    assert all(r.ok for r in results), "smoke stream must succeed"
    eng.close()
    return wall, results, eng


def check_traced_run() -> int:
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((N_DOCS, DIM)).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    index = FlatIndex.build(
        emb, documents=[f"doc-{i}".encode() for i in range(N_DOCS)])
    queries = emb[:N_REQ] + rng.standard_normal(
        (N_REQ, DIM)).astype(np.float32) * 0.01

    _run_stream(index, queries, trace=False)          # jit warmup
    untraced_wall, untraced_res, _ = _run_stream(index, queries,
                                                 trace=False)
    traced_wall, traced_res, eng = _run_stream(index, queries, trace=True)

    failures = 0
    bound = untraced_wall * MAX_TRACED_FACTOR + TRACED_SLACK_S
    if traced_wall > bound:
        print(f"FAIL traced-overhead: traced stream took {traced_wall:.3f}s "
              f"vs {untraced_wall:.3f}s untraced (bound {bound:.3f}s)",
              file=sys.stderr)
        failures += 1
    else:
        print(f"ok   traced-overhead: {traced_wall:.3f}s traced vs "
              f"{untraced_wall:.3f}s untraced "
              f"(bound {MAX_TRACED_FACTOR}x + {TRACED_SLACK_S}s)")

    # tracing must not change results (bit-identity with tracing off)
    for ru, rt in zip(untraced_res, traced_res):
        assert ru.ids.tolist() == rt.ids.tolist(), \
            "tracing changed result ids"
        assert ru.docs == rt.docs, "tracing changed result docs"
    print("ok   traced-identity: traced results bit-identical to untraced")

    fd, path = tempfile.mkstemp(suffix=".json", prefix="trace-smoke-")
    os.close(fd)
    try:
        n_events = eng.write_trace(path)
        doc = obs.load_chrome_trace(path)
        names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
        missing = [s for s in CORE_STAGES if s not in names]
        if missing or n_events == 0:
            print(f"FAIL trace-file: {n_events} events, missing stages "
                  f"{missing}", file=sys.stderr)
            failures += 1
        else:
            print(f"ok   trace-file: {n_events} events load, all "
                  f"{len(CORE_STAGES)} core stages present")
    finally:
        os.unlink(path)

    # per-request reconciliation: queue_wait + dispatch must bound the
    # reported end-to-end latency (small tolerance for clock reads
    # between the dispatch span end and the latency stamp)
    spans = eng.tracer.spans()
    dispatches = {s.batch_id: s for s in spans if s.name == "dispatch"}
    waits = {s.request_id: s for s in spans if s.name == "queue_wait"}
    bad = 0
    for res in traced_res:
        w = waits.get(res.request_id)
        d = dispatches.get(w.batch_id) if w is not None else None
        if w is None or d is None:
            bad += 1
            continue
        explained = w.duration_s + d.duration_s
        if not (res.latency_s <= explained + 0.05):
            bad += 1
    if bad:
        print(f"FAIL trace-reconcile: {bad}/{len(traced_res)} requests' "
              f"latency not explained by queue_wait + dispatch",
              file=sys.stderr)
        failures += 1
    else:
        print(f"ok   trace-reconcile: all {len(traced_res)} request "
              f"latencies within queue_wait + dispatch")
    return failures


def main() -> int:
    failures = check_disabled_overhead()
    failures += check_traced_run()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
