"""Per-tenant serving metrics: latency percentiles + wire-byte accounting.

Latency is measured enqueue -> result (queue wait included, the number a
tenant actually experiences under micro-batching).  Wire bytes come from the
protocol transcripts, i.e. the same Request.nbytes / Reply.nbytes accounting
the paper's Table 2 uses.

Memory is bounded: latency and batch-size *samples* live in a fixed-size
sliding window (``window`` items, default 8192 — configurable through
`ServeMetrics` / ``EngineConfig.metrics_window``), so a long-lived engine
under the million-user north star cannot grow without bound.  Counts and
byte totals stay exact forever (they are plain integer accumulators);
`percentile`/`summary` statistics are computed over the current window.

Fault-isolation accounting (all exact integers): a lane pulled out of a
batched dispatch after fault attribution is *quarantined*
(``quarantined_lanes``); its solo retries are ``retried_requests`` and a
retry that succeeds is ``quarantined_retry_ok`` (also tracked per tenant, so
per-tenant error counts distinguish healed lanes from terminal
``errors``).  ``lane_encryptions`` counts every tenant-side query
encryption the engine performs; ``healthy_reencryptions`` counts
encryptions beyond the first for lanes that were never quarantined — the
isolation contract keeps it at zero (gated in CI by
``scripts/check_bench_regression.py``).  ``dispatch_lanes`` accumulates
the lanes *completed* inside batched dispatches so `occupancy` reports
useful batch fill (a quarantined lane is lost fill, not a full batch);
refill-triggered dispatches are counted separately
(``refill_dispatches`` / ``refilled_requests``).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import math
import threading
from typing import Deque, Dict, Optional

import numpy as np

from repro.core.protocol import ProtocolTranscript

DEFAULT_WINDOW = 8192


def _locked(method):
    """Serialize a ServeMetrics method on the instance lock: replica
    engines record from their own step workers while the router thread
    reads summaries, and compound updates (tenant + aggregate + reason
    maps) must stay atomic across threads."""
    @functools.wraps(method)
    def wrapper(self, *args, **kwargs):
        with self._lock:
            return method(self, *args, **kwargs)
    return wrapper


@dataclasses.dataclass
class TenantStats:
    """Exact integer totals + windowed latency/batch-size samples."""
    window: int = DEFAULT_WINDOW
    count: int = 0                 # exact: every recorded result
    errors: int = 0                # exact: terminal failures (retries spent)
    quarantined_retry_ok: int = 0  # exact: quarantined, healed on solo retry
    admitted: int = 0              # exact: submits past the admission tier
    shed: int = 0                  # exact: requests shed/rejected untried
    deadline_misses: int = 0       # exact: completions after their deadline
    request_bytes: int = 0
    reply_bytes: int = 0
    fetch_bytes: int = 0
    docs_bytes: int = 0
    ot_wire_bytes: int = 0
    direct_count: int = 0
    ot_count: int = 0
    latencies_s: Deque[float] = dataclasses.field(init=False, repr=False)
    batch_sizes: Deque[int] = dataclasses.field(init=False, repr=False)

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        self.latencies_s = collections.deque(maxlen=self.window)
        self.batch_sizes = collections.deque(maxlen=self.window)

    @property
    def total_wire_bytes(self) -> int:
        return (self.request_bytes + self.reply_bytes + self.fetch_bytes
                + self.docs_bytes + self.ot_wire_bytes)

    def percentile(self, q: float) -> float:
        """Latency percentile over the current window (the trailing
        ``window`` results), not all-time.  NaN on an empty window — an
        error-only or untouched tenant has no latency samples, and that
        must read as "no data", not an opaque numpy error."""
        if not self.latencies_s:
            return math.nan
        return float(np.percentile(self.latencies_s, q))

    def summary(self) -> dict:
        if not self.latencies_s:
            # error-only (or untouched) stats: no samples to summarize —
            # percentile on an empty window must not blow up the summary
            out = {"count": self.count}
            if self.errors:
                out["errors"] = self.errors
            if self.quarantined_retry_ok:
                out["quarantined_retry_ok"] = self.quarantined_retry_ok
            self._admission_summary(out)
            return out
        out = {
            "count": self.count,
            "p50_latency_s": round(self.percentile(50), 4),
            "p99_latency_s": round(self.percentile(99), 4),
            "mean_latency_s": round(float(np.mean(self.latencies_s)), 4),
            "mean_batch_size": round(float(np.mean(self.batch_sizes)), 2),
            "mean_wire_kb": round(
                self.total_wire_bytes / max(self.count, 1) / 1024, 2),
            "paths": {"direct": self.direct_count, "ot": self.ot_count},
        }
        if self.errors:
            out["errors"] = self.errors
        if self.quarantined_retry_ok:
            out["quarantined_retry_ok"] = self.quarantined_retry_ok
        self._admission_summary(out)
        return out

    def _admission_summary(self, out: dict) -> None:
        """Admission-tier counters, surfaced only when the tier touched
        this tenant — a run without admission control keeps the exact
        historical summary shape."""
        if self.admitted:
            out["admitted"] = self.admitted
        if self.shed:
            out["shed"] = self.shed
        if self.deadline_misses:
            out["deadline_misses"] = self.deadline_misses


class ServeMetrics:
    """Accumulates TenantStats per tenant plus a process-wide aggregate.

    Dispatch-level accounting is exact-total + windowed-sample like the
    tenant stats: ``num_batches``/``dispatch_lanes``/``failed_dispatches``
    and the quarantine/refill counters are exact; ``dispatch_sizes`` keeps
    the trailing ``window`` batch sizes.  A batch is recorded only once the
    dispatch *completed for at least one lane* — a dispatch whose every
    lane failed calls `record_dispatch_failure` (never `record_batch`), so
    failed batches can never masquerade as served traffic, and a
    quarantined lane's solo retry is never recorded as a batch of its own
    (no phantom or duplicate batches).
    """

    def __init__(self, window: int = DEFAULT_WINDOW, *,
                 tracer=None) -> None:
        self._lock = threading.Lock()
        self.window = window
        # optional repro.obs.Tracer: when attached (the engine does this
        # under EngineConfig(trace=True)), summary() carries the stage-
        # level telemetry snapshot alongside the tenant metrics
        self.tracer = tracer
        self.tenants: Dict[str, TenantStats] = {}
        self.aggregate = TenantStats(window=window)
        self.dispatch_sizes: Deque[int] = collections.deque(maxlen=window)
        self.num_batches = 0           # exact: completed dispatches
        self.dispatch_lanes = 0        # exact: lanes *completed* in batches
        self.failed_dispatches = 0     # exact: dispatches with zero lanes ok
        self.failed_requests = 0       # exact: requests in failed dispatches
        self.quarantined_lanes = 0     # exact: lanes isolated out of a batch
        self.retried_requests = 0      # exact: solo quarantine retries run
        self.quarantined_retry_ok = 0   # exact: solo retries that healed
        self.error_results = 0         # exact: error results handed back
        self.lane_encryptions = 0      # exact: tenant query encryptions
        self.healthy_reencryptions = 0  # exact: must stay 0 (CI-gated)
        self.refill_dispatches = 0     # exact: dispatches on the refill path
        self.refilled_requests = 0     # exact: requests they carried
        # admission-tier accounting (all exact; zero and invisible in the
        # summary unless an admission tier / per-request deadline is used)
        self.admitted_requests = 0     # exact: submits past the tier
        self.shed_requests = 0         # exact: shed + rejected, all reasons
        self.shed_by_reason: Dict[str, int] = {}
        self.deadline_misses = 0       # exact: completions past deadline
        self.goodput_requests = 0      # exact: ok completions within SLO

    def _tenant(self, tenant: str) -> TenantStats:
        stats = self.tenants.get(tenant)
        if stats is None:
            stats = self.tenants[tenant] = TenantStats(window=self.window)
        return stats

    @_locked
    def record_batch(self, size: int, completed: Optional[int] = None) -> None:
        """One batched dispatch went out: ``size`` lanes in the slot, of
        which ``completed`` (default: all) actually finished there.
        `occupancy` reads the completed count, so a quarantined lane shows
        up as lost occupancy instead of hiding inside a full-looking
        batch."""
        self.num_batches += 1
        self.dispatch_lanes += size if completed is None else completed
        self.dispatch_sizes.append(size)

    @_locked
    def record_dispatch_failure(self, size: int) -> None:
        self.failed_dispatches += 1
        self.failed_requests += size

    @_locked
    def record_quarantined(self, n: int = 1) -> None:
        """n lanes were attributed a fault and pulled out of their batch."""
        self.quarantined_lanes += n

    @_locked
    def record_retries(self, n: int = 1) -> None:
        self.retried_requests += n

    @_locked
    def record_quarantined_retry_ok(self, tenant: str) -> None:
        """A quarantined lane healed on its solo retry (counted per tenant
        so error accounting distinguishes healed from terminal)."""
        self.quarantined_retry_ok += 1
        for stats in (self._tenant(tenant), self.aggregate):
            stats.quarantined_retry_ok += 1

    @_locked
    def record_encryptions(self, n: int = 1) -> None:
        self.lane_encryptions += n

    @_locked
    def record_healthy_reencryptions(self, n: int) -> None:
        """Encryptions beyond the first for a never-quarantined lane —
        wasted crypto the lane-isolation contract promises never happens."""
        self.healthy_reencryptions += n

    @_locked
    def record_refill(self, size: int) -> None:
        """One dispatch went out on the refill trigger (group credit)."""
        self.refill_dispatches += 1
        self.refilled_requests += size

    @_locked
    def record_error(self, tenant: str) -> None:
        """One request came back as an error result (retries exhausted)."""
        self.error_results += 1
        for stats in (self._tenant(tenant), self.aggregate):
            stats.errors += 1

    @_locked
    def record_admitted(self, tenant: str) -> None:
        """One submit passed the admission tier and was enqueued."""
        self.admitted_requests += 1
        for stats in (self._tenant(tenant), self.aggregate):
            stats.admitted += 1

    @_locked
    def record_shed(self, tenant: str, reason: str) -> None:
        """One request was shed (queued then displaced/expired) or
        rejected at submit (rate limit, full queue) — counted drops,
        keyed by the typed reason, so offered == completed + shed always
        reconciles."""
        self.shed_requests += 1
        self.shed_by_reason[reason] = self.shed_by_reason.get(reason, 0) + 1
        for stats in (self._tenant(tenant), self.aggregate):
            stats.shed += 1

    @_locked
    def record(self, tenant: str, *, latency_s: float, batch_size: int,
               transcript: ProtocolTranscript,
               deadline_s: Optional[float] = None) -> None:
        # goodput = completions within their SLO; a request without a
        # deadline always counts (no SLO to miss), one past its deadline
        # is a deadline miss — completed, billed, but not goodput
        missed = deadline_s is not None and latency_s > deadline_s
        if missed:
            self.deadline_misses += 1
        else:
            self.goodput_requests += 1
        for stats in (self._tenant(tenant), self.aggregate):
            stats.count += 1
            if missed:
                stats.deadline_misses += 1
            stats.latencies_s.append(latency_s)
            stats.batch_sizes.append(batch_size)
            stats.request_bytes += transcript.request_bytes
            stats.reply_bytes += transcript.reply_bytes
            stats.fetch_bytes += transcript.fetch_bytes
            stats.docs_bytes += transcript.docs_bytes
            stats.ot_wire_bytes += transcript.ot_wire_bytes
            if transcript.path == "ot":
                stats.ot_count += 1
            else:
                stats.direct_count += 1

    @_locked
    def occupancy(self, max_batch: int) -> Optional[float]:
        """Mean *completed-lane* fill of batched dispatches relative to
        ``max_batch`` (1.0 = every batch went out full and every lane
        finished in it; quarantined lanes count as lost fill).  None
        before any batch completed."""
        if not self.num_batches or max_batch <= 0:
            return None
        return self.dispatch_lanes / (self.num_batches * max_batch)

    @_locked
    def summary(self) -> dict:
        out = {"aggregate": self.aggregate.summary(),
               "num_batches": self.num_batches,
               "dispatch_lanes": self.dispatch_lanes,
               "tenants": {t: s.summary() for t, s in self.tenants.items()}}
        # surfaced only when the admission tier (or a per-request
        # deadline) actually touched traffic: a default-config run keeps
        # the exact historical summary shape
        if (self.admitted_requests or self.shed_requests
                or self.deadline_misses):
            out["admission"] = {
                "admitted": self.admitted_requests,
                "shed": self.shed_requests,
                "shed_by_reason": dict(sorted(self.shed_by_reason.items())),
                "deadline_misses": self.deadline_misses,
                "goodput_requests": self.goodput_requests,
            }
        if self.refill_dispatches:
            out["refills"] = {
                "refill_dispatches": self.refill_dispatches,
                "refilled_requests": self.refilled_requests,
            }
        # healthy_reencryptions is part of the trigger: it is the CI-gated
        # isolation contract, and a nonzero value must surface even when
        # every other failure counter is zero (a healthy-looking run that
        # silently re-encrypted would otherwise hide its contract breach)
        if (self.failed_dispatches or self.quarantined_lanes
                or self.error_results or self.healthy_reencryptions):
            out["failures"] = {
                "failed_dispatches": self.failed_dispatches,
                "failed_requests": self.failed_requests,
                "quarantined_lanes": self.quarantined_lanes,
                "retried_requests": self.retried_requests,
                "quarantined_retry_ok": self.quarantined_retry_ok,
                "error_results": self.error_results,
                "healthy_reencryptions": self.healthy_reencryptions,
            }
        if self.tracer is not None and getattr(self.tracer, "enabled",
                                               False):
            out["trace"] = self.tracer.snapshot()
        return out


__all__ = ["TenantStats", "ServeMetrics", "DEFAULT_WINDOW"]
