"""Chrome-trace-format export: load the serving timeline in Perfetto.

`write_chrome_trace` turns a tracer's span snapshot into the Trace Event
Format JSON that ``ui.perfetto.dev`` (or ``chrome://tracing``) renders
directly: complete ("X") duration events in microseconds, one thread row
per span *track* — "engine" for batched stages, "admitter" for the
sharded cache's background thread, "request-<id>" rows for per-request
spans — so a batch's lane-parallel structure and the admission copy
overlapping encrypt are visible on a real timeline.

Only the span schema's whitelisted scalars reach ``args``; the exporter
adds nothing beyond ids already on the span.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

from repro.obs.trace import Span

_PID = 1                         # single-process engine


def chrome_trace_events(spans: Sequence[Span]) -> List[dict]:
    """Spans -> Trace Event Format event list (ts normalized to the
    earliest span so Perfetto opens at t=0)."""
    if not spans:
        return []
    t0 = min(s.t_start for s in spans)
    tids: Dict[str, int] = {}
    events: List[dict] = []
    for span in spans:
        tid = tids.get(span.track)
        if tid is None:
            # "engine" first keeps the main pipeline as the top row
            tid = tids[span.track] = 1 if span.track == "engine" \
                else len(tids) + 1
            events.append({
                "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
                "args": {"name": span.track},
            })
        args = dict(span.attrs)
        if span.request_id is not None:
            args["request_id"] = span.request_id
        if span.batch_id is not None:
            args["batch_id"] = span.batch_id
        events.append({
            "name": span.name,
            "ph": "X",
            "ts": round((span.t_start - t0) * 1e6, 3),
            "dur": round(span.duration_s * 1e6, 3),
            "pid": _PID,
            "tid": tid,
            "args": args,
        })
    return events


def write_chrome_trace(path: str, spans: Sequence[Span], *,
                       stage_summary: Optional[dict] = None) -> int:
    """Write ``{"traceEvents": [...]}`` JSON to ``path``; returns the
    number of duration events written.  ``stage_summary`` (if given) is
    attached under ``"metadata"`` so the profile travels with the
    timeline."""
    events = chrome_trace_events(spans)
    doc: dict = {"traceEvents": events, "displayTimeUnit": "ms"}
    if stage_summary is not None:
        doc["metadata"] = {"stage_summary": stage_summary}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    return sum(1 for e in events if e.get("ph") == "X")


def load_chrome_trace(path: str) -> dict:
    """Load + structurally validate a trace file written by
    `write_chrome_trace` (used by the CI overhead gate)."""
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    for e in events:
        if e["ph"] == "X" and (e["dur"] < 0 or e["ts"] < 0):
            raise ValueError(f"negative ts/dur in event {e['name']!r}")
    return doc


__all__ = ["chrome_trace_events", "write_chrome_trace", "load_chrome_trace"]
