"""Privacy/efficiency planner for RemoteRAG.

Turns user-facing knobs (privacy budget eps, or a target perturbation radius r,
or a target candidate count k') into a concrete protocol plan:

  * the perturbation radius the mechanism will use (mean or quantile),
  * the inflated search range k' (Theorem 1),
  * the module-2 retrieval path (direct indices vs k-out-of-k' OT, Theorem 3),
  * predicted communication cost (paper Table 2).

The paper's guideline eps in [10n, 50n] corresponds to mean radii in
[0.02, 0.1]; both parameterizations are supported (Fig. 6b does k' -> eps).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import distancedp, geometry


@dataclasses.dataclass(frozen=True)
class ProtocolPlan:
    n: int                # embedding dimension
    N: int                # corpus size
    k: int                # requested top-k
    eps: float            # privacy budget
    radius: float         # perturbation radius used for Theorem-1 planning
    radial_quantile: float
    delta_alpha: float    # planned perturbed angle
    alpha_k: float        # Lemma-1 polar angle of the top-k cap
    kprime: int           # Theorem-1 inflated search range
    omega: float          # Theorem-3 mean-embedding leakage angle
    use_ot: bool          # module 2(c) if True else 2(b)
    conservative: bool

    @property
    def path(self) -> str:
        return "ot" if self.use_ot else "direct"


def plan(
    *,
    n: int,
    N: int,
    k: int,
    eps: Optional[float] = None,
    radius: Optional[float] = None,
    kprime: Optional[int] = None,
    radial_quantile: float = 0.999,
    conservative: bool = True,
    slack: float = 1.0,
) -> ProtocolPlan:
    """Build a protocol plan from exactly one of (eps, radius, kprime).

    ``radial_quantile`` plans k' against a high quantile of Gamma(n, 1/eps)
    instead of its mean, so the Theorem-1 containment holds w.p. ~quantile
    per request even before the conservative-angle slack.
    """
    provided = sum(x is not None for x in (eps, radius, kprime))
    if provided != 1:
        raise ValueError("specify exactly one of eps / radius / kprime")
    if kprime is not None:
        eps = eps_for_kprime(n=n, N=N, k=k, kprime=kprime,
                             radial_quantile=radial_quantile,
                             conservative=conservative, slack=slack)
    elif radius is not None:
        eps = distancedp.eps_for_radius(n, radius)
    assert eps is not None

    r_plan = distancedp.radial_quantile_np(n, eps, radial_quantile)
    alpha_k = float(geometry.alpha_from_fraction_np(k / N, n))
    d_alpha = float(geometry.perturbed_angle(r_plan, conservative=conservative)) * slack
    kp = geometry.kprime_for(k, N, n, r_plan, conservative=conservative, slack=slack)
    omega = float(geometry.mean_angle_omega(alpha_k, k))
    # Theorem 3 / Algorithm 2: compare against the *mean* perturbation angle,
    # as the paper does (delta_alpha ~= n/eps).
    use_ot = omega < (n / eps)
    return ProtocolPlan(
        n=n, N=N, k=k, eps=float(eps), radius=float(r_plan),
        radial_quantile=radial_quantile, delta_alpha=d_alpha, alpha_k=alpha_k,
        kprime=int(kp), omega=omega, use_ot=bool(use_ot),
        conservative=conservative,
    )


def eps_for_kprime(
    *,
    n: int,
    N: int,
    k: int,
    kprime: int,
    radial_quantile: float = 0.999,
    conservative: bool = True,
    slack: float = 1.0,
    tol: float = 1e-3,
) -> float:
    """Fig. 6(b): the privacy budget whose plan yields the target k' (bisection)."""
    if kprime < k:
        raise ValueError("kprime must be >= k")
    if kprime >= N:
        return 1e-6  # effectively eps -> 0: privacy-conscious limit

    def kp_of(eps: float) -> int:
        r = distancedp.radial_quantile_np(n, eps, radial_quantile)
        return geometry.kprime_for(k, N, n, r, conservative=conservative, slack=slack)

    lo, hi = 1.0, 1e9  # eps: small -> huge k', large -> k' ~= k
    for _ in range(200):
        mid = np.sqrt(lo * hi)
        if kp_of(mid) > kprime:
            lo = mid
        else:
            hi = mid
        if hi / lo < 1 + tol:
            break
    return float(np.sqrt(lo * hi))


__all__ = ["ProtocolPlan", "plan", "eps_for_kprime"]
