"""dien [recsys]: embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80
interaction=augru [arXiv:1809.03672]."""
from repro.models.recsys import DienConfig

CONFIG = DienConfig(name="dien", embed_dim=18, seq_len=100, gru_dim=108,
                    mlp=(200, 80), item_vocab=500_000)

REDUCED = DienConfig(name="dien-smoke", embed_dim=8, seq_len=12, gru_dim=16,
                     mlp=(20, 8), item_vocab=500)
