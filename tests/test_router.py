"""repro.serve.router: differential bit-identity vs a single engine,
scatter-gather merge determinism, replica fault injection with zero lost
requests, and typed admission-error propagation through the router."""

import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.crypto import rlwe
from repro.data import synth
from repro.kernels.scoretopk import ops as sops
from repro.retrieval.index import FlatIndex, plan_row_slices
from repro.retrieval.topk import slice_topk
from repro.serve import (
    AdmissionConfig,
    EngineConfig,
    RateLimited,
    ReplicaRouter,
    ReplicaUnavailable,
    RouterConfig,
    ServeEngine,
    SessionManager,
)
from repro.serve.router import merge_topk

N_DOCS, DIM, K = 1500, 64, 4
N_REQ = 8
TENANTS = ("alice", "bob", "carol", "dave")
PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)
SEED = 0        # every stochastic choice in this file derives from it


@pytest.fixture(scope="module")
def corpus():
    """Corpus with planted duplicate-score ties: three exact row copies
    spread across every replica boundary used in the sweep (750 for N=2;
    375/1125 for N=4), so a query near the original produces identical
    scores on *different* replicas and the merge tie-break is exercised
    for real, not just in theory."""
    rng = np.random.default_rng(SEED)
    emb = synth.uniform_corpus(rng, N_DOCS, DIM)
    emb[800] = emb[100]       # duplicates straddle the 750 boundary (N=2)
    emb[1200] = emb[100]      # ... and the 1125 boundary (N=4)
    emb[400] = emb[100]       # ... and the 375 boundary (N=4)
    docs = [f"passage-{i}".encode() for i in range(N_DOCS)]
    index = FlatIndex.build(emb, documents=docs, normalize=False)
    queries = synth.queries_near_corpus(rng, emb, N_REQ)
    # aim one query straight at the duplicated row so its ties surface in
    # the top-k' candidates (and in the final top-K)
    queries[3] = emb[100]
    return index, emb, queries


def _sessions():
    return SessionManager(rlwe_params=PARAMS, deterministic_seeds=True)


def _open_all(srv, *, backend="rlwe", kprime=None, **kw):
    plan_kw = {"plan_kwargs": {"kprime": kprime}} if kprime else \
        {"radius": 0.05}
    if backend == "paillier":
        kw.setdefault("paillier_bits", 256)
    for t in TENANTS:
        srv.open_session(t, n=DIM, N=N_DOCS, k=K, backend=backend,
                         **plan_kw, **kw)


def _submit_all(srv, queries):
    return [srv.submit(TENANTS[i % len(TENANTS)], q,
                       key=jax.random.PRNGKey(i))
            for i, q in enumerate(queries)]


def _by_rid(results):
    return {r.request_id: r for r in results}


def _single_run(index, queries, *, backend="rlwe", kprime=None,
                max_batch=8):
    eng = ServeEngine(
        index, config=EngineConfig(max_batch=max_batch, max_wait_s=30.0),
        sessions=_sessions())
    _open_all(eng, backend=backend, kprime=kprime)
    _submit_all(eng, queries)
    out = eng.drain()
    eng.close()
    return out


def _router(index, *, num_replicas, max_batch=8, backend="rlwe",
            kprime=None, engine_kw=None, router_kw=None):
    rt = ReplicaRouter(
        index,
        config=RouterConfig(
            num_replicas=num_replicas,
            engine=EngineConfig(max_batch=max_batch, max_wait_s=30.0,
                                **(engine_kw or {})),
            **(router_kw or {})),
        sessions=_sessions())
    _open_all(rt, backend=backend, kprime=kprime)
    return rt


def _assert_results_identical(want, got):
    """Bit-identity down to the wire accounting, request id by request id."""
    assert sorted(r.request_id for r in got) == \
        sorted(r.request_id for r in want)
    wd = _by_rid(want)
    for rb in got:
        rs = wd[rb.request_id]
        assert rs.tenant == rb.tenant
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
        assert rs.transcript.total_bytes == rb.transcript.total_bytes
        assert rs.transcript.request_bytes == rb.transcript.request_bytes
        assert rs.transcript.reply_bytes == rb.transcript.reply_bytes
        assert rs.error == rb.error


# -- satellite 1: differential bit-identity sweep ---------------------------


@pytest.mark.parametrize("num_replicas,max_batch",
                         [(1, 8), (2, 1), (2, 3), (2, 8), (4, 3), (4, 8)])
def test_router_bit_identical_to_single_engine(corpus, num_replicas,
                                               max_batch):
    """ReplicaRouter(N) == ServeEngine over the whole corpus: same request
    ids (shared counter), same docs/ids/wire bytes, for every replica
    count and batch size — including the planted duplicate-score ties."""
    index, _, queries = corpus
    want = _single_run(index, queries, max_batch=max_batch)
    rt = _router(index, num_replicas=num_replicas, max_batch=max_batch)
    rids = _submit_all(rt, queries)
    got = rt.drain()
    rt.close()
    assert rids == [r.request_id for r in want]   # ids are submit order
    assert len(got) == N_REQ and all(r.ok for r in got)
    _assert_results_identical(want, got)
    m = rt.metrics.summary()
    assert sum(m["submitted"]) == N_REQ
    assert sum(m["completed"]) == N_REQ
    assert m["quarantines"] == [] and m["late_dropped"] == 0
    assert m["scatter_calls"] > 0
    assert m["fallback_scans"] == 0


def test_router_bit_identical_paillier_backend(corpus):
    index, _, queries = corpus
    want = _single_run(index, queries[:4], backend="paillier", max_batch=4)
    rt = _router(index, num_replicas=2, max_batch=4, backend="paillier")
    _submit_all(rt, queries[:4])
    got = rt.drain()
    rt.close()
    assert len(got) == 4 and all(r.ok for r in got)
    _assert_results_identical(want, got)


def test_kprime_straddles_replica_boundaries(corpus):
    """Forced k' values around the slice boundaries: candidates must come
    from multiple replicas and still merge to the single-engine list.
    k'=751 > one replica's 750 docs is the k'>slice regression case at
    full corpus scale (search only — K=4 keeps the re-rank affordable)."""
    index, _, queries = corpus
    slices = plan_row_slices(N_DOCS, 2)
    assert slices == [(0, 750), (750, 1500)]
    full = sops.topk_scores(jnp.asarray(queries), index.embeddings, 751)
    parts = [slice_topk(index.slice_view(s, e), jnp.asarray(queries), 751)
             for s, e in slices]
    merged = merge_topk([p.values for p in parts],
                        [p.indices for p in parts], 751)
    assert merged.tolist() == np.asarray(full.indices).tolist()
    # and values are bitwise equal too (the canary for the slice-scan
    # accumulation matching the full-corpus scan exactly)
    gathered = np.concatenate([np.asarray(p.values) for p in parts], axis=1)
    order = np.concatenate([np.asarray(p.indices) for p in parts], axis=1)
    vals = np.take_along_axis(
        gathered, np.argsort(order, axis=1, kind="stable"), axis=1)
    assert np.array_equal(
        np.take_along_axis(vals, merged, axis=1).view(np.uint32),
        np.asarray(full.values).view(np.uint32))


def test_kprime_larger_than_one_replica_slice():
    """k' > docs-in-one-replica: a 40-doc corpus over 4 replicas (10 docs
    each) with k'=25 forces every replica to contribute its entire slice;
    results must still match the single engine bit-for-bit."""
    rng = np.random.default_rng(SEED + 1)
    emb = synth.uniform_corpus(rng, 40, DIM)
    index = FlatIndex.build(emb, documents=[f"d{i}".encode()
                                            for i in range(40)],
                            normalize=False)
    queries = synth.queries_near_corpus(rng, emb, 4)

    def run(make):
        srv = make()
        for t in TENANTS:
            srv.open_session(t, n=DIM, N=40, k=K,
                             plan_kwargs={"kprime": 25})
        _submit_all(srv, queries)
        out = srv.drain()
        srv.close()
        return out

    want = run(lambda: ServeEngine(
        index, config=EngineConfig(max_batch=4, max_wait_s=30.0),
        sessions=_sessions()))
    got = run(lambda: ReplicaRouter(
        index,
        config=RouterConfig(num_replicas=4,
                            engine=EngineConfig(max_batch=4,
                                                max_wait_s=30.0)),
        sessions=_sessions()))
    assert all(r.ok for r in got)
    _assert_results_identical(want, got)


# -- satellite 3: merge-order determinism -----------------------------------


def test_merge_topk_fuzz_matches_full_scan():
    """Random corpora with planted duplicate scores, random slice cuts:
    per-slice top-k + merge == full-corpus `topk_scores`, ids and bits."""
    rng = np.random.default_rng(SEED)
    for trial in range(8):
        n = int(rng.integers(50, 400))
        emb = rng.normal(size=(n, 16)).astype(np.float32)
        # plant duplicates (identical rows -> identical scores everywhere)
        for _ in range(int(rng.integers(1, 6))):
            i, j = rng.integers(0, n, size=2)
            emb[j] = emb[i]
        q = rng.normal(size=(3, 16)).astype(np.float32)
        k = int(rng.integers(1, n + 1))
        n_slices = int(rng.integers(1, min(6, n) + 1))
        cuts = plan_row_slices(n, n_slices)
        index = FlatIndex.build(emb, normalize=False)
        parts = [slice_topk(index.slice_view(s, e), jnp.asarray(q), k)
                 for s, e in cuts]
        merged = merge_topk([p.values for p in parts],
                            [p.indices for p in parts], k)
        full = sops.topk_scores(jnp.asarray(q), index.embeddings, k)
        assert merged.tolist() == np.asarray(full.indices).tolist(), \
            f"trial={trial} n={n} k={k} cuts={cuts}"


def test_merge_is_arrival_order_independent(corpus):
    """Fuzz actual thread completion order with seeded random stalls in
    the scan hook: the merged candidate block must be identical whatever
    order the per-replica scans finish in."""
    index, _, queries = corpus
    rt = _router(index, num_replicas=4)
    try:
        pert = np.asarray(queries[:5], np.float32)
        want = rt._scatter_topk(pert, 32, home=0)
        for trial in range(5):
            delays = np.random.default_rng(SEED + trial).uniform(
                0.0, 0.02, size=4)

            rt._scan_hook = lambda r, d=delays: time.sleep(d[r])
            got = rt._scatter_topk(pert, 32, home=trial % 4)
            assert np.array_equal(want, got), f"trial={trial}"
    finally:
        rt._scan_hook = None
        rt.close()
    assert rt.metrics.summary()["quarantines"] == []


# -- satellite 2: replica fault injection -----------------------------------


class _PoisonOnce:
    """Fail exactly one fetch — the one resolving to ``poison_ids`` — so
    that lane is quarantined in-batch but heals on its solo retry."""

    def __init__(self, cloud, poison_ids):
        self.cloud = cloud
        self.poison_ids = list(poison_ids)
        self.fired = False

    def __call__(self, cand_ids, msg):
        ids = [int(cand_ids[p]) for p in msg.positions]
        if ids == self.poison_ids and not self.fired:
            self.fired = True
            raise RuntimeError("transient poisoned lane")
        return type(self.cloud).handle_fetch(self.cloud, cand_ids, msg)


def test_engine_quarantine_retry_stays_slice_routed(corpus, monkeypatch):
    """A lane quarantined *inside* a replica's engine retries solo through
    the router's scatter-gather searcher — never a direct full-index
    scan.  The protocol-level whole-index top-k' is poisoned to prove it
    is not reached, and the healed result stays bit-identical."""
    from repro.core import protocol as protocol_mod

    def no_full_scan(*a, **kw):
        raise AssertionError(
            "solo retry bypassed the per-slice scatter path")

    monkeypatch.setattr(protocol_mod, "distributed_topk", no_full_scan)
    index, _, queries = corpus
    want = _by_rid(_single_run(index, queries))   # also never full-scans
    rt = _router(index, num_replicas=2)
    victim = rt.home_replica(TENANTS[0])
    eng = rt.replicas[victim].engine
    eng.cloud.handle_fetch = _PoisonOnce(eng.cloud, want[0].ids.tolist())
    rids = _submit_all(rt, queries)
    got = _by_rid(rt.drain())
    rt.close()
    assert set(got) == set(rids)
    assert all(r.ok for r in got.values())
    healed = [rid for rid, r in got.items() if r.quarantined]
    assert healed == [0]
    for rid in rids:
        assert got[rid].ids.tolist() == want[rid].ids.tolist()
        assert got[rid].docs == want[rid].docs
        assert (got[rid].transcript.total_bytes
                == want[rid].transcript.total_bytes)
    m = rt.metrics.summary()
    assert m["quarantines"] == [] and m["fallback_scans"] == 0


def test_scan_fault_quarantines_and_falls_back(corpus):
    """Kill one replica's scan worker mid-dispatch: the router quarantines
    it, serves its slice from the caller-thread fallback, and every
    result stays bit-identical to the single engine.  The dead replica's
    own in-flight requests resolve as typed errors; zero requests lost."""
    index, _, queries = corpus
    want = _by_rid(_single_run(index, queries))
    rt = _router(index, num_replicas=2)
    victim = 1

    def hook(replica_id):
        if replica_id == victim:
            raise RuntimeError("injected scan fault")

    rids = _submit_all(rt, queries)
    victim_rids = {rid for rid, t in zip(rids, TENANTS * 2)
                   if rt.home_replica(t) == victim}
    healthy_rids = set(rids) - victim_rids
    assert victim_rids and healthy_rids   # both replicas own traffic
    rt._scan_hook = hook
    got = _by_rid(rt.drain())
    rt.close()

    # zero lost: every accepted request resolved exactly once
    assert set(got) == set(rids)
    for rid in healthy_rids:
        rs, rb = want[rid], got[rid]
        assert rb.ok
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
        assert rs.transcript.total_bytes == rb.transcript.total_bytes
    for rid in victim_rids:
        rb = got[rid]
        assert not rb.ok and rb.quarantined
        assert "replica_quarantined" in rb.error
        assert rb.docs == [] and rb.ids.size == 0
    m = rt.metrics.summary()
    assert [q[0] for q in m["quarantines"]] == [victim]
    assert m["quarantines"][0][1].startswith("scan:")
    assert m["quarantine_resolved"] == len(victim_rids)
    assert m["fallback_scans"] >= 1


def test_step_fault_resolves_inflight_as_typed_errors(corpus):
    """A replica whose engine step raises outright is quarantined at the
    router tier; its queued requests come back as typed error results
    (never silently dropped) and the other replica is untouched."""
    index, _, queries = corpus
    rt = _router(index, num_replicas=2)
    victim = 0
    rids = _submit_all(rt, queries)
    victim_rids = {rid for rid, t in zip(rids, TENANTS * 2)
                   if rt.home_replica(t) == victim}

    def boom(*a, **kw):
        raise RuntimeError("injected step fault")

    rt.replicas[victim].engine.step = boom
    rt.replicas[victim].engine.drain = boom
    got = _by_rid(rt.drain())
    assert set(got) == set(rids)
    for rid in rids:
        if rid in victim_rids:
            assert not got[rid].ok and got[rid].quarantined
            assert "replica_quarantined(drain:RuntimeError)" in \
                got[rid].error
        else:
            assert got[rid].ok
    m = rt.metrics.summary()
    assert m["quarantines"] == [[victim, "drain:RuntimeError"]]
    assert m["quarantine_resolved"] == len(victim_rids)
    # quarantined replica is out of the submit path from now on
    probe = next(t for t in TENANTS if rt.home_replica(t) == victim)
    rid = rt.submit(probe, queries[0], key=jax.random.PRNGKey(99))
    out = _by_rid(rt.drain())
    assert out[rid].ok                    # rehomed to the healthy replica
    assert rt.metrics.summary()["rehomed"] >= 1
    rt.close()


def test_stalled_replica_times_out_and_quarantines(corpus):
    """A replica that stalls (never returns) past step_timeout_s is
    quarantined with its in-flight requests resolved — the router never
    hangs on a dead peer.  Only the victim holds traffic here, so the
    timeout bounds the stall, not the healthy crypto."""
    index, _, queries = corpus
    rt = _router(index, num_replicas=2,
                 router_kw={"step_timeout_s": 2.0})
    victim_tenant = TENANTS[0]
    victim = rt.home_replica(victim_tenant)
    rids = [rt.submit(victim_tenant, queries[i],
                      key=jax.random.PRNGKey(i)) for i in range(3)]
    stall = threading.Event()

    def hang(*a, **kw):
        stall.wait(timeout=30.0)
        return []

    rt.replicas[victim].engine.drain = hang
    got = _by_rid(rt.drain())
    stall.set()
    assert set(got) == set(rids)
    for rid in rids:
        assert not got[rid].ok
        assert "replica_quarantined(drain_stalled)" in got[rid].error
    m = rt.metrics.summary()
    assert m["quarantines"] == [[victim, "drain_stalled"]]
    rt.close()


def test_all_replicas_down_is_typed(corpus):
    index, _, queries = corpus
    rt = _router(index, num_replicas=2)
    for r in range(2):
        rt._quarantine(r, "test")
    with pytest.raises(ReplicaUnavailable):
        rt.submit(TENANTS[0], queries[0])
    rt.close()


# -- satellite 4: typed admission errors through the router -----------------


def test_rate_limit_propagates_and_consumes_no_request_id(corpus):
    """The home replica's RateLimited (with retry_after_s) surfaces
    through router.submit unchanged, and a rejection never consumes a
    request id on any replica: the shared id counter only advances on
    accepted submits, so ids stay gapless across the fleet."""
    index, _, queries = corpus
    rt = _router(index, num_replicas=2,
                 engine_kw={"admission": AdmissionConfig(
                     tenant_rate=0.001, tenant_burst=2.0)})
    t = TENANTS[0]
    other = next(x for x in TENANTS
                 if rt.home_replica(x) != rt.home_replica(t))
    r0 = rt.submit(t, queries[0], key=jax.random.PRNGKey(0))
    r1 = rt.submit(t, queries[1], key=jax.random.PRNGKey(1))
    with pytest.raises(RateLimited) as exc:
        rt.submit(t, queries[2], key=jax.random.PRNGKey(2))
    assert exc.value.retry_after_s > 0
    # the very next accepted submit — on a *different* replica — takes
    # the very next id: the rejection consumed nothing anywhere
    r2 = rt.submit(other, queries[3], key=jax.random.PRNGKey(3))
    assert [r0, r1, r2] == [0, 1, 2]
    m = rt.metrics.summary()
    assert sum(m["rejected"]) == 1 and sum(m["submitted"]) == 3
    out = rt.drain()
    assert sorted(r.request_id for r in out) == [0, 1, 2]
    assert all(r.ok for r in out)
    rt.close()


def test_unknown_tenant_and_bad_embedding_are_typed(corpus):
    index, _, queries = corpus
    rt = _router(index, num_replicas=2)
    with pytest.raises(KeyError, match="nobody"):
        rt.submit("nobody", queries[0])
    with pytest.raises(ValueError, match="1-D"):
        rt.submit(TENANTS[0], queries[0][None, :])
    # neither consumed an id
    rid = rt.submit(TENANTS[0], queries[0], key=jax.random.PRNGKey(0))
    assert rid == 0
    rt.drain()
    rt.close()
