"""RNS Montgomery bignum primitives as jitted lax ops.

The batched counterpart of ``ref.py`` — identical formulas, expressed over
``jax.numpy`` so a whole ``[batch, k', channels]`` ciphertext block moves
through one fused XLA computation.  The two base extensions are ``@``
contractions against the fixed [s, s+1] matrices from `ref.RnsSystem`, which
XLA CPU lowers to Eigen GEMMs; everything else is elementwise and fuses.

All functions assume float64 inputs and MUST run (trace + execute) under
``jax.experimental.enable_x64()`` — the caller owns that context.  Constants
travel in a plain dict pytree (see `make_consts`): system matrices are
shared across lanes, per-modulus vectors (`c1`, `NMinv_t`, `one`) are
stacked/broadcast by the caller to match the value batch shape, which is
what lets one compiled kernel serve a multi-tenant batch whose lanes hold
*different* keys of one channel count.

Exactness contract (proved in ref.py, differential-tested in
tests/test_bignum.py): channels < 2^23, products < 2^46, GEMM sums
< s·2^46 <= 2^53 for s <= 128 — every double is an exact integer.
"""

from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bignum import ref


def make_consts(system: ref.RnsSystem,
                moduli: Sequence[ref.RnsModulus],
                batch_ndim: int) -> dict:
    """Build the constants pytree for a stack of per-lane moduli.

    ``batch_ndim`` is the number of batch axes on the values the kernel
    will see (e.g. 2 for ``[lanes, k', channels]``): per-lane vectors are
    shaped ``[lanes, 1, ..., s]`` so they broadcast against any trailing
    batch axes, while the shared system matrices stay rank-2.
    """
    if any(m.system is not system for m in moduli):
        raise ValueError("all moduli must share one RnsSystem")
    lane_shape = (len(moduli),) + (1,) * (batch_ndim - 1)

    def stack(rows):
        arr = np.stack(rows).astype(np.float64)
        return arr.reshape(lane_shape + (arr.shape[-1],))

    return {
        "E1": jnp.asarray(system.E1), "E2": jnp.asarray(system.E2),
        "Minv_t": jnp.asarray(system.Minv_t), "c4": jnp.asarray(system.c4),
        "Mp_mod_m": jnp.asarray(system.Mp_mod_m),
        "Mpinv_r": jnp.float64(system.Mpinv_r),
        "mv": jnp.asarray(system.mv), "mpv": jnp.asarray(system.mpv),
        "tgt": jnp.asarray(system.tgt), "allm": jnp.asarray(system.allm),
        "c1": jnp.asarray(stack([m.c1 for m in moduli])),
        "NMinv_t": jnp.asarray(stack([m.NMinv_t for m in moduli])),
        "one": jnp.asarray(stack([m.one for m in moduli])),
        "plain_one": jnp.asarray(stack([m.plain_one for m in moduli])),
    }


def _mod(t: jnp.ndarray, m) -> jnp.ndarray:
    q = jnp.floor(t * (1.0 / m))
    r = t - q * m
    r = r + m * (r < 0)
    return r - m * (r >= m)


def mont_mul(a: jnp.ndarray, b: jnp.ndarray, C: dict) -> jnp.ndarray:
    """Batched RNS Montgomery multiply over channel-last arrays."""
    s = C["mv"].shape[0]
    x = _mod(a * b, C["allm"])
    xi = _mod(x[..., :s] * C["c1"], C["mv"])
    u = _mod(xi @ C["E1"], C["tgt"])
    wt = _mod(x[..., s:] * C["Minv_t"] + u * C["NMinv_t"], C["tgt"])
    xip = _mod(wt[..., :s] * C["c4"], C["mpv"])
    g2 = xip @ C["E2"]
    alpha = _mod((_mod(g2[..., s:], float(ref.RADIX)) - wt[..., s:])
                 * C["Mpinv_r"], float(ref.RADIX))
    wm = _mod(g2[..., :s] - alpha * C["Mp_mod_m"], C["mv"])
    return jnp.concatenate([wm, wt], axis=-1)


def pow_table(base: jnp.ndarray, C: dict, window: int) -> jnp.ndarray:
    """``[2^window, *base.shape]`` table of base^0 .. base^(2^w - 1)."""
    rows = [jnp.broadcast_to(C["one"], base.shape), base]
    for _ in range(2, 1 << window):
        rows.append(mont_mul(rows[-1], base, C))
    return jnp.stack(rows)


def mont_exp_digits(table: jnp.ndarray, digits: jnp.ndarray, C: dict,
                    window: int) -> jnp.ndarray:
    """Left-to-right windowed exponentiation from a precomputed table.

    ``digits`` is ``[*batch, positions]`` int32, most-significant window
    first, with ``*batch`` equal to the table's value batch shape (callers
    broadcast per-lane exponents across candidates on the host — the
    digits are tiny).  Runs as one `lax.scan` whose body is ``window``
    squarings plus one gathered multiply.
    """
    base_shape = table.shape[1:]
    acc0 = jnp.broadcast_to(C["one"], base_shape)

    def body(acc, dig):
        for _ in range(window):
            acc = mont_mul(acc, acc, C)
        t = jnp.take_along_axis(
            table, dig[None, ..., None].astype(jnp.int32), axis=0)[0]
        return mont_mul(acc, t, C), None

    acc, _ = jax.lax.scan(body, acc0, jnp.moveaxis(digits, -1, 0))
    return acc


def square_n(x: jnp.ndarray, C: dict, n: int) -> jnp.ndarray:
    for _ in range(n):
        x = mont_mul(x, x, C)
    return x


def product_reduce(x: jnp.ndarray, C: dict) -> jnp.ndarray:
    """Tree-reduce a ``[..., n, channels]`` stack to ``[..., channels]``
    with Montgomery multiplies (log2(n) levels, odd tails carried)."""
    while x.shape[-2] > 1:
        half = x.shape[-2] // 2
        y = mont_mul(x[..., :half, :], x[..., half:2 * half, :], C)
        if x.shape[-2] % 2:
            y = jnp.concatenate([y, x[..., 2 * half:, :]], axis=-2)
        x = y
    return x[..., 0, :]


def to_digits(exponents: Sequence[int], window: int,
              positions: int | None = None) -> np.ndarray:
    """Fixed-width base-2^window digit planes, most-significant first,
    ``[len(exponents), positions]`` int32 (leading zeros pad short ones)."""
    if positions is None:
        bits = max(int(e).bit_length() for e in exponents)
        positions = max(1, -(-bits // window))
    mask = (1 << window) - 1
    out = np.zeros((len(exponents), positions), np.int32)
    for i, e in enumerate(exponents):
        e = int(e)
        for p in range(positions - 1, -1, -1):
            out[i, p] = e & mask
            e >>= window
        if e:
            raise ValueError("exponent wider than digit plan")
    return out


__all__ = ["make_consts", "mont_mul", "pow_table", "mont_exp_digits",
           "square_n", "product_reduce", "to_digits"]
