"""Quickstart: the RemoteRAG protocol in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small synthetic corpus, plans the privacy budget, runs one private
retrieval round, and checks the result against the plaintext oracle.
"""

import numpy as np

import jax

from repro.core import protocol
from repro.data import synth
from repro.retrieval.index import FlatIndex


def main() -> None:
    rng = np.random.default_rng(0)
    dim, n_docs, k = 384, 5_000, 5

    # --- cloud side: index N documents ------------------------------------
    embeddings = synth.uniform_corpus(rng, n_docs, dim)
    documents = [f"passage #{i}".encode() for i in range(n_docs)]
    index = FlatIndex.build(embeddings, documents=documents)

    # --- user side: pick a privacy budget, make one request ---------------
    user = protocol.RemoteRagUser(n=dim, N=n_docs, k=k, radius=0.05,
                                  backend="rlwe", rng=rng)
    print(f"plan: eps={user.plan.eps:.0f}  k'={user.plan.kprime}  "
          f"module-2 path={user.plan.path}")

    cloud = protocol.RemoteRagCloud(index, rlwe_params=user.rlwe_params)
    query = synth.queries_near_corpus(rng, embeddings, 1)[0]

    docs, ids, transcript = protocol.run_remoterag(
        user, cloud, query, jax.random.PRNGKey(0))

    # --- verify against the plaintext oracle ------------------------------
    oracle = np.argsort(-(embeddings @ query), kind="stable")[:k]
    recall = len(set(ids.tolist()) & set(oracle.tolist())) / k
    print(f"retrieved ids: {ids.tolist()}")
    print(f"recall vs plaintext top-{k}: {recall:.0%}")
    print(f"wire bytes: {transcript.total_bytes:,} "
          f"(request {transcript.request_bytes:,} / "
          f"reply {transcript.reply_bytes:,})")
    assert recall == 1.0


if __name__ == "__main__":
    main()
