"""Pallas TPU kernel: fused corpus scoring + per-tile top-k selection.

RemoteRAG Module 1 scores the perturbed query against the full corpus shard
and keeps the top-k' — a streaming, memory-bound matmul whose output (all N
scores) is pure waste if materialized.  This kernel fuses:

  HBM corpus tile (T, n) -> VMEM -> MXU matmul vs resident queries (B, n)
  -> per-tile top-kk selection (VPU iterative max-extract, no sort)

so only (num_tiles, B, kk) candidates ever reach HBM — an N/kk-fold output
reduction.  The tiny cross-tile merge happens outside (jnp top_k over
num_tiles*kk items); with kk == k' the union provably contains the global
top-k', and for kk < k' the caller checks an exactness certificate (no tile
contributed its full kk) and falls back to the exact path if violated.

Selection is k iterations of (max, argmax, mask) over the tile's scores:
sort-free, fully vectorized over the batch, MXU-aligned tiles (T, n multiples
of 128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, e_ref, vals_ref, idx_ref, *, kk: int, tile: int, n_rows: int):
    i = pl.program_id(0)
    q = q_ref[...]            # (B, n)
    e = e_ref[...]            # (T, n)
    b = q.shape[0]
    scores = jnp.dot(q, e.T, preferred_element_type=jnp.float32)  # (B, T)

    # mask padded rows (beyond the real corpus) to -inf
    row_ids = i * tile + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    scores = jnp.where(row_ids < n_rows, scores, -jnp.inf)

    col = jax.lax.broadcasted_iota(jnp.int32, (b, tile), 1)

    def body(j, carry):
        s, vacc, iacc = carry
        m = jnp.max(s, axis=1)                          # (B,)
        am = jnp.argmax(s, axis=1).astype(jnp.int32)    # (B,)
        vacc = jax.lax.dynamic_update_slice(vacc, m[:, None], (0, j))
        iacc = jax.lax.dynamic_update_slice(
            iacc, (i * tile + am)[:, None], (0, j))
        s = jnp.where(col == am[:, None], -jnp.inf, s)
        return s, vacc, iacc

    vacc = jnp.full((b, kk), -jnp.inf, jnp.float32)
    iacc = jnp.full((b, kk), n_rows, jnp.int32)
    _, vacc, iacc = jax.lax.fori_loop(0, kk, body, (scores, vacc, iacc))
    vals_ref[0] = vacc
    idx_ref[0] = iacc


@functools.partial(jax.jit, static_argnames=("kk", "tile", "interpret"))
def score_topk_pallas(queries, corpus, *, kk: int, tile: int = 2048,
                      interpret: bool = True):
    """Fused scoring + per-tile top-kk.

    queries: (B, n) f32/bf16; corpus: (N, n).  Returns
    vals (num_tiles, B, kk) f32 and global idx (num_tiles, B, kk) int32
    (padded entries have val=-inf, idx=N).
    """
    b, n = queries.shape
    n_rows = corpus.shape[0]
    num_tiles = -(-n_rows // tile)
    pad = num_tiles * tile - n_rows
    if pad:
        corpus = jnp.pad(corpus, ((0, pad), (0, 0)))
    kern = functools.partial(_kernel, kk=kk, tile=tile, n_rows=n_rows)
    return pl.pallas_call(
        kern,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((b, n), lambda i: (0, 0)),
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, b, kk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, b, kk), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_tiles, b, kk), jnp.float32),
            jax.ShapeDtypeStruct((num_tiles, b, kk), jnp.int32),
        ],
        interpret=interpret,
    )(queries.astype(jnp.float32), corpus.astype(jnp.float32))


__all__ = ["score_topk_pallas"]
