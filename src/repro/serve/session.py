"""Per-tenant sessions and the protocol-plan cache.

A Session owns everything the protocol calls "the user": the tenant's secret
key material (RLWE or Paillier), its numpy RNG stream, and its ProtocolPlan.
Plans are pure functions of the planning knobs, so a process-wide PlanCache
lets repeat tenants (or many tenants with the same service tier) skip the
Theorem-1 bisection + scipy quantile work entirely.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Dict, Optional

import numpy as np

from repro.core import planner, protocol
from repro.core.planner import ProtocolPlan
from repro.crypto import rlwe


class PlanCache:
    """Memoize planner.plan on (n, N, k, eps/radius, plan kwargs) — exactly
    the arguments the planner consumes, so tenants that differ only in
    crypto backend share one plan.

    Entries are additionally stamped with the corpus ``epoch`` they were
    planned against: N (the Theorem-1 corpus size) changes when ingestion
    advances the epoch, and the stamp makes a stale plan unreachable even
    for a hypothetical ingest that leaves N unchanged — the serve layer
    passes its pinned `CorpusView.epoch` here."""

    def __init__(self) -> None:
        self._plans: Dict[tuple, ProtocolPlan] = {}
        self.hits = 0
        self.misses = 0

    def get(self, *, n: int, N: int, k: int, eps: Optional[float] = None,
            radius: Optional[float] = None, epoch: int = 0,
            **plan_kwargs) -> ProtocolPlan:
        key = (n, N, k, eps, radius, epoch,
               tuple(sorted(plan_kwargs.items())))
        plan = self._plans.get(key)
        if plan is not None:
            self.hits += 1
            return plan
        self.misses += 1
        plan = planner.plan(n=n, N=N, k=k, eps=eps, radius=radius,
                            **plan_kwargs)
        self._plans[key] = plan
        return plan

    def __len__(self) -> int:
        return len(self._plans)


def tenant_seed(tenant: str) -> int:
    """Stable per-tenant RNG seed (so two engines replay identical streams).

    Derivable from the public tenant id — only safe under
    ``SessionManager(deterministic_seeds=True)`` replay/benchmark setups,
    never as a production default (the key material would be public).
    """
    return int.from_bytes(hashlib.sha256(tenant.encode()).digest()[:8], "big")


@dataclasses.dataclass
class Session:
    tenant: str
    user: protocol.RemoteRagUser
    created_at: float
    knobs: tuple = ()              # the open() arguments that built this
    num_requests: int = 0
    # serializes the tenant's rng-consuming protocol stages (query
    # encryption, OT retrieval): the engine's background retry lane may
    # run a quarantined request for this tenant concurrently with a
    # dispatch batch, and the numpy Generator must advance one draw at a
    # time to keep streams well-defined
    lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    @property
    def backend(self) -> str:
        return self.user.backend

    @property
    def plan(self) -> ProtocolPlan:
        return self.user.plan


class SessionManager:
    """Tenant registry: one Session per tenant id, shared RLWE public params
    (each tenant still generates its own secret key)."""

    def __init__(self, *, rlwe_params: Optional[rlwe.RlweParams] = None,
                 plan_cache: Optional[PlanCache] = None,
                 deterministic_seeds: bool = False):
        self.rlwe_params = (rlwe.RlweParams() if rlwe_params is None
                            else rlwe_params)
        # `is None` (not truthiness): an empty PlanCache has len 0 == falsy
        self.plan_cache = PlanCache() if plan_cache is None else plan_cache
        # True: per-tenant rng seeded from tenant_seed(name) so two engines
        # replay identical key/noise streams (parity tests, benchmarks).
        # False (default): OS entropy — tenant keys are not derivable.
        self.deterministic_seeds = deterministic_seeds
        self._sessions: Dict[str, Session] = {}

    def get(self, tenant: str) -> Session:
        return self._sessions[tenant]

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._sessions

    def __len__(self) -> int:
        return len(self._sessions)

    def open(self, tenant: str, *, n: int, N: int, k: int,
             eps: Optional[float] = None, radius: Optional[float] = None,
             backend: str = "rlwe", seed: Optional[int] = None,
             paillier_bits: int = 512, epoch: int = 0,
             plan_kwargs: Optional[dict] = None) -> Session:
        """Create (or return) the tenant's session.  Keygen happens here,
        once; the plan comes from the shared cache.  Re-opening an existing
        tenant with *different* knobs is an error — the old plan would keep
        being used silently (e.g. a stale, weaker privacy budget).
        ``epoch`` stamps the plan-cache entry with the corpus epoch the
        caller planned against (see `PlanCache`)."""
        knobs = (n, N, k, eps, radius, backend, seed, paillier_bits,
                 tuple(sorted((plan_kwargs or {}).items())))
        if tenant in self._sessions:
            sess = self._sessions[tenant]
            if sess.knobs != knobs:
                raise ValueError(
                    f"tenant {tenant!r} already open with different knobs "
                    f"{sess.knobs}; close/rename the session to change them")
            return sess
        plan = self.plan_cache.get(n=n, N=N, k=k, eps=eps, radius=radius,
                                   epoch=epoch, **(plan_kwargs or {}))
        if seed is None and self.deterministic_seeds:
            seed = tenant_seed(tenant)
        rng = np.random.default_rng(seed)  # seed None -> OS entropy
        user = protocol.RemoteRagUser(
            n=n, N=N, k=k, backend=backend, plan=plan,
            rlwe_params=self.rlwe_params, paillier_bits=paillier_bits,
            rng=rng)
        sess = Session(tenant=tenant, user=user,
                       created_at=time.monotonic(), knobs=knobs)
        self._sessions[tenant] = sess
        return sess


__all__ = ["PlanCache", "Session", "SessionManager", "tenant_seed"]
