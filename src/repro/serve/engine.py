"""Micro-batching request engine for the RemoteRAG protocol.

Requests enqueue via `submit`; `step` forms at most one batch per call using
three triggers — size (a compatible group reached `max_batch`), deadline
(the group's oldest request waited `max_wait_s`), and refill (the group's
previous batch dispatched under `max_batch`, or full with a burst tail
still queued, so waiting requests are admitted into the next dispatch
immediately instead of waiting out the deadline again) — and runs the
full protocol for that batch:

  module 1    vmapped DistanceDP perturbation (per-request PRNG keys)
  module 2a   ONE batched score-top-k' kernel invocation over the shared
              index (run first, so sharded-cache shard admissions can be
              prefetched from the candidate ids — the background H2D copy
              overlaps the per-tenant host encryption that follows), then
              per-tenant query encryption (host), one batched encrypted
              re-rank and one batched decryption through the crypto-backend
              seam (`repro.crypto.backend`) — RLWE scores against the
              index's NTT-domain candidate cache, Paillier through the
              RNS-vectorized kernels; the stage pipeline itself is
              backend-neutral
  module 2b/c direct fetch or k-of-k' OT per request (host)

Batches group by (backend, n, k'): the stacked crypto needs equal ciphertext
shapes, which (n, k') pins down.  Every lane is bit-identical to the
sequential `protocol.run_remoterag` driver — same docs, ids and wire bytes —
so `EngineConfig(sequential=True)` exists purely as the latency/throughput
comparison path.

Failure handling is *lane-level*: a dispatch failure is attributed to the
offending lane(s) — per-lane stages (encryption, retrieval) attribute
directly, batched stages (perturbation, top-k', scoring, decryption) by
bisection over lane subsets — and only those lanes are quarantined: one
solo retry on the sequential path (`EngineConfig.max_retries`), then a
`ServeResult` error result.  Healthy lanes complete from their
already-computed state — they are never re-encrypted, never re-dispatched,
and never double-counted in the metrics.
"""

from __future__ import annotations

import dataclasses
import itertools
import secrets
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro import obs
from repro.core import protocol
from repro.crypto import backend as crypto_backends
from repro.crypto import rlwe
from repro.retrieval.index import FlatIndex
from repro.serve import admission as adm
from repro.serve import batching
from repro.serve.metrics import ServeMetrics
from repro.serve.session import Session, SessionManager


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8          # size trigger
    max_wait_s: float = 0.02    # deadline trigger (age of a group's head)
    sequential: bool = False    # comparison path: loop run_remoterag
    use_pallas: Optional[bool] = None
    # RLWE re-rank candidate cache: True = serve from the index's NTT-domain
    # cache, False = cold per-request packing (bit-identical reference).
    use_candidate_cache: bool = True
    # None = dense device-resident cache; an rlwe.CandidateCacheConfig
    # selects the sharded corpus-scale cache (shard size, device-memory
    # budget for LRU-pinned hot shards, admission policy).
    cache_config: Optional["rlwe.CandidateCacheConfig"] = None
    # solo sequential-path retries per quarantined lane before the request
    # is returned as an error result (0 = fail immediately, never retry)
    max_retries: int = 1
    # continuous refill: a group whose batch dispatched under max_batch
    # (or full but with a burst tail still queued) keeps a one-window
    # credit, so waiting requests join the next dispatch immediately
    # instead of aging out max_wait_s again
    refill: bool = True
    # bounded per-tenant latency/batch-size sample windows (exact totals
    # for counts and wire bytes are kept regardless) — see serve.metrics
    metrics_window: int = 8192
    # stage-level span tracing (repro.obs): off by default — the NULL
    # tracer keeps the disabled cost near zero (CI-gated by
    # scripts/check_trace_overhead.py).  Spans carry only structural
    # facts (redaction enforced at record time, see repro.obs.trace).
    trace: bool = False
    # span ring-buffer capacity; stage histograms stay complete past it
    trace_capacity: int = 65536
    # SLO-aware admission tier (repro.serve.admission): per-tenant token
    # buckets, a bounded global queue with priority displacement, and
    # deadline-aware shedding before any crypto runs.  None (the default)
    # installs no admission machinery at all — submit/step behave
    # bit-identically to the uncontrolled engine.
    admission: Optional["adm.AdmissionConfig"] = None
    # IVF first-stage routing: number of cluster slices each query's
    # top-k' scan probes.  Needs an index built with
    # ``FlatIndex.build(ivf=...)`` (otherwise the flat scan runs and this
    # is ignored).  None = exact flat scan; nprobe >= the cluster count
    # is bit-identical to the flat scan (the differential anchor).  Use
    # `repro.retrieval.topk.plan_nprobe` to derive a bound from the
    # Theorem-1 plan's k'.
    nprobe: Optional[int] = None
    # True (default): quarantine solo retries run on a background retry
    # lane (a single worker thread) so a faulty lane's retry wall never
    # costs a healthy batch's p99 — retry results surface from a later
    # step()/drain(), which barriers on retry completion.  False restores
    # the inline retry on the dispatch thread.
    retry_lane: bool = True


@dataclasses.dataclass
class ServeRequest:
    request_id: int
    tenant: str
    embedding: np.ndarray
    key: jax.Array
    t_enqueue: float
    group: tuple = ()           # the (backend, n, k') queue key
    retries: int = 0            # solo quarantine retries already spent
    encryptions: int = 0        # query-encryption attempts (waste audit)
    priority: str = "interactive"   # admission.PRIORITIES class
    rank: int = 0                   # cached priority_rank(priority)
    deadline_s: Optional[float] = None  # SLO budget from t_enqueue


@dataclasses.dataclass
class ServeResult:
    request_id: int
    tenant: str
    docs: List[bytes]
    ids: np.ndarray
    transcript: Optional[protocol.ProtocolTranscript]
    latency_s: float
    batch_size: int
    # None on success; the lane's failure (repr) after its quarantine
    # retries are exhausted.  Failed requests are returned, never dropped.
    error: Optional[str] = None
    # True when this lane was quarantined out of a batched dispatch (the
    # result then came from a solo retry, or is an error result).
    quarantined: bool = False
    # set when the request was shed by the admission tier before any
    # crypto ran (one of admission.SHED_REASONS); `error` is then
    # "shed(<reason>)" so unaware callers still see a non-ok result
    shed_reason: Optional[str] = None

    @property
    def ok(self) -> bool:
        return self.error is None


def _bisect_lanes(run, lanes: Sequence[int], *,
                  tracer=obs.NULL_TRACER, batch_id: Optional[int] = None,
                  stage: str = "") -> Tuple[dict, dict]:
    """Fault-attribute one batched stage.  ``run(lane_list)`` computes the
    stage for those lanes and returns one output per lane; the full set is
    tried first (the clean-path fast case — identical work to a monolithic
    dispatch), and a raising subset is split in half until the failure pins
    to single lanes.  Stage functions must be deterministic, per-lane
    independent, and free of tenant-rng side effects — true of the
    perturbation, top-k', scoring and decryption stages, which consume only
    per-request PRNG keys, already-encrypted queries, and index state — so
    re-running a lane inside a smaller subset reproduces its bits exactly
    and never re-encrypts anything.  Returns ({lane: output},
    {lane: exception})."""
    out: dict = {}
    bad: dict = {}
    pending = [list(lanes)]
    while pending:
        ls = pending.pop()
        if not ls:
            continue
        try:
            vals = run(ls)
        except Exception as e:        # noqa: BLE001 — attribution scope
            tracer.event("bisect", batch_id=batch_id, stage=stage,
                         subset=len(ls), error_type=type(e).__name__)
            if len(ls) == 1:
                bad[ls[0]] = e
            else:
                mid = len(ls) // 2
                pending.append(ls[mid:])
                pending.append(ls[:mid])   # popped first: keep lane order
            continue
        out.update(zip(ls, vals))
    return out, bad


def _lane_stage(fn, lanes: Sequence[int]) -> Tuple[dict, dict]:
    """Per-lane stage with direct attribution: ``fn(lane)`` runs in lane
    order; a raising lane is recorded and its batchmates continue."""
    out: dict = {}
    bad: dict = {}
    for lane in lanes:
        try:
            out[lane] = fn(lane)
        except Exception as e:        # noqa: BLE001 — lane-isolated
            bad[lane] = e
    return out, bad


class ServeEngine:
    """Multi-tenant front end over one RemoteRagCloud."""

    config: EngineConfig
    sessions: SessionManager
    cloud: protocol.RemoteRagCloud
    metrics: ServeMetrics

    def __init__(self, index: FlatIndex, *,
                 config: Optional[EngineConfig] = None,
                 sessions: Optional[SessionManager] = None,
                 clock=time.monotonic,
                 tracer: Optional[obs.Tracer] = None,
                 searcher=None,
                 request_ids: Optional[itertools.count] = None):
        self.config = EngineConfig() if config is None else config
        # `is None` (not truthiness): an empty SessionManager has len 0
        self.sessions = SessionManager() if sessions is None else sessions
        self.cloud = protocol.RemoteRagCloud(
            index, rlwe_params=self.sessions.rlwe_params,
            use_pallas=self.config.use_pallas,
            use_candidate_cache=self.config.use_candidate_cache,
            cache_config=self.config.cache_config)
        # pin the corpus at construction: every default-path search (and
        # the epoch stamp new sessions plan against) reads this frozen
        # snapshot, so a concurrent ingest advancing the index's epoch
        # never changes what this engine serves until `refresh_corpus`
        self.view = index.corpus_view()
        # an explicit tracer wins (tests inject one built on a fake
        # clock); otherwise EngineConfig.trace selects a real tracer on
        # *the engine's own clock* — queue-wait spans are computed from
        # t_enqueue, so tracer and engine must share one timeline
        if tracer is not None:
            self.tracer = tracer
        elif self.config.trace:
            self.tracer = obs.Tracer(capacity=self.config.trace_capacity,
                                     clock=clock)
        else:
            self.tracer = obs.NULL_TRACER
        self.metrics = ServeMetrics(
            window=self.config.metrics_window,
            tracer=self.tracer if self.tracer.enabled else None)
        self._clock = clock
        # ``request_ids`` lets a fleet share one id counter (the replica
        # router passes its own, so request ids are globally unique and
        # equal to single-engine ids in submit order — the differential
        # harness compares on them); ``searcher`` overrides the top-k'
        # candidate search (the router injects scatter-gather here, see
        # `_search_topk`).  Both default to the standalone behavior.
        self._ids = itertools.count() if request_ids is None else request_ids
        self._searcher = searcher
        self._batch_ids = itertools.count()
        # guards _queues/_refill/_shed_results: a router submits from the
        # client thread while each replica's step runs on its own worker
        self._qlock = threading.Lock()
        # per-group priority-classed FIFO queues keyed once at submit:
        # dispatch pops from a group head instead of rescanning/rewriting
        # one global list.  With every request in the default priority
        # class a GroupQueue is exactly the plain FIFO it replaced.
        self._queues: Dict[tuple, adm.GroupQueue] = {}
        # refill credits: group -> grant time of its last partial dispatch
        self._refill: Dict[tuple, float] = {}
        # admission tier (None = uncontrolled engine, zero new machinery)
        self.admission = (
            None if self.config.admission is None
            else adm.AdmissionController(self.config.admission, clock=clock))
        # shed results produced outside step() (queue-bound displacement
        # at submit time) wait here until the next step()/drain() returns
        # them — a displaced request is resolved, never dropped
        self._shed_results: List[ServeResult] = []
        # background quarantine retry lane (EngineConfig.retry_lane): one
        # worker thread, spawned lazily on the first poisoned lane.
        # Finished retries buffer in _retry_results (like _shed_results)
        # until the next step()/drain(); _retry_inflight counts submitted-
        # but-unfinished retries and _retry_cv (on _qlock) lets drain()
        # barrier on them — every request still gets exactly one result.
        self._retry_pool: Optional[ThreadPoolExecutor] = None
        self._retry_results: List[ServeResult] = []
        self._retry_inflight = 0
        self._retry_cv = threading.Condition(self._qlock)
        self._closed = False

    # -- session + queue ----------------------------------------------------

    def open_session(self, tenant: str, **session_kwargs) -> Session:
        # plans are stamped with the epoch of the corpus they were planned
        # against (see serve.session.PlanCache); callers may still pin an
        # explicit epoch for replay setups
        session_kwargs.setdefault("epoch", self.view.epoch)
        return self.sessions.open(tenant, **session_kwargs)

    def refresh_corpus(self, epoch: Optional[int] = None):
        """Advance (or pin) this engine's corpus view to ``epoch`` (default:
        the index's current epoch) after an ingest.  Sessions opened
        afterwards plan against — and are stamped with — the refreshed
        corpus; already-open sessions keep their plans (the corpus only
        grows, so an old plan's Theorem-1 bound stays valid for the rows
        it was planned over).  Call between batches: an engine mid-dispatch
        keeps scanning the view it started with."""
        self.view = self.cloud.index.corpus_view(epoch)
        return self.view

    def submit(self, tenant: str, embedding: np.ndarray,
               key: Optional[jax.Array] = None, *,
               priority: Optional[str] = None,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue one query for `tenant` (session must be open).  Returns a
        request id; results come back from step()/drain().

        ``key`` seeds the DistanceDP noise.  The default draws OS entropy —
        a predictable key (e.g. the request counter) would let the cloud
        replay the noise and strip the perturbation; pass an explicit key
        only for replay/parity setups.

        ``priority`` (one of `admission.PRIORITIES`, default from
        ``AdmissionConfig.default_priority``) and ``deadline_s`` (SLO
        budget from enqueue, default ``AdmissionConfig.default_deadline_s``)
        feed the admission tier.  Rejections are typed
        `admission.AdmissionError` subclasses — `UnknownTenant` (also a
        ``KeyError``), `InvalidEmbedding` (also a ``ValueError``),
        `RateLimited`, `QueueFull` — and a rejected request was never
        enqueued: no crypto ran and no request id was consumed.
        """
        if self._closed:
            raise RuntimeError("engine is closed; no further submissions")
        if tenant not in self.sessions:
            # a real error, not an assert: `python -O` strips asserts and a
            # missing session would then surface as an opaque KeyError deep
            # inside dispatch (or worse, silently mis-batch)
            raise adm.UnknownTenant(tenant)
        emb = np.asarray(embedding, np.float32)
        if emb.ndim != 1:
            # the group key below uses the last axis only, so a (1, n)
            # embedding would batch with (n,) requests and break the
            # batch-stack shapes mid-dispatch; reject it at the door
            raise adm.InvalidEmbedding(
                f"embedding must be 1-D, got shape {emb.shape}")
        ac = self.config.admission
        if priority is None:
            priority = (ac.default_priority if ac is not None
                        else "interactive")
        rank = adm.priority_rank(priority)
        if deadline_s is None and ac is not None:
            deadline_s = ac.default_deadline_s
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        now = self._clock()
        with self._qlock:
            if self.admission is not None:
                retry = self.admission.check_rate(tenant, now)
                if retry is not None:
                    self.metrics.record_shed(tenant, adm.SHED_RATE_LIMITED)
                    self.tracer.event("rate_limited", tenant=tenant,
                                      priority=priority)
                    raise adm.RateLimited(tenant, retry)
            bound = ac.max_queue if ac is not None else None
            if bound is not None:
                depth = sum(len(q) for q in self._queues.values())
                # displace the youngest request of the worst strictly
                # lower-priority class (it becomes a queue_full shed
                # result, returned by the next step/drain), else reject
                # the newcomer — counted drops either way, never silent
                if depth >= bound and not self._displace(rank, now):
                    self.metrics.record_shed(tenant, adm.SHED_QUEUE_FULL)
                    self.tracer.event("shed", reason=adm.SHED_QUEUE_FULL,
                                      tenant=tenant, priority=priority)
                    raise adm.QueueFull(tenant, depth, bound)
            if self.admission is not None:
                self.metrics.record_admitted(tenant)
            rid = next(self._ids)
            if key is None:
                key = jax.random.PRNGKey(secrets.randbits(63))
            sess = self.sessions.get(tenant)
            group = (sess.backend, emb.shape[-1], sess.plan.kprime)
            self._queues.setdefault(group, adm.GroupQueue()).append(
                ServeRequest(
                    request_id=rid, tenant=tenant, embedding=emb, key=key,
                    t_enqueue=now, group=group,
                    priority=priority, rank=rank, deadline_s=deadline_s))
        return rid

    def _displace(self, rank: int, now: float) -> bool:
        """Evict one queued request of a class strictly worse than `rank`
        to make room: the youngest request of the worst class present,
        resolved as a ``queue_full`` shed result.  False if every queued
        request is at least as good as the newcomer."""
        victim = None
        victim_key = None
        victim_rank = -1
        for key, q in self._queues.items():
            w = q.worst()
            if w is None:
                continue
            r, req = w
            if r <= rank:
                continue
            if (victim is None or r > victim_rank
                    or (r == victim_rank
                        and req.t_enqueue > victim.t_enqueue)):
                victim, victim_key, victim_rank = req, key, r
        if victim is None:
            return False
        q = self._queues[victim_key]
        q.remove(victim)
        if not q:
            del self._queues[victim_key]
            # an emptied group's refill credit dies with it — a credit
            # with no continuity to real queued work must never dispatch
            self._refill.pop(victim_key, None)
        self._shed_results.append(
            self._resolve_shed(victim, adm.SHED_QUEUE_FULL, now))
        return True

    def _resolve_shed(self, req: ServeRequest, reason: str,
                      now: float) -> ServeResult:
        """Turn a queued request into a typed shed result: counted in the
        metrics, surfaced as a trace event, never run through any crypto
        stage, and never recorded as dispatch/latency traffic."""
        self.metrics.record_shed(req.tenant, reason)
        self.tracer.event("shed", track=f"request-{req.request_id}",
                          request_id=req.request_id, tenant=req.tenant,
                          priority=req.priority, reason=reason)
        return ServeResult(
            request_id=req.request_id, tenant=req.tenant, docs=[],
            ids=np.empty(0, np.int64), transcript=None,
            latency_s=now - req.t_enqueue, batch_size=0,
            error=f"shed({reason})", shed_reason=reason)

    @property
    def pending(self) -> int:
        with self._qlock:
            return sum(len(q) for q in self._queues.values())

    def cache_stats(self) -> Optional[dict]:
        """LRU / gather counters of the sharded candidate cache (None for
        the dense cache, cold packing, or before the lazy build — this
        never triggers the build itself)."""
        cache = self.cloud.index.peek_candidate_cache(
            self.cloud.rlwe_params, self.cloud.cache_config)
        if isinstance(cache, rlwe.ShardedCandidateCache):
            return cache.stats()
        return None

    # -- telemetry ----------------------------------------------------------

    def trace_summary(self) -> Optional[dict]:
        """JSON-ready stage-level telemetry snapshot (span counts + per-
        stage histograms); None when tracing is disabled.  The same
        snapshot rides along in ``metrics.summary()["trace"]``."""
        return self.tracer.snapshot() if self.tracer.enabled else None

    def write_trace(self, path: str) -> int:
        """Write the span ring as a Chrome-trace (Perfetto-loadable) JSON
        timeline; returns the number of duration events written."""
        if not self.tracer.enabled:
            raise RuntimeError(
                "tracing is disabled; construct the engine with "
                "EngineConfig(trace=True) or pass tracer=")
        return obs.write_chrome_trace(
            path, self.tracer.spans(),
            stage_summary=self.tracer.stage_summary())

    # -- lifecycle ----------------------------------------------------------

    def close(self, *, shed_pending: bool = False) -> List[ServeResult]:
        """Drain the queues, then release engine-held background resources:
        the sharded candidate cache's admitter thread is stopped (pending
        admissions still complete; the index-memoized cache itself stays
        valid and restarts its worker lazily if another engine touches it).
        Idempotent; returns the final drain's results.  `submit` raises
        after close.

        ``shed_pending=True`` resolves still-queued requests as
        ``shutdown`` shed results instead of dispatching them (see
        `drain`) — the load-shedding shutdown for an engine going away
        under pressure."""
        if self._closed:
            return []
        out = self.drain(shed=shed_pending)
        self._closed = True
        if self._retry_pool is not None:   # idle after the drain barrier
            self._retry_pool.shutdown(wait=True)
            self._retry_pool = None
        cache = self.cloud.index.peek_candidate_cache(
            self.cloud.rlwe_params, self.cloud.cache_config)
        if isinstance(cache, rlwe.ShardedCandidateCache):
            cache.close()
        return out

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    # -- dispatch -----------------------------------------------------------

    def step(self, *, force: bool = False) -> List[ServeResult]:
        """Dispatch at most one batch if a trigger fired (or `force`).

        Among triggered groups the best-priority head wins, oldest first
        within a class — a group that keeps hitting the size trigger must
        not starve another group whose deadline expired, and under
        overload interactive heads pre-empt best-effort ones.  A group
        holding a *refill credit* (its previous batch dispatched under
        `max_batch` within the last `max_wait_s`) triggers immediately:
        continuous batching keeps occupancy up without making late
        arrivals age out a fresh deadline.

        With the admission tier enabled the step starts by resolving any
        pending shed work: queue-bound displacements buffered at submit
        time, then a deadline pass that sheds every queued request whose
        remaining budget is spent or below the group's observed p50
        dispatch latency — all *before* a batch is popped, so shed
        requests never reach any crypto stage."""
        now = self._clock()
        cfg = self.config
        # trigger selection and the batch pop happen under the queue lock
        # (a router submits concurrently from its client thread); the
        # dispatch itself — all the crypto — runs outside it
        with self._qlock:
            shed: List[ServeResult] = []
            if self._shed_results:
                shed, self._shed_results = self._shed_results, []
            if self._retry_results:     # finished background retries
                shed.extend(self._retry_results)
                self._retry_results = []
            if self.admission is not None and cfg.admission.shed_deadlines:
                shed.extend(self._shed_expired(now))
            if self._refill:           # credits live one batching window
                self._refill = {g: t for g, t in self._refill.items()
                                if now - t < cfg.max_wait_s}
            chosen = None
            chosen_key = None
            chosen_refill = False
            for key, group in self._queues.items():
                size_hit = len(group) >= cfg.max_batch
                head_t = group.oldest_enqueue()
                deadline_hit = (now - head_t) >= cfg.max_wait_s
                refill_hit = cfg.refill and key in self._refill
                if not (size_hit or deadline_hit or refill_hit or force):
                    continue
                # (head class rank, oldest enqueue): with every request in
                # the default class this is exactly the oldest-head-wins
                # order of the uncontrolled engine
                cand_key = (group.head_rank(), head_t)
                if chosen is None or cand_key < chosen_key:
                    chosen = key
                    chosen_key = cand_key
                    chosen_refill = refill_hit and not (
                        size_hit or deadline_hit or force)
            if chosen is None:
                return shed
            group = self._queues[chosen]
            batch = group.pop_batch(cfg.max_batch)
            if not group:
                del self._queues[chosen]
            self._refill.pop(chosen, None)       # credit consumed
            leftovers = chosen in self._queues   # burst tail still queued
        t_dispatch = self._clock()
        out = self._dispatch(batch)
        if self.admission is not None:
            # feed the per-group dispatch-latency histogram the deadline
            # shedding reads (p50, biased high by at most one log2 bucket)
            self.admission.observe_dispatch(
                chosen, self._clock() - t_dispatch)
        if chosen_refill and any(r.ok for r in out):
            # recorded post-dispatch like record_batch: an all-lanes
            # failure must not read as refill-served traffic
            self.metrics.record_refill(len(batch))
            self.tracer.event("refill", requests=len(batch))
        # only a deadline/size-triggered dispatch grants a credit — for a
        # partial batch (spare lanes for late arrivals) or a full one that
        # left a burst tail queued.  A refill dispatch must not re-grant
        # (the credit would self-renew and a group under steady light
        # traffic would never form a real batch again; a refill dispatch
        # with a leftover tail is impossible — the size trigger wins
        # there), and drain()'s forced flushes leave no credit behind.
        # Stamped *after* the dispatch returns: the crypto takes far
        # longer than a batching window, so a pre-dispatch stamp would
        # always be expired by the time the caller can step() again.
        if (cfg.refill and not chosen_refill and not force
                and (len(batch) < cfg.max_batch or leftovers)):
            with self._qlock:
                self._refill[chosen] = self._clock()
        return shed + out

    def _shed_expired(self, now: float) -> List[ServeResult]:
        """Deadline pass over every queue: resolve each request the
        controller deems unservable (budget expired, or remaining budget
        below the group's observed p50 dispatch wall) as a ``deadline``
        shed result.  A group emptied by shedding is removed *with its
        refill credit* — a leftover credit would otherwise let the next
        stray submit dispatch instantly as a phantom refill batch."""
        ctl = self.admission
        out: List[ServeResult] = []
        for key, q in list(self._queues.items()):
            expired = q.shed(lambda req: ctl.should_shed(req, now))
            for req in expired:
                out.append(self._resolve_shed(req, adm.SHED_DEADLINE, now))
            if not q:
                del self._queues[key]
                self._refill.pop(key, None)
        return out

    def drain(self, *, shed: bool = False) -> List[ServeResult]:
        """Flush the queue completely; results in request order.

        ``shed=False`` (default) dispatches everything batch by batch —
        the historical behavior.  ``shed=True`` resolves still-queued
        requests as ``shutdown`` shed results instead: an engine shutting
        down under load answers every queued request immediately and
        spends no further crypto on work nobody is waiting for.  Either
        way every submitted request gets exactly one result — buffered
        displacement sheds are flushed here too, even when the queues are
        already empty."""
        out: List[ServeResult] = []
        with self._qlock:
            if self._shed_results:
                out, self._shed_results = self._shed_results, []
            if shed:
                now = self._clock()
                for key, q in list(self._queues.items()):
                    for req in q:
                        out.append(
                            self._resolve_shed(req, adm.SHED_SHUTDOWN, now))
                self._queues.clear()
                self._refill.clear()
        while self.pending:
            out.extend(self.step(force=True))
        # retry-lane barrier: poisoned lanes handed to the background
        # retry lane during the flush above (or by earlier steps) must
        # resolve before drain returns — every submit gets one result
        with self._retry_cv:
            while self._retry_inflight:
                self._retry_cv.wait()
            if self._retry_results:
                out.extend(self._retry_results)
                self._retry_results = []
        return sorted(out, key=lambda r: r.request_id)

    def _dispatch(self, batch: Sequence[ServeRequest]) -> List[ServeResult]:
        """Run one batch through the protocol; never lose a request.

        Both paths attribute failures per lane: the sequential path is a
        lane loop, the batched path isolates inside `_run_batched`.  The
        batch is recorded in the metrics only if at least one lane
        completed in the dispatch — an all-lanes failure is a failed
        dispatch, and solo quarantine retries are never recorded as
        batches of their own (no phantom or duplicate batches)."""
        if not batch:           # defensive: shedding never pops, but an
            return []           # empty dispatch must stay a no-op
        poisoned: List[tuple] = []          # (request, its exception)
        bid = next(self._batch_ids)
        tr = self.tracer
        if tr.enabled:
            # queue wait is the interval the tenant already spent before
            # any stage ran: t_enqueue -> dispatch start, on the engine's
            # own clock (same one t_enqueue was stamped with)
            now = self._clock()
            for req in batch:
                tr.record("queue_wait", req.t_enqueue, now,
                          track=f"request-{req.request_id}",
                          request_id=req.request_id, batch_id=bid,
                          tenant=req.tenant)
        with tr.span("dispatch", batch_id=bid, batch_size=len(batch),
                     backend=batch[0].group[0]):
            if self.config.sequential:
                results, bad = _lane_stage(
                    lambda lane: self._run_one(batch[lane]),
                    range(len(batch)))
                poisoned = [(batch[lane], err)
                            for lane, err in bad.items()]
                results = [results[lane] for lane in sorted(results)]
            else:
                results, poisoned = self._run_batched(batch, bid)
        if results:
            # size = the dispatch slot, completed = the lanes that actually
            # finished in it — occupancy() reads the latter, so quarantined
            # lanes show up as lost occupancy instead of hiding behind a
            # full-looking batch
            self.metrics.record_batch(len(batch), completed=len(results))
        elif poisoned:
            self.metrics.record_dispatch_failure(len(batch))
        by_id = {r.request_id: r for r in batch}
        for res in results:
            self.metrics.record(res.tenant, latency_s=res.latency_s,
                                batch_size=res.batch_size,
                                transcript=res.transcript,
                                deadline_s=by_id[res.request_id].deadline_s)
            extra = by_id[res.request_id].encryptions - 1
            if extra > 0:       # contract: healthy lanes encrypt once
                self.metrics.record_healthy_reencryptions(extra)
        if poisoned:
            results = results + self._quarantine(poisoned, len(batch))
        return results

    def _quarantine(self, poisoned: Sequence[tuple],
                    batch_size: int) -> List[ServeResult]:
        """Quarantine tail of `_dispatch` (``poisoned`` is (request,
        exception) pairs — each lane carries *its own* attributed failure):
        every poisoned lane is isolated from its batchmates and retried
        solo on the sequential path (`EngineConfig.max_retries` attempts,
        latency still measured from the original submit), then returned as
        an error result.  Healthy lanes are untouched — no re-encryption,
        no re-dispatch, no double-counted metrics.

        With `EngineConfig.retry_lane` (the default) the solo retries are
        handed to the background retry lane instead of running here on the
        dispatch thread — this call then returns nothing and the lane's
        result surfaces from a later step()/drain() (which barriers on
        retry completion), so a faulty lane's retry wall stops costing its
        next healthy batch's p99."""
        out: List[ServeResult] = []
        self.metrics.record_quarantined(len(poisoned))
        tr = self.tracer
        for req, err in poisoned:
            tr.event("quarantine", track=f"request-{req.request_id}",
                     request_id=req.request_id, tenant=req.tenant,
                     error_type=type(err).__name__)
            if self.config.retry_lane:
                self._retry_submit(req, err, batch_size)
            else:
                out.append(self._retry_solo(req, err, batch_size))
        return out

    def _retry_solo(self, req: ServeRequest, err: Exception,
                    batch_size: int) -> ServeResult:
        """One quarantined lane's solo retries: sequential-path attempts
        until one completes or `max_retries` is spent, then an error
        result.  Runs on the dispatch thread (retry_lane=False) or the
        retry-lane worker — the metrics are internally locked and the
        sequential path takes the tenant's session lock, so both homes are
        safe."""
        tr = self.tracer
        res = None
        while req.retries < self.config.max_retries:
            req.retries += 1
            self.metrics.record_retries(1)
            try:
                with tr.span("retry", track=f"request-{req.request_id}",
                             request_id=req.request_id,
                             tenant=req.tenant, attempt=req.retries):
                    res = self._run_one(req)
            except Exception as e:  # noqa: BLE001 — retry keeps its err
                err = e
                continue
            res.quarantined = True
            self.metrics.record_quarantined_retry_ok(req.tenant)
            # recorded exactly once, here (the failed batched attempt
            # recorded nothing for this lane)
            self.metrics.record(req.tenant, latency_s=res.latency_s,
                                batch_size=res.batch_size,
                                transcript=res.transcript,
                                deadline_s=req.deadline_s)
            break
        if res is None:
            self.metrics.record_error(req.tenant)
            res = ServeResult(
                request_id=req.request_id, tenant=req.tenant, docs=[],
                ids=np.empty(0, np.int64), transcript=None,
                latency_s=self._clock() - req.t_enqueue,
                batch_size=batch_size, error=repr(err), quarantined=True)
        return res

    def _retry_submit(self, req: ServeRequest, err: Exception,
                      batch_size: int) -> None:
        """Hand one poisoned lane to the background retry lane (spawned
        lazily here — an engine that never quarantines never starts the
        thread).  The inflight count is raised *before* the submit so a
        drain() racing this dispatch already sees the retry coming."""
        with self._qlock:
            if self._retry_pool is None:
                self._retry_pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="retry-lane")
            self._retry_inflight += 1
        self._retry_pool.submit(self._retry_worker, req, err, batch_size)

    def _retry_worker(self, req: ServeRequest, err: Exception,
                      batch_size: int) -> None:
        try:
            res = self._retry_solo(req, err, batch_size)
        except BaseException as e:  # noqa: BLE001 — zero-loss contract
            # _retry_solo resolves protocol failures itself; this only
            # fires on harness-level faults, and the request still gets
            # exactly one (error) result
            res = ServeResult(
                request_id=req.request_id, tenant=req.tenant, docs=[],
                ids=np.empty(0, np.int64), transcript=None,
                latency_s=self._clock() - req.t_enqueue,
                batch_size=batch_size, error=repr(e), quarantined=True)
        with self._retry_cv:
            self._retry_results.append(res)
            self._retry_inflight -= 1
            self._retry_cv.notify_all()

    def _search_topk(self, perturbed: np.ndarray, kprime: int) -> np.ndarray:
        """Module 2a, cloud half: the (B, k') candidate-id block for a
        (B, n) block of perturbed embeddings.  The default scans this
        engine's whole index; a router injects a scatter-gather searcher
        (`searcher=` ctor arg) that fans the block out to every replica's
        corpus slice and merges — by contract bit-identical to the full
        scan, which the differential harness in tests/test_router.py pins.
        Must stay a pure function of (perturbed, kprime): `_bisect_lanes`
        re-runs arbitrary row subsets through it for fault attribution.
        The default scan reads the engine's pinned `CorpusView` (not the
        live index), so a concurrent ingest cannot shift candidate ids
        mid-epoch; with `EngineConfig.nprobe` set on an IVF-built corpus
        it routes through the clustered first stage instead."""
        if self._searcher is not None:
            return np.asarray(self._searcher(perturbed, kprime))
        return np.asarray(batching.topk_batch(
            self.view, perturbed, kprime,
            use_pallas=self.config.use_pallas,
            nprobe=self.config.nprobe).indices)

    # -- sequential comparison path ----------------------------------------

    def _run_one(self, req: ServeRequest) -> ServeResult:
        sess = self.sessions.get(req.tenant)
        req.encryptions += 1
        self.metrics.record_encryptions(1)
        with self.tracer.span("sequential",
                              track=f"request-{req.request_id}",
                              request_id=req.request_id,
                              tenant=req.tenant):
            # top-k' goes through this engine's searcher, not a whole-index
            # scan: under a router that is the per-slice scan + merge, so a
            # quarantined lane's solo retry stays bit-identical to the
            # scatter-gather path by construction.  The session lock keeps
            # the tenant's rng stream serialized against a concurrent
            # dispatch batch when this runs on the retry lane.
            with sess.lock:
                docs, ids, tr = protocol.run_remoterag(
                    sess.user, self.cloud, req.embedding, req.key,
                    topk_fn=self._search_topk)
                sess.num_requests += 1
        return ServeResult(request_id=req.request_id, tenant=req.tenant,
                           docs=docs, ids=ids, transcript=tr,
                           latency_s=self._clock() - req.t_enqueue,
                           batch_size=1)

    # -- batched protocol path ---------------------------------------------

    def _run_batched(self, batch: Sequence[ServeRequest],
                     bid: Optional[int] = None) -> tuple:
        """One batch through the staged batched protocol with lane-level
        fault isolation.  Returns ``(results, poisoned)`` where ``results``
        are the lanes that completed (in lane order) and ``poisoned`` is
        ``[(request, exception)]`` for the lanes a failure was attributed
        to.  A failure *outside* the attributable stages (batch assembly,
        the lazy candidate-cache build, prefetch) cannot be pinned to a
        lane, so the whole batch is returned as poisoned — every request
        still gets its quarantine retry and error accounting; nothing is
        ever lost to a propagating exception."""
        try:
            return self._run_batched_stages(batch, bid)
        except Exception as e:          # noqa: BLE001 — zero-loss contract
            return [], [(req, e) for req in batch]

    def _run_batched_stages(self, batch: Sequence[ServeRequest],
                            bid: Optional[int] = None) -> tuple:
        """Stage pipeline behind `_run_batched`.  Batched stages attribute
        failures by bisection (`_bisect_lanes`); naturally per-lane stages
        attribute directly (`_lane_stage`).  Surviving lanes are re-batched
        (compacted) after every stage and carry their already-computed
        state forward — a healthy lane's query is encrypted exactly once,
        whatever its batchmates do."""
        sessions = [self.sessions.get(r.tenant) for r in batch]
        users = [s.user for s in sessions]
        backend = users[0].backend
        impl = crypto_backends.get_backend(backend)
        kprime = users[0].plan.kprime
        params = self.sessions.rlwe_params
        use_pallas = self.config.use_pallas
        tr = self.tracer

        poisoned: List[tuple] = []
        alive = list(range(len(batch)))

        def drop(bad: dict) -> None:
            nonlocal alive
            if bad:
                for lane in sorted(bad):
                    poisoned.append((batch[lane], bad[lane]))
                alive = [lane for lane in alive if lane not in bad]

        # module 1: vmapped DistanceDP over per-request keys / per-tenant
        # eps.  vmap guarantees lane b == perturb(keys[b], E[b], eps[b]),
        # so a bisected re-run of any lane subset is bit-identical.
        E = np.stack([r.embedding for r in batch])
        with tr.span("perturb", batch_id=bid, lanes=len(alive)):
            pert, bad = _bisect_lanes(
                lambda ls: list(batching.perturb_batch(
                    [batch[lane].key for lane in ls], E[list(ls)],
                    [users[lane].plan.eps for lane in ls])),
                alive, tracer=tr, batch_id=bid, stage="perturb")
        drop(bad)
        if not alive:
            return [], poisoned

        # module 2a, cloud half first: one top-k' kernel call for all
        # surviving lanes.  Running it before the host-side encryption
        # surfaces the candidate ids early so sharded-cache shard
        # admissions can be prefetched — the background H2D copy then
        # overlaps the RLWE encrypt work below (the ROADMAP's async-overlap
        # item, applied to data movement).  Bit-identity is unaffected:
        # top-k' consumes only the perturbed embeddings, never the tenants'
        # rng streams (which also makes its bisected re-runs exact).
        with tr.span("topk", batch_id=bid, lanes=len(alive),
                     kprime=kprime):
            cand, bad = _bisect_lanes(
                lambda ls: list(self._search_topk(
                    np.stack([pert[lane] for lane in ls]), kprime)),
                alive, tracer=tr, batch_id=bid, stage="topk")
        drop(bad)
        if not alive:
            return [], poisoned
        cache = impl.cache_view(self.cloud)
        if isinstance(cache, rlwe.ShardedCandidateCache):
            # stamp the trace context every dispatch: the cache is index-
            # memoized and may be shared across engines, so each dispatch
            # (re)binds its own tracer, and admissions this batch enqueues
            # are parented to it even when the admitter thread completes
            # them later
            cache.set_trace_context(tr, bid)
            try:
                cache.prefetch(np.stack([cand[lane] for lane in alive]))
            except Exception:   # noqa: BLE001 — prefetch is best-effort
                # a pure admission hint: gather streams from the host pool
                # without it, so a prefetch fault must not poison a batch
                # whose crypto path is fine
                pass

        # module 2a, user half: encrypt queries (host, submission order so
        # each tenant's rng stream matches the sequential path).  Naturally
        # per-lane — a raising lane is attributed directly, and healthy
        # lanes keep their ciphertexts (they are never encrypted again).
        def encrypt(lane: int):
            req = batch[lane]
            req.encryptions += 1
            self.metrics.record_encryptions(1)
            with tr.span("encrypt", track=f"request-{req.request_id}",
                         request_id=req.request_id, batch_id=bid,
                         tenant=req.tenant, lane=lane):
                with sessions[lane].lock:   # rng draw vs. the retry lane
                    return users[lane].encrypt_query(req.embedding)

        enc, bad = _lane_stage(encrypt, alive)
        drop(bad)
        if not alive:
            return [], poisoned
        wire = {lane: protocol.Request(perturbed=pert[lane], kprime=kprime,
                                       enc_query=enc[lane], backend=backend)
                for lane in alive}

        # module 2a, cloud half continued: one batched encrypted re-rank
        # over the surviving lanes, through the crypto-backend seam (the
        # RLWE impl hits the index's NTT-domain candidate cache; Paillier
        # runs the RNS-vectorized kernels with per-lane object fallback).
        # The stage is a pure function of the already-encrypted queries,
        # so bisection re-runs scoring, never encryption.  The clean path
        # keeps the whole-batch score object alive so decryption can take
        # the stacked fast path (no per-lane restack); per-lane views are
        # still handed out for the wire Reply objects and for bisected
        # fallbacks.
        full_stack: List[object] = []

        def score(ls):
            stack = impl.score_candidates(
                cloud=self.cloud, users=[users[lane] for lane in ls],
                enc=[enc[lane] for lane in ls],
                cand_ids=np.stack([cand[lane] for lane in ls]),
                kprime=kprime, params=params, cache=cache,
                use_pallas=use_pallas)
            if len(ls) == len(alive):     # full-set call succeeded
                full_stack.append(stack)
            return stack.lanes()

        with tr.span("score", batch_id=bid, lanes=len(alive),
                     kprime=kprime, backend=backend):
            cts, bad = _bisect_lanes(score, alive, tracer=tr,
                                     batch_id=bid, stage="score")
        if bad:
            full_stack.clear()            # stack no longer matches alive
        drop(bad)
        if not alive:
            return [], poisoned

        # back on the users: batched decryption (per-tenant keys) + sort —
        # again pure in the ciphertexts, so bisection is re-decryption only
        def decrypt(ls):
            stacked = (full_stack[0]
                       if full_stack and len(ls) == len(alive)
                       else [cts[lane] for lane in ls])
            return impl.decrypt_scores([users[lane].sk for lane in ls],
                                       stacked, use_pallas=use_pallas)

        with tr.span("decrypt", batch_id=bid, lanes=len(alive)):
            scores, bad = _bisect_lanes(decrypt, alive, tracer=tr,
                                        batch_id=bid, stage="decrypt")
        drop(bad)

        # module 2b/2c + accounting, per lane (direct attribution)
        def finish(lane: int) -> ServeResult:
            user = users[lane]
            req = batch[lane]
            reply = protocol.Reply(candidate_ids=cand[lane],
                                   enc_scores=cts[lane])
            with tr.span("finish", track=f"request-{req.request_id}",
                         request_id=req.request_id, batch_id=bid,
                         tenant=req.tenant, lane=lane):
                with sessions[lane].lock:   # OT draws rng, see Session.lock
                    positions = user.positions_from_scores(
                        scores[lane], len(reply.candidate_ids))
                    docs, ids, transcript = protocol.finish_request(
                        user, self.cloud, wire[lane], reply, positions)
                    sessions[lane].num_requests += 1
            return ServeResult(
                request_id=req.request_id,
                tenant=req.tenant, docs=docs, ids=ids,
                transcript=transcript,
                latency_s=self._clock() - req.t_enqueue,
                batch_size=len(batch))

        done, bad = _lane_stage(finish, alive)
        drop(bad)
        return [done[lane] for lane in alive], poisoned


__all__ = ["EngineConfig", "ServeRequest", "ServeResult", "ServeEngine"]
