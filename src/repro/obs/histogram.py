"""Fixed-bucket latency histograms for per-stage duration profiles.

One `StageHistogram` per stage name turns "p99 of the whole pipeline" into
"p99 of each stage".  Buckets are fixed at construction (log2-spaced from
1 µs to ~2 minutes), so recording is O(log #buckets) with zero allocation,
the memory footprint is constant however many samples arrive, and two
histograms from different processes can be merged bucket-by-bucket.

Percentiles are bucket upper-edge estimates: the reported pXX is the
smallest bucket edge whose cumulative count covers XX% of the samples —
an upper bound that is exact to within one bucket (a factor of 2 here).
Exact min/max/total are tracked alongside, so the mean is exact.
"""

from __future__ import annotations

import bisect
import math
from typing import Dict, List, Optional, Sequence

# Bucket upper edges in seconds: 1us, 2us, 4us, ... 2^27us (~134s).  A
# final implicit overflow bucket catches anything slower.
_EDGES: Sequence[float] = tuple(1e-6 * (1 << i) for i in range(28))


class StageHistogram:
    """Bounded-memory duration histogram with fixed log2 buckets."""

    __slots__ = ("counts", "count", "total_s", "min_s", "max_s")

    def __init__(self) -> None:
        self.counts: List[int] = [0] * (len(_EDGES) + 1)
        self.count = 0
        self.total_s = 0.0
        self.min_s = math.inf
        self.max_s = 0.0

    @staticmethod
    def edges() -> Sequence[float]:
        return _EDGES

    def record(self, duration_s: float) -> None:
        d = max(float(duration_s), 0.0)
        self.counts[bisect.bisect_left(_EDGES, d)] += 1
        self.count += 1
        self.total_s += d
        if d < self.min_s:
            self.min_s = d
        if d > self.max_s:
            self.max_s = d

    def merge(self, other: "StageHistogram") -> None:
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.count += other.count
        self.total_s += other.total_s
        self.min_s = min(self.min_s, other.min_s)
        self.max_s = max(self.max_s, other.max_s)

    def percentile(self, q: float) -> float:
        """Bucket upper-edge estimate of the q-th percentile (q in 0..100).
        NaN on an empty histogram (never an opaque error)."""
        if not self.count:
            return math.nan
        target = math.ceil(self.count * q / 100.0)
        target = min(max(target, 1), self.count)
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                # overflow bucket has no upper edge: report the exact max
                return _EDGES[i] if i < len(_EDGES) else self.max_s
        return self.max_s            # unreachable: counts sum to count

    def summary(self) -> dict:
        if not self.count:
            return {"count": 0}
        return {
            "count": self.count,
            "total_s": round(self.total_s, 6),
            "mean_s": round(self.total_s / self.count, 6),
            "min_s": round(self.min_s, 6),
            "max_s": round(self.max_s, 6),
            "p50_s": round(self.percentile(50), 6),
            "p90_s": round(self.percentile(90), 6),
            "p99_s": round(self.percentile(99), 6),
        }


def summarize(histograms: Dict[str, StageHistogram],
              names: Optional[Sequence[str]] = None) -> dict:
    """{stage: summary} for the given stages (default: all, sorted)."""
    keys = sorted(histograms) if names is None else names
    return {k: histograms[k].summary() for k in keys if k in histograms}


__all__ = ["StageHistogram", "summarize"]
