"""repro.serve.admission: typed submit rejections, token buckets, bounded
queue with priority displacement, deadline-aware shedding before any
crypto, refill-credit interaction, shutdown shedding, metrics + trace
accounting.  The default config (admission=None) stays on the historical
path — tests/test_serve.py covers that side."""

import numpy as np
import pytest

import jax

from repro.crypto import rlwe
from repro.data import synth
from repro.retrieval.index import FlatIndex
from repro.serve import (
    AdmissionConfig,
    AdmissionError,
    EngineConfig,
    InvalidEmbedding,
    QueueFull,
    RateLimited,
    ServeEngine,
    UnknownTenant,
)
from repro.serve import admission as adm
from repro.serve.session import SessionManager

N_DOCS, DIM, K = 1500, 64, 4
TENANTS = ("alice", "bob", "carol")
PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    emb = synth.uniform_corpus(rng, N_DOCS, DIM)
    docs = [f"passage-{i}".encode() for i in range(N_DOCS)]
    index = FlatIndex.build(emb, documents=docs)
    queries = synth.queries_near_corpus(rng, emb, 8)
    return index, emb, queries


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _build(index, *, admission, max_batch=4, clock=None, **config_kw):
    kw = {"clock": clock} if clock is not None else {}
    eng = ServeEngine(
        index,
        config=EngineConfig(max_batch=max_batch, max_wait_s=30.0,
                            admission=admission, **config_kw),
        sessions=SessionManager(rlwe_params=PARAMS,
                                deterministic_seeds=True), **kw)
    for t in TENANTS:
        eng.open_session(t, n=DIM, N=N_DOCS, k=K, radius=0.05,
                         backend="rlwe")
    return eng


# -- typed rejection hierarchy ----------------------------------------------

def test_typed_errors_subclass_legacy_types(corpus):
    """UnknownTenant/InvalidEmbedding stay catchable as KeyError/ValueError
    (the pre-admission contract) *and* as one AdmissionError base."""
    index, _, queries = corpus
    eng = _build(index, admission=None)
    assert issubclass(UnknownTenant, (AdmissionError, KeyError))
    assert issubclass(InvalidEmbedding, (AdmissionError, ValueError))
    assert issubclass(QueueFull, AdmissionError)
    assert issubclass(RateLimited, AdmissionError)
    with pytest.raises(AdmissionError, match="nobody"):
        eng.submit("nobody", queries[0])
    with pytest.raises(AdmissionError, match="1-D"):
        eng.submit("alice", queries[0].reshape(1, -1))
    # never enqueued: no request id consumed, nothing queued
    assert eng.pending == 0
    assert eng.submit("alice", queries[0]) == 0
    with pytest.raises(ValueError, match="deadline_s"):
        eng.submit("alice", queries[1], deadline_s=0.0)
    with pytest.raises(ValueError, match="priority"):
        eng.submit("alice", queries[1], priority="vip")
    eng.close(shed_pending=True)


def test_admission_config_validation():
    with pytest.raises(ValueError, match="tenant_rate"):
        AdmissionConfig(tenant_rate=-1.0)
    with pytest.raises(ValueError, match="max_queue"):
        AdmissionConfig(max_queue=0)
    with pytest.raises(ValueError, match="priority"):
        AdmissionConfig(default_priority="urgent")


# -- token buckets -----------------------------------------------------------

def test_rate_limited_token_bucket(corpus):
    index, _, queries = corpus
    clock = FakeClock()
    eng = _build(index, clock=clock,
                 admission=AdmissionConfig(tenant_rate=1.0, tenant_burst=2.0,
                                           tenant_rates={"carol": 0.0}))
    eng.submit("alice", queries[0])
    eng.submit("alice", queries[1])          # burst of 2 spent
    with pytest.raises(RateLimited) as exc:
        eng.submit("alice", queries[2])
    assert exc.value.retry_after_s == pytest.approx(1.0)
    # buckets are per tenant: bob still has his burst
    eng.submit("bob", queries[3])
    # per-tenant override: carol's rate 0 blocks her outright
    with pytest.raises(RateLimited) as exc:
        eng.submit("carol", queries[4])
    assert exc.value.retry_after_s == float("inf")
    # refill is continuous on the engine clock
    clock.t = 1.0
    eng.submit("alice", queries[2])
    m = eng.metrics
    assert m.admitted_requests == 4
    assert m.shed_by_reason == {"rate_limited": 2}
    assert m.tenants["alice"].admitted == 3
    assert m.tenants["alice"].shed == 1
    assert m.tenants["carol"].shed == 1
    # rejected submissions were never queued
    assert eng.pending == 4
    eng.close(shed_pending=True)


# -- bounded queue + priority displacement ----------------------------------

def test_queue_full_displaces_lower_priority(corpus):
    index, _, queries = corpus
    clock = FakeClock()
    eng = _build(index, clock=clock,
                 admission=AdmissionConfig(max_queue=2))
    r0 = eng.submit("alice", queries[0], priority="best_effort")
    r1 = eng.submit("bob", queries[1], priority="best_effort")
    # same class at the bound: rejected, nothing displaced
    with pytest.raises(QueueFull, match="max_queue=2"):
        eng.submit("carol", queries[2], priority="best_effort")
    assert eng.pending == 2
    # better class displaces the *youngest* worst-class request (r1)
    r2 = eng.submit("carol", queries[2], priority="interactive")
    assert eng.pending == 2
    # interactive at the bound with only interactive/best_effort queued:
    # still displaces the remaining best_effort (r0)
    r3 = eng.submit("carol", queries[3], priority="interactive")
    assert eng.pending == 2
    # all-interactive queue: a batch submit cannot displace anything
    with pytest.raises(QueueFull):
        eng.submit("alice", queries[4], priority="batch")
    shed = eng.close(shed_pending=True)
    by_id = {r.request_id: r for r in shed}
    assert by_id[r1].shed_reason == "queue_full"
    assert by_id[r0].shed_reason == "queue_full"
    assert by_id[r2].shed_reason == "shutdown"
    assert by_id[r3].shed_reason == "shutdown"
    assert all(not r.ok and r.error == f"shed({r.shed_reason})"
               for r in shed)
    m = eng.metrics
    # 2 displacements + 2 QueueFull rejections, all counted drops
    assert m.shed_by_reason == {"queue_full": 4, "shutdown": 2}
    # no crypto was ever spent on any of them
    assert m.lane_encryptions == 0
    assert m.num_batches == 0


# -- deadline-aware shedding -------------------------------------------------

def test_deadline_shed_before_crypto(corpus):
    """An expired request — or one whose remaining budget is below the
    group's observed p50 dispatch wall — is resolved as a deadline shed
    without touching any crypto stage."""
    index, _, queries = corpus
    clock = FakeClock()
    eng = _build(index, clock=clock, admission=AdmissionConfig())
    r0 = eng.submit("alice", queries[0], deadline_s=5.0)
    group = next(iter(eng._queues))
    # outright expiry
    clock.t = 6.0
    out = eng.step(force=True)
    assert [r.request_id for r in out] == [r0]
    assert out[0].shed_reason == "deadline"
    assert out[0].batch_size == 0 and out[0].transcript is None
    assert eng.metrics.lane_encryptions == 0
    assert eng.metrics.num_batches == 0
    assert eng.metrics.dispatch_lanes == 0
    # seed the dispatch estimate: observed p50 >> remaining budget
    eng.admission.observe_dispatch(group, 10.0)
    est = eng.admission.dispatch_estimate(group)
    assert est >= 10.0        # upper-edge bucket estimate, biased high
    r1 = eng.submit("bob", queries[1], deadline_s=5.0)  # remaining 5 < est
    out = eng.step(force=True)
    assert [r.request_id for r in out] == [r1]
    assert out[0].shed_reason == "deadline"
    assert eng.metrics.lane_encryptions == 0
    # no deadline -> never shed for deadline reasons
    r2 = eng.submit("carol", queries[2])
    out = eng.drain()
    assert [r.request_id for r in out] == [r2]
    assert out[0].ok and out[0].shed_reason is None
    assert eng.metrics.goodput_requests == 1
    eng.close()


def test_deadline_miss_accounting_without_admission(corpus):
    """deadline_s works with admission=None too: a completion past its
    budget is a counted deadline miss, not goodput (and nothing is shed —
    there is no shedding tier)."""
    index, _, queries = corpus
    eng = _build(index, admission=None)
    eng.submit("alice", queries[0], deadline_s=1e-6)
    eng.submit("bob", queries[1], deadline_s=60.0)
    out = eng.drain()
    assert all(r.ok for r in out)
    m = eng.metrics
    assert m.deadline_misses == 1
    assert m.goodput_requests == 1
    assert m.shed_requests == 0
    assert m.tenants["alice"].deadline_misses == 1
    summary = m.summary()
    assert summary["admission"]["deadline_misses"] == 1
    assert summary["admission"]["goodput_requests"] == 1
    assert summary["tenants"]["alice"]["deadline_misses"] == 1
    eng.close()


# -- refill-credit interaction (satellite: no phantom refill batches) --------

def test_shed_tail_drops_refill_credit(corpus):
    """A group emptied by deadline shedding must not keep the refill
    credit its earlier partial dispatch granted: a later submit inside
    the credit window must wait for a real trigger, not dispatch
    instantly as a phantom refill batch.  Shed requests never count as
    dispatch lanes or occupancy."""
    index, _, queries = corpus
    clock = FakeClock()
    eng = _build(index, clock=clock, max_batch=3,
                 admission=AdmissionConfig())
    eng.submit("alice", queries[0], key=jax.random.PRNGKey(0))
    eng.submit("bob", queries[1], key=jax.random.PRNGKey(1))
    clock.t = 31.0                     # age past max_wait_s=30
    out = eng.step()
    assert len(out) == 2 and all(r.ok for r in out)
    # partial batch (2 < max_batch=3) granted a refill credit
    assert eng._refill
    # a queued tail arrives, then expires before it can dispatch
    rid = eng.submit("carol", queries[2], deadline_s=0.5)
    clock.t = 32.0
    out = eng.step()
    assert [r.shed_reason for r in out] == ["deadline"]
    assert [r.request_id for r in out] == [rid]
    # the emptied group's credit died with it ...
    assert not eng._refill
    # ... so a fresh submit inside the old credit window does NOT ride a
    # phantom credit: no trigger fires (deadline is 30s away)
    eng.submit("alice", queries[3], deadline_s=60.0)
    assert eng.step() == []
    assert eng.metrics.refill_dispatches == 0
    # shed requests never appeared as dispatched lanes / occupancy
    assert eng.metrics.num_batches == 1
    assert eng.metrics.dispatch_lanes == 2
    assert eng.metrics.occupancy(3) == pytest.approx(2 / 3)
    out = eng.drain()
    assert len(out) == 1 and out[0].ok
    eng.close()


# -- shutdown shedding -------------------------------------------------------

def test_close_shed_pending_resolves_queue(corpus):
    index, _, queries = corpus
    eng = _build(index, admission=AdmissionConfig())
    rids = [eng.submit(TENANTS[i % 3], q) for i, q in enumerate(queries[:5])]
    shed = eng.close(shed_pending=True)
    assert [r.request_id for r in shed] == rids
    assert all(r.shed_reason == "shutdown" and not r.ok for r in shed)
    assert eng.pending == 0
    assert eng.metrics.lane_encryptions == 0
    assert eng.metrics.shed_by_reason == {"shutdown": 5}
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit("alice", queries[0])
    assert eng.close() == []          # idempotent


# -- priority-ordered dispatch ----------------------------------------------

def test_interactive_dispatches_before_best_effort(corpus):
    """Within a group, dispatch pops interactive lanes first even when
    best-effort requests are older — interactive degrades last."""
    index, _, queries = corpus
    clock = FakeClock()
    eng = _build(index, clock=clock, max_batch=2,
                 admission=AdmissionConfig())
    be = [eng.submit("alice", queries[0], priority="best_effort",
                     key=jax.random.PRNGKey(0)),
          eng.submit("bob", queries[1], priority="best_effort",
                     key=jax.random.PRNGKey(1))]
    ia = [eng.submit("carol", queries[2], priority="interactive",
                     key=jax.random.PRNGKey(2)),
          eng.submit("alice", queries[3], priority="interactive",
                     key=jax.random.PRNGKey(3))]
    first = eng.step(force=True)
    assert sorted(r.request_id for r in first) == ia
    second = eng.step(force=True)
    assert sorted(r.request_id for r in second) == be
    assert all(r.ok for r in first + second)
    eng.close()


# -- metrics + trace surfacing ----------------------------------------------

def test_shed_events_traced_and_redacted(corpus):
    """shed / rate_limited events land in trace_summary() with counted
    totals, and every span (shed events included) passes the whitelist
    scan — no query-derived payloads on the overload path."""
    from repro import obs

    index, _, queries = corpus
    clock = FakeClock()
    eng = _build(index, clock=clock, trace=True,
                 admission=AdmissionConfig(tenant_rate=1.0,
                                           tenant_burst=1.0))
    eng.submit("alice", queries[0], deadline_s=5.0,
               key=jax.random.PRNGKey(0))
    with pytest.raises(RateLimited):
        eng.submit("alice", queries[1])
    clock.t = 6.0
    out = eng.step(force=True)
    assert out[0].shed_reason == "deadline"
    snap = eng.trace_summary()
    assert snap["events"]["shed"] == 1
    assert snap["events"]["rate_limited"] == 1
    # summary rides the same snapshot
    assert eng.metrics.summary()["trace"]["events"]["shed"] == 1
    shed_spans = [s for s in eng.tracer.spans() if s.name == "shed"]
    assert shed_spans and shed_spans[0].attrs["reason"] == "deadline"
    assert shed_spans[0].attrs["priority"] == "interactive"
    for span in eng.tracer.spans():
        obs.validate_attrs(span.attrs)   # whitelist scan: must not raise
    eng.close()


def test_admission_summary_block(corpus):
    index, _, queries = corpus
    clock = FakeClock()
    eng = _build(index, clock=clock,
                 admission=AdmissionConfig(tenant_rate=100.0))
    eng.submit("alice", queries[0], key=jax.random.PRNGKey(0))
    clock.t = 31.0
    out = eng.step()
    assert len(out) == 1 and out[0].ok
    s = eng.metrics.summary()
    assert s["admission"] == {
        "admitted": 1, "shed": 0, "shed_by_reason": {},
        "deadline_misses": 0, "goodput_requests": 1}
    assert s["tenants"]["alice"]["admitted"] == 1
    # the dispatch fed the controller's per-group estimate
    assert eng.admission.summary()["dispatch_p50_s"]
    eng.close()


def test_default_config_summary_shape_unchanged(corpus):
    """admission=None + no deadlines: no admission block, no admission
    keys in tenant summaries — the historical summary shape, exactly."""
    index, _, queries = corpus
    eng = _build(index, admission=None)
    eng.submit("alice", queries[0], key=jax.random.PRNGKey(0))
    out = eng.drain()
    assert len(out) == 1 and out[0].ok and out[0].shed_reason is None
    s = eng.metrics.summary()
    assert "admission" not in s
    assert "admitted" not in s["tenants"]["alice"]
    assert "shed" not in s["tenants"]["alice"]
    assert eng.admission is None
    eng.close()
