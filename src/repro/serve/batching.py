"""Stacked-batch protocol primitives for the serving engine.

Every function here is the B-query generalization of an existing single-query
op, built so each lane is *bit-identical* to the unbatched call:

  * perturb_batch       jax.vmap of distancedp.perturb over per-request PRNG
                        keys (and per-tenant eps) — vmap semantics guarantee
                        lane b equals perturb(keys[b], E[b], eps[b]).
  * topk_batch          one score-top-k' kernel invocation with B resident
                        queries instead of B invocations with one.
  * pack_candidates_batch / encrypted_scores_batch / decrypt_scores_batch
                        the RLWE cloud/user crypto with a leading batch axis:
                        one NTT dispatch per prime for the whole batch.  All
                        ops are exact integer arithmetic, so lanes match the
                        sequential path exactly (including wire bytes).
  * encrypted_scores_cached_batch
                        the serving hot path: scores the k' candidates from
                        the index's NTT-domain candidate cache (monomial
                        rotate + fused Hadamard/accumulate) — no per-request
                        candidate packing or forward NTTs, bit-identical to
                        the cold pack+score pipeline.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import distancedp
from repro.crypto import backend as crypto_backend
from repro.crypto import paillier_vec
from repro.crypto import rlwe
from repro.retrieval.index import FlatIndex
from repro.retrieval.topk import SearchResult, cluster_topk, distributed_topk


# ---------------------------------------------------------------------------
# module 1: vmapped DistanceDP
# ---------------------------------------------------------------------------

@jax.jit
def _perturb_lanes(keys, E, epss):
    return jax.vmap(
        lambda key, e, eps: distancedp.perturb(key, e, eps).embedding
    )(keys, E, epss)


def perturb_batch(keys: Sequence[jax.Array], E: np.ndarray,
                  epss: Sequence[float]) -> np.ndarray:
    """(B,) PRNG keys + (B, n) embeddings + (B,) budgets -> (B, n) e'."""
    out = _perturb_lanes(jnp.stack(list(keys)),
                         jnp.asarray(E, jnp.float32),
                         jnp.asarray(np.asarray(epss), jnp.float32))
    return np.asarray(out)


# ---------------------------------------------------------------------------
# module 2a cloud: batched top-k' + batched encrypted re-rank
# ---------------------------------------------------------------------------

def topk_batch(index: FlatIndex, perturbed: np.ndarray, kprime: int,
               *, use_pallas=None, nprobe=None) -> SearchResult:
    """All B perturbed queries through the score-top-k kernel in one call.

    ``index`` may be a FlatIndex or an epoch-pinned `CorpusView` —
    `distributed_topk` only reads rows/mesh, so both duck-type.  With
    ``nprobe`` set (and an IVF-built corpus carrying a ``cluster_map``),
    the scan routes through `cluster_topk` instead: only the ``nprobe``
    nearest cluster slices per query are scanned.  ``nprobe=None`` keeps
    the exact flat scan."""
    q = jnp.asarray(perturbed, jnp.float32)
    if nprobe is not None and getattr(index, "cluster_map", None) is not None:
        return cluster_topk(index, q, kprime, nprobe=nprobe,
                            use_pallas=use_pallas)
    return distributed_topk(index, q, kprime, use_pallas=use_pallas)


# The batched re-rank crypto lives with the schemes (crypto/rlwe.py,
# crypto/paillier_vec.py) behind the crypto-backend seam
# (crypto/backend.py); the single-query ops there are defined as the B=1
# slices of the batch versions, so there is exactly one implementation of
# each.  Re-exported here because this module is the serve layer's
# batching surface — the engine's stage pipeline itself only talks to
# `get_backend(name)` and never branches on the scheme.
# `encrypted_scores_cached_batch` accepts the dense CandidateCache or the
# corpus-scale ShardedCandidateCache (batched lanes then gather only their
# k' candidates' rows from the shard pool instead of assuming a resident
# dense block).
pack_candidates_batch = rlwe.pack_candidates_batch
encrypted_scores_batch = rlwe.encrypted_scores_batch
encrypted_scores_batch_stacked = rlwe.encrypted_scores_batch_stacked
encrypted_scores_cached_batch = rlwe.encrypted_scores_cached_batch
decrypt_scores_batch = rlwe.decrypt_scores_batch
CandidateCacheConfig = rlwe.CandidateCacheConfig
ShardedCandidateCache = rlwe.ShardedCandidateCache
get_backend = crypto_backend.get_backend
UnknownBackend = crypto_backend.UnknownBackend
encrypted_scores_paillier_batch = paillier_vec.encrypted_scores_batch
decrypt_scores_paillier_batch = paillier_vec.decrypt_scores_batch


__all__ = ["perturb_batch", "topk_batch", "pack_candidates_batch",
           "encrypted_scores_batch", "encrypted_scores_batch_stacked",
           "encrypted_scores_cached_batch", "decrypt_scores_batch",
           "CandidateCacheConfig", "ShardedCandidateCache",
           "get_backend", "UnknownBackend",
           "encrypted_scores_paillier_batch",
           "decrypt_scores_paillier_batch"]
