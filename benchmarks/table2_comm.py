"""Paper Table 2: communication comparison (formulas + measured protocol).

Rows: symbolic beta/eta-unit costs at the paper's operating point, concrete
byte models for both crypto backends, and the wire bytes actually metered by
a live protocol round (request/reply/fetch transcripts).
"""

from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import FULL, emit, timeit
from repro.core import accounting as acc
from repro.core import protocol
from repro.data import synth
from repro.retrieval.index import FlatIndex


def run() -> None:
    n, N, k, kp = 768, 10 ** 5, 5, 160
    rows = {
        "table2/ignorant": acc.privacy_ignorant(n, k),
        "table2/conscious": acc.privacy_conscious(n, N),
        "table2/remoterag_direct": acc.remoterag_direct(n, k, kp),
        "table2/remoterag_ot": acc.remoterag_ot(n, kp),
    }
    for name, c in rows.items():
        emit(name, 0.0,
             f"rounds={c.rounds};numbers={c.numbers};docs={c.documents};"
             f"bytes@beta4_eta1024={c.bytes_total()}")

    emit("table2/rlwe_query_bytes", 0.0, str(acc.rlwe_query_bytes(n)))
    emit("table2/paillier_query_bytes", 0.0, str(acc.paillier_query_bytes(n)))
    emit("table2/rlwe_scores_bytes_k160", 0.0,
         str(acc.rlwe_scores_bytes(kp, n)))
    emit("table2/paillier_scores_bytes_k160", 0.0,
         str(acc.paillier_scores_bytes(kp)))

    # live metering (reduced N; wire formulas are N-independent for RemoteRAG)
    rng = np.random.default_rng(0)
    n_docs = 20_000 if FULL else 3_000
    emb = synth.uniform_corpus(rng, n_docs, 384)
    docs = [b"x" * 1024 for _ in range(n_docs)]
    index = FlatIndex.build(emb, documents=docs)
    user = protocol.RemoteRagUser(n=384, N=n_docs, k=5, radius=0.05,
                                  backend="rlwe", rng=rng)
    cloud = protocol.RemoteRagCloud(index, rlwe_params=user.rlwe_params)
    q = synth.queries_near_corpus(rng, emb, 1)[0]

    def round_trip():
        return protocol.run_remoterag(user, cloud, q, jax.random.PRNGKey(0))

    us = timeit(round_trip, repeat=1, warmup=1)
    _, _, tr = round_trip()
    emit("table2/measured_rlwe_request_bytes", us, str(tr.request_bytes))
    emit("table2/measured_rlwe_reply_bytes", us, str(tr.reply_bytes))
    emit("table2/measured_total_bytes", us, str(tr.total_bytes))
