"""Deterministic, seekable, shard-aware batch pipeline.

Requirements at scale: (1) each data-parallel shard reads disjoint data;
(2) any batch is reproducible from (seed, step) alone — checkpoint restart
replays exactly (see train/fault.ResumableRun); (3) no host state to lose.

Everything derives from counter-based RNG: batch(step) = f(seed, step), so
the pipeline is random-access rather than an iterator with hidden position.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LmSyntheticTask:
    """Token-prediction task over a synthetic markovian stream (real lowering
    path, deterministic, no corpus files)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        # block-markov stream: mixes uniform tokens with repeated motifs so
        # the LM loss actually decreases during smoke training
        b, s = self.global_batch, self.seq_len
        base = rng.integers(4, self.vocab, size=(b, s), dtype=np.int32)
        motif = rng.integers(4, self.vocab, size=(b, 8), dtype=np.int32)
        reps = np.tile(motif, (1, s // 8 + 1))[:, :s]
        mask = rng.random((b, s)) < 0.5
        tokens = np.where(mask, reps, base).astype(np.int32)
        targets = np.roll(tokens, -1, axis=1)
        return tokens, targets


@dataclasses.dataclass(frozen=True)
class ClickSyntheticTask:
    """CTR-style task for the recsys archs: clicks correlate with a sparse
    latent preference so AUC is learnable."""

    n_sparse: int
    vocab_per_field: int
    global_batch: int
    n_dense: int = 0
    seed: int = 0

    def batch(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        b = self.global_batch
        ids = rng.integers(0, self.vocab_per_field,
                           size=(b, self.n_sparse), dtype=np.int32)
        ids += np.arange(self.n_sparse, dtype=np.int32) * self.vocab_per_field
        logit = ((ids % 7 == 0).sum(-1) - self.n_sparse / 7.0) * 1.5
        labels = (rng.random(b) < 1 / (1 + np.exp(-logit))).astype(np.float32)
        if self.n_dense:
            dense = rng.normal(size=(b, self.n_dense)).astype(np.float32)
            return dense, ids, labels
        return ids, labels


def host_shard(array: np.ndarray, shard: int, num_shards: int) -> np.ndarray:
    """Row-slice a global batch for this host (multi-host data loading)."""
    per = array.shape[0] // num_shards
    return array[shard * per:(shard + 1) * per]


__all__ = ["LmSyntheticTask", "ClickSyntheticTask", "host_shard"]
