"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
GQA + 128k vocab [arXiv:2407.21783]."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="llama3-8b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256, d_head=128, rope_theta=500_000.0, tp=16)

REDUCED = TransformerConfig(
    name="llama3-8b-smoke", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=1024, d_head=32, dtype="float32", remat=False, kv_chunk=64)
