"""SLO-aware admission control for the serving engine.

The paper's headline number is a *per-query* retrieval SLO (0.67 s at 10^6
docs); a multi-tenant cloud service meets it only if overload is handled
*before* the expensive work runs.  Every request the engine accepts spends
DistanceDP perturbation, an RLWE query encryption, and a batched encrypted
re-rank — so a request that is going to miss its deadline anyway, or a
tenant bursting past its contract, must be rejected at the door (typed
backpressure) or shed from the queue (typed shed results), never silently
queued into a latency collapse.

Three mechanisms, all off by default (``EngineConfig(admission=None)`` is
bit-identical to the uncontrolled engine):

* **Per-tenant token buckets** (``tenant_rate`` / ``tenant_burst``,
  per-tenant overrides via ``tenant_rates``): `ServeEngine.submit` raises
  `RateLimited` — with a ``retry_after_s`` hint — before the request is
  enqueued.
* **A bounded global queue with counted drops** (``max_queue``, the same
  bounded-queue idiom as the shard admitter's admission queue): when the
  queue is full a new request either evicts a strictly lower-priority
  queued request (which is resolved as a shed result — never lost) or is
  rejected with `QueueFull`.
* **Deadline-aware shedding** (``default_deadline_s`` or per-request
  ``deadline_s``): at every batch-formation step, a queued request whose
  remaining budget cannot cover the group's *observed* p50 dispatch
  latency — measured by a per-group `repro.obs.StageHistogram`, the same
  bounded histogram the tracer uses — is resolved as a
  ``ServeResult(shed_reason="deadline")`` before any crypto runs.

Priority classes (`PRIORITIES`: interactive > batch > best_effort) order
both *eviction* (best-effort is displaced first) and *dispatch* (each
group's queue pops interactive lanes first), so interactive traffic
degrades last under overload.

`submit`'s precondition failures are part of the same typed hierarchy:
`UnknownTenant` (also a ``KeyError``) and `InvalidEmbedding` (also a
``ValueError``), so clients catch one `AdmissionError` base for every
admission-tier rejection.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, Iterator, List, Mapping, Optional, Tuple

from repro.obs import StageHistogram

# Priority classes, best first: eviction walks the tuple from the right,
# dispatch pops from the left — interactive degrades last either way.
PRIORITIES: Tuple[str, ...] = ("interactive", "batch", "best_effort")

# Typed shed reasons (`ServeResult.shed_reason` vocabulary)
SHED_DEADLINE = "deadline"        # remaining budget < observed p50 dispatch
SHED_QUEUE_FULL = "queue_full"    # bounded queue displaced/rejected it
SHED_RATE_LIMITED = "rate_limited"  # tenant token bucket was empty
SHED_SHUTDOWN = "shutdown"        # engine shut down with it still queued
SHED_REASONS = frozenset({SHED_DEADLINE, SHED_QUEUE_FULL,
                          SHED_RATE_LIMITED, SHED_SHUTDOWN})


def priority_rank(priority: str) -> int:
    """0 = degrades last.  Unknown classes are a caller bug, not a shed."""
    try:
        return PRIORITIES.index(priority)
    except ValueError:
        raise ValueError(
            f"unknown priority {priority!r}; must be one of {PRIORITIES}"
        ) from None


# ---------------------------------------------------------------------------
# typed rejection hierarchy
# ---------------------------------------------------------------------------

class AdmissionError(Exception):
    """Base of every typed `submit` rejection.  Nothing raising this has
    been enqueued — no crypto ran, no request id was assigned, and the
    client may retry (see `RateLimited.retry_after_s`) or downgrade."""


class UnknownTenant(AdmissionError, KeyError):
    """No open session for the tenant.  Subclasses ``KeyError`` so existing
    callers that caught the untyped rejection keep working."""

    def __init__(self, tenant: str):
        super().__init__(f"no open session for tenant {tenant!r}; call "
                         f"open_session first")
        self.tenant = tenant

    def __str__(self) -> str:         # KeyError would repr-quote the message
        return self.args[0]


class InvalidEmbedding(AdmissionError, ValueError):
    """Malformed query embedding (wrong rank).  Subclasses ``ValueError``
    so existing callers keep working."""


class QueueFull(AdmissionError):
    """The bounded global queue is full and no strictly lower-priority
    request could be displaced for this one."""

    def __init__(self, tenant: str, queued: int, bound: int):
        super().__init__(
            f"queue full ({queued} queued >= max_queue={bound}) and no "
            f"lower-priority request to displace for tenant {tenant!r}")
        self.tenant = tenant
        self.queued = queued
        self.bound = bound


class RateLimited(AdmissionError):
    """The tenant's token bucket is empty.  ``retry_after_s`` is the
    earliest time a single token will be available again."""

    def __init__(self, tenant: str, retry_after_s: float):
        super().__init__(f"tenant {tenant!r} is rate limited; retry in "
                         f"{retry_after_s:.3f}s")
        self.tenant = tenant
        self.retry_after_s = retry_after_s


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Request-tier admission knobs (``EngineConfig.admission``).

    Every field defaults to "off"; an engine built with ``admission=None``
    has no admission tier at all and behaves bit-identically to the
    uncontrolled engine.
    """
    # per-tenant token bucket: sustained requests/s (None = unlimited) and
    # bucket depth (None = max(1, tenant_rate)); tenant_rates overrides the
    # default rate for named tenants (0 = block the tenant entirely)
    tenant_rate: Optional[float] = None
    tenant_burst: Optional[float] = None
    tenant_rates: Optional[Mapping[str, float]] = None
    # bounded global queue across all groups (None = unbounded); a full
    # queue displaces strictly lower-priority work or rejects (QueueFull)
    max_queue: Optional[int] = None
    # deadline applied to requests that don't pass their own deadline_s
    # (None = no default; requests without a deadline are never shed for
    # deadline reasons and always count toward goodput)
    default_deadline_s: Optional[float] = None
    # deadline-aware shedding at batch formation: shed a queued request
    # whose remaining budget < the group's observed p50 dispatch latency
    shed_deadlines: bool = True
    # priority class given to submits that don't name one
    default_priority: str = "interactive"

    def __post_init__(self):
        if self.tenant_rate is not None and self.tenant_rate < 0:
            raise ValueError(f"tenant_rate must be >= 0, got "
                             f"{self.tenant_rate}")
        if self.tenant_burst is not None and self.tenant_burst <= 0:
            raise ValueError(f"tenant_burst must be > 0, got "
                             f"{self.tenant_burst}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if (self.default_deadline_s is not None
                and self.default_deadline_s <= 0):
            raise ValueError(f"default_deadline_s must be > 0, got "
                             f"{self.default_deadline_s}")
        priority_rank(self.default_priority)     # validate eagerly


# ---------------------------------------------------------------------------
# token bucket
# ---------------------------------------------------------------------------

class TokenBucket:
    """Classic token bucket on an injected monotonic clock (the engine's,
    so fake-clock tests and the deadline math share one timeline)."""

    __slots__ = ("rate", "burst", "tokens", "t_last")

    def __init__(self, rate: float, burst: float, now: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self.t_last = now

    def try_take(self, now: float) -> bool:
        self.tokens = min(self.burst,
                          self.tokens + (now - self.t_last) * self.rate)
        self.t_last = now
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def retry_after_s(self) -> float:
        """Seconds until one token is available (inf for a zero rate)."""
        if self.rate <= 0:
            return float("inf")
        return max(0.0, (1.0 - self.tokens) / self.rate)


# ---------------------------------------------------------------------------
# priority-classed group queue
# ---------------------------------------------------------------------------

class GroupQueue:
    """FIFO per priority class for one (backend, n, k') group.

    Dispatch pops in priority order (interactive first, FIFO within a
    class); triggers read the *oldest* head across classes so a waiting
    best-effort request still fires the deadline trigger.  With a single
    class in use this is exactly the plain FIFO deque it replaced.
    """

    __slots__ = ("_ranks",)

    def __init__(self) -> None:
        self._ranks: Tuple[Deque, ...] = tuple(
            collections.deque() for _ in PRIORITIES)

    def append(self, req) -> None:
        self._ranks[req.rank].append(req)

    def __len__(self) -> int:
        return sum(len(d) for d in self._ranks)

    def __bool__(self) -> bool:
        return any(self._ranks)

    def __iter__(self) -> Iterator:
        for d in self._ranks:
            yield from d

    def oldest_enqueue(self) -> float:
        """Enqueue time of the oldest queued request across all classes
        (the deadline-trigger clock must not starve low priorities)."""
        return min(d[0].t_enqueue for d in self._ranks if d)

    def head_rank(self) -> int:
        """Rank of the best-priority nonempty class (dispatch order)."""
        for rank, d in enumerate(self._ranks):
            if d:
                return rank
        raise IndexError("head_rank of empty GroupQueue")

    def pop_batch(self, n: int) -> List:
        """Pop up to ``n`` requests, priority order first, FIFO within."""
        out: List = []
        for d in self._ranks:
            while d and len(out) < n:
                out.append(d.popleft())
        return out

    def worst(self) -> Optional[Tuple[int, object]]:
        """(rank, request) of the *youngest request of the worst class*
        present — the displacement victim candidate — or None if empty."""
        for rank in range(len(self._ranks) - 1, -1, -1):
            if self._ranks[rank]:
                return rank, self._ranks[rank][-1]
        return None

    def remove(self, req) -> None:
        self._ranks[req.rank].remove(req)

    def shed(self, pred) -> List:
        """Remove and return every queued request matching ``pred``
        (FIFO order preserved for the survivors)."""
        out: List = []
        for rank, d in enumerate(self._ranks):
            if not d:
                continue
            keep = collections.deque()
            for req in d:
                (out if pred(req) else keep).append(req)
            self._ranks[rank].clear()
            self._ranks[rank].extend(keep)
        return out


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------

class AdmissionController:
    """Decision core behind `ServeEngine.submit`/`step` when
    ``EngineConfig.admission`` is set.  Owns the per-tenant token buckets
    and the per-group dispatch-latency histograms; the engine owns the
    queues and resolves the shed results."""

    def __init__(self, config: AdmissionConfig, *, clock) -> None:
        self.config = config
        self._clock = clock
        self._buckets: Dict[str, TokenBucket] = {}
        # per-(backend, n, k') dispatch-wall histograms — the same bounded
        # StageHistogram the tracer folds stage spans into, but always on
        # (shedding must work with tracing off)
        self._dispatch: Dict[tuple, StageHistogram] = {}

    # -- rate limiting ------------------------------------------------------

    def _rate_for(self, tenant: str) -> Optional[float]:
        overrides = self.config.tenant_rates
        if overrides is not None and tenant in overrides:
            return overrides[tenant]
        return self.config.tenant_rate

    def check_rate(self, tenant: str, now: float) -> Optional[float]:
        """None if admitted; otherwise the retry-after hint in seconds."""
        rate = self._rate_for(tenant)
        if rate is None:
            return None
        if rate <= 0:            # a zero rate blocks the tenant outright
            return float("inf")  # (no default burst token to spend)
        bucket = self._buckets.get(tenant)
        if bucket is None or bucket.rate != rate:
            burst = (self.config.tenant_burst
                     if self.config.tenant_burst is not None
                     else max(1.0, rate))
            bucket = self._buckets[tenant] = TokenBucket(rate, burst, now)
        if bucket.try_take(now):
            return None
        return bucket.retry_after_s()

    # -- deadline estimation -------------------------------------------------

    def observe_dispatch(self, group: tuple, duration_s: float) -> None:
        hist = self._dispatch.get(group)
        if hist is None:
            hist = self._dispatch[group] = StageHistogram()
        hist.record(duration_s)

    def dispatch_estimate(self, group: tuple) -> Optional[float]:
        """Observed p50 dispatch wall for the group (bucket upper-edge, so
        biased up to one log2 bucket high — shedding errs on the side of
        rejecting a doomed request early).  None before any dispatch."""
        hist = self._dispatch.get(group)
        if hist is None or not hist.count:
            return None
        return hist.percentile(50)

    def should_shed(self, req, now: float) -> bool:
        """Deadline-aware shed decision for one *queued* request: its
        remaining budget has expired outright, or cannot cover the group's
        observed p50 dispatch latency (no estimate -> optimistic: only
        outright expiry sheds)."""
        if req.deadline_s is None:
            return False
        remaining = req.t_enqueue + req.deadline_s - now
        if remaining <= 0.0:
            return True
        est = self.dispatch_estimate(req.group)
        return est is not None and remaining < est

    def summary(self) -> dict:
        """JSON-ready controller state (estimates only; the shed/admit
        counters live in `ServeMetrics`)."""
        return {
            "tenant_buckets": len(self._buckets),
            "dispatch_p50_s": {
                "/".join(map(str, g)): round(h.percentile(50), 6)
                for g, h in self._dispatch.items() if h.count},
        }


__all__ = [
    "PRIORITIES", "priority_rank",
    "SHED_DEADLINE", "SHED_QUEUE_FULL", "SHED_RATE_LIMITED",
    "SHED_SHUTDOWN", "SHED_REASONS",
    "AdmissionError", "UnknownTenant", "InvalidEmbedding", "QueueFull",
    "RateLimited",
    "AdmissionConfig", "TokenBucket", "GroupQueue", "AdmissionController",
]
