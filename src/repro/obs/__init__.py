"""repro.obs — privacy-safe observability for the serving engine.

`Tracer` records bounded per-request stage spans (see `repro.obs.trace`
for the redact-by-construction schema), `StageHistogram` keeps fixed-
bucket per-stage latency profiles, and `repro.obs.export` writes
Perfetto-loadable Chrome-trace timelines.  Tracing is off by default;
`NULL_TRACER` is the shared no-op sink.
"""

from repro.obs.histogram import StageHistogram, summarize
from repro.obs.trace import (ALLOWED_ATTR_KEYS, NULL_TRACER, NullTracer,
                             Span, Tracer, validate_attrs)
from repro.obs.export import (chrome_trace_events, load_chrome_trace,
                              write_chrome_trace)

__all__ = [
    "ALLOWED_ATTR_KEYS", "NULL_TRACER", "NullTracer", "Span", "Tracer",
    "StageHistogram", "summarize", "validate_attrs",
    "chrome_trace_events", "load_chrome_trace", "write_chrome_trace",
]
