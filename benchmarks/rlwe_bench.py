"""Encrypted re-rank hot path: cold per-request packing vs the NTT-domain
candidate cache, XLA fallback vs fused Pallas kernel, batch 1 / 8.

Beyond the usual CSV rows this writes machine-readable ``BENCH_rlwe.json``
(path override: BENCH_RLWE_JSON) so the perf trajectory is trackable across
PRs; ``scripts/check_bench_regression.py`` gates CI on cached > cold.
"""

from __future__ import annotations

import json
import os

import numpy as np

import jax

from benchmarks.common import FULL, emit, timeit
from repro.crypto import rlwe

OUT_PATH = os.environ.get("BENCH_RLWE_JSON", "BENCH_rlwe.json")


def _unit(rng, *shape):
    x = rng.normal(size=shape)
    return (x / np.linalg.norm(x, axis=-1, keepdims=True)).astype(np.float32)


def run() -> None:
    if FULL:
        params = rlwe.RlweParams()                    # N=4096, chunk=1024
        n_dim, num_docs, kprime = 3072, 20_000, 115   # paper Table 5 regime
    else:
        # n_dim=3072 (text-embedding-3-large, Table 5): 6 chunks per doc —
        # the regime where cold per-request packing + forward NTTs dominate
        params = rlwe.RlweParams(n_poly=1024, chunk=512)
        n_dim, num_docs, kprime = 3072, 512, 32
    rng = np.random.default_rng(0)
    docs = _unit(rng, num_docs, n_dim)
    sk = rlwe.keygen(params, rng)

    builds = []
    build_us = timeit(
        lambda: builds.append(rlwe.build_candidate_cache(params, docs)),
        repeat=1, warmup=0)
    cache = builds[0]
    emit("rlwe/cache_build", build_us,
         f"{cache.nbytes / 2**20:.1f}MiB/{num_docs}docs")

    results = {}
    for bsz in (1, 8):
        queries = _unit(rng, bsz, n_dim)
        q_cts = [rlwe.encrypt_query(sk, q, rng) for q in queries]
        ids = rng.integers(0, num_docs, size=(bsz, kprime))
        rows = docs[ids]

        def cold():
            packed = rlwe.pack_candidates_batch(params, rows)
            out = rlwe.encrypted_scores_batch_stacked(
                params, q_cts, packed, kprime, n_dim, use_pallas=False)
            jax.block_until_ready(out.c0)

        def cached():
            out = rlwe.encrypted_scores_cached_batch(
                params, q_cts, cache, ids, use_pallas=False)
            jax.block_until_ready(out.c0)

        def fused():
            out = rlwe.encrypted_scores_cached_batch(
                params, q_cts, cache, ids, use_pallas=True)
            jax.block_until_ready(out.c0)

        cold_us = timeit(cold, repeat=9, warmup=2)
        cached_us = timeit(cached, repeat=9, warmup=2)
        # interpret-mode Pallas off-TPU: correctness/overhead tracking only
        fused_us = timeit(fused, repeat=3)
        qps = bsz / (cached_us / 1e6)
        speedup = cold_us / cached_us
        emit(f"rlwe/score_cold_b{bsz}", cold_us, f"k'={kprime}")
        emit(f"rlwe/score_cached_b{bsz}", cached_us,
             f"{speedup:.1f}x_vs_cold")
        emit(f"rlwe/score_cached_fused_b{bsz}", fused_us,
             "interpret" if jax.default_backend() != "tpu" else "tpu")
        emit(f"rlwe/qps_cached_b{bsz}", cached_us, f"{qps:.1f}qps")
        results[f"batch{bsz}"] = {
            "cold_pack_us": cold_us,
            "cached_us": cached_us,
            "cached_fused_us": fused_us,
            "speedup_cached_vs_cold": speedup,
            "per_request_cold_us": cold_us / bsz,
            "per_request_cached_us": cached_us / bsz,
            "cached_qps": qps,
        }

    payload = {
        "bench": "rlwe_rerank",
        "backend": jax.default_backend(),
        "config": {"n_poly": params.n_poly, "num_primes": params.num_primes,
                   "chunk": params.chunk, "n_dim": n_dim,
                   "num_docs": num_docs, "kprime": kprime,
                   "cache_bytes": cache.nbytes,
                   "cache_build_us": build_us, "full": FULL},
        "results": results,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH}", flush=True)


if __name__ == "__main__":
    run()
