"""repro.serve engine: batched == sequential parity, micro-batch triggers,
plan cache, metrics accounting."""

import numpy as np
import pytest

import jax

from repro.crypto import rlwe
from repro.data import synth
from repro.retrieval.index import FlatIndex
from repro.serve import EngineConfig, ServeEngine
from repro.serve.session import PlanCache, SessionManager

N_DOCS, DIM, K = 1500, 64, 4
N_REQ = 8
TENANTS = ("alice", "bob", "carol")
# small ring keeps the CPU NTTs fast; semantics identical to the default
PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    emb = synth.uniform_corpus(rng, N_DOCS, DIM)
    docs = [f"passage-{i}".encode() for i in range(N_DOCS)]
    index = FlatIndex.build(emb, documents=docs)
    queries = synth.queries_near_corpus(rng, emb, N_REQ)
    return index, emb, queries


def _build(index, *, sequential, max_batch, clock=None):
    kw = {"clock": clock} if clock is not None else {}
    eng = ServeEngine(
        index,
        config=EngineConfig(max_batch=max_batch, max_wait_s=30.0,
                            sequential=sequential),
        sessions=SessionManager(rlwe_params=PARAMS,
                                deterministic_seeds=True), **kw)
    for t in TENANTS:
        eng.open_session(t, n=DIM, N=N_DOCS, k=K, radius=0.05,
                         backend="rlwe")
    return eng


def _run(index, queries, *, sequential, max_batch):
    eng = _build(index, sequential=sequential, max_batch=max_batch)
    for i, q in enumerate(queries):
        eng.submit(TENANTS[i % len(TENANTS)], q, key=jax.random.PRNGKey(i))
    return eng, eng.drain()


def test_batched_matches_sequential_across_batch_sizes(corpus):
    """Same docs / ids / wire bytes at batch sizes 1, 3, 8 as the sequential
    run_remoterag path — the batched crypto is bit-compatible."""
    index, emb, queries = corpus
    _, seq = _run(index, queries, sequential=True, max_batch=1)
    assert [r.batch_size for r in seq] == [1] * N_REQ
    for max_batch in (1, 3, 8):
        eng, got = _run(index, queries, sequential=False,
                        max_batch=max_batch)
        assert len(got) == N_REQ
        assert max(r.batch_size for r in got) == min(max_batch, N_REQ)
        for rs, rb in zip(seq, got):
            assert rs.request_id == rb.request_id
            assert rs.ids.tolist() == rb.ids.tolist()
            assert rs.docs == rb.docs
            assert (rs.transcript.total_bytes
                    == rb.transcript.total_bytes)
            assert (rs.transcript.request_bytes
                    == rb.transcript.request_bytes)
            assert rs.transcript.reply_bytes == rb.transcript.reply_bytes


def test_batched_results_match_plaintext_oracle(corpus):
    index, emb, queries = corpus
    _, got = _run(index, queries, sequential=False, max_batch=8)
    for res in got:
        q = queries[res.request_id]
        oracle = np.argsort(-(emb @ q), kind="stable")[:K]
        assert set(res.ids.tolist()) == set(oracle.tolist())
        assert res.docs == [f"passage-{i}".encode() for i in res.ids]


def test_plan_cache_hits_for_repeat_tenants():
    cache = PlanCache()
    mgr = SessionManager(rlwe_params=PARAMS, plan_cache=cache)
    a = mgr.open("a", n=DIM, N=N_DOCS, k=K, radius=0.05)
    assert (cache.hits, cache.misses) == (0, 1)
    b = mgr.open("b", n=DIM, N=N_DOCS, k=K, radius=0.05)
    assert (cache.hits, cache.misses) == (1, 1)
    assert a.plan is b.plan          # cached object reused, no re-planning
    assert a.user.sk is not b.user.sk  # but keys stay per-tenant
    mgr.open("c", n=DIM, N=N_DOCS, k=K, radius=0.09)
    assert cache.misses == 2         # different knobs -> new plan
    # re-opening an existing tenant with identical knobs is a no-op ...
    assert mgr.open("a", n=DIM, N=N_DOCS, k=K, radius=0.05) is a
    # ... but changing the knobs of a live session is an error
    with pytest.raises(ValueError, match="different knobs"):
        mgr.open("a", n=DIM, N=N_DOCS, k=K, radius=0.09)


def test_paillier_batched_matches_sequential(corpus):
    """The paillier backend batches the top-k' search (crypto stays
    per-lane); parity must hold there too, incl. deterministic keygen."""
    index, emb, queries = corpus

    def run(sequential):
        eng = ServeEngine(
            index,
            config=EngineConfig(max_batch=4, max_wait_s=30.0,
                                sequential=sequential),
            sessions=SessionManager(rlwe_params=PARAMS,
                                    deterministic_seeds=True))
        for t in TENANTS[:2]:
            eng.open_session(t, n=DIM, N=N_DOCS, k=K, radius=0.05,
                             backend="paillier", paillier_bits=256)
        for i in range(4):
            eng.submit(TENANTS[i % 2], queries[i], key=jax.random.PRNGKey(i))
        return eng.drain()

    seq, got = run(True), run(False)
    assert [r.batch_size for r in got] == [4] * 4
    for rs, rb in zip(seq, got):
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
        assert rs.transcript.total_bytes == rb.transcript.total_bytes


def test_size_and_deadline_triggers(corpus):
    index, _, queries = corpus
    now = [0.0]
    eng = _build(index, sequential=False, max_batch=3,
                 clock=lambda: now[0])
    eng.config = EngineConfig(max_batch=3, max_wait_s=5.0, sequential=False)
    eng.submit("alice", queries[0], key=jax.random.PRNGKey(0))
    eng.submit("bob", queries[1], key=jax.random.PRNGKey(1))
    assert eng.step() == []          # neither trigger fired
    assert eng.pending == 2
    eng.submit("carol", queries[2], key=jax.random.PRNGKey(2))
    out = eng.step()                 # size trigger: 3 == max_batch
    assert len(out) == 3 and eng.pending == 0
    eng.submit("alice", queries[3], key=jax.random.PRNGKey(3))
    assert eng.step() == []
    now[0] += 6.0                    # age past the deadline
    out = eng.step()
    assert len(out) == 1 and out[0].batch_size == 1


def test_metrics_accounting(corpus):
    index, _, queries = corpus
    eng, got = _run(index, queries, sequential=False, max_batch=8)
    summary = eng.metrics.summary()
    agg = summary["aggregate"]
    assert agg["count"] == N_REQ
    assert set(summary["tenants"]) == set(TENANTS)
    per_tenant = sum(s["count"] for s in summary["tenants"].values())
    assert per_tenant == N_REQ
    want_wire = sum(r.transcript.total_bytes for r in got)
    assert eng.metrics.aggregate.total_wire_bytes == want_wire
    assert agg["p99_latency_s"] >= agg["p50_latency_s"] >= 0
    assert "failures" not in summary         # clean run: no failure block


def test_submit_without_session_raises_keyerror(corpus):
    """A missing session is a real error, not an assert (`python -O`
    strips asserts, which would turn this into silent mis-batching)."""
    index, _, queries = corpus
    eng = _build(index, sequential=False, max_batch=2)
    with pytest.raises(KeyError, match="nobody"):
        eng.submit("nobody", queries[0])


class _FaultyFetch:
    """Fault-injecting cloud seam: `handle_fetch` raises the first
    ``fail_times`` calls, then delegates — the failure lands mid-dispatch,
    after the crypto, exactly where a lost batch would hurt most."""

    def __init__(self, cloud, fail_times):
        self.cloud = cloud
        self.remaining = fail_times
        self.calls = 0

    def __call__(self, cand_ids, msg):
        self.calls += 1
        if self.remaining:
            self.remaining -= 1
            raise RuntimeError("injected cloud fault")
        return type(self.cloud).handle_fetch(self.cloud, cand_ids, msg)


def test_failed_dispatch_loses_zero_requests(corpus):
    """A dispatch that raises re-enqueues its requests (one retry) and
    records no phantom batch; the retried dispatch returns every request
    with the same docs/ids the clean run produces."""
    index, _, queries = corpus
    _, want = _run(index, queries, sequential=False, max_batch=8)
    eng = _build(index, sequential=False, max_batch=8)
    eng.cloud.handle_fetch = _FaultyFetch(eng.cloud, fail_times=1)
    for i, q in enumerate(queries):
        eng.submit(TENANTS[i % len(TENANTS)], q, key=jax.random.PRNGKey(i))
    got = eng.drain()
    assert len(got) == N_REQ and all(r.ok for r in got)
    for rs, rb in zip(want, got):
        assert rs.request_id == rb.request_id
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
    # only the *completed* dispatch is recorded; the failure is accounted
    # separately and every popped request was retried, none lost
    assert eng.metrics.num_batches == 1
    assert list(eng.metrics.dispatch_sizes) == [N_REQ]
    assert eng.metrics.failed_dispatches == 1
    assert eng.metrics.retried_requests == N_REQ
    assert eng.metrics.error_results == 0 and eng.pending == 0


def test_dispatch_failure_after_retries_returns_error_results(corpus):
    """When the cloud keeps failing, drain() still terminates and hands
    every request back as an error result — zero requests lost, zero
    phantom batches recorded."""
    index, _, queries = corpus
    eng = _build(index, sequential=False, max_batch=3)
    eng.cloud.handle_fetch = _FaultyFetch(eng.cloud, fail_times=10**9)
    rids = [eng.submit(TENANTS[i], queries[i], key=jax.random.PRNGKey(i))
            for i in range(3)]
    got = eng.drain()
    assert [r.request_id for r in got] == rids
    assert all(not r.ok for r in got)
    assert all("injected cloud fault" in r.error for r in got)
    assert all(r.docs == [] and r.ids.size == 0 and r.transcript is None
               for r in got)
    assert eng.pending == 0
    assert eng.metrics.num_batches == 0      # no phantom batches
    assert eng.metrics.failed_dispatches == 2    # first try + one retry
    summary = eng.metrics.summary()
    assert summary["failures"]["error_results"] == 3
    assert eng.metrics.aggregate.errors == 3
    # error-only tenants have no latency samples — their summaries (and the
    # aggregate's) must degrade gracefully, not crash on an empty window
    assert summary["aggregate"] == {"count": 0, "errors": 3}
    for t in TENANTS:
        assert summary["tenants"][t] == {"count": 0, "errors": 1}
    # the engine stays healthy: un-fault the cloud and serve again
    eng.cloud.handle_fetch = _FaultyFetch(eng.cloud, fail_times=0)
    eng.submit(TENANTS[0], queries[0], key=jax.random.PRNGKey(0))
    ok = eng.drain()
    assert len(ok) == 1 and ok[0].ok


def test_sequential_dispatch_isolates_poisoned_lane(corpus):
    """On the sequential comparison path a single poisoned request must not
    sink its batchmates: healthy lanes complete, the poisoned one errors
    after its retry."""
    index, _, queries = corpus
    eng = _build(index, sequential=True, max_batch=3)
    # fail exactly the 2nd request and its retry: lane order is r0(1),
    # r1(2, fails), r2(3) — the loop continues past the failure — then the
    # re-enqueued r1 dispatches alone as call 4 and fails for good
    calls = [0]

    def poisoned(cand_ids, msg):
        calls[0] += 1
        if calls[0] in (2, 4):
            raise RuntimeError("poisoned lane")
        return type(eng.cloud).handle_fetch(eng.cloud, cand_ids, msg)
    eng.cloud.handle_fetch = poisoned
    for i in range(3):
        eng.submit(TENANTS[i], queries[i], key=jax.random.PRNGKey(i))
    got = eng.drain()
    assert len(got) == 3
    oks = [r for r in got if r.ok]
    bad = [r for r in got if not r.ok]
    assert len(oks) == 2 and len(bad) == 1
    assert "poisoned lane" in bad[0].error


def test_metrics_window_bounded():
    """Latency/batch samples are windowed (no unbounded growth under the
    million-user north star) while counts and byte totals stay exact."""
    from repro.core.protocol import ProtocolTranscript
    from repro.serve.metrics import ServeMetrics

    m = ServeMetrics(window=4)
    tr = ProtocolTranscript(plan=None, path="direct", request_bytes=10,
                            reply_bytes=5, fetch_bytes=1, docs_bytes=2,
                            ot_wire_bytes=0)
    for i in range(10):
        m.record("t", latency_s=float(i), batch_size=2, transcript=tr)
        m.record_batch(2)
    agg = m.aggregate
    assert agg.count == 10                       # exact total
    assert agg.total_wire_bytes == 10 * 18       # exact total
    assert len(agg.latencies_s) == 4             # bounded window
    assert list(agg.latencies_s) == [6.0, 7.0, 8.0, 9.0]
    assert agg.percentile(50) == 7.5             # over the window
    assert m.num_batches == 10 and len(m.dispatch_sizes) == 4
    assert m.summary()["aggregate"]["count"] == 10
    with pytest.raises(ValueError, match="window"):
        ServeMetrics(window=0).record("t", latency_s=0.0, batch_size=1,
                                      transcript=tr)
