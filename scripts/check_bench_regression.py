#!/usr/bin/env python3
"""CI gate on the encrypted re-rank perf trajectory.

Reads BENCH_rlwe.json (written by ``python -m benchmarks.run --only rlwe``)
and fails if

  * cached scoring is not faster than cold per-request packing at any
    recorded batch size, or
  * (when the corpus-scale section is present) sharded-gather scoring at
    batch 8 is more than ``max_sharded_ratio`` (default 1.3x) slower than
    dense-cache scoring, or the sharded layout's peak device footprint is
    not at least ``min_mem_reduction`` (default 4x) smaller than the dense
    cache, or
  * the single default-policy config (async, frequency-aware admission) is
    more than ``max_skewed_ratio`` (default 1.2x) slower than dense under
    skewed ids, or more than ``max_uniform_ratio`` (default 1.3x) slower
    under uniform ids, at batch 8 — the both-regimes guarantee: one config
    must never regress to synchronous-admission churn in either regime, or
  * (serve_faults section) lane-level fault isolation regressed: any
    healthy-lane re-encryption under a persistently poisoned lane (must be
    exactly 0), more/fewer error results than poisoned lanes, or batch
    occupancy under faults below ``min_occupancy_ratio`` (default 0.9) of
    the fault-free run, or
  * (stage_breakdown section) the repro.obs stage timeline stopped
    accounting for the dispatch it claims to explain: a core pipeline
    stage went missing from a traced serve stream, or the summed stage
    durations fall outside [0.5, 1.05] of the dispatch wall, or
  * (paillier_batch section — missing section = FAIL) the vectorized
    RNS-limb Paillier batch path is less than ``min_paillier_speedup``
    (default 3.0x) faster than the per-lane object path at batch 8, its
    scores were not bit-exact against the object path, or lanes silently
    fell back to objects at the benchmark key size, or
  * (ivf_routing section — missing section = FAIL) the clustered
    first-stage scan is less than ``min_ivf_speedup`` (default 2.0x)
    faster than the flat scan, recall@k' at the planner-derived
    ``nprobe`` is below 1.0, or the ``nprobe=all`` run was not
    bit-identical to the flat scan, or
  * (ingestion section — missing section = FAIL) a live tail-shard
    ingest lost or bit-drifted any in-flight request, the cache recorded
    no ingest, or the corpus epoch failed to advance.

With ``--serve-json BENCH_serve.json`` (written by
``python -m benchmarks.serve_bench``) it additionally gates the serving
engine itself: batch-8 occupancy must reach ``--min-serve-occupancy``
(default 0.8), batch-8 QPS must beat sequential by
``--min-serve-speedup`` (default 1.0x), and the closed-loop overload
section must prove admission control works: zero lost requests at every
offered-load point (offered == completed + shed), goodput at 2x the
saturation knee >= ``--min-goodput-ratio`` (default 0.8) of goodput at
the knee, interactive p99 under 2x overload within the recorded
p99_bound, the 2x point actually shedding, and the unlimited config
measurably collapsing where the admission config holds.

The serve JSON must also carry the scale-out ``replica_sweep`` section
(missing section = FAIL): per-query parity vs the 1-replica run
re-checked, merge overhead bounded, 2-replica QPS >= 1.3x the 1-replica
run on hosts with >= 2 CPUs (on a 1-CPU host thread parallelism is
physically unavailable, so the gate bounds router overhead at >= 0.8x
instead), 4-replica QPS >= 2.0x on hosts with >= 4 CPUs, and the
replica-failure fault point losing zero requests
(offered == returned; ledger submitted == completed +
quarantine-resolved).

The serve JSON must also carry the ``retry_lane`` section (missing
section = FAIL): with quarantine solo retries running on the background
retry lane, the healthy requests' p99 under transient faults must stay
within ``--max-retry-p99-ratio`` (default 1.5) of the fault-free run,
with zero lost requests and the retries actually exercised.

    scripts/check_bench_regression.py [BENCH_rlwe.json] [min_speedup=1.0]
        [max_sharded_ratio=1.3] [min_mem_reduction=4.0]
        [max_skewed_ratio=1.2] [max_uniform_ratio=1.3]
        [min_occupancy_ratio=0.9]
        [--serve-json BENCH_serve.json] [--min-serve-speedup 1.0]
        [--min-serve-occupancy 0.8]
"""

from __future__ import annotations

import argparse
import json
import sys


def _check_cached_vs_cold(results: dict, min_speedup: float) -> int:
    failures = 0
    checked = 0
    for name in sorted(results):
        if not name.startswith("batch"):
            continue
        checked += 1
        row = results[name]
        speedup = row.get("speedup_cached_vs_cold")
        if speedup is None or speedup < min_speedup:
            print(f"FAIL {name}: cached speedup {speedup} < {min_speedup} "
                  f"(cold {row.get('cold_pack_us')}us, "
                  f"cached {row.get('cached_us')}us)", file=sys.stderr)
            failures += 1
        else:
            print(f"ok   {name}: cached {speedup:.2f}x faster than cold "
                  f"({row.get('cached_us'):.0f}us vs "
                  f"{row.get('cold_pack_us'):.0f}us)")
    if not checked:      # a results-key rename must not silently pass CI
        print("FAIL: no batch* rows found — cached-vs-cold gate did not "
              "run", file=sys.stderr)
        failures += 1
    return failures


def _check_sharded(sharded: dict, max_ratio: float,
                   min_mem_reduction: float) -> int:
    row = sharded.get("batch8")
    if row is None:
        print("FAIL sharded: no batch8 row", file=sys.stderr)
        return 1
    failures = 0
    ratio = row.get("ratio_sharded_vs_dense")
    if ratio is None or ratio > max_ratio:
        print(f"FAIL sharded/batch8: sharded scoring {ratio}x dense "
              f"> {max_ratio}x "
              f"(dense {row.get('dense_us')}us, "
              f"sharded {row.get('sharded_us')}us)", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   sharded/batch8: sharded within {ratio:.2f}x of dense "
              f"({row.get('sharded_us'):.0f}us vs "
              f"{row.get('dense_us'):.0f}us at "
              f"{sharded.get('num_docs')} docs)")
    red = row.get("memory_reduction_vs_dense")
    if red is None or red < min_mem_reduction:
        print(f"FAIL sharded/batch8: peak memory reduction {red}x "
              f"< {min_mem_reduction}x "
              f"(dense {sharded.get('dense_cache_bytes')}B, "
              f"sharded peak {row.get('peak_sharded_bytes')}B)",
              file=sys.stderr)
        failures += 1
    else:
        print(f"ok   sharded/batch8: peak cache memory {red:.1f}x smaller "
              f"than dense "
              f"({row.get('peak_sharded_bytes') / 2**20:.0f}MiB vs "
              f"{sharded.get('dense_cache_bytes') / 2**20:.0f}MiB)")
    return failures


def _check_default_config(sharded: dict, max_skewed: float,
                          max_uniform: float) -> int:
    """Both-regimes gate for the ONE default-policy config: a sharded-cache
    JSON without this section fails (the gate must not silently pass after
    a results-key rename), as does either regime's batch-8 ratio."""
    section = sharded.get("default_config")
    if section is None:
        print("FAIL default_config: sharded results lack the both-regimes "
              "section — the one-config gate did not run", file=sys.stderr)
        return 1
    failures = 0
    for regime, bound in (("skewed", max_skewed), ("uniform", max_uniform)):
        row = section.get(regime, {})
        ratio = row.get("ratio_vs_dense_b8")
        if ratio is None or ratio > bound:
            print(f"FAIL default_config/{regime}: batch-8 scoring {ratio}x "
                  f"dense > {bound}x under the default admission policy "
                  f"(dense {row.get('dense_us')}us, "
                  f"adaptive {row.get('adaptive_us')}us) — async/"
                  f"frequency-aware admission has regressed to request-path "
                  f"churn", file=sys.stderr)
            failures += 1
        else:
            print(f"ok   default_config/{regime}: one-config batch-8 "
                  f"within {ratio:.2f}x of dense "
                  f"({row.get('adaptive_us'):.0f}us vs "
                  f"{row.get('dense_us'):.0f}us)")
    return failures


def _check_serve_faults(section: dict, min_occupancy_ratio: float) -> int:
    """Lane-isolation gate: under one persistently poisoned lane in a
    batch of 8, no healthy lane may be re-encrypted, exactly the poisoned
    lanes may error, and batch occupancy must stay within
    ``min_occupancy_ratio`` of the fault-free run.  A JSON without the
    section fails — the gate must not silently pass after a results-key
    rename."""
    if section is None:
        print("FAIL serve_faults: results lack the fault-injection section "
              "— the lane-isolation gate did not run", file=sys.stderr)
        return 1
    failures = 0
    reenc = section.get("healthy_lane_reencryptions")
    if reenc != 0:
        print(f"FAIL serve_faults: {reenc} healthy-lane re-encryptions "
              f"under faults (must be exactly 0 — quarantine is leaking "
              f"work back onto healthy lanes)", file=sys.stderr)
        failures += 1
    else:
        print("ok   serve_faults: 0 healthy-lane re-encryptions under a "
              "persistently poisoned lane")
    errors = section.get("error_results")
    poisoned = section.get("poisoned_lanes")
    if errors != poisoned:
        print(f"FAIL serve_faults: {errors} error results for {poisoned} "
              f"poisoned lanes (quarantine must error exactly the poisoned "
              f"lanes)", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   serve_faults: exactly {poisoned} error result(s) for "
              f"{poisoned} poisoned lane(s)")
    ratio = section.get("occupancy_ratio")
    if ratio is None or ratio < min_occupancy_ratio:
        print(f"FAIL serve_faults: batch occupancy under faults is {ratio}x "
              f"the fault-free run < {min_occupancy_ratio}x "
              f"(faulty {section.get('occupancy_faulty')}, fault-free "
              f"{section.get('occupancy_fault_free')})", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   serve_faults: occupancy {ratio:.2f}x of fault-free "
              f"({section.get('occupancy_faulty'):.3f} vs "
              f"{section.get('occupancy_fault_free'):.3f} at batch "
              f"{section.get('max_batch')})")
    return failures


def _check_stage_breakdown(section: dict, min_coverage: float = 0.5,
                           max_coverage: float = 1.05) -> int:
    """Observability gate: the traced serve stream must record every core
    pipeline stage, and the summed stage durations must reconcile with
    the dispatch wall they partition.  A JSON without the section fails —
    the gate must not silently pass after a results-key rename."""
    if section is None:
        print("FAIL stage_breakdown: results lack the traced stage-"
              "breakdown section — the observability gate did not run",
              file=sys.stderr)
        return 1
    failures = 0
    stages = section.get("stages", {})
    core = ("queue_wait", "dispatch", "perturb", "topk", "encrypt",
            "score", "decrypt", "finish")
    missing = [s for s in core
               if stages.get(s, {}).get("count", 0) <= 0]
    if missing:
        print(f"FAIL stage_breakdown: traced stream recorded no spans for "
              f"stage(s) {missing} — the timeline lost part of the "
              f"pipeline", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   stage_breakdown: all {len(core)} core stages present "
              f"({section.get('trace_spans')} spans, "
              f"{section.get('trace_dropped')} dropped)")
    coverage = section.get("stage_coverage")
    if coverage is None or not (min_coverage <= coverage <= max_coverage):
        print(f"FAIL stage_breakdown: stage durations cover {coverage}x of "
              f"the dispatch wall, outside [{min_coverage}, "
              f"{max_coverage}] — spans no longer reconcile with "
              f"end-to-end latency", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   stage_breakdown: stage durations cover "
              f"{coverage:.2f}x of the dispatch wall")
    return failures


def _check_paillier_batch(section: dict, min_speedup_b8: float = 3.0) -> int:
    """Vectorized-Paillier gate: the RNS limb-array batch path must beat
    the per-lane object path by ``min_speedup_b8``x at batch 8, the
    recorded scores must have decrypted bit-exact against the object
    path, and no lane may have silently fallen back to objects at the
    benchmark's key size.  A JSON without the section fails — the gate
    must not silently pass after a results-key rename."""
    if section is None:
        print("FAIL paillier_batch: results lack the vectorized-Paillier "
              "section — the batch-crypto gate did not run",
              file=sys.stderr)
        return 1
    failures = 0
    speedup = section.get("batch8", {}).get("speedup_vectorized_vs_object")
    if speedup is None or speedup < min_speedup_b8:
        print(f"FAIL paillier_batch: batch-8 vectorized scoring "
              f"{speedup}x the object path < {min_speedup_b8}x "
              f"(object {section.get('batch8', {}).get('object_ms')}ms, "
              f"vectorized "
              f"{section.get('batch8', {}).get('vectorized_ms')}ms)",
              file=sys.stderr)
        failures += 1
    else:
        b8 = section["batch8"]
        print(f"ok   paillier_batch: batch-8 vectorized {speedup:.2f}x "
              f"the object path ({b8.get('vectorized_ms'):.0f}ms vs "
              f"{b8.get('object_ms'):.0f}ms at kb="
              f"{section.get('key_bits')})")
    b1 = section.get("batch1", {})
    s1 = b1.get("speedup_vectorized_vs_object")
    if s1 is None:
        print("FAIL paillier_batch: no batch-1 row", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   paillier_batch: batch-1 vectorized {s1:.2f}x the "
              f"object path (recorded, ungated)")
    if not section.get("bit_exact"):
        print("FAIL paillier_batch: vectorized scores did not decrypt "
              "bit-exact against the object path", file=sys.stderr)
        failures += 1
    else:
        print("ok   paillier_batch: decrypted scores bit-exact vs the "
              "object path")
    fell_back = section.get("object_fallback_lanes", 0)
    if fell_back:
        print(f"FAIL paillier_batch: {fell_back} lane(s) silently fell "
              f"back to the object path at the benchmark key size",
              file=sys.stderr)
        failures += 1
    else:
        print("ok   paillier_batch: 0 object-path fallbacks at the "
              "benchmark key size")
    return failures


def _check_ivf_routing(section: dict, min_speedup: float = 2.0) -> int:
    """IVF first-stage routing gate: the routed scan must beat the flat
    scan by ``min_speedup``x at the bench corpus size, recall@k' at the
    planner-derived ``nprobe`` must be exactly 1.0 (the Theorem-1 bound
    covers the probed clusters), and the ``nprobe=all`` run must have
    been bit-identical to the flat scan — routing is a schedule change,
    never a scoring change.  A JSON without the section fails — the gate
    must not silently pass after a results-key rename."""
    if section is None:
        print("FAIL ivf_routing: results lack the IVF routing section — "
              "the first-stage routing gate did not run", file=sys.stderr)
        return 1
    failures = 0
    speedup = section.get("speedup_routed_vs_flat")
    if speedup is None or speedup < min_speedup:
        print(f"FAIL ivf_routing: routed scan {speedup}x the flat scan "
              f"< {min_speedup}x at {section.get('num_docs')} docs "
              f"(flat {section.get('flat_us')}us, routed "
              f"{section.get('routed_us')}us)", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   ivf_routing: routed scan {speedup:.2f}x the flat "
              f"scan at {section.get('num_docs')} docs "
              f"(nprobe={section.get('nprobe')})")
    recall = section.get("recall_at_kprime")
    if recall is None or recall < 1.0:
        print(f"FAIL ivf_routing: recall@k' {recall} < 1.0 at the "
              f"planner-derived nprobe={section.get('nprobe')} — the "
              f"probe bound no longer covers the planned search range",
              file=sys.stderr)
        failures += 1
    else:
        print(f"ok   ivf_routing: recall@k' == 1.0 at the planned "
              f"nprobe={section.get('nprobe')} "
              f"(k'={section.get('kprime')})")
    if not section.get("nprobe_all_bit_identical"):
        print("FAIL ivf_routing: nprobe=all was not bit-identical to the "
              "flat scan — the differential anchor broke",
              file=sys.stderr)
        failures += 1
    else:
        print("ok   ivf_routing: nprobe=all bit-identical to the flat "
              "scan")
    return failures


def _check_ingestion(section: dict) -> int:
    """Streaming-ingestion gate: a tail-shard ingest landing mid-stream
    must lose zero in-flight requests and bit-drift zero results (the
    serving engine stays pinned to its epoch-0 view), the sharded cache
    must have recorded the ingest, the corpus epoch must have advanced,
    and the ingested rows must have been reachable after
    ``refresh_corpus``.  A JSON without the section fails — the gate
    must not silently pass after a results-key rename."""
    if section is None:
        print("FAIL ingestion: results lack the streaming-ingestion "
              "section — the live tail-shard swap gate did not run",
              file=sys.stderr)
        return 1
    failures = 0
    lost = section.get("lost_requests")
    drift = section.get("bit_drift_requests")
    if lost != 0 or drift != 0:
        print(f"FAIL ingestion: live tail-shard swap lost {lost} and "
              f"bit-drifted {drift} of {section.get('requests')} "
              f"in-flight requests (both must be 0)", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   ingestion: {section.get('requests')} in-flight "
              f"requests across the swap, 0 lost, 0 bit-drifted")
    if section.get("cache_ingests", 0) < 1:
        print("FAIL ingestion: the sharded cache recorded no tail-shard "
              "ingest — the swap never reached the cache",
              file=sys.stderr)
        failures += 1
    elif section.get("epoch_after", 0) <= section.get("epoch_before", 0):
        print(f"FAIL ingestion: corpus epoch did not advance "
              f"({section.get('epoch_before')} -> "
              f"{section.get('epoch_after')})", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   ingestion: cache ingests="
              f"{section.get('cache_ingests')}, epoch "
              f"{section.get('epoch_before')} -> "
              f"{section.get('epoch_after')}")
    if not section.get("tail_reachable_after_refresh"):
        print("FAIL ingestion: ingested rows were not servable after "
              "refresh_corpus", file=sys.stderr)
        failures += 1
    else:
        print("ok   ingestion: ingested rows servable after "
              "refresh_corpus")
    return failures


def _check_overload(results: dict, min_goodput_ratio: float = 0.8) -> int:
    """Overload gate on the closed-loop offered-load sweep: admission
    control must keep goodput flat and interactive p99 bounded past the
    saturation knee, account for every offered request (zero lost), and
    beat the unlimited configuration it exists to replace.  A JSON
    without the section fails — the gate must not silently pass after a
    results-key rename."""
    section = results.get("overload")
    if section is None:
        print("FAIL overload: serve results lack the offered-load sweep "
              "section — the admission-control gate did not run",
              file=sys.stderr)
        return 1
    failures = 0
    points = section.get("points", {})
    for label in ("0.5x", "1x", "2x", "2x_unlimited"):
        point = points.get(label)
        if point is None:
            print(f"FAIL overload: missing point {label}", file=sys.stderr)
            failures += 1
            continue
        lost = point.get("lost")
        balanced = (point.get("offered")
                    == point.get("completed", 0) + point.get("shed", 0))
        if lost != 0 or not balanced:
            print(f"FAIL overload/{label}: {lost} lost requests, offered "
                  f"{point.get('offered')} != completed "
                  f"{point.get('completed')} + shed {point.get('shed')} — "
                  f"requests are being dropped silently", file=sys.stderr)
            failures += 1
        else:
            print(f"ok   overload/{label}: offered {point['offered']} == "
                  f"completed {point['completed']} + shed {point['shed']} "
                  f"(0 lost)")
    knee = points.get("1x", {})
    two_x = points.get("2x", {})
    unlimited = points.get("2x_unlimited", {})
    g1, g2 = knee.get("goodput_qps"), two_x.get("goodput_qps")
    if g1 is None or g2 is None or g2 < min_goodput_ratio * g1:
        print(f"FAIL overload: goodput at 2x saturation {g2} qps < "
              f"{min_goodput_ratio}x of the knee's {g1} qps — admission "
              f"control no longer holds goodput past the knee",
              file=sys.stderr)
        failures += 1
    else:
        print(f"ok   overload: goodput holds past the knee "
              f"({g2:.2f} qps at 2x vs {g1:.2f} qps at 1x, "
              f">= {min_goodput_ratio}x)")
    bound = section.get("p99_bound_s")
    p99 = two_x.get("p99_interactive_s")
    if bound is None or p99 is None or p99 > bound:
        print(f"FAIL overload: interactive p99 at 2x is {p99}s, above the "
              f"recorded bound {bound}s — interactive traffic is no "
              f"longer protected under overload", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   overload: interactive p99 {p99:.3f}s <= bound "
              f"{bound:.3f}s at 2x offered load")
    if two_x.get("shed", 0) <= 0:
        print("FAIL overload: the 2x point shed nothing — the sweep is "
              "not actually overloading the engine", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   overload: 2x point shed {two_x['shed']} requests "
              f"({two_x.get('shed_by_reason')})")
    # the point of the tier: at the same 2x offered load the unlimited
    # config must do measurably worse — lower goodput (queue-wait
    # latency eats the deadlines) or a blown p99
    g_unl = unlimited.get("goodput_qps")
    p99_unl = unlimited.get("p99_interactive_s")
    collapsed = ((g_unl is not None and g2 is not None and g_unl < g2)
                 or (p99_unl is not None and bound is not None
                     and p99_unl > bound))
    if not collapsed:
        print(f"FAIL overload: unlimited config did not collapse at 2x "
              f"(goodput {g_unl} vs admission {g2}, p99 {p99_unl}s vs "
              f"bound {bound}s) — the sweep no longer demonstrates the "
              f"admission win", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   overload: unlimited config collapses at 2x "
              f"(goodput {g_unl:.2f} vs {g2:.2f} qps, p99 "
              f"{p99_unl:.3f}s vs bound {bound:.3f}s)")
    return failures


def _check_replica_sweep(results: dict, min_scaling: float = 1.3,
                         max_overhead_ratio: float = 0.8,
                         max_merge_frac: float = 0.25,
                         min_scaling4: float = 2.0) -> int:
    """Scale-out gate on the replica sweep: the section must exist (a
    results-key rename must not silently drop the scale-out contract),
    the sweep must have re-checked per-query parity against the
    1-replica run, the merge must stay cheap, and the fault point must
    account for every request — zero lost.

    The QPS bound is physical: replica drains and slice scans run on
    separate worker threads, so on a host with >= 2 CPUs the 2-replica
    run must reach ``min_scaling``x the 1-replica QPS.  A 1-CPU host
    cannot parallelize threads at all — there the gate instead bounds
    the router's overhead (scatter + merge + ledger must not cost more
    than ``1 - max_overhead_ratio`` of single-engine throughput).  On a
    host with >= 4 CPUs the 4-replica point is armed too: it must reach
    ``min_scaling4``x the 1-replica QPS (four drains genuinely in
    flight, not just two)."""
    section = results.get("replica_sweep")
    if section is None:
        print("FAIL replica_sweep: serve results lack the replica-sweep "
              "section — the scale-out gate did not run", file=sys.stderr)
        return 1
    failures = 0
    if not section.get("parity_checked"):
        print("FAIL replica_sweep: per-query parity vs the 1-replica run "
              "was not checked", file=sys.stderr)
        failures += 1
    points = section.get("points", {})
    for label in ("1", "2", "4"):
        if label not in points:
            print(f"FAIL replica_sweep: missing point at {label} replicas",
                  file=sys.stderr)
            failures += 1
    if failures:
        return failures
    q1 = points["1"].get("qps")
    q2 = points["2"].get("qps")
    cpus = section.get("host_cpus")
    if q1 is None or q2 is None:
        print("FAIL replica_sweep: points lack qps", file=sys.stderr)
        failures += 1
    elif cpus is not None and cpus >= 2:
        if q2 < min_scaling * q1:
            print(f"FAIL replica_sweep: 2-replica qps {q2:.3f} < "
                  f"{min_scaling}x the 1-replica {q1:.3f} on a "
                  f"{cpus}-CPU host — scale-out is not scaling",
                  file=sys.stderr)
            failures += 1
        else:
            print(f"ok   replica_sweep: 2 replicas {q2 / q1:.2f}x the "
                  f"1-replica qps (>= {min_scaling}x, {cpus} CPUs)")
    else:
        # single-CPU host: thread parallelism is physically unavailable,
        # so gate the router's overhead instead of the scaling win
        if q2 < max_overhead_ratio * q1:
            print(f"FAIL replica_sweep: 2-replica qps {q2:.3f} < "
                  f"{max_overhead_ratio}x the 1-replica {q1:.3f} on a "
                  f"1-CPU host — router overhead regressed",
                  file=sys.stderr)
            failures += 1
        else:
            print(f"note replica_sweep: 1-CPU host ({cpus}) — the "
                  f"{min_scaling}x scaling gate needs >= 2 CPUs; gating "
                  f"overhead instead")
            print(f"ok   replica_sweep: 2 replicas {q2 / q1:.2f}x the "
                  f"1-replica qps (>= {max_overhead_ratio}x overhead "
                  f"bound)")
    q4 = points["4"].get("qps")
    if cpus is not None and cpus >= 4:
        if q1 is None or q4 is None or q4 < min_scaling4 * q1:
            print(f"FAIL replica_sweep: 4-replica qps {q4} < "
                  f"{min_scaling4}x the 1-replica {q1} on a "
                  f"{cpus}-CPU host — scale-out stops paying past 2 "
                  f"replicas", file=sys.stderr)
            failures += 1
        else:
            print(f"ok   replica_sweep: 4 replicas {q4 / q1:.2f}x the "
                  f"1-replica qps (>= {min_scaling4}x, {cpus} CPUs)")
    else:
        print(f"note replica_sweep: host has {cpus} CPU(s) — the "
              f"{min_scaling4}x 4-replica gate arms at >= 4 CPUs")
    merge_ok = True
    for label, point in sorted(points.items()):
        frac = point.get("merge_frac")
        if frac is None or frac > max_merge_frac:
            print(f"FAIL replica_sweep/{label}: merge overhead {frac} of "
                  f"wall > {max_merge_frac}", file=sys.stderr)
            failures += 1
            merge_ok = False
    if merge_ok:
        print(f"ok   replica_sweep: merge overhead <= {max_merge_frac} "
              f"of wall at every point")
    fault = section.get("fault")
    if fault is None:
        print("FAIL replica_sweep: no fault point — the zero-lost "
              "contract under replica failure is untested",
              file=sys.stderr)
        return failures + 1
    lost = fault.get("lost")
    returned = fault.get("returned")
    offered = fault.get("offered")
    resolved = fault.get("quarantine_resolved", 0)
    submitted = sum(fault.get("submitted", []))
    completed = sum(fault.get("completed", []))
    if (lost != 0 or returned != offered
            or submitted != completed + resolved):
        print(f"FAIL replica_sweep/fault: {lost} lost, returned "
              f"{returned} of {offered} offered, ledger "
              f"{submitted} != {completed} + {resolved} — requests are "
              f"being dropped silently under replica failure",
              file=sys.stderr)
        failures += 1
    else:
        print(f"ok   replica_sweep/fault: offered {offered} == returned "
              f"{returned}, ledger {submitted} == {completed} completed "
              f"+ {resolved} quarantine-resolved (0 lost)")
    if not fault.get("quarantines"):
        print("FAIL replica_sweep/fault: no quarantine recorded — the "
              "injected fault did not fire", file=sys.stderr)
        failures += 1
    return failures


def _check_retry_lane(section: dict, max_p99_ratio: float = 1.5) -> int:
    """Retry-lane gate on the serve JSON: with quarantine solo retries on
    the background lane, the healthy requests' p99 under transient faults
    must stay within ``max_p99_ratio`` of the fault-free run — retries
    must not stall the dispatch thread — with zero lost requests and the
    retries actually exercised.  A JSON without the section fails — the
    gate must not silently pass after a results-key rename."""
    if section is None:
        print("FAIL retry_lane: serve results lack the retry-lane "
              "section — the healthy-batch p99 gate did not run",
              file=sys.stderr)
        return 1
    failures = 0
    ratio = section.get("healthy_p99_ratio_vs_fault_free")
    if ratio is None or ratio > max_p99_ratio:
        print(f"FAIL retry_lane: healthy p99 under faults {ratio}x the "
              f"fault-free run > {max_p99_ratio}x "
              f"(lane {section.get('p99_healthy_retry_lane_s')}s vs "
              f"fault-free {section.get('p99_fault_free_s')}s) — "
              f"retries are stalling the dispatch thread",
              file=sys.stderr)
        failures += 1
    else:
        inline = section.get("healthy_p99_ratio_vs_inline")
        print(f"ok   retry_lane: healthy p99 {ratio:.2f}x fault-free "
              f"(<= {max_p99_ratio}x; {inline:.2f}x the inline-retry "
              f"pass, recorded ungated)")
    if section.get("lost_requests") != 0:
        print(f"FAIL retry_lane: {section.get('lost_requests')} requests "
              f"lost under transient faults", file=sys.stderr)
        failures += 1
    if section.get("retried_requests_lane", 0) < 1:
        print("FAIL retry_lane: no retries recorded — the fault "
              "injection did not exercise the lane", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   retry_lane: {section.get('retried_requests_lane')} "
              f"solo retries off the dispatch thread, 0 lost")
    return failures


def _check_serve(path: str, min_speedup: float,
                 min_occupancy: float, min_goodput_ratio: float,
                 max_retry_p99_ratio: float = 1.5) -> int:
    """Serving-engine gate on BENCH_serve.json: batch-8 fill and the
    batched-vs-sequential throughput win."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL: cannot read {path}: {e}", file=sys.stderr)
        return 1
    results = data.get("results", {})
    big = results.get("big_batch", 8)
    row = results.get(f"batch{big}")
    if row is None:
        print(f"FAIL serve: no batch{big} row in {path}", file=sys.stderr)
        return 1
    failures = 0
    speedup = row.get("speedup_vs_sequential")
    if speedup is None or speedup < min_speedup:
        print(f"FAIL serve/batch{big}: batched qps {speedup}x sequential "
              f"< {min_speedup}x (qps {row.get('qps')})", file=sys.stderr)
        failures += 1
    else:
        print(f"ok   serve/batch{big}: batched {speedup:.2f}x sequential "
              f"qps ({row.get('qps'):.3f} qps)")
    occ = row.get("occupancy")
    if occ is None or occ < min_occupancy:
        print(f"FAIL serve/batch{big}: occupancy {occ} < {min_occupancy} "
              f"(batching is dispatching underfilled slots)",
              file=sys.stderr)
        failures += 1
    else:
        print(f"ok   serve/batch{big}: occupancy {occ:.2f} "
              f"(>= {min_occupancy})")
    failures += _check_overload(results, min_goodput_ratio)
    failures += _check_replica_sweep(results)
    failures += _check_retry_lane(results.get("retry_lane"),
                                  max_retry_p99_ratio)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(
        description="CI gate on BENCH_rlwe.json (and optionally "
                    "BENCH_serve.json) perf/contract sections.")
    # positionals keep the historical argv layout working
    ap.add_argument("path", nargs="?", default="BENCH_rlwe.json")
    ap.add_argument("min_speedup", nargs="?", type=float, default=1.0)
    ap.add_argument("max_sharded_ratio", nargs="?", type=float, default=1.3)
    ap.add_argument("min_mem_reduction", nargs="?", type=float, default=4.0)
    ap.add_argument("max_skewed_ratio", nargs="?", type=float, default=1.2)
    ap.add_argument("max_uniform_ratio", nargs="?", type=float, default=1.3)
    ap.add_argument("min_occupancy_ratio", nargs="?", type=float,
                    default=0.9)
    ap.add_argument("--serve-json", default=None, metavar="PATH",
                    help="also gate BENCH_serve.json (serving-engine "
                         "occupancy + batched-vs-sequential QPS)")
    ap.add_argument("--min-serve-speedup", type=float, default=1.0)
    ap.add_argument("--min-serve-occupancy", type=float, default=0.8)
    ap.add_argument("--min-goodput-ratio", type=float, default=0.8,
                    help="overload gate: goodput at 2x saturation must be "
                         "at least this fraction of goodput at the knee")
    ap.add_argument("--min-paillier-speedup", type=float, default=3.0,
                    help="paillier_batch gate: vectorized RNS scoring at "
                         "batch 8 must beat the per-lane object path by "
                         "this factor")
    ap.add_argument("--min-ivf-speedup", type=float, default=2.0,
                    help="ivf_routing gate: the routed first-stage scan "
                         "must beat the flat scan by this factor")
    ap.add_argument("--max-retry-p99-ratio", type=float, default=1.5,
                    help="retry_lane gate: healthy-request p99 under "
                         "transient faults (background retry lane on) "
                         "must stay within this ratio of the fault-free "
                         "run")
    args = ap.parse_args()
    try:
        with open(args.path) as f:
            data = json.load(f)
    except (OSError, ValueError) as e:   # missing file or truncated JSON
        print(f"FAIL: cannot read {args.path}: {e}", file=sys.stderr)
        return 2
    results = data.get("results", {})
    if not results:
        print(f"FAIL: {args.path} has no results", file=sys.stderr)
        return 2
    failures = _check_cached_vs_cold(results, args.min_speedup)
    sharded = results.get("sharded")
    if sharded is not None:
        failures += _check_sharded(sharded, args.max_sharded_ratio,
                                   args.min_mem_reduction)
        failures += _check_default_config(sharded, args.max_skewed_ratio,
                                          args.max_uniform_ratio)
    else:
        print("note: no sharded section in results (pre-sharded-cache "
              "JSON); skipping the sharded gates")
    failures += _check_serve_faults(results.get("serve_faults"),
                                    args.min_occupancy_ratio)
    failures += _check_stage_breakdown(results.get("stage_breakdown"))
    failures += _check_paillier_batch(results.get("paillier_batch"),
                                      args.min_paillier_speedup)
    failures += _check_ivf_routing(results.get("ivf_routing"),
                                   args.min_ivf_speedup)
    failures += _check_ingestion(results.get("ingestion"))
    if args.serve_json is not None:
        failures += _check_serve(args.serve_json, args.min_serve_speedup,
                                 args.min_serve_occupancy,
                                 args.min_goodput_ratio,
                                 args.max_retry_p99_ratio)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
