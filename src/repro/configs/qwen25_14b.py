"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias [hf:Qwen/Qwen2.5-14B]. 40 heads are not divisible by
the 16-way TP axis -> heads pad to 48 and KV MHA-izes (DESIGN.md)."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab=152064, d_head=128, qkv_bias=True,
    rope_theta=1_000_000.0, tp=16)

REDUCED = TransformerConfig(
    name="qwen2.5-14b-smoke", n_layers=2, d_model=320, n_heads=10,
    n_kv_heads=2, d_ff=640, vocab=1024, d_head=32, qkv_bias=True,
    dtype="float32", remat=False, kv_chunk=64)
