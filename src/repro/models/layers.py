"""Shared transformer building blocks (pure JAX, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; every constructor has an
    ``abstract=True`` mode returning jax.ShapeDtypeStruct (dry-run: no
    allocation).
  * attention is GQA with optional qk-norm / qkv-bias; KV heads are
    *replicated* and Q heads zero-padded up to the tensor-parallel degree when
    needed (the Megatron GQA-TP trick) — controlled by the config, so the
    single-device smoke tests run the unpadded math.
  * training attention uses an online-softmax scan over KV chunks (flash
    structure in pure JAX) so the (S, S) score matrix never materializes.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def make_param(key, shape, dtype, scale, abstract: bool):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def make_zeros(shape, dtype, abstract: bool):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def make_ones(shape, dtype, abstract: bool):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_freqs(d_head: int, theta: float = 500_000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 500_000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      kv_chunk: int = 1024, kv_len: Optional[jax.Array] = None):
    """Online-softmax attention, O(S) memory in KV length.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (decode: Skv_cached).
    ``kv_len``: optional dynamic valid-length mask for cache decoding.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    scale = 1.0 / math.sqrt(d)

    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, hkv, d)
    vc = v.reshape(b, n_chunks, kv_chunk, hkv, d)

    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m_prev, l_prev, o_prev = carry
        kb, vb, c_idx = inp
        # scores: (B, Sq, Hkv, G, C)
        s = jnp.einsum("bqhgd,bchd->bqhgc", qg.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        kv_pos = c_idx * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((sq, kv_chunk), bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        mask &= (kv_pos[None, :] < (kv_len if kv_len is not None else skv))
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhgc,bchd->bqhgd", p, vb.astype(jnp.float32))
        o_new = o_prev * corr[..., None] + pv
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, sq, hkv, group), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, group), jnp.float32)
    o0 = jnp.zeros((b, sq, hkv, group, d), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        step, (m0, l0, o0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def direct_attention(q, k, v, *, q_offset=0, kv_len=None, causal=True):
    """Unchunked attention for decode (q_len small, KV possibly huge).

    Reductions over the KV sequence are plain einsum/softmax reductions, so a
    sequence-sharded cache lowers to flash-decoding-style split-K partial
    reductions + small all-reduces under GSPMD (long_500k relies on this).
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bshd->bqhgs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(d)
    kv_pos = jnp.arange(skv)
    q_pos = q_offset + jnp.arange(sq)
    mask = kv_pos[None, :] < (kv_len if kv_len is not None else skv)
    if causal:
        mask = mask & (q_pos[:, None] >= kv_pos[None, :])
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgs,bshd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    # tensor-parallel padding (see module docstring); 1 = no padding
    tp_pad_to: int = 1

    @property
    def padded_heads(self) -> int:
        return -(-self.n_heads // self.tp_pad_to) * self.tp_pad_to

    @property
    def padded_kv_heads(self) -> int:
        """KV heads after TP padding.

        If no q-padding was needed and the rounded-up KV count divides the q
        count, consecutive replication (the Megatron GQA-TP trick) preserves
        the q->kv grouping.  Otherwise padding q heads changes the grouping
        arithmetic and we MHA-ize (one kv head per padded q head) — more KV
        FLOPs/cache, but exact; qwen2.5-14b (40 q) and granite (24 q) hit
        this on the 16-way mesh (see DESIGN.md).
        """
        if self.tp_pad_to == 1:
            return self.n_kv_heads
        cand = max(self.n_kv_heads, self.tp_pad_to)
        cand = -(-cand // self.tp_pad_to) * self.tp_pad_to
        if self.padded_heads == self.n_heads and self.padded_heads % cand == 0:
            return cand
        return self.padded_heads

    def kv_head_source(self):
        """Source original-kv-head index for each padded kv head (for
        checkpoint import and equivalence tests)."""
        import numpy as np

        group = self.n_heads // self.n_kv_heads
        pk = self.padded_kv_heads
        if pk == self.padded_heads:  # MHA-ized
            j = np.minimum(np.arange(pk), self.n_heads - 1)
            return j // group
        rep = pk // self.n_kv_heads
        return np.arange(pk) // rep


def attention_params(key, spec: AttentionSpec, dtype, abstract: bool):
    hq, hkv, d = spec.padded_heads, spec.padded_kv_heads, spec.d_head
    scale = 1.0 / math.sqrt(spec.d_model)
    ks = jax.random.split(key, 4) if not abstract else [None] * 4
    p = {
        "wq": make_param(ks[0], (spec.d_model, hq * d), dtype, scale, abstract),
        "wk": make_param(ks[1], (spec.d_model, hkv * d), dtype, scale, abstract),
        "wv": make_param(ks[2], (spec.d_model, hkv * d), dtype, scale, abstract),
        "wo": make_param(ks[3], (hq * d, spec.d_model), dtype, scale, abstract),
    }
    if spec.qkv_bias:
        p["bq"] = make_zeros((hq * d,), dtype, abstract)
        p["bk"] = make_zeros((hkv * d,), dtype, abstract)
        p["bv"] = make_zeros((hkv * d,), dtype, abstract)
    if spec.qk_norm:
        p["q_norm"] = make_ones((d,), dtype, abstract)
        p["k_norm"] = make_ones((d,), dtype, abstract)
    return p


def attention_fwd(p, x, spec: AttentionSpec, *, positions, causal=True,
                  cache=None, kv_len=None, kv_chunk=1024):
    """Returns (out, new_kv) — new_kv is the (k, v) for this segment."""
    b, s, _ = x.shape
    hq, hkv, d = spec.padded_heads, spec.padded_kv_heads, spec.d_head
    q = jnp.einsum("bsm,mh->bsh", x, p["wq"])
    k = jnp.einsum("bsm,mh->bsh", x, p["wk"])
    v = jnp.einsum("bsm,mh->bsh", x, p["wv"])
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, d)
    k = k.reshape(b, s, hkv, d)
    v = v.reshape(b, s, hkv, d)
    if spec.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, spec.rope_theta)
    k = apply_rope(k, positions, spec.rope_theta)

    if cache is not None:
        ck, cv, cache_len = cache
        k_all = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                             (0, cache_len, 0, 0))
        v_all = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                             (0, cache_len, 0, 0))
        # decode: direct attention (GSPMD split-K over a sharded cache)
        out = direct_attention(q, k_all, v_all, q_offset=cache_len,
                               kv_len=cache_len + s, causal=True)
        new_kv = (k_all, v_all)
    else:
        out = chunked_attention(q, k, v, causal=causal, kv_chunk=kv_chunk)
        new_kv = (k, v)
    out = out.reshape(b, s, hq * d)
    return jnp.einsum("bsh,hm->bsm", out, p["wo"]), new_kv


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_params(key, d_model: int, d_ff: int, dtype, abstract: bool):
    scale = 1.0 / math.sqrt(d_model)
    ks = jax.random.split(key, 3) if not abstract else [None] * 3
    return {
        "w_gate": make_param(ks[0], (d_model, d_ff), dtype, scale, abstract),
        "w_up": make_param(ks[1], (d_model, d_ff), dtype, scale, abstract),
        "w_down": make_param(ks[2], (d_ff, d_model), dtype,
                             1.0 / math.sqrt(d_ff), abstract),
    }


def mlp_fwd(p, x):
    g = jax.nn.silu(jnp.einsum("bsm,mf->bsf", x, p["w_gate"]))
    u = jnp.einsum("bsm,mf->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fm->bsm", g * u, p["w_down"])


__all__ = [
    "make_param", "make_zeros", "make_ones", "rms_norm", "apply_rope",
    "chunked_attention", "direct_attention", "AttentionSpec",
    "attention_params", "attention_fwd", "mlp_params", "mlp_fwd",
]
