"""Fused score+select kernel vs pure-jnp oracle: shape/dtype sweeps."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.scoretopk import ops, ref
from repro.kernels.scoretopk import scoretopk as kern


def _data(rng, b, n_rows, n, dtype=np.float32):
    q = rng.normal(size=(b, n)).astype(dtype)
    e = rng.normal(size=(n_rows, n)).astype(dtype)
    q /= np.linalg.norm(q, axis=-1, keepdims=True)
    e /= np.linalg.norm(e, axis=-1, keepdims=True)
    return jnp.asarray(q), jnp.asarray(e)


@pytest.mark.parametrize("b,n_rows,n,kk,tile", [
    (1, 512, 128, 8, 256),
    (4, 1000, 384, 16, 256),     # non-multiple rows -> padding path
    (2, 4096, 768, 32, 2048),
    (8, 300, 64, 300, 512),      # kk > rows in tile tail
])
def test_kernel_matches_tile_oracle(b, n_rows, n, kk, tile):
    rng = np.random.default_rng(0)
    q, e = _data(rng, b, n_rows, n)
    kk_eff = min(kk, tile, n_rows)
    got_v, got_i = kern.score_topk_pallas(q, e, kk=kk_eff, tile=tile)
    want_v, want_i = ref.tile_topk_ref(q, e, kk_eff, tile)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=1e-6, atol=1e-6)
    finite = np.isfinite(np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i)[finite],
                                  np.asarray(want_i)[finite])


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(1)
    q, e = _data(rng, 2, 512, 128, dtype)
    got_v, got_i = kern.score_topk_pallas(q, e, kk=8, tile=256)
    want_v, want_i = ref.tile_topk_ref(q, e, 8, 256)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=1e-5, atol=1e-5)


def test_topk_end_to_end_exact():
    rng = np.random.default_rng(2)
    q, e = _data(rng, 3, 5000, 256)
    out = ops.topk_scores(q, e, k=25, tile=1024, use_pallas=True)
    want_v, want_i = ref.topk_ref(q, e, 25)
    assert bool(out.exact)
    np.testing.assert_allclose(np.asarray(out.values), np.asarray(want_v),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out.indices), np.asarray(want_i))


def test_topk_certificate_path():
    """per_tile_k < k: certificate true on benign data, result still exact."""
    rng = np.random.default_rng(3)
    q, e = _data(rng, 2, 8192, 128)
    out = ops.topk_scores(q, e, k=64, tile=1024, per_tile_k=32, use_pallas=True)
    want_v, want_i = ref.topk_ref(q, e, 64)
    if bool(out.exact):
        np.testing.assert_array_equal(np.asarray(out.indices),
                                      np.asarray(want_i))


def test_certificate_detects_adversarial_tile():
    """All winners in one tile with kk < k: certificate must be False."""
    n, k = 64, 16
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(1, n)).astype(np.float32))
    base = rng.normal(size=(2048, n)).astype(np.float32) * 0.01
    base[:32] = np.asarray(q)[0] * 10.0  # tile 0 dominates with 32 >= kk=8 rows
    out = ops.topk_scores(q, jnp.asarray(base), k=k, tile=256, per_tile_k=8,
                          use_pallas=True)
    assert not bool(out.exact)
    # fallback recovers exactness
    fb = ops.exact_fallback(q, jnp.asarray(base), k)
    want_v, _ = ref.topk_ref(q, jnp.asarray(base), k)
    np.testing.assert_allclose(np.asarray(fb.values), np.asarray(want_v),
                               rtol=1e-6)


def test_small_corpus_single_tile():
    rng = np.random.default_rng(5)
    q, e = _data(rng, 2, 100, 32)
    out = ops.topk_scores(q, e, k=10, tile=2048, use_pallas=True)
    want_v, want_i = ref.topk_ref(q, e, 10)
    np.testing.assert_array_equal(np.asarray(out.indices), np.asarray(want_i))


def test_k_exceeds_corpus():
    rng = np.random.default_rng(6)
    q, e = _data(rng, 1, 17, 16)
    out = ops.topk_scores(q, e, k=40, use_pallas=True)
    assert out.indices.shape == (1, 17)
