"""Inversion-attack proxies: recovery decays with perturbation (Fig. 4)."""

import numpy as np
import pytest

from repro.core import attacks
from repro.data import synth


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(0)
    return synth.token_corpus(rng, 600, 256, vocab=512, doc_len=16)


def test_token_f1_basics():
    assert attacks.token_f1({1, 2, 3}, {1, 2, 3}) == 1.0
    assert attacks.token_f1({1, 2}, {3, 4}) == 0.0
    assert 0 < attacks.token_f1({1, 2, 3, 4}, {1, 2}) < 1


def test_nn_attack_perfect_at_zero_perturbation(corpus):
    atk = attacks.NearestNeighborAttack(aux=corpus)
    scores = [atk.score(corpus.embeddings[i], corpus.token_sets[i])
              for i in range(20)]
    assert np.mean(scores) > 0.95


def test_attack_curve_monotone_decay(corpus):
    """1-NN proxy needs ~sqrt(dim)-scaled radii (see attacks.py note); the
    validated property is the monotone decay to chance."""
    rng = np.random.default_rng(1)
    atk = attacks.NearestNeighborAttack(aux=corpus)
    radii = [0.0, 0.5, 4.0, 10.0]
    curve = attacks.attack_curve(atk, corpus, range(30), radii, rng)
    assert curve[0] > 0.9
    assert curve[-1] < 0.6 * curve[0]  # large r kills the attack (Fig. 4a)
    assert curve[0] >= curve[2] >= curve[3]


def test_exact_recovery_cliffs_before_f1(corpus):
    rng = np.random.default_rng(5)
    atk = attacks.NearestNeighborAttack(aux=corpus)
    radii = [0.0, 1.0]
    exact = attacks.exact_recovery_curve(atk, corpus, range(30), radii, rng)
    f1 = attacks.attack_curve(atk, corpus, range(30), radii, rng)
    assert exact[0] == 1.0
    # exact-identity recovery degrades at least as fast as token F1
    assert exact[1] <= f1[1] + 1e-9


def test_linear_decoder_recovers_tokens(corpus):
    atk = attacks.LinearDecoderAttack(aux=corpus, top_m=16)
    s = [atk.score(corpus.embeddings[i], corpus.token_sets[i])
         for i in range(20)]
    assert np.mean(s) > 0.3  # far above chance (16/512)


def test_linear_decoder_decays(corpus):
    rng = np.random.default_rng(2)
    atk = attacks.LinearDecoderAttack(aux=corpus, top_m=16)
    curve = attacks.attack_curve(atk, corpus, range(20), [0.0, 4.0], rng)
    assert curve[1] < 0.75 * curve[0]
