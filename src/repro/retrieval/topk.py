"""Distributed exact top-k' search over a sharded FlatIndex.

Per-device: the fused Pallas score+select kernel reduces the local shard to
(B, k_local) candidates.  Cross-device: shards are stacked along a leading
axis (shard_map out_spec), and a tiny replicated top-k merge runs outside.
Collective bytes scale with devices * B * k (KB), never with N.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

import numpy as np

from repro.kernels.scoretopk import ops as sops
from repro.retrieval.index import FlatIndex, IndexSlice


class SearchResult(NamedTuple):
    values: jax.Array    # (B, k) descending scores (inner products)
    indices: jax.Array   # (B, k) int32 global ids
    exact: jax.Array     # () bool


def make_sharded_topk(mesh, axes, n_rows: int, k: int, *, tile: int = 2048,
                      per_tile_k: Optional[int] = None, use_pallas=None):
    """Functional core: (queries, corpus) -> SearchResult, jit/lower-able.

    ``corpus`` must be row-sharded over ``axes``; rows must divide evenly.
    """
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    rows_local = n_rows // n_shards
    k_local = min(k, rows_local)

    def local_search(q, shard):
        # linearized shard position over the row axes
        pos = jnp.int32(0)
        for a in axes:
            pos = pos * mesh.shape[a] + jax.lax.axis_index(a)
        out = sops.topk_scores(q, shard, k_local, tile=min(tile, rows_local),
                               per_tile_k=per_tile_k, use_pallas=use_pallas)
        gidx = out.indices + pos * rows_local
        return (out.values[None], gidx[None],
                out.exact.reshape(1)[None])

    def search(queries, corpus):
        stacked_v, stacked_i, stacked_ok = shard_map(
            local_search, mesh=mesh,
            in_specs=(P(), P(axes, None)),
            out_specs=(P(axes), P(axes), P(axes)),
            check_rep=False,
        )(queries, corpus)
        b = queries.shape[0]
        flat_v = jnp.swapaxes(stacked_v, 0, 1).reshape(b, n_shards * k_local)
        flat_i = jnp.swapaxes(stacked_i, 0, 1).reshape(b, n_shards * k_local)
        k_eff = min(k, n_shards * k_local)
        mv, mpos = jax.lax.top_k(flat_v, k_eff)
        mi = jnp.take_along_axis(flat_i, mpos, axis=1)
        return SearchResult(mv, mi, jnp.all(stacked_ok))

    return search


def distributed_topk(index: FlatIndex, queries, k: int, *,
                     tile: int = 2048, per_tile_k: Optional[int] = None,
                     use_pallas=None) -> SearchResult:
    """Exact top-k of <query, corpus row> over the (possibly sharded) index."""
    n_rows = index.num_rows  # includes shard padding
    if index.mesh is None:
        out = sops.topk_scores(queries, index.embeddings, k, tile=tile,
                               per_tile_k=per_tile_k, use_pallas=use_pallas)
        return SearchResult(out.values, out.indices, out.exact)
    search = make_sharded_topk(index.mesh, index.row_axes, n_rows, k,
                               tile=tile, per_tile_k=per_tile_k,
                               use_pallas=use_pallas)
    return search(queries, index.embeddings)


def slice_topk(sl: IndexSlice, queries, k: int, *, tile: int = 2048,
               per_tile_k: Optional[int] = None,
               use_pallas=None) -> SearchResult:
    """Exact top-k over one replica's row slice, in *global* ids.

    Runs the same fused score+select as the full-index path (same tile
    schedule, same stable tie-break toward lower row id), then offsets
    local ids by ``sl.start``.  Per-slice results merged by (score desc,
    global id asc) therefore reproduce the full-index top-k bit-for-bit —
    the invariant the scale-out router's differential harness pins.
    """
    k_local = min(k, sl.num_rows)
    out = sops.topk_scores(queries, sl.embeddings, k_local,
                           tile=min(tile, sl.num_rows),
                           per_tile_k=per_tile_k, use_pallas=use_pallas)
    return SearchResult(out.values, out.indices + sl.start, out.exact)


def plan_nprobe(cluster_map, kprime: int, *, slack: float = 4.0) -> int:
    """Theorem-1 search range -> IVF probe bound.

    The planner guarantees the true top-k lie inside the k' nearest rows
    of the perturbed query; routing must therefore scan at least enough
    clusters to contain those k' rows.  Conservatively: the smallest n
    such that even the n *smallest* clusters hold ``slack * kprime``
    docs — so whichever clusters the router actually picks, the scanned
    candidate pool covers the planned search range with ``slack``x
    headroom.  Clamped to [1, num_clusters]."""
    if kprime < 1:
        raise ValueError(f"kprime must be >= 1, got {kprime}")
    sizes = np.sort(np.asarray(cluster_map.sizes, np.int64))
    need = min(int(sizes.sum()), int(np.ceil(slack * kprime)))
    cum = np.cumsum(sizes)
    n = int(np.searchsorted(cum, need)) + 1
    return max(1, min(n, int(sizes.size)))


def cluster_topk(view, queries, k: int, *, nprobe: Optional[int] = None,
                 tile: int = 2048, per_tile_k: Optional[int] = None,
                 use_pallas=None) -> SearchResult:
    """IVF first-stage routed top-k over a `CorpusView` (or any object
    with ``cluster_map`` + ``cluster_slice``).

    Each query routes to its ``nprobe`` nearest clusters (centroid score
    desc, cluster id asc); each routed cluster's contiguous slice runs the
    same fused per-slice scan as the replica router (`slice_topk`), and
    per-query results merge by (score desc, global id asc).  With
    ``nprobe=None`` (or >= the cluster count) every cluster is scanned and
    the result is bit-identical to the flat `distributed_topk` scan — the
    differential anchor; smaller ``nprobe`` trades recall outside the
    routed clusters for skipping their rows entirely (``exact`` is then
    False).  Use `plan_nprobe` to derive the probe count from the
    Theorem-1 plan's k'."""
    cm = view.cluster_map
    if cm is None:
        raise ValueError("cluster_topk needs an IVF-built corpus "
                         "(FlatIndex.build(ivf=...))")
    num_clusters = cm.num_clusters
    probe = num_clusters if nprobe is None else max(1, min(int(nprobe),
                                                           num_clusters))
    queries = jnp.asarray(queries, jnp.float32)
    bsz = queries.shape[0]
    routed = cm.route(np.asarray(queries), probe)            # (B, probe)
    if np.min(cm.sizes[routed].sum(axis=1)) < k:
        raise ValueError(
            f"nprobe={probe} routes fewer than k={k} rows; raise nprobe")
    vals = [[] for _ in range(bsz)]
    gids = [[] for _ in range(bsz)]
    exact = True
    for c in np.unique(routed):
        qsel = np.nonzero((routed == int(c)).any(axis=1))[0]
        out = slice_topk(view.cluster_slice(int(c)), queries[qsel], k,
                         tile=tile, per_tile_k=per_tile_k,
                         use_pallas=use_pallas)
        exact = exact and bool(out.exact)
        ov = np.asarray(out.values)
        oi = np.asarray(out.indices)
        for j, q in enumerate(qsel):
            vals[int(q)].append(ov[j])
            gids[int(q)].append(oi[j])
    mv = np.empty((bsz, k), np.float32)
    mi = np.empty((bsz, k), np.int32)
    for b in range(bsz):
        v = np.concatenate(vals[b])
        g = np.concatenate(gids[b])
        order = np.lexsort((g, -v))[:k]     # score desc, global id asc
        mv[b] = v[order]
        mi[b] = g[order]
    return SearchResult(jnp.asarray(mv), jnp.asarray(mi),
                        jnp.asarray(exact and probe == num_clusters))


def distances_from_scores(values):
    """Cosine distance (paper Definition 2) from inner-product scores."""
    return 1.0 - values


__all__ = ["SearchResult", "make_sharded_topk", "distributed_topk",
           "slice_topk", "cluster_topk", "plan_nprobe",
           "distances_from_scores"]
