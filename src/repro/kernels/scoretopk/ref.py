"""Pure-jnp oracle for the fused score+select kernel.

Scores are inner products (cosine similarity for unit-norm rows); the
RemoteRAG cosine *distance* is 1 - score.  Ties break toward the lower index
(XLA top_k semantics), matching the kernel's tile-major merge order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def score_ref(queries, corpus):
    """(B, n) x (N, n) -> (B, N) inner-product scores in f32."""
    return jnp.dot(queries.astype(jnp.float32), corpus.astype(jnp.float32).T,
                   preferred_element_type=jnp.float32)


def topk_ref(queries, corpus, k: int):
    """Exact top-k scores+indices per query: (B, k) vals, (B, k) int32 idx."""
    scores = score_ref(queries, corpus)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def tile_topk_ref(queries, corpus, kk: int, tile: int):
    """Per-tile top-kk (the kernel's actual contract).

    Returns (num_tiles, B, kk) vals and global idx; tiles shorter than
    ``tile`` are padded with -inf / index N.
    """
    b = queries.shape[0]
    n_rows = corpus.shape[0]
    num_tiles = -(-n_rows // tile)
    pad = num_tiles * tile - n_rows
    scores = score_ref(queries, corpus)  # (B, N)
    scores = jnp.pad(scores, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    tiles = scores.reshape(b, num_tiles, tile).transpose(1, 0, 2)
    vals, idx = jax.lax.top_k(tiles, kk)  # (num_tiles, B, kk)
    gidx = idx + (jnp.arange(num_tiles, dtype=jnp.int32) * tile)[:, None, None]
    return vals, gidx.astype(jnp.int32)


def merge_tiles_ref(vals, gidx, k: int):
    """Merge per-tile candidates into global top-k (tile-major tie order)."""
    num_tiles, b, kk = vals.shape
    flat_v = vals.transpose(1, 0, 2).reshape(b, num_tiles * kk)
    flat_i = gidx.transpose(1, 0, 2).reshape(b, num_tiles * kk)
    mv, mpos = jax.lax.top_k(flat_v, k)
    mi = jnp.take_along_axis(flat_i, mpos, axis=1)
    return mv, mi


__all__ = ["score_ref", "topk_ref", "tile_topk_ref", "merge_tiles_ref"]
