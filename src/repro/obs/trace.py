"""Span-based request tracing with a redact-by-construction schema.

The serving path is a privacy boundary: the paper's threat model is an
honest-but-curious cloud reconstructing queries from embeddings, so the
telemetry must never become the side channel the protocol closes.  Spans
therefore carry *only* structural facts — stage names, durations, lane
counts, shard ids, tenant ids, byte counts — and the schema enforces that
at record time: every attribute key must be on `ALLOWED_ATTR_KEYS` and
every value must be a short scalar.  Embeddings, plaintexts, scores, doc
ids, or any array/bytes payload are rejected with an exception, not
logged.  Exceptions are recorded as ``type(e).__name__`` only (a repr
could embed query-derived payloads).

`Tracer` is thread-safe (the sharded cache's background admitter records
into the same ring as the engine thread) and bounded: spans live in a
fixed-capacity ring buffer (oldest dropped first, `dropped` counts them)
while per-stage `StageHistogram` aggregates are updated on every span, so
the stage-level p50/p99 profile stays complete even after the ring wraps.

Tracing is off by default — `NULL_TRACER` is a shared no-op sink whose
`span()` returns a reusable empty context manager, keeping the disabled
cost to a dict build and an attribute lookup per call site (gated in CI
by ``scripts/check_trace_overhead.py``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, List, Optional

import numpy as np

from repro.obs.histogram import StageHistogram, summarize

# The full vocabulary of span attribute keys.  Everything here is
# structural (sizes, ids of *public* objects like shards and tenants,
# counters, stage/error names) — never query-derived content.  Adding a
# key is a reviewed schema change, not a call-site convenience.
ALLOWED_ATTR_KEYS = frozenset({
    "attempt",        # solo-retry attempt number
    "backend",        # "rlwe" | "paillier"
    "batch_size",     # lanes in the dispatch slot
    "bytes",          # byte *count* (never byte contents)
    "capacity",       # ring/queue capacity
    "count",          # generic item count
    "error_type",     # exception class name only
    "hits",           # cache hits (count)
    "kprime",         # candidate count k' (public plan knob)
    "lane",           # lane index within a batch
    "lanes",          # number of lanes in a batched stage
    "misses",         # cache misses (count)
    "n_dim",          # embedding dimensionality (public shape)
    "num_cands",      # candidate rows touched (count)
    "num_shards",     # shards in the cache pool
    "ok",             # success flag
    "priority",       # admission priority class name (public knob)
    "queue",          # queue depth (count)
    "reason",         # short machine-chosen label (e.g. shed reason)
    "replica",        # replica id (public placement index, router tier)
    "replicas",       # replicas touched (count, scatter fan-out)
    "requests",       # request count
    "resident",       # device-resident shard count
    "shard",          # shard id (public partition index, not a doc id)
    "shards",         # shards touched (count)
    "stage",          # stage name a meta-event refers to
    "subset",         # bisection subset size
    "tenant",         # tenant id (public session identity)
})

_MAX_STR = 64        # short labels only; doc text cannot fit a label


def validate_attrs(attrs: dict) -> dict:
    """Return a sanitized copy of ``attrs`` or raise.

    Enforces the redaction contract: whitelisted keys, scalar values
    (bool/int/float/str and their numpy scalar equivalents), strings at
    most ``_MAX_STR`` chars.  Arrays, bytes, lists, dicts — anything that
    could smuggle an embedding, plaintext, score vector or doc-id list —
    raise ``ValueError``/``TypeError`` at the record site.
    """
    out = {}
    for key, val in attrs.items():
        if key not in ALLOWED_ATTR_KEYS:
            raise ValueError(
                f"span attribute {key!r} is not in ALLOWED_ATTR_KEYS; "
                f"telemetry only carries whitelisted structural fields")
        if isinstance(val, bool):
            out[key] = val
        elif isinstance(val, (int, np.integer)):
            out[key] = int(val)
        elif isinstance(val, (float, np.floating)):
            out[key] = float(val)
        elif isinstance(val, str):
            if len(val) > _MAX_STR:
                raise ValueError(
                    f"span attribute {key!r} string exceeds {_MAX_STR} "
                    f"chars; payloads are not loggable")
            out[key] = val
        else:
            raise TypeError(
                f"span attribute {key!r} has non-scalar type "
                f"{type(val).__name__}; arrays/bytes/collections are "
                f"never loggable (redaction contract)")
    return out


@dataclasses.dataclass(frozen=True)
class Span:
    """One completed interval.  ``track`` picks the timeline row in the
    Chrome-trace export ("engine", "admitter", or "request-<id>");
    ``attrs`` passed `validate_attrs` at record time."""
    name: str
    track: str
    t_start: float
    duration_s: float
    request_id: Optional[int] = None
    batch_id: Optional[int] = None
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def t_end(self) -> float:
        return self.t_start + self.duration_s


class Tracer:
    """Bounded, thread-safe span sink with per-stage histograms.

    ``clock`` must be the same monotonic clock the engine stamps
    ``t_enqueue`` with, so queue-wait spans and stage spans share one
    timeline (the engine passes its own clock in).
    """

    enabled = True

    def __init__(self, *, capacity: int = 65536, clock=time.monotonic,
                 common: Optional[dict] = None) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.clock = clock
        # attrs stamped onto every span/event from this tracer (e.g. the
        # router gives each replica's tracer common={"replica": r}) — same
        # redaction contract as per-call attrs; per-call keys win
        self.common = validate_attrs(common or {})
        self.dropped = 0             # spans evicted by the ring bound
        self._spans: deque = deque(maxlen=capacity)
        self._hist: Dict[str, StageHistogram] = {}
        # exact per-name marker counts (shed, rate_limited, refill,
        # quarantine, ...): events carry operational signal — a shed
        # count must survive the ring wrapping just like the histograms
        self._events: Dict[str, int] = {}
        self._lock = threading.Lock()

    # -- recording ----------------------------------------------------------

    def record(self, name: str, t_start: float, t_end: float, *,
               track: str = "engine", request_id: Optional[int] = None,
               batch_id: Optional[int] = None, **attrs) -> Span:
        """Record a completed interval with explicit timestamps (for
        intervals whose start predates the call, e.g. queue wait measured
        from ``t_enqueue``)."""
        span = Span(name=name, track=track, t_start=float(t_start),
                    duration_s=max(float(t_end) - float(t_start), 0.0),
                    request_id=request_id, batch_id=batch_id,
                    attrs={**self.common, **validate_attrs(attrs)})
        with self._lock:
            if len(self._spans) == self.capacity:
                self.dropped += 1
            self._spans.append(span)
            hist = self._hist.get(name)
            if hist is None:
                hist = self._hist[name] = StageHistogram()
            hist.record(span.duration_s)
        return span

    @contextmanager
    def span(self, name: str, *, track: str = "engine",
             request_id: Optional[int] = None,
             batch_id: Optional[int] = None, **attrs):
        """Time a block.  If the body raises, the span is still recorded —
        with the exception *class name* only — and the exception
        propagates (fault attribution stays visible on the timeline)."""
        t0 = self.clock()
        try:
            yield
        except Exception as e:
            self.record(name, t0, self.clock(), track=track,
                        request_id=request_id, batch_id=batch_id,
                        error_type=type(e).__name__, **attrs)
            raise
        self.record(name, t0, self.clock(), track=track,
                    request_id=request_id, batch_id=batch_id, **attrs)

    def event(self, name: str, *, track: str = "engine",
              request_id: Optional[int] = None,
              batch_id: Optional[int] = None, **attrs) -> Span:
        """Zero-duration marker (quarantine, bisection step, refill grant,
        shard eviction).  Not folded into the stage histograms — a marker
        has no duration to profile."""
        now = self.clock()
        span = Span(name=name, track=track, t_start=float(now),
                    duration_s=0.0, request_id=request_id,
                    batch_id=batch_id,
                    attrs={**self.common, **validate_attrs(attrs)})
        with self._lock:
            if len(self._spans) == self.capacity:
                self.dropped += 1
            self._spans.append(span)
            self._events[name] = self._events.get(name, 0) + 1
        return span

    # -- reading ------------------------------------------------------------

    def spans(self) -> List[Span]:
        """Snapshot of the ring (oldest first)."""
        with self._lock:
            return list(self._spans)

    def stage_summary(self) -> dict:
        """{stage: histogram summary} — complete since process start even
        after the span ring wrapped."""
        with self._lock:
            return summarize(self._hist)

    def snapshot(self) -> dict:
        """JSON-ready telemetry snapshot (merged into
        ``ServeMetrics.summary()`` by the engine)."""
        with self._lock:
            return {
                "spans": len(self._spans),
                "dropped": self.dropped,
                "capacity": self.capacity,
                "stages": summarize(self._hist),
                "events": dict(sorted(self._events.items())),
            }

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._hist.clear()
            self._events.clear()
            self.dropped = 0


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op sink so instrumented code needs no ``if traced:`` branches.
    All record/span/event calls reduce to returning a shared constant."""

    enabled = False
    capacity = 0
    dropped = 0
    common: dict = {}
    clock = staticmethod(time.monotonic)

    def record(self, name, t_start, t_end, **kwargs):
        return None

    def span(self, name, **kwargs):
        return _NULL_SPAN

    def event(self, name, **kwargs):
        return None

    def spans(self):
        return []

    def stage_summary(self):
        return {}

    def snapshot(self):
        return {"spans": 0, "dropped": 0, "capacity": 0, "stages": {},
                "events": {}}

    def clear(self):
        pass


NULL_TRACER = NullTracer()

__all__ = ["ALLOWED_ATTR_KEYS", "validate_attrs", "Span", "Tracer",
           "NullTracer", "NULL_TRACER"]
