import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import geometry


def test_cap_fraction_endpoints():
    for n in (3, 16, 384, 768):
        assert geometry.cap_fraction_np(0.0, n) == pytest.approx(0.0, abs=1e-12)
        assert geometry.cap_fraction_np(np.pi / 2, n) == pytest.approx(0.5, abs=1e-9)
        assert geometry.cap_fraction_np(np.pi, n) == pytest.approx(1.0, abs=1e-9)


def test_cap_fraction_monotone():
    alphas = np.linspace(0.0, np.pi, 257)
    for n in (4, 64, 768):
        f = geometry.cap_fraction_np(alphas, n)
        assert np.all(np.diff(f) >= -1e-12)


def test_cap_fraction_matches_3d_closed_form():
    # In R^3 the cap fraction is (1 - cos(alpha)) / 2 exactly.
    alphas = np.linspace(0.01, np.pi - 0.01, 31)
    f = geometry.cap_fraction_np(alphas, 3)
    np.testing.assert_allclose(f, (1 - np.cos(alphas)) / 2, rtol=1e-8)


def test_alpha_fraction_roundtrip_np():
    for n in (8, 384, 1536):
        fr = np.array([1e-6, 1e-4, 1e-2, 0.3, 0.5, 0.7, 0.999])
        a = geometry.alpha_from_fraction_np(fr, n)
        back = geometry.cap_fraction_np(a, n)
        np.testing.assert_allclose(back, fr, rtol=1e-6, atol=1e-12)


def test_alpha_from_fraction_jax_matches_np():
    for n in (16, 768):
        fr = np.array([1e-4, 1e-2, 0.25, 0.5, 0.9], np.float32)
        a_jax = np.asarray(geometry.alpha_from_fraction(jnp.asarray(fr), n))
        a_np = geometry.alpha_from_fraction_np(fr, n)
        np.testing.assert_allclose(a_jax, a_np, atol=2e-3)


def test_kprime_reproduces_paper_operating_point():
    # Paper highlight: N=1e5, k=5, T5 (n=768), r=0.03 -> k'=160.
    kp = geometry.kprime_for(5, 100_000, 768, 0.03, conservative=False)
    assert 100 <= kp <= 260, kp


def test_kprime_monotone_in_r_and_bounded():
    ks = [geometry.kprime_for(5, 10_000, 384, r) for r in (0.01, 0.03, 0.05, 0.1)]
    assert ks == sorted(ks)
    assert all(5 <= kp <= 10_000 for kp in ks)
    assert geometry.kprime_for(5, 100, 384, 3.5) == 100  # huge r -> whole corpus


def test_theorem2_l2_cos_identity():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (16, 64))
    a = a / jnp.linalg.norm(a, axis=-1, keepdims=True)
    b = jnp.roll(a, 1, axis=0)
    d_cos = geometry.cos_distance(a, b)
    d_l2 = jnp.linalg.norm(a - b, axis=-1)
    np.testing.assert_allclose(np.asarray(geometry.l2_from_cos(d_cos)),
                               np.asarray(d_l2), rtol=1e-4, atol=1e-5)


def test_theorem3_omega():
    # tan(omega) = tan(alpha_k)/sqrt(k); omega shrinks with k.
    alpha = 0.8
    o1 = geometry.mean_angle_omega(alpha, 1)
    o4 = geometry.mean_angle_omega(alpha, 4)
    assert o1 == pytest.approx(alpha)
    assert np.tan(o4) == pytest.approx(np.tan(alpha) / 2)


def test_theorem3_monte_carlo():
    # Sample k points uniformly on the alpha_k-cap *boundary* around a pole in
    # R^n; the angle of their mean from the pole should match Theorem 3.
    rng = np.random.default_rng(0)
    n, k, alpha = 256, 16, 0.9
    trials = 200
    angles = []
    for _ in range(trials):
        t = rng.normal(size=(k, n - 1))
        t /= np.linalg.norm(t, axis=-1, keepdims=True)
        pts = np.concatenate(
            [np.full((k, 1), np.cos(alpha)), np.sin(alpha) * t], axis=1)
        m = pts.mean(axis=0)
        angles.append(np.arccos(m[0] / np.linalg.norm(m)))
    expected = geometry.mean_angle_omega(alpha, k)
    assert np.mean(angles) == pytest.approx(expected, rel=0.15)


def test_leakage_requires_ot_limits():
    # Huge eps (tiny perturbation) -> direct path; tiny eps -> OT path.
    assert not geometry.leakage_requires_ot(5, 10_000, 384, eps=1e7)
    # n/eps = 3.84 rad certainly exceeds omega < pi/2.
    assert geometry.leakage_requires_ot(5, 10_000, 384, eps=100.0)
