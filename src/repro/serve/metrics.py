"""Per-tenant serving metrics: latency percentiles + wire-byte accounting.

Latency is measured enqueue -> result (queue wait included, the number a
tenant actually experiences under micro-batching).  Wire bytes come from the
protocol transcripts, i.e. the same Request.nbytes / Reply.nbytes accounting
the paper's Table 2 uses.

Memory is bounded: latency and batch-size *samples* live in a fixed-size
sliding window (``window`` items, default 8192 — configurable through
`ServeMetrics` / ``EngineConfig.metrics_window``), so a long-lived engine
under the million-user north star cannot grow without bound.  Counts and
byte totals stay exact forever (they are plain integer accumulators);
`percentile`/`summary` statistics are computed over the current window.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict

import numpy as np

from repro.core.protocol import ProtocolTranscript

DEFAULT_WINDOW = 8192


@dataclasses.dataclass
class TenantStats:
    """Exact integer totals + windowed latency/batch-size samples."""
    window: int = DEFAULT_WINDOW
    count: int = 0                 # exact: every recorded result
    errors: int = 0                # exact: dispatch failures after retries
    request_bytes: int = 0
    reply_bytes: int = 0
    fetch_bytes: int = 0
    docs_bytes: int = 0
    ot_wire_bytes: int = 0
    direct_count: int = 0
    ot_count: int = 0
    latencies_s: Deque[float] = dataclasses.field(init=False, repr=False)
    batch_sizes: Deque[int] = dataclasses.field(init=False, repr=False)

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        self.latencies_s = collections.deque(maxlen=self.window)
        self.batch_sizes = collections.deque(maxlen=self.window)

    @property
    def total_wire_bytes(self) -> int:
        return (self.request_bytes + self.reply_bytes + self.fetch_bytes
                + self.docs_bytes + self.ot_wire_bytes)

    def percentile(self, q: float) -> float:
        """Latency percentile over the current window (the trailing
        ``window`` results), not all-time."""
        return float(np.percentile(self.latencies_s, q))

    def summary(self) -> dict:
        if not self.latencies_s:
            # error-only (or untouched) stats: no samples to summarize —
            # percentile on an empty window must not blow up the summary
            out = {"count": self.count}
            if self.errors:
                out["errors"] = self.errors
            return out
        out = {
            "count": self.count,
            "p50_latency_s": round(self.percentile(50), 4),
            "p99_latency_s": round(self.percentile(99), 4),
            "mean_latency_s": round(float(np.mean(self.latencies_s)), 4),
            "mean_batch_size": round(float(np.mean(self.batch_sizes)), 2),
            "mean_wire_kb": round(
                self.total_wire_bytes / max(self.count, 1) / 1024, 2),
            "paths": {"direct": self.direct_count, "ot": self.ot_count},
        }
        if self.errors:
            out["errors"] = self.errors
        return out


class ServeMetrics:
    """Accumulates TenantStats per tenant plus a process-wide aggregate.

    Dispatch-level accounting is exact-total + windowed-sample like the
    tenant stats: ``num_batches``/``failed_dispatches``/``retried_requests``
    are exact counters; ``dispatch_sizes`` keeps the trailing ``window``
    batch sizes.  A batch is recorded only once it *completed* — the engine
    calls `record_dispatch_failure` (never `record_batch`) for a dispatch
    that raised, so failed batches can never masquerade as served traffic.
    """

    def __init__(self, window: int = DEFAULT_WINDOW) -> None:
        self.window = window
        self.tenants: Dict[str, TenantStats] = {}
        self.aggregate = TenantStats(window=window)
        self.dispatch_sizes: Deque[int] = collections.deque(maxlen=window)
        self.num_batches = 0           # exact: completed dispatches
        self.failed_dispatches = 0     # exact: dispatches that raised
        self.failed_requests = 0       # exact: requests in failed dispatches
        self.retried_requests = 0      # exact: requests re-enqueued once
        self.error_results = 0         # exact: error results handed back

    def _tenant(self, tenant: str) -> TenantStats:
        stats = self.tenants.get(tenant)
        if stats is None:
            stats = self.tenants[tenant] = TenantStats(window=self.window)
        return stats

    def record_batch(self, size: int) -> None:
        self.num_batches += 1
        self.dispatch_sizes.append(size)

    def record_dispatch_failure(self, size: int) -> None:
        self.failed_dispatches += 1
        self.failed_requests += size

    def record_retries(self, n: int) -> None:
        self.retried_requests += n

    def record_error(self, tenant: str) -> None:
        """One request came back as an error result (retries exhausted)."""
        self.error_results += 1
        for stats in (self._tenant(tenant), self.aggregate):
            stats.errors += 1

    def record(self, tenant: str, *, latency_s: float, batch_size: int,
               transcript: ProtocolTranscript) -> None:
        for stats in (self._tenant(tenant), self.aggregate):
            stats.count += 1
            stats.latencies_s.append(latency_s)
            stats.batch_sizes.append(batch_size)
            stats.request_bytes += transcript.request_bytes
            stats.reply_bytes += transcript.reply_bytes
            stats.fetch_bytes += transcript.fetch_bytes
            stats.docs_bytes += transcript.docs_bytes
            stats.ot_wire_bytes += transcript.ot_wire_bytes
            if transcript.path == "ot":
                stats.ot_count += 1
            else:
                stats.direct_count += 1

    def summary(self) -> dict:
        out = {"aggregate": self.aggregate.summary(),
               "num_batches": self.num_batches,
               "tenants": {t: s.summary() for t, s in self.tenants.items()}}
        if self.failed_dispatches:
            out["failures"] = {
                "failed_dispatches": self.failed_dispatches,
                "failed_requests": self.failed_requests,
                "retried_requests": self.retried_requests,
                "error_results": self.error_results,
            }
        return out


__all__ = ["TenantStats", "ServeMetrics", "DEFAULT_WINDOW"]
