"""RecSys archs: FM, two-tower retrieval, DIEN (AUGRU), DCN-v2.

The shared substrate is the sparse-embedding layer: JAX has no EmbeddingBag,
so lookups are `jnp.take` + `jax.ops.segment_sum` (multi-hot bags) over
row-sharded tables — the FBGEMM/TBE layout mapped onto the mesh's "model"
axis.  Two-tower's candidate scoring plugs directly into `repro.retrieval`
(it *is* the RemoteRAG workload — DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers


# ---------------------------------------------------------------------------
# EmbeddingBag substrate
# ---------------------------------------------------------------------------

def embedding_lookup(table, ids):
    """Plain per-id lookup: (..., ) int32 -> (..., d)."""
    return jnp.take(table, ids, axis=0)


def embedding_bag(table, ids, segment_ids, num_bags: int, *, mode="sum"):
    """Multi-hot bag reduce: ids/segment_ids (nnz,) -> (num_bags, d)."""
    rows = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, table.dtype),
                                  segment_ids, num_segments=num_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def _mlp_params(key, dims, dtype, abstract):
    out = []
    ks = jax.random.split(key, len(dims) - 1) if not abstract else \
        [None] * (len(dims) - 1)
    for i in range(len(dims) - 1):
        out.append({
            "w": layers.make_param(ks[i], (dims[i], dims[i + 1]), dtype,
                                   1.0 / math.sqrt(dims[i]), abstract),
            "b": layers.make_zeros((dims[i + 1],), dtype, abstract),
        })
    return out


def _mlp(ps, x, final_act=False):
    for i, p in enumerate(ps):
        x = x @ p["w"] + p["b"]
        if i < len(ps) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _table(key, vocab, dim, dtype, abstract):
    return layers.make_param(key, (vocab, dim), dtype, 1.0 / math.sqrt(dim),
                             abstract)


# ---------------------------------------------------------------------------
# FM  (Rendle ICDM'10): O(nk) sum-square pairwise interactions
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FmConfig:
    name: str = "fm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab_per_field: int = 100_000
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def fm_init(key, cfg: FmConfig, abstract=False):
    ks = jax.random.split(key, 3) if not abstract else [None] * 3
    v = cfg.n_sparse * cfg.vocab_per_field
    return {
        "table": _table(ks[0], v, cfg.embed_dim, cfg.jdtype, abstract),
        "linear": _table(ks[1], v, 1, cfg.jdtype, abstract),
        "bias": layers.make_zeros((), cfg.jdtype, abstract),
    }


def fm_forward(params, cfg: FmConfig, sparse_ids):
    """sparse_ids: (B, n_sparse) globally-offset ids -> logits (B,)."""
    emb = embedding_lookup(params["table"], sparse_ids)     # (B, F, k)
    lin = embedding_lookup(params["linear"], sparse_ids)[..., 0].sum(-1)
    s = emb.sum(axis=1)                                     # (B, k)
    inter = 0.5 * (jnp.square(s) - jnp.square(emb).sum(axis=1)).sum(-1)
    return params["bias"] + lin + inter


def fm_loss(params, cfg: FmConfig, sparse_ids, labels):
    logits = fm_forward(params, cfg, sparse_ids).astype(jnp.float32)
    return jnp.mean(_bce(logits, labels))


def _bce(logits, labels):
    return jnp.maximum(logits, 0) - logits * labels + jnp.log1p(
        jnp.exp(-jnp.abs(logits)))


# ---------------------------------------------------------------------------
# Two-tower retrieval (YouTube RecSys'19)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256
    tower_mlp: Sequence[int] = (1024, 512, 256)
    user_vocab: int = 1_000_000
    item_vocab: int = 1_000_000
    n_user_feats: int = 8
    n_item_feats: int = 4
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def twotower_init(key, cfg: TwoTowerConfig, abstract=False):
    ks = jax.random.split(key, 4) if not abstract else [None] * 4
    d_in_u = cfg.n_user_feats * cfg.embed_dim
    d_in_i = cfg.n_item_feats * cfg.embed_dim
    return {
        "user_table": _table(ks[0], cfg.user_vocab, cfg.embed_dim,
                             cfg.jdtype, abstract),
        "item_table": _table(ks[1], cfg.item_vocab, cfg.embed_dim,
                             cfg.jdtype, abstract),
        "user_mlp": _mlp_params(ks[2], (d_in_u,) + tuple(cfg.tower_mlp),
                                cfg.jdtype, abstract),
        "item_mlp": _mlp_params(ks[3], (d_in_i,) + tuple(cfg.tower_mlp),
                                cfg.jdtype, abstract),
    }


def user_embedding(params, cfg: TwoTowerConfig, user_feats):
    """user_feats (B, n_user_feats) ids -> unit-norm (B, d)."""
    e = embedding_lookup(params["user_table"], user_feats)
    e = e.reshape(e.shape[0], -1)
    u = _mlp(params["user_mlp"], e)
    return u / (jnp.linalg.norm(u, axis=-1, keepdims=True) + 1e-6)


def item_embedding(params, cfg: TwoTowerConfig, item_feats):
    e = embedding_lookup(params["item_table"], item_feats)
    e = e.reshape(e.shape[0], -1)
    i = _mlp(params["item_mlp"], e)
    return i / (jnp.linalg.norm(i, axis=-1, keepdims=True) + 1e-6)


def twotower_loss(params, cfg: TwoTowerConfig, user_feats, item_feats,
                  temperature: float = 0.05):
    """In-batch sampled softmax."""
    u = user_embedding(params, cfg, user_feats)
    i = item_embedding(params, cfg, item_feats)
    logits = (u @ i.T) / temperature
    labels = jnp.arange(u.shape[0])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], 1))


def twotower_score_candidates(params, cfg: TwoTowerConfig, user_feats,
                              cand_embeddings):
    """retrieval_cand shape: one query batch vs 1e6 candidates — batched dot
    via the retrieval substrate (no loop)."""
    u = user_embedding(params, cfg, user_feats)
    return u @ cand_embeddings.T


# ---------------------------------------------------------------------------
# DIEN (AUGRU interest evolution)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DienConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: Sequence[int] = (200, 80)
    item_vocab: int = 500_000
    dtype: str = "float32"
    unroll: int = 1

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def _gru_params(key, d_in, d_h, dtype, abstract):
    ks = jax.random.split(key, 3) if not abstract else [None] * 3
    s = 1.0 / math.sqrt(d_in + d_h)
    return {
        "wz": layers.make_param(ks[0], (d_in + d_h, d_h), dtype, s, abstract),
        "wr": layers.make_param(ks[1], (d_in + d_h, d_h), dtype, s, abstract),
        "wh": layers.make_param(ks[2], (d_in + d_h, d_h), dtype, s, abstract),
    }


def dien_init(key, cfg: DienConfig, abstract=False):
    ks = jax.random.split(key, 4) if not abstract else [None] * 4
    d_concat = cfg.gru_dim + 2 * cfg.embed_dim
    return {
        "item_table": _table(ks[0], cfg.item_vocab, cfg.embed_dim,
                             cfg.jdtype, abstract),
        "gru1": _gru_params(ks[1], cfg.embed_dim, cfg.gru_dim, cfg.jdtype,
                            abstract),
        "augru": _gru_params(ks[2], cfg.gru_dim, cfg.gru_dim, cfg.jdtype,
                             abstract),
        "mlp": _mlp_params(ks[3], (d_concat,) + tuple(cfg.mlp) + (1,),
                           cfg.jdtype, abstract),
    }


def _gru_cell(p, h, x, att=None):
    hx = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(hx @ p["wz"])
    r = jax.nn.sigmoid(hx @ p["wr"])
    hh = jnp.tanh(jnp.concatenate([x, r * h], axis=-1) @ p["wh"])
    if att is not None:           # AUGRU: attention scales the update gate
        z = z * att[:, None]
    return (1 - z) * h + z * hh


def dien_forward(params, cfg: DienConfig, hist_ids, target_ids):
    """hist_ids (B, S), target_ids (B,) -> logits (B,)."""
    b, s = hist_ids.shape
    hist = embedding_lookup(params["item_table"], hist_ids)   # (B, S, k)
    target = embedding_lookup(params["item_table"], target_ids)  # (B, k)

    def gru1_step(h, x):
        return _gru_cell(params["gru1"], h, x), _gru_cell(params["gru1"], h, x)

    h0 = jnp.zeros((b, cfg.gru_dim), cfg.jdtype)
    _, interests = jax.lax.scan(gru1_step, h0, hist.swapaxes(0, 1),
                                unroll=cfg.unroll)
    interests = interests.swapaxes(0, 1)                      # (B, S, H)

    # attention of target on interests
    proj = interests[..., : cfg.embed_dim]
    att = jax.nn.softmax(
        jnp.einsum("bsh,bh->bs", proj, target).astype(jnp.float32), axis=-1
    ).astype(cfg.jdtype)

    def augru_step(h, inp):
        x, a = inp
        return _gru_cell(params["augru"], h, x, att=a), None

    h_final, _ = jax.lax.scan(
        augru_step, h0, (interests.swapaxes(0, 1), att.swapaxes(0, 1)),
        unroll=cfg.unroll)
    feats = jnp.concatenate([h_final, target,
                             hist.mean(axis=1)], axis=-1)
    return _mlp(params["mlp"], feats)[:, 0]


def dien_loss(params, cfg: DienConfig, hist_ids, target_ids, labels):
    logits = dien_forward(params, cfg, hist_ids, target_ids).astype(jnp.float32)
    return jnp.mean(_bce(logits, labels))


# ---------------------------------------------------------------------------
# DCN-v2
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DcnV2Config:
    name: str = "dcn-v2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    n_cross_layers: int = 3
    mlp: Sequence[int] = (1024, 1024, 512)
    vocab_per_field: int = 100_000
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_in(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def dcnv2_init(key, cfg: DcnV2Config, abstract=False):
    ks = jax.random.split(key, 4) if not abstract else [None] * 4
    d = cfg.d_in
    cross = []
    for i in range(cfg.n_cross_layers):
        kk = jax.random.fold_in(ks[1], i) if not abstract else None
        cross.append({
            "w": layers.make_param(kk, (d, d), cfg.jdtype, 1.0 / math.sqrt(d),
                                   abstract),
            "b": layers.make_zeros((d,), cfg.jdtype, abstract),
        })
    if abstract:
        cross_stacked = {
            "w": jax.ShapeDtypeStruct((cfg.n_cross_layers, d, d), cfg.jdtype),
            "b": jax.ShapeDtypeStruct((cfg.n_cross_layers, d), cfg.jdtype),
        }
    else:
        cross_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *cross)
    return {
        "table": _table(ks[0], cfg.n_sparse * cfg.vocab_per_field,
                        cfg.embed_dim, cfg.jdtype, abstract),
        "cross": cross_stacked,
        "deep": _mlp_params(ks[2], (d,) + tuple(cfg.mlp), cfg.jdtype, abstract),
        "head": _mlp_params(ks[3], (d + cfg.mlp[-1], 1), cfg.jdtype, abstract),
    }


def dcnv2_forward(params, cfg: DcnV2Config, dense, sparse_ids):
    """dense (B, n_dense) float; sparse_ids (B, n_sparse) -> logits (B,)."""
    emb = embedding_lookup(params["table"], sparse_ids)
    x0 = jnp.concatenate([dense.astype(cfg.jdtype),
                          emb.reshape(emb.shape[0], -1)], axis=-1)

    def cross_step(x, wb):
        return x0 * (x @ wb["w"] + wb["b"]) + x, None

    xc, _ = jax.lax.scan(cross_step, x0, params["cross"])
    xd = _mlp(params["deep"], x0, final_act=True)
    return _mlp(params["head"], jnp.concatenate([xc, xd], -1))[:, 0]


def dcnv2_loss(params, cfg: DcnV2Config, dense, sparse_ids, labels):
    logits = dcnv2_forward(params, cfg, dense, sparse_ids).astype(jnp.float32)
    return jnp.mean(_bce(logits, labels))


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------

def table_spec(tp_axis="model"):
    """Row-sharded embedding tables (the TBE layout)."""
    return P(tp_axis, None)


__all__ = [
    "embedding_lookup", "embedding_bag",
    "FmConfig", "fm_init", "fm_forward", "fm_loss",
    "TwoTowerConfig", "twotower_init", "user_embedding", "item_embedding",
    "twotower_loss", "twotower_score_candidates",
    "DienConfig", "dien_init", "dien_forward", "dien_loss",
    "DcnV2Config", "dcnv2_init", "dcnv2_forward", "dcnv2_loss",
    "table_spec",
]
