"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936
qk_norm + GQA [hf:Qwen/Qwen3-8B]."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151936, d_head=128, qk_norm=True,
    rope_theta=1_000_000.0, tp=16)

REDUCED = TransformerConfig(
    name="qwen3-8b-smoke", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    d_ff=512, vocab=1024, d_head=32, qk_norm=True, dtype="float32",
    remat=False, kv_chunk=64)
