"""NTT-domain candidate cache: cached+rotated scoring must be bit-identical
to fresh per-request packing (both strides, batch 1/3/8, fallback + fused
Pallas kernel), plus the monomial-rotation identity it rests on."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.crypto import modring, rlwe
from repro.crypto.modring import PrimeCtx
from repro.kernels.ntt import ops as ntt_ops

# n_dim=384 <= chunk -> stride=chunk (2 cands/ct); n_dim=768 > chunk ->
# stride=2*chunk (1 cand/ct, 2 chunks): both packing regimes.
PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)
NUM_DOCS = 40
KPRIME = 9          # not a multiple of cands_per_ct=2: pad path


def _unit(rng, *shape):
    x = rng.normal(size=shape)
    return (x / np.linalg.norm(x, axis=-1, keepdims=True)).astype(np.float32)


@pytest.fixture(scope="module")
def sk():
    return rlwe.keygen(PARAMS, np.random.default_rng(0))


@pytest.fixture(scope="module", params=[384, 768])
def setup(request, sk):
    n_dim = request.param
    rng = np.random.default_rng(n_dim)
    docs = _unit(rng, NUM_DOCS, n_dim)
    cache = rlwe.build_candidate_cache(PARAMS, docs)
    q_cts = [rlwe.encrypt_query(sk, q, rng) for q in _unit(rng, 8, n_dim)]
    return n_dim, docs, cache, q_cts, rng


def test_cache_hoists_packing_geometry(setup):
    n_dim, docs, cache, _, _ = setup
    assert cache.n_dim == n_dim and cache.num_docs == NUM_DOCS
    assert cache.stride == PARAMS.stride(n_dim)
    assert cache.cands_per_ct == PARAMS.cands_per_ct(n_dim)
    assert cache.num_chunks == PARAMS.num_chunks(n_dim)
    # memory contract: 4 * P * N bytes per chunk per doc
    assert cache.nbytes == (4 * PARAMS.num_primes * PARAMS.n_poly
                            * cache.num_chunks * NUM_DOCS)


@pytest.mark.parametrize("bsz", [1, 3, 8])
def test_cached_scoring_bit_identical_to_fresh_packing(setup, bsz):
    n_dim, docs, cache, q_cts, _ = setup
    rng = np.random.default_rng(bsz)
    ids = rng.integers(0, NUM_DOCS, size=(bsz, KPRIME))
    packed = rlwe.pack_candidates_batch(PARAMS, docs[ids])
    cold = rlwe.encrypted_scores_batch_stacked(
        PARAMS, q_cts[:bsz], packed, KPRIME, n_dim, use_pallas=False)
    cached = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:bsz], cache, ids, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(cold.c0), np.asarray(cached.c0))
    np.testing.assert_array_equal(np.asarray(cold.c1), np.asarray(cached.c1))
    assert (cold.n_dim, cold.num_cands) == (cached.n_dim, cached.num_cands)


def test_fused_pallas_kernel_bit_identical(setup):
    n_dim, docs, cache, q_cts, _ = setup
    rng = np.random.default_rng(99)
    ids = rng.integers(0, NUM_DOCS, size=(2, KPRIME))
    ref = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:2], cache, ids, use_pallas=False)
    kern = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:2], cache, ids, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(ref.c0), np.asarray(kern.c0))
    np.testing.assert_array_equal(np.asarray(ref.c1), np.asarray(kern.c1))


def test_cached_scores_decrypt_to_inner_products(setup, sk):
    n_dim, docs, cache, q_cts, rng = setup
    ids = rng.integers(0, NUM_DOCS, size=(1, KPRIME))
    res = rlwe.encrypted_scores_cached(PARAMS, q_cts[0], cache, ids[0])
    got = rlwe.decrypt_scores(sk, res)
    want = rlwe.decrypt_scores(
        sk, rlwe.encrypted_scores(
            PARAMS, q_cts[0], rlwe.pack_candidates(PARAMS, docs[ids[0]])))
    np.testing.assert_array_equal(got, want)


def test_single_query_wrapper_matches_batch_lane(setup):
    n_dim, docs, cache, q_cts, _ = setup
    ids = np.arange(KPRIME) % NUM_DOCS
    one = rlwe.encrypted_scores_cached(PARAMS, q_cts[0], cache, ids)
    bat = rlwe.encrypted_scores_cached_batch(
        PARAMS, q_cts[:1], cache, ids[None])
    assert isinstance(one, rlwe.ScoreCiphertexts)
    np.testing.assert_array_equal(np.asarray(one.c0), np.asarray(bat.c0[0]))


def test_cache_rejects_mismatched_params(setup):
    n_dim, docs, cache, q_cts, _ = setup
    other = rlwe.RlweParams(n_poly=1024, chunk=256)
    with pytest.raises(ValueError, match="rebuild the cache"):
        cache.check_compatible(other)
    ids = np.zeros((1, 4), np.int64)
    with pytest.raises(ValueError, match="rebuild the cache"):
        rlwe.encrypted_scores_cached_batch(other, q_cts[:1], cache, ids)
    # equal-valued params object is compatible (value key, not identity)
    cache.check_compatible(rlwe.RlweParams(n_poly=1024, chunk=512))
    with pytest.raises(ValueError, match="n_dim"):
        cache.check_compatible(PARAMS, n_dim=n_dim + 64)


def test_index_memoizes_cache_per_params_value(setup):
    from repro.retrieval.index import FlatIndex
    n_dim, docs, _, _, _ = setup
    index = FlatIndex.build(docs, normalize=False)
    a = index.candidate_cache(PARAMS)
    b = index.candidate_cache(rlwe.RlweParams(n_poly=1024, chunk=512))
    assert a is b                       # one build per params *value*
    c = index.candidate_cache(rlwe.RlweParams(n_poly=1024, chunk=256))
    assert c is not a
    assert c.num_chunks == -(-n_dim // 256)


def test_monomial_rotation_identity_hypothesis():
    """NTT(X^o * p) == NTT(X^o) . NTT(p) coefficient-exactly — the identity
    the candidate cache rests on — against the independent schoolbook
    negacyclic oracle."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    n = 256
    ctx = PrimeCtx.build(modring.find_ntt_primes(2 * n, 1)[0], n)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1),
           st.integers(min_value=0, max_value=n - 1))
    def prop(seed, offset):
        rng = np.random.default_rng(seed)
        p = rng.integers(0, ctx.q, size=(n,), dtype=np.int64).astype(np.int32)
        mono = np.zeros(n, np.int32)
        mono[offset] = 1
        rotated = modring.negacyclic_mul_np(mono, p, ctx.q).astype(np.int32)
        lhs = np.asarray(ntt_ops.ntt_fwd(rotated, ctx, use_pallas=False))
        tw = ntt_ops.ntt_fwd(mono, ctx, use_pallas=False)
        fp = ntt_ops.ntt_fwd(p, ctx, use_pallas=False)
        rhs = np.asarray(modring.mod_mul(jnp.asarray(tw), jnp.asarray(fp),
                                         ctx.q, ctx.mu))
        np.testing.assert_array_equal(lhs, rhs)

    prop()
