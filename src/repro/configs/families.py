"""Dry-run cell builders per architecture family.

A Cell is everything `launch/dryrun.py` needs for one (arch x shape x mesh):
the step function, abstract (ShapeDtypeStruct) inputs, explicit shardings,
and donation hints.  Cells never allocate device memory.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import shapes as shp
from repro.crypto import rlwe
from repro.launch.mesh import batch_axes as _batch_axes, row_axes as _row_axes
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tf_lib
from repro.retrieval.topk import make_sharded_topk
from repro.train import optimizer as opt_lib
from repro.train import trainer as trainer_lib


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    args: tuple                   # pytrees of ShapeDtypeStruct
    in_shardings: tuple
    out_shardings: object = None  # None -> let GSPMD choose
    donate_argnums: tuple = ()
    static_argnums: tuple = ()


def _shard(mesh: Mesh, spec_tree):
    to_ns = lambda s: NamedSharding(mesh, s)
    return jax.tree.map(to_ns, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


OPT_CFG = opt_lib.AdamWConfig()


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def lm_cell(cfg: tf_lib.TransformerConfig, shape: shp.LmShape,
            mesh: Mesh, *, roofline: bool = False,
            scan_knob: Optional[int] = None,
            variant: Optional[str] = None) -> Cell:
    """variant: None | "moe_a2a" | "tp_repl" | "micro2" | "micro16" — the
    hillclimb configurations (EXPERIMENTS.md §Perf)."""
    ba = _batch_axes(mesh)
    cfg = dataclasses.replace(
        cfg, batch_axes=ba,
        n_layers=scan_knob if scan_knob else cfg.n_layers,
        scan_unroll=cfg.n_layers if roofline and not scan_knob else 1)
    if variant == "moe_a2a":
        cfg = dataclasses.replace(cfg, moe_impl="shard_a2a", mesh=mesh)
    if variant in ("fsdp_only", "fsdp_noremat"):
        # no TP: batch and weights shard over ALL axes; no head padding.
        # fsdp_noremat additionally drops remat (activations/chip are tiny at
        # 256-way batch sharding) -> one fewer weight all-gather pass.
        all_axes = tuple(mesh.axis_names)
        cfg = dataclasses.replace(cfg, tp=1, batch_axes=all_axes,
                                  tp_axis=None,  # no TP dim for activations
                                  remat=variant != "fsdp_noremat")
        ba = all_axes
    pspec = (tf_lib.fsdp_param_specs(cfg, tuple(mesh.axis_names))
             if variant in ("fsdp_only", "fsdp_noremat")
             else tf_lib.param_specs(cfg))
    opt_pspec = pspec
    if variant == "tp_repl":
        # pure-TP weights (replicated over "data"), ZeRO-1 optimizer state
        # (still 2D-sharded): no per-microbatch FSDP weight all-gathers; one
        # grad all-reduce + one master->param all-gather per step.
        def strip_data(p_):
            return P(*[None if ax == "data" else ax for ax in p_])
        pspec = jax.tree.map(strip_data, pspec,
                             is_leaf=lambda x: isinstance(x, P))
    params_sds = tf_lib.abstract_params(cfg)
    spec = cfg.attn_spec

    if variant == "pp2" and "pod" in mesh.axis_names:
        # pipeline over the pod axis: layer params P("pod") on dim 0, batch
        # parallelism stays within-pod (data axis); microbatching = pipeline
        cfg = dataclasses.replace(cfg, batch_axes=("data",))
        ba = ("data",)
        pspec = tf_lib.param_specs(cfg)

        def add_pod(p_):
            return P("pod", *tuple(p_)[1:])
        pspec = dict(pspec)
        pspec["layers"] = jax.tree.map(add_pod, pspec["layers"],
                                       is_leaf=lambda x: isinstance(x, P))
        opt_pspec = pspec

    if shape.kind == "train":
        opt_sds = opt_lib.abstract_init(params_sds, OPT_CFG)
        opt_spec = opt_lib.state_specs(opt_pspec)
        tok_sds = _sds((shape.global_batch, shape.seq_len), jnp.int32)
        batch_spec = (P(ba, None), P(ba, None))

        if variant == "pp2" and "pod" in mesh.axis_names:
            def loss(p, tokens, targets):
                return tf_lib.pipeline_loss_fn(p, cfg, tokens, targets,
                                               mesh=mesh, n_micro=8)
        else:
            def loss(p, tokens, targets):
                return tf_lib.loss_fn(p, cfg, tokens, targets)

        # grad accumulation bounds activation memory; the roofline variant
        # uses microbatches=1 + unrolled layers for exact cost_analysis
        # (XLA visits while bodies once).
        micro = {"micro2": 2, "micro16": 16, "pp2": 1}.get(variant, 8)
        step = trainer_lib.make_train_step(
            loss, OPT_CFG, param_dtype=cfg.jdtype,
            microbatches=1 if roofline else micro)
        return Cell(
            arch=cfg.name, shape=shape.name, fn=step,
            args=(params_sds, opt_sds, (tok_sds, tok_sds)),
            in_shardings=(_shard(mesh, pspec), _shard(mesh, opt_spec),
                          _shard(mesh, batch_spec)),
            out_shardings=(_shard(mesh, pspec), _shard(mesh, opt_spec),
                           _shard(mesh, {"loss": P(), "grad_norm": P(),
                                         "lr": P()})),
            donate_argnums=(0, 1),
        )

    if shape.kind == "prefill":
        tok_sds = _sds((shape.global_batch, shape.seq_len), jnp.int32)
        cache_sds = tf_lib.init_cache(cfg, shape.global_batch, shape.seq_len,
                                      abstract=True)
        kv_spec = P(None, ba, None, "model", None)
        cache_spec = {"k": kv_spec, "v": kv_spec, "len": P()}

        def prefill_step(p, tokens):
            logits, cache = tf_lib.prefill(p, cfg, tokens, shape.seq_len)
            return logits[:, -1, :], cache

        return Cell(
            arch=cfg.name, shape=shape.name, fn=prefill_step,
            args=(params_sds, tok_sds),
            in_shardings=(_shard(mesh, pspec), _shard(mesh, P(ba, None))),
            out_shardings=(_shard(mesh, (P(ba, "model"), cache_spec))),
        )

    # decode: one token against a seq_len KV cache (+headroom, padded so a
    # sequence-sharded cache divides the mesh evenly).
    # Serving uses the UNPADDED config (tp=1): weights shard on their input
    # dim (per-projection psums are tiny at B x 1 activations), the cache
    # keeps the true kv-head count and shards on d_head — this keeps the
    # MHA-ized archs (qwen2.5, granite) inside per-device HBM.
    cfg = dataclasses.replace(cfg, tp=1)
    pspec = tf_lib.decode_param_specs(cfg)
    params_sds = tf_lib.abstract_params(cfg)
    max_len = shape.seq_len + 1024
    tok_sds = _sds((shape.global_batch, 1), jnp.int32)
    cache_sds = tf_lib.init_cache(cfg, shape.global_batch, max_len,
                                  abstract=True)
    n_batch_shards = 1
    for a in ba:
        n_batch_shards *= mesh.shape[a]
    if shape.global_batch % n_batch_shards == 0:
        kv_spec = P(None, ba, None, None, "model")     # batch x d_head
        tok_spec = P(ba, None)
        out_logit_spec = P(ba, "model")
    else:
        # long_500k (batch=1): shard the cache SEQUENCE over the data axes;
        # decode attention lowers to flash-decoding-style split-K reductions.
        kv_spec = P(None, None, ba, None, "model")
        tok_spec = P(None, None)
        out_logit_spec = P(None, "model")
    cache_spec = {"k": kv_spec, "v": kv_spec, "len": P()}

    def decode(p, tokens, cache):
        return tf_lib.decode_step(p, cfg, tokens, cache)

    return Cell(
        arch=cfg.name, shape=shape.name, fn=decode,
        args=(params_sds, tok_sds, cache_sds),
        in_shardings=(_shard(mesh, pspec), _shard(mesh, tok_spec),
                      _shard(mesh, cache_spec)),
        out_shardings=(_shard(mesh, (out_logit_spec, cache_spec))),
        donate_argnums=(2,),
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def gnn_cell(cfg: gnn_lib.GnnConfig, shape: shp.GraphShape,
             mesh: Mesh, *, roofline: bool = False,
             scan_knob: Optional[int] = None,
             variant: Optional[str] = None) -> Cell:
    # graphs have no TP dim: nodes/edges shard over EVERY mesh axis
    # (a data-axes-only layout leaves 16x more per-device edge state —
    # ogb_products would need ~722 GB/dev instead of ~45)
    ba = _row_axes(mesh)
    cfg = dataclasses.replace(
        cfg, d_feat=shape.d_feat,
        n_layers=scan_knob if scan_knob else cfg.n_layers,
        scan_unroll=cfg.n_layers if roofline and not scan_knob else 1)
    params_sds = gnn_lib.abstract_params(cfg)
    pspec = jax.tree.map(lambda _: P(), params_sds)  # replicated (small)
    opt_sds = opt_lib.abstract_init(params_sds, OPT_CFG)
    opt_spec = opt_lib.state_specs(pspec)

    batch_sds = gnn_lib.GraphBatch(
        node_feats=_sds((shape.n_nodes, shape.d_feat), jnp.float32),
        edge_src=_sds((shape.n_edges,), jnp.int32),
        edge_dst=_sds((shape.n_edges,), jnp.int32),
        targets=_sds((shape.n_nodes, cfg.n_vars), jnp.float32))
    batch_spec = gnn_lib.GraphBatch(
        node_feats=P(ba, None), edge_src=P(ba), edge_dst=P(ba),
        targets=P(ba, None))

    def loss(p, node_feats, edge_src, edge_dst, targets):
        return gnn_lib.loss_fn(p, cfg, gnn_lib.GraphBatch(
            node_feats, edge_src, edge_dst, targets))

    step = trainer_lib.make_train_step(loss, OPT_CFG, param_dtype=cfg.jdtype)
    return Cell(
        arch=cfg.name, shape=shape.name, fn=step,
        args=(params_sds, opt_sds, tuple(batch_sds)),
        in_shardings=(_shard(mesh, pspec), _shard(mesh, opt_spec),
                      _shard(mesh, tuple(batch_spec))),
        donate_argnums=(0, 1),
    )


# ---------------------------------------------------------------------------
# recsys family
# ---------------------------------------------------------------------------

def _recsys_batch(arch: str, cfg, b: int):
    """(sds tree, spec tree, loss_fn(params, *leaves)) per arch."""
    if arch == "fm":
        ids = _sds((b, cfg.n_sparse), jnp.int32)
        lbl = _sds((b,), jnp.float32)
        return ((ids, lbl), (P(("data",), None), P(("data",))),
                lambda p, i, l: rec_lib.fm_loss(p, cfg, i, l))
    if arch == "dcn-v2":
        dense = _sds((b, cfg.n_dense), jnp.float32)
        ids = _sds((b, cfg.n_sparse), jnp.int32)
        lbl = _sds((b,), jnp.float32)
        return ((dense, ids, lbl),
                (P(("data",), None), P(("data",), None), P(("data",))),
                lambda p, d, i, l: rec_lib.dcnv2_loss(p, cfg, d, i, l))
    if arch == "dien":
        hist = _sds((b, cfg.seq_len), jnp.int32)
        tgt = _sds((b,), jnp.int32)
        lbl = _sds((b,), jnp.float32)
        return ((hist, tgt, lbl),
                (P(("data",), None), P(("data",)), P(("data",))),
                lambda p, h, t, l: rec_lib.dien_loss(p, cfg, h, t, l))
    if arch == "two-tower-retrieval":
        uf = _sds((b, cfg.n_user_feats), jnp.int32)
        itf = _sds((b, cfg.n_item_feats), jnp.int32)
        return ((uf, itf), (P(("data",), None), P(("data",), None)),
                lambda p, u, i: rec_lib.twotower_loss(p, cfg, u, i))
    raise KeyError(arch)


def _recsys_forward(arch: str, cfg):
    if arch == "fm":
        return lambda p, i: rec_lib.fm_forward(p, cfg, i)
    if arch == "dcn-v2":
        return lambda p, d, i: rec_lib.dcnv2_forward(p, cfg, d, i)
    if arch == "dien":
        return lambda p, h, t: rec_lib.dien_forward(p, cfg, h, t)
    if arch == "two-tower-retrieval":
        return lambda p, u, i: jnp.einsum(
            "bd,bd->b", rec_lib.user_embedding(p, cfg, u),
            rec_lib.item_embedding(p, cfg, i))
    raise KeyError(arch)


def _recsys_init(arch: str, cfg, abstract: bool, key=None):
    init = {"fm": rec_lib.fm_init, "dcn-v2": rec_lib.dcnv2_init,
            "dien": rec_lib.dien_init,
            "two-tower-retrieval": rec_lib.twotower_init}[arch]
    return init(key, cfg, abstract=abstract)


def _recsys_pspec(arch: str, params_sds):
    """Row-shard every large table over 'model'; replicate small MLPs."""
    def spec_for(path, leaf):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if "table" in name or "linear" in name:
            return P("model", None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_for, params_sds)


def recsys_cell(arch: str, cfg, shape: shp.RecsysShape, mesh: Mesh,
                *, roofline: bool = False,
                scan_knob: Optional[int] = None,
                variant: Optional[str] = None) -> Cell:
    if arch == "two-tower-retrieval" and variant == "bf16":
        cfg = dataclasses.replace(cfg, dtype="bfloat16")
    ba = _batch_axes(mesh)
    if arch == "dien" and roofline:
        cfg = dataclasses.replace(cfg, unroll=cfg.seq_len)
    params_sds = _recsys_init(arch, cfg, abstract=True)
    pspec = _recsys_pspec(arch, params_sds)

    def fix_ba(spec):  # replace ("data",) with mesh batch axes
        parts = tuple(ba if p == ("data",) else p for p in spec)
        return P(*parts)

    if shape.kind == "train":
        batch_sds, batch_spec, loss = _recsys_batch(arch, cfg, shape.batch)
        batch_spec = tuple(fix_ba(s) for s in batch_spec)
        opt_sds = opt_lib.abstract_init(params_sds, OPT_CFG)
        opt_spec = opt_lib.state_specs(pspec)
        step = trainer_lib.make_train_step(loss, OPT_CFG,
                                           param_dtype=cfg.jdtype)
        return Cell(arch=arch, shape=shape.name, fn=step,
                    args=(params_sds, opt_sds, batch_sds),
                    in_shardings=(_shard(mesh, pspec), _shard(mesh, opt_spec),
                                  _shard(mesh, batch_spec)),
                    donate_argnums=(0, 1))

    if shape.kind == "serve":
        batch_sds, batch_spec, _ = _recsys_batch(arch, cfg, shape.batch)
        batch_sds = batch_sds[:-1] if arch != "two-tower-retrieval" else batch_sds
        batch_spec = tuple(fix_ba(s) for s in batch_spec)[: len(batch_sds)]
        fwd = _recsys_forward(arch, cfg)
        return Cell(arch=arch, shape=shape.name, fn=fwd,
                    args=(params_sds,) + tuple(batch_sds),
                    in_shardings=(_shard(mesh, pspec),)
                    + tuple(_shard(mesh, s) for s in batch_spec))

    # retrieval: 1 query vs n_candidates (padded to shard evenly)
    ra = _row_axes(mesh)
    n_shards = 1
    for a in ra:
        n_shards *= mesh.shape[a]
    c = -(-shape.n_candidates // n_shards) * n_shards
    if arch == "two-tower-retrieval":
        uf = _sds((shape.batch, cfg.n_user_feats), jnp.int32)
        cands = _sds((c, cfg.tower_mlp[-1]), jnp.float32)

        def score(p, u, cand):
            return rec_lib.twotower_score_candidates(p, cfg, u, cand)

        return Cell(arch=arch, shape=shape.name, fn=score,
                    args=(params_sds, uf, cands),
                    in_shardings=(_shard(mesh, pspec),
                                  _shard(mesh, P(None, None)),
                                  _shard(mesh, P(ra, None))))
    # ranking archs: bulk-score c candidates for one user context
    if arch == "dien":
        hist = _sds((1, cfg.seq_len), jnp.int32)
        tgt = _sds((c,), jnp.int32)

        def score(p, h, t):
            hb = jnp.broadcast_to(h, (c, cfg.seq_len))
            return rec_lib.dien_forward(p, cfg, hb, t)

        return Cell(arch=arch, shape=shape.name, fn=score,
                    args=(params_sds, hist, tgt),
                    in_shardings=(_shard(mesh, pspec),
                                  _shard(mesh, P(None, None)),
                                  _shard(mesh, P(ra))))
    if arch == "fm":
        ids = _sds((c, cfg.n_sparse), jnp.int32)
        fwd = _recsys_forward(arch, cfg)
        return Cell(arch=arch, shape=shape.name, fn=fwd,
                    args=(params_sds, ids),
                    in_shardings=(_shard(mesh, pspec),
                                  _shard(mesh, P(ra, None))))
    # dcn-v2
    dense = _sds((c, cfg.n_dense), jnp.float32)
    ids = _sds((c, cfg.n_sparse), jnp.int32)
    fwd = _recsys_forward(arch, cfg)
    return Cell(arch=arch, shape=shape.name, fn=fwd,
                args=(params_sds, dense, ids),
                in_shardings=(_shard(mesh, pspec),
                              _shard(mesh, P(ra, None)),
                              _shard(mesh, P(ra, None))))


# ---------------------------------------------------------------------------
# remoterag (the paper's own service steps)
# ---------------------------------------------------------------------------

def remoterag_cell(shape: shp.RagShape, mesh: Mesh,
                   params: Optional[rlwe.RlweParams] = None,
                   *, roofline: bool = False,
                   scan_knob: Optional[int] = None,
                   variant: Optional[str] = None) -> Cell:
    dtype = jnp.float32
    per_tile_k = None
    if shape.kind == "module1" and variant:
        if "big" in variant:  # serving-scale stress: 64M docs, 256 queries
            shape = dataclasses.replace(shape, corpus=2 ** 26, batch=256)
        if "bf16" in variant:
            dtype = jnp.bfloat16
        if "ptk32" in variant:  # certificate-checked reduced local top-k
            per_tile_k = 32
    params = params or rlwe.RlweParams()
    ra = _row_axes(mesh)
    ba = _batch_axes(mesh)
    if shape.kind == "module1":
        corpus = _sds((shape.corpus, shape.dim), dtype)
        queries = _sds((shape.batch, shape.dim), dtype)
        search = make_sharded_topk(mesh, ra, shape.corpus, shape.kprime,
                                   per_tile_k=per_tile_k, use_pallas=False)
        return Cell(arch="remoterag", shape=shape.name,
                    fn=lambda q, c: tuple(search(q, c)),
                    args=(queries, corpus),
                    in_shardings=(_shard(mesh, P(None, None)),
                                  _shard(mesh, P(ra, None))))
    # module 2a: batched encrypted re-ranking over R requests
    chunks = params.num_chunks(shape.dim)
    cpt = params.cands_per_ct(shape.dim)
    num_ct = -(-shape.kprime // cpt)
    r = shape.batch
    c0 = _sds((r, chunks, params.num_primes, params.n_poly), jnp.int32)
    packed = _sds((r, num_ct, chunks, params.num_primes, params.n_poly),
                  jnp.int32)

    def enc_scores(c0_, c1_, packed_):
        # vectorized per-prime path, batched over (R, num_ct)
        outs0, outs1 = [], []
        from repro.kernels.ntt import ops as ntt_ops
        from repro.crypto import modring
        for i, ctx in enumerate(params.ctxs):
            f0 = ntt_ops.ntt_fwd(c0_[:, :, i, :], ctx, use_pallas=False)
            f1 = ntt_ops.ntt_fwd(c1_[:, :, i, :], ctx, use_pallas=False)
            pk = packed_[:, :, :, i, :]                  # (R, CT, CH, N)
            p0 = modring.mod_mul(pk, f0[:, None, :, :], ctx.q, ctx.mu)
            p1 = modring.mod_mul(pk, f1[:, None, :, :], ctx.q, ctx.mu)
            a0 = p0[:, :, 0, :]
            a1 = p1[:, :, 0, :]
            for ch in range(1, chunks):
                a0 = modring.mod_add(a0, p0[:, :, ch, :], ctx.q)
                a1 = modring.mod_add(a1, p1[:, :, ch, :], ctx.q)
            outs0.append(ntt_ops.ntt_inv(a0, ctx, use_pallas=False))
            outs1.append(ntt_ops.ntt_inv(a1, ctx, use_pallas=False))
        return jnp.stack(outs0, 2), jnp.stack(outs1, 2)

    return Cell(arch="remoterag", shape=shape.name, fn=enc_scores,
                args=(c0, c0, packed),
                in_shardings=(_shard(mesh, P(ba, None, None, None)),
                              _shard(mesh, P(ba, None, None, None)),
                              _shard(mesh, P(ba, None, None, None, None))))


__all__ = ["Cell", "lm_cell", "gnn_cell", "recsys_cell", "remoterag_cell",
           "OPT_CFG"]
