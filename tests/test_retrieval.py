import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.scoretopk import ref as sref
from repro.retrieval.index import FlatIndex
from repro.retrieval.topk import distributed_topk, distances_from_scores


def _corpus(rng, n_rows, n):
    e = rng.normal(size=(n_rows, n)).astype(np.float32)
    return e / np.linalg.norm(e, axis=-1, keepdims=True)


def test_unsharded_index_topk():
    rng = np.random.default_rng(0)
    e = _corpus(rng, 4000, 128)
    q = _corpus(rng, 3, 128)
    idx = FlatIndex.build(e)
    out = distributed_topk(idx, jnp.asarray(q), 20)
    want_v, want_i = sref.topk_ref(jnp.asarray(q), jnp.asarray(e), 20)
    np.testing.assert_array_equal(np.asarray(out.indices), np.asarray(want_i))
    np.testing.assert_allclose(np.asarray(out.values), np.asarray(want_v),
                               rtol=1e-6)


def test_single_device_mesh_matches_oracle():
    rng = np.random.default_rng(1)
    e = _corpus(rng, 2048, 64)
    q = _corpus(rng, 2, 64)
    mesh = jax.make_mesh((1,), ("data",))
    idx = FlatIndex.build(e, mesh=mesh)
    out = distributed_topk(idx, jnp.asarray(q), 15)
    want_v, want_i = sref.topk_ref(jnp.asarray(q), jnp.asarray(e), 15)
    np.testing.assert_array_equal(np.asarray(out.indices), np.asarray(want_i))


def test_distances_are_cosine():
    rng = np.random.default_rng(2)
    e = _corpus(rng, 100, 32)
    q = _corpus(rng, 1, 32)
    idx = FlatIndex.build(e)
    out = distributed_topk(idx, jnp.asarray(q), 5)
    d = np.asarray(distances_from_scores(out.values))
    full = 1.0 - e @ q[0]
    np.testing.assert_allclose(d[0], np.sort(full)[:5], rtol=1e-5, atol=1e-6)


def test_document_fetch_roundtrip():
    rng = np.random.default_rng(3)
    e = _corpus(rng, 64, 16)
    docs = [f"doc-{i}".encode() for i in range(64)]
    idx = FlatIndex.build(e, documents=docs)
    out = distributed_topk(idx, jnp.asarray(e[:1]), 1)
    assert idx.fetch_documents(np.asarray(out.indices)[0]) == [docs[0]]


MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
import sys
sys.path.insert(0, "src")
from repro.kernels.scoretopk import ref as sref
from repro.retrieval.index import FlatIndex
from repro.retrieval.topk import distributed_topk

rng = np.random.default_rng(7)
e = rng.normal(size=(1000, 96)).astype(np.float32)   # non-multiple of 8 shards
e /= np.linalg.norm(e, axis=-1, keepdims=True)
q = rng.normal(size=(4, 96)).astype(np.float32)
q /= np.linalg.norm(q, axis=-1, keepdims=True)

mesh = jax.make_mesh((4, 2), ("data", "model"))
idx = FlatIndex.build(e, mesh=mesh)
out = distributed_topk(idx, jnp.asarray(q), 25)
want_v, want_i = sref.topk_ref(jnp.asarray(q), jnp.asarray(e), 25)
assert np.array_equal(np.asarray(out.indices), np.asarray(want_i)), "idx mismatch"
assert np.allclose(np.asarray(out.values), np.asarray(want_v), rtol=1e-5), "val mismatch"
assert bool(out.exact)
print("MULTIDEV_OK")
"""


def test_multidevice_sharded_search():
    """8 virtual devices in a subprocess (keeps this process single-device)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SNIPPET],
                       capture_output=True, text=True, timeout=300,
                       cwd=__file__.rsplit("/tests/", 1)[0])
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr
