"""Vectorized Paillier: the object path's batch twin (ROADMAP RNS item).

`crypto/paillier.py` is the paper-faithful per-lane implementation: pure
Python bignums, one interpreter-level modmul at a time.  This module computes
the *same integers* — wire-byte identical ciphertexts given the same rng,
bit-exact decryptions — but moves the modular arithmetic onto the RNS
Montgomery kernels in `repro.kernels.bignum`, batched over every lane of a
serve group at once.  Division of labor per stage:

  encrypt    r^n for all dims of a query in one windowed-modexp kernel
             (blinding r drawn host-side in the object path's exact draw
             order, so ciphertext bytes match under a shared rng)
  score      the big one: per-(lane, dim) windowed power tables for the
             query ciphertexts and their inverses, then per window position
             one gathered [lanes, k', dims] multiply + a product tree over
             dims — replacing k'·dims·popcount interpreter modmuls with a
             handful of fused array ops (candidate scalars are 15-bit
             fixed-point, so 3 windows of 5 bits cover them)
  decrypt    batched c^lambda, host L-function/mu finish

Query-ciphertext inverses (for negative fixed-point scalars) use Montgomery's
batch-inversion trick: one modular inverse plus 3 multiplies per element,
instead of one ~50us extended-gcd per (lane, dim).

Fallback: keys whose n^2 needs more residue channels than the compiled
budget (`bignum.ref.MAX_CHANNELS`, e.g. 1024-bit keys at the default
budget) transparently take the object path per lane; `counters` records
which path served each lane so tests and benches can assert the boundary.
Lanes of *different* key sizes within one batch are grouped by channel
count and each cohort runs as one kernel call.
"""

from __future__ import annotations

import functools
import math
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import paillier as pai
from repro.kernels.bignum import ops, ref

SCORE_WINDOW = 5    # 15-bit fixed-point scalars -> at most 3 window positions
EXP_WINDOW = 4      # dense (key-sized) exponents: n for blinding, lambda

# Which path served each lane-call: tests and the fallback-boundary bench
# assert on these.  reset_counters() between measurements.
counters = {"vectorized": 0, "object": 0}


def reset_counters() -> None:
    counters["vectorized"] = 0
    counters["object"] = 0


def fits(pub: pai.PaillierPublicKey) -> bool:
    """True when this key's n^2 is inside the compiled channel budget."""
    return ref.fits(pub.n_sq)


@functools.lru_cache(maxsize=64)
def _ctx(n_sq: int) -> ref.RnsModulus:
    return ref.for_modulus(n_sq)


def _draw_r(pub: pai.PaillierPublicKey,
            rng: Optional[np.random.Generator]) -> int:
    # Exact replica of paillier.encrypt's draw loop: consuming the same
    # rng stream in the same order is what makes wire bytes match.
    while True:
        r = pai._randbelow(pub.n, rng)
        if r and math.gcd(r, pub.n) == 1:
            return r


def _batch_modinv(values: Sequence[int], modulus: int) -> List[int]:
    """Montgomery batch inversion: one extended-gcd + 3 muls per element."""
    prefix = [1]
    for v in values:
        prefix.append(prefix[-1] * v % modulus)
    inv = pow(prefix[-1], -1, modulus)
    out = [0] * len(values)
    for i in range(len(values) - 1, -1, -1):
        out[i] = inv * prefix[i] % modulus
        inv = inv * values[i] % modulus
    return out


def _consts(ctxs: Sequence[ref.RnsModulus], batch_ndim: int) -> dict:
    return ops.make_consts(ctxs[0].system, list(ctxs), batch_ndim)


def _to_rns_mont(ctxs: Sequence[ref.RnsModulus],
                 rows: Sequence[Sequence[int]]) -> np.ndarray:
    """Per-lane int rows -> stacked Montgomery-form channel array
    [lanes, len(row), channels]."""
    out = [ref.to_rns(c, [v * c.system.M % c.modulus for v in row])
           for c, row in zip(ctxs, rows)]
    return np.stack(out)


@functools.partial(jax.jit, static_argnames=("window",))
def _exp_kernel(base, digits, C, window):
    table = ops.pow_table(base, C, window)
    acc = ops.mont_exp_digits(table, digits, C, window)
    return ops.mont_mul(acc, C["plain_one"], C)


@functools.partial(jax.jit, static_argnames=("wscore", "wexp"))
def _score_kernel(q, qinv, digits, signs, rbase, rdigits, C2, C3,
                  wscore, wexp):
    """One serve group's encrypted re-rank.

    q/qinv: [L, D, C] Montgomery query cts (+inverses); digits: [L, K, D, P]
    window digits of |k| (most-significant first); signs: [L, K, D] int32
    (1 = negative scalar -> inverse table); rbase: [L, K, C] Montgomery
    blinding bases; rdigits: [L, K, Pn] digits of each lane's n.
    Returns demontgomerized [L, K, C] score ciphertext channels.

    Candidates run through a `lax.scan` in chunks so the gather + product
    tree working set stays cache-sized instead of materializing the full
    [L, k', dims, C] block per window position (~20% on a 1-core host).
    """
    table = jnp.concatenate(
        [ops.pow_table(q, C2, wscore), ops.pow_table(qinv, C2, wscore)], 0)
    nlanes, kprime = digits.shape[0], digits.shape[1]
    chunk = next(c for c in (8, 4, 2, 1) if kprime % c == 0)

    def one_chunk(dig, sgn):                                  # [L, c, D, ...]
        acc = jnp.broadcast_to(C2["one"], (nlanes, chunk, table.shape[-1]))
        for p in range(dig.shape[-1]):
            acc = ops.square_n(acc, C2, wscore)
            idx = dig[..., p] + sgn * (1 << wscore)           # [L, c, D]
            g = jnp.take_along_axis(table[:, :, None], idx[None, ..., None],
                                    axis=0)[0]                # [L, c, D, C]
            acc = ops.mont_mul(acc, ops.product_reduce(g, C3), C2)
        return acc

    dch = jnp.moveaxis(
        digits.reshape(nlanes, -1, chunk, *digits.shape[2:]), 1, 0)
    sch = jnp.moveaxis(
        signs.reshape(nlanes, -1, chunk, signs.shape[-1]), 1, 0)
    _, accs = jax.lax.scan(
        lambda _, ds: (None, one_chunk(*ds)), None, (dch, sch))
    acc = jnp.moveaxis(accs, 0, 1).reshape(nlanes, kprime, -1)
    blind = ops.mont_exp_digits(ops.pow_table(rbase, C2, wexp),
                                rdigits, C2, wexp)
    return ops.mont_mul(ops.mont_mul(acc, blind, C2), C2["plain_one"], C2)


def _from_channels(ctx: ref.RnsModulus, arr: np.ndarray) -> List[int]:
    return [v % ctx.modulus for v in ref.from_rns(ctx, arr)]


def encrypt_vector(pub: pai.PaillierPublicKey, e: np.ndarray,
                   rng: Optional[np.random.Generator] = None) -> list:
    """Drop-in for `paillier.encrypt_vector`: same bytes, batched r^n."""
    e = np.asarray(e, np.float64)
    if not fits(pub) or len(e) == 0:
        counters["object"] += 1
        return pai.encrypt_vector(pub, e, rng)
    counters["vectorized"] += 1
    ms = pai.encode_vector(e, pub.n)     # one batched call, not per-lane
    rs = [_draw_r(pub, rng) for _ in ms]
    ctx = _ctx(pub.n_sq)
    with jax.experimental.enable_x64():
        C = _consts([ctx], batch_ndim=2)
        base = _to_rns_mont([ctx], [rs])
        ndig = ops.to_digits([pub.n], EXP_WINDOW)
        digits = np.ascontiguousarray(np.broadcast_to(
            ndig[:, None, :], (1, len(ms), ndig.shape[-1])))
        rn = np.asarray(_exp_kernel(base, digits, C, EXP_WINDOW))
    rn_ints = _from_channels(ctx, rn[0])
    return [(1 + m * pub.n) % pub.n_sq * x % pub.n_sq
            for m, x in zip(ms, rn_ints)]


def encrypted_scores_batch(
        pubs: Sequence[pai.PaillierPublicKey],
        enc_queries: Sequence[Sequence[int]],
        cands: Sequence[np.ndarray],
        rngs: Optional[Sequence[Optional[np.random.Generator]]] = None,
) -> List[list]:
    """Batched `paillier.encrypted_scores` across lanes.

    ``cands[i]`` is lane i's [k', dims] candidate block (same shape across
    lanes — the serve group contract).  ``rngs`` supplies per-lane blinding
    randomness in the object path's draw order; None draws from `secrets`.
    Oversized keys fall back per lane.  Returns per-lane ciphertext lists.
    """
    nlanes = len(pubs)
    if rngs is None:
        rngs = [None] * nlanes
    out: List[Optional[list]] = [None] * nlanes

    # Blinding must be drawn lane-by-lane in candidate order *before* any
    # cohort regrouping, to consume each lane's stream exactly as the
    # object path would.
    cohorts: dict = {}
    for i, pub in enumerate(pubs):
        kprime = np.asarray(cands[i]).shape[0]
        if not fits(pub):
            counters["object"] += 1
            out[i] = pai.encrypted_scores(pub, enc_queries[i], cands[i],
                                          rng=rngs[i])
            continue
        counters["vectorized"] += 1
        rs = [_draw_r(pub, rngs[i]) for _ in range(kprime)]
        cohorts.setdefault(ref.num_channels(pub.n_sq), []).append((i, rs))

    for s, members in cohorts.items():
        lanes = [i for i, _ in members]
        ctxs = [_ctx(pubs[i].n_sq) for i in lanes]
        blk = np.stack([np.asarray(cands[i], np.float64) for i in lanes])
        ks = np.rint(blk * (1 << pai.FRAC_BITS)).astype(np.int64)
        signs = (ks < 0).astype(np.int32)
        kabs = np.abs(ks)
        npos = max(1, -(-int(kabs.max()).bit_length() // SCORE_WINDOW))
        shifts = SCORE_WINDOW * np.arange(npos - 1, -1, -1)
        digits = ((kabs[..., None] >> shifts)
                  & ((1 << SCORE_WINDOW) - 1)).astype(np.int32)
        qs = [list(enc_queries[i]) for i in lanes]
        qinvs = [_batch_modinv(row, ctx.modulus)
                 for row, ctx in zip(qs, ctxs)]
        ndig = ops.to_digits([pubs[i].n for i in lanes], EXP_WINDOW)
        kprime = blk.shape[1]
        with jax.experimental.enable_x64():
            res = _score_kernel(
                _to_rns_mont(ctxs, qs),
                _to_rns_mont(ctxs, qinvs),
                digits, signs,
                _to_rns_mont(ctxs, [rs for _, rs in members]),
                np.ascontiguousarray(np.broadcast_to(
                    ndig[:, None, :], (len(lanes), kprime, ndig.shape[-1]))),
                _consts(ctxs, batch_ndim=2), _consts(ctxs, batch_ndim=3),
                SCORE_WINDOW, EXP_WINDOW)
            res = np.asarray(res)
        for j, i in enumerate(lanes):
            out[i] = _from_channels(ctxs[j], res[j])
    return out


def decrypt_scores_batch(sks: Sequence[pai.PaillierSecretKey],
                         enc_lists: Sequence[Sequence[int]],
                         ) -> List[np.ndarray]:
    """Batched `paillier.decrypt_scores`: c^lambda in one kernel per cohort,
    L-function + centered fixed-point decode on the host (bit-exact)."""
    nlanes = len(sks)
    out: List[Optional[np.ndarray]] = [None] * nlanes
    cohorts: dict = {}
    for i, sk in enumerate(sks):
        if not fits(sk.pub) or len(enc_lists[i]) == 0:
            counters["object"] += 1
            out[i] = pai.decrypt_scores(sk, enc_lists[i])
            continue
        counters["vectorized"] += 1
        cohorts.setdefault(ref.num_channels(sk.pub.n_sq), []).append(i)

    for s, lanes in cohorts.items():
        ctxs = [_ctx(sks[i].pub.n_sq) for i in lanes]
        kprime = len(enc_lists[lanes[0]])
        ldig = ops.to_digits([sks[i].lam for i in lanes], EXP_WINDOW)
        with jax.experimental.enable_x64():
            res = np.asarray(_exp_kernel(
                _to_rns_mont(ctxs, [enc_lists[i] for i in lanes]),
                np.ascontiguousarray(np.broadcast_to(
                    ldig[:, None, :], (len(lanes), kprime, ldig.shape[-1]))),
                _consts(ctxs, batch_ndim=2), EXP_WINDOW))
        for j, i in enumerate(lanes):
            sk = sks[i]
            xs = _from_channels(ctxs[j], res[j])
            ms = [(x - 1) // sk.pub.n * sk.mu % sk.pub.n for x in xs]
            out[i] = np.asarray(
                [pai._decode(m, sk.pub.n, 2 * pai.FRAC_BITS) for m in ms],
                np.float64)
    return out


__all__ = ["fits", "encrypt_vector", "encrypted_scores_batch",
           "decrypt_scores_batch", "counters", "reset_counters",
           "SCORE_WINDOW", "EXP_WINDOW"]
