import numpy as np
import pytest

from repro.crypto import paillier


@pytest.fixture(scope="module")
def sk():
    return paillier.keygen(bits=256)  # small test key; 1024+ in benchmarks


def test_roundtrip(sk):
    for m in (0, 1, 42, sk.pub.n - 1, sk.pub.n // 2):
        assert paillier.decrypt(sk, paillier.encrypt(sk.pub, m)) == m % sk.pub.n


def test_additive_homomorphism(sk):
    c = paillier.add(sk.pub, paillier.encrypt(sk.pub, 1234),
                     paillier.encrypt(sk.pub, 4321))
    assert paillier.decrypt(sk, c) == 5555


def test_plain_multiplication(sk):
    c = paillier.mul_plain(sk.pub, paillier.encrypt(sk.pub, 77), 13)
    assert paillier.decrypt(sk, c) == 1001


def test_probabilistic_encryption(sk):
    assert paillier.encrypt(sk.pub, 5) != paillier.encrypt(sk.pub, 5)


def test_encrypted_dot_matches_plain(sk):
    rng = np.random.default_rng(0)
    q = rng.normal(size=32)
    q /= np.linalg.norm(q)
    cands = rng.normal(size=(6, 32))
    cands /= np.linalg.norm(cands, axis=-1, keepdims=True)
    enc_q = paillier.encrypt_vector(sk.pub, q)
    scores = paillier.decrypt_scores(
        sk, paillier.encrypted_scores(sk.pub, enc_q, cands))
    np.testing.assert_allclose(scores, cands @ q, atol=2e-3)


def test_encrypted_dot_negative_values(sk):
    q = np.array([-0.5, 0.5, -0.5, 0.5])
    c = np.array([[0.5, 0.5, 0.5, 0.5]])
    enc_q = paillier.encrypt_vector(sk.pub, q)
    scores = paillier.decrypt_scores(
        sk, paillier.encrypted_scores(sk.pub, enc_q, c))
    assert scores[0] == pytest.approx(0.0, abs=1e-3)


def test_encode_vector_matches_scalar_encode():
    """The batched fixed-point encode is bit-identical to the per-component
    scalar path, including round-half-even ties and negative residues."""
    n = (1 << 255) + 97
    rng = np.random.default_rng(0)
    e = rng.normal(size=257)
    assert paillier.encode_vector(e, n) == \
        [paillier._encode(v, n) for v in e]
    # exact .5 ties at the rounding boundary, both signs, plus zeros
    step = 1.0 / (1 << paillier.FRAC_BITS)
    ties = np.array([(k + 0.5) * step for k in range(-8, 8)] + [0.0, -0.0])
    assert paillier.encode_vector(ties, n) == \
        [paillier._encode(v, n) for v in ties]


def test_ciphertext_size_model(sk):
    assert sk.pub.ciphertext_bytes() == pytest.approx(2 * 256 / 8, abs=2)
