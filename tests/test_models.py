"""Model zoo unit tests (reduced sizes, CPU)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import embedder, gnn, layers, moe, recsys, transformer

# multi-minute on CPU even at reduced sizes; run with `pytest -m ""`
pytestmark = pytest.mark.slow


def _tiny_cfg(**kw):
    base = dict(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                d_ff=128, vocab=997, d_head=16, dtype="float32", remat=False,
                kv_chunk=32)
    base.update(kw)
    return transformer.TransformerConfig(**base)


def test_chunked_attention_matches_naive():
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    out = layers.chunked_attention(q, k, v, causal=True, kv_chunk=16)
    # naive reference
    kr = jnp.repeat(k, hq // hkv, axis=2)
    vr = jnp.repeat(v, hq // hkv, axis=2)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(d)
    mask = jnp.tril(jnp.ones((s, s), bool))
    s_ = jnp.where(mask[None, None], s_, -jnp.inf)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s_, -1), vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_transformer_forward_shapes_no_nans():
    cfg = _tiny_cfg()
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jnp.ones((2, 16), jnp.int32)
    logits, aux = transformer.forward(params, cfg, tokens)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_transformer_decode_matches_forward():
    """Prefill + decode must agree with full forward on the same tokens."""
    cfg = _tiny_cfg()
    params = transformer.init_params(jax.random.PRNGKey(1), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab)
    full, _ = transformer.forward(params, cfg, tokens)
    logits_pre, cache = transformer.prefill(params, cfg, tokens[:, :8],
                                            max_len=16)
    np.testing.assert_allclose(np.asarray(logits_pre), np.asarray(full[:, :8]),
                               rtol=2e-4, atol=2e-4)
    lg, cache = transformer.decode_step(params, cfg, tokens[:, 8:9], cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 8]),
                               rtol=2e-4, atol=2e-4)
    lg, cache = transformer.decode_step(params, cfg, tokens[:, 9:10], cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 9]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("tp,heads,kv", [
    (8, 4, 2),    # q-padding -> MHA-ized kv
    (2, 4, 2),    # no padding needed at all
    (4, 8, 2),    # consecutive-repeat kv path (like llama3 on tp=16)
])
def test_gqa_tp_padding_preserves_math(tp, heads, kv):
    """tp-padded params (zero extra q heads, mapped kv) == unpadded model."""
    cfg = _tiny_cfg(n_heads=heads, n_kv_heads=kv, d_model=heads * 16)
    cfg_pad = _tiny_cfg(n_heads=heads, n_kv_heads=kv, d_model=heads * 16, tp=tp)
    params = transformer.init_params(jax.random.PRNGKey(3), cfg)
    spec, pspec = cfg.attn_spec, cfg_pad.attn_spec
    p = params["layers"]["attn"]
    src = pspec.kv_head_source()

    def pad_q(w):  # (L, d_model, hq*d) -> zero-pad new heads
        L, dm, _ = w.shape
        w4 = w.reshape(L, dm, spec.n_heads, spec.d_head)
        pad = jnp.zeros((L, dm, pspec.padded_heads - spec.n_heads, spec.d_head),
                        w.dtype)
        return jnp.concatenate([w4, pad], 2).reshape(L, dm, -1)

    def map_kv(w):  # gather source kv heads per the spec's mapping
        L, dm, _ = w.shape
        w4 = w.reshape(L, dm, spec.n_kv_heads, spec.d_head)
        return w4[:, :, src, :].reshape(L, dm, -1)

    def pad_o(w):  # (L, hq*d, d_model)
        L, _, dm = w.shape
        w4 = w.reshape(L, spec.n_heads, spec.d_head, dm)
        pad = jnp.zeros((L, pspec.padded_heads - spec.n_heads, spec.d_head, dm),
                        w.dtype)
        return jnp.concatenate([w4, pad], 1).reshape(L, -1, dm)

    padded = dict(params)
    padded["layers"] = dict(params["layers"])
    padded["layers"]["attn"] = {
        "wq": pad_q(p["wq"]), "wk": map_kv(p["wk"]), "wv": map_kv(p["wv"]),
        "wo": pad_o(p["wo"]),
    }
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab)
    out1, _ = transformer.forward(params, cfg, tokens)
    out2, _ = transformer.forward(padded, cfg_pad, tokens)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-4, atol=2e-4)


def test_assigned_arch_head_padding_rules():
    """The five assigned LMs on the 16-way TP mesh (DESIGN.md table)."""
    from repro.models.layers import AttentionSpec
    mk = lambda h, kv: AttentionSpec(d_model=h * 128, n_heads=h,
                                     n_kv_heads=kv, d_head=128, tp_pad_to=16)
    assert (mk(32, 8).padded_heads, mk(32, 8).padded_kv_heads) == (32, 16)
    assert (mk(40, 8).padded_heads, mk(40, 8).padded_kv_heads) == (48, 48)
    assert (mk(32, 4).padded_heads, mk(32, 4).padded_kv_heads) == (32, 16)
    assert (mk(24, 8).padded_heads, mk(24, 8).padded_kv_heads) == (32, 32)
    # every padded count divides by 16
    for h, kv in ((32, 8), (40, 8), (32, 4), (24, 8)):
        s = mk(h, kv)
        assert s.padded_heads % 16 == 0 and s.padded_kv_heads % 16 == 0
        # mapping is group-consistent for every real q head
        src = s.kv_head_source()
        group_p = s.padded_heads // s.padded_kv_heads
        for q in range(s.n_heads):
            assert src[q // group_p] == q // (s.n_heads // s.n_kv_heads)


def test_moe_forward_and_aux():
    spec = moe.MoeSpec(d_model=32, d_ff=64, n_experts=6, top_k=2, ep_pad_to=4)
    assert spec.padded_experts == 8
    params = moe.moe_params(jax.random.PRNGKey(0), spec, jnp.float32, False)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32))
    out, aux = moe.moe_fwd(params, x, spec)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0


def test_moe_capacity_drops_gracefully():
    spec = moe.MoeSpec(d_model=16, d_ff=16, n_experts=2, top_k=1,
                       capacity_factor=0.5)
    params = moe.moe_params(jax.random.PRNGKey(0), spec, jnp.float32, False)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    out, _ = moe.moe_fwd(params, x, spec)
    assert np.isfinite(np.asarray(out)).all()


def test_moe_matches_dense_expert_computation():
    """With E=1, k=1 and huge capacity, MoE == its single expert's MLP."""
    spec = moe.MoeSpec(d_model=16, d_ff=32, n_experts=1, top_k=1,
                       capacity_factor=4.0)
    params = moe.moe_params(jax.random.PRNGKey(5), spec, jnp.float32, False)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 8, 16))
    out, _ = moe.moe_fwd(params, x, spec)
    h = jax.nn.silu(x @ params["w_gate"][0]) * (x @ params["w_up"][0])
    want = h @ params["w_down"][0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_gnn_forward_and_loss():
    cfg = gnn.GnnConfig(n_layers=3, d_hidden=32, d_feat=8, n_vars=4,
                        dtype="float32", remat=False)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    v, e = 30, 64
    rng = np.random.default_rng(0)
    batch = gnn.GraphBatch(
        node_feats=jnp.asarray(rng.normal(size=(v, 8)), jnp.float32),
        edge_src=jnp.asarray(rng.integers(0, v, e), jnp.int32),
        edge_dst=jnp.asarray(rng.integers(0, v, e), jnp.int32),
        targets=jnp.asarray(rng.normal(size=(v, 4)), jnp.float32))
    pred = gnn.forward(params, cfg, batch)
    assert pred.shape == (v, 4)
    loss = gnn.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))


def test_gnn_neighbor_sampler():
    rng = np.random.default_rng(1)
    v, e = 200, 1000
    src = rng.integers(0, v, e)
    dst = rng.integers(0, v, e)
    offsets, nbrs = gnn.build_csr(src, dst, v)
    assert offsets[-1] == e
    nodes, s, d = gnn.sample_fanout(rng, offsets, nbrs,
                                    np.arange(10), fanouts=(5, 3))
    assert len(s) == len(d) > 0
    assert s.max() < len(nodes) and d.max() < len(nodes)


def test_fm_sum_square_trick():
    cfg = recsys.FmConfig(n_sparse=5, embed_dim=4, vocab_per_field=100)
    params = recsys.fm_init(jax.random.PRNGKey(0), cfg)
    ids = jnp.asarray(np.random.default_rng(0).integers(0, 500, (3, 5)),
                      jnp.int32)
    logits = recsys.fm_forward(params, cfg, ids)
    # brute-force pairwise check
    emb = np.asarray(recsys.embedding_lookup(params["table"], ids))
    lin = np.asarray(recsys.embedding_lookup(params["linear"], ids))[..., 0]
    want = []
    for b in range(3):
        tot = float(params["bias"]) + lin[b].sum()
        for i in range(5):
            for j in range(i + 1, 5):
                tot += float(emb[b, i] @ emb[b, j])
        want.append(tot)
    np.testing.assert_allclose(np.asarray(logits), want, rtol=1e-4)


def test_twotower_loss_and_scoring():
    cfg = recsys.TwoTowerConfig(embed_dim=16, tower_mlp=(32, 16),
                                user_vocab=1000, item_vocab=1000,
                                n_user_feats=3, n_item_feats=2)
    params = recsys.twotower_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    uf = jnp.asarray(rng.integers(0, 1000, (8, 3)), jnp.int32)
    itf = jnp.asarray(rng.integers(0, 1000, (8, 2)), jnp.int32)
    loss = recsys.twotower_loss(params, cfg, uf, itf)
    assert np.isfinite(float(loss))
    cands = jnp.asarray(rng.normal(size=(50, 16)), jnp.float32)
    scores = recsys.twotower_score_candidates(params, cfg, uf[:1], cands)
    assert scores.shape == (1, 50)


def test_dien_forward():
    cfg = recsys.DienConfig(embed_dim=8, seq_len=12, gru_dim=16, mlp=(20, 8),
                            item_vocab=500)
    params = recsys.dien_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    hist = jnp.asarray(rng.integers(0, 500, (4, 12)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, 500, (4,)), jnp.int32)
    logits = recsys.dien_forward(params, cfg, hist, tgt)
    assert logits.shape == (4,)
    loss = recsys.dien_loss(params, cfg, hist, tgt,
                            jnp.asarray([0., 1., 0., 1.]))
    assert np.isfinite(float(loss))


def test_dcnv2_forward():
    cfg = recsys.DcnV2Config(n_dense=4, n_sparse=6, embed_dim=8,
                             n_cross_layers=2, mlp=(32, 16),
                             vocab_per_field=100)
    params = recsys.dcnv2_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    dense = jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 600, (5, 6)), jnp.int32)
    logits = recsys.dcnv2_forward(params, cfg, dense, ids)
    assert logits.shape == (5,)
    assert np.isfinite(np.asarray(logits)).all()


def test_embedding_bag_modes():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([0, 1, 2, 5], jnp.int32)
    segs = jnp.asarray([0, 0, 1, 1], jnp.int32)
    s = recsys.embedding_bag(table, ids, segs, 2, mode="sum")
    m = recsys.embedding_bag(table, ids, segs, 2, mode="mean")
    np.testing.assert_allclose(np.asarray(s[0]), [2.0, 4.0])
    np.testing.assert_allclose(np.asarray(m[1]), [7.0, 8.0])


def test_embedder_unit_norm():
    cfg = embedder.encoder_config(dim=128, vocab=512, n_layers=2)
    params = embedder.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 0, 512)
    e = embedder.embed(params, cfg, tokens)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(e), axis=-1), 1.0,
                               atol=1e-4)


def test_abstract_params_match_real_shapes():
    cfg = _tiny_cfg(moe_experts=4, moe_top_k=2, moe_d_ff=32)
    real = transformer.init_params(jax.random.PRNGKey(0), cfg)
    abst = transformer.abstract_params(cfg)
    real_shapes = jax.tree.map(lambda x: (x.shape, str(x.dtype)), real)
    abst_shapes = jax.tree.map(lambda x: (x.shape, str(x.dtype)), abst)
    assert real_shapes == abst_shapes
