"""End-to-end RemoteRAG protocol: recall vs plaintext oracle, both backends,
both module-2 paths, transcript accounting."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.core.baselines import privacy_conscious_service, privacy_ignorant_service
from repro.data import synth
from repro.retrieval.index import FlatIndex
from repro.retrieval.topk import distributed_topk


def _setup(rng, n_docs=2000, dim=384, kind="uniform"):
    if kind == "uniform":
        emb = synth.uniform_corpus(rng, n_docs, dim)
    else:
        emb = synth.clustered_corpus(rng, n_docs, dim)
    docs = [f"passage-{i}".encode() for i in range(n_docs)]
    return FlatIndex.build(emb, documents=docs), emb


def _plain_topk(emb, e, k):
    return np.argsort(-(emb @ e), kind="stable")[:k]


@pytest.mark.parametrize("backend", ["rlwe", "paillier"])
def test_protocol_recall_and_docs(backend):
    rng = np.random.default_rng(0)
    index, emb = _setup(rng)
    k = 5
    user = protocol.RemoteRagUser(n=384, N=2000, k=k, radius=0.05,
                                  backend=backend, rng=rng)
    cloud = protocol.RemoteRagCloud(index, rlwe_params=getattr(
        user, "rlwe_params", None))
    e = synth.queries_near_corpus(rng, emb, 1)[0]
    docs, ids, tr = protocol.run_remoterag(user, cloud, e, jax.random.PRNGKey(0))
    want = _plain_topk(emb, e, k)
    assert set(ids.tolist()) == set(want.tolist()), (ids, want)
    assert docs == [f"passage-{i}".encode() for i in ids]
    assert tr.total_bytes > 0 and tr.request_bytes > 0


def test_protocol_recall_sweep_uniform():
    """Paper Table 3 (reduced): recall must be 100% across k and r."""
    rng = np.random.default_rng(1)
    index, emb = _setup(rng, n_docs=5000, dim=384)
    for k in (5, 10):
        for r in (0.03, 0.07):
            user = protocol.RemoteRagUser(n=384, N=5000, k=k, radius=r,
                                          backend="rlwe", rng=rng)
            cloud = protocol.RemoteRagCloud(index, rlwe_params=user.rlwe_params)
            e = synth.queries_near_corpus(rng, emb, 1)[0]
            _, ids, _ = protocol.run_remoterag(user, cloud, e,
                                               jax.random.PRNGKey(k * 100))
            want = _plain_topk(emb, e, k)
            recall = len(set(ids.tolist()) & set(want.tolist())) / k
            assert recall == 1.0, (k, r, recall)


def test_ot_path_used_when_budget_tight():
    rng = np.random.default_rng(2)
    index, emb = _setup(rng, n_docs=500, dim=64)
    user = protocol.RemoteRagUser(n=64, N=500, k=3, eps=40.0, backend="rlwe",
                                  rng=rng)
    assert user.plan.use_ot
    cloud = protocol.RemoteRagCloud(index, rlwe_params=user.rlwe_params)
    e = synth.queries_near_corpus(rng, emb, 1)[0]
    docs, ids, tr = protocol.run_remoterag(user, cloud, e, jax.random.PRNGKey(7))
    assert tr.path == "ot" and tr.ot_wire_bytes > 0 and tr.fetch_bytes == 0
    assert docs == [f"passage-{i}".encode() for i in ids]


def test_direct_path_used_when_budget_loose():
    rng = np.random.default_rng(3)
    index, emb = _setup(rng, n_docs=500, dim=64)
    user = protocol.RemoteRagUser(n=64, N=500, k=3, radius=0.05,
                                  backend="rlwe", rng=rng)
    assert not user.plan.use_ot
    cloud = protocol.RemoteRagCloud(index, rlwe_params=user.rlwe_params)
    e = synth.queries_near_corpus(rng, emb, 1)[0]
    _, _, tr = protocol.run_remoterag(user, cloud, e, jax.random.PRNGKey(8))
    assert tr.path == "direct" and tr.fetch_bytes > 0 and tr.ot_wire_bytes == 0


def test_perturbed_embedding_differs_from_query():
    """The cloud must never see e_k: the request carries e_k' != e_k and an
    encryption of e_k."""
    rng = np.random.default_rng(4)
    user = protocol.RemoteRagUser(n=128, N=1000, k=5, radius=0.05,
                                  backend="rlwe", rng=rng)
    e = synth.uniform_corpus(rng, 1, 128)[0]
    req = user.make_request(e, jax.random.PRNGKey(1))
    d = np.linalg.norm(req.perturbed - e)
    assert d > 0.01  # the DistanceDP radius
    assert req.kprime == user.plan.kprime


def test_baselines_agree_with_protocol():
    rng = np.random.default_rng(5)
    index, emb = _setup(rng, n_docs=300, dim=64)
    e = synth.queries_near_corpus(rng, emb, 1)[0]
    ign = privacy_ignorant_service(index, e, 5)
    con = privacy_conscious_service(index, e, 5, backend="rlwe", rng=rng)
    want = _plain_topk(emb, e, 5)
    assert set(ign.ids.tolist()) == set(want.tolist())
    assert set(con.ids.tolist()) == set(want.tolist())
    assert con.wire_bytes > ign.wire_bytes  # privacy has a price
