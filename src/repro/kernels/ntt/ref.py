"""Pure-jnp reference for the negacyclic NTT (oracle for the Pallas kernel).

Implements the Longa-Naehrig merged-psi NTT (CT forward: standard -> bit-rev
order; GS inverse: bit-rev -> standard) with the same int32-lane-safe modular
primitives the kernel uses, expressed as plain jnp reshapes/broadcasts so XLA
(not Pallas) executes it.  A second, fully independent numpy-int64 oracle
(`modring.negacyclic_mul_np`) backs the tests.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.crypto import modring
from repro.crypto.modring import PrimeCtx


def ntt_fwd_ref(x, ctx: PrimeCtx):
    """Forward negacyclic NTT. x: (..., N) int32 in [0, q). Out bit-rev order."""
    n = ctx.n
    assert x.shape[-1] == n
    a = jnp.asarray(x, jnp.int32)
    psi = jnp.asarray(ctx.psi_table)
    lead = a.shape[:-1]
    t = n
    m = 1
    while m < n:
        t //= 2
        g = a.reshape(lead + (m, 2, t))
        s = psi[m : 2 * m].reshape((1,) * len(lead) + (m, 1))
        u = g[..., 0, :]
        v = modring.mod_mul(g[..., 1, :], s, ctx.q, ctx.mu)
        a = jnp.stack(
            [modring.mod_add(u, v, ctx.q), modring.mod_sub(u, v, ctx.q)], axis=-2
        ).reshape(lead + (n,))
        m *= 2
    return a


def ntt_inv_ref(x, ctx: PrimeCtx):
    """Inverse negacyclic NTT. Input bit-rev order, output standard order."""
    n = ctx.n
    assert x.shape[-1] == n
    a = jnp.asarray(x, jnp.int32)
    ipsi = jnp.asarray(ctx.ipsi_table)
    lead = a.shape[:-1]
    t = 1
    m = n
    while m > 1:
        h = m // 2
        g = a.reshape(lead + (h, 2, t))
        s = ipsi[h : 2 * h].reshape((1,) * len(lead) + (h, 1))
        u = g[..., 0, :]
        v = g[..., 1, :]
        a = jnp.stack(
            [
                modring.mod_add(u, v, ctx.q),
                modring.mod_mul(modring.mod_sub(u, v, ctx.q), s, ctx.q, ctx.mu),
            ],
            axis=-2,
        ).reshape(lead + (n,))
        t *= 2
        m = h
    n_inv = jnp.int32(ctx.n_inv)
    return modring.mod_mul(a, n_inv, ctx.q, ctx.mu)


def negacyclic_mul_ref(a, b, ctx: PrimeCtx):
    """Negacyclic a*b in Z_q[X]/(X^N+1) via the reference NTT."""
    fa = ntt_fwd_ref(a, ctx)
    fb = ntt_fwd_ref(b, ctx)
    return ntt_inv_ref(modring.mod_mul(fa, fb, ctx.q, ctx.mu), ctx)


def random_poly(rng: np.random.Generator, shape, q: int) -> np.ndarray:
    return rng.integers(0, q, size=shape, dtype=np.int64).astype(np.int32)


__all__ = ["ntt_fwd_ref", "ntt_inv_ref", "negacyclic_mul_ref", "random_poly"]
