"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite]. 24 heads / 40 experts are
not divisible by 16 -> heads pad to 32 (KV MHA-izes), experts pad to 48."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=512, vocab=49155, d_head=64, rope_theta=10_000.0,
    moe_experts=40, moe_top_k=8, moe_d_ff=512, tp=16)

REDUCED = TransformerConfig(
    name="granite-moe-smoke", n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=64, vocab=1024, d_head=16, moe_experts=5, moe_top_k=2, moe_d_ff=64,
    dtype="float32", remat=False, kv_chunk=64)
