"""End-to-end driver: a private RAG *service* with a real embedding model.

    PYTHONPATH=src python examples/private_rag_serve.py

1. builds the in-framework text embedder (mean-pooled transformer encoder),
2. embeds a synthetic passage corpus and indexes it,
3. stands up the micro-batching `repro.serve` engine with one session per
   tenant and pushes all tenants' queries through it — the cloud only ever
   sees DistanceDP-perturbed embeddings and RLWE ciphertexts, and the
   encrypted re-rank runs once per *batch* instead of once per query,
4. reports recall vs the plaintext pipeline, per-request wire bytes, and the
   engine's per-tenant latency/byte metrics.

This is the serving-kind end-to-end deliverable (the training-kind one is
examples/train_lm.py).  Pass --no-batch to compare against the sequential
one-query-at-a-time path, and --trace-out trace.json to record a stage-
level span timeline (repro.obs) viewable at https://ui.perfetto.dev —
spans carry only sizes/shard ids/tenant ids, never query-derived payloads.
"""

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.data import synth
from repro.data.tokenizer import HashTokenizer
from repro.models import embedder
from repro.retrieval.index import FlatIndex
from repro.serve import AdmissionError, EngineConfig, ServeEngine

DIM = 256
N_DOCS = 2_000
SEQ = 32
K = 5


def main() -> None:
    ap = argparse.ArgumentParser(
        description="End-to-end private RAG service over the repro.serve "
                    "micro-batching engine.")
    ap.add_argument("--no-batch", action="store_true",
                    help="run the sequential one-query-at-a-time comparison "
                         "path instead of micro-batching")
    ap.add_argument("--no-candidate-cache", action="store_true",
                    help="disable the NTT-domain candidate cache: the cloud "
                         "re-packs + forward-NTTs the k' candidates on every "
                         "request (cold reference path; bit-identical "
                         "results, ~6x slower re-rank)")
    ap.add_argument("--cache-shard-docs", type=int, default=None,
                    metavar="DOCS",
                    help="serve the re-rank from the sharded corpus-scale "
                         "cache with DOCS documents per shard (host-pooled "
                         "shards + per-request gather of only the k' "
                         "selected candidates) instead of the dense "
                         "device-resident cache")
    ap.add_argument("--cache-budget-mb", type=float, default=None,
                    metavar="MB",
                    help="device-memory budget for LRU-pinned hot shards of "
                         "the sharded cache (0 = stream-only, no pinning; "
                         "default: unbounded).  Implies --cache-shard-docs' "
                         "sharded mode when set")
    ap.add_argument("--sync-admission", action="store_true",
                    help="sharded cache: use the deterministic legacy "
                         "admission mode (synchronous first-touch LRU, "
                         "copy in the request path) instead of the default "
                         "async frequency-aware admitter (2nd-touch policy, "
                         "background H2D copy, engine prefetch overlap)")
    ap.add_argument("--rounds", type=int, default=1, metavar="N",
                    help="submit the query set N times (default 1).  With "
                         "hot sharded-cache shards (e.g. --cache-shard-docs "
                         "1000 --rounds 2), repeat rounds cross the "
                         "2nd-touch admission threshold, so a traced run "
                         "shows the background shard admissions overlapping "
                         "the encrypt stage on the timeline")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable stage-level tracing and write a Perfetto-"
                         "loadable Chrome-trace JSON timeline to PATH "
                         "(spans carry only structural fields — see "
                         "docs/observability.md)")
    args = ap.parse_args()

    cache_config = None
    if args.cache_shard_docs is not None or args.cache_budget_mb is not None:
        from repro.crypto import rlwe
        budget = (None if args.cache_budget_mb is None
                  else int(args.cache_budget_mb * 2**20))
        cache_config = rlwe.CandidateCacheConfig(
            shard_docs=args.cache_shard_docs, max_resident_bytes=budget,
            async_admission=not args.sync_admission)

    rng = np.random.default_rng(0)
    tok = HashTokenizer(vocab_size=8192)
    cfg = embedder.encoder_config(dim=DIM, vocab=8192, n_layers=2)
    params = embedder.init_params(jax.random.PRNGKey(0), cfg)
    embed = jax.jit(lambda t: embedder.embed(params, cfg, t))

    # corpus: synthetic "passages" with topical token structure
    topics = ["weather storm rain wind", "finance stock bond market",
              "health doctor medicine flu", "sports game team score",
              "music concert guitar song", "travel flight hotel beach"]
    passages = []
    for i in range(N_DOCS):
        t = topics[i % len(topics)]
        extra = " ".join(f"w{rng.integers(0, 500)}" for _ in range(12))
        passages.append(f"{t} {extra}")

    print(f"embedding {N_DOCS} passages with {cfg.name} ...")
    ids = tok.encode_batch(passages, SEQ)
    embs = np.asarray(jax.lax.map(
        embed, jnp.asarray(ids).reshape(-1, 50, SEQ)).reshape(N_DOCS, DIM))
    index = FlatIndex.build(embs, documents=[p.encode() for p in passages])

    engine = ServeEngine(index, config=EngineConfig(
        max_batch=4, sequential=args.no_batch,
        use_candidate_cache=not args.no_candidate_cache,
        cache_config=cache_config,
        trace=args.trace_out is not None))

    queries = ["rain and storms this weekend", "stock market crash bond",
               "flu medicine from the doctor"]
    tenants = [f"user-{i}" for i in range(len(queries))]
    for t in tenants:
        engine.open_session(t, n=DIM, N=N_DOCS, k=K, radius=0.05,
                            backend="rlwe")
    plan = engine.sessions.get(tenants[0]).plan
    cache = engine.sessions.plan_cache
    print(f"plan: k'={plan.kprime}, path={plan.path} "
          f"(plan cache: {cache.hits} hits / {cache.misses} misses)")

    embedded = [(tenant, qtext,
                 np.asarray(embed(jnp.asarray(
                     tok.encode_batch([qtext], SEQ))))[0])
                for tenant, qtext in zip(tenants, queries)]
    q_embs = {}
    for rnd in range(max(args.rounds, 1)):
        for qi, (tenant, qtext, q_emb) in enumerate(embedded):
            # typed backpressure: with admission control configured a
            # submit can be rejected (RateLimited, QueueFull, ...) — a
            # client reports it and keeps serving the rest of its queue
            try:
                rid = engine.submit(
                    tenant, q_emb,
                    key=jax.random.PRNGKey(rnd * len(embedded) + qi))
            except AdmissionError as e:
                print(f"rejected ({type(e).__name__}): {qtext!r}")
                continue
            q_embs[rid] = (qtext, q_emb)
    results = engine.drain()

    for res in results:
        if res.shed_reason is not None:
            print(f"shed ({res.shed_reason}): request {res.request_id} "
                  f"for tenant {res.tenant}")
            continue
        assert res.ok, f"dispatch failed: {res.error}"
        qtext, q_emb = q_embs[res.request_id]
        oracle = np.argsort(-(embs @ q_emb), kind="stable")[:K]
        recall = len(set(res.ids.tolist()) & set(oracle.tolist())) / K
        if res.request_id < len(embedded):   # print the first round only
            print(f"\nquery: {qtext!r}  (tenant {res.tenant}, "
                  f"batch of {res.batch_size})")
            print(f"  top doc: {res.docs[0][:60]!r}")
            print(f"  recall={recall:.0%}  "
                  f"wire={res.transcript.total_bytes/1024:.1f} KB  "
                  f"path={res.transcript.path}")
        assert recall == 1.0

    agg = engine.metrics.summary()["aggregate"]
    print(f"\nengine: {agg['count']} requests, "
          f"p50={agg['p50_latency_s']}s p99={agg['p99_latency_s']}s, "
          f"mean batch {agg['mean_batch_size']}")
    stats = engine.cache_stats()
    if stats is not None:
        print(f"sharded cache: {stats['hits']} shard hits / "
              f"{stats['misses']} misses, "
              f"resident {stats['resident_bytes'] / 2**20:.1f} MiB "
              f"(peak {stats['peak_resident_bytes'] / 2**20:.1f}) "
              f"of {stats['pool_bytes'] / 2**20:.1f} MiB pool")
        print(f"admission: {stats['admissions']} total "
              f"({stats['async_admissions']} async, "
              f"{stats['pending_admissions']} in flight), "
              f"{stats['prefetches']} prefetched touches, "
              f"{stats['policy_deferrals']} deferred below threshold, "
              f"{stats['admit_dropped']} dropped at the queue cap")
    if args.trace_out is not None:
        stages = engine.tracer.stage_summary()
        n_events = engine.write_trace(args.trace_out)
        print(f"trace: {n_events} spans over stages "
              f"{sorted(stages)} -> {args.trace_out} "
              f"(load at https://ui.perfetto.dev)")
    # release the sharded cache's background admitter thread — without
    # this, the daemon worker (and its host-pool reference) would outlive
    # the engine until its idle timeout
    engine.close()


if __name__ == "__main__":
    main()
