"""Pallas NTT kernel vs pure-jnp ref vs independent numpy-int64 oracle."""

import numpy as np
import pytest

from repro.crypto import modring
from repro.crypto.modring import PrimeCtx
from repro.kernels.ntt import ops, ref


def _ctx(n=1024, which=0):
    primes = modring.find_ntt_primes(2 * n, which + 1)
    return PrimeCtx.build(primes[which], n)


# ---------------------------------------------------------------------------
# modular primitive correctness (int32-safe path vs int64)
# ---------------------------------------------------------------------------

def test_mod_mul_matches_int64():
    rng = np.random.default_rng(0)
    ctx = _ctx(256)
    a = rng.integers(0, ctx.q, size=(4096,)).astype(np.int32)
    b = rng.integers(0, ctx.q, size=(4096,)).astype(np.int32)
    got = np.asarray(modring.mod_mul(a, b, ctx.q, ctx.mu))
    want = modring.mod_mul_np(a, b, ctx.q).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_mod_mul_edge_values():
    ctx = _ctx(256)
    edge = np.array([0, 1, 2, ctx.q - 2, ctx.q - 1], dtype=np.int32)
    a, b = np.meshgrid(edge, edge)
    got = np.asarray(modring.mod_mul(a.ravel(), b.ravel(), ctx.q, ctx.mu))
    want = modring.mod_mul_np(a.ravel(), b.ravel(), ctx.q).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_barrett_full_range():
    ctx = _ctx(256)
    rng = np.random.default_rng(1)
    x = rng.integers(0, 2**31 - 1, size=(8192,)).astype(np.int32)
    got = np.asarray(modring.barrett_reduce(x, ctx.q, ctx.mu))
    np.testing.assert_array_equal(got, (x.astype(np.int64) % ctx.q).astype(np.int32))


# ---------------------------------------------------------------------------
# reference NTT correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [256, 1024])
def test_ref_roundtrip(n):
    ctx = _ctx(n)
    rng = np.random.default_rng(2)
    x = ref.random_poly(rng, (8, n), ctx.q)
    back = np.asarray(ops.ntt_inv(ops.ntt_fwd(x, ctx, use_pallas=False), ctx,
                                  use_pallas=False))
    np.testing.assert_array_equal(back, x)


@pytest.mark.parametrize("n", [256, 1024])
def test_ref_negacyclic_matches_schoolbook(n):
    ctx = _ctx(n)
    rng = np.random.default_rng(3)
    a = ref.random_poly(rng, (3, n), ctx.q)
    b = ref.random_poly(rng, (3, n), ctx.q)
    got = np.asarray(ops.negacyclic_mul(a, b, ctx, use_pallas=False))
    want = modring.negacyclic_mul_np(a, b, ctx.q).astype(np.int32)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Pallas kernel (interpret mode) vs reference — shape/prime sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [256, 1024, 4096])
@pytest.mark.parametrize("batch", [1, 8, 96])
def test_kernel_fwd_matches_ref(n, batch):
    ctx = _ctx(n)
    rng = np.random.default_rng(4)
    x = ref.random_poly(rng, (batch, n), ctx.q)
    got = np.asarray(ops.ntt_fwd(x, ctx, use_pallas=True))
    want = np.asarray(ops.ntt_fwd(x, ctx, use_pallas=False))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", [256, 1024])
@pytest.mark.parametrize("which_prime", [0, 1, 2])
def test_kernel_roundtrip_all_primes(n, which_prime):
    ctx = _ctx(n, which=which_prime)
    rng = np.random.default_rng(5)
    x = ref.random_poly(rng, (16, n), ctx.q)
    y = ops.ntt_fwd(x, ctx, use_pallas=True)
    back = np.asarray(ops.ntt_inv(y, ctx, use_pallas=True))
    np.testing.assert_array_equal(back, x)


def test_kernel_negacyclic_matches_schoolbook():
    ctx = _ctx(1024)
    rng = np.random.default_rng(6)
    a = ref.random_poly(rng, (4, 1024), ctx.q)
    b = ref.random_poly(rng, (4, 1024), ctx.q)
    got = np.asarray(ops.negacyclic_mul(a, b, ctx, use_pallas=True))
    want = modring.negacyclic_mul_np(a, b, ctx.q).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_kernel_leading_dims():
    ctx = _ctx(256)
    rng = np.random.default_rng(7)
    x = ref.random_poly(rng, (3, 5, 256), ctx.q)
    got = np.asarray(ops.ntt_fwd(x, ctx))
    want = np.asarray(ops.ntt_fwd(x.reshape(15, 256), ctx)).reshape(3, 5, 256)
    np.testing.assert_array_equal(got, want)
