#!/usr/bin/env bash
# CI smoke job: tier-1 tests (slow excluded) + docs check + optional perf
# regression gate.
#
#   scripts/smoke.sh                 # pytest -m "not slow" + docs check
#   SMOKE_BENCH=1 scripts/smoke.sh   # ... plus rlwe bench + regression check
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q -m "not slow" "$@"

# docs gate: every intra-repo link in docs/ + README resolves, every
# documented `repro.*` symbol imports
python scripts/check_docs.py

if [[ "${SMOKE_BENCH:-0}" == "1" ]]; then
  python -m benchmarks.run --only rlwe
  python scripts/check_bench_regression.py BENCH_rlwe.json
fi
