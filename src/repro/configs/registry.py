"""Architecture registry: `--arch <id>` -> config + shapes + cell builder."""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Sequence

from repro.configs import (dcn_v2, dien, families, fm, granite_moe_3b_a800m,
                           graphcast, llama3_8b, qwen25_14b, qwen3_8b,
                           qwen3_moe_30b_a3b, remoterag, shapes,
                           two_tower_retrieval)


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str                  # "lm" | "gnn" | "recsys" | "rag"
    config: object
    reduced: object
    shapes: Dict[str, object]
    build_cell: Callable         # (config, shape, mesh, **kw) -> families.Cell

    def scan_trip_count(self) -> int:
        """Trip count of the dominant scan (for roofline extrapolation);
        0 = no scan (metrics are exact as measured)."""
        if self.family in ("lm", "gnn"):
            return self.config.n_layers
        if self.arch_id == "dien":
            return self.config.seq_len
        return 0


def _lm(arch_id, mod):
    return ArchEntry(arch_id, "lm", mod.CONFIG, mod.REDUCED,
                     shapes.LM_SHAPES, families.lm_cell)


def _gnn(arch_id, mod):
    return ArchEntry(arch_id, "gnn", mod.CONFIG, mod.REDUCED,
                     shapes.GNN_SHAPES, families.gnn_cell)


def _recsys(arch_id, mod):
    return ArchEntry(
        arch_id, "recsys", mod.CONFIG, mod.REDUCED, shapes.RECSYS_SHAPES,
        lambda cfg, shp, mesh, **kw: families.recsys_cell(
            arch_id, cfg, shp, mesh, **kw))


REGISTRY: Dict[str, ArchEntry] = {
    "llama3-8b": _lm("llama3-8b", llama3_8b),
    "qwen3-8b": _lm("qwen3-8b", qwen3_8b),
    "qwen2.5-14b": _lm("qwen2.5-14b", qwen25_14b),
    "qwen3-moe-30b-a3b": _lm("qwen3-moe-30b-a3b", qwen3_moe_30b_a3b),
    "granite-moe-3b-a800m": _lm("granite-moe-3b-a800m", granite_moe_3b_a800m),
    "graphcast": _gnn("graphcast", graphcast),
    "fm": _recsys("fm", fm),
    "two-tower-retrieval": _recsys("two-tower-retrieval", two_tower_retrieval),
    "dien": _recsys("dien", dien),
    "dcn-v2": _recsys("dcn-v2", dcn_v2),
    "remoterag": ArchEntry(
        "remoterag", "rag", remoterag.RLWE, remoterag.RLWE,
        shapes.REMOTERAG_SHAPES,
        lambda cfg, shp, mesh, **kw: families.remoterag_cell(
            shp, mesh, cfg, **kw)),
}

ASSIGNED = [a for a in REGISTRY if a != "remoterag"]


def get(arch_id: str) -> ArchEntry:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(REGISTRY)}")
    return REGISTRY[arch_id]


def cells(arch_id: str, mesh, shape_names: Sequence[str] = ()) -> list:
    entry = get(arch_id)
    names = shape_names or list(entry.shapes)
    return [entry.build_cell(entry.config, entry.shapes[s], mesh)
            for s in names]


__all__ = ["ArchEntry", "REGISTRY", "ASSIGNED", "get", "cells"]
