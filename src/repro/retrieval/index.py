"""Device-sharded flat corpus index.

The cloud's N document embeddings are row-sharded across every axis of the
mesh (the paper's single-host vector DB, scaled out).  Each device owns a
contiguous row range; global ids are shard_offset + local id.  Documents
themselves (bytes) stay host-side, keyed by global id.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class FlatIndex:
    """A flat (exact-search) embedding index, optionally mesh-sharded."""

    embeddings: jax.Array          # (N, n) unit-norm rows
    mesh: Optional[Mesh] = None
    row_axes: Optional[tuple] = None   # mesh axes the rows are sharded over
    documents: Optional[Sequence[bytes]] = None
    # NTT-domain candidate caches, memoized per RlweParams value so every
    # RemoteRagCloud over this index shares one build (build-once/serve-many)
    _cand_caches: dict = dataclasses.field(default_factory=dict, repr=False,
                                           compare=False)

    @property
    def num_rows(self) -> int:
        return self.embeddings.shape[0]

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]

    @classmethod
    def build(cls, embeddings: np.ndarray, *, mesh: Optional[Mesh] = None,
              row_axes: Optional[tuple] = None,
              documents: Optional[Sequence[bytes]] = None,
              normalize: bool = True) -> "FlatIndex":
        emb = np.asarray(embeddings, np.float32)
        if normalize:
            emb = emb / np.linalg.norm(emb, axis=-1, keepdims=True)
        if mesh is not None:
            row_axes = row_axes or tuple(mesh.axis_names)
            n_shards = int(np.prod([mesh.shape[a] for a in row_axes]))
            pad = (-emb.shape[0]) % n_shards
            if pad:
                emb = np.concatenate([emb, np.zeros((pad, emb.shape[1]),
                                                    np.float32)])
            sharding = NamedSharding(mesh, P(row_axes, None))
            arr = jax.device_put(jnp.asarray(emb), sharding)
        else:
            arr = jnp.asarray(emb)
        return cls(embeddings=arr, mesh=mesh, row_axes=row_axes,
                   documents=documents)

    def fetch_documents(self, ids: Sequence[int]):
        assert self.documents is not None, "index built without documents"
        return [self.documents[int(i)] for i in ids]

    def rows(self, ids) -> jax.Array:
        """Gather embedding rows by global id (host-driven, small batches)."""
        return jnp.take(self.embeddings, jnp.asarray(ids), axis=0)

    def candidate_cache(self, rlwe_params):
        """NTT-domain candidate cache for this index under ``rlwe_params``
        (see crypto.rlwe.CandidateCache): every document's reversed-chunk
        plaintext forward-NTT'd once, so the encrypted re-rank never re-packs
        or re-NTTs candidates per request.  Built on first use and memoized
        per RlweParams *value*; costs 4 * P * N bytes per chunk per row."""
        from repro.crypto import rlwe

        key = rlwe.params_key(rlwe_params)
        cache = self._cand_caches.get(key)
        if cache is None:
            cache = rlwe.build_candidate_cache(rlwe_params,
                                               np.asarray(self.embeddings))
            self._cand_caches[key] = cache
        return cache


__all__ = ["FlatIndex"]
