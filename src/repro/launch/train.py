"""Training driver: `python -m repro.launch.train --arch <id> [...]`.

Runs a real training loop (synthetic deterministic data) with checkpointing,
restart, straggler monitoring, and optional int8 gradient compression.  On
this CPU container use --reduced (default) for the smoke-scale configs; on a
real pod the full configs + production mesh apply unchanged.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data.pipeline import LmSyntheticTask
from repro.models import transformer as tf_lib
from repro.train import fault
from repro.train import optimizer as opt_lib
from repro.train import trainer


def make_lm_run(cfg, *, batch: int, seq: int, lr: float, steps: int,
                microbatches: int = 1):
    task = LmSyntheticTask(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    opt_cfg = opt_lib.AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 1),
                                  total_steps=steps)
    step = trainer.make_train_step(
        lambda p, t, y: tf_lib.loss_fn(p, cfg, t, y), opt_cfg,
        param_dtype=cfg.jdtype, microbatches=microbatches)
    jstep = jax.jit(step, donate_argnums=(0, 1))

    def step_fn(state, batch_np):
        params, opt_state = state
        tokens, targets = (jnp.asarray(b) for b in batch_np)
        params, opt_state, metrics = jstep(params, opt_state,
                                           (tokens, targets))
        return (params, opt_state), metrics

    def batches_fn(i):
        return task.batch(i)

    params = tf_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(params, opt_cfg)
    return step_fn, batches_fn, (params, opt_state)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="runs/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (drill)")
    args = ap.parse_args()

    entry = registry.get(args.arch)
    assert entry.family == "lm", "train.py drives LM archs; see examples/"
    cfg = entry.reduced if args.reduced else entry.config

    step_fn, batches_fn, state = make_lm_run(
        cfg, batch=args.batch, seq=args.seq, lr=args.lr, steps=args.steps)
    run = fault.ResumableRun(args.ckpt_dir, checkpoint_every=args.ckpt_every)
    injector = (fault.FailureInjector(fail_at_steps=(args.fail_at,))
                if args.fail_at >= 0 else None)
    monitor = fault.StragglerMonitor()

    t0 = time.monotonic()
    state, done, history = run.run(step_fn, state, batches_fn, args.steps,
                                   injector=injector, monitor=monitor)
    dt = time.monotonic() - t0
    losses = [h["loss"] for h in history]
    print(json.dumps({
        "arch": cfg.name, "steps_run": done, "wall_s": round(dt, 2),
        "loss_first": round(float(losses[0]), 4) if losses else None,
        "loss_last": round(float(losses[-1]), 4) if losses else None,
        "stragglers": len(monitor.straggler_steps),
    }))


if __name__ == "__main__":
    main()
