"""Token-choice top-k MoE with grouped, capacity-bounded dispatch.

GShard-style routing shaped for GSPMD on a ("data", "model") mesh:

  * routing groups = batch rows (GShard's "groups"); every group sorts and
    capacity-drops its own tokens, so all dispatch tensors keep a leading
    batch axis sharded over "data" — nothing re-materializes at global size;
  * expert weights are stacked (E, ...) and sharded on E over "model"
    (expert parallelism); experts are zero-padded to a multiple of the EP
    degree and the router never routes to padding;
  * `shard_axes` (set by the launch layer) adds with_sharding_constraint on
    the (B, E, C, d) dispatch buffers so XLA places the data->expert
    all-to-all exactly once per direction.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MoeSpec:
    d_model: int
    d_ff: int                  # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    ep_pad_to: int = 1         # pad experts to a multiple of this
    # activation sharding (None = no constraints; set by launch layer)
    batch_axes: Optional[tuple] = None
    ep_axis: Optional[str] = None
    # "einsum" (GSPMD auto) | "shard_a2a" (shard_map: local dispatch to the
    # shard's own experts + ONE psum combine — see moe_fwd_sharded)
    impl: str = "einsum"
    mesh: Optional[object] = None  # required for impl="shard_a2a"

    @property
    def padded_experts(self) -> int:
        return -(-self.n_experts // self.ep_pad_to) * self.ep_pad_to

    def capacity(self, group_tokens: int) -> int:
        cap = int(self.capacity_factor * group_tokens * self.top_k
                  / self.n_experts)
        return max(4, -(-cap // 4) * 4)


def moe_params(key, spec: MoeSpec, dtype, abstract: bool):
    e = spec.padded_experts
    scale = 1.0 / math.sqrt(spec.d_model)
    ks = jax.random.split(key, 4) if not abstract else [None] * 4
    return {
        "router": layers.make_param(ks[0], (spec.d_model, e), dtype, scale,
                                    abstract),
        "w_gate": layers.make_param(ks[1], (e, spec.d_model, spec.d_ff),
                                    dtype, scale, abstract),
        "w_up": layers.make_param(ks[2], (e, spec.d_model, spec.d_ff),
                                  dtype, scale, abstract),
        "w_down": layers.make_param(ks[3], (e, spec.d_ff, spec.d_model),
                                    dtype, 1.0 / math.sqrt(spec.d_ff),
                                    abstract),
    }


def _constrain(x, spec: MoeSpec, parts):
    if spec.batch_axes is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*parts))


def moe_fwd(p, x, spec: MoeSpec):
    if spec.impl == "shard_a2a" and spec.mesh is not None:
        return moe_fwd_sharded(p, x, spec)
    return moe_fwd_einsum(p, x, spec)


def _dispatch_compute(p, x, gate_w, gate_i, e_lo, n_loc: int, cap: int,
                      spec: MoeSpec):
    """Capacity-bounded dispatch of (B, S, d) tokens to experts
    [e_lo, e_lo + n_loc) of the stacked weights p (already sliced to this
    range), combined with gate weights.  Pure local computation.
    ``e_lo`` may be traced (axis_index); ``n_loc`` is static."""
    b, s, d = x.shape
    flat_e = gate_i.reshape(b, s * spec.top_k)
    flat_t = jnp.tile(jnp.repeat(jnp.arange(s), spec.top_k)[None], (b, 1))
    flat_w = gate_w.reshape(b, s * spec.top_k)
    mine = (flat_e >= e_lo) & (flat_e < e_lo + n_loc)
    loc_e = jnp.where(mine, flat_e - e_lo, n_loc)  # n_loc = drop bucket
    order = jnp.argsort(loc_e, axis=1, stable=True)
    se = jnp.take_along_axis(loc_e, order, 1)
    st = jnp.take_along_axis(flat_t, order, 1)
    sw = jnp.take_along_axis(flat_w, order, 1)
    idx = jnp.arange(s * spec.top_k)[None]
    same = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32),
         (se[:, 1:] == se[:, :-1]).astype(jnp.int32)], 1)
    seg_start = jnp.where(same == 0, idx, 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, seg_start, axis=1)
    seg_pos = idx - seg_start
    keep = (seg_pos < cap) & (se < n_loc)
    buf_slot = jnp.where(keep, se * cap + seg_pos, n_loc * cap)

    gathered = jnp.take_along_axis(x, st[..., None], axis=1)
    buffers = jnp.zeros((b, n_loc * cap + 1, d), x.dtype)
    buffers = jax.vmap(lambda bf, sl, g: bf.at[sl].set(g))(
        buffers, buf_slot, gathered)
    buffers = buffers[:, :-1].reshape(b, n_loc, cap, d)

    h_g = jax.nn.silu(jnp.einsum("becd,edf->becf", buffers, p["w_gate"]))
    h_u = jnp.einsum("becd,edf->becf", buffers, p["w_up"])
    h = jnp.einsum("becf,efd->becd", h_g * h_u, p["w_down"])

    flat_out = h.reshape(b, n_loc * cap, d)
    safe_slot = jnp.minimum(buf_slot, n_loc * cap - 1)
    contrib = jnp.take_along_axis(flat_out, safe_slot[..., None], axis=1)
    contrib = jnp.where(keep[..., None], contrib, 0.0) * sw[..., None]
    out = jnp.zeros((b, s, d), x.dtype)
    return jax.vmap(lambda o, t, c: o.at[t].add(c))(out, st, contrib)


def moe_fwd_sharded(p, x, spec: MoeSpec):
    """shard_map MoE: tokens are data-sharded and model-replicated, so each
    expert-parallel shard locally selects the (token, k) pairs routed to its
    own expert slice — dispatch costs ZERO communication — computes them, and
    the combine is ONE psum of the (B_loc, S, d) output over the EP axis
    (exactly a dense-TP all-reduce).  Replaces the einsum formulation's
    gather/scatter all-reduces of (B, E, C, d) buffers (~16x the bytes).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, ep = spec.mesh, spec.ep_axis
    ba = spec.batch_axes or ()
    e = spec.padded_experts
    n_shards = mesh.shape[ep]
    e_loc = e // n_shards
    b, s, d = x.shape
    cap = spec.capacity(s)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    if e != spec.n_experts:
        pad_mask = jnp.arange(e) >= spec.n_experts
        logits = jnp.where(pad_mask[None, None, :], -jnp.inf, logits)
    gate_w, gate_i = jax.lax.top_k(logits, spec.top_k)
    gate_w = jax.nn.softmax(gate_w, axis=-1).astype(x.dtype)

    probs = jax.nn.softmax(logits, axis=-1)
    onehot1 = jax.nn.one_hot(gate_i[..., 0], e, dtype=jnp.float32)
    aux = spec.n_experts * jnp.mean(
        jnp.mean(onehot1, axis=1) * jnp.mean(probs, axis=1))

    tok_spec = P(ba, None, None)
    route_spec = P(ba, None, None)
    w_spec = {"w_gate": P(ep, None, None), "w_up": P(ep, None, None),
              "w_down": P(ep, None, None)}

    def local(weights, x_loc, gw, gi):
        my = jax.lax.axis_index(ep)
        out = _dispatch_compute(weights, x_loc, gw, gi,
                                my * e_loc, e_loc, cap, spec)
        return jax.lax.psum(out, ep)

    out = shard_map(
        local, mesh=mesh,
        in_specs=(w_spec, tok_spec, route_spec, route_spec),
        out_specs=tok_spec, check_rep=False,
    )({k: p[k] for k in ("w_gate", "w_up", "w_down")}, x, gate_w, gate_i)
    return out, aux


def moe_fwd_einsum(p, x, spec: MoeSpec):
    """x: (B, S, d) -> (B, S, d) + aux loss. Each batch row is a group."""
    b, s, d = x.shape
    e = spec.padded_experts
    cap = spec.capacity(s)
    ba = spec.batch_axes
    ep = spec.ep_axis

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    if e != spec.n_experts:
        pad_mask = jnp.arange(e) >= spec.n_experts
        logits = jnp.where(pad_mask[None, None, :], -jnp.inf, logits)
    gate_w, gate_i = jax.lax.top_k(logits, spec.top_k)     # (B, S, K)
    gate_w = jax.nn.softmax(gate_w, axis=-1).astype(x.dtype)

    probs = jax.nn.softmax(logits, axis=-1)
    onehot1 = jax.nn.one_hot(gate_i[..., 0], e, dtype=jnp.float32)
    aux = spec.n_experts * jnp.mean(
        jnp.mean(onehot1, axis=1) * jnp.mean(probs, axis=1))

    # ---- per-group (per batch row) sort-based dispatch -------------------
    flat_e = gate_i.reshape(b, s * spec.top_k)
    flat_t = jnp.tile(jnp.repeat(jnp.arange(s), spec.top_k)[None], (b, 1))
    flat_w = gate_w.reshape(b, s * spec.top_k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, 1)
    st = jnp.take_along_axis(flat_t, order, 1)
    sw = jnp.take_along_axis(flat_w, order, 1)
    idx = jnp.arange(s * spec.top_k)[None]
    same = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32),
         (se[:, 1:] == se[:, :-1]).astype(jnp.int32)], 1)
    seg_start = jnp.where(same == 0, idx, 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, seg_start, axis=1)
    seg_pos = idx - seg_start
    keep = seg_pos < cap
    buf_slot = jnp.where(keep, se * cap + seg_pos, e * cap)   # e*cap = drop

    gathered = jnp.take_along_axis(x, st[..., None], axis=1)  # (B, S*K, d)
    buffers = jnp.zeros((b, e * cap + 1, d), x.dtype)
    buffers = jax.vmap(lambda bf, sl, g: bf.at[sl].set(g))(
        buffers, buf_slot, gathered)
    buffers = buffers[:, :-1].reshape(b, e, cap, d)
    buffers = _constrain(buffers, spec, (ba, ep, None, None))

    h_g = jax.nn.silu(jnp.einsum("becd,edf->becf", buffers, p["w_gate"]))
    h_u = jnp.einsum("becd,edf->becf", buffers, p["w_up"])
    h = jnp.einsum("becf,efd->becd", h_g * h_u, p["w_down"])
    h = _constrain(h, spec, (ba, ep, None, None))

    flat_out = h.reshape(b, e * cap, d)
    safe_slot = jnp.minimum(buf_slot, e * cap - 1)
    contrib = jnp.take_along_axis(flat_out, safe_slot[..., None], axis=1)
    contrib = jnp.where(keep[..., None], contrib, 0.0) * sw[..., None]
    out = jnp.zeros((b, s, d), x.dtype)
    out = jax.vmap(lambda o, t, c: o.at[t].add(c))(out, st, contrib)
    out = _constrain(out, spec, (ba, None, None))
    return out, aux


__all__ = ["MoeSpec", "moe_params", "moe_fwd", "moe_fwd_einsum",
           "moe_fwd_sharded"]
