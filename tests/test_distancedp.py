import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import distancedp


def test_radial_moments_match_gamma():
    key = jax.random.PRNGKey(1)
    n, eps = 768, 10 * 768.0
    r = distancedp.sample_radial(key, n, eps, (20_000,))
    mean, var = float(jnp.mean(r)), float(jnp.var(r))
    assert mean == pytest.approx(n / eps, rel=0.02)
    assert var == pytest.approx(n / eps**2, rel=0.1)


def test_direction_uniform():
    key = jax.random.PRNGKey(2)
    v = distancedp.sample_direction(key, 64, (5000,))
    norms = jnp.linalg.norm(v, axis=-1)
    np.testing.assert_allclose(np.asarray(norms), 1.0, atol=1e-5)
    assert float(jnp.abs(jnp.mean(v, axis=0)).max()) < 0.05


def test_perturb_shapes_and_radius_consistency():
    key = jax.random.PRNGKey(3)
    e = distancedp.sample_direction(jax.random.PRNGKey(9), 384, (7,))
    out = distancedp.perturb(key, e, eps=384 * 20.0)
    assert out.embedding.shape == (7, 384)
    d = jnp.linalg.norm(out.embedding - e, axis=-1)
    np.testing.assert_allclose(np.asarray(d), np.asarray(out.radius), rtol=1e-4)


@settings(max_examples=50, deadline=None)
@given(
    st.integers(min_value=2, max_value=64),
    st.floats(min_value=0.1, max_value=1e4),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_distancedp_inequality_property(n, eps, seed):
    """Definition 1: |log p(y|x) - log p(y|x')| <= eps * ||x - x'|| for all y."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n,))
    x_alt = rng.normal(size=(n,))
    ys = rng.normal(size=(16, n)) * rng.uniform(0.1, 10)
    lr = np.asarray(distancedp.dp_log_ratio(ys, x, x_alt, eps))
    bound = eps * np.linalg.norm(x - x_alt) + 1e-2 * eps  # f32 slop
    assert np.all(np.abs(lr) <= bound + 1e-4)


def test_eps_radius_inverses():
    assert distancedp.eps_for_radius(768, 0.03) == pytest.approx(25600.0)
    assert distancedp.expected_radius(768, 25600.0) == pytest.approx(0.03)


def test_radial_quantile_brackets_mean():
    n, eps = 768, 768 * 10.0
    q50 = distancedp.radial_quantile_np(n, eps, 0.5)
    q999 = distancedp.radial_quantile_np(n, eps, 0.999)
    assert q50 == pytest.approx(n / eps, rel=0.01)  # Gamma(n) median ~ mean, large n
    assert q999 > q50
    assert q999 < 1.2 * (n / eps)  # concentration at n=768 (Fig. 2)
