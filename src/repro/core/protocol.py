"""End-to-end RemoteRAG protocol (paper Algorithms 1 + 2).

Two explicit state machines — `RemoteRagUser` and `RemoteRagCloud` — exchange
typed messages so tests and benchmarks can meter every byte on the wire.

    user                                   cloud
    ----                                   -----
    Module 1: perturb e_k -> e_k' (DistanceDP), plan k'
    Module 2a: enc(e_k)
          -- Request{e_k', k', enc_query} -->
                                           top-k' of e_k' over sharded index
                                           encrypted cos-distances on the k'
          <-- Reply{candidate_ids, enc_scores} --
    decrypt + sort -> local top-k candidate positions
    Theorem 3: omega >= delta_alpha ?
      yes -- Fetch{positions} -->          return docs        (Module 2b)
      no  -- k-of-k' OT        -->         oblivious docs     (Module 2c)

Crypto backend: "rlwe" (TPU-native, default) or "paillier" (paper-faithful).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import distancedp, planner
from repro.core.planner import ProtocolPlan
from repro.crypto import backend as backends
from repro.crypto import ot as ot_mod
from repro.crypto import paillier as pai
from repro.crypto import rlwe
from repro.retrieval.index import FlatIndex
from repro.retrieval.topk import distributed_topk


# ---------------------------------------------------------------------------
# wire messages
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    perturbed: np.ndarray          # e_k' (n,)
    kprime: int
    enc_query: object              # rlwe.QueryCiphertext | list[int] (paillier)
    backend: str

    def nbytes(self, params: Optional[rlwe.RlweParams] = None,
               key_bits: int = 2048) -> int:
        base = self.perturbed.size * 4 + 4
        return base + backends.get_backend(self.backend).request_nbytes(
            self.enc_query, params=params, key_bits=key_bits)


@dataclasses.dataclass
class Reply:
    candidate_ids: np.ndarray      # (k',) global ids (order defines positions)
    enc_scores: object             # rlwe.ScoreCiphertexts | list[int]

    def nbytes(self, params: Optional[rlwe.RlweParams] = None,
               key_bits: int = 2048) -> int:
        base = self.candidate_ids.size * 4
        return base + backends.scores_backend(self.enc_scores).reply_nbytes(
            self.enc_scores, params=params, key_bits=key_bits)


@dataclasses.dataclass
class FetchDirect:
    positions: Sequence[int]       # positions within candidate_ids (k of them)

    def nbytes(self) -> int:
        return len(self.positions) * 4


@dataclasses.dataclass
class Documents:
    docs: List[bytes]

    def nbytes(self) -> int:
        return sum(len(d) for d in self.docs)


# ---------------------------------------------------------------------------
# cloud
# ---------------------------------------------------------------------------

class RemoteRagCloud:
    """Holds the sharded index + documents; executes modules 1, 2a, 2b, 2c.

    The RLWE re-rank runs against the index's NTT-domain candidate cache
    (built once per (index, params, cache-config) and shared across
    clouds/engines), so the per-request encrypted workload touches only
    per-request data.  ``cache_config`` (an `rlwe.CandidateCacheConfig`)
    selects the corpus-scale sharded cache — host-pooled shards, LRU-pinned
    device-resident hot set under the config's admission policy (async
    background admitter + 2nd-touch frequency threshold by default),
    per-request gather of only the k' selected candidates — instead of the
    dense device-resident pool; ``use_candidate_cache=False`` restores cold
    per-request packing (the reference path).  All three are bit-identical,
    whatever the admission history."""

    def __init__(self, index: FlatIndex, *,
                 rlwe_params: Optional[rlwe.RlweParams] = None,
                 use_pallas: Optional[bool] = None,
                 use_candidate_cache: bool = True,
                 cache_config: Optional[rlwe.CandidateCacheConfig] = None):
        self.index = index
        self.rlwe_params = rlwe_params or rlwe.RlweParams()
        self.use_pallas = use_pallas
        self.use_candidate_cache = use_candidate_cache
        self.cache_config = cache_config

    @property
    def candidate_cache(self):
        """The index's cache for this cloud's (params, cache-config) —
        dense `rlwe.CandidateCache` or `rlwe.ShardedCandidateCache`; None
        when disabled.  Built lazily so paillier-only clouds never pay."""
        if not self.use_candidate_cache:
            return None
        return self.index.candidate_cache(self.rlwe_params,
                                          self.cache_config)

    def handle_request(self, req: Request, *, topk_fn=None) -> Reply:
        """Modules 1 + 2a, cloud half.  ``topk_fn(perturbed_batch, kprime)``
        optionally replaces the whole-index top-k' scan — the serve layer
        passes its searcher here so a solo (quarantine-retry) request goes
        through the *same* per-slice scan + merge as the scatter-gather
        path, keeping retried results bit-identical by construction."""
        if topk_fn is None:
            q = jnp.asarray(req.perturbed, jnp.float32)[None, :]
            res = distributed_topk(self.index, q, req.kprime,
                                   use_pallas=self.use_pallas)
            cand_ids = np.asarray(res.indices)[0]
        else:
            cand_ids = np.asarray(
                topk_fn(np.asarray(req.perturbed)[None, :], req.kprime))[0]
        enc = backends.get_backend(req.backend).score_request(
            self, req, cand_ids)
        return Reply(candidate_ids=cand_ids, enc_scores=enc)

    def register_paillier(self, pub: pai.PaillierPublicKey) -> None:
        self._paillier_pub = pub

    def handle_fetch(self, cand_ids: np.ndarray, msg: FetchDirect) -> Documents:
        ids = [int(cand_ids[p]) for p in msg.positions]
        return Documents(docs=self.index.fetch_documents(ids))

    def ot_documents(self, cand_ids: np.ndarray) -> List[bytes]:
        docs = self.index.fetch_documents([int(i) for i in cand_ids])
        width = max(len(d) for d in docs)
        return [d.ljust(width, b"\x00") for d in docs]


# ---------------------------------------------------------------------------
# user
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ProtocolTranscript:
    plan: ProtocolPlan
    path: str                      # "direct" | "ot"
    request_bytes: int
    reply_bytes: int
    fetch_bytes: int
    docs_bytes: int
    ot_wire_bytes: int = 0

    @property
    def total_bytes(self) -> int:
        return (self.request_bytes + self.reply_bytes + self.fetch_bytes
                + self.docs_bytes + self.ot_wire_bytes)


class RemoteRagUser:
    def __init__(self, *, n: int, N: int, k: int,
                 eps: Optional[float] = None, radius: Optional[float] = None,
                 backend: str = "rlwe",
                 rlwe_params: Optional[rlwe.RlweParams] = None,
                 paillier_bits: int = 512,
                 rng: Optional[np.random.Generator] = None,
                 plan_kwargs: Optional[dict] = None,
                 plan: Optional[ProtocolPlan] = None):
        self.impl = backends.get_backend(backend)   # raises UnknownBackend
        self.backend = backend
        self.rng = rng or np.random.default_rng(0)
        # Paillier randomness: a caller-provided rng makes key/nonce streams
        # replayable (serve parity); with no rng the scheme keeps its
        # `secrets` CSPRNG default instead of inheriting the seed-0 rng.
        self._pai_rng = rng
        # `plan` injects a precomputed plan (serve-layer plan cache); the
        # Theorem-1 planning is host-side scipy work worth skipping for
        # repeat tenants with identical (n, N, k, eps) knobs.
        self.plan = plan if plan is not None else planner.plan(
            n=n, N=N, k=k, eps=eps, radius=radius, **(plan_kwargs or {}))
        self.rlwe_params = rlwe_params or rlwe.RlweParams()
        self.paillier_bits = paillier_bits
        self.sk = self.impl.keygen(self)

    # -- module 1 + 2a ------------------------------------------------------
    def encrypt_query(self, e: np.ndarray):
        """Encrypt the true embedding under this user's key (module 2a,
        user half).  Shared by make_request and the serve layer's batched
        path, which perturbs whole batches separately."""
        self._e = np.asarray(e, np.float64)
        return self.impl.encrypt_query(self, self._e)

    def make_request(self, e: np.ndarray, key: jax.Array) -> Request:
        pert = distancedp.perturb(key, jnp.asarray(e, jnp.float32),
                                  self.plan.eps)
        enc = self.encrypt_query(e)
        return Request(perturbed=np.asarray(pert.embedding),
                       kprime=self.plan.kprime, enc_query=enc,
                       backend=self.backend)

    # -- decrypt + sort (module 2a end) --------------------------------------
    def positions_from_scores(self, scores: np.ndarray,
                              num_candidates: int) -> np.ndarray:
        """Stable sort of decrypted scores -> local top-k candidate
        positions (shared by the sequential and batched serving paths)."""
        scores = scores[: num_candidates]
        order = np.argsort(-scores, kind="stable")
        return order[: self.plan.k]

    def top_positions(self, reply: Reply) -> np.ndarray:
        scores = self.impl.decrypt_reply(self, reply.enc_scores)
        return self.positions_from_scores(scores, len(reply.candidate_ids))

    # -- module 2b / 2c ------------------------------------------------------
    def retrieve(self, cloud: RemoteRagCloud, reply: Reply,
                 positions: np.ndarray) -> tuple:
        """Returns (documents, transcript extras)."""
        if not self.plan.use_ot:
            msg = FetchDirect(positions=[int(p) for p in positions])
            docs = cloud.handle_fetch(reply.candidate_ids, msg)
            return docs.docs, dict(fetch_bytes=msg.nbytes(),
                                   docs_bytes=docs.nbytes(), ot_wire_bytes=0)
        padded = cloud.ot_documents(reply.candidate_ids)
        got, wire = ot_mod.run_ot(padded, [int(p) for p in positions])
        docs = [d.rstrip(b"\x00") for d in got]
        return docs, dict(fetch_bytes=0, docs_bytes=0, ot_wire_bytes=wire)


# ---------------------------------------------------------------------------
# one-shot driver
# ---------------------------------------------------------------------------

def finish_request(user: RemoteRagUser, cloud: RemoteRagCloud, req: Request,
                   reply: Reply, positions: np.ndarray) -> tuple:
    """Module 2b/2c + accounting: retrieve the documents at ``positions``
    and assemble (docs, global ids, transcript).  Shared tail of the
    sequential driver and the serve layer's batched path — the wire-byte
    accounting must stay identical between them."""
    docs, extras = user.retrieve(cloud, reply, positions)
    params, kb = user.impl.wire_context(user)
    transcript = ProtocolTranscript(
        plan=user.plan, path=user.plan.path,
        request_bytes=req.nbytes(params, kb),
        reply_bytes=reply.nbytes(params, kb), **extras)
    ids = np.asarray([int(reply.candidate_ids[p]) for p in positions])
    return docs, ids, transcript


def run_remoterag(user: RemoteRagUser, cloud: RemoteRagCloud, e: np.ndarray,
                  key: jax.Array, *, topk_fn=None) -> tuple:
    """Full protocol round; returns (docs, top-k global ids, transcript).

    ``topk_fn`` threads through to `RemoteRagCloud.handle_request` so a
    caller embedded in the serve layer (e.g. a quarantine solo retry) can
    reuse its own sliced/scatter top-k' search."""
    user.impl.prepare_cloud(cloud, user)
    req = user.make_request(e, key)
    reply = cloud.handle_request(req, topk_fn=topk_fn)
    positions = user.top_positions(reply)
    return finish_request(user, cloud, req, reply, positions)


__all__ = [
    "Request", "Reply", "FetchDirect", "Documents", "RemoteRagCloud",
    "RemoteRagUser", "ProtocolTranscript", "finish_request", "run_remoterag",
]
