"""moe_fwd_sharded (shard_map a2a) must equal moe_fwd_einsum exactly.

Both implementations use identical per-row capacity semantics: a token's
position within an expert's segment is its rank among that expert's tokens in
flat (s, k) order, so drops coincide and outputs match to numerics.
"""

import subprocess
import sys

SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.models import moe

mesh = jax.make_mesh((2, 4), ("data", "model"))
spec_e = moe.MoeSpec(d_model=32, d_ff=16, n_experts=8, top_k=2,
                     ep_pad_to=4, batch_axes=("data",), ep_axis="model")
spec_s = moe.MoeSpec(d_model=32, d_ff=16, n_experts=8, top_k=2,
                     ep_pad_to=4, batch_axes=("data",), ep_axis="model",
                     impl="shard_a2a", mesh=mesh)
params = moe.moe_params(jax.random.PRNGKey(0), spec_e, jnp.float32, False)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

with mesh:
    oe, ae = jax.jit(lambda p, x: moe.moe_fwd_einsum(p, x, spec_e))(params, x)
    os_, as_ = jax.jit(lambda p, x: moe.moe_fwd_sharded(p, x, spec_s))(params, x)
assert np.allclose(np.asarray(oe), np.asarray(os_), rtol=1e-4, atol=1e-5), \
    np.abs(np.asarray(oe) - np.asarray(os_)).max()
assert abs(float(ae) - float(as_)) < 1e-5

# gradients must agree too (training path)
def loss_e(p, x):
    o, a = moe.moe_fwd_einsum(p, x, spec_e)
    return jnp.sum(o * o) + a

def loss_s(p, x):
    o, a = moe.moe_fwd_sharded(p, x, spec_s)
    return jnp.sum(o * o) + a

with mesh:
    ge = jax.jit(jax.grad(loss_e))(params, x)
    gs = jax.jit(jax.grad(loss_s))(params, x)
for k in ge:
    assert np.allclose(np.asarray(ge[k]), np.asarray(gs[k]),
                       rtol=1e-3, atol=1e-4), k
print("MOE_A2A_OK")
"""


def test_moe_sharded_matches_einsum():
    r = subprocess.run([sys.executable, "-c", SNIPPET],
                       capture_output=True, text=True, timeout=600,
                       cwd=__file__.rsplit("/tests/", 1)[0])
    assert "MOE_A2A_OK" in r.stdout, r.stdout + r.stderr[-3000:]
