"""End-to-end driver: a private RAG service with a *real* embedding model.

    PYTHONPATH=src python examples/private_rag_serve.py

1. builds the in-framework text embedder (mean-pooled transformer encoder),
2. embeds a synthetic passage corpus and indexes it,
3. serves user queries through the full RemoteRAG protocol — the cloud only
   ever sees the DistanceDP-perturbed embedding and RLWE ciphertexts,
4. reports recall vs the plaintext pipeline and per-request wire bytes.

This is the serving-kind end-to-end deliverable (the training-kind one is
examples/train_lm.py).
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.data import synth
from repro.data.tokenizer import HashTokenizer
from repro.models import embedder
from repro.retrieval.index import FlatIndex

DIM = 256
N_DOCS = 2_000
SEQ = 32
K = 5


def main() -> None:
    rng = np.random.default_rng(0)
    tok = HashTokenizer(vocab_size=8192)
    cfg = embedder.encoder_config(dim=DIM, vocab=8192, n_layers=2)
    params = embedder.init_params(jax.random.PRNGKey(0), cfg)
    embed = jax.jit(lambda t: embedder.embed(params, cfg, t))

    # corpus: synthetic "passages" with topical token structure
    topics = ["weather storm rain wind", "finance stock bond market",
              "health doctor medicine flu", "sports game team score",
              "music concert guitar song", "travel flight hotel beach"]
    passages = []
    for i in range(N_DOCS):
        t = topics[i % len(topics)]
        extra = " ".join(f"w{rng.integers(0, 500)}" for _ in range(12))
        passages.append(f"{t} {extra}")

    print(f"embedding {N_DOCS} passages with {cfg.name} ...")
    ids = tok.encode_batch(passages, SEQ)
    embs = np.asarray(jax.lax.map(
        embed, jnp.asarray(ids).reshape(-1, 50, SEQ)).reshape(N_DOCS, DIM))
    index = FlatIndex.build(embs, documents=[p.encode() for p in passages])

    user = protocol.RemoteRagUser(n=DIM, N=N_DOCS, k=K, radius=0.05,
                                  backend="rlwe", rng=rng)
    cloud = protocol.RemoteRagCloud(index, rlwe_params=user.rlwe_params)
    print(f"plan: k'={user.plan.kprime}, path={user.plan.path}")

    queries = ["rain and storms this weekend", "stock market crash bond",
               "flu medicine from the doctor"]
    for qi, qtext in enumerate(queries):
        q_emb = np.asarray(embed(jnp.asarray(
            tok.encode_batch([qtext], SEQ))))[0]
        docs, got_ids, tr = protocol.run_remoterag(
            user, cloud, q_emb, jax.random.PRNGKey(qi))
        oracle = np.argsort(-(embs @ q_emb), kind="stable")[:K]
        recall = len(set(got_ids.tolist()) & set(oracle.tolist())) / K
        print(f"\nquery: {qtext!r}")
        print(f"  top doc: {docs[0][:60]!r}")
        print(f"  recall={recall:.0%}  wire={tr.total_bytes/1024:.1f} KB  "
              f"path={tr.path}")
        assert recall == 1.0


if __name__ == "__main__":
    main()
