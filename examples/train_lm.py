"""End-to-end training driver: ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Trains a ~100M llama-style model (same code path as the full llama3-8b
config) on the deterministic synthetic LM task with checkpointing, a
mid-run injected failure + automatic restart, and straggler monitoring —
the fault-tolerance drill is part of the example.
"""

import argparse
import shutil

import jax

from repro.configs import registry  # noqa: F401 (registry self-check)
from repro.launch.train import make_lm_run
from repro.models.transformer import TransformerConfig
from repro.train import fault


def config_100m() -> TransformerConfig:
    # ~100M params: 12L x d512 x ff2048, vocab 32768
    return TransformerConfig(
        name="llama-100m", n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
        d_ff=2048, vocab=32768, d_head=64, dtype="float32", remat=False,
        kv_chunk=256)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="runs/train_lm_100m")
    args = ap.parse_args()

    cfg = config_100m()
    n_params = cfg.param_count()
    print(f"training {cfg.name}: {n_params/1e6:.0f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    step_fn, batches_fn, state = make_lm_run(
        cfg, batch=args.batch, seq=args.seq, lr=3e-3, steps=args.steps)
    run = fault.ResumableRun(args.ckpt_dir, checkpoint_every=50)
    monitor = fault.StragglerMonitor()

    # drill: die a third of the way in, then resume from checkpoint
    injector = fault.FailureInjector(fail_at_steps=(args.steps // 3,))
    try:
        run.run(step_fn, state, batches_fn, args.steps, injector=injector,
                monitor=monitor)
    except fault.InjectedFailure as e:
        print(f"[drill] {e} — restarting from checkpoint "
              f"step {run.latest()}")
    _, batches_fn2, state0 = make_lm_run(
        cfg, batch=args.batch, seq=args.seq, lr=3e-3, steps=args.steps)
    state, done, history = run.run(step_fn, state0, batches_fn2, args.steps,
                                   injector=injector, monitor=monitor)

    losses = [h["loss"] for h in history]
    print(f"resumed and ran {done} steps")
    print(f"loss: first={losses[0]:.3f}  last={losses[-1]:.3f}")
    print(f"stragglers flagged: {len(monitor.straggler_steps)}")
    assert losses[-1] < losses[0], "loss must decrease over the run"


if __name__ == "__main__":
    main()
