"""Deterministic hash tokenizer (no external vocab files).

Words map to stable ids via FNV-1a; round-trip is not required for training
pipelines (ids -> text uses a placeholder form).  Special ids: 0=pad, 1=bos,
2=eos, 3=unk; hashed ids start at 4.
"""

from __future__ import annotations

from typing import List

PAD, BOS, EOS, UNK = 0, 1, 2, 3
RESERVED = 4


def _fnv1a(token: str) -> int:
    h = 0xCBF29CE484222325
    for b in token.encode():
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


class HashTokenizer:
    def __init__(self, vocab_size: int = 32768):
        assert vocab_size > RESERVED
        self.vocab_size = vocab_size

    def encode(self, text: str, *, bos: bool = True, eos: bool = False) -> List[int]:
        ids = [RESERVED + _fnv1a(w) % (self.vocab_size - RESERVED)
               for w in text.lower().split()]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def encode_batch(self, texts, seq_len: int):
        """Pad/truncate to (len(texts), seq_len) int32 with pad=0."""
        import numpy as np

        out = np.zeros((len(texts), seq_len), np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t)[:seq_len]
            out[i, : len(ids)] = ids
        return out


__all__ = ["HashTokenizer", "PAD", "BOS", "EOS", "UNK", "RESERVED"]
