"""Encrypted re-rank hot path: cold per-request packing vs the NTT-domain
candidate cache, XLA fallback vs fused Pallas kernel, batch 1 / 8 — plus
the corpus-scale section: the dense device-resident cache vs the sharded
HBM-resident cache at 10^4 documents (10^5 under REPRO_BENCH_FULL=1), in
both access regimes — streaming on-demand gather under uniform-random ids
(the gated comparison; pinning is pure churn without locality) and
device-side gather from explicitly pinned hot shards under skewed ids (the
repeat-tenant case) — recording scoring latency, gather latency, and the
device memory footprint of each layout.

A final section runs *both* regimes against one default-policy config
(async, frequency-aware admission: 2nd-touch within a decayed window,
background H2D copy off the request path) — the configuration the serving
engine ships with — so the synchronous-admission churn regression stays
measurable.

Beyond the usual CSV rows this writes machine-readable ``BENCH_rlwe.json``
(path override: BENCH_RLWE_JSON) so the perf trajectory is trackable across
PRs; ``scripts/check_bench_regression.py`` gates CI on cached > cold, on
sharded batch-8 scoring staying within 1.3x of dense at a >= 4x smaller
peak cache footprint, and on the single default config staying within 1.2x
(skewed ids) / 1.3x (uniform ids) of dense at batch 8.  A stage-breakdown
section (repro.obs tracing over a served stream) records where request
time goes per pipeline stage; its stage-duration coverage of the dispatch
wall is gated too.  A ``paillier_batch`` section times the vectorized
RNS-limb Paillier batch path against the per-lane object path at batch
1 / 8; the batch-8 speedup (>= 3x), bit-exact decryption, and zero
silent object fallbacks are gated.

Two corpus-lifecycle sections close the file: ``ivf_routing`` times the
clustered first-stage scan (`repro.retrieval.topk.cluster_topk`) against
the flat scan at 10^4 docs — gated on >= 2x speedup, recall@k' == 1.0 at
the planner-derived ``nprobe``, and ``nprobe=all`` bit-identity with the
flat scan; ``ingestion`` drains a live serving stream across a tail-shard
ingest — gated on zero lost and zero bit-drifted requests while the cache
epoch advances.
"""

from __future__ import annotations

import json
import os

import numpy as np

import jax

from benchmarks.common import FULL, emit, timeit
from repro.crypto import rlwe

OUT_PATH = os.environ.get("BENCH_RLWE_JSON", "BENCH_rlwe.json")


def _unit(rng, *shape):
    x = rng.normal(size=shape)
    return (x / np.linalg.norm(x, axis=-1, keepdims=True)).astype(np.float32)


def _serve_fault_section(params, rng) -> dict:
    """Fault injection on the serving engine's batched dispatch path:
    1-in-16 lanes persistently poisoned at batch 8.  Lane-level fault
    isolation must quarantine exactly the poisoned lane (one error result)
    while its 7 batchmates complete from their already-computed state —
    zero healthy-lane re-encryptions, batch occupancy within 0.9x of the
    fault-free run.  Both are CI-gated by
    ``scripts/check_bench_regression.py``."""
    import time

    from repro.retrieval.index import FlatIndex
    from repro.serve import EngineConfig, ServeEngine
    from repro.serve.session import SessionManager

    dim, num_docs, n_req, max_batch = 64, 2048, 16, 8
    emb = _unit(rng, num_docs, dim)
    index = FlatIndex.build(
        emb, documents=[f"doc-{i}".encode() for i in range(num_docs)])
    queries = _unit(rng, n_req, dim)

    def run_stream(poison_ids=None):
        # deterministic seeds + fixed per-request keys: both passes replay
        # identical streams, so the fault-free pass's result ids identify
        # the poisoned lane's fetches in the faulty pass
        eng = ServeEngine(
            index,
            config=EngineConfig(max_batch=max_batch, max_wait_s=30.0),
            sessions=SessionManager(rlwe_params=params,
                                    deterministic_seeds=True))
        for t in range(4):
            eng.open_session(f"bench-{t}", n=dim, N=num_docs, k=4,
                             radius=0.05, backend="rlwe")
        if poison_ids is not None:
            real = type(eng.cloud).handle_fetch

            def poisoned(cand_ids, msg):
                ids = [int(cand_ids[p]) for p in msg.positions]
                if ids == poison_ids:       # that lane and its solo retry
                    raise RuntimeError("bench-poisoned lane")
                return real(eng.cloud, cand_ids, msg)

            eng.cloud.handle_fetch = poisoned
        for i in range(n_req):
            eng.submit(f"bench-{i % 4}", queries[i],
                       key=jax.random.PRNGKey(i))
        t0 = time.perf_counter()
        out = eng.drain()
        wall_us = (time.perf_counter() - t0) * 1e6
        eng.close()
        return out, eng.metrics, wall_us

    clean, m_clean, clean_us = run_stream()
    assert all(r.ok for r in clean), "fault-free serve pass must succeed"
    faulty, m_fault, fault_us = run_stream(clean[0].ids.tolist())
    errors = [r for r in faulty if not r.ok]
    assert len(errors) == 1 and errors[0].request_id == 0, \
        "exactly the poisoned lane must error"
    for rs, rb in zip(clean[1:], faulty[1:]):
        assert rs.ids.tolist() == rb.ids.tolist(), \
            "healthy lanes must be unaffected by the poisoned lane"
    occ_clean = m_clean.occupancy(max_batch)
    occ_fault = m_fault.occupancy(max_batch)
    section = {
        "num_docs": num_docs,
        "requests": n_req,
        "max_batch": max_batch,
        "poisoned_lanes": 1,
        "wall_fault_free_us": clean_us,
        "wall_faulty_us": fault_us,
        "occupancy_fault_free": occ_clean,
        "occupancy_faulty": occ_fault,
        "occupancy_ratio": occ_fault / occ_clean,
        "healthy_lane_reencryptions": m_fault.healthy_reencryptions,
        "lane_encryptions": m_fault.lane_encryptions,
        "quarantined_lanes": m_fault.quarantined_lanes,
        "retried_requests": m_fault.retried_requests,
        "error_results": m_fault.error_results,
        "num_batches": m_fault.num_batches,
    }
    emit("rlwe/serve_fault_occupancy_b8", fault_us,
         f"{section['occupancy_ratio']:.2f}x_vs_fault_free")
    emit("rlwe/serve_fault_wasted_lanes", m_fault.healthy_reencryptions,
         f"{m_fault.quarantined_lanes}quarantined_"
         f"{m_fault.error_results}errors")
    return section


def _stage_breakdown_section(params, rng) -> dict:
    """Where a served request's time goes, stage by stage: one traced
    engine stream (sharded cache, so admission/gather show up) emits the
    repro.obs per-stage histograms into the bench payload — future PRs
    (Paillier limb batching, ANN routing, TPU kernels) can prove which
    stage they moved instead of pointing at an end-to-end number.  An
    untraced pass of the same stream runs first (also the jit warmup), so
    the traced/untraced wall ratio documents the enabled-tracing cost;
    ``stage_coverage`` (sum of stage durations / dispatch duration) is
    CI-gated to stay in [0.5, 1.05] — the timeline must keep accounting
    for the pipeline it claims to explain."""
    import time

    from repro.retrieval.index import FlatIndex
    from repro.serve import EngineConfig, ServeEngine
    from repro.serve.session import SessionManager

    dim, num_docs, n_req, max_batch = 64, 2048, 16, 8
    emb = _unit(rng, num_docs, dim)
    index = FlatIndex.build(
        emb, documents=[f"doc-{i}".encode() for i in range(num_docs)])
    queries = _unit(rng, n_req, dim)
    cache_cfg = rlwe.CandidateCacheConfig(num_shards=8, admit_threshold=1)

    def run_stream(trace: bool):
        eng = ServeEngine(
            index,
            config=EngineConfig(max_batch=max_batch, max_wait_s=30.0,
                                cache_config=cache_cfg, trace=trace),
            sessions=SessionManager(rlwe_params=params,
                                    deterministic_seeds=True))
        for t in range(4):
            eng.open_session(f"bench-{t}", n=dim, N=num_docs, k=4,
                             radius=0.05, backend="rlwe")
        for i in range(n_req):
            eng.submit(f"bench-{i % 4}", queries[i],
                       key=jax.random.PRNGKey(i))
        t0 = time.perf_counter()
        out = eng.drain()
        wall_us = (time.perf_counter() - t0) * 1e6
        assert all(r.ok for r in out), "stage-breakdown stream must succeed"
        tracer = eng.tracer
        eng.close()
        return wall_us, tracer

    untraced_us, _ = run_stream(trace=False)       # also the jit warmup
    traced_us, tracer = run_stream(trace=True)
    stages = tracer.stage_summary()
    core = ("perturb", "topk", "encrypt", "score", "decrypt", "finish")
    stage_sum = sum(stages[s]["total_s"] for s in core if s in stages)
    dispatch_s = stages["dispatch"]["total_s"]
    coverage = stage_sum / dispatch_s
    emit("rlwe/serve_stage_coverage_b8", traced_us, f"{coverage:.2f}x")
    for s in core:
        if s in stages:
            emit(f"rlwe/serve_stage_{s}", stages[s]["total_s"] * 1e6,
                 f"p99={stages[s]['p99_s'] * 1e6:.0f}us")
    return {
        "num_docs": num_docs,
        "requests": n_req,
        "max_batch": max_batch,
        "wall_untraced_us": untraced_us,
        "wall_traced_us": traced_us,
        "traced_overhead_ratio": traced_us / untraced_us,
        "stage_coverage": coverage,
        "trace_spans": len(tracer.spans()),
        "trace_dropped": tracer.dropped,
        "stages": stages,
    }


def _paillier_batch_section(rng) -> dict:
    """Vectorized-Paillier section: the RNS limb-array batch path
    (`repro.crypto.paillier_vec`, fixed-width residue channels +
    Montgomery GEMM kernels) vs the per-lane bignum object path
    (`repro.crypto.paillier`) on the encrypted re-rank, at batch 1 and 8.
    The batch-8 speedup is CI-gated at >= 3x by
    ``scripts/check_bench_regression.py`` (missing section = FAIL), along
    with bit-exact decrypted scores and zero silent object fallbacks at
    the benchmark key size."""
    import time

    from repro.crypto import paillier as pai
    from repro.crypto import paillier_vec as pvec

    key_bits, dim, kprime, big = 256, 384, 64, 8
    keys = [pai.keygen(key_bits, rng=np.random.default_rng(1000 + i))
            for i in range(big)]
    queries = _unit(rng, big, dim).astype(np.float64)
    cands = [_unit(rng, kprime, dim).astype(np.float64) for _ in range(big)]
    enc = [pai.encrypt_vector(k.pub, q, rng=np.random.default_rng(2000 + i))
           for i, (k, q) in enumerate(zip(keys, queries))]

    pvec.reset_counters()
    t0 = time.perf_counter()          # first call pays the jit compile
    warm = pvec.encrypted_scores_batch([k.pub for k in keys], enc, cands)
    compile_ms = (time.perf_counter() - t0) * 1e3

    # bit-exactness: the vectorized ciphertexts must decrypt to exactly
    # the object path's scores (both are exact integer arithmetic)
    obj_cts = [pai.encrypted_scores(k.pub, e, c)
               for k, e, c in zip(keys, enc, cands)]
    bit_exact = all(
        np.array_equal(pai.decrypt_scores(k, v), pai.decrypt_scores(k, o))
        for k, v, o in zip(keys, warm, obj_cts))
    assert bit_exact, "vectorized scores must decrypt bit-exact vs object"

    section = {"key_bits": key_bits, "dim": dim, "kprime": kprime,
               "compile_ms": compile_ms, "bit_exact": bit_exact}
    for bsz in (1, big):
        ks, es, cs = keys[:bsz], enc[:bsz], cands[:bsz]

        def object_path():
            for k, e, c in zip(ks, es, cs):
                pai.encrypted_scores(k.pub, e, c)

        def vectorized():
            pvec.encrypted_scores_batch([k.pub for k in ks], es, cs)

        object_us = timeit(object_path, repeat=2, warmup=0)
        vec_us = timeit(vectorized, repeat=3, warmup=1)
        speedup = object_us / vec_us
        emit(f"paillier/score_object_b{bsz}", object_us,
             f"kb={key_bits}_k'={kprime}")
        emit(f"paillier/score_vectorized_b{bsz}", vec_us,
             f"{speedup:.2f}x_vs_object")
        section[f"batch{bsz}"] = {
            "object_ms": object_us / 1e3,
            "vectorized_ms": vec_us / 1e3,
            "speedup_vectorized_vs_object": speedup,
        }
    section["object_fallback_lanes"] = pvec.counters["object"]
    section["vectorized_lanes"] = pvec.counters["vectorized"]
    emit("paillier/vectorized_fallbacks", section["object_fallback_lanes"],
         f"{section['vectorized_lanes']}vectorized_lanes")
    return section


def _ivf_routing_section(rng) -> dict:
    """IVF first-stage routing vs the flat scan at corpus scale (the
    ``ivf_routing`` section): a clustered 10^4-doc corpus (10^5 under
    REPRO_BENCH_FULL=1), top-k' through `cluster_topk` at the
    planner-derived ``nprobe`` vs `distributed_topk` over every row.
    CI gates (``scripts/check_bench_regression.py``, missing section =
    FAIL): routed >= 2x faster than flat, recall@k' == 1.0 at the planned
    probe bound, and the ``nprobe=all`` run bit-identical to the flat
    scan — the differential anchor that routing is a pure schedule
    change, not a scoring change."""
    from repro.retrieval.index import FlatIndex, IvfConfig
    from repro.retrieval.topk import (cluster_topk, distributed_topk,
                                      plan_nprobe)

    num_docs = 100_000 if FULL else 10_000
    dim, num_clusters, kprime, n_q = 256, 25, 32, 16
    # clustered corpus: equal-size tight clusters around random unit
    # centers — the regime IVF exists for (uniform-random rows have no
    # locality to route on, and no planner bound can fix that).  The
    # perturbation is a *unit* direction scaled to 0.1, so cluster radius
    # stays small at any dim (per-component gaussians would grow the
    # noise norm with sqrt(dim) and smear the clusters).
    centers = _unit(rng, num_clusters, dim)
    assign = np.repeat(np.arange(num_clusters), num_docs // num_clusters)
    emb = centers[assign] + 0.1 * _unit(rng, num_docs, dim)
    emb = (emb / np.linalg.norm(emb, axis=-1, keepdims=True)).astype(
        np.float32)
    index = FlatIndex.build(
        emb, normalize=False, ivf=IvfConfig(num_clusters=num_clusters))
    view = index.corpus_view()
    cm = view.cluster_map
    # queries concentrate on 4 hot topics (the repeat-tenant regime the
    # routed scan batches well: few distinct clusters per dispatch wave)
    hot = centers[np.repeat([0, 6, 12, 18], n_q // 4)]
    queries = hot + 0.1 * _unit(rng, n_q, dim)
    queries = (queries / np.linalg.norm(queries, axis=-1,
                                        keepdims=True)).astype(np.float32)

    nprobe = plan_nprobe(cm, kprime)
    flat = distributed_topk(index, queries, kprime)
    routed = cluster_topk(view, queries, kprime, nprobe=nprobe)
    flat_ids = np.asarray(flat.indices)
    routed_ids = np.asarray(routed.indices)
    recall = float(np.mean([
        len(set(flat_ids[b]) & set(routed_ids[b])) / kprime
        for b in range(n_q)]))
    # nprobe=all == flat scan, bit-identical (values and ids)
    full = cluster_topk(view, queries, kprime, nprobe=num_clusters)
    anchor = bool(
        np.array_equal(np.asarray(full.indices), flat_ids)
        and np.array_equal(np.asarray(full.values),
                           np.asarray(flat.values))
        and bool(full.exact))
    assert anchor, "nprobe=all must be bit-identical to the flat scan"

    def flat_scan():
        np.asarray(distributed_topk(index, queries, kprime).values)

    def routed_scan():
        np.asarray(cluster_topk(view, queries, kprime,
                                nprobe=nprobe).values)

    flat_us = timeit(flat_scan, repeat=9, warmup=2)
    routed_us = timeit(routed_scan, repeat=9, warmup=2)
    speedup = flat_us / routed_us
    rows_routed = int(np.max(cm.sizes[cm.route(queries, nprobe)]
                             .sum(axis=1)))
    emit("rlwe/ivf_flat_scan", flat_us, f"{num_docs}docs_k'={kprime}")
    emit("rlwe/ivf_routed_scan", routed_us,
         f"{speedup:.1f}x_vs_flat_nprobe={nprobe}")
    emit("rlwe/ivf_recall_at_kprime", recall * 100.0,
         f"rows<={rows_routed}/{num_docs}")
    return {
        "num_docs": num_docs,
        "dim": dim,
        "num_clusters": num_clusters,
        "kprime": kprime,
        "queries": n_q,
        "nprobe": nprobe,
        "flat_us": flat_us,
        "routed_us": routed_us,
        "speedup_routed_vs_flat": speedup,
        "recall_at_kprime": recall,
        "nprobe_all_bit_identical": anchor,
        "max_rows_routed": rows_routed,
    }


def _ingestion_section(params, rng) -> dict:
    """Streaming ingestion under live traffic (the ``ingestion`` section):
    a serving engine over the sharded candidate cache, with a tail-shard
    ingest (`FlatIndex.ingest` -> `ShardedCandidateCache.ingest_tail`)
    landing *between dispatch steps* of a draining stream.  The engine is
    pinned to its epoch-0 `CorpusView`, so every in-flight request must
    return bit-identical results to a no-ingest reference run — zero
    lost, zero bit-drift — while the cache's epoch advances underneath.
    After `refresh_corpus` the ingested rows are reachable.  All
    CI-gated (missing section = FAIL)."""
    import time

    from repro.retrieval.index import FlatIndex, IvfConfig
    from repro.serve import EngineConfig, ServeEngine
    from repro.serve.session import SessionManager

    dim, num_docs, n_new, n_req, max_batch = 64, 2048, 128, 16, 4
    shard_docs = 256
    emb = _unit(rng, num_docs, dim)
    docs = [f"doc-{i}".encode() for i in range(num_docs)]
    tail = _unit(rng, n_new, dim)
    queries = _unit(rng, n_req, dim)
    # shard-aligned IVF build: each 256-row cluster is exactly one cache
    # shard, so routing and residency speak the same ranges
    cfg = rlwe.CandidateCacheConfig(shard_docs=shard_docs)

    def build_engine():
        index = FlatIndex.build(
            emb, documents=docs, normalize=False,
            ivf=IvfConfig(num_clusters=num_docs // shard_docs,
                          align=shard_docs))
        eng = ServeEngine(
            index,
            config=EngineConfig(max_batch=max_batch, max_wait_s=30.0,
                                cache_config=cfg),
            sessions=SessionManager(rlwe_params=params,
                                    deterministic_seeds=True))
        for t in range(4):
            eng.open_session(f"bench-{t}", n=dim, N=num_docs, k=4,
                             radius=0.05, backend="rlwe")
        return eng

    def submit_all(eng):
        for i in range(n_req):
            eng.submit(f"bench-{i % 4}", queries[i],
                       key=jax.random.PRNGKey(i))

    ref_eng = build_engine()
    submit_all(ref_eng)
    want = {r.request_id: r for r in ref_eng.drain()}
    ref_eng.close()

    eng = build_engine()
    submit_all(eng)
    out = eng.step()        # first batch through: the lazy cache is live
    t0 = time.perf_counter()
    eng.cloud.index.ingest(tail, documents=[f"new-{i}".encode()
                                            for i in range(n_new)],
                           normalize=False)
    ingest_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    out += eng.drain()      # the rest of the stream rides the swap
    drain_us = (time.perf_counter() - t0) * 1e6
    stats = eng.cache_stats()

    lost = n_req - len(out)
    drift = sum(
        1 for r in out
        if not (r.ok and r.ids.tolist() == want[r.request_id].ids.tolist()
                and r.docs == want[r.request_id].docs
                and r.transcript.total_bytes
                == want[r.request_id].transcript.total_bytes))
    assert lost == 0 and drift == 0, \
        f"ingest under live traffic: lost={lost} drift={drift}"

    # epoch advance: after refresh the tail rows are reachable
    view = eng.refresh_corpus()
    eng.open_session("bench-fresh", n=dim, N=num_docs + n_new, k=4,
                     radius=0.05, backend="rlwe")
    probe = eng.submit("bench-fresh", tail[0],
                       key=jax.random.PRNGKey(10_000))
    post = eng.drain()
    reachable = any(r.request_id == probe
                    and any(int(i) >= num_docs for i in r.ids)
                    for r in post)
    assert reachable, "ingested rows must be servable after refresh"
    eng.close()

    section = {
        "num_docs": num_docs,
        "ingested_docs": n_new,
        "shard_docs": shard_docs,
        "requests": n_req,
        "max_batch": max_batch,
        "ingest_us": ingest_us,
        "drain_after_ingest_us": drain_us,
        "lost_requests": lost,
        "bit_drift_requests": drift,
        "epoch_before": 0,
        "epoch_after": int(view.epoch),
        "cache_ingests": int(stats["ingests"]) if stats else 0,
        "tail_reachable_after_refresh": reachable,
    }
    emit("rlwe/ingest_tail_swap", ingest_us,
         f"{n_new}docs_epoch{section['epoch_after']}")
    emit("rlwe/ingest_live_stream", drain_us,
         f"lost={lost}_drift={drift}")
    return section


def run() -> None:
    if FULL:
        params = rlwe.RlweParams()                    # N=4096, chunk=1024
        n_dim, num_docs, kprime = 3072, 20_000, 115   # paper Table 5 regime
    else:
        # n_dim=3072 (text-embedding-3-large, Table 5): 6 chunks per doc —
        # the regime where cold per-request packing + forward NTTs dominate
        params = rlwe.RlweParams(n_poly=1024, chunk=512)
        n_dim, num_docs, kprime = 3072, 512, 32
    rng = np.random.default_rng(0)
    docs = _unit(rng, num_docs, n_dim)
    sk = rlwe.keygen(params, rng)

    builds = []
    build_us = timeit(
        lambda: builds.append(rlwe.build_candidate_cache(params, docs)),
        repeat=1, warmup=0)
    cache = builds[0]
    emit("rlwe/cache_build", build_us,
         f"{cache.nbytes / 2**20:.1f}MiB/{num_docs}docs")

    results = {}
    for bsz in (1, 8):
        queries = _unit(rng, bsz, n_dim)
        q_cts = [rlwe.encrypt_query(sk, q, rng) for q in queries]
        ids = rng.integers(0, num_docs, size=(bsz, kprime))
        rows = docs[ids]

        def cold():
            packed = rlwe.pack_candidates_batch(params, rows)
            out = rlwe.encrypted_scores_batch_stacked(
                params, q_cts, packed, kprime, n_dim, use_pallas=False)
            jax.block_until_ready(out.c0)

        def cached():
            out = rlwe.encrypted_scores_cached_batch(
                params, q_cts, cache, ids, use_pallas=False)
            jax.block_until_ready(out.c0)

        def fused():
            out = rlwe.encrypted_scores_cached_batch(
                params, q_cts, cache, ids, use_pallas=True)
            jax.block_until_ready(out.c0)

        cold_us = timeit(cold, repeat=9, warmup=2)
        cached_us = timeit(cached, repeat=9, warmup=2)
        # interpret-mode Pallas off-TPU: correctness/overhead tracking only
        fused_us = timeit(fused, repeat=3)
        qps = bsz / (cached_us / 1e6)
        speedup = cold_us / cached_us
        emit(f"rlwe/score_cold_b{bsz}", cold_us, f"k'={kprime}")
        emit(f"rlwe/score_cached_b{bsz}", cached_us,
             f"{speedup:.1f}x_vs_cold")
        emit(f"rlwe/score_cached_fused_b{bsz}", fused_us,
             "interpret" if jax.default_backend() != "tpu" else "tpu")
        emit(f"rlwe/qps_cached_b{bsz}", cached_us, f"{qps:.1f}qps")
        results[f"batch{bsz}"] = {
            "cold_pack_us": cold_us,
            "cached_us": cached_us,
            "cached_fused_us": fused_us,
            "speedup_cached_vs_cold": speedup,
            "per_request_cold_us": cold_us / bsz,
            "per_request_cached_us": cached_us / bsz,
            "cached_qps": qps,
        }

    # -- corpus scale: dense device-resident vs sharded HBM-resident cache --
    big_docs = 100_000 if FULL else 10_000
    big = _unit(rng, big_docs, n_dim)
    big_builds = []
    big_build_us = timeit(
        lambda: big_builds.append(rlwe.build_candidate_cache(params, big)),
        repeat=1, warmup=0)
    dense_big = big_builds[0]
    emit("rlwe/dense_cache_build_10k", big_build_us,
         f"{dense_big.nbytes / 2**20:.0f}MiB/{big_docs}docs")
    num_shards = 16
    budget = dense_big.nbytes // 8           # room for 2 of the 16 shards
    # two access regimes, two configs:
    #  * uniform-random ids (the gated comparison): stream-only — pinning
    #    under uniform traffic is pure churn (a shard admission is a
    #    shard-sized host->device copy in the request path), so the right
    #    configuration gathers each request's k' rows on demand and keeps
    #    device memory at just the gather buffer;
    #  * skewed ids confined to explicitly pinned hot shards (the repeat-
    #    tenant case the LRU exists for): gathers run device-side.
    cfg_stream = rlwe.CandidateCacheConfig(num_shards=num_shards,
                                           max_resident_bytes=0)
    views = []
    view_us = timeit(
        lambda: views.append(rlwe.shard_candidate_cache(dense_big,
                                                        cfg_stream)),
        repeat=1, warmup=0)   # re-view of the retained host pool, no re-pack
    stream = views[0]
    emit("rlwe/sharded_view_10k", view_us, f"{stream.num_shards}shards")
    hot = rlwe.shard_candidate_cache(
        dense_big, rlwe.CandidateCacheConfig(
            num_shards=num_shards, max_resident_bytes=budget,
            pin_on_access=False))
    hot.pin(0)
    hot.pin(1)

    sharded = {
        "num_docs": big_docs,
        "num_shards": stream.num_shards,
        "shard_docs": stream.shard_docs,
        "dense_cache_bytes": dense_big.nbytes,
        "hot_budget_bytes": budget,
        "dense_cache_build_us": big_build_us,
        "shard_view_us": view_us,
    }
    for bsz in (1, 8):
        queries = _unit(rng, bsz, n_dim)
        q_cts = [rlwe.encrypt_query(sk, q, rng) for q in queries]
        ids = rng.integers(0, big_docs, size=(bsz, kprime))
        ids_hot = rng.integers(0, 2 * stream.shard_docs, size=(bsz, kprime))

        def dense_score(ids=ids):
            out = rlwe.encrypted_scores_cached_batch(
                params, q_cts, dense_big, ids, use_pallas=False)
            jax.block_until_ready(out.c0)

        def stream_score():
            out = rlwe.encrypted_scores_cached_batch(
                params, q_cts, stream, ids, use_pallas=False)
            jax.block_until_ready(out.c0)

        def hot_score():
            out = rlwe.encrypted_scores_cached_batch(
                params, q_cts, hot, ids_hot, use_pallas=False)
            jax.block_until_ready(out.c0)

        def gather_only():
            jax.block_until_ready(stream.gather(ids))

        dense_us = timeit(dense_score, repeat=9, warmup=2)
        sharded_us = timeit(stream_score, repeat=9, warmup=2)
        gather_us = timeit(gather_only, repeat=9, warmup=2)
        dense_hot_us = timeit(lambda: dense_score(ids_hot),
                              repeat=9, warmup=2)
        hot_us = timeit(hot_score, repeat=9, warmup=2)
        gather_buf = bsz * kprime * stream.num_chunks * \
            params.num_primes * params.n_poly * 4
        # peak device footprint of the gated (streaming) layout: no pinned
        # shards, just the transient per-request gather buffer
        peak = stream.peak_resident_bytes + gather_buf
        ratio = sharded_us / dense_us
        emit(f"rlwe/score_dense10k_b{bsz}", dense_us, f"k'={kprime}")
        emit(f"rlwe/score_sharded10k_b{bsz}", sharded_us,
             f"{ratio:.2f}x_vs_dense")
        emit(f"rlwe/gather_sharded10k_b{bsz}", gather_us,
             f"{gather_buf / 2**20:.1f}MiB/req")
        emit(f"rlwe/score_sharded_hot10k_b{bsz}", hot_us,
             f"{hot_us / dense_hot_us:.2f}x_vs_dense_pinned")
        sharded[f"batch{bsz}"] = {
            "dense_us": dense_us,
            "sharded_us": sharded_us,
            "gather_us": gather_us,
            "ratio_sharded_vs_dense": ratio,
            "dense_hot_us": dense_hot_us,
            "sharded_hot_us": hot_us,
            "ratio_hot_vs_dense": hot_us / dense_hot_us,
            "request_gather_bytes": gather_buf,
            "peak_sharded_bytes": peak,
            "memory_reduction_vs_dense": dense_big.nbytes / peak,
            "hot_peak_bytes": hot.peak_resident_bytes + gather_buf,
        }
    sharded["hot_lru"] = hot.stats()
    sharded["hot_lru"]["resident_shards"] = list(
        sharded["hot_lru"]["resident_shards"])
    emit("rlwe/sharded_peak_mem_mib",
         sharded["batch8"]["peak_sharded_bytes"] / 2**20,
         f"{sharded['batch8']['memory_reduction_vs_dense']:.1f}x_smaller"
         f"_than_dense")

    # -- both regimes under ONE default-policy config ------------------------
    # The async, frequency-aware admission policy (admit on 2nd touch inside
    # a decayed-counter window; H2D copy on the background admitter, off the
    # request path) is what lets a single CandidateCacheConfig serve both
    # access regimes: skewed ids admit their hot shards after one repeat and
    # then gather device-side, while uniform ids mostly stream (background
    # churn bounded by the admit queue) instead of paying a shard-sized
    # synchronous copy per miss.  CI gates both ratios under this one
    # config (scripts/check_bench_regression.py) so the synchronous-
    # admission churn regression can never come back.
    cfg_default = rlwe.CandidateCacheConfig(num_shards=num_shards,
                                            max_resident_bytes=budget)
    adaptive = rlwe.shard_candidate_cache(dense_big, cfg_default)
    default_cfg = {
        "num_shards": adaptive.num_shards,
        "hot_budget_bytes": budget,
        "async_admission": cfg_default.async_admission,
        "admit_threshold": cfg_default.admit_threshold,
    }
    bsz = 8
    queries = _unit(rng, bsz, n_dim)
    q_cts = [rlwe.encrypt_query(sk, q, rng) for q in queries]
    regime_ids = {
        "uniform": rng.integers(0, big_docs, size=(bsz, kprime)),
        "skewed": rng.integers(0, 2 * adaptive.shard_docs,
                               size=(bsz, kprime)),
    }
    for regime, ids in regime_ids.items():
        def dense_score():
            out = rlwe.encrypted_scores_cached_batch(
                params, q_cts, dense_big, ids, use_pallas=False)
            jax.block_until_ready(out.c0)

        def adaptive_score():
            # the serving engine's request shape: prefetch the admissions
            # as soon as the ids are known, then score (the gather streams
            # until the background swap lands — it never blocks)
            adaptive.prefetch(ids)
            out = rlwe.encrypted_scores_cached_batch(
                params, q_cts, adaptive, ids, use_pallas=False)
            jax.block_until_ready(out.c0)

        dense_us = timeit(dense_score, repeat=9, warmup=2)
        adaptive_us = timeit(adaptive_score, repeat=9, warmup=2)
        ratio = adaptive_us / dense_us
        emit(f"rlwe/score_default_cfg_{regime}10k_b{bsz}", adaptive_us,
             f"{ratio:.2f}x_vs_dense")
        default_cfg[regime] = {
            "dense_us": dense_us,
            "adaptive_us": adaptive_us,
            "ratio_vs_dense_b8": ratio,
        }
    adaptive.flush()
    stats = adaptive.stats()
    stats["resident_shards"] = list(stats["resident_shards"])
    default_cfg["stats"] = stats
    emit("rlwe/default_cfg_admissions", stats["async_admissions"],
         f"{stats['policy_deferrals']}deferred_"
         f"{stats['admit_dropped']}dropped")
    sharded["default_config"] = default_cfg
    results["sharded"] = sharded

    results["serve_faults"] = _serve_fault_section(params, rng)
    results["stage_breakdown"] = _stage_breakdown_section(params, rng)
    results["paillier_batch"] = _paillier_batch_section(rng)
    results["ivf_routing"] = _ivf_routing_section(rng)
    results["ingestion"] = _ingestion_section(params, rng)

    payload = {
        "bench": "rlwe_rerank",
        "backend": jax.default_backend(),
        "config": {"n_poly": params.n_poly, "num_primes": params.num_primes,
                   "chunk": params.chunk, "n_dim": n_dim,
                   "num_docs": num_docs, "kprime": kprime,
                   "cache_bytes": cache.nbytes,
                   "cache_build_us": build_us, "full": FULL},
        "results": results,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH}", flush=True)


if __name__ == "__main__":
    run()
