"""Compressed gradient all-reduce inside shard_map (multi-device subprocess)."""

import subprocess
import sys

SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import compress

mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
# per-device distinct gradients: the compressed psum must approximate the sum
g = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
g_sharded = jax.device_put(g, NamedSharding(mesh, P("data", None)))

transform = compress.make_compressed_psum(mesh, ("data",))
with mesh:
    out = jax.jit(lambda x: transform({"w": x}))(g_sharded)["w"]
want = np.asarray(g).sum(axis=0, keepdims=True).repeat(4, 0).reshape(4, 256)
# int8 quantization error bounded by scale/2 per term, 4 terms
got = np.asarray(out)
scale = np.abs(np.asarray(g)).max() / 127.0
assert got.shape == (4, 256)
assert np.max(np.abs(got - want)) <= 4 * scale + 1e-5, \
    np.max(np.abs(got - want))
print("COMPRESS_OK")
"""


def test_compressed_psum_multidevice():
    r = subprocess.run([sys.executable, "-c", SNIPPET],
                       capture_output=True, text=True, timeout=300,
                       cwd=__file__.rsplit("/tests/", 1)[0])
    assert "COMPRESS_OK" in r.stdout, r.stdout + r.stderr[-2000:]
