"""AdamW + global-norm clipping + cosine schedule, pure JAX (no optax).

Optimizer state is a pytree mirroring params (fp32 master copy + moments),
so it shards with the same PartitionSpecs as the parameters — FSDP'd over
"data" automatically under the 2D sharding.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array        # () int32
    master: object         # fp32 copy of params
    m: object
    v: object


def init(params, cfg: AdamWConfig) -> OptState:
    # copy=True: with f32 params, astype would alias the param buffer and
    # donating (params, opt_state) together would donate it twice.
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.int32(0),
                    master=jax.tree.map(f32, params),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def abstract_init(abstract_params, cfg: AdamWConfig) -> OptState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    master=jax.tree.map(f32, abstract_params),
                    m=jax.tree.map(f32, abstract_params),
                    v=jax.tree.map(f32, abstract_params))


def state_specs(param_specs) -> OptState:
    """PartitionSpecs for the optimizer state (mirror the params)."""
    from jax.sharding import PartitionSpec as P

    return OptState(step=P(), master=param_specs, m=param_specs,
                    v=param_specs)


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def apply(grads, state: OptState, cfg: AdamWConfig, *, param_dtype=None):
    """One AdamW step; returns (new_params_in_compute_dtype, new_state, stats)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    lr = schedule(cfg, step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.beta1 * m + (1 - cfg.beta1) * g,
                         state.m, grads)
    new_v = jax.tree.map(lambda v, g: cfg.beta2 * v + (1 - cfg.beta2) * g * g,
                         state.v, grads)

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        return p - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)

    new_master = jax.tree.map(upd, state.master, new_m, new_v)
    cast = (lambda p: p) if param_dtype is None else \
        (lambda p: p.astype(param_dtype))
    new_params = jax.tree.map(cast, new_master)
    return new_params, OptState(step, new_master, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}


__all__ = ["AdamWConfig", "OptState", "init", "abstract_init", "state_specs",
           "schedule", "global_norm", "apply"]
