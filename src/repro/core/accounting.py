"""Communication cost model (paper Table 2 + Section 4.2).

Units: one number = beta units, one document = eta units.  We provide both
the paper's symbolic formulas (validated against measured message sizes in
tests) and concrete byte counts for each crypto backend.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CommCost:
    rounds: float
    numbers: int        # beta units
    documents: int      # eta units

    def bytes_total(self, beta: int = 4, eta: int = 1024) -> int:
        return self.numbers * beta + self.documents * eta


def privacy_ignorant(n: int, k: int) -> CommCost:
    """Plaintext embedding up, k documents down."""
    return CommCost(rounds=1.0, numbers=n, documents=k)


def privacy_conscious(n: int, big_n: int) -> CommCost:
    """Modules 2(a)+2(c) with k' = N: PHE over all N + OT over all N."""
    return CommCost(rounds=2.0, numbers=n + 2 * big_n + 1, documents=big_n)


def remoterag_direct(n: int, k: int, kprime: int) -> CommCost:
    """Modules 1 + 2(a) + 2(b): 2.5 rounds, (2n + k + k' + 1)b + k*eta."""
    return CommCost(rounds=2.5, numbers=2 * n + k + kprime + 1, documents=k)


def remoterag_ot(n: int, kprime: int) -> CommCost:
    """Modules 1 + 2(a) + 2(c): 3 rounds, 2(n + k' + 1)b + k'*eta."""
    return CommCost(rounds=3.0, numbers=2 * (n + kprime + 1), documents=kprime)


def optimized_rounds(cost: CommCost) -> CommCost:
    """Section 4.2 'practical optimization': piggyback module-1 + 2(a) and the
    distance reply + OT start — 2 rounds for either path."""
    return dataclasses.replace(cost, rounds=2.0)


# ---------------------------------------------------------------------------
# concrete wire-size models per crypto backend
# ---------------------------------------------------------------------------

def paillier_query_bytes(n: int, key_bits: int = 2048) -> int:
    """n ciphertexts of 2*key_bits each."""
    return n * 2 * key_bits // 8


def paillier_scores_bytes(kprime: int, key_bits: int = 2048) -> int:
    return kprime * 2 * key_bits // 8


def rlwe_query_bytes(n: int, *, n_poly: int = 4096, num_primes: int = 3,
                     chunk: int = 1024, coeff_bits: int = 20) -> int:
    chunks = -(-n // chunk)
    return chunks * 2 * num_primes * n_poly * coeff_bits // 8


def rlwe_scores_bytes(kprime: int, n: int, *, n_poly: int = 4096,
                      num_primes: int = 3, chunk: int = 1024,
                      coeff_bits: int = 20) -> int:
    stride = chunk if n <= chunk else 2 * chunk
    cands_per_ct = n_poly // stride
    num_ct = -(-kprime // cands_per_ct)
    return num_ct * 2 * num_primes * n_poly * coeff_bits // 8


__all__ = [
    "CommCost", "privacy_ignorant", "privacy_conscious", "remoterag_direct",
    "remoterag_ot", "optimized_rounds", "paillier_query_bytes",
    "paillier_scores_bytes", "rlwe_query_bytes", "rlwe_scores_bytes",
]
