"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, d_ff=768, vocab=151936, d_head=128, qk_norm=True,
    rope_theta=1_000_000.0, moe_experts=128, moe_top_k=8, moe_d_ff=768,
    tp=16)

REDUCED = TransformerConfig(
    name="qwen3-moe-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=1024, d_head=32, qk_norm=True, moe_experts=8, moe_top_k=2,
    moe_d_ff=96, dtype="float32", remat=False, kv_chunk=64)
