"""The crypto-backend seam (repro.crypto.backend) and the vectorized
Paillier fallback boundary (repro.crypto.paillier_vec): typed unknown-
backend errors, wire parity against the object path under deterministic
seeds, bit-exact batched decryption, and the oversized-key object
fallback."""

import numpy as np
import pytest

from repro.core import protocol
from repro.crypto import backend as backends
from repro.crypto import paillier as pai
from repro.crypto import paillier_vec as pvec
from repro.crypto import rlwe

DIM, KPRIME = 48, 12


def _keys(n, bits=256):
    return [pai.keygen(bits, rng=np.random.default_rng(100 + i))
            for i in range(n)]


def _unit(rng, *shape):
    x = rng.normal(size=shape)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


# -- registry / typed errors (satellite: UnknownBackend) --------------------


def test_get_backend_registry():
    assert backends.available() == ("paillier", "rlwe")
    assert backends.get_backend("rlwe").name == "rlwe"
    assert backends.get_backend("paillier").name == "paillier"


def test_unknown_backend_is_typed_valueerror():
    with pytest.raises(backends.UnknownBackend) as ei:
        backends.get_backend("ecc")
    assert isinstance(ei.value, ValueError)
    assert ei.value.backend == "ecc"
    assert ei.value.known == ("paillier", "rlwe")
    assert "ecc" in str(ei.value) and "rlwe" in str(ei.value)


def test_unknown_backend_raises_from_user_ctor():
    with pytest.raises(backends.UnknownBackend):
        protocol.RemoteRagUser(n=DIM, N=512, k=3, radius=0.05,
                               backend="bgv")


def test_scores_backend_structural_dispatch():
    params = rlwe.RlweParams(n_poly=1024, chunk=512)
    sk = rlwe.keygen(params, np.random.default_rng(0))
    ct = rlwe.encrypt_query(sk, _unit(np.random.default_rng(1), DIM),
                            np.random.default_rng(2))
    rows = _unit(np.random.default_rng(3), KPRIME, DIM)
    packed = rlwe.pack_candidates(params, rows)
    scores = rlwe.encrypted_scores(params, ct, packed, use_pallas=False)
    assert backends.scores_backend(scores).name == "rlwe"
    assert backends.scores_backend([1, 2, 3]).name == "paillier"


# -- satellite 4: wire parity at the fallback boundary ----------------------


def test_encrypt_vector_wire_parity():
    """Same seed -> the vectorized encryptor must emit the *identical*
    ciphertext integers as the object path (not just equal plaintexts):
    identical randomness consumption, identical wire bytes."""
    sk = _keys(1)[0]
    e = _unit(np.random.default_rng(5), DIM)
    want = pai.encrypt_vector(sk.pub, e, rng=np.random.default_rng(42))
    got = pvec.encrypt_vector(sk.pub, e, rng=np.random.default_rng(42))
    assert got == want


def test_encrypted_scores_wire_parity():
    """Per-lane seeded blinding: the batched RNS score path must produce
    bit-identical score ciphertexts to per-lane object calls."""
    keys = _keys(3)
    rng = np.random.default_rng(6)
    queries = _unit(rng, 3, DIM)
    cands = [_unit(rng, KPRIME, DIM) for _ in keys]
    enc = [pai.encrypt_vector(k.pub, q, rng=np.random.default_rng(7 + i))
           for i, (k, q) in enumerate(zip(keys, queries))]
    want = [pai.encrypted_scores(k.pub, e, c,
                                 rng=np.random.default_rng(50 + i))
            for i, (k, e, c) in enumerate(zip(keys, enc, cands))]
    got = pvec.encrypted_scores_batch(
        [k.pub for k in keys], enc, cands,
        rngs=[np.random.default_rng(50 + i) for i in range(3)])
    assert got == want


@pytest.mark.parametrize("batch", [1, 3, 8])
def test_decrypt_bit_exact_across_batch_sizes(batch):
    """Vectorized score + vectorized decrypt == object score + object
    decrypt, element-exact, at batch 1 / 3 / 8."""
    keys = _keys(batch)
    rng = np.random.default_rng(batch)
    queries = _unit(rng, batch, DIM)
    cands = [_unit(rng, KPRIME, DIM) for _ in keys]
    enc = [pvec.encrypt_vector(k.pub, q, rng=np.random.default_rng(9))
           for k, q in zip(keys, queries)]
    cts = pvec.encrypted_scores_batch([k.pub for k in keys], enc, cands)
    got = pvec.decrypt_scores_batch(keys, cts)
    for k, e, c, g in zip(keys, enc, cands, got):
        obj = pai.decrypt_scores(k, pai.encrypted_scores(k.pub, e, c))
        assert np.array_equal(g, obj)
        assert g.shape == (KPRIME,)


def test_oversized_key_selects_object_path():
    """A 1024-bit key needs 90 RNS channels — over the MAX_CHANNELS=64
    vectorization budget — so every stage must fall back to the object
    path per lane, counted, while a 256-bit lane in the same batch stays
    vectorized.  Results remain exact either way."""
    from repro.kernels.bignum import ref

    big = pai.keygen(1024, rng=np.random.default_rng(0))
    small = pai.keygen(256, rng=np.random.default_rng(1))
    assert not ref.fits(big.pub.n_sq) and ref.fits(small.pub.n_sq)
    assert not pvec.fits(big.pub) and pvec.fits(small.pub)

    rng = np.random.default_rng(2)
    queries = _unit(rng, 2, DIM)
    cands = [_unit(rng, KPRIME, DIM) for _ in range(2)]

    pvec.reset_counters()
    enc = [pvec.encrypt_vector(k.pub, q, rng=np.random.default_rng(3))
           for k, q in zip((big, small), queries)]
    assert pvec.counters == {"vectorized": 1, "object": 1}

    cts = pvec.encrypted_scores_batch([big.pub, small.pub], enc, cands)
    assert pvec.counters == {"vectorized": 2, "object": 2}

    got = pvec.decrypt_scores_batch([big, small], cts)
    assert pvec.counters == {"vectorized": 3, "object": 3}

    for k, e, c, g in zip((big, small), enc, cands, got):
        obj = pai.decrypt_scores(k, pai.encrypted_scores(k.pub, e, c))
        assert np.array_equal(g, obj)


def test_fallback_wire_parity_under_seeds():
    """The fallback lane consumes its rng exactly as a direct object call
    would: same seeds -> same ciphertext integers on both sides of the
    fits() boundary."""
    big = pai.keygen(1024, rng=np.random.default_rng(0))
    e = _unit(np.random.default_rng(4), DIM)
    assert (pvec.encrypt_vector(big.pub, e, rng=np.random.default_rng(8))
            == pai.encrypt_vector(big.pub, e, rng=np.random.default_rng(8)))
    enc = pai.encrypt_vector(big.pub, e, rng=np.random.default_rng(8))
    cands = [_unit(np.random.default_rng(5), KPRIME, DIM)]
    assert (pvec.encrypted_scores_batch(
                [big.pub], [enc], cands,
                rngs=[np.random.default_rng(11)])[0]
            == pai.encrypted_scores(big.pub, enc, cands[0],
                                    rng=np.random.default_rng(11)))


# -- backend objects drive the protocol symmetrically -----------------------


@pytest.mark.parametrize("backend", ["rlwe", "paillier"])
def test_backend_roundtrip_through_protocol(backend):
    """Both registered backends run the whole sequential protocol through
    the same seam methods — no scheme-specific branches left in the
    driver."""
    import jax

    from repro.data import synth
    from repro.retrieval.index import FlatIndex

    rng = np.random.default_rng(0)
    emb = synth.uniform_corpus(rng, 256, DIM)
    index = FlatIndex.build(
        emb, documents=[f"d{i}".encode() for i in range(256)])
    kw = ({"rlwe_params": rlwe.RlweParams(n_poly=1024, chunk=512)}
          if backend == "rlwe" else {"paillier_bits": 256})
    user = protocol.RemoteRagUser(n=DIM, N=256, k=3, radius=0.05,
                                  backend=backend,
                                  rng=np.random.default_rng(1), **kw)
    assert user.impl is backends.get_backend(backend)
    cloud = protocol.RemoteRagCloud(index, **(
        {"rlwe_params": kw["rlwe_params"]} if backend == "rlwe" else {}))
    q = synth.queries_near_corpus(np.random.default_rng(2), emb, 1)[0]
    docs, ids, tr = protocol.run_remoterag(user, cloud, q,
                                           jax.random.PRNGKey(0))
    assert len(docs) == 3 and ids.shape == (3,)
    assert tr.request_bytes > 0 and tr.reply_bytes > 0
    oracle = np.argsort(-(emb @ q), kind="stable")[:3]
    assert set(ids.tolist()) == set(oracle.tolist())
