"""GraphCast-style encode-process-decode GNN (assigned arch: graphcast).

Message passing is built on `jax.ops.segment_sum` over an explicit edge list
(src, dst) — the JAX-native scatter formulation (no sparse formats).  The
config follows the assignment: 16 processor layers, d_hidden=512, sum
aggregator, 227 output variables.  Four graph shape regimes are supported,
including a real fanout neighbour sampler for minibatch training.

RemoteRAG applicability: none (no query/corpus structure) — see DESIGN.md
§Arch-applicability; the arch runs without the paper's technique.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class GnnConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    d_feat: int = 227          # input feature dim
    n_vars: int = 227          # output variables
    mesh_refinement: int = 6   # metadata (icosahedral level in the paper)
    aggregator: str = "sum"
    dtype: str = "bfloat16"
    remat: bool = True
    scan_unroll: int = 1

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


class GraphBatch(NamedTuple):
    node_feats: jax.Array    # (V, d_feat)
    edge_src: jax.Array      # (E,) int32
    edge_dst: jax.Array      # (E,) int32
    targets: jax.Array       # (V, n_vars)


def _mlp_params(key, dims, dtype, abstract):
    out = []
    ks = jax.random.split(key, len(dims) - 1) if not abstract else \
        [None] * (len(dims) - 1)
    for i in range(len(dims) - 1):
        out.append({
            "w": layers.make_param(ks[i], (dims[i], dims[i + 1]), dtype,
                                   1.0 / math.sqrt(dims[i]), abstract),
            "b": layers.make_zeros((dims[i + 1],), dtype, abstract),
        })
    return out


def _mlp(ps, x):
    for i, p in enumerate(ps):
        x = x @ p["w"] + p["b"]
        if i < len(ps) - 1:
            x = jax.nn.silu(x)
    return x


def init_params(key, cfg: GnnConfig, abstract: bool = False):
    ks = jax.random.split(key, 4) if not abstract else [None] * 4
    d = cfg.d_hidden
    layer = {
        "edge_mlp": _mlp_params(ks[1], (3 * d, d, d), cfg.jdtype, abstract),
        "node_mlp": _mlp_params(ks[2], (2 * d, d, d), cfg.jdtype, abstract),
    }
    if abstract:
        stacked = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
            layer)
    else:
        per = []
        for i in range(cfg.n_layers):
            ki = jax.random.fold_in(ks[1], i)
            per.append({
                "edge_mlp": _mlp_params(jax.random.fold_in(ki, 0),
                                        (3 * d, d, d), cfg.jdtype, False),
                "node_mlp": _mlp_params(jax.random.fold_in(ki, 1),
                                        (2 * d, d, d), cfg.jdtype, False),
            })
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    return {
        "encoder": _mlp_params(ks[0], (cfg.d_feat, d, d), cfg.jdtype, abstract),
        "edge_encoder": _mlp_params(
            jax.random.fold_in(ks[0], 7) if not abstract else None,
            (2 * d, d), cfg.jdtype, abstract),
        "layers": stacked,
        "decoder": _mlp_params(ks[3], (d, d, cfg.n_vars), cfg.jdtype, abstract),
    }


def abstract_params(cfg: GnnConfig):
    return init_params(None, cfg, abstract=True)


def forward(params, cfg: GnnConfig, batch: GraphBatch):
    """Encode-process-decode; returns (V, n_vars) predictions."""
    v = batch.node_feats.shape[0]
    h = _mlp(params["encoder"], batch.node_feats.astype(cfg.jdtype))
    e = _mlp(params["edge_encoder"],
             jnp.concatenate([h[batch.edge_src], h[batch.edge_dst]], -1))

    def step(carry, layer_p):
        h, e = carry
        msg_in = jnp.concatenate([h[batch.edge_src], h[batch.edge_dst], e], -1)

        def apply(lp, h, e, msg_in):
            e_new = e + _mlp(lp["edge_mlp"], msg_in)
            agg = jax.ops.segment_sum(e_new, batch.edge_dst, num_segments=v)
            if cfg.aggregator == "mean":
                deg = jax.ops.segment_sum(
                    jnp.ones_like(batch.edge_dst, cfg.jdtype),
                    batch.edge_dst, num_segments=v)
                agg = agg / jnp.maximum(deg, 1.0)[:, None]
            h_new = h + _mlp(lp["node_mlp"], jnp.concatenate([h, agg], -1))
            return h_new, e_new

        fn = jax.checkpoint(apply) if cfg.remat else apply
        h, e = fn(layer_p, h, e, msg_in)
        return (h, e), None

    (h, _), _ = jax.lax.scan(step, (h, e), params["layers"],
                             unroll=cfg.scan_unroll)
    return _mlp(params["decoder"], h)


def loss_fn(params, cfg: GnnConfig, batch: GraphBatch):
    pred = forward(params, cfg, batch).astype(jnp.float32)
    return jnp.mean(jnp.square(pred - batch.targets.astype(jnp.float32)))


# ---------------------------------------------------------------------------
# neighbour sampler (host-side, for minibatch_lg)
# ---------------------------------------------------------------------------

def build_csr(edge_src: np.ndarray, edge_dst: np.ndarray, n_nodes: int):
    """In-neighbour CSR: for each dst node, its src list."""
    order = np.argsort(edge_dst, kind="stable")
    sorted_src = edge_src[order]
    counts = np.bincount(edge_dst, minlength=n_nodes)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    return offsets, sorted_src


def sample_fanout(rng: np.random.Generator, offsets, nbrs,
                  seed_nodes: np.ndarray, fanouts) -> GraphBatch:
    """GraphSAGE-style layered fanout sampling -> one merged subgraph.

    Returns a GraphBatch over the union of sampled nodes, with local ids and
    zero targets (caller attaches real features/targets by global id).
    """
    frontier = np.unique(seed_nodes)
    nodes = [frontier]
    src_list, dst_list = [], []
    for f in fanouts:
        new = []
        for u in frontier:
            lo, hi = offsets[u], offsets[u + 1]
            if hi == lo:
                continue
            cand = nbrs[lo:hi]
            take = cand if hi - lo <= f else rng.choice(cand, f, replace=False)
            for s in take:
                src_list.append(s)
                dst_list.append(u)
            new.append(take)
        frontier = np.unique(np.concatenate(new)) if new else np.array([], np.int64)
        nodes.append(frontier)
    all_nodes = np.unique(np.concatenate(nodes))
    local = {g: i for i, g in enumerate(all_nodes)}
    src = np.array([local[s] for s in src_list], np.int32)
    dst = np.array([local[d] for d in dst_list], np.int32)
    return all_nodes, src, dst


__all__ = ["GnnConfig", "GraphBatch", "init_params", "abstract_params",
           "forward", "loss_fn", "build_csr", "sample_fanout"]
