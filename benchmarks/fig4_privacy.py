"""Paper Fig. 4: attack performance vs perturbation r and budget eps.

Proxies (no Vec2Text offline; see core/attacks.py): 1-NN decode over an aux
corpus with paraphrase clusters + a ridge bag-of-words decoder.  Two metrics:
  * exact  — P[attacker identifies the literal query document]
  * f1     — token-set F1 of the reconstruction (semantic leakage)
The 1-NN proxy is the noise-optimal attacker, so its decay needs ~sqrt(n)-
scaled radii relative to the paper's Vec2Text curve (documented deviation);
both curves reproduce Fig. 4's shape: full recovery at r=0 decaying
monotonically to chance.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, emit
from repro.core import attacks
from repro.data import synth


def run() -> None:
    rng = np.random.default_rng(0)
    dim = 768 if FULL else 256
    n_docs = 3000 if FULL else 800
    corpus = synth.token_corpus(rng, n_docs, dim, vocab=1024, doc_len=20,
                                paraphrases=15)
    n_q = 50 if FULL else 20
    radii = [0.0, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 4.0]

    nn = attacks.NearestNeighborAttack(aux=corpus)
    exact = attacks.exact_recovery_curve(nn, corpus, range(n_q), radii, rng)
    f1 = attacks.attack_curve(nn, corpus, range(n_q), radii, rng)
    for r, e_, v in zip(radii, exact, f1):
        emit(f"fig4a/nn_attack_r{r}", 0.0, f"exact={e_:.3f};token_f1={v:.3f}")

    lin = attacks.LinearDecoderAttack(aux=corpus, top_m=20)
    curve = attacks.attack_curve(lin, corpus, range(n_q), radii, rng)
    for r, v in zip(radii, curve):
        emit(f"fig4a/linear_attack_r{r}", 0.0, f"token_f1={v:.3f}")

    # Fig 4b: vs eps (r = n/eps expected radius, scaled per the proxy note)
    for mult in (0.25, 1, 3, 10, 50):
        eps = mult * dim
        r = dim / eps
        e_ = attacks.exact_recovery_curve(nn, corpus, range(n_q), [r], rng)[0]
        v = attacks.attack_curve(nn, corpus, range(n_q), [r], rng)[0]
        emit(f"fig4b/nn_attack_eps{mult}n", 0.0,
             f"exact={e_:.3f};token_f1={v:.3f};r={r:.3f}")
