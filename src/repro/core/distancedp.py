"""(n, eps)-DistanceDP mechanism (paper Definition 1 + Section 3.2.1).

Mechanism: given a query embedding ``e in R^n`` and budget ``eps``, output
``e' = e + r * v`` with radial component ``r ~ Gamma(n, 1/eps)`` and direction
``v`` uniform on the unit sphere.  The output density is

    D_{n,eps}(x | e)  proportional to  exp(-eps * ||x - e||)

so for any x, x':  |log p(y|x) - log p(y|x')| = eps * | ||y-x|| - ||y-x'|| |
<= eps * ||x - x'||  (triangle inequality), i.e. (n, eps)-DistanceDP holds.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp


class Perturbation(NamedTuple):
    embedding: jax.Array  # e' = e + r*v, shape (..., n)
    radius: jax.Array     # r, shape (...,)
    direction: jax.Array  # v, unit-norm, shape (..., n)


def sample_radial(key, n: int, eps, shape=()):
    """r ~ Gamma(shape=n, scale=1/eps).  Mean n/eps, concentrates for large n."""
    g = jax.random.gamma(key, a=float(n), shape=shape, dtype=jnp.float32)
    return g / jnp.asarray(eps, jnp.float32)


def sample_direction(key, n: int, shape=()):
    """Uniform direction on S^{n-1} via normalized gaussians."""
    t = jax.random.normal(key, shape + (n,), dtype=jnp.float32)
    return t / jnp.linalg.norm(t, axis=-1, keepdims=True)


def perturb(key, e, eps) -> Perturbation:
    """Apply the (n, eps)-DistanceDP mechanism to embedding(s) ``e``.

    ``e`` has shape (..., n); one independent perturbation per leading index.
    """
    e = jnp.asarray(e, jnp.float32)
    n = e.shape[-1]
    kr, kd = jax.random.split(key)
    r = sample_radial(kr, n, eps, e.shape[:-1])
    v = sample_direction(kd, n, e.shape[:-1])
    return Perturbation(e + r[..., None] * v, r, v)


def log_density_unnormalized(y, x, eps):
    """log D_{n,eps}(y | x) up to the (x-independent) normalizer."""
    y = jnp.asarray(y, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    return -jnp.asarray(eps, jnp.float32) * jnp.linalg.norm(y - x, axis=-1)


def dp_log_ratio(y, x, x_alt, eps):
    """L(K(x), K(x')) evaluated at y: must be <= eps * ||x - x'||."""
    return log_density_unnormalized(y, x, eps) - log_density_unnormalized(y, x_alt, eps)


def radial_quantile_np(n: int, eps: float, q: float) -> float:
    """Host-side Gamma(n, 1/eps) quantile — used by the planner for robust k'."""
    import scipy.special as sps

    return float(sps.gammaincinv(n, q) / eps)


def expected_radius(n: int, eps: float) -> float:
    """E[r] = n / eps (paper: delta_alpha_k ~= r_bar = n/eps)."""
    return n / eps


def eps_for_radius(n: int, r: float) -> float:
    """Budget giving expected perturbation radius r."""
    return n / r


__all__ = [
    "Perturbation",
    "sample_radial",
    "sample_direction",
    "perturb",
    "log_density_unnormalized",
    "dp_log_ratio",
    "radial_quantile_np",
    "expected_radius",
    "eps_for_radius",
]
