"""repro.kernels.bignum: RNS limb-array Montgomery arithmetic, differential
against CPython's arbitrary-precision ``pow``/``*`` at Paillier-relevant
modulus sizes (n^2 for 256- and 512-bit n)."""

import numpy as np
import pytest

import jax

from repro.crypto import paillier as pai
from repro.kernels.bignum import ops, ref

# n^2 moduli exactly as the Paillier backend sees them
KEY_BITS = (256, 512)


@pytest.fixture(scope="module", params=KEY_BITS, ids=lambda b: f"kb{b}")
def ctx(request):
    sk = pai.keygen(request.param, rng=np.random.default_rng(request.param))
    return ref.for_modulus(sk.pub.n_sq)


def _rand_ints(rng, modulus, count):
    return [int(rng.integers(0, 2**62)) * int(rng.integers(0, 2**62))
            % modulus for _ in range(count)]


# -- channel system ---------------------------------------------------------


def test_channel_primes_are_distinct_odd_primes():
    primes = ref._channel_primes(48)
    assert len(set(primes)) == 48
    for p in primes:
        assert p < ref.RADIX and p % 2 == 1
        assert all(p % d for d in range(2, int(p**0.5) + 1))


def test_num_channels_and_fits_boundaries():
    # kb-bit keys score mod n^2 (~2*kb bits): 256/512 fit the vectorized
    # budget, 1024 falls back to the object path
    for kb, should_fit in ((256, True), (512, True), (1024, False)):
        m = (1 << 2 * kb) - 1
        s = ref.num_channels(m)
        assert s == -(-(m.bit_length() + ref.HEADROOM_BITS) // ref.CH_BITS)
        assert ref.fits(m) == should_fit
        # the f64-exactness ceiling is a separate, harder bound
        assert ref.fits(m, budget=ref.HARD_CHANNELS)
    assert not ref.fits((1 << 2950) - 1, budget=ref.HARD_CHANNELS)


def test_incomplete_reduction_invariant():
    # correctness condition for the two approximate base extensions:
    # (s+1)^2 * 2^-(HEADROOM-1) <= 1 up to the channel budget
    for s in (2, 24, 46, ref.MAX_CHANNELS, ref.HARD_CHANNELS):
        assert (s + 1) ** 2 <= 2 ** (ref.HEADROOM_BITS - 1)


# -- reference implementation vs CPython bignums ----------------------------


def test_to_rns_from_rns_round_trip(ctx):
    rng = np.random.default_rng(1)
    vals = _rand_ints(rng, ctx.modulus, 17) + [0, 1, ctx.modulus - 1]
    back = ref.from_rns(ctx, ref.to_rns(ctx, vals))
    assert [v % ctx.modulus for v in back] == [v % ctx.modulus for v in vals]


def test_mont_mul_matches_python_pow(ctx):
    rng = np.random.default_rng(2)
    a = _rand_ints(rng, ctx.modulus, 9)
    b = _rand_ints(rng, ctx.modulus, 9)
    got = ref.from_rns(ctx, ref.mont_mul(ctx, ref.to_rns(ctx, [ref.to_mont(ctx, x) for x in a]),
                                         ref.to_rns(ctx, [ref.to_mont(ctx, y) for y in b])))
    for x, y, g in zip(a, b, got):
        assert ref.from_mont(ctx, g) % ctx.modulus == x * y % ctx.modulus


def test_mont_mul_chain_matches_python(ctx):
    # repeated squarings: the incomplete-reduction domain must not drift
    rng = np.random.default_rng(3)
    x = _rand_ints(rng, ctx.modulus, 1)[0]
    vec = ref.to_rns(ctx, [ref.to_mont(ctx, x)])
    want = x
    for _ in range(40):
        vec = ref.mont_mul(ctx, vec, vec)
        want = want * want % ctx.modulus
    got = ref.from_mont(ctx, ref.from_rns(ctx, vec)[0]) % ctx.modulus
    assert got == want


def test_mont_exp_matches_python_pow(ctx):
    rng = np.random.default_rng(4)
    base = _rand_ints(rng, ctx.modulus, 1)[0]
    for exp in (0, 1, 2, 3, 12345, ctx.modulus >> 7):
        got = ref.from_mont(ctx, ref.from_rns(ctx, ref.mont_exp(
            ctx, ref.to_rns(ctx, [ref.to_mont(ctx, base)]), exp))[0])
        assert got % ctx.modulus == pow(base, exp, ctx.modulus)


def test_modmul_helper(ctx):
    rng = np.random.default_rng(5)
    x, y = _rand_ints(rng, ctx.modulus, 2)
    assert ref.modmul(ctx, x, y) == x * y % ctx.modulus


# -- jitted ops vs the reference --------------------------------------------


def test_ops_mont_mul_matches_ref(ctx):
    rng = np.random.default_rng(6)
    a = _rand_ints(rng, ctx.modulus, 5)
    b = _rand_ints(rng, ctx.modulus, 5)
    am = ref.to_rns(ctx, [ref.to_mont(ctx, x) for x in a])
    bm = ref.to_rns(ctx, [ref.to_mont(ctx, y) for y in b])
    with jax.experimental.enable_x64():
        C = ops.make_consts(ctx.system, [ctx], batch_ndim=2)
        got = np.asarray(ops.mont_mul(am[None], bm[None], C))[0]
    want = ref.from_rns(ctx, ref.mont_mul(ctx, am, bm))
    assert ref.from_rns(ctx, got) == want


def test_ops_windowed_exp_matches_python_pow(ctx):
    rng = np.random.default_rng(7)
    bases = _rand_ints(rng, ctx.modulus, 3)
    exps = [int(rng.integers(1, 2**60)) for _ in bases]
    window = 4
    base = ref.to_rns(ctx, [ref.to_mont(ctx, x) for x in bases])[None]
    digits = ops.to_digits(exps, window)[None]
    with jax.experimental.enable_x64():
        C = ops.make_consts(ctx.system, [ctx], batch_ndim=2)
        table = ops.pow_table(base, C, window)
        got = np.asarray(ops.mont_exp_digits(table, digits, C, window))[0]
    for x, e, g in zip(bases, exps, ref.from_rns(ctx, got)):
        assert ref.from_mont(ctx, g) % ctx.modulus == pow(x, e, ctx.modulus)


@pytest.mark.parametrize("count", [1, 2, 5, 8])
def test_ops_product_reduce_matches_python(ctx, count):
    rng = np.random.default_rng(8 + count)
    xs = _rand_ints(rng, ctx.modulus, count)
    vec = ref.to_rns(ctx, [ref.to_mont(ctx, x) for x in xs])
    with jax.experimental.enable_x64():
        C = ops.make_consts(ctx.system, [ctx], batch_ndim=2)
        # product_reduce folds over axis -2; a [count, width] leaf block
        got = np.asarray(ops.product_reduce(vec[None], C))[0]
    want = 1
    for x in xs:
        want = want * x % ctx.modulus
    # the odd-aware tree performs count-1 mont_muls: one residual M factor
    g = ref.from_rns(ctx, got[None])[0]
    assert ref.from_mont(ctx, g) % ctx.modulus == want


def test_to_digits_round_trip():
    window = 5
    exps = [0, 1, 31, 32, 12345, 2**64 - 1]
    digits = ops.to_digits(exps, window)
    for e, row in zip(exps, digits):
        back = 0
        for d in row:
            back = (back << window) | int(d)
        assert back == e
