#!/usr/bin/env bash
# CI smoke job: tier-1 tests (slow excluded) + docs check + optional perf
# regression gate.
#
#   scripts/smoke.sh                 # pytest -m "not slow" + docs check
#   SMOKE_BENCH=1 scripts/smoke.sh   # ... plus rlwe bench + regression check
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# repo cleanliness: compiled/tooling artifacts must never be tracked
# (they were once, in b8649f6 — .gitignore plus this gate keeps them out)
if git ls-files | grep -E '(^|/)__pycache__/|\.py[cod]$|(^|/)\.pytest_cache/|(^|/)\.hypothesis/'; then
  echo "FAIL: compiled artifacts tracked in git (see lines above)" >&2
  exit 1
fi

python -m pytest -q -m "not slow" "$@"

# docs gate: every intra-repo link in docs/ + README resolves, every
# documented `repro.*` symbol imports
python scripts/check_docs.py

# observability gate: tracing disabled costs ~nothing, a traced run
# writes a loadable Chrome-trace covering every pipeline stage, and
# tracing never changes results
python scripts/check_trace_overhead.py

# overload gate (fast): closed-loop offered-load sweep on a tiny corpus —
# zero lost requests at every point, the 2x point actually sheds
python -m benchmarks.serve_bench --overload-smoke

if [[ "${SMOKE_BENCH:-0}" == "1" ]]; then
  # the regression gate fails on any missing section, so this also
  # covers ivf_routing (routed >= 2x flat, recall@k' == 1.0, nprobe=all
  # bit-identity), ingestion (zero lost / zero bit-drift across a live
  # tail-shard swap), and retry_lane (healthy p99 under faults)
  python -m benchmarks.run --only rlwe
  python -m benchmarks.serve_bench
  python scripts/check_bench_regression.py BENCH_rlwe.json \
    --serve-json BENCH_serve.json
fi
