"""Dense & MoE causal LM with scanned layers (pure JAX).

Layer parameters are stacked along a leading (n_layers,) axis and the forward
pass is a single `lax.scan` — one layer's HLO regardless of depth (compile
time and HLO size stay bounded for the 512-device dry-runs, and remat applies
per scan step).

Entry points:
  init_params(key, cfg)            real weights (smoke tests / training)
  abstract_params(cfg)             ShapeDtypeStructs (dry-run, no allocation)
  forward(params, cfg, tokens)     logits for training
  loss_fn / train-step             in train/trainer.py
  prefill / decode_step            serving with a KV cache
  param_specs(cfg, ...)            PartitionSpec pytree (2D FSDP x TP)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers, moe as moe_lib
from repro.models.layers import AttentionSpec


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    # MoE (None = dense)
    moe_experts: Optional[int] = None
    moe_top_k: int = 8
    moe_d_ff: Optional[int] = None
    # system
    dtype: str = "bfloat16"
    tp: int = 1                 # tensor-parallel degree (padding target)
    vocab_pad_to: int = 512
    remat: bool = True
    kv_chunk: int = 1024
    scan_unroll: int = 1        # n_layers => fully unrolled (dry-run roofline)
    # activation sharding constraints (None = none; set by the launch layer)
    batch_axes: Optional[tuple] = None
    tp_axis: Optional[str] = "model"
    moe_impl: str = "einsum"    # "einsum" | "shard_a2a" (needs mesh)
    mesh: Optional[object] = None

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        return -(-self.vocab // self.vocab_pad_to) * self.vocab_pad_to

    @property
    def attn_spec(self) -> AttentionSpec:
        return AttentionSpec(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, d_head=self.d_head,
            qk_norm=self.qk_norm, qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta, tp_pad_to=self.tp)

    @property
    def moe_spec(self) -> Optional[moe_lib.MoeSpec]:
        if self.moe_experts is None:
            return None
        return moe_lib.MoeSpec(
            d_model=self.d_model, d_ff=self.moe_d_ff or self.d_ff,
            n_experts=self.moe_experts, top_k=self.moe_top_k,
            ep_pad_to=self.tp, batch_axes=self.batch_axes,
            ep_axis=(self.tp_axis if self.batch_axes is not None
                     and self.tp > 1 else None),
            impl=self.moe_impl, mesh=self.mesh)

    def _constrain(self, x, *parts):
        if self.batch_axes is None:
            return x
        from jax.sharding import PartitionSpec as _P
        return jax.lax.with_sharding_constraint(x, _P(*parts))

    @property
    def is_moe(self) -> bool:
        return self.moe_experts is not None

    def param_count(self) -> int:
        """Approximate true (unpadded) parameter count."""
        a = self.d_model * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.is_moe:
            f = 3 * self.d_model * (self.moe_d_ff or self.d_ff) * self.moe_experts
            f += self.d_model * self.moe_experts
        else:
            f = 3 * self.d_model * self.d_ff
        emb = self.vocab * self.d_model * 2
        return self.n_layers * (a + f) + emb

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        a = self.d_model * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2)
        f = 3 * self.d_model * (self.moe_d_ff or self.d_ff) * self.moe_top_k
        emb = self.vocab * self.d_model * 2
        return self.n_layers * (a + f) + emb


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def _layer_params(key, cfg: TransformerConfig, abstract: bool):
    ks = jax.random.split(key, 3) if not abstract else [None] * 3
    p = {
        "attn_norm": layers.make_ones((cfg.d_model,), cfg.jdtype, abstract),
        "mlp_norm": layers.make_ones((cfg.d_model,), cfg.jdtype, abstract),
        "attn": layers.attention_params(ks[0], cfg.attn_spec, cfg.jdtype,
                                        abstract),
    }
    if cfg.is_moe:
        p["moe"] = moe_lib.moe_params(ks[1], cfg.moe_spec, cfg.jdtype, abstract)
    else:
        p["mlp"] = layers.mlp_params(ks[1], cfg.d_model, cfg.d_ff, cfg.jdtype,
                                     abstract)
    return p


def _stack_layers(cfg: TransformerConfig, key, abstract: bool):
    if abstract:
        one = _layer_params(None, cfg, True)
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
            one)
    keys = jax.random.split(key, cfg.n_layers)
    per = [_layer_params(k, cfg, False) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def init_params(key, cfg: TransformerConfig):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    emb_scale = 1.0 / math.sqrt(cfg.d_model)
    return {
        "embed": layers.make_param(k_emb, (cfg.padded_vocab, cfg.d_model),
                                   cfg.jdtype, emb_scale, False),
        "layers": _stack_layers(cfg, k_layers, False),
        "final_norm": layers.make_ones((cfg.d_model,), cfg.jdtype, False),
        "unembed": layers.make_param(k_out, (cfg.d_model, cfg.padded_vocab),
                                     cfg.jdtype, emb_scale, False),
    }


def abstract_params(cfg: TransformerConfig):
    return {
        "embed": jax.ShapeDtypeStruct((cfg.padded_vocab, cfg.d_model),
                                      cfg.jdtype),
        "layers": _stack_layers(cfg, None, True),
        "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), cfg.jdtype),
        "unembed": jax.ShapeDtypeStruct((cfg.d_model, cfg.padded_vocab),
                                        cfg.jdtype),
    }


# ---------------------------------------------------------------------------
# sharding specs (2D: "data" = FSDP dim, "model" = TP dim)
# ---------------------------------------------------------------------------

def decode_param_specs(cfg: TransformerConfig, *, tp_axis="model"):
    """Serving-time weight sharding: every projection sharded on its INPUT
    dim (contraction) over TP.  At decode the activations are (B, 1, .) so
    the per-projection psum is tiny, no head padding is needed (the KV cache
    keeps the true kv-head count) and the cache shards on d_head.
    """
    m = tp_axis
    attn = {"wq": P(None, m, None), "wk": P(None, m, None),
            "wv": P(None, m, None), "wo": P(None, m, None)}
    if cfg.qkv_bias:
        attn.update({"bq": P(None, None), "bk": P(None, None),
                     "bv": P(None, None)})
    if cfg.qk_norm:
        attn.update({"q_norm": P(None, None), "k_norm": P(None, None)})
    layer = {"attn_norm": P(None, None), "mlp_norm": P(None, None),
             "attn": attn}
    if cfg.is_moe:
        # input-dim sharding per expert matrix (the expert dim is NOT padded
        # at tp=1 — granite's 40 experts don't divide the mesh)
        layer["moe"] = {
            "router": P(None, None, None),
            "w_gate": P(None, None, m, None),
            "w_up": P(None, None, m, None),
            "w_down": P(None, None, m, None),
        }
    else:
        layer["mlp"] = {"w_gate": P(None, m, None), "w_up": P(None, m, None),
                        "w_down": P(None, m, None)}
    return {"embed": P(None, m), "layers": layer, "final_norm": P(None),
            "unembed": P(m, None)}


def fsdp_param_specs(cfg: TransformerConfig, axes=("data", "model")):
    """Pure FSDP: every weight sharded over ALL mesh axes on one dim, no
    tensor parallelism (use with tp=1 configs).  For batch >= devices this
    removes the per-layer TP activation all-reduces entirely; the only
    collectives left are the per-layer weight all-gathers and the gradient
    reduce-scatter (EXPERIMENTS.md §Perf, train hillclimb)."""
    fs = axes
    attn = {"wq": P(None, fs, None), "wk": P(None, fs, None),
            "wv": P(None, fs, None), "wo": P(None, fs, None)}
    if cfg.qkv_bias:
        attn.update({"bq": P(None, None), "bk": P(None, None),
                     "bv": P(None, None)})
    if cfg.qk_norm:
        attn.update({"q_norm": P(None, None), "k_norm": P(None, None)})
    layer = {"attn_norm": P(None, None), "mlp_norm": P(None, None),
             "attn": attn}
    if cfg.is_moe:
        layer["moe"] = {
            "router": P(None, fs, None),
            "w_gate": P(None, None, fs, None),
            "w_up": P(None, None, fs, None),
            "w_down": P(None, None, None, fs),
        }
    else:
        layer["mlp"] = {"w_gate": P(None, fs, None),
                        "w_up": P(None, fs, None),
                        "w_down": P(None, None, fs)}
    return {"embed": P(fs, None), "layers": layer, "final_norm": P(None),
            "unembed": P(fs, None)}


def param_specs(cfg: TransformerConfig, *, fsdp_axis="data", tp_axis="model"):
    f, m = fsdp_axis, tp_axis
    attn = {
        "wq": P(None, f, m), "wk": P(None, f, m), "wv": P(None, f, m),
        "wo": P(None, m, f),
    }
    if cfg.qkv_bias:
        attn.update({"bq": P(None, m), "bk": P(None, m), "bv": P(None, m)})
    if cfg.qk_norm:
        attn.update({"q_norm": P(None, None), "k_norm": P(None, None)})
    layer = {
        "attn_norm": P(None, None),
        "mlp_norm": P(None, None),
        "attn": attn,
    }
    if cfg.is_moe:
        layer["moe"] = {
            "router": P(None, None, None),
            "w_gate": P(None, m, f, None),
            "w_up": P(None, m, f, None),
            "w_down": P(None, m, None, f),
        }
    else:
        layer["mlp"] = {
            "w_gate": P(None, f, m), "w_up": P(None, f, m),
            "w_down": P(None, m, f),
        }
    return {
        "embed": P(m, f),
        "layers": layer,
        "final_norm": P(None),
        "unembed": P(f, m),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block(p, x, cfg: TransformerConfig, positions, cache=None, kv_len=None):
    h, new_kv = layers.attention_fwd(
        p["attn"], layers.rms_norm(x, p["attn_norm"]), cfg.attn_spec,
        positions=positions, causal=cache is None, cache=cache,
        kv_chunk=cfg.kv_chunk)
    x = x + h
    aux = jnp.float32(0.0)
    if cfg.is_moe:
        h, aux = moe_lib.moe_fwd(p["moe"], layers.rms_norm(x, p["mlp_norm"]),
                                 cfg.moe_spec)
    else:
        h = layers.mlp_fwd(p["mlp"], layers.rms_norm(x, p["mlp_norm"]))
    return x + h, new_kv, aux


def forward(params, cfg: TransformerConfig, tokens):
    """Training forward: tokens (B, S) -> logits (B, S, padded_vocab)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = cfg._constrain(x, cfg.batch_axes, None, None)
    positions = jnp.arange(s)[None, :]

    def scan_fn(carry, layer_p):
        x, aux = carry
        fn = lambda q, y: _block(q, y, cfg, positions)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x, _, a = fn(layer_p, x)
        x = cfg._constrain(x, cfg.batch_axes, None, None)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.float32(0.0)),
                               params["layers"], unroll=cfg.scan_unroll)
    x = layers.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsm,mv->bsv", x, params["unembed"])
    logits = cfg._constrain(logits, cfg.batch_axes, None, cfg.tp_axis)
    return logits, aux / cfg.n_layers


def pipeline_forward(params, cfg: TransformerConfig, tokens, *, mesh,
                     n_micro: int = 8, axis: str = "pod"):
    """GPipe training forward: layer stack split into mesh.shape[axis]
    stages (stacked layer params sharded P(axis) on dim 0), microbatches
    streamed with ppermute.  The pipeline region is fully manual: the
    per-microbatch batch dim shards over the remaining batch axes (when it
    divides), everything else — including any TP axis — replicates inside
    stages.  Embed/unembed run outside the pipeline (pod-replicated)."""
    from repro.models.pipeline import pipeline_apply

    b, s = tokens.shape
    assert b % n_micro == 0 and cfg.n_layers % mesh.shape[axis] == 0
    x = jnp.take(params["embed"], tokens, axis=0)
    x = cfg._constrain(x, cfg.batch_axes, None, None)
    positions = jnp.arange(s)[None, :]
    d = cfg.d_model
    xm = x.reshape(n_micro, b // n_micro, s, d)

    def stage_fn(layers_local, h):
        def scan_fn(h, lp):
            fn = lambda q, y: _block(q, y, cfg, positions)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            h, _, _ = fn(lp, h)
            return h, None

        h, _ = jax.lax.scan(scan_fn, h, layers_local,
                            unroll=cfg.scan_unroll)
        return h

    # shard the per-microbatch batch dim over the non-pipeline batch axes
    # when it divides evenly; remaining axes (e.g. TP) replicate inside the
    # manual pipeline region.
    rest = tuple(a for a in (cfg.batch_axes or ()) if a != axis)
    mb = b // n_micro
    rest_devices = math.prod(mesh.shape[a] for a in rest) if rest else 1
    mb_spec = rest if rest and mb % rest_devices == 0 else None
    out = pipeline_apply(params["layers"], xm, stage_fn, mesh=mesh,
                         axis=axis, inner_specs=P(None, mb_spec, None, None))
    x = out.reshape(b, s, d)
    x = layers.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsm,mv->bsv", x, params["unembed"])
    logits = cfg._constrain(logits, cfg.batch_axes, None, cfg.tp_axis)
    return logits, jnp.float32(0.0)


def pipeline_loss_fn(params, cfg: TransformerConfig, tokens, targets, *,
                     mesh, n_micro: int = 8, axis: str = "pod"):
    logits, aux = pipeline_forward(params, cfg, tokens, mesh=mesh,
                                   n_micro=n_micro, axis=axis)
    logits = logits.astype(jnp.float32)
    mask = jnp.arange(logits.shape[-1]) < cfg.vocab
    logits = jnp.where(mask[None, None, :], logits, -1e30)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with a preallocated KV cache
# ---------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               abstract: bool = False):
    spec = cfg.attn_spec
    shape = (cfg.n_layers, batch, max_len, spec.padded_kv_heads, spec.d_head)
    if abstract:
        k = jax.ShapeDtypeStruct(shape, cfg.jdtype)
        return {"k": k, "v": k, "len": jax.ShapeDtypeStruct((), jnp.int32)}
    return {"k": jnp.zeros(shape, cfg.jdtype), "v": jnp.zeros(shape, cfg.jdtype),
            "len": jnp.int32(0)}


def cache_specs(cfg: TransformerConfig, *, batch_axes=("data",),
                tp_axis="model"):
    """KV cache sharding: batch over data axes, head_dim over TP (GQA-safe
    for any kv_heads; see DESIGN.md)."""
    kv = P(None, batch_axes, None, None, tp_axis)
    return {"k": kv, "v": kv, "len": P()}


def decode_step(params, cfg: TransformerConfig, tokens, cache):
    """tokens (B, 1) + cache -> (logits (B, vocab), new cache)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = cache["len"] + jnp.arange(s)[None, :]

    def scan_fn(carry, inp):
        x = carry
        layer_p, ck, cv = inp
        x, (nk, nv), _ = _block(layer_p, x, cfg, positions,
                                cache=(ck, cv, cache["len"]))
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(scan_fn, x,
                               (params["layers"], cache["k"], cache["v"]),
                               unroll=cfg.scan_unroll)
    x = layers.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsm,mv->bsv", x[:, -1:, :], params["unembed"])
    logits = cfg._constrain(logits, cfg.batch_axes, None, cfg.tp_axis)
    new_cache = {"k": nk, "v": nv, "len": cache["len"] + s}
    return logits[:, 0, :], new_cache


def prefill(params, cfg: TransformerConfig, tokens, max_len: int):
    """Full-sequence prefill building the cache; returns (logits, cache)."""
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)[None, :]

    def scan_fn(carry, inp):
        x = carry
        layer_p, ck, cv = inp
        x, (nk, nv), _ = _block(layer_p, x, cfg, positions)
        ck = jax.lax.dynamic_update_slice(ck, nk.astype(ck.dtype), (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, nv.astype(cv.dtype), (0, 0, 0, 0))
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(scan_fn, x,
                               (params["layers"], cache["k"], cache["v"]),
                               unroll=cfg.scan_unroll)
    x = layers.rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsm,mv->bsv", x, params["unembed"])
    logits = cfg._constrain(logits, cfg.batch_axes, None, cfg.tp_axis)
    return logits, {"k": nk, "v": nv, "len": jnp.int32(s)}


def loss_fn(params, cfg: TransformerConfig, tokens, targets, *,
            aux_weight: float = 0.01):
    logits, aux = forward(params, cfg, tokens)
    logits = logits.astype(jnp.float32)
    mask = jnp.arange(logits.shape[-1]) < cfg.vocab
    logits = jnp.where(mask[None, None, :], logits, -1e30)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + aux_weight * aux


__all__ = [
    "TransformerConfig", "init_params", "abstract_params", "param_specs",
    "forward", "init_cache", "cache_specs", "decode_step", "prefill",
    "loss_fn",
]
