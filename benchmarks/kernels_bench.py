"""Kernel micro-benchmarks: NTT and fused score+select vs their references.

On this CPU container the Pallas kernels run in interpret mode (correctness
path); the XLA reference path is the meaningful CPU timing.  On TPU the same
entry points dispatch the compiled kernels.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import FULL, emit, timeit
from repro.crypto import modring
from repro.crypto.modring import PrimeCtx
from repro.kernels.ntt import ops as ntt_ops
from repro.kernels.ntt import ref as ntt_ref
from repro.kernels.scoretopk import ops as st_ops


def run() -> None:
    rng = np.random.default_rng(0)

    # NTT throughput (XLA path), batch of polys as in module-2a at k'=160
    for n in (1024, 4096):
        ctx = PrimeCtx.build(modring.find_ntt_primes(2 * n, 1)[0], n)
        batch = 120 if not FULL else 480
        x = jnp.asarray(ntt_ref.random_poly(rng, (batch, n), ctx.q))
        us = timeit(lambda: jax.block_until_ready(
            ntt_ops.ntt_fwd(x, ctx, use_pallas=False)), repeat=5)
        emit(f"kernels/ntt_fwd_b{batch}_n{n}", us,
             f"Mcoeff_per_s={batch * n / us:.1f}")

    # fused score+select vs full-sort oracle
    n_rows = 200_000 if FULL else 50_000
    dim = 768
    e = jnp.asarray(synth_unit(rng, n_rows, dim))
    q = jnp.asarray(synth_unit(rng, 8, dim))
    us_fused = timeit(lambda: jax.block_until_ready(
        st_ops.topk_scores(q, e, 160, use_pallas=False).values), repeat=3)
    emit(f"kernels/scoretopk_fused_N{n_rows}", us_fused, "per-tile select")

    def full_sort():
        s = q @ e.T
        return jax.block_until_ready(jnp.sort(s, axis=-1))

    us_sort = timeit(full_sort, repeat=3)
    emit(f"kernels/score_fullsort_N{n_rows}", us_sort,
         f"fused_speedup={us_sort / us_fused:.2f}x")


def synth_unit(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=-1, keepdims=True)
