"""Public API: exact top-k over a corpus with the fused kernel + certificate."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.scoretopk import ref as _ref
from repro.kernels.scoretopk import scoretopk as _kern


class TopK(NamedTuple):
    values: jax.Array   # (B, k) scores, descending
    indices: jax.Array  # (B, k) int32 global row ids
    exact: jax.Array    # () bool — certificate that the result is exact


def _resolve(use_pallas):
    if use_pallas is None:
        return jax.default_backend() == "tpu"
    return use_pallas


def topk_scores(queries, corpus, k: int, *, tile: int = 2048,
                per_tile_k: int | None = None, use_pallas=None) -> TopK:
    """Exact top-k inner-product search.

    ``per_tile_k`` < k trades selection work for a (checked) exactness
    certificate: the merged result is exact iff no tile contributed all of its
    per-tile candidates.  Default per_tile_k = min(k, tile) which is always
    exact.
    """
    use_pallas = _resolve(use_pallas)
    b = queries.shape[0]
    n_rows = corpus.shape[0]
    k = min(k, n_rows)
    kk = min(per_tile_k or k, k, tile, n_rows)
    if n_rows <= tile or not use_pallas:
        if use_pallas:
            vals, gidx = _kern.score_topk_pallas(
                queries, corpus, kk=min(kk, n_rows), tile=min(tile, n_rows),
                interpret=jax.default_backend() != "tpu")
        else:
            vals, gidx = _ref.tile_topk_ref(queries, corpus, kk, tile)
        mv, mi = _ref.merge_tiles_ref(vals, gidx, k)
        exact = _certificate(gidx, mi, kk) if kk < k else jnp.asarray(True)
        return TopK(mv, mi, exact)
    vals, gidx = _kern.score_topk_pallas(
        queries, corpus, kk=kk, tile=tile,
        interpret=jax.default_backend() != "tpu")
    mv, mi = _ref.merge_tiles_ref(vals, gidx, k)
    exact = _certificate(gidx, mi, kk) if kk < k else jnp.asarray(True)
    return TopK(mv, mi, exact)


def _certificate(tile_idx, merged_idx, kk: int):
    """True iff every tile contributed < kk entries to the merged top-k."""
    num_tiles = tile_idx.shape[0]
    # tile of each merged index = merged_idx // tile-size; recover from the
    # per-tile candidate layout instead: membership count per tile.
    b = merged_idx.shape[0]
    cand = tile_idx.transpose(1, 0, 2).reshape(b, num_tiles, kk)
    member = (cand[:, :, :, None] == merged_idx[:, None, None, :]).any(-1)
    per_tile = member.sum(-1)  # (B, num_tiles)
    return jnp.all(per_tile < kk)


def exact_fallback(queries, corpus, k: int) -> TopK:
    vals, idx = _ref.topk_ref(queries, corpus, k)
    return TopK(vals, idx, jnp.asarray(True))


__all__ = ["TopK", "topk_scores", "exact_fallback"]
