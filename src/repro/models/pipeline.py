"""Pipeline parallelism (GPipe) over a mesh axis via shard_map + ppermute.

The layer stack is split into S contiguous stages, stage s owned by mesh
slice s of the pipeline axis (layer-stacked params sharded P(axis) on dim 0).
Microbatches stream through: at tick t, stage s runs microbatch t-s; between
ticks, activations move one hop with `ppermute` (whose transpose is the
reverse permute, so `jax.grad` differentiates straight through the schedule —
the backward pipeline emerges from autodiff).

This is the cross-pod option for multi-pod training: inter-pod traffic
becomes (mb, S, d) activations once per tick instead of gradient all-reduces
of the full parameter set.  Bubble fraction = (S-1)/(n_micro + S - 1).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_params, x_micro, stage_fn: Callable, *, mesh,
                   axis: str = "pod", inner_specs=P()):
    """Run the pipeline.

    stage_params: pytree, leaves (S*per_stage, ...) sharded P(axis) on dim 0
                  (each stage holds `per_stage` layers).
    x_micro:      (n_micro, mb, seq, d) — microbatched activations (replicated
                  along `axis`; shard other dims via `inner_specs`).
    stage_fn(local_params, x) -> y: applies ONE stage's layers.

    Returns (n_micro, mb, seq, d) outputs (as produced by the last stage,
    valid on every device after the closing gather).
    """
    s_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + s_stages - 1

    def body(params_local, xs):
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        state = jnp.zeros(mb_shape, xs.dtype)          # in-flight activation
        out = jnp.zeros_like(xs)                       # last stage's results

        def tick(t, carry):
            state, out = carry
            # stage 0 ingests microbatch t (if any); others use state
            feed = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            x_in = jnp.where(stage == 0, feed, state)
            y = stage_fn(params_local, x_in)
            # the last stage writes microbatch t-(S-1) to the output buffer
            out_slot = jnp.clip(t - (s_stages - 1), 0, n_micro - 1)
            take = (stage == s_stages - 1) & (t >= s_stages - 1)
            cur = jax.lax.dynamic_index_in_dim(out, out_slot, 0,
                                               keepdims=False)
            out = jax.lax.dynamic_update_index_in_dim(
                out, jnp.where(take, y, cur), out_slot, 0)
            # move activations one hop forward (ring; last->first is ignored)
            state = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s_stages) for i in range(s_stages)])
            return state, out

        _, out = jax.lax.fori_loop(0, ticks, tick, (state, out))
        # broadcast the last stage's buffer to every stage (psum of one-hot)
        mask = (stage == s_stages - 1).astype(out.dtype)
        return jax.lax.psum(out * mask, axis)

    in_leaf_spec = jax.tree.map(lambda _: P(axis), stage_params)
    # Fully manual over every mesh axis: partial-manual (auto=) lowering of
    # this schedule trips XLA's PartitionId/manual-subgroup limitations on the
    # pinned jax version, so non-pipeline axes are handled by `inner_specs`
    # instead (shard the microbatch dim there; unmentioned axes replicate).
    return shard_map(
        body, mesh=mesh,
        in_specs=(in_leaf_spec, inner_specs),
        out_specs=inner_specs, check_rep=False,
    )(stage_params, x_micro)


__all__ = ["pipeline_apply"]
