"""Paper Table 4: end-to-end efficiency at k'=160.

Measures computation (wall) and communication (metered bytes) for:
  privacy-ignorant | privacy-conscious | RemoteRAG direct | RemoteRAG OT
on both crypto backends.  The privacy-conscious scheme is measured at small
N and scaled linearly to N=1e6 (it is exactly linear in N by construction —
the per-candidate PHE distance dominates); the scaling model itself is
validated on two measured sizes (`conscious_linearity_check`).
"""

from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import FULL, emit, timeit
from repro.core import baselines, planner, protocol
from repro.data import synth
from repro.retrieval.index import FlatIndex


def run() -> None:
    rng = np.random.default_rng(0)
    dim = 768
    n_docs = 50_000 if FULL else 5_000
    target_n = 10 ** 6
    kp_target = 160
    emb = synth.uniform_corpus(rng, n_docs, dim)
    docs = [b"p" * 1024 for _ in range(n_docs)]
    index = FlatIndex.build(emb, documents=docs)
    q = synth.queries_near_corpus(rng, emb, 1)[0]

    # privacy-ignorant
    us = timeit(lambda: baselines.privacy_ignorant_service(index, q, 5),
                repeat=3)
    res = baselines.privacy_ignorant_service(index, q, 5)
    emit("table4/ignorant", us, f"bytes={res.wire_bytes}")

    # privacy-conscious measured at two sizes -> linear extrapolation to 1e6
    sizes = (200, 400)
    per_doc = []
    for m in sizes:
        sub = FlatIndex.build(emb[:m], documents=docs[:m])
        t0 = time.perf_counter()
        r = baselines.privacy_conscious_service(sub, q, 5, backend="paillier",
                                                paillier_bits=512, rng=rng)
        dt = time.perf_counter() - t0
        per_doc.append((m, dt, r.wire_bytes))
    slope_t = (per_doc[1][1] - per_doc[0][1]) / (sizes[1] - sizes[0])
    slope_b = (per_doc[1][2] - per_doc[0][2]) / (sizes[1] - sizes[0])
    t_1m = slope_t * target_n
    b_1m = slope_b * target_n
    emit("table4/conscious_paillier_extrap_1m", t_1m * 1e6,
         f"hours={t_1m / 3600:.2f};GB={b_1m / 1e9:.2f};paper=2.72hr/1.43GB")
    lin_err = abs(per_doc[1][1] - 2 * per_doc[0][1]) / per_doc[1][1]
    emit("table4/conscious_linearity_check", 0.0, f"rel_dev={lin_err:.3f}")

    # RemoteRAG at the paper's operating point (k'~160) — both backends,
    # both module-2 paths
    eps = planner.eps_for_kprime(n=dim, N=n_docs, k=5, kprime=kp_target)
    for backend in ("rlwe", "paillier"):
        user = protocol.RemoteRagUser(n=dim, N=n_docs, k=5, eps=eps,
                                      backend=backend, paillier_bits=512,
                                      rng=rng)
        cloud = protocol.RemoteRagCloud(
            index, rlwe_params=getattr(user, "rlwe_params", None))

        def go():
            return protocol.run_remoterag(user, cloud, q,
                                          jax.random.PRNGKey(1))

        us = timeit(go, repeat=3 if backend == "rlwe" else 1)
        _, _, tr = go()
        emit(f"table4/remoterag_{backend}_{tr.path}", us,
             f"seconds={us / 1e6:.3f};KB={tr.total_bytes / 1024:.2f};"
             f"kprime={user.plan.kprime};paper=0.67s/46.66KB")

    # force the OT path (tight budget) for the Direct-vs-OT comparison row
    user = protocol.RemoteRagUser(n=dim, N=n_docs, k=5, eps=dim / 2.0,
                                  backend="rlwe", rng=rng,
                                  plan_kwargs={"radial_quantile": 0.5})
    if user.plan.use_ot and user.plan.kprime < n_docs:
        cloud = protocol.RemoteRagCloud(index, rlwe_params=user.rlwe_params)
        us = timeit(lambda: protocol.run_remoterag(
            user, cloud, q, jax.random.PRNGKey(2)), repeat=1)
        _, _, tr = protocol.run_remoterag(user, cloud, q,
                                          jax.random.PRNGKey(2))
        emit("table4/remoterag_rlwe_ot_forced", us,
             f"seconds={us / 1e6:.3f};KB={tr.total_bytes / 1024:.2f};"
             f"kprime={user.plan.kprime}")
