"""Hypersphere-cap geometry for RemoteRAG (paper Lemma 1, Theorems 1-3).

All embeddings live on the unit sphere S^{n-1} subset R^n.  The paper models the
corpus as N points uniform on the sphere; the *cap fraction* F(alpha) is the
fraction of the sphere's surface within polar angle alpha of a given point:

    F(alpha) = (Omega_{n-1}(pi) / Omega_n(pi)) * int_0^alpha sin^{n-2}(t) dt
             = 1/2 * I_{sin^2 alpha}((n-1)/2, 1/2)          for alpha <= pi/2
             = 1 - 1/2 * I_{sin^2 alpha}((n-1)/2, 1/2)      for alpha >  pi/2

where I is the regularized incomplete beta function.  Lemma 1 is then
``k = N * F(alpha_k)``; Theorem 1 is ``k' = N * F(alpha_k + delta_alpha)``;
Theorem 3 is ``tan(omega) = tan(alpha_k) / sqrt(k)``.

Everything here is pure math: the JAX paths are jittable (used inside the
protocol), the scipy paths are host-side planners (exact inverse beta).
"""

from __future__ import annotations

import numpy as np
import scipy.special as sps

import jax
import jax.numpy as jnp
from jax.scipy import special as jsp


# ---------------------------------------------------------------------------
# Cap fraction (Lemma 1)
# ---------------------------------------------------------------------------

def cap_fraction(alpha, n: int):
    """Fraction of S^{n-1} surface within polar angle ``alpha`` (JAX, jittable)."""
    alpha = jnp.asarray(alpha, jnp.float32)
    a = (n - 1) / 2.0
    b = 0.5
    s2 = jnp.sin(alpha) ** 2
    half = 0.5 * jsp.betainc(a, b, jnp.clip(s2, 0.0, 1.0))
    return jnp.where(alpha <= jnp.pi / 2, half, 1.0 - half)


def cap_fraction_np(alpha, n: int):
    """Host/double-precision cap fraction (numpy+scipy)."""
    alpha = np.asarray(alpha, np.float64)
    s2 = np.clip(np.sin(alpha) ** 2, 0.0, 1.0)
    half = 0.5 * sps.betainc((n - 1) / 2.0, 0.5, s2)
    return np.where(alpha <= np.pi / 2, half, 1.0 - half)


def alpha_from_fraction_np(frac, n: int):
    """Inverse of :func:`cap_fraction_np` — polar angle containing fraction ``frac``."""
    frac = np.asarray(frac, np.float64)
    if np.any((frac < 0) | (frac > 1)):
        raise ValueError("cap fraction must be in [0, 1]")
    lower = np.minimum(frac, 1.0 - frac)  # solve on the <= pi/2 branch
    s2 = sps.betaincinv((n - 1) / 2.0, 0.5, np.clip(2.0 * lower, 0.0, 1.0))
    alpha = np.arcsin(np.sqrt(np.clip(s2, 0.0, 1.0)))
    return np.where(frac <= 0.5, alpha, np.pi - alpha)


def alpha_from_fraction(frac, n: int, *, iters: int = 60):
    """JAX bisection inverse of :func:`cap_fraction` (jittable, f32)."""
    frac = jnp.asarray(frac, jnp.float32)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        too_small = cap_fraction(mid, n) < frac
        return jnp.where(too_small, mid, lo), jnp.where(too_small, hi, mid)

    lo, hi = jax.lax.fori_loop(
        0, iters, body, (jnp.zeros_like(frac), jnp.full_like(frac, jnp.pi))
    )
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------------------
# Theorem 1 — search-range inflation
# ---------------------------------------------------------------------------

def perturbed_angle(r, *, conservative: bool = False):
    """Angle between ``e_k`` and ``e_k + r*v`` for unit ``e_k``.

    The paper approximates ``delta_alpha ~= r`` (small-r chord~angle).  The
    conservative variant uses the worst case over directions v, which is the
    tangent angle ``arcsin(r)`` for r < 1 (and pi for r >= 1).
    """
    r = np.asarray(r, np.float64)
    if conservative:
        return np.where(r < 1.0, np.arcsin(np.clip(r, 0.0, 1.0)), np.pi)
    return r


def kprime_for(
    k: int,
    N: int,
    n: int,
    r: float,
    *,
    conservative: bool = True,
    slack: float = 1.0,
) -> int:
    """Theorem 1: minimum k' so that top-k' of e_{k'} contains top-k of e_k.

    ``r`` is the (expected or quantile) perturbation radius; ``slack``
    multiplies delta_alpha for extra safety margin.
    """
    if k <= 0:
        raise ValueError("k must be positive")
    if k >= N:
        return N
    alpha_k = float(alpha_from_fraction_np(k / N, n))
    d_alpha = float(perturbed_angle(r, conservative=conservative)) * slack
    alpha_kp = min(alpha_k + d_alpha, np.pi)
    kp = int(np.ceil(N * float(cap_fraction_np(alpha_kp, n))))
    return max(min(kp, N), k)


def delta_k(k: int, N: int, n: int, r: float, **kw) -> int:
    """Theorem 1 stated as the increment ``k' - k``."""
    return kprime_for(k, N, n, r, **kw) - k


# ---------------------------------------------------------------------------
# Theorem 3 — mean-embedding leakage angle
# ---------------------------------------------------------------------------

def mean_angle_omega(alpha_k, k):
    """Theorem 3: mean angle between e_k and the mean of its top-k neighbours."""
    return np.arctan(np.tan(np.asarray(alpha_k, np.float64)) / np.sqrt(k))


def leakage_requires_ot(k: int, N: int, n: int, eps: float) -> bool:
    """Algorithm 2 line 7: OT needed iff omega < delta_alpha_mean (= n/eps)."""
    alpha_k = float(alpha_from_fraction_np(k / N, n))
    omega = float(mean_angle_omega(alpha_k, k))
    return omega < (n / eps)


# ---------------------------------------------------------------------------
# Theorem 2 — metric equivalence (used by tests and the scorer)
# ---------------------------------------------------------------------------

def l2_from_cos(d_cos):
    """Theorem 2: d_l2 = sqrt(2 * d_cos) for unit-norm embeddings."""
    return jnp.sqrt(2.0 * jnp.asarray(d_cos))


def cos_distance(a, b):
    """Cosine distance 1 - <a, b> for (batched) unit-norm embeddings."""
    return 1.0 - jnp.sum(jnp.asarray(a) * jnp.asarray(b), axis=-1)


__all__ = [
    "cap_fraction",
    "cap_fraction_np",
    "alpha_from_fraction",
    "alpha_from_fraction_np",
    "perturbed_angle",
    "kprime_for",
    "delta_k",
    "mean_angle_omega",
    "leakage_requires_ot",
    "l2_from_cos",
    "cos_distance",
]
