"""Epoch-versioned corpus core + IVF first-stage routing: the acceptance
harness of the dynamic-corpus refactor.

Load-bearing invariants pinned here:

  * IVF with ``nprobe == num_clusters`` is bit-identical to the flat scan
    (values, ids, tie order) — at the retrieval layer and through the full
    protocol, swept over batch {1,3,8} x {rlwe,paillier} x replicas
    {1,2,4}.
  * A fixed-epoch replay returns pre-ingestion bits even while (or after)
    a writer appends — engines/routers pin their `CorpusView` at
    construction, so ingestion under live traffic never shifts an open
    epoch's results.
  * A mid-ingestion gather never observes a half-swapped tail shard
    (`ShardedCandidateCache.ingest_tail` publishes atomically).
  * Router slice re-plan on epoch advance preserves the (score desc,
    global id asc) merge order — post-replan scatter-gather equals a
    whole-corpus scan of the grown corpus.
"""

import threading

import numpy as np
import pytest

import jax

from repro.crypto import rlwe
from repro.data import synth
from repro.retrieval.index import ClusterMap, FlatIndex, IvfConfig
from repro.retrieval.topk import cluster_topk, distributed_topk, plan_nprobe
from repro.serve import (
    EngineConfig,
    ReplicaRouter,
    RouterConfig,
    ServeEngine,
    SessionManager,
)
from repro.serve.session import PlanCache

N_DOCS, DIM, K = 600, 64, 4
N_NEW = 72          # ingested tail (multiple of nothing in particular)
N_REQ = 6
NUM_CLUSTERS = 6
TENANTS = ("alice", "bob", "carol")
PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)
SEED = 0


def _corpus(rng):
    """Unit corpus with planted duplicate rows: after the IVF build
    permutation the copies land wherever k-means puts them, so identical
    scores surface across cluster (and replica-slice) boundaries and the
    merge tie-break is exercised for real."""
    emb = synth.uniform_corpus(rng, N_DOCS, DIM)
    emb[450] = emb[10]
    emb[300] = emb[10]
    return emb


def _build(rng):
    emb = _corpus(rng)
    docs = [f"passage-{i}".encode() for i in range(N_DOCS)]
    return FlatIndex.build(emb, documents=docs, normalize=False,
                           ivf=IvfConfig(num_clusters=NUM_CLUSTERS,
                                         seed=SEED))


def _tail(rng):
    emb = synth.uniform_corpus(rng, N_NEW, DIM)
    return emb, [f"ingested-{i}".encode() for i in range(N_NEW)]


@pytest.fixture(scope="module")
def queries():
    rng = np.random.default_rng(SEED + 1)
    emb = _corpus(np.random.default_rng(SEED))
    q = synth.queries_near_corpus(rng, emb, N_REQ)
    q[2] = emb[10]          # aim one query straight at the duplicated row
    return q


@pytest.fixture(scope="module")
def static_index():
    """Shared read-only IVF index for the retrieval-layer differentials
    (the protocol tests build fresh indexes — they ingest)."""
    return _build(np.random.default_rng(SEED))


# ---------------------------------------------------------------------------
# retrieval layer
# ---------------------------------------------------------------------------

def test_ivf_build_geometry(static_index):
    cm = static_index.cluster_map
    assert cm is not None
    assert cm.num_clusters == NUM_CLUSTERS
    assert int(cm.sizes.sum()) == N_DOCS
    assert cm.starts[0] == 0 and cm.stops[-1] == N_DOCS
    # clusters tile the row space contiguously (starts == previous stops)
    assert np.array_equal(cm.starts[1:], cm.stops[:-1])
    assert static_index.epoch == 0
    assert static_index.corpus_view().cluster_map is cm


def test_ivf_shard_alignment():
    """IVF clusters built with ``align=shard_docs`` share boundaries with
    candidate-cache shards, so cluster routing doubles as shard
    prediction."""
    shard_docs = 50
    idx = FlatIndex.build(
        _corpus(np.random.default_rng(SEED)), normalize=False,
        ivf=IvfConfig(num_clusters=NUM_CLUSTERS, align=shard_docs))
    cm = idx.cluster_map
    assert all(int(s) % shard_docs == 0 for s in cm.starts)


def test_nprobe_all_is_bit_identical_to_flat_scan(static_index, queries):
    view = static_index.corpus_view()
    flat = distributed_topk(static_index, queries, 2 * K)
    for nprobe in (None, NUM_CLUSTERS, NUM_CLUSTERS + 3):
        routed = cluster_topk(view, queries, 2 * K, nprobe=nprobe)
        assert np.array_equal(np.asarray(routed.indices),
                              np.asarray(flat.indices))
        assert np.array_equal(np.asarray(routed.values),
                              np.asarray(flat.values))
        assert bool(routed.exact)


def test_small_nprobe_recall_at_planned_bound():
    """On a clustered corpus (the workload IVF exists for) the planner-
    derived nprobe recovers the flat scan's top-k exactly, while ``exact``
    honestly reports the skipped rows."""
    rng = np.random.default_rng(SEED)
    centers = rng.normal(size=(NUM_CLUSTERS, DIM))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    emb = np.repeat(centers, N_DOCS // NUM_CLUSTERS, axis=0)
    emb = emb + 0.05 * rng.normal(size=emb.shape)
    emb = (emb / np.linalg.norm(emb, axis=1, keepdims=True)).astype(
        np.float32)
    idx = FlatIndex.build(emb, normalize=False,
                          ivf=IvfConfig(num_clusters=NUM_CLUSTERS,
                                        seed=SEED))
    view = idx.corpus_view()
    q = synth.queries_near_corpus(np.random.default_rng(SEED + 1), emb,
                                  N_REQ).astype(np.float32)
    nprobe = plan_nprobe(view.cluster_map, 2 * K)
    assert 1 <= nprobe < NUM_CLUSTERS
    routed = cluster_topk(view, q, K, nprobe=nprobe)
    assert not bool(routed.exact)
    flat = distributed_topk(idx, q, K)
    assert np.array_equal(np.asarray(routed.indices),
                          np.asarray(flat.indices))    # recall@k == 1.0


def test_plan_nprobe_bounds(static_index):
    cm = static_index.cluster_map
    assert plan_nprobe(cm, 1) >= 1
    assert plan_nprobe(cm, N_DOCS) == NUM_CLUSTERS     # need everything
    assert plan_nprobe(cm, 1, slack=1e9) == NUM_CLUSTERS
    with pytest.raises(ValueError):
        plan_nprobe(cm, 0)


def test_cluster_topk_requires_ivf():
    idx = FlatIndex.build(_corpus(np.random.default_rng(SEED)),
                          normalize=False)
    with pytest.raises(ValueError, match="IVF"):
        cluster_topk(idx.corpus_view(), np.zeros((1, DIM), np.float32), K)


def test_ingest_advances_epoch_and_appends_tail_cluster(queries):
    idx = _build(np.random.default_rng(SEED))
    new_emb, new_docs = _tail(np.random.default_rng(SEED + 2))
    before = distributed_topk(idx, queries, 2 * K)
    v1 = idx.ingest(new_emb, documents=new_docs, normalize=False)
    assert (idx.epoch, v1.epoch) == (1, 1)
    assert v1.num_rows == N_DOCS + N_NEW
    assert v1.cluster_map.num_clusters == NUM_CLUSTERS + 1
    assert int(v1.cluster_map.starts[-1]) == N_DOCS
    assert idx.documents[N_DOCS:] == new_docs
    # epoch-0 view: old geometry, old bits
    v0 = idx.corpus_view(0)
    assert v0.num_rows == N_DOCS
    assert v0.cluster_map.num_clusters == NUM_CLUSTERS
    replay = cluster_topk(v0, queries, 2 * K)
    assert np.array_equal(np.asarray(replay.indices),
                          np.asarray(before.indices))
    # grown corpus: routed == flat over all N_DOCS + N_NEW rows
    after_flat = distributed_topk(idx, queries, 2 * K)
    after_routed = cluster_topk(v1, queries, 2 * K)
    assert np.array_equal(np.asarray(after_routed.indices),
                          np.asarray(after_flat.indices))


def test_fixed_epoch_replay_under_concurrent_ingestion(queries):
    """A pinned epoch-0 view replays identical bits while a writer thread
    appends tail after tail."""
    idx = _build(np.random.default_rng(SEED))
    v0 = idx.corpus_view()
    want = np.asarray(cluster_topk(v0, queries, 2 * K).indices)
    stop = threading.Event()
    errs = []

    def writer():
        rng = np.random.default_rng(SEED + 3)
        try:
            for _ in range(6):
                emb, docs = _tail(rng)
                idx.ingest(emb, documents=docs, normalize=False)
        except Exception as e:          # noqa: BLE001 — surfaced below
            errs.append(e)
        finally:
            stop.set()

    t = threading.Thread(target=writer)
    t.start()
    rounds = 0
    while not stop.is_set() or rounds == 0:
        got = np.asarray(cluster_topk(v0, queries, 2 * K).indices)
        assert np.array_equal(got, want), "fixed-epoch replay drifted"
        rounds += 1
    t.join()
    assert not errs
    assert idx.epoch == 6
    # and the pinned view still replays after all six ingests landed
    got = np.asarray(cluster_topk(idx.corpus_view(0), queries,
                                  2 * K).indices)
    assert np.array_equal(got, want)


def test_ingest_validation():
    idx = _build(np.random.default_rng(SEED))
    with pytest.raises(ValueError):
        idx.ingest(np.zeros((3, DIM + 1), np.float32))      # dim mismatch
    docless = FlatIndex.build(_corpus(np.random.default_rng(SEED)),
                              normalize=False)
    with pytest.raises(ValueError):
        docless.ingest(np.zeros((3, DIM), np.float32),
                       documents=[b"a", b"b", b"c"])


# ---------------------------------------------------------------------------
# sharded candidate cache: atomic tail-shard swap
# ---------------------------------------------------------------------------

def _sharded_cache(emb, shard_docs=64):
    dense = rlwe.build_candidate_cache(PARAMS, emb)
    return rlwe.shard_candidate_cache(
        dense, rlwe.CandidateCacheConfig(shard_docs=shard_docs))


def test_ingest_tail_bits_and_epoch():
    rng = np.random.default_rng(SEED)
    emb = _corpus(rng)
    new_emb, _ = _tail(rng)
    sh = _sharded_cache(emb)
    ids = np.array([[0, 5, 599], [123, 64, 7]])
    before = np.asarray(sh.gather(ids))
    sh.ingest_tail(rlwe._pack_corpus_ntt(PARAMS, new_emb), epoch=1)
    assert (sh.epoch, sh.num_docs) == (1, N_DOCS + N_NEW)
    assert sh.stats()["ingests"] == 1
    # old ids: bit-identical to pre-ingest
    assert np.array_equal(np.asarray(sh.gather(ids)), before)
    # new ids: bit-identical to a cache built from the full corpus
    full = _sharded_cache(np.concatenate([emb, new_emb]))
    tail_ids = np.array([[N_DOCS, N_DOCS + N_NEW - 1, 60]])
    assert np.array_equal(np.asarray(sh.gather(tail_ids)),
                          np.asarray(full.gather(tail_ids)))
    with pytest.raises(ValueError, match="stale"):
        sh.ingest_tail(rlwe._pack_corpus_ntt(PARAMS, new_emb[:2]), epoch=1)
    sh.close()
    full.close()


def test_mid_ingestion_gather_never_half_swapped():
    """Concurrent gathers during ingest_tail see either the old corpus or
    the fully published one — never a half-swapped tail.  The `_ingest_hook`
    seam runs a gather at the worst moment (tail packed, publish pending),
    and a hammering reader thread covers the in-between interleavings."""
    rng = np.random.default_rng(SEED)
    emb = _corpus(rng)
    new_emb, _ = _tail(rng)
    sh = _sharded_cache(emb)
    ids = np.array([[0, 63, 64, 599]])
    want = np.asarray(sh.gather(ids))
    mid = {}

    def hook(cache):
        assert cache.num_docs == N_DOCS      # not yet published
        mid["gather"] = np.asarray(cache.gather(ids))

    sh._ingest_hook = hook
    stop = threading.Event()
    errs = []

    def reader():
        try:
            while not stop.is_set():
                got = np.asarray(sh.gather(ids))
                if not np.array_equal(got, want):
                    errs.append("old-id gather drifted during ingest")
                    return
        except Exception as e:          # noqa: BLE001 — surfaced below
            errs.append(repr(e))

    t = threading.Thread(target=reader)
    t.start()
    sh.ingest_tail(rlwe._pack_corpus_ntt(PARAMS, new_emb), epoch=1)
    stop.set()
    t.join()
    assert not errs
    assert np.array_equal(mid["gather"], want)
    assert np.array_equal(np.asarray(sh.gather(ids)), want)
    assert sh.num_docs == N_DOCS + N_NEW
    sh.close()


# ---------------------------------------------------------------------------
# full protocol: batch x backend x replicas sweep
# ---------------------------------------------------------------------------

def _sessions():
    return SessionManager(rlwe_params=PARAMS, deterministic_seeds=True)


def _open_all(srv, *, backend, N=N_DOCS):
    kw = {"paillier_bits": 256} if backend == "paillier" else {}
    for t in TENANTS:
        srv.open_session(t, n=DIM, N=N, k=K, backend=backend,
                         plan_kwargs={"kprime": 8}, **kw)


def _submit_all(srv, queries):
    return [srv.submit(TENANTS[i % len(TENANTS)], q,
                       key=jax.random.PRNGKey(i))
            for i, q in enumerate(queries)]


def _flat_reference(queries, *, max_batch, backend):
    """Flat-scan single engine over a fresh pre-ingestion index."""
    idx = _build(np.random.default_rng(SEED))
    eng = ServeEngine(
        idx, config=EngineConfig(max_batch=max_batch, max_wait_s=30.0),
        sessions=_sessions())
    _open_all(eng, backend=backend)
    _submit_all(eng, queries)
    out = eng.drain()
    eng.close()
    return out


_REFS = {}      # (max_batch, backend) -> flat pre-ingestion results


def _assert_identical(want, got):
    assert sorted(r.request_id for r in got) == \
        sorted(r.request_id for r in want)
    by_rid = {r.request_id: r for r in want}
    for rb in got:
        rs = by_rid[rb.request_id]
        assert rs.tenant == rb.tenant
        assert rs.ids.tolist() == rb.ids.tolist()
        assert rs.docs == rb.docs
        assert rs.transcript.total_bytes == rb.transcript.total_bytes


@pytest.mark.parametrize("backend", ["rlwe", "paillier"])
@pytest.mark.parametrize("max_batch", [1, 3, 8])
@pytest.mark.parametrize("num_replicas", [1, 2, 4])
def test_differential_sweep(queries, max_batch, backend, num_replicas):
    """The acceptance sweep, two differentials per combo:

    1. IVF serving == flat scan: a router over the IVF-built corpus
       (cluster-aligned replica slices, engines configured nprobe=all)
       returns bit-identical results to the flat single-engine scan.
    2. Fixed-epoch replay == pre-ingestion corpus: the router pinned its
       view at construction, so a tail ingested *before the requests run*
       changes nothing — the grown index serves epoch-0 bits.
    """
    key = (max_batch, backend)
    if key not in _REFS:
        _REFS[key] = _flat_reference(queries, max_batch=max_batch,
                                     backend=backend)
    want = _REFS[key]

    idx = _build(np.random.default_rng(SEED))
    rt = ReplicaRouter(
        idx,
        config=RouterConfig(
            num_replicas=num_replicas,
            engine=EngineConfig(max_batch=max_batch, max_wait_s=30.0,
                                nprobe=NUM_CLUSTERS)),
        sessions=_sessions())
    _open_all(rt, backend=backend)
    # ingest under the router's feet: epoch advances, the pinned view
    # must not
    new_emb, new_docs = _tail(np.random.default_rng(SEED + 2))
    idx.ingest(new_emb, documents=new_docs, normalize=False)
    assert idx.epoch == 1 and rt.view.epoch == 0
    _submit_all(rt, queries)
    got = rt.drain()
    rt.close()
    _assert_identical(want, got)


def test_router_replan_preserves_merge_order(queries):
    """After ingest + replan the scatter-gather merge equals a whole-
    corpus scan of the grown corpus (score desc, global id asc), and the
    full protocol through the replanned router equals a fresh single
    engine at the new epoch."""
    idx = _build(np.random.default_rng(SEED))
    rt = ReplicaRouter(
        idx, config=RouterConfig(
            num_replicas=2,
            engine=EngineConfig(max_batch=3, max_wait_s=30.0)),
        sessions=_sessions())
    new_emb, new_docs = _tail(np.random.default_rng(SEED + 2))
    idx.ingest(new_emb, documents=new_docs, normalize=False)
    spans = rt.replan()
    assert rt.view.epoch == 1
    assert spans[0][0] == 0 and spans[-1][1] == N_DOCS + N_NEW
    # slices land on cluster boundaries (cluster map drives the cuts)
    stops = {int(s) for s in idx.cluster_map.stops} | {0}
    assert all(start in stops for start, _ in spans)
    # scatter merge over the new slices == whole-corpus flat scan
    q32 = np.asarray(queries, np.float32)
    merged = rt._scatter_topk(q32, 2 * K, home=0)
    flat = distributed_topk(idx, q32, 2 * K)
    assert np.array_equal(merged, np.asarray(flat.indices))
    # protocol-level: replanned router == fresh whole-corpus engine
    _open_all(rt, backend="rlwe", N=N_DOCS + N_NEW)
    _submit_all(rt, queries)
    got = rt.drain()
    rt.close()
    eng = ServeEngine(
        idx, config=EngineConfig(max_batch=3, max_wait_s=30.0),
        sessions=_sessions())
    _open_all(eng, backend="rlwe", N=N_DOCS + N_NEW)
    _submit_all(eng, queries)
    want = eng.drain()
    eng.close()
    _assert_identical(want, got)


def test_plan_cache_epoch_stamp():
    pc = PlanCache()
    a = pc.get(n=DIM, N=N_DOCS, k=K, radius=0.05)
    b = pc.get(n=DIM, N=N_DOCS, k=K, radius=0.05)
    assert a is b and (pc.hits, pc.misses) == (1, 1)
    c = pc.get(n=DIM, N=N_DOCS, k=K, radius=0.05, epoch=1)
    assert c is not None and pc.misses == 2     # epoch is part of the key
    assert len(pc) == 2


def test_engine_refresh_corpus_serves_new_rows(queries):
    """refresh_corpus() is the engine-level epoch advance: before it the
    engine scans the pinned rows, after it the ingested rows are
    reachable."""
    idx = _build(np.random.default_rng(SEED))
    eng = ServeEngine(idx, config=EngineConfig(max_batch=3,
                                               max_wait_s=30.0),
                      sessions=_sessions())
    assert eng.view.epoch == 0
    # make the tail irresistible: exact copies of the queries
    tail = np.asarray(queries, np.float32)
    idx.ingest(tail, documents=[f"hot-{i}".encode()
                                for i in range(len(tail))],
               normalize=False)
    pinned = np.asarray(eng._search_topk(np.asarray(queries, np.float32),
                                         2 * K))
    assert pinned.max() < N_DOCS            # new rows invisible pre-refresh
    view = eng.refresh_corpus()
    assert view.epoch == 1 and eng.view.num_rows == N_DOCS + len(tail)
    refreshed = np.asarray(eng._search_topk(
        np.asarray(queries, np.float32), 2 * K))
    for i in range(len(tail)):
        assert N_DOCS + i in refreshed[i]   # each query finds its copy
    eng.close()


def test_cluster_map_appended():
    cm = ClusterMap(centroids=np.eye(2, DIM, dtype=np.float32),
                    starts=np.array([0, 30]), stops=np.array([30, 60]))
    cm2 = cm.appended(np.ones(DIM, np.float32), 60, 75)
    assert cm2.num_clusters == 3
    assert (int(cm2.starts[-1]), int(cm2.stops[-1])) == (60, 75)
    assert cm.num_clusters == 2             # immutable original
