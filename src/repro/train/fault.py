"""Fault tolerance & straggler mitigation for the training driver.

At 1000+ nodes the failure model is: any step may die (preemption, hardware),
some steps run slow (stragglers), and restarts may come back with a different
device count (elastic).  The policies here are host-side and composable with
`trainer.fit`:

  * `ResumableRun` — checkpoint/restart orchestration: restores the newest
    committed checkpoint, replays the data pipeline to the right position,
    and re-shards onto the current mesh (elastic restarts).
  * `FailureInjector` — deterministic fault injection for tests/drills: kills
    the process-equivalent (raises) at chosen steps.
  * `StragglerMonitor` — per-step deadline tracking with an EWMA baseline;
    flags and counts stragglers, and (policy hook) requests micro-batch
    redistribution when a persistent straggler is detected.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

from repro.train import checkpoint as ckpt


class InjectedFailure(RuntimeError):
    """Stands in for a node loss / preemption in drills."""


@dataclasses.dataclass
class FailureInjector:
    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time baseline; a step > threshold x baseline is a straggler."""

    threshold: float = 2.0
    alpha: float = 0.2
    baseline: Optional[float] = None
    straggler_steps: list = dataclasses.field(default_factory=list)
    consecutive: int = 0
    redistribute_after: int = 3
    redistributions: int = 0

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = (self.baseline is not None
                        and dt > self.threshold * self.baseline)
        if self.baseline is None:
            self.baseline = dt
        elif not is_straggler:  # don't poison the baseline with outliers
            self.baseline = (1 - self.alpha) * self.baseline + self.alpha * dt
        if is_straggler:
            self.straggler_steps.append(step)
            self.consecutive += 1
            if self.consecutive >= self.redistribute_after:
                self.redistributions += 1  # policy hook: shrink slow host's
                self.consecutive = 0       # microbatch share / evict host
        else:
            self.consecutive = 0
        return is_straggler


@dataclasses.dataclass
class ResumableRun:
    """Checkpoint/restart orchestration around a step function."""

    ckpt_dir: str
    checkpoint_every: int = 10
    keep: int = 3

    def latest(self) -> Optional[int]:
        return ckpt.latest_step(self.ckpt_dir)

    def run(self, step_fn: Callable, state: Any, batches_fn: Callable,
            n_steps: int, *, injector: Optional[FailureInjector] = None,
            monitor: Optional[StragglerMonitor] = None,
            state_shardings: Any = None) -> tuple:
        """Runs up to n_steps, resuming from the newest checkpoint.

        `batches_fn(step) -> batch` must be random-access (deterministic,
        seekable) so the data pipeline replays exactly after restart.
        Returns (state, completed_steps, metrics_history).
        """
        start = 0
        last = self.latest()
        if last is not None:
            state = ckpt.restore(self.ckpt_dir, last, state,
                                 shardings=state_shardings)
            start = last + 1
        history = []
        for step in range(start, n_steps):
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.monotonic()
            state, metrics = step_fn(state, batches_fn(step))
            dt = time.monotonic() - t0
            if monitor is not None:
                metrics = dict(metrics)
                metrics["straggler"] = monitor.observe(step, dt)
            history.append(metrics)
            if (step + 1) % self.checkpoint_every == 0 or step == n_steps - 1:
                ckpt.save(self.ckpt_dir, step, state, keep=self.keep)
        return state, n_steps - start, history


__all__ = ["InjectedFailure", "FailureInjector", "StragglerMonitor",
           "ResumableRun"]
