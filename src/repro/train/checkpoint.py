"""Sharded, fault-tolerant checkpointing (no orbax — npz + msgpack).

Layout:  <dir>/step_<N>/
            meta.msgpack        tree structure, shapes, dtypes, step
            shard_<i>.npz       flat arrays owned by host shard i
            COMMIT              written last — a checkpoint without COMMIT is
                                incomplete and ignored by `latest_step`

Elastic restore: arrays are saved whole (gathered per leaf); on restore they
are re-laid out with whatever sharding the *new* mesh requests, so a job can
restart on a different device count (elastic re-shard).  For multi-host
deployments each host saves only the leaves it owns; in this single-process
container host-sharding degenerates to one shard, which keeps the format
identical.
"""

from __future__ import annotations

import os
import shutil
from pathlib import Path
from typing import Any, Optional

import msgpack
import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory, step: int, tree: Any, *, keep: int = 3) -> Path:
    """Atomically save a pytree checkpoint for `step`."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    arrays = {}
    meta_leaves = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"a{i}"] = arr
        meta_leaves.append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
    np.savez(tmp / "shard_0.npz", **arrays)
    meta = {"step": step, "n_leaves": len(leaves),
            "treedef": str(treedef), "leaves": meta_leaves}
    (tmp / "meta.msgpack").write_bytes(msgpack.packb(meta))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: Path, keep: int) -> None:
    steps = sorted(p for p in directory.glob("step_*") if (p / "COMMIT").exists())
    for p in steps[:-keep]:
        shutil.rmtree(p)


def latest_step(directory) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if (p / "COMMIT").exists()]
    return max(steps) if steps else None


def restore(directory, step: int, example_tree: Any, *,
            shardings: Any = None) -> Any:
    """Restore into the structure of `example_tree` (values are replaced).

    `shardings`: optional pytree of NamedSharding for elastic re-sharding onto
    the current mesh — pass the same specs the train step uses and the arrays
    are placed accordingly, regardless of the mesh shape at save time.
    """
    directory = Path(directory) / f"step_{step:08d}"
    if not (directory / "COMMIT").exists():
        raise FileNotFoundError(f"no committed checkpoint at {directory}")
    meta = msgpack.unpackb((directory / "meta.msgpack").read_bytes())
    data = np.load(directory / "shard_0.npz")
    leaves = [data[f"a{i}"] for i in range(meta["n_leaves"])]
    _, treedef = _flatten(example_tree)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s) if s is not None else
            jax.device_put(a), tree, shardings)
    else:
        example_leaves = jax.tree.leaves(example_tree)
        tree = jax.tree.unflatten(
            treedef,
            [jax.device_put(np.asarray(a, dtype=e.dtype))
             for a, e in zip(leaves, example_leaves)])
    return tree


__all__ = ["save", "restore", "latest_step"]
