"""Public jit'd API over the NTT kernel with an XLA fallback.

``use_pallas`` selects the Pallas kernel (interpret-mode on CPU, compiled on
TPU); the fallback is the pure-jnp reference, which XLA fuses reasonably but
round-trips HBM between stages on real hardware.
"""

from __future__ import annotations

import functools

import jax

from repro.crypto import modring
from repro.crypto.modring import PrimeCtx
from repro.kernels.ntt import fused as _fused
from repro.kernels.ntt import ntt as _kern
from repro.kernels.ntt import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# PrimeCtx instances are interned per (q, n) and hash by identity, so they
# are valid static args: jitting here collapses the ~log2(N) stages of eager
# jnp dispatch in the reference path into one compiled call per shape —
# the serving hot loop on CPU is NTT-bound.
@functools.partial(jax.jit, static_argnames=("ctx",))
def _ntt_fwd_ref(x, ctx: PrimeCtx):
    return _ref.ntt_fwd_ref(x, ctx)


@functools.partial(jax.jit, static_argnames=("ctx",))
def _ntt_inv_ref(x, ctx: PrimeCtx):
    return _ref.ntt_inv_ref(x, ctx)


@functools.partial(jax.jit, static_argnames=("ctx",))
def _pointwise_mul_ref(a, b, ctx: PrimeCtx):
    return modring.mod_mul(a, b, ctx.q, ctx.mu)


@functools.partial(jax.jit, static_argnames=("ctx",))
def _fused_rotate_hadamard_ref(polys, tw, f0, f1, ctx: PrimeCtx):
    bsz, num_ct, rows, n = polys.shape
    cpt, chunks = tw.shape[0], f0.shape[1]
    g = polys.reshape(bsz, num_ct, cpt, chunks, n)
    rot = modring.mod_mul(g, tw[None, None, :, None, :], ctx.q, ctx.mu)
    p0 = modring.mod_mul(rot, f0[:, None, None], ctx.q, ctx.mu)
    p1 = modring.mod_mul(rot, f1[:, None, None], ctx.q, ctx.mu)
    return (modring.mod_sum(p0.reshape(bsz, num_ct, rows, n),
                            ctx.q, ctx.mu, axis=2),
            modring.mod_sum(p1.reshape(bsz, num_ct, rows, n),
                            ctx.q, ctx.mu, axis=2))


@functools.partial(jax.jit, static_argnames=("ctx",))
def _fused_rotate_hadamard_intt_ref(polys, tw, f0, f1, ctx: PrimeCtx):
    acc0, acc1 = _fused_rotate_hadamard_ref(polys, tw, f0, f1, ctx)
    return _ref.ntt_inv_ref(acc0, ctx), _ref.ntt_inv_ref(acc1, ctx)


def _resolve(use_pallas):
    """None -> auto: Pallas on TPU, XLA reference path elsewhere (tests pass
    use_pallas=True explicitly to exercise the kernel in interpret mode)."""
    if use_pallas is None:
        return jax.default_backend() == "tpu"
    return use_pallas


def ntt_fwd(x, ctx: PrimeCtx, *, use_pallas=None):
    """Forward negacyclic NTT, (..., N) int32 in [0, q) -> bit-rev NTT domain."""
    use_pallas = _resolve(use_pallas)
    if not use_pallas:
        return _ntt_fwd_ref(x, ctx)
    lead = x.shape[:-1]
    flat = x.reshape((-1, ctx.n))
    out = _kern.ntt_pallas(flat, ctx, inverse=False, interpret=_interpret())
    return out.reshape(lead + (ctx.n,))


def ntt_inv(x, ctx: PrimeCtx, *, use_pallas=None):
    """Inverse negacyclic NTT, bit-rev NTT domain -> coefficient domain."""
    use_pallas = _resolve(use_pallas)
    if not use_pallas:
        return _ntt_inv_ref(x, ctx)
    lead = x.shape[:-1]
    flat = x.reshape((-1, ctx.n))
    out = _kern.ntt_pallas(flat, ctx, inverse=True, interpret=_interpret())
    return out.reshape(lead + (ctx.n,))


def pointwise_mul(a, b, ctx: PrimeCtx, *, use_pallas=None):
    """Hadamard modular product in the NTT domain."""
    use_pallas = _resolve(use_pallas)
    if not use_pallas:
        return _pointwise_mul_ref(a, b, ctx)
    lead = a.shape[:-1]
    fa = a.reshape((-1, ctx.n))
    fb = b.reshape((-1, ctx.n))
    out = _kern.pointwise_mul_pallas(fa, fb, ctx, interpret=_interpret())
    return out.reshape(lead + (ctx.n,))


def fused_rotate_hadamard(polys, tw, f0, f1, ctx: PrimeCtx, *,
                          use_pallas=None):
    """Cached re-rank core for one prime: slot twiddle rotate -> Hadamard
    against both query components -> slot/chunk mod-sum.

    polys: (B, num_ct, cpt*chunks, N) slot-major gathered cache rows;
    tw: (cpt, N) NTT-domain monomial diagonals; f0/f1: (B, chunks, N) query
    NTTs.  Returns (acc0, acc1), each (B, num_ct, N).  The Pallas path runs
    the whole thing as one kernel (grid batch x result-ct); the fallback is
    a single jitted XLA composition — both bit-identical to the cold
    pack-then-NTT pipeline.
    """
    use_pallas = _resolve(use_pallas)
    if not use_pallas:
        return _fused_rotate_hadamard_ref(polys, tw, f0, f1, ctx)
    return _fused.fused_rerank_pallas(polys, tw, f0, f1, ctx,
                                      interpret=_interpret())


def fused_rotate_hadamard_intt(polys, tw, f0, f1, ctx: PrimeCtx, *,
                               use_pallas=None):
    """`fused_rotate_hadamard` with the per-prime inverse NTT absorbed: the
    returned (acc0, acc1) are coefficient-domain result-ciphertext
    components, (B, num_ct, N) each.

    On the Pallas path the inverse butterfly network runs inside the same
    kernel while the accumulator tile is still VMEM-resident (no HBM
    round-trip between accumulate and iNTT — the batch-8 Hadamard/iNTT
    bottleneck); the fallback composes the jitted XLA reference fused op
    with the reference inverse NTT.  Both paths run the exact same integer
    ops as the staged rotate/Hadamard + `ntt_inv` pipeline, so all three
    are bit-identical.
    """
    use_pallas = _resolve(use_pallas)
    if not use_pallas:
        return _fused_rotate_hadamard_intt_ref(polys, tw, f0, f1, ctx)
    return _fused.fused_rerank_intt_pallas(polys, tw, f0, f1, ctx,
                                           interpret=_interpret())


def negacyclic_mul(a, b, ctx: PrimeCtx, *, use_pallas=None):
    """a * b in Z_q[X]/(X^N + 1)."""
    use_pallas = _resolve(use_pallas)
    fa = ntt_fwd(a, ctx, use_pallas=use_pallas)
    fb = ntt_fwd(b, ctx, use_pallas=use_pallas)
    return ntt_inv(pointwise_mul(fa, fb, ctx, use_pallas=use_pallas), ctx,
                   use_pallas=use_pallas)


__all__ = ["ntt_fwd", "ntt_inv", "pointwise_mul", "fused_rotate_hadamard",
           "fused_rotate_hadamard_intt", "negacyclic_mul"]
