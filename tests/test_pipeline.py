"""Pipeline parallelism == sequential execution (values AND gradients)."""

import subprocess
import sys

SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.models.pipeline import pipeline_apply
from jax.sharding import PartitionSpec as P

mesh = jax.make_mesh((2,), ("pod",))
L, D = 4, 16           # 4 layers -> 2 stages x 2 layers
n_micro, mb, S = 3, 2, 8

rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(size=(L, D, D)).astype(np.float32) / np.sqrt(D))
x = jnp.asarray(rng.normal(size=(n_micro, mb, S, D)).astype(np.float32))

def stage_fn(w_local, h):     # w_local: (2, D, D) — this stage's layers
    for i in range(w_local.shape[0]):
        h = jnp.tanh(h @ w_local[i])
    return h

def pipe(Ws, x):
    return pipeline_apply(Ws, x, stage_fn, mesh=mesh, axis="pod",
                          inner_specs=P(None, None, None, None))

def seq(Ws, x):
    h = x
    for i in range(L):
        h = jnp.tanh(h @ Ws[i])
    return h

with mesh:
    got = jax.jit(pipe)(Ws, x)
want = seq(Ws, x)
assert np.allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6), \
    np.abs(np.asarray(got) - np.asarray(want)).max()

# gradients flow through the ppermute schedule
def loss_p(Ws, x): return jnp.sum(pipe(Ws, x) ** 2)
def loss_s(Ws, x): return jnp.sum(seq(Ws, x) ** 2)
with mesh:
    gp = jax.jit(jax.grad(loss_p))(Ws, x)
gs = jax.grad(loss_s)(Ws, x)
assert np.allclose(np.asarray(gp), np.asarray(gs), rtol=1e-4, atol=1e-5), \
    np.abs(np.asarray(gp) - np.asarray(gs)).max()
print("PIPELINE_OK")
"""


def test_pipeline_matches_sequential():
    r = subprocess.run([sys.executable, "-c", SNIPPET],
                       capture_output=True, text=True, timeout=600,
                       cwd=__file__.rsplit("/tests/", 1)[0])
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr[-3000:]


TRANSFORMER_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np, jax, jax.numpy as jnp
from repro.models import transformer as tf

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = tf.TransformerConfig(name="t", n_layers=4, d_model=64, n_heads=4,
                           n_kv_heads=2, d_ff=128, vocab=512, d_head=16,
                           dtype="float32", remat=False, kv_chunk=32,
                           batch_axes=("data",))
params = tf.init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
with mesh:
    l_pipe = float(jax.jit(lambda p, t, y: tf.pipeline_loss_fn(
        p, cfg, t, y, mesh=mesh, n_micro=4))(params, tokens, tokens))
    l_seq = float(jax.jit(lambda p, t, y: tf.loss_fn(p, cfg, t, y))(
        params, tokens, tokens))
assert abs(l_pipe - l_seq) < 1e-4, (l_pipe, l_seq)
with mesh:
    g = jax.jit(jax.grad(lambda p: tf.pipeline_loss_fn(
        p, cfg, tokens, tokens, mesh=mesh, n_micro=4)))(params)
gn = float(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(g)))
assert np.isfinite(gn) and gn > 0
print("PP_TRANSFORMER_OK")
"""


def test_transformer_pipeline_loss_matches():
    """Full-transformer pipeline_loss_fn == loss_fn on a (pod,data,model)
    mesh, with finite grads through the ppermute schedule."""
    r = subprocess.run([sys.executable, "-c", TRANSFORMER_SNIPPET],
                       capture_output=True, text=True, timeout=600,
                       cwd=__file__.rsplit("/tests/", 1)[0])
    assert "PP_TRANSFORMER_OK" in r.stdout, r.stdout + r.stderr[-3000:]
