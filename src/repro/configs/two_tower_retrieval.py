"""two-tower-retrieval [recsys]: embed_dim=256 tower_mlp=1024-512-256
interaction=dot — sampled-softmax retrieval [RecSys'19 YouTube].
This is the RemoteRAG-native arch: its candidate index plugs directly into
the private retrieval protocol."""
from repro.models.recsys import TwoTowerConfig

CONFIG = TwoTowerConfig(name="two-tower-retrieval", embed_dim=256,
                        tower_mlp=(1024, 512, 256), user_vocab=1_000_000,
                        item_vocab=1_000_000, n_user_feats=8, n_item_feats=4)

REDUCED = TwoTowerConfig(name="two-tower-smoke", embed_dim=16,
                         tower_mlp=(32, 16), user_vocab=500, item_vocab=500,
                         n_user_feats=3, n_item_feats=2)
