"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only table4]``
prints ``name,us_per_call,derived`` CSV.  REPRO_BENCH_FULL=1 for paper-scale.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark module names")
    args = ap.parse_args()

    from benchmarks import (fig4_privacy, fig5_modules, fig6_hyper,
                            kernels_bench, rlwe_bench, table2_comm,
                            table3_recall, table4_efficiency)

    modules = [table2_comm, table3_recall, table4_efficiency, fig4_privacy,
               fig5_modules, fig6_hyper, kernels_bench, rlwe_bench]
    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        name = mod.__name__.split(".")[-1]
        if args.only and args.only not in name:
            continue
        t0 = time.monotonic()
        try:
            mod.run()
            print(f"# {name} done in {time.monotonic() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures += 1
            print(f"{name}/ERROR,0,{traceback.format_exc(limit=1)!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
