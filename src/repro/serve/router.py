"""Scale-out serving: a replica router with scatter-gather top-k'.

`ReplicaRouter` fronts N `ServeEngine` replicas.  Each replica owns a
contiguous corpus slice (`FlatIndex.slice_view` over `plan_row_slices`,
aligned to the sharded candidate cache's shard size so slices and cache
shards share boundaries), its own admission controller, its own metrics
and its own replica-tagged tracer.  Tenants hash to a home replica
(`session.tenant_seed`, linear probing past quarantined replicas), so
submit load — admission checks, queueing, and the per-tenant crypto of
dispatch — spreads across the fleet while each tenant's rng stream still
advances in its own submit order (sessions are shared, so bit-identity
with a single engine is preserved).

Retrieval is scatter-gather: when a home replica's batch reaches its
top-k' stage, the perturbed embedding block fans out to *every* replica's
scan worker, each scanning only its slice (`topk.slice_topk`, global
ids), and the per-replica candidates are merged with a deterministic
tie-break — score descending, then global doc id ascending — which is
exactly `jax.lax.top_k`'s tie order over the full corpus.  The merged
candidate list is therefore bit-identical to a single engine's, whatever
the replica count or thread arrival order, and everything downstream
(encrypted re-rank, fetch/OT) is untouched.  The differential harness in
``tests/test_router.py`` pins this end to end.

Failure semantics (router tier, on top of the engine's lane-level
isolation): a replica whose step/scan raises or stalls past its timeout
is *quarantined* — taken out of scatter fan-out, submit homing, and
stepping.  Its in-flight requests are resolved from the router's
outstanding ledger as typed error results (``replica_quarantined(...)``,
``quarantined=True``) — never silently dropped — and late results from a
zombie replica thread are discarded and counted, so every request id
resolves exactly once.  Slice *data* is host-shared in this single-host
reproduction, so a quarantined replica's slice keeps being scanned by a
fallback on the caller's thread: healthy replicas' results stay
bit-identical even while a peer is down.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro import obs
from repro.retrieval.index import FlatIndex, IndexSlice, plan_row_slices
from repro.retrieval.topk import slice_topk
from repro.serve import admission as adm
from repro.serve.engine import EngineConfig, ServeEngine, ServeResult
from repro.serve.session import Session, SessionManager, tenant_seed


class ReplicaUnavailable(adm.AdmissionError):
    """Every replica is quarantined: nothing can home this submit.  Typed
    into the `admission.AdmissionError` hierarchy so clients handle it
    like any other admission rejection — the request was never enqueued
    and no request id was consumed anywhere."""

    def __init__(self, num_replicas: int):
        super().__init__(
            f"all {num_replicas} replicas are quarantined; "
            f"no replica can accept submissions")
        self.num_replicas = num_replicas


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    num_replicas: int = 2
    # per-replica engine config (each replica gets its own admission
    # controller from this — the per-replica admitter seam)
    engine: EngineConfig = dataclasses.field(default_factory=EngineConfig)
    # a slice scan that raises — or exceeds this wall — quarantines its
    # replica; the slice is then served by the caller-thread fallback so
    # the in-flight batch still completes bit-identically.  None = wait
    # indefinitely (faults still quarantine, stalls never time out).
    scan_timeout_s: Optional[float] = None
    # a replica engine step()/drain() that raises — or exceeds this wall —
    # quarantines the replica; its in-flight requests resolve as typed
    # error results from the outstanding ledger.  None = no stall bound.
    step_timeout_s: Optional[float] = None

    def __post_init__(self):
        if self.num_replicas < 1:
            raise ValueError(
                f"num_replicas must be >= 1, got {self.num_replicas}")


class RouterMetrics:
    """Router-tier counters (thread-safe; replica workers record
    concurrently).  Everything is an exact integer — the router's
    zero-lost contract is audited as ``submitted == completed +
    quarantine_resolved`` per replica fleet-wide."""

    def __init__(self, num_replicas: int):
        self._lock = threading.Lock()
        self.num_replicas = num_replicas
        self.submitted = [0] * num_replicas     # accepted submits per home
        self.completed = [0] * num_replicas     # results returned per home
        self.rejected = [0] * num_replicas      # typed submit rejections
        self.rehomed = 0            # submits probed past a quarantined home
        self.scatter_calls = 0      # scatter-gather top-k' invocations
        self.slice_scans = 0        # per-replica slice scans completed
        self.fallback_scans = 0     # slices served by the caller fallback
        self.merged_candidates = 0  # candidate rows fed through the merge
        self.merge_wall_s = 0.0     # host time inside merge_topk
        self.quarantines: List[Tuple[int, str]] = []   # (replica, reason)
        self.quarantine_resolved = 0  # in-flight resolved as typed errors
        self.late_dropped = 0       # zombie-replica results discarded

    def record_submit(self, replica: int, *, rehomed: bool) -> None:
        with self._lock:
            self.submitted[replica] += 1
            if rehomed:
                self.rehomed += 1

    def record_rejected(self, replica: int) -> None:
        with self._lock:
            self.rejected[replica] += 1

    def record_completed(self, replica: int, n: int) -> None:
        with self._lock:
            self.completed[replica] += n

    def record_scatter(self, *, scans: int, fallbacks: int,
                       merged: int, merge_wall_s: float) -> None:
        with self._lock:
            self.scatter_calls += 1
            self.slice_scans += scans
            self.fallback_scans += fallbacks
            self.merged_candidates += merged
            self.merge_wall_s += merge_wall_s

    def record_quarantine(self, replica: int, reason: str,
                          resolved: int) -> None:
        with self._lock:
            self.quarantines.append((replica, reason))
            self.quarantine_resolved += resolved

    def record_late_dropped(self, n: int = 1) -> None:
        with self._lock:
            self.late_dropped += n

    def summary(self) -> dict:
        with self._lock:
            return {
                "num_replicas": self.num_replicas,
                "submitted": list(self.submitted),
                "completed": list(self.completed),
                "rejected": list(self.rejected),
                "rehomed": self.rehomed,
                "scatter_calls": self.scatter_calls,
                "slice_scans": self.slice_scans,
                "fallback_scans": self.fallback_scans,
                "merged_candidates": self.merged_candidates,
                "merge_wall_s": round(self.merge_wall_s, 6),
                "quarantines": [list(q) for q in self.quarantines],
                "quarantine_resolved": self.quarantine_resolved,
                "late_dropped": self.late_dropped,
            }


def merge_topk(values: Sequence[np.ndarray], ids: Sequence[np.ndarray],
               kprime: int) -> np.ndarray:
    """Merge per-replica top-k' candidates into the global (B, k') id
    block.

    Total order: score descending, then global doc id ascending — the
    tie-break `jax.lax.top_k` (stable, lower-index-first) produces over
    the full corpus, because global ids are assigned in row order and the
    full-index scan flattens tiles in row order too.  Duplicate scores
    across replicas therefore resolve exactly as a single engine would
    resolve them, and the result is independent of both the replica count
    and the order scan results arrived in (`np.lexsort` is a stable sort
    over deterministic inputs)."""
    vals = np.concatenate([np.asarray(v, np.float32) for v in values],
                          axis=1)
    gids = np.concatenate([np.asarray(i) for i in ids], axis=1)
    k = min(kprime, gids.shape[1])
    out = np.empty((gids.shape[0], k), gids.dtype)
    for lane in range(gids.shape[0]):
        order = np.lexsort((gids[lane], -vals[lane]))[:k]
        out[lane] = gids[lane][order]
    return out


class _ScatterSearcher:
    """The ``searcher`` injected into a replica's engine: binds the home
    replica id so scatter results/events are attributed to the home's
    tracer track.  Pure in (perturbed, kprime) — `_bisect_lanes` re-runs
    lane subsets through it during fault attribution."""

    __slots__ = ("router", "home")

    def __init__(self, router: "ReplicaRouter", home: int):
        self.router = router
        self.home = home

    def __call__(self, perturbed: np.ndarray, kprime: int) -> np.ndarray:
        return self.router._scatter_topk(perturbed, kprime, home=self.home)


@dataclasses.dataclass
class _Replica:
    """One replica: an engine (compute + admission + queues), its slice,
    and two single-thread workers — `step_pool` runs the engine's
    dispatch, `scan_pool` answers scatter requests from *other* replicas'
    dispatches (separate pools, or two replicas could deadlock waiting on
    each other's busy step worker)."""
    replica_id: int
    engine: ServeEngine
    sl: IndexSlice
    step_pool: ThreadPoolExecutor
    scan_pool: ThreadPoolExecutor
    # request id -> (tenant, t_submit): the router's zero-lost ledger
    outstanding: Dict[int, tuple] = dataclasses.field(default_factory=dict)
    quarantined: bool = False
    quarantine_reason: str = ""


class ReplicaRouter:
    """Front-end over N slice-owning `ServeEngine` replicas (see module
    docstring for the placement, scatter-gather and failure contracts).

    Bit-identity: results are identical — docs, ids, transcript bytes,
    request ids — to one `ServeEngine` over the whole corpus fed the same
    submissions in the same order, for any ``num_replicas``.  The
    replicas share the index (and its memoized candidate caches), the
    session manager, and one request-id counter; only the top-k' scan is
    sharded, and the merge reproduces the full scan's order exactly.

    A lane that gets quarantined *inside* an engine retries solo via the
    sequential path, but the engine threads its own searcher into that
    retry (`run_remoterag(..., topk_fn=...)`), so the retried top-k' goes
    through the same per-slice scan + merge as the scatter-gather path —
    slice-routed *and* bit-identical by construction.
    """

    def __init__(self, index: FlatIndex, *,
                 config: Optional[RouterConfig] = None,
                 sessions: Optional[SessionManager] = None,
                 clock=time.monotonic):
        self.config = config or RouterConfig()
        self.index = index
        self.sessions = SessionManager() if sessions is None else sessions
        self.metrics = RouterMetrics(self.config.num_replicas)
        self._clock = clock
        self._ids = itertools.count()   # shared: rids are global submit order
        self._lock = threading.Lock()   # ledger + quarantine flags
        self._resolved: List[ServeResult] = []  # quarantine-synthesized
        self._closed = False
        # test seam: called with (replica_id) on the scan worker before a
        # slice scan runs — lets tests fuzz arrival order / inject faults
        self._scan_hook: Optional[Callable[[int], None]] = None

        ecfg = self.config.engine
        # pin the corpus at construction, like each engine does: slice
        # ownership is planned against this frozen view and only moves
        # when `replan` advances it after an ingest
        self.view = index.corpus_view()
        spans = self._plan_spans(self.view)
        self.replicas: List[_Replica] = []
        for r, (start, stop) in enumerate(spans):
            tracer = None
            if ecfg.trace:
                tracer = obs.Tracer(capacity=ecfg.trace_capacity,
                                    clock=clock, common={"replica": r})
            engine = ServeEngine(
                index, config=ecfg, sessions=self.sessions, clock=clock,
                tracer=tracer, request_ids=self._ids,
                searcher=_ScatterSearcher(self, r))
            self.replicas.append(_Replica(
                replica_id=r, engine=engine,
                sl=self.view.slice_view(start, stop),
                step_pool=ThreadPoolExecutor(
                    1, thread_name_prefix=f"replica{r}-step"),
                scan_pool=ThreadPoolExecutor(
                    1, thread_name_prefix=f"replica{r}-scan")))

    def _plan_spans(self, view) -> List[Tuple[int, int]]:
        """Slice ownership for ``view``'s rows.  With an IVF-built corpus
        the cuts land on *cluster* boundaries nearest an even row split —
        each replica owns whole clusters, so first-stage routing doubles
        as replica prediction, and (clusters being built shard-aligned)
        slices still share candidate-cache shard boundaries.  Without a
        cluster map this is the historical cache-aligned even split."""
        num_rows = view.num_rows
        nrep = self.config.num_replicas
        cm = view.cluster_map
        if cm is not None and cm.num_clusters >= nrep:
            stops = [int(s) for s in cm.stops]
            if stops[-1] != num_rows:       # defensive: cover a ragged tail
                stops.append(num_rows)
            # choose nrep-1 strictly increasing cluster boundaries, each
            # nearest its even-split target; stops[-1] (== num_rows) is
            # never a cut, so every replica gets at least one cluster
            cuts: List[int] = []
            prev = -1
            for r in range(1, nrep):
                target = num_rows * r / nrep
                lo = prev + 1
                hi = len(stops) - 2 - (nrep - 1 - r)
                j = min(range(lo, hi + 1),
                        key=lambda i: abs(stops[i] - target))
                cuts.append(stops[j])
                prev = j
            edges = [0] + cuts + [num_rows]
            return list(zip(edges[:-1], edges[1:]))
        ecfg = self.config.engine
        align = 1
        if ecfg.cache_config is not None:
            shard_docs = ecfg.cache_config.resolve_shard_docs(num_rows)
            if shard_docs * nrep <= num_rows:
                align = shard_docs
        return plan_row_slices(num_rows, nrep, align=align)

    def replan(self, epoch: Optional[int] = None) -> List[List[int]]:
        """Re-plan replica slice ownership from the corpus cluster map
        after an epoch advance (default: the index's current epoch).

        Slices swap atomically under the router lock and every healthy
        replica's engine re-pins its corpus view, so subsequent scatters
        cover the new rows and new sessions plan against (and are epoch-
        stamped with) the grown corpus.  The per-slice scan + (score desc,
        global id asc) merge is partition-independent, so results stay
        bit-identical to a single whole-corpus engine at the same epoch —
        the invariant the differential harness pins.  Call while quiesced
        (between step/drain calls): an engine mid-dispatch keeps the view
        it started with.  Returns the new ``[start, stop)`` spans."""
        if self._closed:
            raise RuntimeError("router is closed; cannot replan")
        view = self.index.corpus_view(epoch)
        spans = self._plan_spans(view)
        with self._lock:
            self.view = view
            for h, (start, stop) in zip(self.replicas, spans):
                h.sl = view.slice_view(start, stop)
        for h in self.replicas:
            if not h.quarantined:
                h.engine.refresh_corpus(view.epoch)
        return [[start, stop] for start, stop in spans]

    # -- sessions + submit ---------------------------------------------------

    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    def open_session(self, tenant: str, **session_kwargs) -> Session:
        # same epoch stamp as ServeEngine.open_session, from the router's
        # pinned view — a single engine and a router fed the same opens
        # therefore hit identical plan-cache keys
        session_kwargs.setdefault("epoch", self.view.epoch)
        return self.sessions.open(tenant, **session_kwargs)

    def home_replica(self, tenant: str) -> int:
        """The tenant's home replica id (hash placement, before probing)."""
        return tenant_seed(tenant) % self.num_replicas

    def _route(self, tenant: str) -> Tuple[_Replica, bool]:
        """Home replica for a submit: hash, then linear-probe past
        quarantined replicas.  Raises `ReplicaUnavailable` (a typed
        `AdmissionError`) when the whole fleet is down.  Caller holds
        ``self._lock``."""
        base = self.home_replica(tenant)
        for probe in range(self.num_replicas):
            h = self.replicas[(base + probe) % self.num_replicas]
            if not h.quarantined:
                return h, probe > 0
        raise ReplicaUnavailable(self.num_replicas)

    def submit(self, tenant: str, embedding: np.ndarray, key=None, *,
               priority: Optional[str] = None,
               deadline_s: Optional[float] = None) -> int:
        """Enqueue one query on the tenant's home replica.  Same contract
        as `ServeEngine.submit`: returns a request id; typed
        `admission.AdmissionError` rejections (including the home
        replica's `RateLimited` with its ``retry_after_s``) propagate
        unchanged, and a rejected submit consumed no request id on *any*
        replica — the id counter is shared and only advances on accept."""
        if self._closed:
            raise RuntimeError("router is closed; no further submissions")
        with self._lock:
            h, rehomed = self._route(tenant)
            try:
                rid = h.engine.submit(tenant, embedding, key,
                                      priority=priority,
                                      deadline_s=deadline_s)
            except adm.AdmissionError:
                self.metrics.record_rejected(h.replica_id)
                raise
            # ledger entry is written under the same lock as the submit, so
            # a quarantine firing from a replica worker can never slip in
            # between accept and ledger (which would orphan the result)
            h.outstanding[rid] = (tenant, self._clock())
        self.metrics.record_submit(h.replica_id, rehomed=rehomed)
        return rid

    @property
    def pending(self) -> int:
        return sum(h.engine.pending for h in self.replicas
                   if not h.quarantined)

    # -- scatter-gather top-k' ----------------------------------------------

    def _slice_scan(self, replica_id: int, perturbed: np.ndarray,
                    kprime: int) -> tuple:
        """One replica's share of a scatter: exact top-k' over its slice,
        global ids.  Runs on the replica's scan worker."""
        hook = self._scan_hook
        if hook is not None:
            hook(replica_id)
        h = self.replicas[replica_id]
        out = slice_topk(h.sl, jnp.asarray(perturbed, jnp.float32), kprime,
                         use_pallas=self.config.engine.use_pallas)
        return np.asarray(out.values), np.asarray(out.indices)

    def _fallback_scan(self, replica_id: int, perturbed: np.ndarray,
                       kprime: int) -> tuple:
        """Scan a quarantined replica's slice on the caller's thread.
        Slice data is host-shared, so this keeps in-flight and future
        batches on healthy replicas bit-identical while the owner is
        down (compute failed over, placement unchanged)."""
        h = self.replicas[replica_id]
        out = slice_topk(h.sl, jnp.asarray(perturbed, jnp.float32), kprime,
                         use_pallas=self.config.engine.use_pallas)
        return np.asarray(out.values), np.asarray(out.indices)

    def _scatter_topk(self, perturbed: np.ndarray, kprime: int, *,
                      home: int) -> np.ndarray:
        """Fan a (B, n) perturbed block out to every replica's slice and
        merge to the global (B, k') candidate ids.  Called from the home
        replica's dispatch (step worker); runs scans concurrently on the
        other replicas' scan workers and falls back inline for
        quarantined or failing slices."""
        cfg = self.config
        n = self.num_replicas
        with self._lock:
            down = [h.quarantined for h in self.replicas]
        futures: Dict[int, object] = {}
        for r in range(n):
            if not down[r]:
                futures[r] = self.replicas[r].scan_pool.submit(
                    self._slice_scan, r, perturbed, kprime)
        parts_v: List[np.ndarray] = [None] * n
        parts_i: List[np.ndarray] = [None] * n
        fallbacks = 0
        tracer = self.replicas[home].engine.tracer
        for r in range(n):
            fut = futures.get(r)
            if fut is not None:
                try:
                    parts_v[r], parts_i[r] = fut.result(
                        timeout=cfg.scan_timeout_s)
                    continue
                except FutureTimeoutError:
                    self._quarantine(r, "scan_stalled")
                except Exception as e:   # noqa: BLE001 — fault boundary
                    self._quarantine(r, f"scan:{type(e).__name__}")
            fallbacks += 1
            tracer.event("scan_fallback", shard=r)
            parts_v[r], parts_i[r] = self._fallback_scan(r, perturbed,
                                                         kprime)
        t0 = self._clock()
        merged = merge_topk(parts_v, parts_i, kprime)
        self.metrics.record_scatter(
            scans=n - fallbacks, fallbacks=fallbacks,
            merged=int(sum(p.size for p in parts_i)),
            merge_wall_s=self._clock() - t0)
        tracer.event("scatter", replicas=n - fallbacks, kprime=kprime,
                     lanes=perturbed.shape[0])
        return merged

    # -- quarantine + collection --------------------------------------------

    def _quarantine(self, replica_id: int, reason: str) -> None:
        """Take a replica out of service: no more homing, stepping, or
        scatter fan-out to it.  Every ledgered in-flight request resolves
        *now* as a typed error result (returned by the next step/drain) —
        the zero-lost contract at router scope.  Results the zombie
        replica produces later are dropped and counted (`_collect`)."""
        h = self.replicas[replica_id]
        with self._lock:
            if h.quarantined:
                return
            h.quarantined = True
            h.quarantine_reason = reason
            stranded = sorted(h.outstanding.items())
            h.outstanding.clear()
        now = self._clock()
        resolved = [
            ServeResult(
                request_id=rid, tenant=tenant, docs=[],
                ids=np.empty(0, np.int64), transcript=None,
                latency_s=now - t_submit, batch_size=0,
                error=f"replica_quarantined({reason})", quarantined=True)
            for rid, (tenant, t_submit) in stranded]
        with self._lock:
            self._resolved.extend(resolved)
        self.metrics.record_quarantine(replica_id, reason, len(resolved))
        h.engine.tracer.event("replica_quarantine", reason=reason[:64],
                              requests=len(resolved))

    def _collect(self, h: _Replica,
                 results: List[ServeResult]) -> List[ServeResult]:
        """Reconcile a replica's step/drain output against the ledger:
        each request id resolves exactly once — a result whose id was
        already resolved at quarantine time is a zombie duplicate and is
        dropped (counted, never returned twice)."""
        kept = []
        late = 0
        with self._lock:
            for res in results:
                if h.outstanding.pop(res.request_id, None) is None:
                    late += 1
                    continue
                kept.append(res)
        if late:
            self.metrics.record_late_dropped(late)
        self.metrics.record_completed(h.replica_id, len(kept))
        return kept

    def _take_resolved(self) -> List[ServeResult]:
        with self._lock:
            out, self._resolved = self._resolved, []
        return out

    def _run_on_replicas(self, call, *, timeout: Optional[float],
                         label: str) -> List[ServeResult]:
        """Run ``call(engine)`` on every healthy replica's step worker in
        parallel, collecting through the ledger; a raise or stall
        quarantines that replica."""
        out = self._take_resolved()
        with self._lock:
            healthy = [h for h in self.replicas if not h.quarantined]
        futures = [(h, h.step_pool.submit(call, h.engine)) for h in healthy]
        for h, fut in futures:
            try:
                results = fut.result(timeout=timeout)
            except FutureTimeoutError:
                self._quarantine(h.replica_id, f"{label}_stalled")
                continue
            except Exception as e:       # noqa: BLE001 — fault boundary
                self._quarantine(h.replica_id, f"{label}:{type(e).__name__}")
                continue
            out.extend(self._collect(h, results))
        out.extend(self._take_resolved())
        return out

    # -- dispatch ------------------------------------------------------------

    def step(self, *, force: bool = False) -> List[ServeResult]:
        """Step every healthy replica once, in parallel (each replica
        dispatches at most one batch, per `ServeEngine.step`).  Returns
        completed/shed results plus any quarantine-resolved errors."""
        return self._run_on_replicas(
            lambda eng: eng.step(force=force),
            timeout=self.config.step_timeout_s, label="step")

    def drain(self, *, shed: bool = False) -> List[ServeResult]:
        """Flush every healthy replica (`ServeEngine.drain`); results in
        request order.  Quarantine-resolved error results ride along, so
        ledger accounting holds: every accepted submit resolves exactly
        once across step/drain calls."""
        out = self._run_on_replicas(
            lambda eng: eng.drain(shed=shed),
            timeout=self.config.step_timeout_s, label="drain")
        return sorted(out, key=lambda r: r.request_id)

    # -- telemetry + lifecycle ----------------------------------------------

    def summary(self) -> dict:
        """Router counters + per-replica engine summaries (JSON-ready)."""
        return {
            "router": self.metrics.summary(),
            "epoch": self.view.epoch,
            "slices": [[h.sl.start, h.sl.stop] for h in self.replicas],
            "quarantined": {
                str(h.replica_id): h.quarantine_reason
                for h in self.replicas if h.quarantined},
            "replicas": {str(h.replica_id): h.engine.metrics.summary()
                         for h in self.replicas},
        }

    def write_trace(self, path: str) -> int:
        """Merge every replica's span ring into one Chrome-trace timeline
        (spans carry a ``replica`` attr; see obs.trace)."""
        if not self.config.engine.trace:
            raise RuntimeError(
                "tracing is disabled; construct the router with "
                "RouterConfig(engine=EngineConfig(trace=True))")
        spans = []
        for h in self.replicas:
            spans.extend(h.engine.tracer.spans())
        spans.sort(key=lambda s: s.t_start)
        return obs.write_chrome_trace(path, spans)

    def close(self, *, shed_pending: bool = False) -> List[ServeResult]:
        """Drain, close every healthy replica engine (idempotent; the
        shared candidate cache's admitter stops with the last closer), and
        shut the worker pools down.  Quarantined replicas are not drained
        — their requests already resolved at quarantine time."""
        if self._closed:
            return []
        out = self.drain(shed=shed_pending)
        self._closed = True
        with self._lock:
            healthy = [h for h in self.replicas if not h.quarantined]
        for h in healthy:
            try:
                h.step_pool.submit(h.engine.close).result(
                    timeout=self.config.step_timeout_s)
            except Exception:            # noqa: BLE001 — already leaving
                pass
        for h in self.replicas:
            h.step_pool.shutdown(wait=False)
            h.scan_pool.shutdown(wait=False)
        return out

    def __enter__(self) -> "ReplicaRouter":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False


__all__ = ["RouterConfig", "RouterMetrics", "ReplicaRouter",
           "ReplicaUnavailable", "merge_topk"]
