"""repro.obs: span schema redaction, ring bounding, stage histograms,
Chrome-trace export.  The engine-integration side (stage coverage over a
real served stream, admitter-span parenting/overlap) lives in
tests/test_serve.py next to the engine tests."""

import json
import math

import numpy as np
import pytest

from repro import obs
from repro.obs.trace import _MAX_STR


def _clock(seq):
    """Deterministic fake clock: pops successive times from a list."""
    it = iter(seq)
    return lambda: next(it)


# -- redaction contract -----------------------------------------------------

def test_redaction_rejects_unknown_keys():
    tracer = obs.Tracer()
    # the exact attack the schema exists to stop: logging doc ids
    with pytest.raises(ValueError, match="ALLOWED_ATTR_KEYS"):
        tracer.event("gather", doc_ids=17)
    with pytest.raises(ValueError, match="ALLOWED_ATTR_KEYS"):
        tracer.event("perturb", embedding=1.0)
    assert tracer.spans() == []        # nothing was recorded


def test_redaction_rejects_non_scalar_values():
    tracer = obs.Tracer()
    for payload in (np.zeros(4),           # an embedding
                    [0.1, 0.9],            # a score vector
                    b"plaintext",          # document bytes
                    {"id": 3},             # structured payload
                    (1, 2)):
        with pytest.raises(TypeError, match="non-scalar"):
            tracer.event("stage", count=payload)
    with pytest.raises(ValueError, match="chars"):
        tracer.event("stage", reason="x" * (_MAX_STR + 1))
    assert tracer.spans() == []


def test_redaction_converts_numpy_scalars():
    out = obs.validate_attrs({"count": np.int64(3),
                              "bytes": np.float32(1.5),
                              "ok": True, "tenant": "alice"})
    assert out == {"count": 3, "bytes": 1.5, "ok": True, "tenant": "alice"}
    assert type(out["count"]) is int and type(out["bytes"]) is float


def test_span_failure_records_error_class_name_only():
    tracer = obs.Tracer()
    with pytest.raises(RuntimeError):
        with tracer.span("score", lanes=4):
            raise RuntimeError("secret query payload in the message")
    (span,) = tracer.spans()
    assert span.attrs["error_type"] == "RuntimeError"
    # the exception *message* must never reach the span
    assert "secret" not in json.dumps(
        [dict(s.attrs) for s in tracer.spans()])


# -- tracer mechanics -------------------------------------------------------

def test_ring_buffer_bounded_histograms_complete():
    tracer = obs.Tracer(capacity=4, clock=_clock(
        [float(t) for i in range(10) for t in (i, i + 0.5)]))
    for i in range(10):
        with tracer.span("stage", lanes=i):
            pass
    spans = tracer.spans()
    assert len(spans) == 4                    # ring bound
    assert tracer.dropped == 6
    assert spans[-1].attrs["lanes"] == 9      # newest kept
    # the histogram saw every span, wrapped or not
    assert tracer.stage_summary()["stage"]["count"] == 10
    snap = tracer.snapshot()
    assert snap["spans"] == 4 and snap["dropped"] == 6
    tracer.clear()
    assert tracer.spans() == [] and tracer.stage_summary() == {}
    with pytest.raises(ValueError, match="capacity"):
        obs.Tracer(capacity=0)


def test_record_explicit_interval_and_event():
    tracer = obs.Tracer(clock=_clock([5.0]))
    span = tracer.record("queue_wait", 1.0, 3.5, request_id=7, batch_id=2,
                         tenant="bob")
    assert span.duration_s == 2.5 and span.t_end == 3.5
    assert span.request_id == 7 and span.batch_id == 2
    evt = tracer.event("refill", requests=3)
    assert evt.duration_s == 0.0 and evt.t_start == 5.0
    # events don't pollute the stage histograms with zero durations
    assert "refill" not in tracer.stage_summary()
    assert tracer.stage_summary()["queue_wait"]["count"] == 1


def test_null_tracer_is_inert():
    nt = obs.NULL_TRACER
    assert not nt.enabled
    with nt.span("stage", lanes=8):
        pass
    assert nt.record("x", 0, 1) is None and nt.event("x") is None
    assert nt.spans() == [] and nt.stage_summary() == {}
    assert nt.snapshot()["spans"] == 0
    # even bad attrs are ignored when disabled — no validation cost
    with nt.span("stage", embedding=np.zeros(3)):
        pass


# -- histograms -------------------------------------------------------------

def test_histogram_percentiles_and_merge():
    h = obs.StageHistogram()
    assert math.isnan(h.percentile(50))
    assert h.summary() == {"count": 0}
    for d in (1e-6, 2e-6, 4e-6, 1e-3, 1.0):
        h.record(d)
    s = h.summary()
    assert s["count"] == 5
    assert s["min_s"] == 1e-6 and s["max_s"] == 1.0
    # bucket upper-edge estimate: median sample 4us sits exactly on an edge
    assert h.percentile(50) == pytest.approx(4e-6)
    # p100 falls in the bucket holding 1.0s; upper edge is 2^20us
    assert 1.0 <= h.percentile(100) <= 2.1
    h2 = obs.StageHistogram()
    h2.record(10.0)
    h.merge(h2)
    assert h.count == 6 and h.max_s == 10.0
    # durations beyond the last edge land in the overflow bucket and
    # report the exact max
    h3 = obs.StageHistogram()
    h3.record(500.0)
    assert h3.percentile(99) == 500.0


# -- chrome-trace export ----------------------------------------------------

def test_chrome_trace_roundtrip(tmp_path):
    tracer = obs.Tracer(clock=_clock([10.0, 10.5, 10.1, 10.2]))
    with tracer.span("dispatch", batch_id=0, batch_size=2):
        pass                                   # 10.0 -> 10.5
    tracer.record("cache_admit", 10.1, 10.3, track="admitter",
                  batch_id=0, shard=3)
    path = tmp_path / "trace.json"
    n = obs.write_chrome_trace(str(path), tracer.spans(),
                               stage_summary=tracer.stage_summary())
    assert n == 2
    doc = obs.load_chrome_trace(str(path))
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    durs = [e for e in events if e["ph"] == "X"]
    assert {m["args"]["name"] for m in meta} == {"engine", "admitter"}
    by_name = {e["name"]: e for e in durs}
    # ts normalized to the earliest span, microseconds
    assert by_name["dispatch"]["ts"] == 0.0
    assert by_name["dispatch"]["dur"] == pytest.approx(5e5)
    assert by_name["cache_admit"]["ts"] == pytest.approx(1e5)
    assert by_name["cache_admit"]["args"]["shard"] == 3
    assert by_name["cache_admit"]["args"]["batch_id"] == 0
    # distinct tracks get distinct tids; "engine" is row 1
    assert by_name["dispatch"]["tid"] != by_name["cache_admit"]["tid"]
    assert by_name["dispatch"]["tid"] == 1
    assert doc["metadata"]["stage_summary"]["dispatch"]["count"] == 1
    assert obs.chrome_trace_events([]) == []
