"""Modular-arithmetic substrate for the TPU-native RLWE path.

Design constraints (TPU int32 lanes, no 64-bit integers on the device path):

  * RNS primes q in (2^19, 2^20) with q = 1 (mod 2N)  -> NTT-friendly and every
    partial product in the limb-split modular multiply fits in int32:
      - one operand split into 10-bit limbs: a*b_hi < 2^20 * 2^10 = 2^30
      - Barrett estimate (x >> 11) * mu with mu = floor(2^30 / q) < 2^11:
        (2^20)(2^11) < 2^31
  * ``mod_mul`` below is written with jnp ops only and is used verbatim inside
    the Pallas NTT kernel and in the pure-JAX fallback path.

Host-side helpers (prime search, primitive roots, twiddle tables) use Python
bignums; the resulting tables are int32 numpy arrays.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Host-side number theory (Python ints)
# ---------------------------------------------------------------------------


def is_prime(n: int) -> bool:
    """Deterministic Miller-Rabin for n < 3.3e24 (fixed witness set)."""
    if n < 2:
        return False
    for p in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        if n % p == 0:
            return n == p
    d, s = n - 1, 0
    while d % 2 == 0:
        d //= 2
        s += 1
    for a in (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37):
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(s - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def find_ntt_primes(two_n: int, count: int, *, lo: int = 1 << 19, hi: int = 1 << 20):
    """Primes q in (lo, hi) with q = 1 mod two_n, largest first."""
    primes = []
    k = (hi - 1) // two_n
    while k * two_n + 1 > lo and len(primes) < count:
        q = k * two_n + 1
        if q < hi and is_prime(q):
            primes.append(q)
        k -= 1
    if len(primes) < count:
        raise ValueError(f"only {len(primes)} NTT primes = 1 mod {two_n} in range")
    return tuple(primes)


def primitive_root(q: int) -> int:
    """Smallest generator of Z_q^* (q prime)."""
    factors = []
    phi = q - 1
    m = phi
    d = 2
    while d * d <= m:
        if m % d == 0:
            factors.append(d)
            while m % d == 0:
                m //= d
        d += 1
    if m > 1:
        factors.append(m)
    for g in range(2, q):
        if all(pow(g, phi // f, q) != 1 for f in factors):
            return g
    raise ValueError("no generator found")


def root_of_unity(q: int, order: int) -> int:
    """Element of exact multiplicative order ``order`` mod q."""
    if (q - 1) % order != 0:
        raise ValueError(f"{order} does not divide {q}-1")
    g = primitive_root(q)
    w = pow(g, (q - 1) // order, q)
    assert pow(w, order, q) == 1 and pow(w, order // 2, q) == q - 1
    return w


def bit_reverse_indices(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


# ---------------------------------------------------------------------------
# Per-prime constant bundle
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class PrimeCtx:
    """Everything the NTT kernel needs for one RNS prime.

    ``eq=False``: instances hash by identity; ``build`` is lru_cached so each
    (q, n) pair maps to a single instance, making it a valid jit static arg.
    """

    q: int
    mu: int            # floor(2^30 / q) for Barrett
    n: int             # transform size (polynomial degree)
    psi_table: np.ndarray      # (n,) int32 — bit-rev ordered powers of psi (2n-th root)
    ipsi_table: np.ndarray     # (n,) int32 — bit-rev ordered powers of psi^{-1}
    n_inv: int         # N^{-1} mod q

    @classmethod
    @functools.lru_cache(maxsize=None)
    def build(cls, q: int, n: int) -> "PrimeCtx":
        psi = root_of_unity(q, 2 * n)
        ipsi = pow(psi, -1, q)
        rev = bit_reverse_indices(n)
        psi_pows = np.array([pow(psi, int(i), q) for i in range(n)], dtype=np.int64)
        ipsi_pows = np.array([pow(ipsi, int(i), q) for i in range(n)], dtype=np.int64)
        return cls(
            q=q,
            mu=(1 << 30) // q,
            n=n,
            psi_table=psi_pows[rev].astype(np.int32),
            ipsi_table=ipsi_pows[rev].astype(np.int32),
            n_inv=pow(n, -1, q),
        )


# ---------------------------------------------------------------------------
# int32-lane-safe modular primitives (jnp; usable inside Pallas kernels)
# ---------------------------------------------------------------------------


def barrett_reduce(x, q: int, mu: int):
    """x mod q for 0 <= x < 2^31, q in (2^19, 2^20), mu = floor(2^30/q).

    All intermediates fit in int32:  (x >> 11) < 2^20,  mu < 2^11.
    Estimate error < 4, corrected by 4 conditional subtractions.
    """
    est = ((x >> 11) * jnp.int32(mu)) >> 19
    r = x - est * jnp.int32(q)
    for _ in range(4):
        r = jnp.where(r >= q, r - jnp.int32(q), r)
    return r


def mod_mul(a, b, q: int, mu: int):
    """(a * b) mod q with a, b in [0, q), q < 2^20 — int32-safe limb split."""
    b_hi = b >> 10
    b_lo = b & jnp.int32(1023)
    t = barrett_reduce(a * b_hi, q, mu)          # a*b_hi < 2^30
    t = (t << 10) + a * b_lo                     # < (q-1)(2^11 - 1) < 2^31
    return barrett_reduce(t, q, mu)


def mod_add(a, b, q: int):
    s = a + b
    return jnp.where(s >= q, s - jnp.int32(q), s)


def mod_sub(a, b, q: int):
    d = a - b
    return jnp.where(d < 0, d + jnp.int32(q), d)


def mod_sum(x, q: int, mu: int, axis: int):
    """Modular reduction of a sum along ``axis`` in one shot: terms in [0, q)
    are accumulated in raw int32 and Barrett-reduced once, which is exact as
    long as the accumulator cannot wrap — shape[axis] * (q-1) < 2^31, i.e.
    up to 2^11 terms at q < 2^20.  Bit-identical to a chain of mod_add."""
    terms = x.shape[axis]
    assert terms * (q - 1) < 2**31, f"mod_sum overflow: {terms} terms at q={q}"
    return barrett_reduce(jnp.sum(x, axis=axis), q, mu)


# ---------------------------------------------------------------------------
# numpy int64 oracles (independent implementation for tests)
# ---------------------------------------------------------------------------


def mod_mul_np(a, b, q: int):
    return (a.astype(np.int64) * b.astype(np.int64)) % q


def negacyclic_mul_np(a: np.ndarray, b: np.ndarray, q: int) -> np.ndarray:
    """Schoolbook negacyclic convolution in Z_q[X]/(X^n + 1) (int64 numpy)."""
    n = a.shape[-1]
    a = a.astype(np.int64)
    b = b.astype(np.int64)
    full = np.zeros(a.shape[:-1] + (2 * n,), dtype=object)
    # object dtype: exact big-int accumulation regardless of q and n
    for i in range(n):
        full[..., i : i + n] += a[..., i : i + 1] * b
    lo = full[..., :n]
    hi = full[..., n:]
    return np.array((lo - hi) % q, dtype=np.int64)


__all__ = [
    "is_prime",
    "find_ntt_primes",
    "primitive_root",
    "root_of_unity",
    "bit_reverse_indices",
    "PrimeCtx",
    "barrett_reduce",
    "mod_mul",
    "mod_add",
    "mod_sub",
    "mod_sum",
    "mod_mul_np",
    "negacyclic_mul_np",
]
