"""Shared benchmark utilities.

Every benchmark emits `name,us_per_call,derived` CSV rows via `emit` —
`derived` carries the paper-facing quantity (recall, KB, ratio, ...).
Set REPRO_BENCH_FULL=1 for paper-scale sweeps (minutes-hours on CPU);
the default sizes finish in a couple of minutes and exercise identical code.
"""

from __future__ import annotations

import os
import time

import numpy as np

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timeit(fn, *, repeat: int = 3, warmup: int = 1) -> float:
    """Median wall time of fn() in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


__all__ = ["FULL", "emit", "timeit"]
