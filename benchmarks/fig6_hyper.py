"""Paper Fig. 6: hyperparameter relationships (pure geometry).

(a) k/N vs alpha_k for several dims — the high-dimensional concentration
    that makes the perturbation so sensitive;
(b) eps vs k' for several k — the planner's inverse map (Fig. 6b).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import geometry, planner


def run() -> None:
    for n in (16, 384, 768, 1536):
        for alpha_deg in (60, 75, 85, 89, 90):
            a = np.deg2rad(alpha_deg)
            frac = float(geometry.cap_fraction_np(a, n))
            emit(f"fig6a/n{n}_alpha{alpha_deg}", 0.0, f"k_over_N={frac:.3e}")

    N = 100_000
    for k in (5, 10, 20):
        for kp in (50, 100, 160, 200, 400):
            if kp <= k:
                continue
            eps = planner.eps_for_kprime(n=768, N=N, k=k, kprime=kp)
            emit(f"fig6b/k{k}_kprime{kp}", 0.0,
                 f"eps={eps:.0f};eps_over_n={eps / 768:.1f}")
