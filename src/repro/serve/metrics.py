"""Per-tenant serving metrics: latency percentiles + wire-byte accounting.

Latency is measured enqueue -> result (queue wait included, the number a
tenant actually experiences under micro-batching).  Wire bytes come from the
protocol transcripts, i.e. the same Request.nbytes / Reply.nbytes accounting
the paper's Table 2 uses.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.protocol import ProtocolTranscript


@dataclasses.dataclass
class TenantStats:
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    batch_sizes: List[int] = dataclasses.field(default_factory=list)
    request_bytes: int = 0
    reply_bytes: int = 0
    fetch_bytes: int = 0
    docs_bytes: int = 0
    ot_wire_bytes: int = 0
    direct_count: int = 0
    ot_count: int = 0

    @property
    def count(self) -> int:
        return len(self.latencies_s)

    @property
    def total_wire_bytes(self) -> int:
        return (self.request_bytes + self.reply_bytes + self.fetch_bytes
                + self.docs_bytes + self.ot_wire_bytes)

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.latencies_s, q))

    def summary(self) -> dict:
        return {
            "count": self.count,
            "p50_latency_s": round(self.percentile(50), 4),
            "p99_latency_s": round(self.percentile(99), 4),
            "mean_latency_s": round(float(np.mean(self.latencies_s)), 4),
            "mean_batch_size": round(float(np.mean(self.batch_sizes)), 2),
            "mean_wire_kb": round(
                self.total_wire_bytes / max(self.count, 1) / 1024, 2),
            "paths": {"direct": self.direct_count, "ot": self.ot_count},
        }


class ServeMetrics:
    """Accumulates TenantStats per tenant plus a process-wide aggregate."""

    def __init__(self) -> None:
        self.tenants: Dict[str, TenantStats] = {}
        self.aggregate = TenantStats()
        self.dispatch_sizes: List[int] = []

    @property
    def num_batches(self) -> int:
        return len(self.dispatch_sizes)

    def record_batch(self, size: int) -> None:
        self.dispatch_sizes.append(size)

    def record(self, tenant: str, *, latency_s: float, batch_size: int,
               transcript: ProtocolTranscript) -> None:
        for stats in (self.tenants.setdefault(tenant, TenantStats()),
                      self.aggregate):
            stats.latencies_s.append(latency_s)
            stats.batch_sizes.append(batch_size)
            stats.request_bytes += transcript.request_bytes
            stats.reply_bytes += transcript.reply_bytes
            stats.fetch_bytes += transcript.fetch_bytes
            stats.docs_bytes += transcript.docs_bytes
            stats.ot_wire_bytes += transcript.ot_wire_bytes
            if transcript.path == "ot":
                stats.ot_count += 1
            else:
                stats.direct_count += 1

    def summary(self) -> dict:
        out = {"aggregate": (self.aggregate.summary()
                             if self.aggregate.count else {"count": 0}),
               "num_batches": self.num_batches,
               "tenants": {t: s.summary() for t, s in self.tenants.items()}}
        return out


__all__ = ["TenantStats", "ServeMetrics"]
