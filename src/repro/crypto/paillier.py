"""Paper-faithful Paillier PHE (paper Section 3.3.1).

The paper's Module 2(a) encrypts the query embedding with a partially
homomorphic scheme and has the cloud evaluate cosine distances in encrypted
form: ct+ct addition and ct*plaintext scalar multiplication.  Paillier is the
canonical choice and serves two roles here:

  1. fidelity baseline — the protocol path the paper actually measured
     (its 0.67 s / 2.72 h numbers are Paillier-bound);
  2. cost model — bignum modexp is inherently CPU/client-side, so this module
     is plain Python; the TPU-native path is `crypto/rlwe.py`.

Fixed-point encoding: values v are encoded as round(v * 2^frac_bits) mod n,
with negatives in the upper half of Z_n (centered lift at decode).
"""

from __future__ import annotations

import dataclasses
import math
import secrets
from typing import Sequence

import numpy as np

from repro.crypto.modring import is_prime


def _randbits(bits: int, rng: np.random.Generator | None = None) -> int:
    """`secrets`-backed by default; an np.random.Generator makes key and
    encryption randomness *deterministic* — for reproducible benchmarking /
    replay parity only, not for real deployments."""
    if rng is None:
        return secrets.randbits(bits)
    nbytes = (bits + 7) // 8
    return int.from_bytes(rng.bytes(nbytes), "big") >> (nbytes * 8 - bits)


def _randbelow(n: int, rng: np.random.Generator | None = None) -> int:
    if rng is None:
        return secrets.randbelow(n)
    bits = n.bit_length()
    while True:
        r = _randbits(bits, rng)
        if r < n:
            return r


def _rand_prime(bits: int, rng: np.random.Generator | None = None) -> int:
    while True:
        cand = _randbits(bits, rng) | (1 << (bits - 1)) | 1
        if is_prime(cand):
            return cand


@dataclasses.dataclass(frozen=True)
class PaillierPublicKey:
    n: int
    n_sq: int
    g: int  # fixed to n + 1

    @property
    def key_bits(self) -> int:
        return self.n.bit_length()

    def ciphertext_bytes(self) -> int:
        return (2 * self.key_bits + 7) // 8


@dataclasses.dataclass(frozen=True)
class PaillierSecretKey:
    pub: PaillierPublicKey
    lam: int   # lcm(p-1, q-1)
    mu: int    # (L(g^lam mod n^2))^{-1} mod n


def keygen(bits: int = 1024,
           rng: np.random.Generator | None = None) -> PaillierSecretKey:
    """Generate a Paillier keypair with an n of ~`bits` bits."""
    while True:
        p = _rand_prime(bits // 2, rng)
        q = _rand_prime(bits // 2, rng)
        if p != q:
            break
    n = p * q
    pub = PaillierPublicKey(n=n, n_sq=n * n, g=n + 1)
    lam = math.lcm(p - 1, q - 1)
    x = pow(pub.g, lam, pub.n_sq)
    l_x = (x - 1) // n
    mu = pow(l_x, -1, n)
    return PaillierSecretKey(pub=pub, lam=lam, mu=mu)


def encrypt(pub: PaillierPublicKey, m: int,
            rng: np.random.Generator | None = None) -> int:
    """Enc(m) = (1 + mn) * r^n mod n^2  (g = n+1 shortcut)."""
    m %= pub.n
    while True:
        r = _randbelow(pub.n, rng)
        if r and math.gcd(r, pub.n) == 1:
            break
    return (1 + m * pub.n) % pub.n_sq * pow(r, pub.n, pub.n_sq) % pub.n_sq


def decrypt(sk: PaillierSecretKey, c: int) -> int:
    x = pow(c, sk.lam, sk.pub.n_sq)
    return (x - 1) // sk.pub.n * sk.mu % sk.pub.n


def add(pub: PaillierPublicKey, c1: int, c2: int) -> int:
    """Enc(m1 + m2)."""
    return c1 * c2 % pub.n_sq


def mul_plain(pub: PaillierPublicKey, c: int, k: int) -> int:
    """Enc(m * k) for plaintext scalar k (signed).

    Negative k uses the ciphertext inverse so the exponent stays |k|-sized;
    the naive ``k % n`` lift would turn a 13-bit fixed-point scalar into a
    ~keysize-bit exponent (~500x slower modexp).
    """
    if k < 0:
        c = pow(c, -1, pub.n_sq)
        k = -k
    return pow(c, k, pub.n_sq)


# ---------------------------------------------------------------------------
# fixed-point vector layer (what the protocol uses)
# ---------------------------------------------------------------------------

FRAC_BITS = 13  # matches the RLWE scales for apples-to-apples accuracy


def _encode(v: float, n: int, frac_bits: int = FRAC_BITS) -> int:
    return round(float(v) * (1 << frac_bits)) % n


def encode_vector(e: np.ndarray, n: int,
                  frac_bits: int = FRAC_BITS) -> list[int]:
    """Batched `_encode`: one vectorized scale+round over the whole vector
    instead of a per-component python loop.  Bit-identical — both paths
    compute ``v * 2^frac_bits`` in float64 and round half-even (python
    ``round`` on a float and ``np.rint`` share the IEEE tie rule), and the
    final ``% n`` runs in exact integer arithmetic either way."""
    scaled = np.rint(np.asarray(e, np.float64) * (1 << frac_bits))
    return [int(m) % n for m in scaled.astype(np.int64)]


def _decode(m: int, n: int, frac_bits: int) -> float:
    if m > n // 2:
        m -= n
    return m / (1 << frac_bits)


def encrypt_vector(pub: PaillierPublicKey, e: np.ndarray,
                   rng: np.random.Generator | None = None) -> list:
    """[[e_k]]: componentwise encryption of the query embedding."""
    return [encrypt(pub, _encode(v, pub.n), rng)
            for v in np.asarray(e, np.float64)]


def encrypted_dot(pub: PaillierPublicKey, enc_query: Sequence[int],
                  cand: np.ndarray, enc_query_inv=None) -> int:
    """[[<e_k, cand>]] = prod_j [[e_j]]^{cand_j}  (ct*plain + ct+ct only).

    ``enc_query_inv``: optional precomputed ciphertext inverses so negative
    fixed-point scalars cost a small-exponent pow instead of a modinv per
    (dim x candidate) — see encrypted_scores.
    """
    acc = encrypt(pub, 0)
    for j, (c_j, v) in enumerate(zip(enc_query, np.asarray(cand, np.float64))):
        k = round(float(v) * (1 << FRAC_BITS))
        if not k:
            continue
        if k < 0 and enc_query_inv is not None:
            acc = acc * pow(enc_query_inv[j], -k, pub.n_sq) % pub.n_sq
        else:
            acc = add(pub, acc, mul_plain(pub, c_j, k))
    return acc


def encrypted_scores(pub: PaillierPublicKey, enc_query: Sequence[int],
                     cands: np.ndarray,
                     rng: np.random.Generator | None = None) -> list:
    """Encrypted inner products against each of the k' candidates.

    Fixed-base optimization: each query ciphertext is the base for k'
    exponentiations by small signed scalars, so we precompute its (and its
    inverse's) bit powers c^(2^i) once per request; each candidate dim then
    costs only popcount(k) modmuls — no per-candidate squarings.

    ``rng`` seeds the per-candidate blinding (the fresh encryption of zero);
    the default draws from `secrets`.  A seeded generator exists so the
    vectorized twin (`paillier_vec`) can be checked for wire-byte parity —
    blinding cancels at decryption either way.
    """
    n_sq = pub.n_sq
    bits = FRAC_BITS + 2
    pows, ipows = [], []
    for c in enc_query:
        ci = pow(c, -1, n_sq)
        row, irow = [c], [ci]
        for _ in range(bits - 1):
            row.append(row[-1] * row[-1] % n_sq)
            irow.append(irow[-1] * irow[-1] % n_sq)
        pows.append(row)
        ipows.append(irow)

    out = []
    for cand in np.asarray(cands, np.float64):
        acc = encrypt(pub, 0, rng)
        ks = np.rint(cand * (1 << FRAC_BITS)).astype(np.int64)
        for j, k in enumerate(ks):
            if not k:
                continue
            row = pows[j] if k > 0 else ipows[j]
            k = int(abs(k))
            i = 0
            while k:
                if k & 1:
                    acc = acc * row[i] % n_sq
                k >>= 1
                i += 1
        out.append(acc)
    return out


def decrypt_scores(sk: PaillierSecretKey, enc_scores: Sequence[int]) -> np.ndarray:
    out = [_decode(decrypt(sk, c), sk.pub.n, 2 * FRAC_BITS) for c in enc_scores]
    return np.asarray(out, np.float64)


__all__ = [
    "PaillierPublicKey", "PaillierSecretKey", "keygen", "encrypt", "decrypt",
    "add", "mul_plain", "encrypt_vector", "encode_vector", "encrypted_dot",
    "encrypted_scores", "decrypt_scores", "FRAC_BITS",
]
