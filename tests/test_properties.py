"""Hypothesis property tests on system invariants (beyond DistanceDP)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import geometry
from repro.crypto import modring
from repro.crypto.modring import PrimeCtx
from repro.kernels.scoretopk import ops as st_ops
from repro.kernels.scoretopk import ref as st_ref
from repro.models import moe


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=3, max_value=2048),
       st.floats(min_value=0.01, max_value=3.1))
def test_cap_fraction_in_unit_interval_and_symmetric(n, alpha):
    f = float(geometry.cap_fraction_np(alpha, n))
    assert 0.0 <= f <= 1.0
    # antipodal symmetry: F(a) + F(pi - a) == 1
    g = float(geometry.cap_fraction_np(np.pi - alpha, n))
    assert abs(f + g - 1.0) < 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=512),
       st.integers(min_value=1, max_value=50),
       st.floats(min_value=1e-3, max_value=0.5))
def test_kprime_containment_invariants(n, k, r):
    N = 1000
    k = min(k, N)
    kp = geometry.kprime_for(k, N, n, r)
    assert k <= kp <= N
    # monotone in k
    assert geometry.kprime_for(min(k + 5, N), N, n, r) >= kp


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_modring_field_properties(seed):
    """(a*b)*c == a*(b*c), a*(b+c) == a*b + a*c over the NTT prime."""
    ctx = PrimeCtx.build(modring.find_ntt_primes(2048, 1)[0], 1024)
    rng = np.random.default_rng(seed)
    a, b, c = (rng.integers(0, ctx.q, 64).astype(np.int32) for _ in range(3))
    mm = lambda x, y: np.asarray(modring.mod_mul(x, y, ctx.q, ctx.mu))
    ma = lambda x, y: np.asarray(modring.mod_add(x, y, ctx.q))
    np.testing.assert_array_equal(mm(mm(a, b), c), mm(a, mm(b, c)))
    np.testing.assert_array_equal(mm(a, ma(b, c)), ma(mm(a, b), mm(a, c)))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=4),
       st.integers(min_value=20, max_value=300),
       st.integers(min_value=1, max_value=20),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_topk_is_exact_for_any_shape(b, n_rows, k, seed):
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(b, 16)).astype(np.float32)
    e = rng.normal(size=(n_rows, 16)).astype(np.float32)
    out = st_ops.topk_scores(jnp.asarray(q), jnp.asarray(e), k,
                             tile=64, use_pallas=False)
    want_v, want_i = st_ref.topk_ref(jnp.asarray(q), jnp.asarray(e),
                                     min(k, n_rows))
    np.testing.assert_allclose(np.asarray(out.values), np.asarray(want_v),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=4))
def test_moe_output_is_convex_combination_scale(seed, top_k):
    """Router weights are a softmax -> MoE output norm is bounded by the max
    expert-output norm over routed tokens (no amplification by routing)."""
    spec = moe.MoeSpec(d_model=16, d_ff=16, n_experts=4, top_k=top_k,
                       capacity_factor=4.0)
    params = moe.moe_params(jax.random.PRNGKey(seed % 1000), spec,
                            jnp.float32, False)
    x = jax.random.normal(jax.random.PRNGKey(seed % 997), (2, 8, 16))
    out, aux = moe.moe_fwd(params, x, spec)
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.0
    # with huge capacity nothing is dropped: every token got >= 1 expert
    # (output not identically zero unless weights make it so)
    assert out.shape == x.shape
