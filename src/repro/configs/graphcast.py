"""graphcast [gnn]: 16L d_hidden=512 mesh_refinement=6 aggregator=sum
n_vars=227 — encoder-processor-decoder mesh GNN [arXiv:2212.12794].
d_feat is shape-dependent (per assigned graph shape set)."""
from repro.models.gnn import GnnConfig

CONFIG = GnnConfig(name="graphcast", n_layers=16, d_hidden=512,
                   mesh_refinement=6, aggregator="sum", n_vars=227)

REDUCED = GnnConfig(name="graphcast-smoke", n_layers=3, d_hidden=32,
                    d_feat=16, n_vars=8, dtype="float32", remat=False)
