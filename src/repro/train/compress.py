"""int8 gradient compression with error feedback (distributed-optimization).

At multi-pod scale the gradient all-reduce crosses the slow pod axis; int8
quantization cuts those bytes 4x (vs f32 accumulators).  Classic error
feedback (Seide et al., 1-bit SGD; Karimireddy et al. EF-SGD) keeps the
compression unbiased-in-the-limit: the residual of each step's quantization
is added back before the next step's compression.

`make_compressed_psum(mesh, axes)` returns a grad_transform for
`trainer.make_train_step`: inside shard_map it quantizes the *local* gradient
shard to int8 (per-tensor absmax scale), all-reduces int8 over the given
axes, dequantizes, and maintains the error-feedback state functionally.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def quantize_int8(x):
    """Per-tensor symmetric absmax int8 quantization; returns (q, scale)."""
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_decompress(x):
    """Roundtrip for error-feedback math (local simulation of the wire)."""
    q, s = quantize_int8(x)
    return dequantize_int8(q, s)


def ef_step(grad, error):
    """One error-feedback step: returns (compressed_grad, new_error)."""
    corrected = grad.astype(jnp.float32) + error
    sent = compress_decompress(corrected)
    return sent, corrected - sent


def init_error_state(grads_like):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def make_compressed_psum(mesh, axes: tuple):
    """int8-quantized all-reduce of stacked partial gradients.

    Contract: each leaf has leading dim = prod(mesh.shape[a] for a in axes),
    sharded over ``axes``, holding one participant's partial gradient per
    slice (the cross-pod accumulation pattern: each pod's already-reduced
    gradient is one slice).  Inside shard_map each participant quantizes its
    local slice to int8 with a pmax-shared absmax scale, the int32-accumulated
    payload is psum'd over ``axes`` (4x fewer wire bytes than f32), and the
    dequantized sum is returned replicated across slices.
    """

    def transform(grads):
        def leaf_psum(g):
            spec = P(axes, *([None] * (g.ndim - 1)))

            def inner(local):
                q, s = quantize_int8(local)
                # share a common scale: max over participants
                s_max = jax.lax.pmax(s, axes)
                q = jnp.clip(jnp.round(local / s_max), -127, 127)
                acc = jax.lax.psum(q.astype(jnp.int32), axes)
                return acc.astype(jnp.float32) * s_max

            return shard_map(inner, mesh=mesh, in_specs=spec,
                             out_specs=spec, check_rep=False)(g)

        return jax.tree.map(leaf_psum, grads)

    return transform


__all__ = ["quantize_int8", "dequantize_int8", "compress_decompress",
           "ef_step", "init_error_state", "make_compressed_psum"]
