import numpy as np
import pytest

from repro.core import planner


def test_plan_paper_operating_point():
    p = planner.plan(n=768, N=100_000, k=5, radius=0.03,
                     radial_quantile=0.5, conservative=False)
    assert p.eps == pytest.approx(768 / 0.03, rel=1e-6)
    assert 100 <= p.kprime <= 300
    assert p.path in ("direct", "ot")


def test_plan_requires_exactly_one_knob():
    with pytest.raises(ValueError):
        planner.plan(n=384, N=1000, k=5)
    with pytest.raises(ValueError):
        planner.plan(n=384, N=1000, k=5, eps=1e4, radius=0.05)


def test_kprime_monotone_in_privacy():
    # Smaller eps (more privacy) => larger search range.
    kps = [
        planner.plan(n=384, N=10_000, k=5, eps=e).kprime
        for e in (50 * 384.0, 20 * 384.0, 10 * 384.0)
    ]
    assert kps == sorted(kps)


def test_eps_for_kprime_roundtrip():
    target = 160
    eps = planner.eps_for_kprime(n=768, N=100_000, k=5, kprime=target)
    p = planner.plan(n=768, N=100_000, k=5, eps=eps)
    assert abs(p.kprime - target) / target < 0.25


def test_ot_decision_matches_theorem3():
    # direct when budget loose, OT when tight
    loose = planner.plan(n=384, N=10_000, k=5, eps=1e7)
    tight = planner.plan(n=384, N=10_000, k=5, eps=200.0)
    assert not loose.use_ot
    assert tight.use_ot


def test_plan_quantile_inflates_range():
    base = planner.plan(n=768, N=100_000, k=5, eps=25_600.0, radial_quantile=0.5)
    hi = planner.plan(n=768, N=100_000, k=5, eps=25_600.0, radial_quantile=0.9999)
    assert hi.kprime >= base.kprime
