"""Serving driver: the private RAG service end to end.

Builds a synthetic corpus + FlatIndex, spins up the micro-batching
`repro.serve` engine with a pool of tenant sessions, and serves a stream of
queries through the full protocol (Module 1 DistanceDP + range limitation,
Module 2a encrypted re-rank, Module 2b/2c retrieval), printing latency and
wire-size stats per request plus the per-tenant engine metrics.

`python -m repro.launch.serve --n-docs 20000 --requests 8 --backend rlwe`
`... --no-batch` runs the sequential one-query-at-a-time comparison path.
`... --replicas N` serves through the scale-out `ReplicaRouter` (N engine
replicas over contiguous corpus slices, scatter-gather top-k'; results
stay bit-identical to a single engine — docs/scale_out.md) and prints the
router summary instead of the single-engine one.
`... --trace-out trace.json` enables stage-level span tracing (repro.obs)
and writes a Chrome-trace timeline loadable at https://ui.perfetto.dev;
the summary then carries per-stage latency histograms.

Admission control (off unless one of these is set): `--tenant-rate R`
installs per-tenant token buckets, `--max-queue N` bounds the global
queue with priority displacement, `--deadline-ms MS` applies a default
SLO budget with deadline-aware shedding, `--priority CLASS` picks the
default class.  Typed rejections (`RateLimited`, `QueueFull`, ...) and
shed results are printed per request — the submit loop never dies on
backpressure.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

from repro.crypto import backend as crypto_backend
from repro.data import synth
from repro.retrieval.index import FlatIndex, IvfConfig
from repro.retrieval.topk import plan_nprobe
from repro.serve import (AdmissionConfig, AdmissionError, EngineConfig,
                         RateLimited, ReplicaRouter, RouterConfig,
                         ServeEngine)
from repro.serve.admission import PRIORITIES
from repro.serve.session import PlanCache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-docs", type=int, default=20_000)
    ap.add_argument("--dim", type=int, default=384)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--radius", type=float, default=0.05)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--backend", choices=crypto_backend.available(),
                    default="rlwe")
    ap.add_argument("--corpus", choices=("uniform", "clustered"),
                    default="uniform")
    ap.add_argument("--ivf-clusters", type=int, default=None, metavar="C",
                    help="build the index with C-cluster IVF first-stage "
                         "routing (k-means at build, cluster-aligned row "
                         "layout; docs/corpus.md); replica slices then "
                         "land on cluster boundaries")
    ap.add_argument("--nprobe", default=None, metavar="N|auto",
                    help="clusters scanned per query (needs "
                         "--ivf-clusters): an integer, or 'auto' for the "
                         "planner-derived Theorem-1 bound "
                         "(plan_nprobe on the session plan's k'); N >= C "
                         "is bit-identical to the flat scan")
    ap.add_argument("--ingest", type=int, default=None, metavar="D",
                    help="after the first wave, ingest D new docs (tail-"
                         "shard append, epoch advance), refresh/replan, "
                         "and serve the stream again at the new epoch")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=20.0)
    ap.add_argument("--no-batch", action="store_true",
                    help="sequential comparison path (one query per step)")
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="N > 1 serves through a ReplicaRouter: N engine "
                         "replicas over contiguous corpus slices with "
                         "scatter-gather top-k' (bit-identical to N=1); "
                         "prints the router summary (docs/scale_out.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable stage tracing and write a Perfetto-"
                         "loadable Chrome-trace JSON timeline to PATH")
    ap.add_argument("--tenant-rate", type=float, default=None, metavar="R",
                    help="per-tenant token-bucket rate limit in "
                         "requests/s (enables the admission tier; "
                         "rejections surface as rate_limited drops)")
    ap.add_argument("--max-queue", type=int, default=None, metavar="N",
                    help="bound the global request queue at N; a full "
                         "queue displaces lower-priority work or rejects "
                         "the submit (queue_full drops, counted)")
    ap.add_argument("--deadline-ms", type=float, default=None, metavar="MS",
                    help="default per-request SLO budget; requests whose "
                         "remaining budget cannot cover the observed "
                         "dispatch latency are shed before any crypto")
    ap.add_argument("--priority", choices=PRIORITIES, default=None,
                    help="default admission priority class (interactive "
                         "degrades last under overload)")
    args = ap.parse_args()
    if args.tenants < 1 or args.requests < 1:
        ap.error("--tenants and --requests must be >= 1")

    rng = np.random.default_rng(0)
    gen = (synth.uniform_corpus if args.corpus == "uniform"
           else synth.clustered_corpus)
    emb = gen(rng, args.n_docs, args.dim)
    docs = synth.passages(rng, args.n_docs, avg_bytes=256)
    ivf = None
    if args.ivf_clusters is not None:
        if args.ivf_clusters < 1:
            ap.error("--ivf-clusters must be >= 1")
        ivf = IvfConfig(num_clusters=args.ivf_clusters)
    elif args.nprobe is not None:
        ap.error("--nprobe needs --ivf-clusters")
    index = FlatIndex.build(emb, documents=docs, ivf=ivf)
    # IVF builds permute rows into cluster-contiguous order, so result
    # ids live in the index's row space — score recall against that.
    emb = np.asarray(index.embeddings)

    nprobe = None
    if args.nprobe is not None:
        if args.nprobe == "auto":
            # the Theorem-1 probe bound for this session shape: enough
            # clusters that the planned k'-row search range is covered
            plan = PlanCache().get(n=args.dim, N=args.n_docs, k=args.k,
                                   radius=args.radius)
            nprobe = plan_nprobe(index.cluster_map, plan.kprime)
        else:
            nprobe = int(args.nprobe)
    if ivf is not None:
        print(json.dumps({"ivf": {
            "clusters": index.cluster_map.num_clusters,
            "nprobe": nprobe if nprobe is not None else "all"}}))

    admission = None
    if (args.tenant_rate is not None or args.max_queue is not None
            or args.deadline_ms is not None or args.priority is not None):
        admission = AdmissionConfig(
            tenant_rate=args.tenant_rate,
            max_queue=args.max_queue,
            default_deadline_s=(None if args.deadline_ms is None
                                else args.deadline_ms / 1e3),
            default_priority=args.priority or "interactive")

    if args.replicas < 1:
        ap.error("--replicas must be >= 1")
    if args.replicas > 1 and args.no_batch:
        ap.error("--replicas > 1 is the batched path; drop --no-batch")
    ecfg = EngineConfig(
        max_batch=1 if args.no_batch else args.max_batch,
        max_wait_s=args.max_wait_ms / 1e3,
        sequential=args.no_batch,
        trace=args.trace_out is not None,
        admission=admission,
        nprobe=nprobe)
    # context manager: close() drains leftovers and stops the sharded
    # cache's background admitter thread on exit (no thread leak across
    # engine lifetimes); the router additionally stops its per-replica
    # worker pools
    service = (ReplicaRouter(index, config=RouterConfig(
                   num_replicas=args.replicas, engine=ecfg))
               if args.replicas > 1 else
               ServeEngine(index, config=ecfg))
    with service as engine:
        for t in range(args.tenants):
            sess = engine.open_session(f"tenant-{t}", n=args.dim,
                                       N=args.n_docs, k=args.k,
                                       radius=args.radius,
                                       backend=args.backend)
        plan = sess.plan
        print(json.dumps({"plan": {
            "eps": plan.eps, "kprime": plan.kprime, "path": plan.path,
            "radius": plan.radius,
            "plan_cache": {"hits": engine.sessions.plan_cache.hits,
                           "misses": engine.sessions.plan_cache.misses}}}))

        queries = synth.queries_near_corpus(rng, emb, args.requests)
        t0 = time.monotonic()
        rejected = 0
        rid_to_query = {}
        for i, q in enumerate(queries):
            tenant = f"tenant-{i % args.tenants}"
            # typed backpressure: a rejected submit is reported and the
            # loop continues — the client never dies on overload
            try:
                rid = engine.submit(tenant, q, key=jax.random.PRNGKey(i))
            except AdmissionError as e:
                rejected += 1
                rec = {"request": None, "tenant": tenant,
                       "rejected": type(e).__name__}
                if isinstance(e, RateLimited):
                    rec["retry_after_s"] = round(e.retry_after_s, 3)
                print(json.dumps(rec))
                continue
            rid_to_query[rid] = q
        results = engine.drain()
        wall = time.monotonic() - t0

        for res in results:
            if res.shed_reason is not None:  # admission-tier shed, no crypto
                print(json.dumps({
                    "request": res.request_id, "tenant": res.tenant,
                    "latency_s": round(res.latency_s, 3),
                    "shed": res.shed_reason}))
                continue
            if not res.ok:  # lane failed after its quarantine retry
                print(json.dumps({
                    "request": res.request_id, "tenant": res.tenant,
                    "latency_s": round(res.latency_s, 3),
                    "quarantined": res.quarantined,
                    "error": res.error}))
                continue
            q = rid_to_query[res.request_id]
            plain = np.argsort(-(emb @ q), kind="stable")[: args.k]
            recall = (len(set(res.ids.tolist()) & set(plain.tolist()))
                      / args.k)
            print(json.dumps({
                "request": res.request_id, "tenant": res.tenant,
                "latency_s": round(res.latency_s, 3),
                "batch_size": res.batch_size, "recall": recall,
                "wire_bytes": res.transcript.total_bytes,
                "path": res.transcript.path}))
        if args.replicas > 1:
            fleet = engine.summary()
            fleet["router"]["qps"] = round(len(results) / wall, 3)
            print(json.dumps(fleet))
        else:
            summary = engine.metrics.summary()
            summary["aggregate"]["qps"] = round(len(results) / wall, 3)
            occupancy = engine.metrics.occupancy(engine.config.max_batch)
            out = {"summary": summary["aggregate"],
                   "num_batches": summary["num_batches"],
                   "occupancy": None if occupancy is None
                   else round(occupancy, 3)}
            if "failures" in summary:
                out["failures"] = summary["failures"]
            if "admission" in summary:
                out["admission"] = dict(summary["admission"],
                                        rejected_submits=rejected)
            if "trace" in summary:
                out["stages"] = summary["trace"]["stages"]
            print(json.dumps(out))
        if args.ingest is not None and args.ingest >= 1:
            # streaming ingestion: tail-shard append + epoch advance while
            # the service stays up, then the same stream at the new epoch
            rng2 = np.random.default_rng(1)
            new_emb = gen(rng2, args.ingest, args.dim)
            new_docs = synth.passages(rng2, args.ingest, avg_bytes=256)
            t0 = time.monotonic()
            view = index.ingest(new_emb, documents=new_docs)
            spans = (engine.replan() if args.replicas > 1
                     else (engine.refresh_corpus() and None))
            ingest_ms = (time.monotonic() - t0) * 1e3
            print(json.dumps({"ingest": {
                "docs": args.ingest, "epoch": view.epoch,
                "num_rows": index.num_rows,
                "ingest_ms": round(ingest_ms, 1),
                "replanned_slices": spans}}))
            grown = np.asarray(index.embeddings)
            for t in range(args.tenants):   # re-plan sessions for the
                engine.open_session(        # grown corpus + new epoch
                    f"tenant-{t}@e{view.epoch}", n=args.dim,
                    N=index.num_rows, k=args.k, radius=args.radius,
                    backend=args.backend)
            rid_to_query = {}
            for i, q in enumerate(queries):
                rid = engine.submit(
                    f"tenant-{i % args.tenants}@e{view.epoch}", q,
                    key=jax.random.PRNGKey(10_000 + i))
                rid_to_query[rid] = q
            for res in engine.drain():
                if not res.ok:
                    print(json.dumps({
                        "request": res.request_id, "tenant": res.tenant,
                        "epoch": view.epoch, "error": res.error}))
                    continue
                q = rid_to_query[res.request_id]
                plain = np.argsort(-(grown @ q), kind="stable")[: args.k]
                recall = (len(set(res.ids.tolist()) & set(plain.tolist()))
                          / args.k)
                print(json.dumps({
                    "request": res.request_id, "tenant": res.tenant,
                    "epoch": view.epoch,
                    "latency_s": round(res.latency_s, 3),
                    "recall": recall,
                    "wire_bytes": res.transcript.total_bytes}))
        if args.trace_out is not None:
            n_events = engine.write_trace(args.trace_out)
            print(json.dumps({"trace_out": args.trace_out,
                              "trace_events": n_events,
                              "view": "https://ui.perfetto.dev"}))


if __name__ == "__main__":
    main()
