"""Serving-engine benchmark: batched vs sequential QPS and latency.

    PYTHONPATH=src python -m benchmarks.serve_bench

Builds one synthetic corpus, opens a pool of tenant sessions, then pushes the
same request stream through (a) the sequential one-query-per-step path and
(b) the micro-batching engine at several batch sizes.  Reports throughput
(QPS), p50/p99 enqueue-to-result latency, and mean wire KB per request, and
checks the two paths return identical per-query results (ids + wire bytes).

Default sizes finish in a few minutes on CPU; REPRO_BENCH_FULL=1 scales the
corpus and request count toward the paper's 10^6-document setting.

Beyond the CSV rows this writes machine-readable ``BENCH_serve.json``
(path override: BENCH_SERVE_JSON); ``scripts/check_bench_regression.py
--serve-json`` gates batch-8 occupancy and the batched-vs-sequential QPS
ratio on it.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

import jax

from benchmarks.common import FULL, emit
from repro.crypto import rlwe
from repro.data import synth
from repro.retrieval.index import FlatIndex
from repro.serve import EngineConfig, ServeEngine

N_DOCS = 200_000 if FULL else 20_000
DIM = 384 if FULL else 128
N_REQUESTS = 64 if FULL else 16
N_TENANTS = 8
K = 5
RADIUS = 0.05
BATCH_SIZES = (1, 4, 8)
# CPU-friendly ring: the serving hot loop is NTT-bound, and n_poly=1024
# still fits DIM-dim queries in one chunk (identical protocol semantics).
RLWE_PARAMS = rlwe.RlweParams(n_poly=1024, chunk=512)

OUT_PATH = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")


def build_engine(index, *, sequential: bool, max_batch: int) -> ServeEngine:
    from repro.serve.session import SessionManager

    # deterministic seeds: the sequential and batched engines must replay
    # identical tenant key/noise streams for the per-query parity check
    engine = ServeEngine(
        index,
        config=EngineConfig(max_batch=max_batch, sequential=sequential),
        sessions=SessionManager(rlwe_params=RLWE_PARAMS,
                                deterministic_seeds=True))
    for t in range(N_TENANTS):
        engine.open_session(f"tenant-{t}", n=DIM, N=N_DOCS, k=K,
                            radius=RADIUS, backend="rlwe")
    return engine


def run_stream(engine: ServeEngine, queries, *, warmup: bool = True) -> tuple:
    """Push the stream through once untimed (jit warmup for this engine's
    batch shapes), then measure the steady-state pass."""
    from repro.serve.metrics import ServeMetrics

    if warmup:
        for i, q in enumerate(queries):
            engine.submit(f"tenant-{i % N_TENANTS}", q,
                          key=jax.random.PRNGKey(i))
        engine.drain()
        engine.metrics = ServeMetrics()
    t0 = time.monotonic()
    for i, q in enumerate(queries):
        engine.submit(f"tenant-{i % N_TENANTS}", q,
                      key=jax.random.PRNGKey(i))
    results = engine.drain()
    wall = time.monotonic() - t0
    return results, wall


def main() -> None:
    rng = np.random.default_rng(0)
    emb = synth.uniform_corpus(rng, N_DOCS, DIM)
    docs = synth.passages(rng, N_DOCS, avg_bytes=256)
    index = FlatIndex.build(emb, documents=docs)
    queries = synth.queries_near_corpus(rng, emb, N_REQUESTS)

    print(f"# serve_bench: {N_DOCS} docs x dim {DIM}, {N_REQUESTS} requests "
          f"from {N_TENANTS} tenants, k={K}")

    seq_engine = build_engine(index, sequential=True, max_batch=1)
    seq_results, seq_wall = run_stream(seq_engine, queries)
    seq_qps = len(seq_results) / seq_wall
    agg = seq_engine.metrics.aggregate
    emit("serve_sequential", seq_wall / len(seq_results) * 1e6,
         f"qps={seq_qps:.3f} p50={agg.percentile(50):.3f}s "
         f"p99={agg.percentile(99):.3f}s "
         f"wire_kb={agg.total_wire_bytes / agg.count / 1024:.1f}")
    results_json = {"sequential": {
        "qps": seq_qps,
        "p50_s": agg.percentile(50),
        "p99_s": agg.percentile(99),
        "wire_kb_per_request": agg.total_wire_bytes / agg.count / 1024,
    }}

    qps_by_bs = {}
    for bs in BATCH_SIZES:
        engine = build_engine(index, sequential=False, max_batch=bs)
        results, wall = run_stream(engine, queries)
        qps = len(results) / wall
        qps_by_bs[bs] = qps
        agg = engine.metrics.aggregate
        occ = engine.metrics.occupancy(bs)
        emit(f"serve_batched_b{bs}", wall / len(results) * 1e6,
             f"qps={qps:.3f} p50={agg.percentile(50):.3f}s "
             f"p99={agg.percentile(99):.3f}s "
             f"speedup={qps / seq_qps:.2f}x "
             f"occupancy={occ:.2f}")
        # the clean stream must not trip the fault-isolation machinery
        assert engine.metrics.quarantined_lanes == 0
        assert engine.metrics.error_results == 0
        assert engine.metrics.healthy_reencryptions == 0
        # per-query parity with the sequential path
        for rs, rb in zip(seq_results, results):
            assert rs.ids.tolist() == rb.ids.tolist(), (
                f"id mismatch at batch {bs}: {rs.ids} vs {rb.ids}")
            assert rs.docs == rb.docs
            assert rs.transcript.total_bytes == rb.transcript.total_bytes, (
                f"wire mismatch at batch {bs}")
        results_json[f"batch{bs}"] = {
            "qps": qps,
            "p50_s": agg.percentile(50),
            "p99_s": agg.percentile(99),
            "speedup_vs_sequential": qps / seq_qps,
            "occupancy": occ,
            "num_batches": engine.metrics.num_batches,
            "refill_dispatches": engine.metrics.refill_dispatches,
        }

    big = max(bs for bs in BATCH_SIZES if bs >= 8)
    print(f"# batched (b={big}) {qps_by_bs[big]:.3f} qps vs sequential "
          f"{seq_qps:.3f} qps ({qps_by_bs[big] / seq_qps:.2f}x)")
    assert qps_by_bs[big] > seq_qps, \
        "batched throughput at batch >= 8 must beat sequential"
    results_json["parity_checked"] = True
    results_json["big_batch"] = big

    payload = {
        "bench": "serve",
        "backend": jax.default_backend(),
        "config": {"num_docs": N_DOCS, "dim": DIM,
                   "requests": N_REQUESTS, "tenants": N_TENANTS, "k": K,
                   "batch_sizes": list(BATCH_SIZES),
                   "n_poly": RLWE_PARAMS.n_poly,
                   "chunk": RLWE_PARAMS.chunk, "full": FULL},
        "results": results_json,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH}", flush=True)


if __name__ == "__main__":
    main()
