"""Device-sharded flat corpus index with an epoch-versioned corpus core.

The cloud's N document embeddings are row-sharded across every axis of the
mesh (the paper's single-host vector DB, scaled out).  Each device owns a
contiguous row range; global ids are shard_offset + local id.  Documents
themselves (bytes) stay host-side, keyed by global id.

The corpus is no longer static: `FlatIndex.ingest` appends documents under
a monotonically increasing *epoch* counter, and every reader pins a
`CorpusView` — an immutable (epoch, rows) snapshot — so a fixed-epoch
replay is bit-identical while a background writer appends (appends never
mutate existing rows; see docs/corpus.md for the full contract).

Optional IVF first stage: `IvfConfig` runs a balanced spherical k-means at
build time and *permutes* the corpus so each cluster occupies one
contiguous row range, aligned (via ``align``) to candidate-cache shard
boundaries — cluster routing then doubles as cache-shard prediction, and
scanning all clusters reduces bit-identically to the flat scan (the same
per-slice scan + (score desc, global id asc) merge the replica router
pins).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class IndexSlice:
    """A contiguous row-range view of a `FlatIndex` — the unit of replica
    placement in the scale-out serving tier (`repro.serve.router`).

    ``embeddings`` holds rows ``[start, stop)`` of the parent index;
    global ids are ``start + local id``, so a slice's search results drop
    straight into the parent's id space.  Slices are views for placement
    and search only — documents and candidate caches stay with the parent
    index (the re-rank and fetch stages address them by global id)."""

    embeddings: jax.Array          # (stop - start, n) parent rows
    start: int
    stop: int

    @property
    def num_rows(self) -> int:
        return self.stop - self.start

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]


def plan_row_slices(num_rows: int, num_slices: int, *,
                    align: int = 1) -> list:
    """Contiguous near-equal ``(start, stop)`` row ranges covering
    ``[0, num_rows)``.

    ``align`` snaps interior boundaries to multiples of itself (pass the
    candidate cache's shard size so replica slices and cache shards share
    boundaries — one doc range is then exactly one placement unit for
    both).  Raises if ``num_rows`` cannot be cut into ``num_slices``
    nonempty aligned ranges."""
    if num_slices < 1:
        raise ValueError(f"num_slices must be >= 1, got {num_slices}")
    if align < 1:
        raise ValueError(f"align must be >= 1, got {align}")
    if num_slices > num_rows:
        raise ValueError(f"cannot cut {num_rows} rows into {num_slices} "
                         f"nonempty slices")
    bounds = [0]
    for r in range(1, num_slices):
        cut = round(num_rows * r / num_slices / align) * align
        cut = max(cut, bounds[-1] + align)      # keep every slice nonempty
        bounds.append(cut)
    bounds.append(num_rows)
    if any(b >= e for b, e in zip(bounds[:-1], bounds[1:])):
        raise ValueError(
            f"align={align} cannot cut {num_rows} rows into {num_slices} "
            f"nonempty aligned slices")
    return list(zip(bounds[:-1], bounds[1:]))


@dataclasses.dataclass(frozen=True)
class ClusterMap:
    """IVF cluster layout over a row-permuted corpus.

    Cluster ``c`` owns the contiguous global-id range
    ``[starts[c], stops[c])`` — the permutation happens once at index build
    (`IvfConfig`), so the map is pure metadata: centroids for routing plus
    the range table.  Ranges are aligned to candidate-cache shard
    boundaries when the build passed ``align=shard_docs``, making cluster
    routing a cache-shard predictor.  Tail clusters appended by `ingest`
    extend the table without touching earlier entries."""

    centroids: np.ndarray          # (C, n) float32 unit rows
    starts: np.ndarray             # (C,) int64 first global id per cluster
    stops: np.ndarray              # (C,) int64 one-past-last global id

    @property
    def num_clusters(self) -> int:
        return int(self.starts.shape[0])

    @property
    def sizes(self) -> np.ndarray:
        return self.stops - self.starts

    def route(self, queries: np.ndarray, nprobe: int) -> np.ndarray:
        """Top-``nprobe`` clusters per query by centroid score, tie-broken
        (score desc, cluster id asc) — the same deterministic order every
        merge in the repo uses."""
        q = np.asarray(queries, np.float32)
        scores = q @ self.centroids.T.astype(np.float32)      # (B, C)
        order = np.lexsort(
            (np.broadcast_to(np.arange(scores.shape[1]), scores.shape),
             -scores), axis=1)
        return order[:, :nprobe]

    def appended(self, centroid: np.ndarray, start: int,
                 stop: int) -> "ClusterMap":
        """A new map with one tail cluster ``[start, stop)`` added."""
        return ClusterMap(
            centroids=np.concatenate([self.centroids,
                                      centroid[None].astype(np.float32)]),
            starts=np.append(self.starts, start),
            stops=np.append(self.stops, stop))


@dataclasses.dataclass(frozen=True)
class IvfConfig:
    """Build-time IVF clustering knobs (see `FlatIndex.build`).

    ``align`` snaps cluster boundaries to multiples of itself — pass the
    candidate cache's ``shard_docs`` so clusters and cache shards share
    boundaries 1:1 and cluster routing doubles as shard prediction."""

    num_clusters: int
    iters: int = 8
    seed: int = 0
    align: int = 1


def _kmeans_cluster_map(emb: np.ndarray, cfg: IvfConfig):
    """Balanced spherical k-means -> (row permutation, ClusterMap).

    Capacity per cluster comes from `plan_row_slices` (near-equal, aligned
    ranges), so the permuted layout is exactly the shard/replica placement
    geometry.  Assignment is deterministic greedy: docs in decreasing
    best-score order each take their most-preferred cluster with capacity
    left.  Centroids are recomputed from the final membership."""
    num_rows, _ = emb.shape
    c_num = cfg.num_clusters
    if not (1 <= c_num <= num_rows):
        raise ValueError(
            f"num_clusters must be in [1, {num_rows}], got {c_num}")
    rng = np.random.default_rng(cfg.seed)
    # k-means++ (D^2) seeding: each next centroid is drawn proportional
    # to squared cosine distance from the chosen set.  Plain random-row
    # init routinely drops two seeds into one tight cluster and Lloyd
    # iterations never recover — the routed scan then splits true
    # clusters across slices and nprobe=1 recall collapses.
    centroids = np.empty((c_num, emb.shape[1]), np.float32)
    centroids[0] = emb[int(rng.integers(num_rows))]
    best = emb @ centroids[0]
    for c in range(1, c_num):
        d2 = np.maximum(1.0 - best, 0.0) ** 2
        tot = float(d2.sum())
        pick = (int(rng.choice(num_rows, p=d2 / tot)) if tot > 0
                else int(rng.integers(num_rows)))
        centroids[c] = emb[pick]
        best = np.maximum(best, emb @ centroids[c])
    for _ in range(max(0, cfg.iters)):
        assign = (emb @ centroids.T).argmax(axis=1)
        for c in range(c_num):
            members = emb[assign == c]
            if members.shape[0]:
                m = members.mean(axis=0)
                centroids[c] = m / max(np.linalg.norm(m), 1e-12)
    ranges = plan_row_slices(num_rows, c_num, align=cfg.align)
    caps = [stop - start for start, stop in ranges]
    scores = emb @ centroids.T
    pref = np.argsort(-scores, axis=1, kind="stable")
    groups: list = [[] for _ in range(c_num)]
    for d in np.argsort(-scores.max(axis=1), kind="stable"):
        for c in pref[d]:
            if len(groups[c]) < caps[c]:
                groups[c].append(int(d))
                break
    # original-id order within a cluster keeps the permutation stable
    groups = [sorted(g) for g in groups]
    perm = np.concatenate([np.asarray(g, np.int64) for g in groups])
    for c in range(c_num):
        m = emb[groups[c]].mean(axis=0)
        centroids[c] = m / max(np.linalg.norm(m), 1e-12)
    starts = np.asarray([r[0] for r in ranges], np.int64)
    stops = np.asarray([r[1] for r in ranges], np.int64)
    return perm, ClusterMap(centroids=centroids.astype(np.float32),
                            starts=starts, stops=stops)


@dataclasses.dataclass(frozen=True)
class CorpusView:
    """Immutable snapshot of the corpus at one epoch.

    Holds everything a reader needs to search without touching the live
    index again: the embedding rows visible at ``epoch``, the cluster map
    frozen at that epoch, and the mesh placement.  Because `ingest` only
    ever *appends* rows, a view's arrays are never mutated — replaying a
    pinned view is bit-identical no matter how far the live corpus has
    advanced (the serve layer's fixed-epoch replay contract)."""

    epoch: int
    embeddings: jax.Array          # (num_rows_at_epoch, n)
    mesh: Optional[Mesh] = None
    row_axes: Optional[tuple] = None
    cluster_map: Optional[ClusterMap] = None
    # per-cluster IndexSlice memo — identity state, not value state
    _slices: dict = dataclasses.field(default_factory=dict, repr=False,
                                      compare=False)

    @property
    def num_rows(self) -> int:
        return self.embeddings.shape[0]

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]

    def slice_view(self, start: int, stop: int) -> IndexSlice:
        """A contiguous row-range view of this snapshot (same contract as
        `FlatIndex.slice_view`, pinned at this view's epoch)."""
        if not (0 <= start < stop <= self.num_rows):
            raise ValueError(
                f"slice [{start}, {stop}) out of range for "
                f"{self.num_rows}-row view")
        return IndexSlice(embeddings=self.embeddings[start:stop],
                          start=start, stop=stop)

    def cluster_slice(self, c: int) -> IndexSlice:
        """The `IndexSlice` owned by cluster ``c`` (memoized — repeated
        routed scans of a hot cluster never re-slice)."""
        if self.cluster_map is None:
            raise ValueError("view has no cluster map (built without ivf=)")
        sl = self._slices.get(int(c))
        if sl is None:
            sl = self.slice_view(int(self.cluster_map.starts[c]),
                                 int(self.cluster_map.stops[c]))
            self._slices[int(c)] = sl
        return sl


@dataclasses.dataclass
class FlatIndex:
    """A flat (exact-search) embedding index, optionally mesh-sharded."""

    embeddings: jax.Array          # (N, n) unit-norm rows
    mesh: Optional[Mesh] = None
    row_axes: Optional[tuple] = None   # mesh axes the rows are sharded over
    documents: Optional[Sequence[bytes]] = None
    cluster_map: Optional[ClusterMap] = None   # IVF layout (build(ivf=...))
    # NTT-domain candidate caches, memoized per RlweParams value so every
    # RemoteRagCloud over this index shares one build (build-once/serve-many)
    _cand_caches: dict = dataclasses.field(default_factory=dict, repr=False,
                                           compare=False)
    # epoch-versioned corpus core: `ingest` appends rows under `_lock` and
    # bumps `_epoch`; `_epoch_rows[e]` is the row count visible at epoch e,
    # so `corpus_view(epoch=e)` can snapshot any past epoch (appends never
    # mutate earlier rows — old views stay bit-identical)
    _epoch: int = dataclasses.field(default=0, repr=False, compare=False)
    _epoch_rows: list = dataclasses.field(default=None, repr=False,
                                          compare=False)
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    def __post_init__(self):
        if self._epoch_rows is None:
            object.__setattr__(self, "_epoch_rows",
                               [self.embeddings.shape[0]])

    @property
    def num_rows(self) -> int:
        return self.embeddings.shape[0]

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]

    @property
    def epoch(self) -> int:
        """Current corpus epoch (0 at build; +1 per `ingest`)."""
        return self._epoch

    def corpus_view(self, epoch: Optional[int] = None) -> CorpusView:
        """Pin an immutable `CorpusView` snapshot at ``epoch`` (default:
        current).  Readers (engines, routers, benches) search the view, not
        the live index, so a concurrent `ingest` never changes what a
        pinned reader sees."""
        with self._lock:
            e = self._epoch if epoch is None else int(epoch)
            if not (0 <= e <= self._epoch):
                raise ValueError(
                    f"epoch {e} out of range [0, {self._epoch}]")
            rows = self._epoch_rows[e]
            cm = self.cluster_map
            if cm is not None and cm.stops.size and int(cm.stops[-1]) > rows:
                # drop tail clusters appended after the requested epoch
                keep = int(np.searchsorted(cm.stops, rows, side="right"))
                cm = ClusterMap(centroids=cm.centroids[:keep],
                                starts=cm.starts[:keep],
                                stops=cm.stops[:keep])
            return CorpusView(epoch=e, embeddings=self.embeddings[:rows],
                              mesh=self.mesh, row_axes=self.row_axes,
                              cluster_map=cm)

    @classmethod
    def build(cls, embeddings: np.ndarray, *, mesh: Optional[Mesh] = None,
              row_axes: Optional[tuple] = None,
              documents: Optional[Sequence[bytes]] = None,
              normalize: bool = True,
              ivf: Optional[IvfConfig] = None) -> "FlatIndex":
        emb = np.asarray(embeddings, np.float32)
        if normalize:
            emb = emb / np.linalg.norm(emb, axis=-1, keepdims=True)
        cluster_map = None
        if ivf is not None:
            if mesh is not None:
                raise ValueError("ivf clustering over a mesh-sharded index "
                                 "is not supported")
            perm, cluster_map = _kmeans_cluster_map(emb, ivf)
            emb = np.ascontiguousarray(emb[perm])
            if documents is not None:
                documents = [documents[int(i)] for i in perm]
        if mesh is not None:
            row_axes = row_axes or tuple(mesh.axis_names)
            n_shards = int(np.prod([mesh.shape[a] for a in row_axes]))
            pad = (-emb.shape[0]) % n_shards
            if pad:
                emb = np.concatenate([emb, np.zeros((pad, emb.shape[1]),
                                                    np.float32)])
            sharding = NamedSharding(mesh, P(row_axes, None))
            arr = jax.device_put(jnp.asarray(emb), sharding)
        else:
            arr = jnp.asarray(emb)
        if documents is not None:
            documents = list(documents)
        return cls(embeddings=arr, mesh=mesh, row_axes=row_axes,
                   documents=documents, cluster_map=cluster_map)

    def ingest(self, embeddings: np.ndarray,
               documents: Optional[Sequence[bytes]] = None, *,
               normalize: bool = True) -> CorpusView:
        """Append documents to the live corpus and advance the epoch.

        The new rows become a contiguous tail range of the id space; every
        memoized sharded candidate cache gets the new docs' NTT plaintexts
        packed into a *tail shard* published through its atomic admission
        path (`ShardedCandidateCache.ingest_tail`), dense caches are
        dropped for lazy rebuild, and — when the index was built with
        IVF — the tail range becomes a new cluster whose centroid is the
        mean of the ingested rows.  Pinned `CorpusView`s from earlier
        epochs are untouched: appends never mutate existing rows, shards,
        or cluster ranges.  Returns the post-ingest view."""
        from repro.crypto import rlwe

        if self.mesh is not None:
            raise ValueError("streaming ingestion requires an unsharded "
                             "index (mesh=None)")
        emb = np.asarray(embeddings, np.float32)
        if emb.ndim != 2 or emb.shape[1] != self.dim:
            raise ValueError(
                f"ingest embeddings must be (m, {self.dim}), got "
                f"{emb.shape}")
        if emb.shape[0] == 0:
            return self.corpus_view()
        if normalize:
            emb = emb / np.linalg.norm(emb, axis=-1, keepdims=True)
        # pack the tail shard for every live params value OUTSIDE the lock
        # (the expensive pack + forward NTT), like the cache admitter
        # stages its copy off-lock before the atomic swap
        packed: dict = {}
        for (pk, cfg), cache in list(self._cand_caches.items()):
            if cfg is not None and pk not in packed:
                packed[pk] = rlwe._pack_corpus_ntt(cache.params, emb)
        with self._lock:
            old_rows = self.num_rows
            new_rows = old_rows + emb.shape[0]
            epoch = self._epoch + 1
            for key, cache in list(self._cand_caches.items()):
                pk, cfg = key
                if cfg is None:
                    # dense caches rebuild lazily from the grown corpus
                    del self._cand_caches[key]
                else:
                    cache.ingest_tail(packed[pk], epoch=epoch)
            self.embeddings = jnp.concatenate(
                [self.embeddings, jnp.asarray(emb)])
            if documents is not None:
                if self.documents is None:
                    raise ValueError("index was built without documents")
                self.documents.extend(documents)
            if self.cluster_map is not None:
                m = emb.mean(axis=0)
                self.cluster_map = self.cluster_map.appended(
                    m / max(np.linalg.norm(m), 1e-12), old_rows, new_rows)
            self._epoch = epoch
            self._epoch_rows.append(new_rows)
        return self.corpus_view()

    def fetch_documents(self, ids: Sequence[int]):
        assert self.documents is not None, "index built without documents"
        return [self.documents[int(i)] for i in ids]

    def rows(self, ids) -> jax.Array:
        """Gather embedding rows by global id (host-driven, small batches)."""
        return jnp.take(self.embeddings, jnp.asarray(ids), axis=0)

    def candidate_cache(self, rlwe_params, config=None):
        """NTT-domain candidate cache for this index under ``rlwe_params``
        (see crypto.rlwe): every document's reversed-chunk plaintext
        forward-NTT'd once, so the encrypted re-rank never re-packs or
        re-NTTs candidates per request.  Built on first use and memoized per
        (RlweParams *value*, config) pair.

        ``config=None`` builds the dense `rlwe.CandidateCache` (the whole
        pool device-resident: 4 * P * N bytes per chunk per row — fine up to
        a few thousand documents).  Passing an `rlwe.CandidateCacheConfig`
        builds the corpus-scale `rlwe.ShardedCandidateCache` instead: shard
        assignment happens here at index-build time (contiguous global-id
        ranges, same layout as the mesh row sharding of ``embeddings``), and
        when the index is mesh-sharded the pinned hot shards inherit a
        row sharding over the same mesh axes (documents per shard must
        divide evenly over the mesh row shards; otherwise shards stay
        unsharded on device).  The config also carries the shard admission
        policy (async background admitter, 2nd-touch frequency threshold —
        see the `rlwe.CandidateCacheConfig` docstring); configs differing
        only in policy share one packed pool but keep separate resident
        sets, since the whole config is part of the memoization key."""
        from repro.crypto import rlwe

        pk = rlwe.params_key(rlwe_params)
        key = (pk, config)
        cache = self._cand_caches.get(key)
        if cache is None:
            # the packed pool (corpus pack + forward NTT) depends only on
            # the params value: any existing cache for pk donates its pool
            # and the new config is just a re-view, not a re-build
            donor = next((c for (p, _), c in self._cand_caches.items()
                          if p == pk), None)
            if config is None:
                cache = (rlwe.densify_candidate_cache(donor)
                         if donor is not None else
                         rlwe.build_candidate_cache(
                             rlwe_params, np.asarray(self.embeddings)))
            else:
                sharding = self._shard_sharding(rlwe_params, config)
                cache = (rlwe.shard_candidate_cache(donor, config, sharding)
                         if donor is not None else
                         rlwe.build_sharded_candidate_cache(
                             rlwe_params, np.asarray(self.embeddings),
                             config=config, sharding=sharding))
            self._cand_caches[key] = cache
        return cache

    def peek_candidate_cache(self, rlwe_params, config=None):
        """The memoized cache for (params value, config) if already built,
        else None — never triggers a build (stats/observability paths)."""
        from repro.crypto import rlwe

        return self._cand_caches.get((rlwe.params_key(rlwe_params), config))

    def slice_view(self, start: int, stop: int) -> IndexSlice:
        """A contiguous row-range view ``[start, stop)`` of this index (the
        replica placement unit — see `IndexSlice`).  The slice materializes
        its rows once here; repeated searches over it never re-gather."""
        if not (0 <= start < stop <= self.num_rows):
            raise ValueError(
                f"slice [{start}, {stop}) out of range for "
                f"{self.num_rows}-row index")
        return IndexSlice(embeddings=self.embeddings[start:stop],
                          start=start, stop=stop)

    def _shard_sharding(self, rlwe_params, config):
        """NamedSharding for a pinned cache shard (doc axis over the mesh
        row axes), or None when the index is unsharded / indivisible."""
        if self.mesh is None:
            return None
        shard_docs = config.resolve_shard_docs(self.num_rows)
        n_shards = int(np.prod([self.mesh.shape[a] for a in self.row_axes]))
        if shard_docs % n_shards or self.num_rows % shard_docs:
            return None
        return NamedSharding(self.mesh, P(self.row_axes, None, None, None))


__all__ = ["ClusterMap", "CorpusView", "FlatIndex", "IndexSlice",
           "IvfConfig", "plan_row_slices"]
