"""k-out-of-k' oblivious transfer (paper Appendix A.1, Chou-Orlandi style).

The cloud (sender) holds k' documents; the user (receiver) wants the k at
indices S without revealing S.  Group: 2048-bit MODP group (RFC 3526 group
14); hash: SHA-256; symmetric cipher: SHA-256-keyed XOR keystream.

    cloud:  a random,  A = g^a mod p                         -> user
    user:   B_i = A^{c_i} * g^{b_i},  c_i = 0 iff i in S     -> cloud
    cloud:  Key_i = H(B_i^a),  sends Enc(m_i, Key_i)         -> user
    user:   Key_{s_j} = H(A^{b_{s_j}}) decrypts the selected k

For i in S:   B_i^a = g^{a b_i}   = A^{b_i}          -> keys agree.
For i not in S: B_i^a = g^{a(a + b_i)} != g^{a b_i}  -> key mismatch.
"""

from __future__ import annotations

import dataclasses
import hashlib
import secrets
from typing import List, Sequence

# RFC 3526, 2048-bit MODP group 14.
MODP_2048_P = int(
    "FFFFFFFFFFFFFFFFC90FDAA22168C234C4C6628B80DC1CD129024E088A67CC74"
    "020BBEA63B139B22514A08798E3404DDEF9519B3CD3A431B302B0A6DF25F1437"
    "4FE1356D6D51C245E485B576625E7EC6F44C42E9A637ED6B0BFF5CB6F406B7ED"
    "EE386BFB5A899FA5AE9F24117C4B1FE649286651ECE45B3DC2007CB8A163BF05"
    "98DA48361C55D39A69163FA8FD24CF5F83655D23DCA3AD961C62F356208552BB"
    "9ED529077096966D670C354E4ABC9804F1746C08CA18217C32905E462E36CE3B"
    "E39E772C180E86039B2783A2EC07A28FB5C55DF06F4C52C9DE2BCBF695581718"
    "3995497CEA956AE515D2261898FA051015728E5A8AACAA68FFFFFFFFFFFFFFFF",
    16,
)
MODP_G = 2


def _hash_key(x: int) -> bytes:
    return hashlib.sha256(x.to_bytes((x.bit_length() + 7) // 8 or 1, "big")).digest()


def _keystream(key: bytes, nonce: int, length: int) -> bytes:
    out = b""
    counter = 0
    while len(out) < length:
        out += hashlib.sha256(key + nonce.to_bytes(8, "big")
                              + counter.to_bytes(8, "big")).digest()
        counter += 1
    return out[:length]


def _xor(data: bytes, stream: bytes) -> bytes:
    return bytes(a ^ b for a, b in zip(data, stream))


@dataclasses.dataclass
class OtSender:
    """Cloud side."""
    messages: List[bytes]
    p: int = MODP_2048_P
    g: int = MODP_G

    def round1(self) -> int:
        self._a = secrets.randbelow(self.p - 2) + 1
        self.A = pow(self.g, self._a, self.p)
        return self.A

    def round2(self, bs: Sequence[int]) -> List[bytes]:
        """Receive B_i, return all k' messages encrypted under Key_i."""
        assert len(bs) == len(self.messages)
        out = []
        for i, (b_i, m) in enumerate(zip(bs, self.messages)):
            key = _hash_key(pow(b_i, self._a, self.p))
            out.append(_xor(m, _keystream(key, i, len(m))))
        return out

    def bytes_sent(self, encrypted: List[bytes]) -> int:
        return (self.p.bit_length() + 7) // 8 + sum(len(e) for e in encrypted)


@dataclasses.dataclass
class OtReceiver:
    """User side."""
    selected: Sequence[int]   # indices S, |S| = k
    total: int                # k'
    p: int = MODP_2048_P
    g: int = MODP_G

    def round1(self, A: int) -> List[int]:
        self._A = A
        self._bs = []
        out = []
        sel = set(self.selected)
        for i in range(self.total):
            b_i = secrets.randbelow(self.p - 2) + 1
            self._bs.append(b_i)
            c_i = 0 if i in sel else 1
            out.append(pow(A, c_i, self.p) * pow(self.g, b_i, self.p) % self.p)
        return out

    def round2(self, encrypted: List[bytes]) -> List[bytes]:
        """Decrypt exactly the selected messages (order of ``selected``)."""
        out = []
        for s in self.selected:
            key = _hash_key(pow(self._A, self._bs[s], self.p))
            out.append(_xor(encrypted[s], _keystream(key, s, len(encrypted[s]))))
        return out


def run_ot(messages: List[bytes], selected: Sequence[int]) -> tuple:
    """Execute the protocol; returns (plaintexts for user, wire bytes)."""
    sender = OtSender(messages=messages)
    receiver = OtReceiver(selected=selected, total=len(messages))
    A = sender.round1()
    bs = receiver.round1(A)
    enc = sender.round2(bs)
    got = receiver.round2(enc)
    group_bytes = (sender.p.bit_length() + 7) // 8
    wire = group_bytes * (1 + len(bs)) + sum(len(e) for e in enc)
    return got, wire


__all__ = ["OtSender", "OtReceiver", "run_ot", "MODP_2048_P", "MODP_G"]
