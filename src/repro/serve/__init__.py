"""Batched multi-tenant serving engine for the RemoteRAG protocol.

Layers (bottom up):

  batching.py   stacked-batch primitives: vmapped DistanceDP perturbation,
                batched score-top-k' over the shared index, and batched RLWE
                score encryption / decryption (one NTT dispatch per prime for
                the whole batch, per-tenant secret keys).
  session.py    per-tenant state: keys, protocol plan (via a PlanCache keyed
                on the planning knobs so repeat tenants skip Theorem-1 work).
  admission.py  SLO-aware admission tier: typed submit rejections
                (AdmissionError hierarchy), per-tenant token buckets,
                priority-classed queues, deadline-aware shedding fed by the
                observed per-group dispatch latency.
  engine.py     micro-batching request engine: size/deadline triggers form
                per-step batches grouped by (backend, n, k'); each step runs
                the full protocol for the batch.
  metrics.py    per-tenant latency percentiles + wire-byte accounting built
                on Request.nbytes / Reply.nbytes.
  router.py     scale-out tier: `ReplicaRouter` over N slice-owning engine
                replicas — tenant-hash placement, scatter-gather top-k'
                with a deterministic merge, per-replica admitters, and
                replica quarantine with ledger-backed zero-lost results.

The batched path is bit-compatible with the one-query `run_remoterag` driver:
identical docs, ids and wire bytes at any batch size (tests/test_serve.py);
the router is bit-compatible with a single whole-corpus engine at any
replica count (tests/test_router.py).
"""

from repro.serve.admission import (
    PRIORITIES,
    AdmissionConfig,
    AdmissionController,
    AdmissionError,
    InvalidEmbedding,
    QueueFull,
    RateLimited,
    UnknownTenant,
)
from repro.serve.batching import CandidateCacheConfig, ShardedCandidateCache
from repro.serve.engine import EngineConfig, ServeEngine, ServeResult
from repro.serve.metrics import ServeMetrics
from repro.serve.router import (
    ReplicaRouter,
    ReplicaUnavailable,
    RouterConfig,
    RouterMetrics,
    merge_topk,
)
from repro.serve.session import PlanCache, Session, SessionManager

__all__ = [
    "EngineConfig", "ServeEngine", "ServeResult", "ServeMetrics",
    "PlanCache", "Session", "SessionManager",
    "CandidateCacheConfig", "ShardedCandidateCache",
    "PRIORITIES", "AdmissionConfig", "AdmissionController",
    "AdmissionError", "UnknownTenant", "InvalidEmbedding", "QueueFull",
    "RateLimited",
    "ReplicaRouter", "RouterConfig", "RouterMetrics", "ReplicaUnavailable",
    "merge_topk",
]
